"""Property test (satellite of the telemetry PR): for ANY lossy +
Byzantine + replay fault configuration, replaying the JSONL event log
reconstructs the Network's live bandwidth counters exactly — including the
scalar-conservation identity ``sent == delivered + dropped + in_flight``.

Two layers:

* a fast Network-level property driving random send/deliver schedules
  through a recorded network (the direct analog of
  ``tests/stream/test_stream_properties.py::test_network_scalar_conservation``,
  now asserted on the REPLAYED ledger);
* a full simulator-level property running hostile fault plans end to end
  (few examples — each runs a real streaming round loop).
"""
import os

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.families import ISING  # noqa: E402
from repro.core.graphs import star_graph  # noqa: E402
from repro.stream.faults import (ByzantineSpec, FaultPlan,  # noqa: E402
                                 ReplaySpec)
from repro.stream.network import Network, NetworkConfig  # noqa: E402
from repro.stream.simulator import (ArrivalSpec,  # noqa: E402
                                    StreamSimulator)
from repro.telemetry import (Recorder, TelemetrySpec,  # noqa: E402
                             replay_network_counters)

_LINKS = [(0, 1), (1, 0), (1, 2), (2, 1), (0, 2)]


def _assert_replay_exact(events, net):
    replayed = replay_network_counters(events)
    live = net.counters_dict()
    for key, val in live.items():
        assert replayed[key] == val, (key, replayed[key], val)
    assert replayed["in_flight"] == net.in_flight
    assert replayed["scalars_in_flight"] == net.scalars_in_flight
    assert replayed["scalars_sent"] == (replayed["scalars_delivered"]
                                        + replayed["scalars_dropped"]
                                        + replayed["scalars_in_flight"])
    assert replayed["msgs_sent"] == (replayed["msgs_delivered"]
                                     + replayed["msgs_dropped"]
                                     + replayed["in_flight"])


@given(
    drop=st.floats(0.0, 1.0),
    delay=st.integers(0, 3),
    jitter=st.integers(0, 2),
    sends=st.lists(
        st.tuples(st.integers(0, len(_LINKS) - 1), st.integers(0, 17)),
        min_size=0, max_size=40),
    seed=st.integers(0, 2 ** 16),
)
@settings(max_examples=50, deadline=None)
def test_network_replay_matches_live_counters(drop, delay, jitter, sends,
                                              seed):
    rec = Recorder(TelemetrySpec())
    net = Network(_LINKS, NetworkConfig(drop_prob=drop, delay=delay,
                                        jitter=jitter, seed=seed),
                  recorder=rec)
    rnd = 0
    for link_idx, n_scalars in sends:
        src, dst = _LINKS[link_idx]
        net.send(rnd, src, dst, {"round": rnd}, n_scalars)
        net.deliver(rnd)
        _assert_replay_exact(rec.events, net)         # exact at EVERY round
        rnd += 1
    net.deliver(rnd + delay + jitter + 1)             # drain
    _assert_replay_exact(rec.events, net)


@pytest.fixture(scope="module")
def star5_pool():
    g = star_graph(5)
    theta_star = np.full(ISING.n_params(g), 0.3)
    pool = np.asarray(ISING.exact_sample(g, theta_star, 300,
                                         jax.random.PRNGKey(7)))
    return g, theta_star, pool


@given(
    drop=st.floats(0.0, 0.5),
    delay=st.integers(0, 2),
    byz_kind=st.sampled_from(["sign_flip", "scaled_noise", "fixed_value"]),
    replay_prob=st.floats(0.0, 1.0),
    seed=st.integers(0, 99),
)
@settings(max_examples=8, deadline=None)
def test_hostile_simulator_replay_exact(star5_pool, tmp_path_factory, drop,
                                        delay, byz_kind, replay_prob, seed):
    """End-to-end: a lossy network + a Byzantine node + replay attacks,
    arbitrary parameters — the JSONL log is always an exact ledger."""
    g, theta_star, pool = star5_pool
    path = os.path.join(tmp_path_factory.mktemp("replay"), "t.jsonl")
    faults = FaultPlan(
        byzantine=(ByzantineSpec(node=4, kind=byz_kind, start=1),),
        replay=ReplaySpec(prob=replay_prob, delay=2))
    sim = StreamSimulator(
        g, pool, scheme="trimmed_mean", theta_star=theta_star,
        arrivals=ArrivalSpec(rate=8.0),
        network=NetworkConfig(drop_prob=drop, delay=delay),
        capacity=64, seed=seed, faults=faults,
        telemetry=TelemetrySpec(jsonl=path))
    sim.run(4)
    from repro.telemetry import read_events
    _assert_replay_exact(read_events(path), sim.net)
