"""Unit tests for the telemetry core: recorders, spans, sinks, snapshots."""
import json
import os

import numpy as np
import pytest

from repro.telemetry import (NULL_RECORDER, NullRecorder, Recorder,
                             TelemetrySpec, make_recorder, read_jsonl)
from repro.telemetry.recorder import _ACTIVE, _NULL_SPAN, record_kernel_trace


# ------------------------------------------------------------------- null
def test_null_recorder_is_allocation_free():
    """The disabled path hands out ONE shared span object and never
    records anything — the zero-overhead-when-off contract."""
    assert NULL_RECORDER.enabled is False
    s1 = NULL_RECORDER.span("fit", tag=1)
    s2 = NULL_RECORDER.span("anything")
    assert s1 is s2 is _NULL_SPAN
    with s1:
        pass
    NULL_RECORDER.inc("c", 3)
    NULL_RECORDER.gauge("g", 1.0)
    NULL_RECORDER.observe("h", 2.0)
    NULL_RECORDER.point("m", 0, 1.0)
    assert NULL_RECORDER.mark() == 0
    assert NULL_RECORDER.snapshot() is None


def test_null_span_not_active_for_kernel_trace():
    with NULL_RECORDER.span("fit"):
        assert not _ACTIVE
        record_kernel_trace("kernel.x", shape=(1,))   # must be a no-op


# ------------------------------------------------------------------ spans
def test_span_paths_nest_and_aggregate():
    rec = Recorder(TelemetrySpec())
    with rec.span("fit"):
        with rec.span("bucket_solve", deg_pad=3):
            pass
        with rec.span("bucket_solve", deg_pad=5):
            pass
        with rec.span("combine", scheme="uniform"):
            pass
    snap = rec.snapshot()
    assert set(snap.spans) == {"fit", "fit/bucket_solve", "fit/combine"}
    assert snap.spans["fit/bucket_solve"]["count"] == 2
    assert snap.spans["fit"]["count"] == 1
    assert snap.spans["fit"]["total_s"] >= \
        snap.spans["fit/bucket_solve"]["total_s"]
    # stack fully unwound
    assert not rec._stack and not _ACTIVE


def test_open_span_receives_kernel_trace_events():
    rec = Recorder(TelemetrySpec())
    with rec.span("fit"):
        record_kernel_trace("kernel.test", kind="ising", shape=(2, 3))
    ev = [e for e in rec.events if e["kind"] == "event"]
    assert len(ev) == 1
    assert ev[0]["name"] == "kernel.test"
    assert ev[0]["tags"] == {"kind": "ising", "shape": (2, 3)}
    record_kernel_trace("kernel.after")               # no open span: dropped
    assert len([e for e in rec.events if e["kind"] == "event"]) == 1


def test_spans_disabled_by_spec():
    rec = Recorder(TelemetrySpec(spans=False))
    assert rec.span("fit") is _NULL_SPAN
    rec.inc("c", 1)                                   # metrics still live
    assert rec.snapshot().counters == {"c": 1}


def test_metrics_disabled_by_spec():
    rec = Recorder(TelemetrySpec(metrics=False))
    rec.inc("c", 1)
    rec.gauge("g", 2.0)
    rec.point("m", 0, 3.0)
    snap = rec.snapshot()
    assert not snap.counters and not snap.gauges and not snap.points
    with rec.span("fit"):                             # spans still live
        pass
    assert rec.snapshot().spans["fit"]["count"] == 1


# ---------------------------------------------------------------- metrics
def test_metrics_aggregate():
    rec = Recorder(TelemetrySpec())
    rec.inc("net.send", 5)
    rec.inc("net.send", 7, src=0, dst=1)
    rec.gauge("buf", 3)
    rec.gauge("buf", 9)
    rec.observe("lat", 0.5)
    rec.observe("lat", 1.5)
    rec.point("err", 1, 10.0)
    rec.point("err", 2, 4.0)
    snap = rec.snapshot()
    assert snap.counters["net.send"] == 12
    assert snap.gauges["buf"] == 9
    assert snap.histograms["lat"] == [0.5, 1.5]
    rounds, vals = snap.timeline("err")
    np.testing.assert_array_equal(rounds, [1, 2])
    np.testing.assert_array_equal(vals, [10.0, 4.0])
    with pytest.raises(KeyError, match="err"):
        snap.timeline("nope")


def test_mark_scopes_snapshot():
    rec = Recorder(TelemetrySpec())
    rec.inc("a", 1)
    mark = rec.mark()
    rec.inc("a", 10)
    assert rec.snapshot(mark).counters == {"a": 10}
    assert rec.snapshot().counters == {"a": 11}


# ------------------------------------------------------------------- sink
def test_jsonl_sink_round_trips_events(tmp_path):
    path = os.path.join(tmp_path, "sub", "trace.jsonl")
    rec = Recorder(TelemetrySpec(jsonl=path))
    with rec.span("fit", n=400):
        rec.inc("net.send", 3, src=0, dst=1)
        rec.gauge("buf", np.int64(7))                 # numpy scalars coerce
    rec.flush()
    logged = read_jsonl(path)
    assert len(logged) == len(rec.events)
    for disk, mem in zip(logged, rec.events):
        assert disk["seq"] == mem["seq"]
        assert disk["kind"] == mem["kind"]
        assert disk["name"] == mem["name"]
    # every line is standalone-parseable json
    with open(path) as f:
        for line in f:
            json.loads(line)


# ----------------------------------------------------------- make_recorder
def test_make_recorder_dispatch():
    assert make_recorder(None) is NULL_RECORDER
    assert make_recorder(False) is NULL_RECORDER
    live = Recorder(TelemetrySpec())
    assert make_recorder(live) is live                # pass-through sharing
    assert make_recorder(NULL_RECORDER) is NULL_RECORDER
    from_spec = make_recorder(TelemetrySpec())
    assert isinstance(from_spec, Recorder)
    from_dict = make_recorder({"spans": False, "metrics": True,
                               "jsonl": None, "profile_dir": None})
    assert isinstance(from_dict, Recorder)
    assert from_dict.spec.spans is False
    with pytest.raises(TypeError, match="TelemetrySpec"):
        make_recorder(42)


def test_spec_round_trip_and_validation():
    spec = TelemetrySpec(spans=True, metrics=False, jsonl="/tmp/x.jsonl")
    assert TelemetrySpec.from_dict(spec.to_dict()) == spec
    with pytest.raises(TypeError):
        TelemetrySpec(jsonl=7)
    with pytest.raises(TypeError):
        TelemetrySpec(profile_dir=3.5)


def test_null_recorder_span_is_cheap():
    """100k disabled span entries must be effectively free (generous CI
    bound — the point is catching an accidental allocation/IO path on the
    disabled branch, not microbenchmarking)."""
    import time
    t0 = time.perf_counter()
    for _ in range(100_000):
        with NULL_RECORDER.span("hot"):
            NULL_RECORDER.inc("c")
    assert time.perf_counter() - t0 < 2.0
