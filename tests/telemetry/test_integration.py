"""Telemetry integration: session verbs, streaming, and exact JSONL replay.

The three contracts the observability layer must keep:

* **off == invisible** — with no ``telemetry`` on the plan every numeric
  output is bit-identical to the uninstrumented path and nothing is
  attached to results;
* **on == faithful** — ``StreamResult.timeline("err")`` equals the
  recorded error column, and replaying the JSONL event log reconstructs
  the network's live bandwidth counters exactly (lossy + Byzantine +
  replay faults included);
* **compile split** — ``EstimateResult.wall_s`` still means total wall
  (backward compatible) while ``compile_s`` isolates the compiling
  dispatches: positive on a cold fit, exactly 0.0 warm.
"""
import os

import jax
import numpy as np
import pytest

import repro.api as A
from repro.core.batched import clear_bucket_solver_caches
from repro.core.families import ISING
from repro.core.graphs import chain_graph, star_graph
from repro.stream.faults import ByzantineSpec, FaultPlan, ReplaySpec
from repro.stream.network import NetworkConfig
from repro.stream.simulator import ArrivalSpec, StreamSimulator
from repro.telemetry import (TelemetrySpec, read_events,
                             replay_network_counters)


@pytest.fixture(scope="module")
def chain_data():
    g = chain_graph(6)
    theta = np.full(ISING.n_params(g), 0.25)
    X = np.asarray(ISING.exact_sample(g, theta, 400, jax.random.PRNGKey(1)))
    return g, theta, X


# ----------------------------------------------------------------- session
def test_fit_bit_identical_with_telemetry(chain_data):
    g, _, X = chain_data
    on = A.Plan(graph=g, combiners=("uniform", "diagonal"),
                telemetry=TelemetrySpec()).session().fit(X)
    off = A.Plan(graph=g, combiners=("uniform", "diagonal")).session().fit(X)
    np.testing.assert_array_equal(on.theta, off.theta)
    for scheme in on.combined:
        np.testing.assert_array_equal(on.combined[scheme],
                                      off.combined[scheme])
    assert off.telemetry is None
    assert on.telemetry is not None


def test_fit_snapshot_spans_and_kernel_tags(chain_data):
    g, _, X = chain_data
    sess = A.Plan(graph=g, combiners=("uniform",),
                  telemetry=TelemetrySpec()).session()
    clear_bucket_solver_caches()
    res = sess.fit(X)
    snap = res.telemetry
    assert "fit" in snap.spans
    assert "fit/bucket_solve" in snap.spans
    assert "fit/combine" in snap.spans
    assert snap.spans["fit"]["new_compiles"] == res.new_compiles > 0
    # trace-time kernel tags landed while the bucket solvers compiled
    kernels = [e for e in snap.events if e["kind"] == "event"
               and e["name"].startswith("kernel.")]
    assert kernels, "expected trace-time kernel dispatch events"
    from repro.kernels.cl.ops import KERNEL_PATHS
    assert all(e["tags"]["backend"] in KERNEL_PATHS for e in kernels)
    # per-bucket Newton iteration counts observed
    assert snap.histograms["engine.newton_iters"]
    # comm scalars gauged per requested scheme
    assert "comm.scalars_per_round" in snap.gauges


def test_compile_split_cold_then_warm(chain_data):
    g, _, X = chain_data
    clear_bucket_solver_caches()
    sess = A.Plan(graph=g, combiners=("diagonal",),
                  telemetry=TelemetrySpec()).session()
    cold = sess.fit(X)
    assert 0.0 < cold.compile_s <= cold.wall_s
    warm = sess.fit(np.ascontiguousarray(X[::-1]))
    assert warm.compile_s == 0.0
    assert warm.new_compiles == 0


def test_compile_split_tracked_without_telemetry(chain_data):
    """The wall/compile split is measured by the stats dict, not the
    recorder — a plain plan still reports it."""
    g, _, X = chain_data
    clear_bucket_solver_caches()
    sess = A.Plan(graph=g, combiners=("diagonal",)).session()
    cold = sess.fit(X)
    assert 0.0 < cold.compile_s <= cold.wall_s
    assert cold.telemetry is None


def test_joint_spans(chain_data):
    g, _, X = chain_data
    res = A.Plan(graph=g, combiners=("diagonal",), admm_iters=3,
                 telemetry=TelemetrySpec()).session().joint(X)
    snap = res.telemetry
    assert "joint" in snap.spans
    assert snap.spans["joint/admm_iter"]["count"] == 3
    assert len(snap.histograms["admm.primal_residual"]) == 3
    assert res.compile_s > 0.0


def test_plan_serializes_telemetry(chain_data):
    g, _, _ = chain_data
    plan = A.Plan(graph=g, combiners=("uniform",),
                  telemetry=TelemetrySpec(metrics=False,
                                          jsonl="/tmp/t.jsonl"))
    again = A.Plan.from_dict(plan.to_dict())
    assert again == plan
    assert again.telemetry == plan.telemetry
    with pytest.raises(TypeError, match="telemetry"):
        A.Plan(graph=g, combiners=("uniform",), telemetry="yes")


# ------------------------------------------------------------------ stream
def _hostile_sim(pool, theta_star, g, telemetry=None, jsonl=None):
    faults = FaultPlan(
        byzantine=(ByzantineSpec(node=4, kind="sign_flip", start=1),),
        replay=ReplaySpec(prob=0.4, delay=2))
    spec = telemetry
    if spec is None and jsonl is not None:
        spec = TelemetrySpec(jsonl=jsonl)
    return StreamSimulator(
        g, pool, scheme="trimmed_mean", theta_star=theta_star,
        arrivals=ArrivalSpec(rate=8.0),
        network=NetworkConfig(drop_prob=0.25, delay=1),
        capacity=64, seed=5, faults=faults, telemetry=spec)


@pytest.fixture(scope="module")
def star_pool():
    g = star_graph(5)
    theta_star = np.full(ISING.n_params(g), 0.3)
    pool = np.asarray(ISING.exact_sample(g, theta_star, 400,
                                         jax.random.PRNGKey(2)))
    return g, theta_star, pool


def test_stream_timeline_matches_recorded_columns(star_pool, tmp_path):
    g, theta_star, pool = star_pool
    sim = _hostile_sim(pool, theta_star, g,
                       jsonl=os.path.join(tmp_path, "t.jsonl"))
    res = sim.run(6, record_every=2)
    rounds, err = res.timeline("err")
    np.testing.assert_array_equal(rounds, res.rounds)
    np.testing.assert_array_equal(err, res.err)
    _, scal = res.timeline("scalars_sent")
    np.testing.assert_array_equal(scal.astype(np.int64), res.scalars_sent)
    _, stale = res.timeline("staleness")
    np.testing.assert_array_equal(stale, res.staleness)
    # observability counters fired under the hostile plan
    snap = res.telemetry
    assert snap.counters.get("fault.injections", 0) > 0
    assert "stream/round/refit" in snap.spans


def test_stream_run_bit_identical_with_telemetry(star_pool, tmp_path):
    g, theta_star, pool = star_pool
    on = _hostile_sim(pool, theta_star, g,
                      jsonl=os.path.join(tmp_path, "t.jsonl")).run(5)
    off = _hostile_sim(pool, theta_star, g).run(5)
    np.testing.assert_array_equal(on.theta, off.theta)
    np.testing.assert_array_equal(on.scalars_sent, off.scalars_sent)
    assert off.telemetry is None
    # the fallback timeline still answers from the recorded columns
    rounds, err = off.timeline("err")
    np.testing.assert_array_equal(err, off.err)
    with pytest.raises(KeyError, match="unknown timeline"):
        off.timeline("nonsense")


def test_jsonl_replay_reconstructs_live_counters(star_pool, tmp_path):
    g, theta_star, pool = star_pool
    path = os.path.join(tmp_path, "replay.jsonl")
    sim = _hostile_sim(pool, theta_star, g, jsonl=path)
    sim.run(6)
    replayed = replay_network_counters(read_events(path))
    live = sim.net.counters_dict()
    for key, val in live.items():
        assert replayed[key] == val, (key, replayed[key], val)
    assert replayed["in_flight"] == sim.net.in_flight
    assert replayed["scalars_in_flight"] == sim.net.scalars_in_flight
    # conservation holds in the replayed ledger too
    assert replayed["scalars_sent"] == (replayed["scalars_delivered"]
                                        + replayed["scalars_dropped"]
                                        + replayed["scalars_in_flight"])


def test_session_simulate_shares_recorder(star_pool):
    g, theta_star, pool = star_pool
    plan = A.Plan(graph=g, combiners=("diagonal",),
                  telemetry=TelemetrySpec())
    sess = plan.session()
    sim = sess.simulate(pool, theta_star=theta_star, seed=3)
    assert sim.recorder is sess.recorder
    res = sim.run(4)
    assert res.telemetry is not None
    assert "stream" in res.telemetry.spans
