"""Validation of the paper's asymptotic theory (Sec. 4) against exact
computation and simulation: info-unbiasedness, Thm 4.1/4.3, Prop 4.4/4.6,
Claim 4.9 orderings and the Claim 4.10 phase boundary."""
import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import repro.core as C


def two_node_model(theta_e, s1, s2):
    g = C.Graph(2, ((0, 1),))
    th = np.array([s1, s2, theta_e], dtype=np.float32)
    return C.IsingModel(g, jax.numpy.asarray(th))


def test_info_unbiasedness_exact():
    """Conditional likelihoods are information-unbiased: V = H^{-1} at theta*."""
    g = C.star_graph(6)
    m = C.random_model(g, 0.6, 0.4, jax.random.PRNGKey(0))
    for i in range(g.p):
        loc = C.exact_local(m, i)
        np.testing.assert_allclose(loc.V, np.linalg.inv(loc.H),
                                   rtol=2e-3, atol=2e-4)


def test_max_consensus_variance_is_min_owner_variance():
    """Thm 4.3/Prop 4.4: max-consensus var per param = min_i V^i_aa."""
    g = C.star_graph(5)
    m = C.random_model(g, 0.5, 0.5, jax.random.PRNGKey(1))
    locs = C.exact_locals(m, include_singleton=False)
    _, per = C.exact_consensus_variance(m, locs, "max",
                                        include_singleton=False)
    owners = C.param_owners(g, include_singleton=False)
    for a, own in owners.items():
        vmin = min(locs[i].V[pos, pos] for (i, pos) in own)
        np.testing.assert_allclose(per[a], vmin, rtol=1e-4)


def test_optimal_weights_are_optimal():
    """Prop 4.6: V_a^{-1} e beats random weight vectors (exact variance)."""
    g = C.star_graph(5)
    m = C.random_model(g, 0.7, 0.3, jax.random.PRNGKey(2))
    locs = C.exact_locals(m, include_singleton=False)
    _, per_opt = C.exact_consensus_variance(m, locs, "optimal",
                                            include_singleton=False)
    owners = C.param_owners(g, include_singleton=False)
    rng = np.random.RandomState(0)
    for a, own in owners.items():
        Va = C.cross_cov(locs, a, own)
        for _ in range(25):
            w = rng.rand(len(own)) + 1e-3
            w = w / w.sum()
            assert per_opt[a] <= w @ Va @ w + 1e-10


@given(st.floats(-1.2, 1.2), st.floats(-1.5, 1.5), st.floats(-1.5, 1.5))
@settings(max_examples=25, deadline=None)
def test_claim_4_9_ordering(theta_e, s1, s2):
    """linOpt <= joint <= linUnif and linOpt <= maxOpt (exact, toy model)."""
    m = two_node_model(theta_e, s1, s2)
    locs = C.exact_locals(m, include_singleton=False)
    v = {}
    for sch in ("uniform", "optimal", "max"):
        v[sch], _ = C.exact_consensus_variance(m, locs, sch,
                                               include_singleton=False)
    v["joint"], _ = C.exact_joint_mple_variance(m, include_singleton=False)
    tol = 1e-5 + 1e-3 * abs(v["joint"])
    assert v["optimal"] <= v["joint"] + tol
    assert v["joint"] <= v["uniform"] + tol
    assert v["optimal"] <= v["max"] + tol


@given(st.floats(-1.0, 1.0), st.floats(-1.5, 1.5), st.floats(-1.5, 1.5))
@settings(max_examples=25, deadline=None)
def test_claim_4_10_phase_boundary(theta_e, s1, s2):
    """joint <= maxOpt iff rho12 <= sqrt(gamma)(gamma+1)/2 (Claim 4.10)."""
    m = two_node_model(theta_e, s1, s2)
    locs = C.exact_locals(m, include_singleton=False)
    v1 = locs[0].V[0, 0]
    v2 = locs[1].V[0, 0]
    probs = locs[0].probs
    v12 = float((locs[0].S[:, 0] * probs) @ locs[1].S[:, 0])
    rho = v12 / np.sqrt(v1 * v2)
    gam = min(v1 / v2, v2 / v1)
    v_joint, _ = C.exact_joint_mple_variance(m, include_singleton=False)
    v_max, _ = C.exact_consensus_variance(m, locs, "max",
                                          include_singleton=False)
    lhs_leq = v_joint <= v_max[0] if isinstance(v_max, tuple) else v_joint <= v_max
    boundary = 0.5 * np.sqrt(gam) * (gam + 1)
    margin = 0.02  # skip razor-edge cases (numerical)
    if rho < boundary - margin:
        assert v_joint <= v_max + 1e-5 + 1e-3 * v_max
    elif rho > boundary + margin:
        assert v_joint >= v_max - 1e-5 - 1e-3 * v_max


def test_joint_equals_hessian_weighted_matrix_consensus():
    """Cor 4.2 (empirical): matrix consensus with W=H ~ joint MPLE estimate."""
    g = C.grid_graph(2, 3)
    m = C.random_model(g, 0.5, 0.3, jax.random.PRNGKey(3))
    X = C.exact_sample(m, 8000, jax.random.PRNGKey(4))
    fits = C.fit_all_local(g, X)
    th_matrix = C.combine(g, fits, "matrix")
    th_joint = C.fit_mple(g, X)
    # asymptotically equivalent: difference is o_p(1/sqrt(n))
    assert np.linalg.norm(th_matrix - th_joint) < 0.12


def test_mle_is_cramer_rao_floor():
    """No consensus scheme beats the exact MLE variance (Sec. 2.3)."""
    g = C.star_graph(6)
    m = C.random_model(g, 0.5, 0.5, jax.random.PRNGKey(5))
    locs = C.exact_locals(m, include_singleton=False)
    tr_mle, _ = C.exact_mle_variance(m, include_singleton=False)
    for sch in ("uniform", "diagonal", "optimal", "max"):
        tr, _ = C.exact_consensus_variance(m, locs, sch,
                                           include_singleton=False)
        assert tr >= tr_mle * (1 - 1e-4)
    tr_joint, _ = C.exact_joint_mple_variance(m, include_singleton=False)
    assert tr_joint >= tr_mle * (1 - 1e-4)


@pytest.mark.slow
def test_exact_matches_empirical_efficiency_star():
    """Fig 2(b): empirical n*MSE must track the exact asymptotic variance."""
    g = C.star_graph(6)
    m = C.random_model(g, 0.5, 0.5, jax.random.PRNGKey(6))
    tf = np.asarray(m.theta).copy()
    locs = C.exact_locals(m, include_singleton=False)
    free = C.free_indices(g, include_singleton=False)

    exact = {}
    for sch in ("uniform", "max"):
        exact[sch], _ = C.exact_consensus_variance(m, locs, sch,
                                                   include_singleton=False)
    n, R = 4000, 25
    emp = {sch: [] for sch in exact}
    for r in range(R):
        X = C.exact_sample(m, n, jax.random.PRNGKey(100 + r))
        fits = C.fit_all_local(g, X, include_singleton=False,
                               theta_fixed=jax.numpy.asarray(tf))
        for sch in exact:
            th = C.combine(g, fits, sch, include_singleton=False,
                           theta_fixed=tf)
            emp[sch].append(n * C.mse(th, np.asarray(m.theta), free))
    for sch in exact:
        ratio = np.mean(emp[sch]) / exact[sch]
        assert 0.6 < ratio < 1.6, (sch, ratio, np.mean(emp[sch]), exact[sch])
