"""Structure tests + hypothesis property tests for graph utilities."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import repro.core as C


def test_star():
    g = C.star_graph(6)
    assert g.p == 6 and g.m == 5
    assert g.degree(0) == 5
    assert all(g.degree(i) == 1 for i in range(1, 6))
    assert g.neighbors(0) == [1, 2, 3, 4, 5]


def test_grid():
    g = C.grid_graph(3, 4)
    assert g.p == 12
    assert g.m == 3 * 3 + 2 * 4  # horizontal + vertical
    degs = sorted(g.degree(i) for i in range(g.p))
    assert degs[0] == 2 and degs[-1] == 4


def test_chain_and_complete():
    assert C.chain_graph(5).m == 4
    assert C.complete_graph(5).m == 10


def test_scale_free_connected_and_hubby():
    g = C.scale_free_graph(60, m=1, seed=1)
    assert g.p == 60
    degs = np.array([g.degree(i) for i in range(g.p)])
    assert degs.max() >= 6          # preferential attachment creates hubs
    assert degs.min() >= 1


def test_euclidean_radius():
    g = C.euclidean_graph(50, radius=0.3, seed=2)
    assert g.p == 50 and g.m > 0


def test_bad_edges_rejected():
    with pytest.raises(ValueError):
        C.Graph(3, ((1, 1),))
    with pytest.raises(ValueError):
        C.Graph(3, ((0, 1), (0, 1)))
    with pytest.raises(ValueError):
        C.Graph(3, ((2, 5),))


@st.composite
def random_graphs(draw):
    p = draw(st.integers(3, 8))
    all_edges = [(i, j) for i in range(p) for j in range(i + 1, p)]
    k = draw(st.integers(1, len(all_edges)))
    idx = draw(st.permutations(range(len(all_edges))))
    edges = tuple(sorted(all_edges[i] for i in idx[:k]))
    return C.Graph(p, edges)


@given(random_graphs())
@settings(max_examples=40, deadline=None)
def test_beta_covers_all_params(g):
    """Union of beta_i must cover the whole index set (paper Sec. 3)."""
    covered = set()
    for i in range(g.p):
        covered.update(g.beta(i))
    assert covered == set(range(g.n_params))


@given(random_graphs())
@settings(max_examples=40, deadline=None)
def test_param_owner_counts(g):
    """Each singleton has 1 owner; each edge has exactly 2 owners."""
    owners = C.param_owners(g)
    for a, own in owners.items():
        if a < g.p:
            assert own == [(a, 0)]
        else:
            i, j = g.edges[a - g.p]
            assert sorted(o[0] for o in own) == [i, j]


@given(random_graphs())
@settings(max_examples=40, deadline=None)
def test_incident_edge_positions_match_design(g):
    """node_design column order must match beta ordering (edge block)."""
    for i in range(g.p):
        ks = g.incident_edges(i)
        beta = g.beta(i)
        assert beta[0] == i
        assert beta[1:] == [g.p + k for k in ks]
