"""Batched local-estimator engine vs the seed per-node path, plus the
chromatic Gibbs sampler vs exact/sequential sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core.batched import (_gauss_jordan_solve, _pad_degree,
                                _solve_bucket, clear_bucket_solver_caches)


# ------------------------------------------------------------ infrastructure
def test_pad_degree_powers_of_four():
    assert [_pad_degree(d) for d in [0, 1, 2, 3, 4, 5, 16, 17]] == \
        [1, 1, 4, 4, 4, 16, 16, 64]


def test_degree_buckets_cover_all_nodes():
    g = C.scale_free_graph(30, m=1, seed=3)
    buckets = C.degree_buckets(g)
    seen = sorted(int(i) for b in buckets for i in b.nodes)
    assert seen == list(range(g.p))
    for b in buckets:
        for row, i in enumerate(b.nodes):
            deg = g.degree(int(i))
            assert deg <= b.deg_pad
            assert b.mask[row].sum() == deg
            # neighbor order matches the seed design (incident-edge order)
            ks = g.incident_edges(int(i))
            others = [g.edges[k][0] if g.edges[k][1] == int(i)
                      else g.edges[k][1] for k in ks]
            assert list(b.nbrs[row, :deg]) == others


def test_gauss_jordan_matches_linalg_solve():
    rng = np.random.RandomState(0)
    for d in (1, 2, 5, 9):
        A = rng.randn(7, d, d).astype(np.float32)
        # well-conditioned negative definite (jax runs float32 by default)
        A = -(A @ A.transpose(0, 2, 1) + d * np.eye(d, dtype=np.float32))
        B = rng.randn(7, d, 2).astype(np.float32)
        X = np.asarray(_gauss_jordan_solve(jnp.asarray(A), jnp.asarray(B)))
        np.testing.assert_allclose(X, np.linalg.solve(A, B),
                                   atol=2e-5, rtol=2e-4)


# ----------------------------------------------------- batched == seed solver
@pytest.fixture(scope="module")
def grid_setup():
    g = C.grid_graph(3, 3)
    m = C.random_model(g, 0.5, 0.3, jax.random.PRNGKey(0))
    X = C.exact_sample(m, 3000, jax.random.PRNGKey(1))
    return g, m, X


def test_batched_matches_loop_free_singleton(grid_setup):
    g, m, X = grid_setup
    loop = C.fit_all_local_loop(g, X)
    bat = C.fit_all_local(g, X, method="batched")
    for a, b in zip(loop, bat):
        assert a.i == b.i and a.beta == b.beta
        np.testing.assert_allclose(a.theta, b.theta, atol=1e-5)
        np.testing.assert_allclose(a.H, b.H, atol=1e-4)
        np.testing.assert_allclose(a.J, b.J, atol=1e-4)


def test_batched_matches_loop_fixed_singleton(grid_setup):
    g, m, X = grid_setup
    tf = jnp.asarray(np.asarray(m.theta))
    loop = C.fit_all_local_loop(g, X, include_singleton=False, theta_fixed=tf)
    bat = C.fit_all_local(g, X, include_singleton=False, theta_fixed=tf,
                          method="batched")
    for a, b in zip(loop, bat):
        assert a.beta == b.beta
        assert len(a.theta) == g.degree(a.i)
        np.testing.assert_allclose(a.theta, b.theta, atol=1e-5)


@pytest.mark.slow
def test_batched_matches_loop_scale_free():
    """Heterogeneous degrees (the bucketing actually has work to do)."""
    g = C.scale_free_graph(24, m=1, seed=0)
    m = C.random_model(g, 0.4, 0.3, jax.random.PRNGKey(2))
    X = C.gibbs_sample(m, 1500, jax.random.PRNGKey(3), burnin=100, thin=2)
    loop = C.fit_all_local_loop(g, X)
    bat = C.fit_all_local(g, X, method="batched")
    max_diff = max(float(np.max(np.abs(a.theta - b.theta)))
                   for a, b in zip(loop, bat))
    assert max_diff <= 1e-5


def test_compile_count_bounded_by_buckets():
    """One XLA compile per degree bucket, reused across data/replicates."""
    g = C.scale_free_graph(26, m=1, seed=7)
    m = C.random_model(g, 0.4, 0.3, jax.random.PRNGKey(4))
    clear_bucket_solver_caches()
    n_buckets = len(C.degree_buckets(g))
    for r in range(3):
        X = C.gibbs_sample(m, 400, jax.random.PRNGKey(10 + r),
                           burnin=50, thin=1)
        C.fit_all_local(g, X, method="batched")
    assert C.bucket_compile_count() == n_buckets


def test_batched_feeds_consensus(grid_setup):
    """End-to-end: batched fits drive every consensus scheme sanely."""
    g, m, X = grid_setup
    fits = C.fit_all_local(g, X, method="batched")
    for sch in C.SCHEMES:
        th = C.combine(g, fits, sch)
        assert np.all(np.isfinite(th))
        assert C.mse(th, np.asarray(m.theta)) < 5.0


# ------------------------------------------------------------ chromatic Gibbs
def test_greedy_coloring_proper():
    for g in (C.grid_graph(4, 4), C.scale_free_graph(40, m=2, seed=1),
              C.complete_graph(6), C.star_graph(9)):
        colors = g.greedy_coloring()
        assert colors.min() >= 0
        for (i, j) in g.edges:
            assert colors[i] != colors[j]


def test_coloring_sparse_graphs_few_colors():
    # grids are bipartite; greedy colorings of sparse BA graphs stay small
    assert int(C.grid_graph(4, 4).greedy_coloring().max()) + 1 == 2
    assert int(C.scale_free_graph(50, m=1, seed=0).greedy_coloring().max()) + 1 <= 3
    # complete graph needs p colors -> auto dispatch falls back to sequential
    assert int(C.complete_graph(6).greedy_coloring().max()) + 1 == 6


def test_chromatic_gibbs_matches_exact_marginals():
    """Chromatic Gibbs must hit the exact singleton/pair moments (p=9)."""
    g = C.grid_graph(3, 3)
    m = C.random_model(g, 0.4, 0.3, jax.random.PRNGKey(5))
    mu, _ = C.exact_moments(g, m.theta)
    n = 6000
    Xc = C.chromatic_gibbs_sample(m, n, jax.random.PRNGKey(6),
                                  burnin=300, thin=3)
    emp = np.mean(np.asarray(C.suff_stats(g, Xc)), axis=0)
    # MC tolerance ~4 sigma: se <= 1/sqrt(n) per +-1 statistic
    assert np.max(np.abs(emp - np.asarray(mu))) < 4.5 / np.sqrt(n)


def test_chromatic_matches_sequential_marginals():
    """Both Gibbs schedules target the same stationary law (p=12)."""
    g = C.scale_free_graph(12, m=1, seed=2)
    m = C.random_model(g, 0.5, 0.3, jax.random.PRNGKey(7))
    n = 5000
    Xs = C.gibbs_sample(m, n, jax.random.PRNGKey(8), burnin=300, thin=3,
                        method="sequential")
    Xc = C.gibbs_sample(m, n, jax.random.PRNGKey(9), burnin=300, thin=3,
                        method="chromatic")
    es = np.mean(np.asarray(C.suff_stats(g, Xs)), axis=0)
    ec = np.mean(np.asarray(C.suff_stats(g, Xc)), axis=0)
    assert np.max(np.abs(es - ec)) < 6.0 / np.sqrt(n)


def test_gibbs_auto_dispatch_runs():
    g_sparse = C.grid_graph(3, 3)
    g_dense = C.complete_graph(6)
    for g in (g_sparse, g_dense):
        m = C.random_model(g, 0.3, 0.2, jax.random.PRNGKey(11))
        X = C.gibbs_sample(m, 100, jax.random.PRNGKey(12), burnin=20, thin=1)
        assert X.shape == (100, g.p)
        assert set(np.unique(np.asarray(X))) <= {-1.0, 1.0}
