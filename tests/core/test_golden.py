"""Golden-value regression pins for the estimator stack.

``tests/core/golden_estimators.json`` (regenerated only deliberately via
``tools/gen_golden.py``) freezes seeded outputs of the batched local-fit
engine and all four one-step consensus schemes on a small grid-graph Ising
problem. Reproducing them to 1e-10 catches *silent* numeric drift — a
changed einsum association, a reordered reduction, an accidental dtype
downgrade — that tolerance-based correctness tests would absorb.
"""
import json
import os

import numpy as np
import pytest

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_estimators.json")
ATOL = 1e-10


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def recomputed():
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                    "tools"))
    try:
        import gen_golden
    finally:
        sys.path.pop(0)
    return gen_golden.compute()


def test_scenario_is_the_frozen_one(golden, recomputed):
    assert recomputed["config"] == golden["config"]
    np.testing.assert_allclose(recomputed["theta_star"],
                               golden["theta_star"], atol=ATOL)


def test_batched_local_fits_bitstable(golden, recomputed):
    assert len(recomputed["local_theta"]) == len(golden["local_theta"])
    for got, want in zip(recomputed["local_theta"], golden["local_theta"]):
        np.testing.assert_allclose(got, want, atol=ATOL)
    for got, want in zip(recomputed["local_vdiag"], golden["local_vdiag"]):
        np.testing.assert_allclose(got, want, atol=ATOL)


def test_combine_all_schemes_bitstable(golden, recomputed):
    assert set(recomputed["combine"]) == set(golden["combine"])
    for sch, want in golden["combine"].items():
        np.testing.assert_allclose(recomputed["combine"][sch], want,
                                   atol=ATOL, err_msg=sch)
