"""Byzantine-robust combiners (trimmed_mean, krum): unit semantics of the
filters/selectors, the registry contracts they declare, batch-driver
robustness to a corrupted leaf fit, and the everyone-rejects-NaN
conformance check over the full combiner registry."""
import dataclasses

import jax
import numpy as np
import pytest

import repro.core as C
from repro.core.combiners import (KrumCombiner, TrimmedMeanCombiner,
                                  get_combiner, registered_combiners)


@pytest.fixture(scope="module")
def fitted():
    g = C.star_graph(6)
    m = C.random_model(g, 0.5, 0.4, jax.random.PRNGKey(4))
    X = C.exact_sample(m, 2000, jax.random.PRNGKey(5))
    fits = C.fit_all_local(g, X)
    return g, m, fits


# ------------------------------------------------------- declared contracts
def test_robust_combiners_declare_their_contracts():
    tm = get_combiner("trimmed_mean")
    kr = get_combiner("krum")
    assert tm.anchored and kr.anchored
    assert tm.breakdown_point == tm.trim > 0.0
    assert kr.breakdown_point == 0.5
    assert tm.needs == {"variance"} and tm.scalars_per_shared_param == 2
    assert kr.needs == frozenset() and kr.scalars_per_shared_param == 1
    # the classical linear schemes honestly declare breakdown 0
    for name in ("uniform", "diagonal", "optimal"):
        assert get_combiner(name).breakdown_point == 0.0
        assert not getattr(get_combiner(name), "anchored", False)


def test_trim_fraction_validation():
    with pytest.raises(ValueError, match=r"\[0\.0, 0\.5\)"):
        TrimmedMeanCombiner(trim=0.5)
    with pytest.raises(ValueError, match="kappa"):
        TrimmedMeanCombiner(kappa=0.0)
    with pytest.raises(ValueError, match="kappa"):
        TrimmedMeanCombiner(kappa=float("nan"))


# -------------------------------------------------- streaming-side fusion
def test_trimmed_mean_rejects_incompatible_candidate():
    """A fixed-magnitude lie lands outside kappa*sqrt(V_a+V_b) once the
    variances have shrunk; the honest pair is averaged, the liar dropped."""
    tm = TrimmedMeanCombiner()
    v = 1e-4                       # ~n=10k worth of variance
    honest = [(0.50, v), (0.52, v)]
    out = tm.combine_candidates(honest + [(-0.50, v)], own_index=0)
    np.testing.assert_allclose(out, 0.51, atol=1e-12)
    # ...while a statistically compatible spread is fully averaged
    out2 = tm.combine_candidates([(0.50, v), (0.51, v)], own_index=0)
    np.testing.assert_allclose(out2, 0.505, atol=1e-12)


def test_trimmed_mean_anchor_is_the_receiver_not_column_zero():
    tm = TrimmedMeanCombiner()
    v = 1e-4
    cands = [(-0.5, v), (0.5, v), (0.52, v)]
    assert tm.combine_candidates(cands, own_index=1) == pytest.approx(0.51)
    # anchored on the liar, the honest pair is what gets rejected — the
    # documented two-owner limitation: a corrupted HOME cannot be fixed
    assert tm.combine_candidates(cands, own_index=0) == pytest.approx(-0.5)


def test_trimmed_mean_rank_trim_drops_extremes_with_many_owners():
    """With k=8 candidates and trim=0.25, two come off each flank even
    when all are within the compatibility radius (huge kappa isolates the
    order-statistic path)."""
    tm = TrimmedMeanCombiner(trim=0.25, kappa=1e9)
    ests = [0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]
    out = tm.combine_candidates([(e, 1.0) for e in ests], own_index=3)
    np.testing.assert_allclose(out, np.mean(ests[2:-2]), atol=1e-12)


def test_krum_two_owner_tie_prefers_home():
    """At the paper's two-owner edge blocks both candidates see the same
    single distance — a lying peer must never displace the home fit."""
    kr = KrumCombiner()
    assert kr.combine_candidates([(0.4, 0.0), (-4.0, 0.0)],
                                 own_index=0) == 0.4
    assert kr.combine_candidates([(-4.0, 0.0), (0.4, 0.0)],
                                 own_index=1) == 0.4
    # without an anchor, first minimum wins (lowest-index convention)
    assert kr.combine_candidates([(0.4, 0.0), (-4.0, 0.0)]) == 0.4


def test_krum_selects_from_the_majority_cluster():
    kr = KrumCombiner()
    cands = [(0.50, 0.0), (0.51, 0.0), (0.49, 0.0), (5.0, 0.0), (-5.0, 0.0)]
    out = kr.combine_candidates(cands, own_index=4)   # even anchored on liar
    assert out in (0.50, 0.51, 0.49)


def test_non_finite_candidates_are_ignored_by_both():
    tm, kr = TrimmedMeanCombiner(), KrumCombiner()
    cands = [(0.5, 1e-4), (np.nan, 1e-4), (0.52, np.inf), (0.54, 1e-4)]
    assert np.isfinite(tm.combine_candidates(cands, own_index=0))
    assert np.isfinite(kr.combine_candidates(cands, own_index=0))
    assert abs(tm.combine_candidates(cands, own_index=0)) < 1.0


# ------------------------------------------------------------ batch driver
def test_batch_combine_survives_corrupted_leaf(fitted):
    """Poison one leaf's outbound estimates by +10: uniform averages the
    lie in (shifts by ~5 on that leaf's edge params); trimmed_mean and krum
    stay glued to the clean consensus."""
    g, m, fits = fitted
    clean = {s: C.combine(g, fits, s)
             for s in ("uniform", "trimmed_mean", "krum")}
    liar = 3
    dirty = list(fits)
    dirty[liar] = dataclasses.replace(
        fits[liar], theta=fits[liar].theta + 10.0)
    hostile = {s: C.combine(g, dirty, s)
               for s in ("uniform", "trimmed_mean", "krum")}
    owners = C.param_owners(g)
    lied = [a for a, own in owners.items()
            if len(own) > 1 and any(i == liar for i, _ in own)]
    assert lied                                      # the leaf owns edges
    # krum picked the home owner already, so rejecting the liar changes
    # nothing; trimmed_mean falls back to the surviving honest owner,
    # moving only by the (tiny) honest-pair gap — never by the lie
    np.testing.assert_allclose(hostile["krum"][lied], clean["krum"][lied],
                               atol=1e-12)
    np.testing.assert_allclose(hostile["trimmed_mean"][lied],
                               clean["trimmed_mean"][lied], atol=0.05)
    assert np.min(np.abs(hostile["uniform"][lied]
                         - clean["uniform"][lied])) > 1.0


def test_krum_batch_equals_clean_under_perfect_honesty(fitted):
    """All-honest Krum picks the home owner everywhere at k=2 — identical
    to itself under any candidate permutation-free corruption-free run
    (determinism of the first-minimum convention)."""
    g, m, fits = fitted
    th1 = C.combine(g, fits, "krum")
    th2 = C.combine(g, fits, "krum")
    np.testing.assert_array_equal(th1, th2)
    assert np.all(np.isfinite(th1))


# ----------------------------------------- satellite 2: NaN/inf conformance
@pytest.mark.parametrize("poison", ["nan", "inf", "huge"])
def test_every_registered_combiner_rejects_poisoned_fit(fitted, poison):
    """Conformance: a single NaN/inf/diverged local fit must not leak into
    ANY registered combiner's output — diverged owners are disqualified
    (the TRUST_RADIUS rule) and the combined estimate stays finite and
    close to the clean consensus."""
    g, m, fits = fitted
    bad_theta = {"nan": np.nan, "inf": np.inf, "huge": 1e6}[poison]
    dirty = list(fits)
    dirty[0] = dataclasses.replace(
        fits[0],
        theta=np.full_like(fits[0].theta, bad_theta),
        H=np.full_like(fits[0].H, bad_theta),
        V=np.full_like(fits[0].V, bad_theta))
    for comb in registered_combiners():
        th = comb.combine(g, dirty, family=C.get_family("ising"))
        assert np.all(np.isfinite(th)), \
            f"{comb.name} leaked {poison} into the combined estimate"
        clean = comb.combine(g, fits, family=C.get_family("ising"))
        shared = [a for a, own in C.param_owners(g).items() if len(own) > 1]
        # params NOT owned by the poisoned node are untouched
        untouched = [a for a in shared
                     if all(i != 0 for i, _ in C.param_owners(g)[a])]
        if untouched:
            np.testing.assert_allclose(th[untouched], clean[untouched],
                                       atol=1e-8,
                                       err_msg=f"{comb.name} perturbed "
                                               f"params the bad node "
                                               f"does not own")
