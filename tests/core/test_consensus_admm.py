"""Consensus combiners + ADMM behaviour (Sec. 3, Thm 3.1, Fig 3c)."""
import jax
import numpy as np
import pytest

import repro.core as C


@pytest.fixture(scope="module")
def grid_setup():
    g = C.grid_graph(3, 3)
    m = C.random_model(g, 0.5, 0.3, jax.random.PRNGKey(0))
    X = C.exact_sample(m, 3000, jax.random.PRNGKey(1))
    fits = C.fit_all_local(g, X)
    return g, m, X, fits


def test_all_schemes_finite_and_reasonable(grid_setup):
    g, m, X, fits = grid_setup
    base = C.mse(C.fit_mple(g, X), np.asarray(m.theta))
    for sch in C.SCHEMES:
        th = C.combine(g, fits, sch)
        assert np.all(np.isfinite(th))
        assert C.mse(th, np.asarray(m.theta)) < 30 * base + 0.5


def test_singleton_passthrough(grid_setup):
    """Singletons have one owner: every scheme returns the local estimate."""
    g, m, X, fits = grid_setup
    ths = {sch: C.combine(g, fits, sch) for sch in
           ("uniform", "diagonal", "optimal", "max")}
    for i in range(g.p):
        vals = {sch: th[i] for sch, th in ths.items()}
        assert np.ptp(list(vals.values())) < 1e-9
        assert abs(vals["uniform"] - fits[i].theta[0]) < 1e-9


def test_uniform_is_plain_average(grid_setup):
    g, m, X, fits = grid_setup
    th = C.combine(g, fits, "uniform")
    owners = C.param_owners(g)
    for a, own in owners.items():
        avg = np.mean([fits[i].theta[pos] for (i, pos) in own])
        np.testing.assert_allclose(th[a], avg, rtol=1e-6, atol=1e-7)


def test_unknown_scheme_raises_listing_registered(grid_setup):
    """Regression: an unknown scheme name must fail loudly — a ValueError
    naming every registered combiner — through both the legacy facade and
    the registry, never fall through silently."""
    g, m, X, fits = grid_setup
    with pytest.raises(ValueError) as ei:
        C.combine(g, fits, "no_such_scheme")
    msg = str(ei.value)
    assert "no_such_scheme" in msg
    for comb in C.registered_combiners():
        assert comb.name in msg
    with pytest.raises(ValueError) as ei2:
        C.get_combiner("also_bogus")
    assert "also_bogus" in str(ei2.value)


def test_registry_resolves_every_seed_scheme(grid_setup):
    """The registry serves every seed scheme name, and the facade's output
    is the strategy object's output exactly."""
    g, m, X, fits = grid_setup
    for sch in C.SCHEMES:
        comb = C.get_combiner(sch)
        assert comb.name == sch
        np.testing.assert_array_equal(
            C.combine(g, fits, sch), comb.combine(g, fits))


def test_weighted_vote_two_owners_matches_max(grid_setup):
    """With exactly two owners per shared parameter (every pairwise graph),
    the weighted median IS the max-vote winner up to exact weight ties."""
    g, m, X, fits = grid_setup
    tv = C.combine(g, fits, "weighted_vote")
    tm = C.combine(g, fits, "max")
    np.testing.assert_allclose(tv, tm, atol=1e-12)


def test_combiner_needs_declarations(grid_setup):
    """Strategies declare their second-order demands: only Linear-Opt asks
    for influence samples, only the matrix reference for full Hessians —
    and fits computed without influence make Linear-Opt fail loudly."""
    g, m, X, fits = grid_setup
    needs = {c.name: c.needs for c in C.registered_combiners()}
    assert "influence" in needs["optimal"]
    assert "hessian" in needs["matrix"]
    for name in ("uniform", "diagonal", "max", "weighted_vote"):
        assert "influence" not in needs[name]
    from repro.core.batched import fit_all_local_batched
    import jax.numpy as jnp
    slim = fit_all_local_batched(g, jnp.asarray(X[:500]),
                                 want_influence=False)
    assert all(f.s.shape[0] == 0 for f in slim)
    with pytest.raises(ValueError, match="influence"):
        C.combine(g, slim, "optimal")
    # slim fits lose nothing the variance-based schemes read
    full = fit_all_local_batched(g, jnp.asarray(X[:500]))
    for sch in ("uniform", "diagonal", "max", "weighted_vote"):
        np.testing.assert_allclose(C.combine(g, slim, sch),
                                   C.combine(g, full, sch), atol=1e-12)


def test_max_picks_min_variance_owner(grid_setup):
    g, m, X, fits = grid_setup
    th = C.combine(g, fits, "max")
    owners = C.param_owners(g)
    for a, own in owners.items():
        best = min(own, key=lambda ip: fits[ip[0]].V[ip[1], ip[1]])
        np.testing.assert_allclose(th[a], fits[best[0]].theta[best[1]])


@pytest.mark.slow
def test_admm_converges_to_mple(grid_setup):
    g, m, X, fits = grid_setup
    th_mple = C.fit_mple(g, X)
    res = C.admm_mple(g, X, n_iters=25, init="diagonal", fits=fits)
    assert np.linalg.norm(res.trajectory[-1] - th_mple) < 1e-3
    # primal residual decreases
    assert res.primal_residual[-1] < res.primal_residual[0]


def test_admm_anytime_consistency(grid_setup):
    """Thm 3.1: with consensus init, every iterate stays near theta*
    (error never blows past the one-step estimate's error)."""
    g, m, X, fits = grid_setup
    res = C.admm_mple(g, X, n_iters=15, init="diagonal", fits=fits)
    errs = [C.mse(t, np.asarray(m.theta)) for t in res.trajectory]
    assert max(errs) <= errs[0] * 2.0 + 1e-3  # no divergence at any iterate


def test_admm_consensus_init_faster_than_zero(grid_setup):
    """Fig 3(c): one-step initialization accelerates ADMM convergence."""
    g, m, X, fits = grid_setup
    th_mple = C.fit_mple(g, X)
    res_d = C.admm_mple(g, X, n_iters=6, init="diagonal", fits=fits)
    res_0 = C.admm_mple(g, X, n_iters=6, init="zero")
    err_d = np.linalg.norm(res_d.trajectory[-1] - th_mple)
    err_0 = np.linalg.norm(res_0.trajectory[-1] - th_mple)
    assert err_d < err_0


def test_admm_family_batched_matches_seed_trajectory(grid_setup):
    """The family-generic batched ADMM (one prox solve per degree bucket
    per round — the engine behind EstimationSession.joint) solves the same
    objective as the seed per-node-loop ADMM: same fixed point, same
    decreasing primal residual."""
    g, m, X, fits = grid_setup
    th_mple = C.fit_mple(g, X)
    res = C.admm_mple_family(g, X, n_iters=20, init="diagonal", fits=fits,
                             newton_iters=15)
    assert np.linalg.norm(res.trajectory[-1] - th_mple) < 5e-3
    assert res.primal_residual[-1] < res.primal_residual[0]
    seed = C.admm_mple(g, X, n_iters=8, init="diagonal", fits=fits)
    fam = C.admm_mple_family(g, X, n_iters=8, init="diagonal", fits=fits)
    np.testing.assert_allclose(fam.trajectory[-1], seed.trajectory[-1],
                               atol=2e-4)


@pytest.mark.slow
def test_star_max_beats_uniform():
    """The paper's headline: on stars, max >> uniform consensus."""
    g = C.star_graph(8)
    m = C.random_model(g, 0.5, 0.5, jax.random.PRNGKey(5))
    tf = np.asarray(m.theta).copy()
    errs = {"uniform": [], "max": []}
    for r in range(6):
        X = C.exact_sample(m, 1500, jax.random.PRNGKey(50 + r))
        fits = C.fit_all_local(g, X, include_singleton=False,
                               theta_fixed=jax.numpy.asarray(tf))
        for sch in errs:
            th = C.combine(g, fits, sch, include_singleton=False,
                           theta_fixed=tf)
            errs[sch].append(C.mse(th, tf))
    assert np.mean(errs["max"]) < np.mean(errs["uniform"])
