"""shard_map-over-mesh execution path of the batched engine.

The acceptance bar: on the host mesh (one device) the sharded path is
numerically identical (<= 1e-10 — in practice bitwise) to the plain
single-program path, for plain fits, weighted/warm-started streaming
re-fits, and the proximal ADMM primal update, across every registered
family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
import repro.stream as S
from repro.core.batched import (_mesh_data_size, fit_all_local_batched,
                                prox_update_batched)
from repro.launch.mesh import make_host_mesh

FAMILIES = [f.name for f in C.registered_families()]


@pytest.fixture(scope="module")
def host_mesh():
    return make_host_mesh()


def _setup(name, seed=0, n=600):
    fam = C.get_family(name)
    g = C.grid_graph(2, 3)
    theta = fam.random_params(g, jax.random.PRNGKey(seed))
    X = jnp.asarray(fam.exact_sample(g, theta, n,
                                     jax.random.PRNGKey(seed + 1)))
    return fam, g, X


@pytest.mark.parametrize("name", FAMILIES)
def test_sharded_fit_identical_on_host_mesh(name, host_mesh):
    fam, g, X = _setup(name)
    plain = fit_all_local_batched(g, X, family=fam)
    shard = fit_all_local_batched(g, X, family=fam, mesh=host_mesh)
    for a, b in zip(plain, shard):
        assert a.beta == b.beta
        np.testing.assert_allclose(b.theta, a.theta, atol=1e-10)
        np.testing.assert_allclose(b.H, a.H, atol=1e-10)
        np.testing.assert_allclose(b.J, a.J, atol=1e-10)
        np.testing.assert_allclose(b.V, a.V, atol=1e-10)
        np.testing.assert_allclose(b.s, a.s, atol=1e-10)


@pytest.mark.parametrize("name", FAMILIES)
def test_sharded_weighted_warm_fit_identical(name, host_mesh):
    """The streaming hot path — per-node 0/1 masks + warm starts — stays
    identical through the sharded solver."""
    fam, g, X = _setup(name, seed=2)
    n = X.shape[0]
    masks = (np.arange(n)[None, :]
             < (200 + 57 * np.arange(g.p))[:, None]).astype(np.float32)
    warm = [np.zeros(len(fam.beta(g, i))) for i in range(g.p)]
    kw = dict(family=fam, sample_weight=jnp.asarray(masks), warm_start=warm)
    plain = fit_all_local_batched(g, X, **kw)
    shard = fit_all_local_batched(g, X, mesh=host_mesh, **kw)
    for a, b in zip(plain, shard):
        np.testing.assert_allclose(b.theta, a.theta, atol=1e-10)
        np.testing.assert_allclose(b.V, a.V, atol=1e-10)


@pytest.mark.parametrize("name", FAMILIES)
def test_sharded_prox_identical_on_host_mesh(name, host_mesh):
    fam, g, X = _setup(name, seed=4)
    betas = [fam.beta(g, i) for i in range(g.p)]
    lambdas = [0.01 * np.ones(len(b)) for b in betas]
    rhos = [np.full(len(b), 0.5) for b in betas]
    tbar = np.zeros(fam.n_params(g))
    plain = prox_update_batched(g, X, tbar, lambdas, rhos, family=fam)
    shard = prox_update_batched(g, X, tbar, lambdas, rhos, family=fam,
                                mesh=host_mesh)
    for a, b in zip(plain, shard):
        np.testing.assert_allclose(b, a, atol=1e-10)


def test_streaming_estimator_sharded_matches_plain(host_mesh):
    """Chunked streaming through the mesh-backed estimator bank reproduces
    the plain bank exactly (same buffers, same warm starts, same masks)."""
    fam, g, X = _setup("potts", seed=6)
    Xn = np.asarray(X)
    est_a = S.StreamingEstimator(g, capacity=32, family=fam)
    est_b = S.StreamingEstimator(g, capacity=32, family=fam, mesh=host_mesh)
    for chunk in np.array_split(Xn[:500], 4):
        for est in (est_a, est_b):
            est.ingest(chunk)
            est.refit()
    for a, b in zip(est_a.fits, est_b.fits):
        np.testing.assert_allclose(b.theta, a.theta, atol=1e-10)


def test_fit_all_local_forwards_mesh(host_mesh):
    fam, g, X = _setup("ising", seed=8)
    plain = C.fit_all_local(g, X)
    shard = C.fit_all_local(g, X, mesh=host_mesh)
    for a, b in zip(plain, shard):
        np.testing.assert_allclose(b.theta, a.theta, atol=1e-10)
    with pytest.raises(ValueError, match="mesh"):
        C.fit_all_local(g, X, method="loop", mesh=host_mesh)


_MULTI_DEVICE_SCRIPT = """
import numpy as np, jax, jax.numpy as jnp
import repro.core as C
from repro.core.batched import fit_all_local_batched
assert len(jax.devices()) == 4, jax.devices()
mesh = jax.make_mesh((4, 1), ("data", "model"))
fam = C.get_family("potts")
g = C.grid_graph(2, 3)                      # 6 nodes -> pad to 8 rows
theta = fam.random_params(g, jax.random.PRNGKey(0))
X = jnp.asarray(fam.exact_sample(g, theta, 400, jax.random.PRNGKey(1)))
plain = fit_all_local_batched(g, X, family=fam)
shard = fit_all_local_batched(g, X, family=fam, mesh=mesh)
diff = max(float(np.max(np.abs(a.theta - b.theta)))
           for a, b in zip(plain, shard))
assert diff <= 1e-5, diff
print("MULTI_DEVICE_OK", diff)
"""


@pytest.mark.slow
def test_sharded_fit_on_four_devices_subprocess():
    """Exercise the pad>0 multi-shard path for real: 4 forced host devices
    (set before jax initializes, hence the subprocess), a 6-node bucket
    padded to 8 rows across 4 shards. Converged fits agree with the plain
    path to Newton tolerance (per-shard while_loop iteration counts may
    differ, so this is 1e-5, not the single-device 1e-10)."""
    import os
    import subprocess
    import sys
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    out = subprocess.run([sys.executable, "-c", _MULTI_DEVICE_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MULTI_DEVICE_OK" in out.stdout


def test_mesh_without_data_axis_rejected():
    fam, g, X = _setup("ising", seed=9, n=64)
    mesh = jax.make_mesh((1,), ("model",))
    assert _mesh_data_size(make_host_mesh()) == 1
    with pytest.raises(ValueError, match="data"):
        fit_all_local_batched(g, X, mesh=mesh)
