"""Streaming extensions of the batched engine: observation masks, warm
starts, and the batched proximal (ADMM) update."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core.admm import _prox_solve
from repro.core.estimators import node_design


@pytest.fixture(scope="module")
def setup():
    g = C.grid_graph(3, 3)
    m = C.random_model(g, 0.5, 0.3, jax.random.PRNGKey(0))
    X = np.asarray(C.exact_sample(m, 1400, jax.random.PRNGKey(1)))
    return g, m, X


def test_global_mask_equals_subset_fit(setup):
    g, m, X = setup
    c = 800
    w = np.zeros(len(X), np.float32)
    w[:c] = 1.0
    masked = C.fit_all_local(g, jnp.asarray(X), sample_weight=jnp.asarray(w))
    subset = C.fit_all_local(g, jnp.asarray(X[:c]))
    for a, b in zip(masked, subset):
        np.testing.assert_allclose(a.theta, b.theta, atol=1e-5)
        np.testing.assert_allclose(a.H, b.H, atol=1e-4)
        np.testing.assert_allclose(a.J, b.J, atol=1e-4)


def test_per_node_masks_equal_per_node_subsets(setup):
    g, m, X = setup
    counts = 400 + (np.arange(g.p) * 97) % 900
    w = (np.arange(len(X))[None, :] < counts[:, None]).astype(np.float32)
    masked = C.fit_all_local(g, jnp.asarray(X), sample_weight=jnp.asarray(w))
    for i in (1, 5, 7):
        ref = C.fit_all_local(g, jnp.asarray(X[: counts[i]]))[i]
        np.testing.assert_allclose(masked[i].theta, ref.theta, atol=1e-5)


def test_warm_start_reaches_same_optimum(setup):
    g, m, X = setup
    Xj = jnp.asarray(X)
    cold = C.fit_all_local(g, Xj)
    warm = [f.theta + 0.25 for f in cold]
    rewarmed = C.fit_all_local(g, Xj, warm_start=warm)
    for a, b in zip(cold, rewarmed):
        np.testing.assert_allclose(a.theta, b.theta, atol=1e-5)


def test_loop_method_rejects_streaming_args(setup):
    g, m, X = setup
    with pytest.raises(ValueError):
        C.fit_all_local(g, jnp.asarray(X), method="loop",
                        sample_weight=jnp.ones(len(X)))


def test_prox_update_matches_seed_prox_solve(setup):
    """Batched bucket prox == the seed per-node ADMM primal update."""
    g, m, X = setup
    Xj = jnp.asarray(X)
    rng = np.random.RandomState(0)
    theta_bar = rng.randn(g.n_params) * 0.1
    lambdas = [rng.randn(len(g.beta(i))) * 0.05 for i in range(g.p)]
    rhos = [np.ones(len(g.beta(i))) for i in range(g.p)]
    got = C.prox_update_batched(g, Xj, theta_bar, lambdas, rhos, n_iter=30)
    tf = jnp.zeros(g.n_params)
    for i in range(g.p):
        b = np.asarray(g.beta(i))
        ref = np.asarray(_prox_solve(
            node_design(g, Xj, i), Xj[:, i], tf[i],
            jnp.asarray(lambdas[i]), jnp.asarray(rhos[i]),
            jnp.asarray(theta_bar[b]), jnp.asarray(theta_bar[b]), True, 30))
        np.testing.assert_allclose(got[i], ref, atol=1e-5)


def test_prox_update_per_node_bar_views(setup):
    """Per-node consensus views (the asynchronous streaming case) are
    honored: passing identical views as a list equals the flat path."""
    g, m, X = setup
    Xj = jnp.asarray(X)
    rng = np.random.RandomState(1)
    theta_bar = rng.randn(g.n_params) * 0.1
    lambdas = [np.zeros(len(g.beta(i))) for i in range(g.p)]
    rhos = [np.ones(len(g.beta(i))) for i in range(g.p)]
    flat = C.prox_update_batched(g, Xj, theta_bar, lambdas, rhos, n_iter=20)
    views = [theta_bar[np.asarray(g.beta(i))] for i in range(g.p)]
    listed = C.prox_update_batched(g, Xj, views, lambdas, rhos, n_iter=20)
    for a, b in zip(flat, listed):
        np.testing.assert_allclose(a, b, atol=1e-6)
