"""Model-math correctness: exact enumeration, conditionals, samplers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import repro.core as C


def _rand_model(p_edges="grid", key=0, sp=0.5, ss=0.3):
    g = C.grid_graph(2, 3) if p_edges == "grid" else C.star_graph(5)
    return C.random_model(g, sp, ss, jax.random.PRNGKey(key))


def test_exact_probs_normalized():
    m = _rand_model()
    pr = C.exact_probs(m.graph, m.theta)
    assert pr.shape == (2 ** m.graph.p,)
    np.testing.assert_allclose(float(pr.sum()), 1.0, rtol=1e-5)


def test_conditional_matches_joint():
    """sigmoid(2 x_i eta_i) must equal the exact conditional p(x_i | x_rest)."""
    m = _rand_model(key=3)
    g = m.graph
    states = C.all_states(g.p)
    pr = np.asarray(C.exact_probs(g, m.theta))
    eta = np.asarray(C.conditional_logits(g, m.theta, jnp.asarray(states)))
    for i in range(g.p):
        # brute-force conditional: group states by x_{-i}
        flip = states.copy()
        flip[:, i] = -flip[:, i]
        # index of flipped state
        bits = ((flip + 1) / 2).astype(np.int64)
        idx = (bits << np.arange(g.p)).sum(1)
        p_cond = pr / (pr + pr[idx])
        pred = 1.0 / (1.0 + np.exp(-2.0 * states[:, i] * eta[:, i]))
        np.testing.assert_allclose(p_cond, pred, rtol=2e-4, atol=2e-5)


def test_log_partition_bruteforce():
    m = _rand_model(key=5)
    g = m.graph
    states = C.all_states(g.p)
    U = np.asarray(C.suff_stats(g, jnp.asarray(states)))
    lz = np.log(np.exp(U @ np.asarray(m.theta)).sum())
    np.testing.assert_allclose(float(C.log_partition(g, m.theta)), lz, rtol=1e-5)


def test_exact_sample_moments():
    m = _rand_model(key=7)
    mu, _ = C.exact_moments(m.graph, m.theta)
    X = C.exact_sample(m, 20000, jax.random.PRNGKey(1))
    emp = np.asarray(C.suff_stats(m.graph, X)).mean(0)
    np.testing.assert_allclose(emp, np.asarray(mu), atol=0.03)


def test_gibbs_matches_exact_moments():
    m = _rand_model(key=9)
    mu, _ = C.exact_moments(m.graph, m.theta)
    X = C.gibbs_sample(m, 4000, jax.random.PRNGKey(2), burnin=300, thin=3)
    emp = np.asarray(C.suff_stats(m.graph, X)).mean(0)
    np.testing.assert_allclose(emp, np.asarray(mu), atol=0.06)


def test_pseudo_loglik_value():
    """Pseudo-likelihood equals the sum of per-node conditional logliks."""
    m = _rand_model(key=11)
    X = C.exact_sample(m, 64, jax.random.PRNGKey(3))
    pll = float(C.pseudo_loglik(m.graph, m.theta, X))
    cll = np.asarray(C.cond_loglik(m.graph, m.theta, X))
    np.testing.assert_allclose(pll, cll.sum(1).mean(), rtol=1e-5)
    assert pll < 0.0


@given(st.integers(0, 10000))
@settings(max_examples=20, deadline=None)
def test_suff_stats_range(seed):
    """Sufficient statistics of +-1 data are +-1 (hypothesis sweep)."""
    g = C.grid_graph(2, 2)
    X = np.sign(np.random.RandomState(seed).randn(8, g.p)).astype(np.float32)
    X[X == 0] = 1.0
    U = np.asarray(C.suff_stats(g, jnp.asarray(X)))
    assert U.shape == (8, g.n_params)
    assert np.all(np.abs(U) == 1.0)
