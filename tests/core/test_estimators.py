"""Estimator correctness: stationarity, consistency, local CL = logistic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core.ising import pseudo_loglik


@pytest.fixture(scope="module")
def setup():
    g = C.grid_graph(2, 3)
    m = C.random_model(g, 0.5, 0.3, jax.random.PRNGKey(0))
    X = C.exact_sample(m, 4000, jax.random.PRNGKey(1))
    return g, m, X


def test_mple_stationarity(setup):
    g, m, X = setup
    th = C.fit_mple(g, X)
    grad = jax.grad(lambda t: pseudo_loglik(g, t, X))(jnp.asarray(th))
    assert float(jnp.abs(grad).max()) < 1e-4


def test_mle_stationarity(setup):
    g, m, X = setup
    th = C.fit_mle_exact(g, X)
    mean_u = jnp.mean(C.suff_stats(g, X), axis=0)
    ll = lambda t: t @ mean_u - C.log_partition(g, t)
    grad = jax.grad(ll)(jnp.asarray(th))
    assert float(jnp.abs(grad).max()) < 1e-4


def test_local_cl_stationarity(setup):
    g, m, X = setup
    for i in [0, 3]:
        fit = C.fit_local_cl(g, X, i)
        fun, d = __import__("repro.core.estimators", fromlist=["node_cl_fn"]).node_cl_fn(
            g, X, i, True, jnp.zeros(g.n_params))
        grad = jax.grad(fun)(jnp.asarray(fit.theta, dtype=jnp.float32))
        assert float(jnp.abs(grad).max()) < 1e-4


def test_consistency_with_n(setup):
    """MSE of MPLE decreases roughly like 1/n (consistency)."""
    g, m, _ = setup
    errs = []
    for k, n in enumerate([500, 8000]):
        X = C.exact_sample(m, n, jax.random.PRNGKey(10 + k))
        th = C.fit_mple(g, X)
        errs.append(C.mse(th, np.asarray(m.theta)))
    assert errs[1] < errs[0]


@pytest.mark.slow
def test_mle_beats_or_ties_mple_avg():
    """Across a few seeds, exact MLE MSE <= MPLE MSE on average (efficiency)."""
    g = C.grid_graph(2, 3)
    r_mle, r_mple = [], []
    for s in range(4):
        m = C.random_model(g, 0.5, 0.3, jax.random.PRNGKey(100 + s))
        X = C.exact_sample(m, 3000, jax.random.PRNGKey(200 + s))
        r_mle.append(C.mse(C.fit_mle_exact(g, X), np.asarray(m.theta)))
        r_mple.append(C.mse(C.fit_mple(g, X), np.asarray(m.theta)))
    assert np.mean(r_mle) <= np.mean(r_mple) * 1.15  # slack for noise


def test_local_cl_is_logistic_regression(setup):
    """Node CL fit must equal logistic regression of x_i on neighbors."""
    g, m, X = setup
    i = 2
    fit = C.fit_local_cl(g, X, i)
    # hand-rolled logistic regression via jax on the same design
    Z = np.asarray(C.node_design(g, X, i))
    xi = np.asarray(X[:, i])
    Zb = np.concatenate([np.ones((Z.shape[0], 1)), Z], axis=1)

    def nll(w):
        eta = Zb @ w
        return -jnp.mean(jax.nn.log_sigmoid(2.0 * xi * eta))

    w = C.newton_maximize(lambda w: -nll(w), jnp.zeros(Zb.shape[1]))
    np.testing.assert_allclose(fit.theta, np.asarray(w), atol=1e-4)


def test_fixed_singleton_mode(setup):
    g, m, X = setup
    tf = jnp.asarray(m.theta)  # true singletons fixed
    fit = C.fit_local_cl(g, X, 0, include_singleton=False, theta_fixed=tf)
    assert len(fit.beta) == g.degree(0)
    assert all(a >= g.p for a in fit.beta)
    free = C.free_indices(g, include_singleton=False)
    th = C.fit_mple(g, X, free_idx=free, theta_fixed=tf)
    np.testing.assert_allclose(th[: g.p], np.asarray(m.theta[: g.p]), atol=1e-6)
