"""Per-architecture smoke tests (deliverable f): reduced variant of each
family — forward shapes + finiteness, one train step, decode equivalence.

The whole module is `slow`: ~10 architectures x (forward + train step +
decode) dominates suite wall-clock; the fast CI tier covers the estimator
core, the slow tier runs these.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as CFG
from repro.models import transformer as T
from repro.models import decoding as E
from repro.train import step as TS
from repro.optim.adamw import AdamWConfig

pytestmark = pytest.mark.slow

ARCHS = list(CFG.ARCH_IDS)


def _inputs(r, b=2, s=16, key=1):
    tokens = jax.random.randint(jax.random.PRNGKey(key), (b, s), 0,
                                r.vocab_size)
    kw = {}
    if r.enc_dec:
        kw["enc_frames"] = 0.1 * jnp.ones((b, r.n_frames, r.d_model),
                                          r.jdtype)
    if r.n_patches:
        kw["patch_embeds"] = 0.1 * jnp.ones((b, r.n_patches, r.d_model),
                                            r.jdtype)
    return tokens, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch):
    r = CFG.reduced(CFG.get(arch))
    params = T.model_init(r, jax.random.PRNGKey(0))
    tokens, kw = _inputs(r)
    logits, aux = T.forward(r, params, tokens, remat=False, **kw)
    assert logits.shape == (2, 16, r.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    """One forward/backward + AdamW update: loss finite, params move."""
    r = CFG.reduced(CFG.get(arch))
    state = TS.init_state(r, jax.random.PRNGKey(0))
    tokens, kw = _inputs(r, b=2, s=16)
    batch = {"tokens": tokens, "labels": tokens}
    batch.update(kw)
    tcfg = TS.TrainConfig(microbatch=0, remat=True)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    train_step = TS.make_train_step(r, ocfg, tcfg)
    new_state, metrics = train_step(state, batch)
    assert bool(jnp.isfinite(metrics["nll"]))
    # params actually moved
    before = jax.tree_util.tree_leaves(state.params)[0]
    after = jax.tree_util.tree_leaves(new_state.params)[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))
    assert int(new_state.opt.step) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the full-sequence logits."""
    S = 8
    r = CFG.reduced(CFG.get(arch))
    params = T.model_init(r, jax.random.PRNGKey(0))
    tokens, kw = _inputs(r, b=1, s=S, key=3)
    kw.pop("patch_embeds", None)   # decode is text-only past the prompt
    logits_full, _ = T.forward(r, params, tokens, remat=False, **kw)
    enc_out = (T.encode(r, params, kw["enc_frames"])
               if r.enc_dec else None)
    cache = T.materialize_cache(r, 1, S)
    dec = jax.jit(functools.partial(T.decode_step, r))
    outs = []
    for t in range(S):
        if enc_out is not None:
            lg, cache = dec(params, cache, tokens[:, t:t + 1], t,
                            enc_out=enc_out)
        else:
            lg, cache = dec(params, cache, tokens[:, t:t + 1], t)
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_full),
                               np.asarray(logits_dec), atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "recurrentgemma-2b",
                                  "xlstm-1.3b", "minicpm3-4b"])
def test_prefill_then_decode_continuation(arch):
    """prefill(return_cache) + decode continuation == full forward."""
    S, EXTRA = 10, 3
    r = CFG.reduced(CFG.get(arch))
    params = T.model_init(r, jax.random.PRNGKey(0))
    tokens, kw = _inputs(r, b=2, s=S + EXTRA, key=5)
    full, _ = T.forward(r, params, tokens, remat=False, **kw)
    logits, cache = E.prefill(r, params, tokens[:, :S], S + EXTRA,
                              enc_frames=kw.get("enc_frames"))
    np.testing.assert_allclose(np.asarray(full[:, :S]), np.asarray(logits),
                               atol=2e-3, rtol=2e-3)
    for t in range(EXTRA):
        lg, cache = T.decode_step(r, params, cache,
                                  tokens[:, S + t:S + t + 1], S + t)
        np.testing.assert_allclose(np.asarray(full[:, S + t]),
                                   np.asarray(lg[:, 0]), atol=2e-3, rtol=2e-3)


def test_sliding_window_cache_is_bounded():
    """long_500k carve-out: SWA cache size is window, not seq_len."""
    r = CFG.reduced(CFG.get("llama3.2-3b"))
    spec = T.init_cache(r, 1, 500_000, window_override=64)
    k = spec["units"]["b0"]["k"]
    assert k.shape[1 + 1] == 64  # (units, B, eff_len, kh, hd)


def test_generate_runs():
    r = CFG.reduced(CFG.get("phi3-mini-3.8b"))
    params = T.model_init(r, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 5), 0,
                                r.vocab_size)
    out = E.generate(r, params, prompt, n_new=4)
    assert out.shape == (2, 4)
    assert bool(jnp.all((out >= 0) & (out < r.vocab_size)))
