"""Substrate-level property tests: attention variants, MoE dispatch,
recurrences — oracle equivalences swept with hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import attention as A
from repro.models import moe as M
from repro.models.common import ArchConfig


@given(st.integers(1, 3), st.sampled_from([8, 24, 65]), st.integers(1, 4),
       st.sampled_from([0, 16]), st.integers(0, 100))
@settings(max_examples=12, deadline=None)
def test_blocked_equals_plain_attention(b, s, h, window, seed):
    """Flash-style blocked scan == materialized attention (any shape)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    d = 16
    q = jax.random.normal(k1, (b, s, h, d))
    k = jax.random.normal(k2, (b, s, h, d))
    v = jax.random.normal(k3, (b, s, h, d))
    ref = A._plain_attention(q, k, v, causal=True, window=window)
    out = A._blocked_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def _moe_cfg(e=4, k=2):
    return ArchConfig(arch_id="t", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                      n_experts=e, experts_per_tok=k, d_expert=32,
                      dtype="float32")


def _moe_reference(cfg, p, x):
    """Dense per-token reference: every expert computed, gated combine."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, cfg.experts_per_tok)
    gv = gv / gv.sum(-1, keepdims=True)
    outs = []
    for e in range(cfg.n_experts):
        h = jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])
        outs.append(h @ p["w_out"][e])
    dense = jnp.stack(outs, 1)                      # (T, E, d)
    w = jnp.zeros((xt.shape[0], cfg.n_experts))
    w = jax.vmap(lambda wi, gii, gvi: wi.at[gii].add(gvi))(w, gi, gv)
    return jnp.einsum("te,ted->td", w, dense).reshape(b, s, d)


@given(st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_moe_dropless_equals_dense_reference(seed):
    cfg = _moe_cfg()
    from repro.models.transformer import init_params
    from repro.models.moe import moe_spec
    key = jax.random.PRNGKey(seed)
    p = init_params(moe_spec(cfg), key, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, 16))
    out, aux = M.moe_apply(cfg, p, x)      # T=16 <= 4096 -> dropless
    ref = _moe_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    assert float(aux) > 0.0 and np.isfinite(float(aux))


def test_moe_capacity_drops_when_forced():
    """Above the dropless threshold the capacity buffer bounds compute."""
    cfg = _moe_cfg(e=2, k=1)
    from repro.models.transformer import init_params
    from repro.models.moe import moe_spec
    p = init_params(moe_spec(cfg), jax.random.PRNGKey(0), jnp.float32)
    # all tokens route to one expert: make router column 0 dominant
    p["router"] = p["router"].at[:, 0].set(10.0).at[:, 1].set(-10.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 16))
    out, _ = M.moe_apply(cfg, p, x)
    ref = _moe_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@given(st.integers(0, 30), st.sampled_from([17, 64]))
@settings(max_examples=8, deadline=None)
def test_mlstm_chunked_invariant_to_chunk_size(seed, s):
    """Chunkwise mLSTM must be invariant to the chunk partition."""
    from repro.models import xlstm as X
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    b, h, d = 1, 2, 8
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, h, s, d))
    v = jax.random.normal(ks[2], (b, h, s, d))
    li = jax.random.normal(ks[3], (b, h, s))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, h, s)) + 1.0)
    orig = X.MLSTM_CHUNK
    try:
        X.MLSTM_CHUNK = s          # single chunk == fully parallel
        h1, _ = X._mlstm_chunk_scan(q, k, v, li, lf)
        X.MLSTM_CHUNK = 1          # fully recurrent
        h2, _ = X._mlstm_chunk_scan(q, k, v, li, lf)
    finally:
        X.MLSTM_CHUNK = orig
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=1e-4, rtol=1e-3)


def test_rope_preserves_norm_and_relativity():
    from repro.models.common import apply_rope
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 2, 8))
    pos = jnp.arange(6)
    y = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # relative property: <R_m q, R_n k> depends only on (m - n)
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 8))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 8))
    def dot_at(m, n):
        qm = apply_rope(q, jnp.array([m]), 10_000.0)
        kn = apply_rope(k, jnp.array([n]), 10_000.0)
        return float(jnp.sum(qm * kn))
    np.testing.assert_allclose(dot_at(3, 1), dot_at(7, 5), rtol=1e-4,
                               atol=1e-5)


def test_cross_entropy_matches_manual():
    from repro.train.loss import cross_entropy
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 5))
    labels = jnp.array([[0, 2, -1], [4, -1, 1]])
    loss, metrics = cross_entropy(logits, labels, z_loss=0.0)
    lp = jax.nn.log_softmax(logits, -1)
    manual = -(lp[0, 0, 0] + lp[0, 1, 2] + lp[1, 0, 4] + lp[1, 2, 1]) / 4
    np.testing.assert_allclose(float(loss), float(manual), rtol=1e-6)
    assert float(metrics["n_tokens"]) == 4
