"""Hypothesis property tests for the declarative estimation plan.

* ``Plan.to_dict`` -> ``Plan.from_dict`` round-trips EXACTLY (equality and
  hash) for random valid plans — every registered family x every non-empty
  ordered subset of registered combiners x mesh policy on/off x random
  graphs, precisions, fixed coordinates, and solver budgets;
* two equal plans hash-key to the same cached session (the compiled-solver
  sharing guarantee), and unequal plans to different sessions.
"""
import json

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.api as A  # noqa: E402
import repro.core as C  # noqa: E402

FAMILY_NAMES = [f.name for f in C.registered_families()]
COMBINER_NAMES = [c.name for c in C.registered_combiners()]


@st.composite
def graphs(draw):
    p = draw(st.integers(min_value=2, max_value=7))
    pairs = [(i, j) for i in range(p) for j in range(i + 1, p)]
    chosen = draw(st.lists(st.sampled_from(pairs), min_size=1,
                           max_size=len(pairs), unique=True))
    return C.Graph(p, tuple(sorted(chosen)))


@st.composite
def plans(draw):
    graph = draw(graphs())
    family = draw(st.sampled_from(FAMILY_NAMES))
    combiners = tuple(draw(st.lists(st.sampled_from(COMBINER_NAMES),
                                    min_size=1, max_size=len(COMBINER_NAMES),
                                    unique=True)))
    include_singleton = draw(st.booleans())
    mesh = draw(st.sampled_from([None, "host"]))
    n_params = C.get_family(family).n_params(graph)
    theta_fixed = draw(st.one_of(
        st.none(),
        st.lists(st.floats(min_value=-1.0, max_value=1.0,
                           allow_nan=False, width=32),
                 min_size=n_params, max_size=n_params).map(tuple)))
    return A.Plan(
        graph=graph, family=family, combiners=combiners,
        include_singleton=include_singleton, theta_fixed=theta_fixed,
        n_iter=draw(st.integers(min_value=1, max_value=60)),
        mesh=mesh,
        precision=draw(st.sampled_from(["float32", "float64",
                                        "bfloat16"])),
        capacity=draw(st.integers(min_value=1, max_value=256)),
        admm_iters=draw(st.integers(min_value=1, max_value=40)),
        admm_init=draw(st.sampled_from(["zero", "uniform", "diagonal"])),
        admm_newton_iters=draw(st.integers(min_value=1, max_value=20)),
        admm_rho=draw(st.floats(min_value=1e-3, max_value=10.0,
                                allow_nan=False)))


@settings(max_examples=60, deadline=None)
@given(plan=plans())
def test_plan_dict_round_trip_is_exact(plan):
    d = plan.to_dict()
    # the dict is honestly JSON (what configs/benchmarks persist)
    d2 = json.loads(json.dumps(d))
    back = A.Plan.from_dict(d2)
    assert back == plan
    assert hash(back) == hash(plan)
    assert back.to_dict() == d


@settings(max_examples=25, deadline=None)
@given(plan=plans())
def test_equal_plans_share_one_cached_session(plan):
    """The session cache is keyed by plan equality: an equal plan built
    from the serialized dict resolves to the SAME session object (hence
    the same derived structures and jitted solver cache), while a
    materially different plan gets its own."""
    twin = A.Plan.from_dict(plan.to_dict())
    s1 = A.EstimationSession.for_plan(plan)
    s2 = A.EstimationSession.for_plan(twin)
    assert s1 is s2
    assert s2.plan == plan
    other = plan.replace(n_iter=plan.n_iter + 1)
    assert A.EstimationSession.for_plan(other) is not s1


@settings(max_examples=25, deadline=None)
@given(plan=plans())
def test_session_derivations_are_consistent(plan):
    """Compiled-session derivations agree with the registries for random
    plans: bucket count, owner structure size, combiner demand union."""
    sess = A.EstimationSession.for_plan(plan)
    fam = plan.family_instance
    assert sess.n_buckets == len(C.degree_buckets(plan.graph))
    n_params = fam.n_params(plan.graph)
    if plan.include_singleton:
        assert set(sess.owners) == set(range(n_params))
    assert sess.want_influence == any(
        "influence" in c.needs for c in plan.combiner_instances)
    assert sess.theta_fixed.shape == (n_params,)
    if plan.theta_fixed is not None:
        np.testing.assert_allclose(sess.theta_fixed,
                                   np.asarray(plan.theta_fixed))
