"""The estimation-plan API contract.

The acceptance criteria of the Plan -> EstimationSession redesign:

* ``session.fit`` and the legacy ``fit_all_local`` + ``combine`` pipeline
  agree to 1e-10 on the golden-fixture scenario, for EVERY registered
  family and every combiner the plan requests (the shims and the session
  share one engine — this pins it);
* a warm session ``fit`` on fresh same-shape data triggers ZERO new bucket
  solver compilations, and a cold one compiles exactly one program per
  degree bucket;
* ``session.stream()`` is plan-bound (chunked streaming == session.fit);
* ``session.joint()`` converges to the centralized MPLE;
* plans validate loudly and sessions honor the combiner ``needs``
  declarations.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as A
import repro.core as C
from repro.core.batched import (bucket_compile_count,
                                clear_bucket_solver_caches as
                                _clear_solver_caches)

ALL_COMBINERS = tuple(c.name for c in C.registered_combiners())


@pytest.fixture(scope="module", params=[f.name for f in
                                        C.registered_families()])
def family_setup(request):
    """(family, graph, theta_star, X) on a small grid per family."""
    fam = C.get_family(request.param)
    g = C.grid_graph(2, 3)
    theta = fam.random_params(g, jax.random.PRNGKey(3))
    X = np.asarray(fam.exact_sample(g, theta, 900, jax.random.PRNGKey(4)))
    return fam, g, np.asarray(theta, dtype=np.float64), X


def test_session_fit_matches_legacy_pipeline_exactly(family_setup):
    """Acceptance: session.fit == fit_all_local + combine to 1e-10, every
    registered family, every registered combiner."""
    fam, g, theta, X = family_setup
    plan = A.Plan(graph=g, family=fam.name, combiners=ALL_COMBINERS)
    res = plan.session().fit(X)
    fits = C.fit_all_local(g, jnp.asarray(X), family=fam)
    for a, b in zip(res.fits, fits):
        assert a.beta == b.beta
        np.testing.assert_allclose(a.theta, b.theta, atol=1e-10)
    for name in ALL_COMBINERS:
        ref = C.combine(g, fits, name, family=fam)
        np.testing.assert_allclose(res.combined[name], ref, atol=1e-10,
                                   err_msg=name)
    assert np.array_equal(res.theta, res.combined[plan.combiners[0]])
    assert res.mode == "fit" and res.n_samples == X.shape[0]
    assert np.isfinite(res.score_norm) and res.wall_s > 0.0


def test_warm_session_fit_compiles_nothing_new(family_setup):
    """Acceptance: cold fit compiles one program per degree bucket; a warm
    fit on FRESH same-shape data compiles nothing."""
    fam, g, theta, X = family_setup
    _clear_solver_caches()
    plan = A.Plan(graph=g, family=fam.name, combiners=("diagonal", "max"))
    sess = plan.session()
    cold = sess.fit(X)
    assert cold.new_compiles == sess.n_buckets
    fresh = np.ascontiguousarray(X[::-1])          # same shape, new values
    warm = sess.fit(fresh)
    assert warm.new_compiles == 0
    assert bucket_compile_count() == sess.n_buckets
    # and a re-acquired session for an equal plan reuses the same solvers
    again = A.Plan(graph=g, family=fam.name,
                   combiners=("diagonal", "max")).session()
    assert again is sess
    assert again.fit(X).new_compiles == 0


def test_session_stream_is_plan_bound(family_setup):
    """The streaming verb inherits the plan: chunked ingestion through
    session.stream() reproduces session.fit on the same data."""
    fam, g, theta, X = family_setup
    sess = A.Plan(graph=g, family=fam.name, capacity=32).session()
    est = sess.stream()
    assert est.family is fam
    # the plan's influence demand threads through to streaming re-fits
    assert est.want_influence == sess.want_influence
    for chunk in np.array_split(X[:600], 4):
        est.ingest(chunk)
        est.refit()
    ref = sess.fit(X[:600])
    for a, b in zip(est.fits, ref.fits):
        np.testing.assert_allclose(a.theta, b.theta, atol=2e-4)


def test_session_joint_tracks_centralized_mple():
    """The joint verb (family-generic batched ADMM) lands on the
    centralized MPLE with decreasing primal residual."""
    g = C.grid_graph(2, 3)
    m = C.random_model(g, 0.5, 0.3, jax.random.PRNGKey(7))
    X = C.exact_sample(m, 800, jax.random.PRNGKey(8))
    sess = A.Plan(graph=g, admm_iters=25).session()
    res = sess.joint(X)
    assert res.mode == "joint"
    assert res.trajectory.shape == (26, g.n_params)
    assert res.primal_residual[-1] < res.primal_residual[0]
    mple = C.fit_mple(g, X)
    assert float(np.max(np.abs(res.theta - mple))) < 5e-3
    assert res.comm_scalars["admm"] == 25 * 2 * sum(
        len(g.beta(i)) for i in range(g.p))


def test_session_honors_combiner_needs():
    """A plan whose combiners never declare "influence" gets fits without
    the per-sample influence stacks; adding Linear-Opt turns them on."""
    g = C.star_graph(6)
    m = C.random_model(g, 0.5, 0.4, jax.random.PRNGKey(9))
    X = C.exact_sample(m, 500, jax.random.PRNGKey(10))
    slim = A.Plan(graph=g, combiners=("diagonal",)).session()
    assert not slim.want_influence
    assert all(f.s.shape[0] == 0 for f in slim.fit(X).fits)
    rich = A.Plan(graph=g, combiners=("diagonal", "optimal")).session()
    assert rich.want_influence
    res = rich.fit(X)
    assert all(f.s.shape[0] == X.shape[0] for f in res.fits)
    # slim and rich sessions agree on everything slim computes
    np.testing.assert_allclose(slim.fit(X).combined["diagonal"],
                               res.combined["diagonal"], atol=1e-10)


def test_comm_scalar_accounting_matches_cost_table():
    """EstimateResult.comm_scalars reproduces the shared combinatorial
    accounting of repro.stream.costs for every distributable scheme."""
    from repro.stream.costs import comm_costs
    g = C.grid_graph(3, 3)
    m = C.random_model(g, 0.5, 0.3, jax.random.PRNGKey(12))
    X = C.exact_sample(m, 300, jax.random.PRNGKey(13))
    sess = A.Plan(graph=g, combiners=("uniform", "diagonal", "max",
                                      "weighted_vote", "optimal",
                                      "matrix")).session()
    res = sess.fit(X)
    table = comm_costs(g, X.shape[0], 0)
    assert res.comm_scalars["uniform"] == table["one_step_linear"]
    assert res.comm_scalars["diagonal"] == table["diagonal_or_max"]
    assert res.comm_scalars["max"] == table["diagonal_or_max"]
    assert res.comm_scalars["weighted_vote"] == table["diagonal_or_max"]
    assert res.comm_scalars["optimal"] == table["linear_opt"]
    assert "matrix" not in res.comm_scalars      # not distributable


def test_plan_validation_fails_loudly():
    g = C.chain_graph(4)
    with pytest.raises(KeyError, match="registered"):
        A.Plan(graph=g, family="no_such_family")
    with pytest.raises(ValueError, match="registered combiners"):
        A.Plan(graph=g, combiners=("diagonal", "bogus"))
    with pytest.raises(ValueError, match="at least one combiner"):
        A.Plan(graph=g, combiners=())
    with pytest.raises(ValueError, match="mesh policy"):
        A.Plan(graph=g, mesh="torus")
    with pytest.raises(ValueError, match="theta_fixed"):
        A.Plan(graph=g, theta_fixed=(0.0,) * 3)
    with pytest.raises(TypeError, match="Graph"):
        A.Plan(graph="not a graph")
    with pytest.raises(ValueError, match="admm_init"):
        A.Plan(graph=g, admm_init="warm")
    for rho in (0.0, -1.0, float("nan")):
        with pytest.raises(ValueError, match="admm_rho"):
            A.Plan(graph=g, admm_rho=rho)
    # a bare string combiner is normalized, not 8 one-letter combiners
    assert A.Plan(graph=g, combiners="diagonal").combiners == ("diagonal",)


def test_simulate_accepts_mesh_override():
    """session.simulate's documented override contract: an explicit mesh=
    in overrides wins instead of colliding with the session's mesh."""
    from repro.launch.mesh import make_host_mesh
    g = C.star_graph(5)
    m = C.random_model(g, 0.5, 0.4, jax.random.PRNGKey(20))
    pool = np.asarray(C.exact_sample(m, 200, jax.random.PRNGKey(21)))
    sess = A.Plan(graph=g).session()
    import repro.stream as S
    sim = sess.simulate(pool, mesh=make_host_mesh(),
                        arrivals=S.ArrivalSpec(rate=50.0))
    sim.run(2)
    assert np.all(np.isfinite(sim.current_estimate()))


def test_float64_plan_fails_loudly_without_x64():
    """precision="float64" without jax x64 raises instead of silently
    truncating the samples to float32."""
    g = C.chain_graph(4)
    sess = A.Plan(graph=g, precision="float64").session()
    with pytest.raises(ValueError, match="x64"):
        sess.fit(np.zeros((8, 4), dtype=np.float64))


def test_broken_third_party_candidates_cannot_break_streaming(monkeypatch):
    """Streamability is detected by override, not by executing user code:
    a registered combiner whose combine_candidates would crash on a probe
    (e.g. assumes >= 2 candidates) is simply listed as streamable, and
    built-in simulator construction keeps working."""
    import repro.stream as S
    from repro.core.combiners import (Combiner, DiagonalCombiner, _REGISTRY,
                                      streamable_combiners)

    class TrimmedMean(DiagonalCombiner):
        name = "trimmed_mean"

        def combine_candidates(self, cands):
            return float(np.mean([e for e, _ in sorted(cands)[1:-1]]))

    class NotStreamable(Combiner):
        name = "batch_only"
        scalars_per_shared_param = 2

        def group_weights(self, est, diag, bad, cols):
            return np.where(bad, 0.0, 1.0)

    monkeypatch.setitem(_REGISTRY, "trimmed_mean", TrimmedMean())
    monkeypatch.setitem(_REGISTRY, "batch_only", NotStreamable())
    names = {c.name for c in streamable_combiners()}
    assert "trimmed_mean" in names          # probe never executed it
    assert "batch_only" not in names        # no combine_candidates override
    g = C.star_graph(5)
    m = C.random_model(g, 0.5, 0.4, jax.random.PRNGKey(22))
    pool = np.asarray(C.exact_sample(m, 200, jax.random.PRNGKey(23)))
    sim = S.StreamSimulator(g, pool, scheme="diagonal",
                            arrivals=S.ArrivalSpec(rate=50.0))
    sim.run(2)
    assert np.all(np.isfinite(sim.current_estimate()))


def test_host_mesh_plan_matches_plain():
    """mesh="host" (the 1x1 shard_map path) is numerically identical to
    the plain single-program plan through the session facade — and the
    compile-reuse invariant (cold == #buckets, warm == 0) holds on the
    sharded solver path too, not just the plain one."""
    g = C.star_graph(6)
    m = C.random_model(g, 0.5, 0.4, jax.random.PRNGKey(14))
    X = C.exact_sample(m, 400, jax.random.PRNGKey(15))
    plain = A.Plan(graph=g).session().fit(X)
    _clear_solver_caches()
    sess = A.Plan(graph=g, mesh="host").session()
    meshed = sess.fit(X)
    assert meshed.new_compiles == sess.n_buckets
    warm = sess.fit(np.ascontiguousarray(np.asarray(X)[::-1]))
    assert warm.new_compiles == 0
    np.testing.assert_allclose(meshed.theta, plain.theta, atol=1e-10)
    for a, b in zip(meshed.fits, plain.fits):
        np.testing.assert_allclose(a.theta, b.theta, atol=1e-10)


def test_late_registered_combiner_streams_and_bills(monkeypatch):
    """Registry pluggability end to end: a combiner registered AFTER
    import streams through the simulator (accepted, billed by its own
    scalars_per_shared_param, fused by its combine_candidates) and plugs
    into a Plan. Registered via monkeypatch so the registry is restored."""
    import repro.stream as S
    from repro.core.combiners import (DiagonalCombiner, _REGISTRY,
                                      get_combiner)

    class HalfWeight(DiagonalCombiner):
        name = "half_weight"

        def group_weights(self, est, diag, bad, cols):
            return 0.5 / diag

        def combine_candidates(self, cands):
            w = np.array([0.5 / v for _, v in cands])
            e = np.array([e for e, _ in cands])
            return float((w @ e) / w.sum())

    monkeypatch.setitem(_REGISTRY, "half_weight", HalfWeight())
    assert get_combiner("half_weight").scalars_per_shared_param == 2
    g = C.star_graph(5)
    m = C.random_model(g, 0.5, 0.4, jax.random.PRNGKey(16))
    pool = np.asarray(C.exact_sample(m, 400, jax.random.PRNGKey(17)))
    plan = A.Plan(graph=g, combiners=("half_weight",), capacity=64)
    sim = S.StreamSimulator.from_plan(plan, pool,
                                      arrivals=S.ArrivalSpec(rate=80.0))
    res = sim.run(3)
    assert np.all(np.isfinite(res.theta))
    # billed through the live registry: 2 scalars per shared param slot,
    # exactly like diagonal
    assert sim.net.scalars_sent > 0
    assert S.one_step_message_scalars(3, "half_weight") == 6
    ref = S.StreamSimulator.from_plan(
        A.Plan(graph=g, combiners=("diagonal",), capacity=64), pool,
        arrivals=S.ArrivalSpec(rate=80.0))
    ref.run(3)
    assert sim.net.scalars_sent == ref.net.scalars_sent
