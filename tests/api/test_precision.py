"""Mixed-precision plan contract: bfloat16 is strictly opt-in, documented,
and numerically gated; float32/float64 behavior is untouched by it."""
import numpy as np
import pytest

import repro.api as A
import repro.core as C
from repro.kernels.cl.precision import (PRECISION_TOLERANCES,
                                        precision_tolerance)


def _data(g, n=400, seed=0):
    rng = np.random.default_rng(seed)
    x = np.sign(rng.standard_normal((n, g.p))).astype(np.float32)
    x[x == 0] = 1.0
    return x


def test_bfloat16_plan_round_trips():
    g = C.chain_graph(6)
    plan = A.Plan(graph=g, precision="bfloat16", combiners=("uniform",))
    assert A.Plan.from_dict(plan.to_dict()) == plan
    assert plan.to_dict()["precision"] == "bfloat16"


def test_unknown_precision_rejected():
    g = C.chain_graph(4)
    with pytest.raises(ValueError, match="precision"):
        A.Plan(graph=g, precision="float16")


def test_precision_tolerance_table():
    assert set(PRECISION_TOLERANCES) == {"float64", "float32", "bfloat16"}
    assert precision_tolerance("bfloat16") == \
        PRECISION_TOLERANCES["bfloat16"]
    with pytest.raises(ValueError, match="float8"):
        precision_tolerance("float8")


def test_bfloat16_fit_within_documented_tolerance_of_float32():
    """An end-to-end bf16 session fit (bf16 designs, f32 Gram/solver
    state) lands within the documented bfloat16 tolerance of the float32
    fit — on Gaussian data, where bf16 load quantization is real."""
    g = C.chain_graph(8)
    rng = np.random.default_rng(3)
    X = rng.standard_normal((500, g.p)).astype(np.float32)
    kw = dict(graph=g, family="gaussian", combiners=("uniform", "diagonal"))
    r32 = A.Plan(**kw).session().fit(X)
    rbf = A.Plan(precision="bfloat16", **kw).session().fit(X)
    assert np.all(np.isfinite(rbf.theta))
    err = np.max(np.abs(r32.theta - rbf.theta))
    assert err < PRECISION_TOLERANCES["bfloat16"]
    for scheme in kw["combiners"]:
        assert np.max(np.abs(r32.combined[scheme]
                             - rbf.combined[scheme])) \
            < PRECISION_TOLERANCES["bfloat16"]


def test_bfloat16_is_strictly_opt_in():
    """A float32 plan's fit is bit-identical whether or not bf16 code
    paths exist in the process — mixed precision must never leak."""
    g = C.chain_graph(6)
    X = _data(g)
    a = A.Plan(graph=g, combiners=("uniform",)).session().fit(X)
    # interleave a bf16 fit, then refit f32: still bit-identical
    A.Plan(graph=g, combiners=("uniform",),
           precision="bfloat16").session().fit(X)
    b = A.Plan(graph=g, combiners=("uniform",)).session().fit(X)
    np.testing.assert_array_equal(np.asarray(a.theta), np.asarray(b.theta))


def test_bfloat16_joint_admm_runs_finite():
    """The joint (ADMM) verb also survives bf16 designs: the proximal
    solver keeps float32 state, so iterates stay finite and close to the
    float32 run."""
    g = C.chain_graph(5)
    X = _data(g, n=300, seed=7)
    kw = dict(graph=g, combiners=("uniform",), admm_iters=5)
    t32 = A.Plan(**kw).session().joint(X)
    tbf = A.Plan(precision="bfloat16", **kw).session().joint(X)
    assert np.all(np.isfinite(tbf.theta))
    assert np.max(np.abs(np.asarray(t32.theta) - np.asarray(tbf.theta))) \
        < PRECISION_TOLERANCES["bfloat16"]
