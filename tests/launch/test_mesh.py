"""Mesh construction: the consensus mesh must fail loudly on indivisible
device counts instead of silently mis-shaping."""
import jax
import pytest

from repro.launch.mesh import make_consensus_mesh, make_host_mesh


def test_host_mesh_axes():
    mesh = make_host_mesh()
    assert tuple(mesh.axis_names) == ("data", "model")
    assert int(mesh.shape["data"]) * int(mesh.shape["model"]) == 1


def test_consensus_mesh_single_pod():
    mesh = make_consensus_mesh(n_pods=1)
    assert tuple(mesh.axis_names) == ("pod", "data", "model")
    assert int(mesh.shape["pod"]) == 1
    assert mesh.devices.size == len(jax.devices())


def test_consensus_mesh_rejects_indivisible_pods():
    """len(jax.devices()) == 1 here, so any n_pods > 1 is indivisible; the
    seed code floor-divided to per_pod == 0 and handed jax.make_mesh a
    mis-shaped request."""
    with pytest.raises(ValueError, match="divisible"):
        make_consensus_mesh(n_pods=len(jax.devices()) + 1)


def test_consensus_mesh_rejects_nonpositive_pods():
    with pytest.raises(ValueError, match="n_pods"):
        make_consensus_mesh(n_pods=0)
