"""At lambda = 0 the structure solver is the estimator.

The unpenalized end of the path must reproduce ``session.fit``'s
free-edge estimates exactly (1e-8), for EVERY registered family — the
structure layer reuses the same compiled dense solve, so any drift here
means the candidate-graph remap or the debiasing mask corrupted the
estimates. Runs as a plain parametrize over the family registry; a
hypothesis-fuzzed variant rides along when hypothesis is installed.
"""
import jax
import numpy as np
import pytest

from repro.api import Plan, StructureSpec
from repro.core import chain_graph
from repro.core.families import random_rows, registered_families

FAMILY_NAMES = [f.name for f in registered_families()]


def _dense_matches_fit(name, seed, p=5, n=200):
    g = chain_graph(p)
    spec = StructureSpec(policy="given", given_edges=g.edges,
                         lambdas=(0.0,))
    plan = Plan(graph=g, family=name, structure=spec)
    fam = plan.family_instance
    X = np.asarray(random_rows(fam, jax.random.PRNGKey(seed), n, p))

    sess = plan.session()
    fit = sess.fit(X)
    res = sess.select(X)

    # lambda 0 on the plan graph: support is the full candidate set and
    # the "debiased" thetas ARE the dense fit — same compiled program,
    # same inputs, so agreement should be essentially exact.
    assert res.lambda_selected == 0.0
    assert res.support == g.edges
    for i in range(p):
        np.testing.assert_allclose(res.thetas[i], fit.fits[i].theta,
                                   atol=1e-8, rtol=0)


@pytest.mark.parametrize("name", FAMILY_NAMES)
def test_lambda0_matches_fit_all_families(name):
    _dense_matches_fit(name, seed=11)


@pytest.mark.parametrize("name", FAMILY_NAMES)
def test_lambda0_matches_fit_property(name):
    """Hypothesis variant: same invariant under fuzzed seeds/sizes."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(seed=st.integers(0, 2**16), p=st.integers(3, 7),
               n=st.integers(64, 256))
    @hyp.settings(max_examples=5, deadline=None)
    def run(seed, p, n):
        _dense_matches_fit(name, seed=seed, p=p, n=n)

    run()
