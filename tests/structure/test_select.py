"""End-to-end ``session.select``: planted-graph recovery, warm-started
path compile invariants (cold == n_buckets, warm == 0), candidate
policies, per-call spec override, telemetry spans, and the exact vote
comm bill."""
import jax
import numpy as np
import pytest

from repro.api import Plan, StructureResult, StructureSpec
from repro.core import chain_graph, complete_graph, grid_graph
from repro.core.batched import clear_bucket_solver_caches, degree_buckets
from repro.core.families import random_rows
from repro.core.graphs import Graph
from repro.stream.costs import structure_vote_scalars
from repro.structure import candidate_graph
from repro.telemetry import TelemetrySpec


@pytest.fixture(scope="module")
def planted_grid():
    """3x3 Ising grid, couplings +-0.5 — recoverable at n=1500."""
    g = grid_graph(3, 3)
    plan = Plan(graph=g, family="ising")
    fam = plan.family_instance
    theta = np.zeros(fam.n_params(g))
    signs = np.where(np.random.RandomState(7).rand(g.m) < 0.5, 1.0, -1.0)
    theta[g.p:] = 0.5 * signs
    X = np.asarray(fam.sample(g, theta, 1500, jax.random.PRNGKey(3)))
    return g, plan, X


def test_select_recovers_planted_grid(planted_grid):
    g, plan, X = planted_grid
    spec = StructureSpec(policy="full", n_lambdas=8)
    res = plan.replace(structure=spec).session().select(X)
    m = res.edge_metrics(g.edges)
    assert m["f1"] == 1.0, m
    # the recovered graph is a real Graph, ready to re-plan
    assert isinstance(res.graph, Graph)
    assert res.graph.edges == res.support
    # margins align with the candidate set; kept edges voted positive
    assert res.margins.shape == (len(res.candidate_edges),)
    kept = {e: mg for e, mg in zip(res.candidate_edges, res.margins)
            if e in set(res.support)}
    assert all(mg >= 0 for mg in kept.values())
    # EBIC walked the whole grid
    assert res.ebic.shape == (len(res.lambdas),)
    assert res.lambda_selected in res.lambdas
    assert len(res.support_sizes) == len(res.lambdas)


def test_path_compiles_cold_eq_buckets_warm_zero(planted_grid):
    g, plan, X = planted_grid
    spec = StructureSpec(policy="full", n_lambdas=6, admm_rounds=12)
    sess = plan.replace(structure=spec).session()
    clear_bucket_solver_caches()
    cold = sess.select(X)
    n_buckets = len(degree_buckets(complete_graph(g.p)))
    # warm starts across the whole lambda path: one prox program per
    # degree bucket of the candidate graph, never per lambda
    assert cold.path_compiles == n_buckets
    assert cold.new_compiles >= cold.path_compiles
    assert 0.0 < cold.compile_s <= cold.wall_s
    warm = sess.select(np.ascontiguousarray(X[::-1]))
    assert warm.path_compiles == 0
    assert warm.new_compiles == 0
    assert warm.compile_s == 0.0
    assert warm.support == cold.support


def test_select_shares_dense_fit_programs_with_fit():
    """candidate graph == plan graph => the dense fit hits session.fit's
    compiled programs; only the prox path compiles anew."""
    p, n = 5, 200
    g = chain_graph(p)
    spec = StructureSpec(policy="given", given_edges=g.edges,
                         n_lambdas=4, admm_rounds=8)
    plan = Plan(graph=g, structure=spec)
    X = np.asarray(random_rows(plan.family_instance,
                               jax.random.PRNGKey(2), n, p))
    sess = plan.session()
    clear_bucket_solver_caches()
    sess.fit(X)
    res = sess.select(X)
    assert res.new_compiles == res.path_compiles


def test_knn_policy_screens_candidates():
    p, n = 8, 300
    g = chain_graph(p)
    spec = StructureSpec(policy="knn", knn_k=3, n_lambdas=4,
                         admm_rounds=8)
    plan = Plan(graph=g, structure=spec)
    X = np.asarray(random_rows(plan.family_instance,
                               jax.random.PRNGKey(4), n, p))
    res = plan.session().select(X)
    # screening bounds the search: each node proposed at most k, the
    # union-symmetrized candidate set is a strict subset of complete
    assert 0 < len(res.candidate_edges) < complete_graph(p).m
    assert set(res.support) <= set(res.candidate_edges)


def test_candidate_graph_knn_requires_data_and_small_k():
    spec = StructureSpec(policy="knn", knn_k=5)
    with pytest.raises(ValueError, match="knn_k must be < p"):
        candidate_graph(spec, p=5)
    with pytest.raises(ValueError, match="knn"):
        candidate_graph(spec, p=8)          # no X / family supplied


def test_per_call_spec_dict_override(planted_grid):
    g, plan, X = planted_grid
    sess = plan.session()                   # plan has NO structure spec
    res = sess.select(X, spec={"policy": "given",
                               "given_edges": tuple(g.edges),
                               "n_lambdas": 4, "admm_rounds": 8,
                               "vote": "and"})
    assert isinstance(res, StructureResult)
    assert res.vote_rule == "and"
    assert res.candidate_edges == g.edges


def test_select_rejects_wrong_width_X(planted_grid):
    g, plan, X = planted_grid
    with pytest.raises(ValueError, match="columns"):
        plan.session().select(X[:, :-1])


def test_select_telemetry_spans_and_gauges(planted_grid):
    g, plan, X = planted_grid
    spec = StructureSpec(policy="full", n_lambdas=4, admm_rounds=8)
    res = plan.replace(structure=spec,
                       telemetry=TelemetrySpec()).session().select(X)
    snap = res.telemetry
    assert snap is not None
    for path in ("select", "select/screen", "select/dense_fit",
                 "select/path", "select/vote"):
        assert path in snap.spans, path
    assert snap.gauges["structure.candidate_edges"] == complete_graph(g.p).m
    assert snap.gauges["structure.support_size"] == len(res.support)
    assert "comm.scalars_per_round" in snap.gauges


def test_comm_scalars_match_cost_table(planted_grid):
    g, plan, X = planted_grid
    for rule in ("and", "weighted"):
        spec = StructureSpec(policy="full", n_lambdas=4, admm_rounds=8,
                             vote=rule)
        res = plan.replace(structure=spec).session().select(X)
        assert res.comm_scalars == structure_vote_scalars(
            len(res.candidate_edges), rule)
