"""StructureSpec / Plan.structure validation must fail loudly and early.

Every malformed configuration the issue names — negative or unsorted
lambda grids, unknown vote rules, knn k >= p — plus the policy/edges
cross-field rules, each pinned with its pointed message so a regression
that silently accepts (or garbles the error of) a bad spec fails here.
"""
import pytest

from repro.api import Plan, StructureSpec
from repro.core import chain_graph
from repro.structure import CANDIDATE_POLICIES


# ------------------------------------------------------------ lambda grids
def test_negative_lambda_grid_rejected():
    with pytest.raises(ValueError, match="non-negative"):
        StructureSpec(lambdas=(0.5, -0.1))


def test_unsorted_lambda_grid_rejected():
    with pytest.raises(ValueError, match="strictly decreasing"):
        StructureSpec(lambdas=(0.1, 0.5, 0.2))


def test_duplicate_lambda_grid_rejected():
    # duplicates are "not strictly decreasing" too — same pointed error
    with pytest.raises(ValueError, match="strictly decreasing"):
        StructureSpec(lambdas=(0.5, 0.5, 0.1))


def test_empty_lambda_grid_rejected():
    with pytest.raises(ValueError, match="non-empty"):
        StructureSpec(lambdas=())


def test_descending_grid_with_zero_tail_accepted():
    spec = StructureSpec(lambdas=(1.0, 0.25, 0.0))
    assert spec.lambdas == (1.0, 0.25, 0.0)


# -------------------------------------------------------------- vote rules
def test_unknown_vote_rule_lists_registered():
    with pytest.raises(ValueError) as exc:
        StructureSpec(vote="majority")
    msg = str(exc.value)
    assert "majority" in msg
    for name in ("and", "or", "weighted"):
        assert name in msg, f"error should list registered rule {name!r}"


# ------------------------------------------------------- candidate policies
def test_unknown_policy_lists_choices():
    with pytest.raises(ValueError) as exc:
        StructureSpec(policy="everything")
    for name in CANDIDATE_POLICIES:
        assert name in str(exc.value)


def test_knn_k_at_least_p_rejected_by_plan():
    g = chain_graph(5)
    with pytest.raises(ValueError, match="knn_k must be < p"):
        Plan(graph=g, structure=StructureSpec(policy="knn", knn_k=5))


def test_knn_k_nonpositive_rejected():
    with pytest.raises(ValueError, match="knn_k must be >= 1"):
        StructureSpec(policy="knn", knn_k=0)


def test_given_policy_requires_edges():
    with pytest.raises(ValueError, match="given_edges"):
        StructureSpec(policy="given")


def test_given_edges_require_given_policy():
    with pytest.raises(ValueError, match="policy 'given'"):
        StructureSpec(policy="full", given_edges=((0, 1),))


def test_given_edges_validated_against_plan_graph():
    g = chain_graph(4)
    with pytest.raises(ValueError, match="not a valid"):
        Plan(graph=g, structure=StructureSpec(policy="given",
                                              given_edges=((0, 9),)))


# ----------------------------------------------------------- scalar bounds
@pytest.mark.parametrize("kw,match", [
    (dict(n_lambdas=0), "n_lambdas"),
    (dict(lambda_min_ratio=0.0), "lambda_min_ratio"),
    (dict(lambda_min_ratio=1.0), "lambda_min_ratio"),
    (dict(ebic_gamma=-0.1), "ebic_gamma"),
    (dict(ebic_gamma=1.5), "ebic_gamma"),
    (dict(admm_rounds=0), "admm_rounds"),
    (dict(admm_rho=0.0), "admm_rho"),
    (dict(admm_tol=0.0), "admm_tol"),
    (dict(newton_iters=0), "newton_iters"),
])
def test_scalar_bounds(kw, match):
    with pytest.raises(ValueError, match=match):
        StructureSpec(**kw)


# ----------------------------------------------------------- serialization
def test_spec_roundtrip():
    spec = StructureSpec(policy="given", given_edges=((0, 2), (1, 3)),
                         lambdas=(0.8, 0.2, 0.0), vote="and",
                         ebic_gamma=0.25, admm_rounds=17)
    assert StructureSpec.from_dict(spec.to_dict()) == spec


def test_spec_from_dict_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown StructureSpec fields"):
        StructureSpec.from_dict({"polciy": "full"})


def test_plan_roundtrip_with_structure():
    g = chain_graph(6)
    plan = Plan(graph=g, family="ising",
                structure=StructureSpec(policy="knn", knn_k=3, vote="or"))
    back = Plan.from_dict(plan.to_dict())
    assert back == plan
    assert hash(back) == hash(plan)          # still a session-cache key


def test_plan_coerces_structure_dict():
    g = chain_graph(6)
    plan = Plan(graph=g, structure={"policy": "full", "vote": "and"})
    assert isinstance(plan.structure, StructureSpec)
    assert plan.structure.vote == "and"


def test_plan_rejects_non_spec_structure():
    g = chain_graph(6)
    with pytest.raises(TypeError, match="StructureSpec"):
        Plan(graph=g, structure="full")
