"""Support-voting edge cases: registry contracts, unanimous disagreement,
endpoint symmetry (tie-breaking must not depend on node ids), singleton
nodes, and the exact vote-message accounting."""
import numpy as np
import pytest

from repro.api import Plan, StructureSpec
from repro.core import chain_graph
from repro.core.families import random_rows
from repro.stream.costs import structure_vote_scalars
from repro.structure import (VoteRule, get_vote_rule, reconcile,
                             register_vote_rule, registered_vote_rules)

import jax


# ---------------------------------------------------------------- registry
def test_unknown_rule_error_lists_registered():
    with pytest.raises(ValueError) as exc:
        get_vote_rule("nope")
    msg = str(exc.value)
    assert "nope" in msg and "and" in msg and "weighted" in msg


def test_registered_rules_sorted_and_complete():
    names = [r.name for r in registered_vote_rules()]
    assert names == sorted(names)
    assert {"and", "or", "weighted"} <= set(names)


def test_custom_rule_registers_and_bills():
    class Unanimous(VoteRule):
        name = "test_unanimous3"
        scalars_per_edge_vote = 3

        def decide(self, in_a, in_b, mass_a, mass_b):
            keep = in_a & in_b
            return keep, np.where(keep, 1.0, -1.0)

    try:
        register_vote_rule(Unanimous())
        assert get_vote_rule("test_unanimous3").scalars_per_edge_vote == 3
        # the cost table reads the registry — new rules billed correctly
        assert structure_vote_scalars(7, "test_unanimous3") == 2 * 7 * 3
    finally:
        from repro.structure.voting import _VOTE_RULES
        _VOTE_RULES.pop("test_unanimous3", None)


def test_vote_scalar_accounting_per_rule():
    # one decision scalar per endpoint for and/or; decision + mass for
    # weighted — exactly 2 voters per candidate edge
    assert structure_vote_scalars(10, "and") == 20
    assert structure_vote_scalars(10, "or") == 20
    assert structure_vote_scalars(10, "weighted") == 40
    assert structure_vote_scalars(0, "weighted") == 0


# ------------------------------------------------- unanimous disagreement
def test_unanimous_disagreement_and_or():
    in_a = np.array([True, True, False])
    in_b = np.array([False, False, True])     # endpoints disagree everywhere
    keep_and, m_and = reconcile(in_a, in_b, "and")
    keep_or, m_or = reconcile(in_a, in_b, "or")
    assert not keep_and.any()
    assert (m_and == -1.0).all()
    assert keep_or.all()
    assert (m_or == 1.0).all()


def test_weighted_disagreement_mass_decides():
    in_a = np.array([True, True, False])
    in_b = np.array([False, False, True])
    heavy_a = np.full(3, 4.0)
    light_b = np.full(3, 1.0)
    keep, margin = reconcile(in_a, in_b, "weighted",
                             mass_a=heavy_a, mass_b=light_b)
    # the heavier endpoint wins every disagreement
    assert list(keep) == [True, True, False]
    assert np.allclose(np.abs(margin), 0.6)   # (4 - 1) / 5


def test_weighted_exact_tie_falls_back_to_union():
    in_a = np.array([True, False])
    in_b = np.array([False, False])
    keep, margin = reconcile(in_a, in_b, "weighted")   # equal unit masses
    assert (margin == 0.0).all() or margin[1] == -1.0
    assert keep[0]          # disagreement tie -> union keeps it
    assert not keep[1]      # unanimous out stays out


def test_weighted_degenerate_masses_are_guarded():
    in_a = np.array([True, True, True])
    in_b = np.array([False, False, False])
    mass_a = np.array([np.inf, np.nan, 0.0])
    mass_b = np.array([1.0, 1.0, 0.0])
    keep, margin = reconcile(in_a, in_b, "weighted",
                             mass_a=mass_a, mass_b=mass_b)
    assert np.isfinite(margin).all()
    # all-zero masses -> margin 0 -> union fallback keeps the disputed edge
    assert keep[2]


# --------------------------------------------------- permutation symmetry
@pytest.mark.parametrize("rule", ["and", "or", "weighted"])
def test_endpoint_swap_symmetry(rule):
    """decide(a, b) == decide(b, a): no rule may break ties by which
    endpoint has the smaller node id."""
    rng = np.random.RandomState(0)
    in_a = rng.rand(64) < 0.5
    in_b = rng.rand(64) < 0.5
    mass_a = rng.rand(64) + 0.1
    # exercise exact mass ties too
    mass_b = np.where(rng.rand(64) < 0.3, mass_a, rng.rand(64) + 0.1)
    k1, m1 = reconcile(in_a, in_b, rule, mass_a=mass_a, mass_b=mass_b)
    k2, m2 = reconcile(in_b, in_a, rule, mass_a=mass_b, mass_b=mass_a)
    assert (k1 == k2).all()
    assert np.allclose(m1, m2)


def test_select_deterministic_under_node_permutation():
    """Relabeling nodes permutes the recovered support — and nothing else:
    no vote tie-break may leak node ids into the decision."""
    p, n = 6, 600
    g = chain_graph(p)
    plan = Plan(graph=g, family="ising")
    fam = plan.family_instance
    theta = np.zeros(fam.n_params(g))
    theta[g.p:] = 0.8
    X = np.asarray(fam.sample(g, theta, n, jax.random.PRNGKey(5)))
    spec = StructureSpec(policy="full", n_lambdas=5, vote="weighted",
                         admm_rounds=15)
    res = plan.replace(structure=spec).session().select(X)
    assert res.support          # a planted chain at this n recovers edges

    perm = np.array([3, 0, 5, 1, 4, 2])       # new id of each old node
    inv = np.argsort(perm)
    # relabeled dataset: new column perm[i] carries old node i
    res_p = plan.replace(structure=spec).session().select(X[:, inv])
    expected = {tuple(sorted((int(perm[i]), int(perm[j]))))
                for (i, j) in res.support}
    assert set(res_p.support) == expected
    assert res_p.lambda_selected == res.lambda_selected


# ---------------------------------------------------------- singleton nodes
def test_candidate_isolated_nodes_survive_voting():
    """Nodes with NO candidate edges (policy 'given' leaves them isolated)
    must pass through screening/path/vote untouched."""
    p, n = 5, 300
    g = chain_graph(p)
    fam = Plan(graph=g).family_instance
    X = np.asarray(random_rows(fam, jax.random.PRNGKey(6), n, p))
    spec = StructureSpec(policy="given", given_edges=((0, 1), (1, 2)),
                         n_lambdas=4, admm_rounds=10)
    res = Plan(graph=g, structure=spec).session().select(X)
    assert set(res.support) <= {(0, 1), (1, 2)}
    assert res.candidate_edges == ((0, 1), (1, 2))
    # isolated nodes 3 and 4 still have (singleton-only) estimates
    assert len(res.thetas) == p
    assert res.thetas[3].shape == (1,) and res.thetas[4].shape == (1,)
