"""Durable stream checkpoints: a fleet killed mid-stream and restored in a
fresh simulator reproduces the uninterrupted ``estimate_at(t)`` trajectory
and communication counters exactly (1e-10 is the bar; bit-identity is the
reality), through hostile scenarios included."""
import jax
import numpy as np
import pytest

import repro.checkpoint as CK
import repro.core as C
import repro.stream as S


@pytest.fixture(scope="module")
def setup():
    g = C.star_graph(6)
    m = C.random_model(g, 0.5, 0.4, jax.random.PRNGKey(2))
    pool = np.asarray(C.exact_sample(m, 900, jax.random.PRNGKey(3)))
    return g, m, pool


def _hostile():
    return S.FaultPlan(
        crashes=(S.CrashSpec(node=2, at=3, restart_at=8),),
        byzantine=(S.ByzantineSpec(node=5, kind="scaled_noise",
                                   scale=1.0),),
        replay=S.ReplaySpec(prob=0.4, delay=2),
        drift=(S.DriftSpec(at=7, scale=0.3),))


def _mk(g, pool, ts, **over):
    kw = dict(scheme="diagonal", theta_star=ts,
              network=S.NetworkConfig(drop_prob=0.4, delay=1, jitter=1),
              arrivals=S.ArrivalSpec(kind="poisson", rate=30.0),
              capacity=128, seed=11, faults=_hostile(), window=400)
    kw.update(over)
    return S.StreamSimulator(g, pool, **kw)


def test_kill_restore_reproduces_trajectory_to_1e10(setup, tmp_path):
    """Save at round 6, restore into a FRESH simulator (fresh-process
    semantics: reconstructed from configuration, state only from disk),
    run on: every estimate_at(t), error value, and comm counter matches
    the uninterrupted run to 1e-10."""
    g, m, pool = setup
    ts = np.asarray(m.theta)
    full = _mk(g, pool, ts)
    res_full = full.run(12)

    part = _mk(g, pool, ts)
    part.run(6)
    path = CK.save_stream(str(tmp_path), 6, part)
    assert CK.latest_step(str(tmp_path)) == 6

    fresh = _mk(g, pool, ts)
    CK.restore_stream(str(tmp_path), fresh)
    res2 = fresh.run(6)

    for t in range(7, 13):
        np.testing.assert_allclose(res2.estimate_at(t),
                                   res_full.estimate_at(t),
                                   atol=1e-10, rtol=0)
    np.testing.assert_allclose(res2.err, res_full.err[6:], atol=1e-10,
                               rtol=0)
    assert fresh.net.scalars_sent == full.net.scalars_sent
    assert fresh.net.msgs_delivered == full.net.msgs_delivered
    assert fresh.net.scalars_dropped == full.net.scalars_dropped
    assert path.endswith("step_6")


def test_restore_continues_replayed_and_inflight_messages(setup, tmp_path):
    """Checkpoint with messages still in flight (delay+jitter): the queue
    survives the round-trip and conservation holds after restore."""
    g, m, pool = setup
    ts = np.asarray(m.theta)
    part = _mk(g, pool, ts, network=S.NetworkConfig(delay=2, jitter=2))
    part.run(5)
    assert part.net.in_flight > 0          # the premise: owed messages
    CK.save_stream(str(tmp_path), 5, part)
    fresh = _mk(g, pool, ts, network=S.NetworkConfig(delay=2, jitter=2))
    CK.restore_stream(str(tmp_path), fresh)
    assert fresh.net.in_flight == part.net.in_flight
    fresh.run(5)
    net = fresh.net
    assert net.scalars_sent == (net.scalars_delivered + net.scalars_dropped
                                + net.scalars_in_flight)


def test_restore_rejects_mismatched_configuration(setup, tmp_path):
    g, m, pool = setup
    ts = np.asarray(m.theta)
    part = _mk(g, pool, ts)
    part.run(3)
    CK.save_stream(str(tmp_path), 3, part)
    other = _mk(g, pool, ts, scheme="uniform")
    with pytest.raises(ValueError, match="diagonal"):
        CK.restore_stream(str(tmp_path), other)


def test_load_state_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        CK.load_state(str(tmp_path / "nope"))


def test_admm_stream_checkpoint_round_trip(setup, tmp_path):
    """The streaming-ADMM mode checkpoints its primal/dual/consensus state
    too."""
    g, m, pool = setup
    ts = np.asarray(m.theta)

    def mk():
        return S.StreamSimulator(g, pool, estimator="admm", theta_star=ts,
                                 arrivals=S.ArrivalSpec(rate=50.0),
                                 capacity=128, newton_iters=8, seed=5)
    full = mk()
    res_full = full.run(8)
    part = mk()
    part.run(4)
    CK.save_stream(str(tmp_path), 4, part)
    fresh = CK.restore_stream(str(tmp_path), mk())
    res2 = fresh.run(4)
    np.testing.assert_allclose(res2.theta[-1], res_full.theta[-1],
                               atol=1e-10, rtol=0)


def test_generic_state_round_trip_preserves_json_floats(tmp_path):
    """save_state/load_state: arrays exact, meta floats repr-round-trip."""
    arrays = {"a/x": np.arange(6, dtype=np.float32).reshape(2, 3),
              "b": np.array([1.1e-300, np.pi])}
    meta = {"f": 0.1 + 0.2, "nested": {"k": [1, 2.5]}}
    CK.save_state(str(tmp_path), 0, arrays, meta)
    arrays2, meta2 = CK.load_state(str(tmp_path), 0)
    for k in arrays:
        np.testing.assert_array_equal(arrays2[k], arrays[k])
    assert meta2["f"] == 0.1 + 0.2
    assert meta2["nested"]["k"][1] == 2.5
