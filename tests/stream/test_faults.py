"""Hostile-network survival: FaultPlan validation, exact reproducibility of
hostile scenarios under one threaded PRNG key, crash/restart semantics,
Byzantine corruption vs robust combiners, replay absorption, drift +
windowed tracking, and the Plan facade carrying all of it."""
import dataclasses

import jax
import numpy as np
import pytest

import repro.core as C
import repro.stream as S
from repro.api import Plan


@pytest.fixture(scope="module")
def star_setup():
    g = C.star_graph(6)
    m = C.random_model(g, 0.5, 0.4, jax.random.PRNGKey(2))
    pool = np.asarray(C.exact_sample(m, 1000, jax.random.PRNGKey(3)))
    return g, m, pool


# ------------------------------------------------------------- validation
def test_unknown_byzantine_kind_lists_valid_options():
    with pytest.raises(ValueError) as e:
        S.ByzantineSpec(node=1, kind="gaslight")
    msg = str(e.value)
    for kind in S.BYZANTINE_KINDS:
        assert kind in msg


def test_negative_crash_time_rejected():
    with pytest.raises(ValueError, match=">= 0"):
        S.CrashSpec(node=0, at=-1)
    with pytest.raises(ValueError, match="strictly after"):
        S.CrashSpec(node=0, at=5, restart_at=5)
    with pytest.raises(ValueError):
        S.CrashSpec(node=-2, at=0)


def test_replay_and_drift_validation():
    with pytest.raises(ValueError, match=r"\[0, 1\]"):
        S.ReplaySpec(prob=1.5)
    with pytest.raises(ValueError, match=">= 1"):
        S.ReplaySpec(prob=0.5, delay=0)
    with pytest.raises(ValueError, match=">= 0"):
        S.DriftSpec(at=-3)
    with pytest.raises(ValueError, match="finite"):
        S.DriftSpec(at=2, scale=float("inf"))


def test_trim_fraction_validation():
    from repro.core.combiners import TrimmedMeanCombiner
    for bad in (0.5, 0.7, -0.1):
        with pytest.raises(ValueError, match=r"\[0.0, 0.5\)"):
            TrimmedMeanCombiner(trim=bad)
    with pytest.raises(ValueError, match="kappa"):
        TrimmedMeanCombiner(kappa=0.0)


def test_window_discount_validation(star_setup):
    g, m, pool = star_setup
    with pytest.raises(ValueError, match="window"):
        S.StreamingEstimator(g, window=0)
    with pytest.raises(ValueError, match="discount"):
        S.StreamingEstimator(g, discount=0.0)
    with pytest.raises(ValueError, match="discount"):
        S.StreamingEstimator(g, discount=1.5)


def test_fault_spec_off_graph_node_rejected(star_setup):
    g, m, pool = star_setup
    fp = S.FaultPlan(crashes=(S.CrashSpec(node=g.p, at=0),))
    with pytest.raises(ValueError, match="nodes"):
        S.StreamSimulator(g, pool, faults=fp)


def test_drift_without_theta_star_rejected(star_setup):
    g, m, pool = star_setup
    fp = S.FaultPlan(drift=(S.DriftSpec(at=2),))
    with pytest.raises(ValueError, match="theta_star"):
        S.StreamSimulator(g, pool, faults=fp)


def test_fault_plan_serialization_round_trips():
    fp = S.FaultPlan(
        crashes=(S.CrashSpec(node=2, at=3, restart_at=6),
                 S.CrashSpec(node=4, at=1)),
        byzantine=(S.ByzantineSpec(node=5, kind="scaled_noise", scale=2.5),
                   S.ByzantineSpec(node=1, kind="fixed_value", value=-1.0)),
        replay=S.ReplaySpec(prob=0.25, delay=4),
        drift=(S.DriftSpec(at=7, scale=0.4),))
    assert S.FaultPlan.from_dict(fp.to_dict()) == fp
    assert hash(fp) == hash(S.FaultPlan.from_dict(fp.to_dict()))
    assert S.FaultPlan().empty and not fp.empty


def test_plan_facade_carries_faults_and_windows(star_setup):
    g, m, pool = star_setup
    fp = S.FaultPlan(byzantine=(S.ByzantineSpec(node=5),),
                     replay=S.ReplaySpec(prob=0.1, delay=2))
    plan = Plan(graph=g, combiners=("trimmed_mean",), faults=fp,
                stream_window=64, stream_discount=0.98)
    again = Plan.from_dict(plan.to_dict())
    assert again == plan and hash(again) == hash(plan)
    sim = S.StreamSimulator.from_plan(plan, pool)
    assert sim.faults == fp
    assert sim.est.window == 64 and sim.est.discount == 0.98
    assert sim.scheme == "trimmed_mean"
    est = plan.session().stream()
    assert est.window == 64 and est.discount == 0.98
    with pytest.raises(ValueError, match="stream_window"):
        Plan(graph=g, stream_window=0)
    with pytest.raises(ValueError, match="stream_discount"):
        Plan(graph=g, stream_discount=2.0)


# -------------------------------------------------------- reproducibility
def _hostile_plan():
    return S.FaultPlan(
        crashes=(S.CrashSpec(node=2, at=3, restart_at=6),),
        byzantine=(S.ByzantineSpec(node=5, kind="scaled_noise", start=2,
                                   scale=1.5),),
        replay=S.ReplaySpec(prob=0.5, delay=2),
        drift=(S.DriftSpec(at=5, scale=0.3),))


def test_hostile_runs_replay_exactly_from_one_seed(star_setup):
    """ONE threaded PRNG key: the same seed reproduces an entire hostile
    scenario — arrival draws, drops/jitter, Byzantine noise, replay
    coin-flips, drift perturbation — bit for bit; a different seed does
    not."""
    g, m, pool = star_setup

    def run(seed):
        sim = S.StreamSimulator(
            g, pool, scheme="trimmed_mean", theta_star=np.asarray(m.theta),
            network=S.NetworkConfig(drop_prob=0.3, jitter=1),
            arrivals=S.ArrivalSpec(kind="poisson", rate=40.0),
            capacity=128, seed=seed, faults=_hostile_plan())
        return sim.run(10)

    a, b, c = run(7), run(7), run(8)
    np.testing.assert_array_equal(a.theta, b.theta)
    np.testing.assert_array_equal(a.scalars_sent, b.scalars_sent)
    np.testing.assert_array_equal(a.err, b.err)
    assert not np.array_equal(a.theta, c.theta)


def test_explicit_network_seed_keeps_legacy_stream(star_setup):
    """NetworkConfig(seed=int) still pins a private legacy generator:
    simulator-level seeds must not change the link/drop draws."""
    g, m, pool = star_setup
    runs = []
    for sim_seed in (0, 123):
        sim = S.StreamSimulator(
            g, pool[:300], scheme="diagonal",
            network=S.NetworkConfig(drop_prob=0.5, seed=9),
            arrivals=S.ArrivalSpec(rate=30.0), capacity=128, seed=sim_seed)
        sim.run(5)
        runs.append((sim.net.msgs_dropped, sim.net.msgs_sent))
    assert runs[0] == runs[1]


# ------------------------------------------------------- crash semantics
def test_crashed_node_stops_sampling_and_talking(star_setup):
    g, m, pool = star_setup
    fp = S.FaultPlan(crashes=(S.CrashSpec(node=3, at=0),))
    sim = S.StreamSimulator(g, pool, scheme="diagonal", faults=fp,
                            arrivals=S.ArrivalSpec(rate=30.0), capacity=128)
    sim.run(6)
    assert sim.est.counts[3] == 0
    # no message from node 3 was ever processed by any receiver
    assert all(src != 3 for (dst, src) in sim._view)


def test_crash_restart_resumes_sampling(star_setup):
    g, m, pool = star_setup
    fp = S.FaultPlan(crashes=(S.CrashSpec(node=3, at=2, restart_at=5),))
    sim = S.StreamSimulator(g, pool, scheme="diagonal", faults=fp,
                            arrivals=S.ArrivalSpec(rate=20.0), capacity=128)
    sim.run(3)
    down_counts = int(sim.est.counts[3])
    sim.run(5)
    assert int(sim.est.counts[3]) > down_counts        # resumed after restart
    # and the 3-round outage cost exactly 3 rounds of arrivals
    assert int(sim.est.counts[3]) == int(sim.est.counts[1]) - 3 * 20


# -------------------------------------------------- byzantine vs robust
def test_robust_combiners_survive_sign_flip_uniform_does_not(star_setup):
    """Byzantine leaves sign-flip their outbound estimates: the hub's
    uniform average of (theta, -theta) collapses toward 0, while anchored
    trimmed-mean/krum fusion rejects the lies and tracks the fault-free
    error."""
    g, m, pool = star_setup
    ts = np.asarray(m.theta)
    fp = S.FaultPlan(byzantine=(S.ByzantineSpec(node=4, kind="sign_flip"),
                                S.ByzantineSpec(node=5, kind="sign_flip")))
    err = {}
    for scheme in ("uniform", "trimmed_mean", "krum"):
        clean = S.StreamSimulator(g, pool, scheme=scheme, theta_star=ts,
                                  arrivals=S.ArrivalSpec(rate=60.0),
                                  capacity=128).run(8)
        hostile = S.StreamSimulator(g, pool, scheme=scheme, theta_star=ts,
                                    arrivals=S.ArrivalSpec(rate=60.0),
                                    capacity=128, faults=fp).run(8)
        err[scheme] = (float(clean.err[-1]), float(hostile.err[-1]))
    # robust schemes: hostile within 2x of fault-free
    for scheme in ("trimmed_mean", "krum"):
        clean_e, hostile_e = err[scheme]
        assert hostile_e <= 2.0 * clean_e + 1e-6, (scheme, err[scheme])
    # uniform: the lies dominate its error
    assert err["uniform"][1] > 5.0 * err["uniform"][0]
    assert err["uniform"][1] > 3.0 * err["trimmed_mean"][1]


def test_colluding_fixed_value_rejected_by_trimmed_mean(star_setup):
    g, m, pool = star_setup
    ts = np.asarray(m.theta)
    fp = S.FaultPlan(byzantine=(
        S.ByzantineSpec(node=4, kind="fixed_value", value=3.0),
        S.ByzantineSpec(node=5, kind="fixed_value", value=3.0)))
    hostile = S.StreamSimulator(g, pool, scheme="trimmed_mean",
                                theta_star=ts,
                                arrivals=S.ArrivalSpec(rate=60.0),
                                capacity=128, faults=fp).run(8)
    clean = S.StreamSimulator(g, pool, scheme="trimmed_mean", theta_star=ts,
                              arrivals=S.ArrivalSpec(rate=60.0),
                              capacity=128).run(8)
    assert float(hostile.err[-1]) <= 2.0 * float(clean.err[-1]) + 1e-6


# ----------------------------------------------------------------- replay
def test_replayed_stale_messages_are_billed_and_absorbed(star_setup):
    """Certain replay: every successful send re-injects the previous
    payload. Bandwidth goes up, conservation holds, and the
    freshest-version-wins rule keeps every view at the final version."""
    g, m, pool = star_setup
    fp = S.FaultPlan(replay=S.ReplaySpec(prob=1.0, delay=2))
    sim = S.StreamSimulator(g, pool[:200], scheme="diagonal", faults=fp,
                            arrivals=S.ArrivalSpec(rate=100.0),
                            capacity=128)
    sim.run(20)
    base = S.StreamSimulator(g, pool[:200], scheme="diagonal",
                             arrivals=S.ArrivalSpec(rate=100.0),
                             capacity=128)
    base.run(20)
    assert sim.net.scalars_sent > base.net.scalars_sent
    net = sim.net
    assert net.scalars_sent == (net.scalars_delivered + net.scalars_dropped
                                + net.scalars_in_flight)
    final_versions = {i: int(sim.est.versions[i]) for i in range(g.p)}
    for (dst, src), view in sim._view.items():
        assert view["version"] == final_versions[src]


# ------------------------------------------------------------------ drift
def test_drift_changes_truth_and_unseen_pool_only(star_setup):
    g, m, pool = star_setup
    ts = np.asarray(m.theta)
    fp = S.FaultPlan(drift=(S.DriftSpec(at=3, scale=0.5),))
    sim = S.StreamSimulator(g, pool, scheme="diagonal", theta_star=ts,
                            arrivals=S.ArrivalSpec(rate=50.0), capacity=256,
                            faults=fp)
    sim.run(2)
    seen_before = sim.pool[:sim._fed].copy()
    sim.run(4)
    assert not np.array_equal(sim.theta_star, ts)       # truth jumped
    # rows revealed before the change-point kept their original draw
    np.testing.assert_array_equal(sim.pool[:len(seen_before)], seen_before)
    # the caller's pool was never mutated
    np.testing.assert_array_equal(
        pool, np.asarray(C.exact_sample(m, 1000, jax.random.PRNGKey(3))))
    assert np.all(np.isfinite(sim.run(2).err))


def test_windowed_refit_tracks_drift_better_than_infinite_memory(
        star_setup):
    """After a large change-point, a sliding-window stream (which forgets
    the stale regime) ends closer to the drifted truth than the
    infinite-memory stream averaging both regimes."""
    g, m, pool = star_setup
    ts = np.asarray(m.theta)
    fp = S.FaultPlan(drift=(S.DriftSpec(at=6, scale=1.0),))
    kw = dict(scheme="diagonal", theta_star=ts,
              arrivals=S.ArrivalSpec(rate=60.0), capacity=1024, faults=fp,
              seed=4)
    plain = S.StreamSimulator(g, pool, **kw).run(16)
    windowed = S.StreamSimulator(g, pool, window=200, **kw).run(16)
    assert float(windowed.err[-1]) < float(plain.err[-1])


# ------------------------------------------------- window weight algebra
def test_window_weights_shapes_and_composition():
    buf = S.SampleBuffer(3, capacity=8)
    buf.append(np.ones((6, 3), dtype=np.float32))
    counts = np.array([5, 2, 0])
    w = buf.window_weights(counts, window=3)
    np.testing.assert_array_equal(w.sum(axis=1), [3, 2, 0])
    np.testing.assert_array_equal(w[0], [0, 0, 1, 1, 1, 0, 0, 0])
    d = buf.window_weights(counts, discount=0.5)
    np.testing.assert_allclose(d[0, :5], [0.0625, 0.125, 0.25, 0.5, 1.0])
    assert not d[0, 5:].any()
    both = buf.window_weights(counts, window=2, discount=0.5)
    np.testing.assert_allclose(both[0], [0, 0, 0, 0.5, 1.0, 0, 0, 0])
    # plain call is exactly the prefix mask
    np.testing.assert_array_equal(buf.window_weights(counts),
                                  buf.prefix_masks(counts))


def test_windowed_fit_equals_fit_on_window_rows(star_setup):
    """A window-w node fit equals the plain fit on its last w rows."""
    import jax.numpy as jnp
    from repro.core.batched import fit_all_local_batched
    g, m, pool = star_setup
    est = S.StreamingEstimator(g, capacity=64, window=150)
    est.ingest(pool[:400])
    est.refit()
    ref = fit_all_local_batched(g, jnp.asarray(pool[250:400]))
    for i in (0, g.p - 1):
        np.testing.assert_allclose(est.fits[i].theta, ref[i].theta,
                                   atol=2e-4)
