"""Simulator invariants: perfect-network streaming reproduces the global
one-step consensus, lossy/stale networks degrade gracefully (finite,
improving), and measured communication matches the shared cost accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
import repro.stream as S


@pytest.fixture(scope="module")
def star_setup():
    g = C.star_graph(8)
    m = C.random_model(g, 0.5, 0.4, jax.random.PRNGKey(2))
    pool = np.asarray(C.exact_sample(m, 1200, jax.random.PRNGKey(3)))
    return g, m, pool


@pytest.mark.parametrize("scheme", S.ONE_STEP_SCHEMES)
def test_perfect_network_equals_global_combine(star_setup, scheme):
    """No drops, no delay: the home-sensor streamed estimate is exactly the
    global one-step combine on the data everyone has seen."""
    g, m, pool = star_setup
    sim = S.StreamSimulator(g, pool, scheme=scheme,
                            theta_star=np.asarray(m.theta),
                            arrivals=S.ArrivalSpec(rate=120.0), capacity=128)
    res = sim.run(8)
    n = int(res.samples_seen[-1])
    fits = C.fit_all_local(g, jnp.asarray(pool[:n]))
    ref = C.combine(g, fits, scheme)
    np.testing.assert_allclose(res.theta[-1], ref, atol=1e-5)


def test_error_decreases_with_data(star_setup):
    g, m, pool = star_setup
    sim = S.StreamSimulator(g, pool, scheme="diagonal",
                            theta_star=np.asarray(m.theta),
                            arrivals=S.ArrivalSpec(rate=100.0), capacity=128)
    res = sim.run(10)
    assert np.all(np.isfinite(res.err))
    assert res.err[-1] < res.err[0]


def test_lossy_stale_network_degrades_gracefully(star_setup):
    """Drops + delay + gossip scheduling: estimates stay finite, views go
    stale but bounded, and error still improves in expectation."""
    g, m, pool = star_setup
    sim = S.StreamSimulator(
        g, pool, scheme="diagonal", theta_star=np.asarray(m.theta),
        network=S.NetworkConfig(drop_prob=0.5, delay=2, jitter=2,
                                link_prob=0.7, seed=11),
        arrivals=S.ArrivalSpec(kind="poisson", rate=40.0), capacity=128,
        seed=5)
    res = sim.run(14)
    assert np.all(np.isfinite(res.theta))
    assert np.all(np.isfinite(res.err))
    assert res.err[-1] < res.err[0]
    assert np.all(res.staleness >= 0.0)


def test_comm_accounting_matches_shared_table(star_setup):
    """One full broadcast round transmits exactly the one-step row of the
    combinatorial comm-cost table — same accounting, two code paths."""
    g, m, pool = star_setup
    rounds = 5
    for scheme, key in (("uniform", "one_step_linear"),
                        ("diagonal", "diagonal_or_max")):
        sim = S.StreamSimulator(g, pool, scheme=scheme,
                                arrivals=S.ArrivalSpec(rate=20.0),
                                capacity=128)
        sim.run(rounds)
        table = S.comm_costs(g, int(sim.est.counts.max()), 20)
        assert sim.net.scalars_sent == rounds * table[key]


def test_heterogeneous_rates_weight_by_data(star_setup):
    """diagonal weights are the estimator variance V_aa/n_i: an owner with
    100x the data dominates the combined edge estimate."""
    g, m, pool = star_setup
    rates = [200.0] + [2.0] * (g.p - 1)       # hub fast, leaves slow
    sim = S.StreamSimulator(g, pool, scheme="diagonal",
                            theta_star=np.asarray(m.theta),
                            arrivals=S.ArrivalSpec(rate=tuple(rates)),
                            capacity=128)
    res = sim.run(5)
    fits = sim.est.fits
    counts = sim.est.counts
    owners = C.param_owners(g)
    for a, own in owners.items():
        if len(own) < 2:
            continue
        cands, plain = [], []
        hub_idx = None
        for (node, pos) in own:
            est = float(fits[node].theta[pos])
            v = float(fits[node].V[pos, pos])
            if np.isfinite(est) and np.isfinite(v) and abs(est) <= 25.0:
                if node == 0:
                    hub_idx = len(cands)
                cands.append((est, max(v / max(int(counts[node]), 1),
                                       1e-12)))
                plain.append(max(v, 1e-12))
        w = np.array([1.0 / v for _, v in cands])
        expect = float(w @ np.array([e for e, _ in cands]) / w.sum())
        np.testing.assert_allclose(res.theta[-1][a], expect, atol=1e-6)
        # the data-rich hub's weight share must beat what the asymptotic
        # V_aa alone (the pre-fix weighting) would have granted it
        if hub_idx is not None and len(cands) == 2:
            w_plain = 1.0 / np.array(plain)
            assert (w[hub_idx] / w.sum()
                    > w_plain[hub_idx] / w_plain.sum())


def test_zero_data_owner_cannot_dominate(star_setup):
    """An owner with no observations reports V_aa = 0; that is 'no
    information', and it must be excluded — not granted 1/eps weight that
    collapses the shared estimate to its theta = 0."""
    g, m, pool = star_setup
    rates = [0.0] + [100.0] * (g.p - 1)       # the hub (every edge's home
    for scheme in ("diagonal", "max"):        # owner) never observes
        sim = S.StreamSimulator(g, pool, scheme=scheme,
                                theta_star=np.asarray(m.theta),
                                arrivals=S.ArrivalSpec(rate=tuple(rates)),
                                capacity=128)
        res = sim.run(4)
        fits = sim.est.fits
        owners = C.param_owners(g)
        for a, own in owners.items():
            if len(own) < 2:
                continue
            leaf = max(node for node, _ in own)
            pos = fits[leaf].beta.index(a)
            expect = float(fits[leaf].theta[pos])
            if np.isfinite(expect) and abs(expect) <= 25.0:
                np.testing.assert_allclose(res.theta[-1][a], expect,
                                           atol=1e-6)


def test_dropped_update_is_retransmitted(star_setup):
    """A version whose message was dropped stays owed: with the pool
    exhausted (versions frozen) the link keeps retrying until a copy lands,
    so every view eventually reaches the final version."""
    g, m, pool = star_setup
    sim = S.StreamSimulator(g, pool[:200], scheme="diagonal",
                            network=S.NetworkConfig(drop_prob=0.6, seed=9),
                            arrivals=S.ArrivalSpec(rate=100.0), capacity=128)
    sim.run(25)     # pool exhausts after 2 rounds; 23 retry rounds follow
    final_versions = {i: int(sim.est.versions[i]) for i in range(g.p)}
    for (i, j) in sim.net.links:
        view = sim._view.get((j, i))
        assert view is not None and view["version"] == final_versions[i]


def test_gossip_link_refusal_spends_no_bandwidth(star_setup):
    g, m, pool = star_setup
    sim = S.StreamSimulator(g, pool, scheme="uniform",
                            network=S.NetworkConfig(link_prob=0.0, seed=1),
                            arrivals=S.ArrivalSpec(rate=20.0), capacity=128)
    sim.run(4)
    assert sim.net.scalars_sent == 0
    assert sim.net.msgs_sent == 0


def test_streaming_admm_converges(star_setup):
    g, m, pool = star_setup
    sim = S.StreamSimulator(g, pool, estimator="admm",
                            theta_star=np.asarray(m.theta),
                            arrivals=S.ArrivalSpec(rate=80.0), capacity=128,
                            newton_iters=12)
    res = sim.run(10)
    assert np.all(np.isfinite(res.theta))
    assert res.err[-1] < res.err[0]


def test_estimate_at_anytime_queries(star_setup):
    g, m, pool = star_setup
    sim = S.StreamSimulator(g, pool, scheme="max",
                            theta_star=np.asarray(m.theta),
                            arrivals=S.ArrivalSpec(rate=50.0), capacity=128)
    res = sim.run(6)
    np.testing.assert_array_equal(res.estimate_at(3), res.theta[2])
    np.testing.assert_array_equal(res.estimate_at(res.rounds[0]),
                                  res.theta[0])
    np.testing.assert_array_equal(res.estimate_at(99), res.theta[-1])


def test_estimate_at_before_first_round_returns_initial(star_setup):
    """Both edges of the any-time query range: a query earlier than the
    first recorded round returns the documented initial estimate (the
    pre-data report — theta_fixed for a fresh simulator), never an index
    error or a peek at the first snapshot; a query exactly at the first
    recorded round returns that snapshot."""
    g, m, pool = star_setup
    sim = S.StreamSimulator(g, pool, scheme="diagonal",
                            theta_star=np.asarray(m.theta),
                            arrivals=S.ArrivalSpec(rate=50.0), capacity=128)
    res = sim.run(5, record_every=2)        # snapshots at rounds 2, 4, 5
    first = int(res.rounds[0])
    assert first > 0
    for t in (first - 1, 0, -3):            # strictly earlier than any
        got = res.estimate_at(t)
        np.testing.assert_array_equal(got, res.initial)
    np.testing.assert_array_equal(res.initial, np.zeros(g.n_params))
    np.testing.assert_array_equal(res.estimate_at(first), res.theta[0])
    # legacy results without a recorded initial fall back to the earliest
    # snapshot instead of raising
    legacy = S.StreamResult(
        rounds=res.rounds, theta=res.theta, samples_seen=res.samples_seen,
        samples_total=res.samples_total, scalars_sent=res.scalars_sent,
        err=res.err, score_norm=res.score_norm, staleness=res.staleness)
    np.testing.assert_array_equal(legacy.estimate_at(0), res.theta[0])


def test_dropped_messages_leave_views_stale_not_empty(star_setup):
    """With certain drop, receivers never see peers: the home estimate falls
    back to the home fit alone and stays finite."""
    g, m, pool = star_setup
    sim = S.StreamSimulator(g, pool, scheme="diagonal",
                            theta_star=np.asarray(m.theta),
                            network=S.NetworkConfig(drop_prob=1.0, seed=0),
                            arrivals=S.ArrivalSpec(rate=60.0), capacity=128)
    res = sim.run(6)
    assert sim.net.msgs_delivered == 0
    assert np.all(np.isfinite(res.theta))
    # home-only estimates: every parameter reports its home node's own fit
    fits = sim.est.fits
    owners = C.param_owners(g)
    for a, own in owners.items():
        home = min(node for node, _ in own)
        pos = fits[home].beta.index(a)
        expect = float(fits[home].theta[pos])
        if abs(expect) <= 25.0:
            np.testing.assert_allclose(res.theta[-1][a], expect, atol=1e-6)


def test_bad_inputs_rejected(star_setup):
    g, m, pool = star_setup
    with pytest.raises(ValueError):
        S.StreamSimulator(g, pool, estimator="nope")
    with pytest.raises(ValueError):
        S.StreamSimulator(g, pool, scheme="optimal")
    with pytest.raises(ValueError):
        S.ArrivalSpec(kind="weird").draw(np.random.RandomState(0), 3)
