"""Hypothesis property tests for the streaming substrate.

* :class:`SampleBuffer` — capacity doubling preserves prefix contents
  exactly, padding rows stay zero, and prefix masks cover exactly the
  counted rows;
* ``cl_score_padded`` / the channelized fused pipeline — zero-padded buffer
  rows are invisible to the fused score for EVERY registered family (Ising
  residuals vanish on zero rows; the Gram ignores padding for every kind
  because the padded feature rows are zero — for Potts because state 0 is
  the all-zero reference indicator row);
* :class:`Network` — exact scalar/message conservation:
  sent == delivered + dropped + in-flight at every point, and in-flight
  drains to zero.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.core as C  # noqa: E402
from repro.kernels.cl.family import (family_kernel_inputs,  # noqa: E402
                                     family_score_stats)
from repro.kernels.cl.score import (cl_score,  # noqa: E402
                                    cl_score_padded)
from repro.stream.buffer import SampleBuffer  # noqa: E402
from repro.stream.network import Network, NetworkConfig  # noqa: E402


# ------------------------------------------------------------------ buffer
@given(
    p=st.integers(1, 6),
    capacity=st.integers(1, 8),
    sizes=st.lists(st.integers(1, 37), min_size=1, max_size=8),
    seed=st.integers(0, 2 ** 16),
)
@settings(max_examples=40, deadline=None)
def test_buffer_growth_preserves_prefix_exactly(p, capacity, sizes, seed):
    rng = np.random.RandomState(seed)
    buf = SampleBuffer(p, capacity=capacity)
    chunks = []
    for size in sizes:
        chunk = rng.randn(size, p).astype(np.float32)
        before = buf.rows.copy()
        buf.append(chunk)
        chunks.append(chunk)
        # the prefix that existed before the append (possibly across a
        # capacity doubling) is bit-identical afterwards
        np.testing.assert_array_equal(buf.rows[: len(before)], before)
    all_rows = np.concatenate(chunks, axis=0)
    assert buf.n == len(all_rows)
    np.testing.assert_array_equal(buf.rows, all_rows)
    # capacity grew by doubling only, and padding is exactly zero
    cap = buf.capacity
    while cap > capacity:
        assert cap % 2 == 0
        cap //= 2
    assert cap == capacity
    assert not buf.data[buf.n:].any()


@given(
    p=st.integers(1, 5),
    n=st.integers(0, 30),
    seed=st.integers(0, 2 ** 16),
)
@settings(max_examples=30, deadline=None)
def test_buffer_prefix_masks_cover_exactly_counts(p, n, seed):
    rng = np.random.RandomState(seed)
    buf = SampleBuffer(p, capacity=4)
    if n:
        buf.append(np.sign(rng.randn(n, p)).astype(np.float32))
    counts = rng.randint(0, n + 1, size=p)
    masks = buf.prefix_masks(counts)
    assert masks.shape == (p, buf.capacity)
    np.testing.assert_array_equal(masks.sum(axis=1), counts)
    # each row is a 0/1 prefix indicator, nothing else
    for i in range(p):
        np.testing.assert_array_equal(
            masks[i], (np.arange(buf.capacity) < counts[i]).astype(
                np.float32))
    with pytest.raises(ValueError):
        buf.prefix_masks(np.array([n + 1] * p))


# ---------------------------------------------------- padded-score kernel
@given(
    n=st.integers(1, 24),
    pad=st.integers(0, 40),
    p=st.integers(2, 8),
    seed=st.integers(0, 2 ** 16),
)
@settings(max_examples=25, deadline=None)
def test_zero_padded_rows_invisible_to_fused_score(n, pad, p, seed):
    """ising_cl_score_padded over a zero-padded buffer == the exact-rows
    score: eta/r agree on live rows, r is zero on padding, S matches after
    the live-count renormalization."""
    rng = np.random.RandomState(seed)
    x = np.sign(rng.randn(n, p)).astype(np.float32)
    x[x == 0] = 1.0
    theta = (0.3 * rng.randn(p, p)).astype(np.float32)
    theta = (theta + theta.T) / 2
    mask = (rng.rand(p, p) < 0.5).astype(np.float32)
    mask = np.triu(mask, 1) + np.triu(mask, 1).T
    bias = (0.2 * rng.randn(p)).astype(np.float32)

    x_pad = np.zeros((n + pad, p), dtype=np.float32)
    x_pad[:n] = x
    eta_p, r_p, S_p = cl_score_padded(jnp.asarray(x_pad), jnp.asarray(theta),
                                      jnp.asarray(mask), jnp.asarray(bias),
                                      n, kind="ising")
    eta, r, S = cl_score(jnp.asarray(x), jnp.asarray(theta),
                         jnp.asarray(mask), jnp.asarray(bias), kind="ising")
    np.testing.assert_allclose(np.asarray(eta_p)[:n], np.asarray(eta),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(r_p)[:n], np.asarray(r), atol=1e-5)
    # ising residuals of zero rows are exactly zero — padding is invisible
    assert not np.asarray(r_p)[n:].any()
    np.testing.assert_allclose(np.asarray(S_p), np.asarray(S),
                               atol=1e-4, rtol=1e-4)


@given(
    n=st.integers(1, 16),
    pad=st.integers(0, 24),
    p=st.integers(2, 7),
    bm=st.sampled_from([8, 16]),
    bnk=st.sampled_from([8, 16]),
    seed=st.integers(0, 2 ** 16),
)
@settings(max_examples=10, deadline=None)
def test_zero_padded_rows_invisible_under_edge_tiles(n, pad, p, bm, bnk,
                                                     seed):
    """Buffer zero-padding stays invisible when the kernel ALSO pads for
    tile alignment: the fused score with tiles that do not divide the
    (already padded) buffer shape equals the exact-rows reference. The two
    padding layers — streaming rows and tiling edge tiles — compose."""
    from repro.kernels.cl.autotune import TileConfig
    from repro.kernels.cl.kernel import cl_score_channels
    from repro.kernels.cl.ref import cl_score_channels_ref
    rng = np.random.RandomState(seed)
    x = np.sign(rng.randn(n, p)).astype(np.float32)
    x[x == 0] = 1.0
    theta = (0.3 * rng.randn(p, p)).astype(np.float32)
    theta = (theta + theta.T) / 2
    mask = np.triu((rng.rand(p, p) < 0.5), 1).astype(np.float32)
    mask = mask + mask.T
    bias = (0.2 * rng.randn(p)).astype(np.float32)
    x_pad = np.zeros((n + pad, p), dtype=np.float32)
    x_pad[:n] = x

    tiles = TileConfig(bm=bm, bn=bnk, bk=bnk)
    eta_p, r_p, S_p = cl_score_channels(
        jnp.asarray(x_pad)[None], jnp.asarray(theta)[None],
        jnp.asarray(mask), jnp.asarray(bias)[None], kind="ising",
        interpret=True, tiles=tiles)
    _, _, S = cl_score_channels_ref(
        jnp.asarray(x)[None], jnp.asarray(theta)[None], jnp.asarray(mask),
        jnp.asarray(bias)[None], kind="ising")
    # rescale the buffer-capacity normalizer to the live count
    scale = (n + pad) / n
    np.testing.assert_allclose(np.asarray(S_p)[0, 0] * scale,
                               np.asarray(S)[0, 0], atol=1e-4, rtol=1e-4)
    assert not np.asarray(r_p)[0, n:].any()


@pytest.mark.parametrize("fam", C.registered_families(),
                         ids=lambda f: f.name)
@given(
    n=st.integers(1, 20),
    pad=st.integers(0, 32),
    seed=st.integers(0, 2 ** 16),
)
@settings(max_examples=12, deadline=None)
def test_zero_padded_rows_invisible_every_family(fam, n, pad, seed):
    """The family-generic fused pipeline: for EVERY registered family
    (multi-channel Potts included), a zero-padded buffer yields the same
    eta/r on live rows and the same renormalized cross-channel score Gram
    as the exact-rows kernel, <= 1e-5."""
    p = 6
    g = C.grid_graph(2, 3)
    theta = np.asarray(fam.random_params(g, jax.random.PRNGKey(seed % 997)),
                       dtype=np.float32)
    x = np.asarray(C.random_rows(fam, jax.random.PRNGKey(seed), n, p),
                   dtype=np.float32)
    x_pad = np.zeros((n + pad, p), dtype=np.float32)
    x_pad[:n] = x

    eta, r, S = family_score_stats(fam, g, theta, jnp.asarray(x))
    eta_p, r_p, S_p = family_score_stats(fam, g, theta, jnp.asarray(x_pad))
    S_p = np.asarray(S_p) * ((n + pad) / n)         # live-count renorm
    np.testing.assert_allclose(np.asarray(eta_p)[:, :n], np.asarray(eta),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(r_p)[:, :n], np.asarray(r),
                               atol=1e-5)
    np.testing.assert_allclose(S_p, np.asarray(S), atol=1e-5, rtol=1e-5)
    # padded feature rows really are all-zero (state 0 = reference state)
    F_pad = family_kernel_inputs(fam, g, theta, jnp.asarray(x_pad))[0]
    assert not np.asarray(F_pad)[:, n:].any()


# ----------------------------------------------------------------- network
_LINKS = [(0, 1), (1, 0), (1, 2), (2, 1), (0, 2)]


@given(
    drop=st.floats(0.0, 1.0),
    delay=st.integers(0, 3),
    jitter=st.integers(0, 2),
    sends=st.lists(
        st.tuples(st.integers(0, len(_LINKS) - 1), st.integers(0, 17)),
        min_size=0, max_size=40),
    seed=st.integers(0, 2 ** 16),
)
@settings(max_examples=50, deadline=None)
def test_network_scalar_conservation(drop, delay, jitter, sends, seed):
    """Every scalar sent is accounted for: delivered, dropped, or still in
    flight — at every round, and in-flight drains to zero."""
    net = Network(_LINKS, NetworkConfig(drop_prob=drop, delay=delay,
                                        jitter=jitter, seed=seed))
    rnd = 0
    for link_idx, n_scalars in sends:
        src, dst = _LINKS[link_idx]
        net.send(rnd, src, dst, {"round": rnd}, n_scalars)
        net.deliver(rnd)
        assert net.scalars_sent == (net.scalars_delivered
                                    + net.scalars_dropped
                                    + net.scalars_in_flight)
        assert net.msgs_sent == (net.msgs_delivered + net.msgs_dropped
                                 + net.in_flight)
        rnd += 1
    # drain: everything still queued becomes deliverable eventually
    net.deliver(rnd + delay + jitter + 1)
    assert net.in_flight == 0 and net.scalars_in_flight == 0
    assert net.scalars_sent == net.scalars_delivered + net.scalars_dropped
    assert net.msgs_sent == net.msgs_delivered + net.msgs_dropped
