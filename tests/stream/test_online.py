"""Streaming invariants of the online estimator bank: chunked ingestion
reproduces the batch fit, heterogeneous prefixes match per-node subset fits,
and the fused-kernel score diagnostic equals autodiff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
import repro.stream as S
from repro.core.ising import pseudo_loglik


@pytest.fixture(scope="module")
def grid_setup():
    g = C.grid_graph(3, 3)
    m = C.random_model(g, 0.5, 0.3, jax.random.PRNGKey(0))
    X = np.asarray(C.exact_sample(m, 1600, jax.random.PRNGKey(1)))
    return g, m, X


def test_chunked_ingestion_matches_one_shot(grid_setup):
    """Feeding the same data in k chunks (refitting after each) agrees with
    the one-shot batch fit to Newton tolerance — the headline streaming
    invariant."""
    g, m, X = grid_setup
    est = S.StreamingEstimator(g, capacity=64)
    for chunk in np.array_split(X[:1200], 5):
        est.ingest(chunk)
        est.refit()
    oneshot = C.fit_all_local(g, jnp.asarray(X[:1200]))
    for a, b in zip(est.fits, oneshot):
        assert a.i == b.i and a.beta == b.beta
        np.testing.assert_allclose(a.theta, b.theta, atol=1e-5)


def test_uneven_chunk_sizes_and_regrowth(grid_setup):
    """Capacity doubling mid-stream must not disturb the fits."""
    g, m, X = grid_setup
    est = S.StreamingEstimator(g, capacity=16)   # forces several regrowths
    for size in (7, 50, 3, 301, 239):
        lo = est.n_pool
        est.ingest(X[lo: lo + size])
        est.refit()
    n = est.n_pool
    oneshot = C.fit_all_local(g, jnp.asarray(X[:n]))
    diff = max(float(np.max(np.abs(a.theta - b.theta)))
               for a, b in zip(est.fits, oneshot))
    assert diff <= 1e-5


def test_heterogeneous_prefixes_match_subset_fits(grid_setup):
    """A node that has seen n_i samples fits exactly X[:n_i]."""
    g, m, X = grid_setup
    est = S.StreamingEstimator(g, capacity=64)
    est.extend_pool(X[:900])
    counts = 300 + (np.arange(g.p) * 61) % 600
    est.advance(counts)
    est.refit()
    for i in (0, 4, 8):
        ref = C.fit_all_local(g, jnp.asarray(X[: counts[i]]))[i]
        np.testing.assert_allclose(est.fits[i].theta, ref.theta, atol=1e-5)


def test_zero_count_nodes_are_finite(grid_setup):
    """A sensor that has observed nothing yields a finite (zero) fit and
    does not break consensus."""
    g, m, X = grid_setup
    est = S.StreamingEstimator(g, capacity=64)
    est.extend_pool(X[:400])
    counts = np.full(g.p, 400)
    counts[2] = 0
    est.advance(counts)
    fits = est.refit()
    assert np.all(fits[2].theta == 0.0)
    for scheme in ("uniform", "diagonal", "max"):
        th = C.combine(g, fits, scheme)
        assert np.all(np.isfinite(th))


def test_counts_must_be_monotone(grid_setup):
    g, m, X = grid_setup
    est = S.StreamingEstimator(g, capacity=64)
    est.ingest(X[:100])
    with pytest.raises(ValueError):
        est.advance(np.full(g.p, 50))


def test_warm_start_escapes_saturated_point(grid_setup):
    """A diverged (finite but saturated) warm start must not pin the fit —
    the regression behind the batched engine's backtracking guard."""
    g, m, X = grid_setup
    Xj = jnp.asarray(X[:800])
    cold = C.fit_all_local(g, Xj)
    warm = [None] * g.p
    warm[4] = np.full(len(cold[4].theta), 8.0, dtype=np.float32)
    warmed = C.fit_all_local(g, Xj, warm_start=warm)
    np.testing.assert_allclose(warmed[4].theta, cold[4].theta, atol=1e-4)


def test_pseudo_score_matches_autodiff(grid_setup):
    """Fused-kernel score over the padded buffer == jax.grad of the average
    pseudo-likelihood on the live rows."""
    g, m, X = grid_setup
    est = S.StreamingEstimator(g, capacity=64)
    est.ingest(X[:700])
    theta = np.asarray(m.theta, dtype=np.float64) * 0.7
    ref = np.asarray(jax.grad(
        lambda t: pseudo_loglik(g, t, jnp.asarray(X[:700])))(
            jnp.asarray(theta, dtype=jnp.float32)))
    got = S.pseudo_score(g, theta, est.buffer.data, est.n_pool)
    np.testing.assert_allclose(got, ref, atol=2e-4)


def test_score_norm_shrinks_toward_optimum(grid_setup):
    g, m, X = grid_setup
    est = S.StreamingEstimator(g, capacity=64)
    est.ingest(X[:1000])
    th_mple = C.fit_mple(g, jnp.asarray(X[:1000]))
    assert est.score_norm(th_mple) < est.score_norm(np.zeros(g.n_params))
