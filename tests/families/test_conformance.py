"""Parametric conformance harness for the exponential-family model zoo.

Every family registered in :mod:`repro.core.families` is taken through ONE
contract — layout invariants, closed-form channel hooks vs autodiff,
batched == loop == per-node oracle agreement, one-step consensus within
tolerance of the centralized MPLE oracle, chunked streaming == one-shot
batch, proximal (streaming-ADMM) solves consistent with plain fits, the
family-dispatched pseudo-score vs autodiff, and sampler moment matching
against the exact small-p oracle. A future family (or a refactor of an
existing one) is accepted or rejected by exactly this machinery: register
the instance, add its :class:`Case` row, and the whole suite parametrizes
over it automatically — a registered family *without* a case row fails
``test_every_registered_family_has_a_case``.

The Ising rows additionally pin the new code paths to the seed
implementations (per-node loop solver, fused Pallas score kernel — whose
dispatch tests live in ``tests/kernels/test_score_kernel.py``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
import repro.stream as S
from repro.core.batched import fit_all_local_batched, prox_update_batched
from repro.core.families import (fit_mple_family, fit_node_oracle,
                                 registered_families)
from repro.kernels.cl.epilogues import get_epilogue
from repro.kernels.cl.family import family_kernel_inputs, family_score_stats
from repro.kernels.cl.ref import cl_score_channels_ref


@dataclasses.dataclass(frozen=True)
class Case:
    """Per-family conformance configuration."""
    family: object
    graph: C.Graph
    seed: int
    n_fit: int = 2500
    combine_tol: float = 0.35      # max |combine - centralized MPLE|
    moment_tol: float = 4.5        # sampler moment error, units of 1/sqrt(n)


_FAMS = {f.name: f for f in registered_families()}
CASES = [
    Case(_FAMS["ising"], C.grid_graph(3, 3), seed=0),
    # Gaussian suff stats are unbounded -> looser MC moment tolerance
    Case(_FAMS["gaussian"], C.grid_graph(3, 3), seed=1, moment_tol=9.0),
    # Potts one-step owners see fewer effective samples per indicator
    # channel than binary families at equal n -> looser combine tolerance
    Case(_FAMS["potts"], C.grid_graph(2, 3), seed=2, combine_tol=0.6),
]


def test_every_registered_family_has_a_case():
    """Registration is gated on conformance: a family in the registry with
    no Case row here is a failure, not a silent skip."""
    assert {c.family.name for c in CASES} == set(_FAMS)


@pytest.fixture(params=CASES, ids=lambda c: c.family.name, scope="module")
def case(request):
    return request.param


@pytest.fixture(scope="module")
def setup(case):
    """(family, graph, theta_star, X) with X drawn from the exact joint."""
    fam, g = case.family, case.graph
    theta = fam.random_params(g, jax.random.PRNGKey(case.seed))
    X = fam.exact_sample(g, theta, case.n_fit,
                         jax.random.PRNGKey(case.seed + 100))
    return fam, g, np.asarray(theta, dtype=np.float64), np.asarray(X)


@pytest.fixture(scope="module")
def fits(setup):
    fam, g, theta, X = setup
    return fit_all_local_batched(g, jnp.asarray(X), family=fam)


# ------------------------------------------------------------------ layout
def test_layout_contract(case):
    """Flat layout: p node blocks then m edge blocks of size C; beta block
    order; each edge block owned by exactly its two endpoints."""
    fam, g = case.family, case.graph
    Cdim = fam.block_dim
    assert Cdim >= 1
    assert fam.n_params(g) == (g.p + g.m) * Cdim
    owners = C.param_owners(g, include_singleton=True, family=fam)
    assert set(owners) == set(range(fam.n_params(g)))
    for k, (i, j) in enumerate(g.edges):
        for a in fam.edge_block(g, k):
            assert sorted(node for node, _ in owners[a]) == [i, j]
    for i in range(g.p):
        for a in fam.node_block(g, i):
            assert [node for node, _ in owners[a]] == [i]
    # beta is the node block followed by incident edge blocks, in edge order
    for i in range(g.p):
        beta = fam.beta(g, i)
        expect = fam.node_block(g, i)
        for k in g.incident_edges(i):
            expect += fam.edge_block(g, k)
        assert beta == expect


def test_pseudo_loglik_is_sum_of_conditionals(setup):
    fam, g, theta, X = setup
    t = jnp.asarray(theta, jnp.float32)
    Xj = jnp.asarray(X[:64])
    cl = np.asarray(fam.cond_loglik(g, t, Xj))
    assert cl.shape == (64, g.p)
    np.testing.assert_allclose(float(fam.pseudo_loglik(g, t, Xj)),
                               float(np.mean(cl.sum(axis=1))), rtol=1e-5)


# ----------------------------------------------------- closed-form hooks
def test_channel_hooks_match_autodiff(case):
    """The engine's closed-form score/curvature hooks equal autodiff of the
    channel log-likelihood — the property that lets the batched engine skip
    ``jax.hessian`` entirely."""
    fam = case.family
    Cdim = fam.block_dim
    key = jax.random.PRNGKey(7 + case.seed)
    k1, k2 = jax.random.split(key)
    eta = jnp.asarray(0.8 * jax.random.normal(k1, (Cdim, 6)))
    xi = fam.init_draw(k2, 6)                        # valid node values
    r = np.asarray(fam.dl_deta(eta, xi))
    kap = np.asarray(fam.curvature(eta, xi))
    for t in range(6):
        f = lambda e: fam.loglik_eta(e[:, None], xi[t: t + 1])[0]
        g_ref = np.asarray(jax.grad(f)(eta[:, t]))
        H_ref = -np.asarray(jax.hessian(f)(eta[:, t]))
        np.testing.assert_allclose(r[:, t], g_ref, atol=1e-5)
        np.testing.assert_allclose(kap[:, :, t], H_ref, atol=1e-5)


# ------------------------------------------- batched == loop == oracle
def test_batched_equals_oracle_free_singleton(setup, fits):
    """The degree-bucketed closed-form engine lands on the same optimum as
    a plain autodiff Newton oracle for every node — and, for Ising, as the
    seed per-node loop solver."""
    fam, g, theta, X = setup
    for i in range(g.p):
        oracle = fit_node_oracle(fam, g, X, i)
        np.testing.assert_allclose(fits[i].theta, oracle, atol=5e-4)
    if fam.name == "ising":
        loop = C.fit_all_local_loop(g, jnp.asarray(X))
        for a, b in zip(loop, fits):
            assert a.beta == b.beta
            np.testing.assert_allclose(a.theta, b.theta, atol=1e-4)


def test_batched_equals_oracle_fixed_singleton(setup):
    """The fixed-singleton (offsets) path agrees with the oracle too."""
    fam, g, theta, X = setup
    tf = jnp.asarray(theta, jnp.float32)
    bat = fit_all_local_batched(g, jnp.asarray(X[:1200]),
                                include_singleton=False, theta_fixed=tf,
                                family=fam)
    for i in (0, g.p - 1):
        oracle = fit_node_oracle(fam, g, X[:1200], i,
                                 include_singleton=False, theta_fixed=tf)
        assert len(bat[i].theta) == g.degree(i) * fam.block_dim
        np.testing.assert_allclose(bat[i].theta, oracle, atol=5e-4)


# ------------------------------------------------- combine vs oracle MPLE
@pytest.fixture(scope="module")
def mple(setup):
    fam, g, theta, X = setup
    return fit_mple_family(fam, g, jnp.asarray(X))


@pytest.mark.parametrize("scheme",
                         [c.name for c in C.registered_combiners()])
def test_combine_schemes_track_centralized_mple(case, setup, fits, mple,
                                                scheme):
    """EVERY combiner in the registry stays within the same theoretical
    tolerance band of the centralized MPLE oracle, for every registered
    family (they share the sqrt(n) limit; at this n the gap is
    O(1/sqrt(n)) with a scheme-dependent constant). A newly registered
    combiner is accepted or rejected by exactly this check — the combiner
    twin of the family-registration gate."""
    fam, g, theta, X = setup
    mse_mple = C.mse(mple, theta)
    th = C.get_combiner(scheme).combine(g, fits, family=fam)
    assert np.all(np.isfinite(th)), scheme
    gap = float(np.max(np.abs(th - mple)))
    assert gap <= case.combine_tol, \
        f"{scheme}: |combine - MPLE| = {gap}"
    # and both estimate theta*: combining never catastrophically hurts
    assert C.mse(th, theta) <= 25.0 * max(mse_mple, 1e-3), scheme


# ------------------------------------------------ chunked stream == batch
def test_chunked_streaming_matches_batch(setup):
    """Feeding the same data in chunks through the family-generic streaming
    bank (capacity doubling, masks, warm starts) reproduces the one-shot
    batch fit — the any-time invariant, per family."""
    fam, g, theta, X = setup
    est = S.StreamingEstimator(g, capacity=32, family=fam)
    for chunk in np.array_split(X[:1000], 5):
        est.ingest(chunk)
        est.refit()
    oneshot = fit_all_local_batched(g, jnp.asarray(X[:1000]), family=fam)
    for a, b in zip(est.fits, oneshot):
        assert a.beta == b.beta
        np.testing.assert_allclose(a.theta, b.theta, atol=2e-4)


def test_heterogeneous_prefixes_match_subset_fits(setup):
    """A node that has seen n_i samples fits exactly X[:n_i], any family."""
    fam, g, theta, X = setup
    est = S.StreamingEstimator(g, capacity=64, family=fam)
    est.extend_pool(X[:900])
    counts = 300 + (np.arange(g.p) * 61) % 600
    est.advance(counts)
    est.refit()
    for i in (0, g.p - 1):
        ref = fit_all_local_batched(g, jnp.asarray(X[: counts[i]]),
                                    family=fam)[i]
        np.testing.assert_allclose(est.fits[i].theta, ref.theta, atol=2e-4)


# --------------------------------------------------- proximal consistency
def test_prox_update_with_vanishing_penalty_matches_fit(setup, fits):
    """The streaming-ADMM primal solver is the same criterion as the plain
    fit when the proximal penalty vanishes — ties the family's prox path to
    its conformant local fits."""
    fam, g, theta, X = setup
    betas = [fam.beta(g, i) for i in range(g.p)]
    zeros = [np.zeros(len(b)) for b in betas]
    rhos = [np.full(len(b), 1e-4) for b in betas]
    out = prox_update_batched(g, jnp.asarray(X[:1000]),
                              np.zeros(fam.n_params(g)), zeros, rhos,
                              n_iter=40, family=fam)
    ref = fit_all_local_batched(g, jnp.asarray(X[:1000]), family=fam)
    for w, f in zip(out, ref):
        np.testing.assert_allclose(w, f.theta, atol=5e-3)


# ----------------------------------------------------- dispatched score
def test_pseudo_score_dispatch_matches_autodiff(setup):
    """The streaming pseudo-score — the fused CL kernel for every family
    with a registered epilogue (all three registered families, the
    multi-channel Potts included) — equals the reference gradient on the
    live rows of a padded buffer."""
    fam, g, theta, X = setup
    est = S.StreamingEstimator(g, capacity=64, family=fam)
    est.ingest(X[:700])
    probe = theta * 0.6
    ref = fam.pseudo_score(g, probe, X[:700])
    got = S.pseudo_score(g, probe, est.buffer.data, est.n_pool, family=fam)
    np.testing.assert_allclose(got, ref, atol=3e-4)
    # the zoo's dispatch map: every registered family runs the fused path.
    # The live registry is the gate (KERNEL_KINDS is an import-time
    # snapshot and would wrongly reject families registered later).
    assert get_epilogue(fam.kernel_kind) is not None


def test_fused_kernel_matches_reference(setup):
    """Conformance gate for the fused kernel path itself: the channelized
    Pallas score kernel (interpret mode) agrees with the jnp reference
    <= 1e-5 on the family's own sampled data — every registered family
    exercises its epilogue here."""
    fam, g, theta, X = setup
    t32 = jnp.asarray(theta, jnp.float32)
    Xj = jnp.asarray(X[:512])
    out = family_score_stats(fam, g, t32, Xj, use_pallas=True,
                             interpret=True)
    ref = cl_score_channels_ref(*family_kernel_inputs(fam, g, t32, Xj),
                                kind=fam.kernel_kind)
    for o, r in zip(out, ref):
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(r, np.float32),
                                   atol=1e-5, rtol=1e-5)


def test_fused_kernel_bfloat16_within_documented_tolerance(setup):
    """Mixed-precision conformance gate: the fused pipeline on bfloat16
    designs (Gram accumulation stays float32 via dtype promotion) matches
    the float32 reference within the documented
    ``PRECISION_TOLERANCES["bfloat16"]`` — for every registered family,
    on both the chunked compiled-CPU twin and the whole-axis path."""
    from repro.kernels.cl.precision import PRECISION_TOLERANCES
    from repro.kernels.cl.tiled import cl_score_channels_tiled
    fam, g, theta, X = setup
    t32 = jnp.asarray(theta, jnp.float32)
    Xj = jnp.asarray(X[:256])
    F, tc, mask, bias = family_kernel_inputs(fam, g, t32, Xj)
    ref = cl_score_channels_ref(F, tc, mask, bias, kind=fam.kernel_kind)
    tol = PRECISION_TOLERANCES["bfloat16"]
    for chunk in (None, 64):
        out = cl_score_channels_tiled(F.astype(jnp.bfloat16), tc, mask,
                                      bias, kind=fam.kernel_kind,
                                      chunk=chunk)
        for o, r in zip(out, ref):
            np.testing.assert_allclose(np.asarray(o, np.float32),
                                       np.asarray(r, np.float32),
                                       atol=tol, rtol=tol)


# --------------------------------------------------- sampler vs oracle
def test_sampler_moments_match_exact_oracle(case):
    """Family-generic chromatic Gibbs hits the exact sufficient-statistic
    moments of the small-p oracle (enumeration / closed form)."""
    fam, g = case.family, case.graph
    theta = fam.random_params(g, jax.random.PRNGKey(case.seed + 50))
    mu = fam.exact_moments(g, theta)
    n = 4000
    Xs = C.gibbs_sample_family(fam, g, theta, n,
                               jax.random.PRNGKey(case.seed + 51),
                               burnin=300, thin=3)
    emp = np.mean(np.asarray(fam.suff_stats(g, Xs)), axis=0)
    assert np.max(np.abs(emp - mu)) < case.moment_tol / np.sqrt(n)
