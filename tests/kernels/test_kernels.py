"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle,
swept over shapes/dtypes (hypothesis + parametrized grids)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.ising_cl.kernel import ising_cl_logits
from repro.kernels.ising_cl.ref import ising_cl_logits_ref
from repro.kernels.gram.kernel import gram
from repro.kernels.gram.ref import gram_ref
from repro.kernels.swa.kernel import swa_attention
from repro.kernels.swa.ref import swa_attention_ref


# ------------------------------------------------------------------ ising_cl
@pytest.mark.parametrize("n,p", [(32, 10), (128, 128), (200, 150), (5, 260)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ising_cl_shapes(n, p, dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    x = jnp.sign(jax.random.normal(ks[0], (n, p))).astype(dtype)
    theta = (0.3 * jax.random.normal(ks[1], (p, p))).astype(dtype)
    theta = (theta + theta.T) / 2
    mask = (jax.random.uniform(ks[2], (p, p)) < 0.3).astype(dtype)
    mask = jnp.triu(mask, 1) + jnp.triu(mask, 1).T
    bias = (0.1 * jax.random.normal(ks[0], (p,))).astype(dtype)
    out = ising_cl_logits(x, theta, mask, bias, interpret=True)
    ref = ising_cl_logits_ref(x, theta, mask, bias)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@given(st.integers(1, 40), st.integers(2, 30), st.integers(0, 99))
@settings(max_examples=10, deadline=None)
def test_ising_cl_property(n, p, seed):
    key = jax.random.PRNGKey(seed)
    x = jnp.sign(jax.random.normal(key, (n, p)))
    theta = 0.5 * jax.random.normal(jax.random.PRNGKey(seed + 1), (p, p))
    mask = jnp.ones((p, p)) - jnp.eye(p)
    bias = jnp.zeros(p)
    out = ising_cl_logits(x, theta, mask, bias, interpret=True)
    ref = ising_cl_logits_ref(x, theta, mask, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_ising_cl_consistent_with_core():
    """Kernel must agree with the core library's conditional_logits."""
    import repro.core as C
    g = C.grid_graph(3, 4)
    m = C.random_model(g, 0.5, 0.3, jax.random.PRNGKey(1))
    X = C.exact_sample(m, 64, jax.random.PRNGKey(2))
    ref = C.conditional_logits(g, m.theta, X)
    from repro.core.ising import pair_matrix
    T = pair_matrix(g, m.theta_edges)
    A = jnp.asarray(g.adjacency)
    out = ising_cl_logits(X, T, A, m.theta_single, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)


# ---------------------------------------------------------------------- gram
@pytest.mark.parametrize("n,d", [(100, 7), (512, 128), (1000, 40), (3, 300)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_shapes(n, d, dtype):
    s = jax.random.normal(jax.random.PRNGKey(0), (n, d)).astype(dtype)
    out = gram(s, interpret=True)
    ref = gram_ref(s)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=tol, rtol=tol)


@given(st.integers(1, 60), st.integers(1, 50), st.integers(0, 99))
@settings(max_examples=10, deadline=None)
def test_gram_property(n, d, seed):
    s = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    out = np.asarray(gram(s, interpret=True))
    np.testing.assert_allclose(out, out.T, atol=1e-5)   # symmetry
    assert np.all(np.diag(out) >= -1e-6)                # PSD diagonal
    np.testing.assert_allclose(out, np.asarray(gram_ref(s)), atol=1e-4)


# ----------------------------------------------------------------------- swa
@pytest.mark.parametrize("s,h,kh,window", [
    (64, 2, 2, 0), (128, 4, 2, 0), (200, 2, 1, 64),
    (256, 4, 4, 128), (300, 6, 3, 0),
])
def test_swa_shapes(s, h, kh, window):
    b, d = 2, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))
    out = swa_attention(q, k, v, window=window, interpret=True)
    ref = swa_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_swa_bf16(dtype):
    b, s, h, d = 1, 128, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, h, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, h, d)).astype(dtype)
    out = swa_attention(q, k, v, window=64, interpret=True)
    ref = swa_attention_ref(q, k, v, window=64)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=5e-2)


@given(st.integers(1, 2), st.sampled_from([32, 96, 130]),
       st.sampled_from([0, 32, 128]), st.integers(0, 99))
@settings(max_examples=8, deadline=None)
def test_swa_property(b, s, window, seed):
    h, d = 2, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    out = swa_attention(q, k, v, window=window, interpret=True)
    ref = swa_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-4)


def test_swa_matches_model_attention():
    """Kernel oracle == the model's sdpa path (same masking semantics)."""
    from repro.models.attention import _plain_attention
    b, s, h, d = 1, 96, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    out = swa_attention(q, k, v, window=32, interpret=True)
    ref = _plain_attention(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-4)
