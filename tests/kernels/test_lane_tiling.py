"""Lane-aligned tiling invariants for the fused CL kernels.

The acceptance contract of the tiling tentpole: zero padding is provably
invisible —

* the bucket Newton kernel's tiny ``d*C`` output axis can be padded up to
  any lane multiple without changing g or K (padded design rows are zero,
  so every contribution vanishes term-by-term);
* the ``(j, i, k)`` score-kernel grid handles shapes that do NOT divide
  the tile sizes (edge tiles) exactly like shapes that do.

Both are pinned as hypothesis properties against the jnp references.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.kernels.cl.autotune import TileConfig  # noqa: E402
from repro.kernels.cl.kernel import cl_score_channels  # noqa: E402
from repro.kernels.cl.newton import (bucket_newton_stats,  # noqa: E402
                                     bucket_newton_stats_ref,
                                     lane_padded_width)
from repro.kernels.cl.ref import cl_score_channels_ref  # noqa: E402


# ------------------------------------------------------- lane-pad algebra
@given(d=st.integers(1, 40), C=st.integers(1, 6),
       lane=st.sampled_from([8, 16, 32, 64, 128]))
@settings(max_examples=60, deadline=None)
def test_lane_padded_width_is_minimal_and_aligned(d, C, lane):
    dp = lane_padded_width(d, C, lane)
    assert dp >= d
    assert (dp * C) % lane == 0
    # minimal: no smaller d' >= d aligns
    for cand in range(d, dp):
        assert (cand * C) % lane != 0


# ------------------------------------------- lane padding invisible (g, K)
def _newton_case(kind, k, C, d, n, seed, weighted):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    Zb = jax.random.normal(ks[0], (k, C, d, n))
    base = 0.1 * jax.random.normal(ks[1], (k, C, n))
    if kind == "potts":
        xi = jax.random.randint(ks[2], (k, n), 0, C + 1).astype(jnp.float32)
    else:
        xi = jnp.sign(jax.random.normal(ks[2], (k, n)))
    W = 0.2 * jax.random.normal(ks[3], (k, d * C))
    sw = jax.random.uniform(ks[4], (k, n)) if weighted else None
    return Zb, base, xi, W, sw


@given(d=st.integers(1, 6), n=st.integers(1, 50),
       lane=st.sampled_from([8, 16, 32]),
       bm=st.sampled_from([8, 16, 32]),
       weighted=st.booleans(), seed=st.integers(0, 2 ** 16))
@settings(max_examples=12, deadline=None)
def test_lane_padded_newton_matches_ref_potts(d, n, lane, bm, weighted,
                                              seed):
    """Interpret-mode bucket Newton with lane padding AND a sample tile
    that does not divide n == the unpadded jnp reference (multi-channel)."""
    kind, C, k = "potts", 2, 2
    Zb, base, xi, W, sw = _newton_case(kind, k, C, d, n, seed, weighted)
    g0, K0 = bucket_newton_stats_ref(kind, Zb, base, xi, W, sw)
    g1, K1 = bucket_newton_stats(kind, Zb, base, xi, W, sw, interpret=True,
                                 tiles=TileConfig(bm=bm, lane=lane))
    assert g1.shape == g0.shape and K1.shape == K0.shape
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g0), atol=2e-5)
    np.testing.assert_allclose(np.asarray(K1), np.asarray(K0), atol=2e-5)


@given(d=st.integers(1, 8), n=st.integers(1, 60),
       lane=st.sampled_from([8, 32, 128]), seed=st.integers(0, 2 ** 16))
@settings(max_examples=12, deadline=None)
def test_lane_padded_newton_matches_ref_ising(d, n, lane, seed):
    """Single-channel fast path under lane padding."""
    kind, C, k = "ising", 1, 3
    Zb, base, xi, W, sw = _newton_case(kind, k, C, d, n, seed, False)
    g0, K0 = bucket_newton_stats_ref(kind, Zb, base, xi, W)
    g1, K1 = bucket_newton_stats(kind, Zb, base, xi, W, interpret=True,
                                 tiles=TileConfig(bm=16, lane=lane))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g0), atol=2e-5)
    np.testing.assert_allclose(np.asarray(K1), np.asarray(K0), atol=2e-5)


# ------------------------------------------------- score-kernel edge tiles
@given(n=st.integers(1, 40), p=st.integers(2, 11),
       tiles=st.sampled_from([TileConfig(bm=8, bn=8, bk=8),
                              TileConfig(bm=16, bn=8, bk=16),
                              TileConfig(bm=32, bn=16, bk=8)]),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_score_kernel_edge_tiles_match_ref(n, p, tiles, seed):
    """The (j, i, k) score grid with tiles that do NOT divide (n, p) — and
    bn != bk, so the p-pad is the lcm — equals the reference exactly up to
    float32 jitter, multi-channel epilogue included."""
    from repro.kernels.cl.epilogues import get_epilogue
    C = 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.randint(ks[0], (n, p), 0, C + 1).astype(jnp.float32)
    F = get_epilogue("potts").features(x, C)
    theta = 0.3 * jax.random.normal(ks[1], (C, p, p))
    mask = jnp.ones((p, p)) - jnp.eye(p)
    bias = 0.1 * jax.random.normal(ks[2], (C, p))
    ref = cl_score_channels_ref(F, theta, mask, bias, kind="potts")
    out = cl_score_channels(F, theta, mask, bias, kind="potts",
                            interpret=True, tiles=tiles)
    for o, r in zip(out, ref):
        assert o.shape == r.shape
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)
