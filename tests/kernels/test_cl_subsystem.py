"""Family-generic CL kernel subsystem: public-name backward compatibility,
the epilogue registry, the channelized multi-channel (Potts) pipeline, and
the fused bucket Newton-step entry point."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core.batched import _bucket_design, _channel_ops, degree_buckets
from repro.kernels.cl import (bucket_newton_stats, bucket_newton_stats_ref,
                              cl_logits, fused_pseudo_score)
from repro.kernels.cl.epilogues import (Epilogue, get_epilogue,
                                        register_epilogue, registered_kinds,
                                        require_epilogue)
from repro.kernels.cl.family import family_score_stats
from repro.kernels.cl.ref import cl_logits_ref, cl_score_channels_ref


# -------------------------------------------------------- public name lock
def test_seed_public_names_remain_importable():
    """The ising_cl -> cl dissolution keeps every public name importable
    from its original path (the backward-compat contract of the refactor)."""
    from repro.kernels.ising_cl.kernel import ising_cl_logits  # noqa: F401
    from repro.kernels.ising_cl.ops import (conditional_logits_op,  # noqa
                                            score_stats_op)
    from repro.kernels.ising_cl.ref import (cl_score_ref,  # noqa: F401
                                            ising_cl_logits_ref,
                                            ising_cl_score_ref)
    from repro.kernels.ising_cl.score import (KERNEL_KINDS,  # noqa: F401
                                              cl_score, cl_score_padded,
                                              ising_cl_score,
                                              ising_cl_score_padded)
    assert {"ising", "gaussian", "potts"} <= set(KERNEL_KINDS)
    # the shims re-export the cl implementations, not copies
    from repro.kernels import cl
    assert ising_cl_score is cl.ising_cl_score
    assert cl_score is cl.cl_score
    assert cl_score_ref is cl.cl_score_ref


# ------------------------------------------------------- epilogue registry
def test_registry_roundtrip_and_errors():
    assert set(registered_kinds()) >= {"ising", "gaussian", "potts"}
    assert get_epilogue("ising").channels == "single"
    assert get_epilogue("potts").channels == "multi"
    assert get_epilogue(None) is None
    assert get_epilogue("no-such-kind") is None
    with pytest.raises(ValueError, match="no epilogue"):
        require_epilogue("no-such-kind")
    with pytest.raises(ValueError):
        Epilogue(kind="x", channels="both", features=None, residual=None,
                 curvature=None)
    with pytest.raises(ValueError):
        register_epilogue(Epilogue(kind="", channels="single", features=None,
                                   residual=None, curvature=None))


def test_every_registered_family_has_an_epilogue():
    """The ROADMAP debt this PR pays: every family in the model zoo runs
    the fused kernel path — no more autodiff-only fallbacks."""
    for fam in C.registered_families():
        assert get_epilogue(fam.kernel_kind) is not None, fam.name


# --------------------------------------------------- channelized pipeline
def test_channelized_logits_match_ref():
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    Cdim, n, p = 3, 40, 17
    F = (jax.random.uniform(ks[0], (Cdim, n, p)) < 0.4).astype(jnp.float32)
    theta = 0.3 * jax.random.normal(ks[1], (Cdim, p, p))
    mask = (jax.random.uniform(ks[2], (p, p)) < 0.4).astype(jnp.float32)
    bias = 0.2 * jax.random.normal(ks[3], (Cdim, p))
    out = cl_logits(F, theta, mask, bias, interpret=True)
    ref = cl_logits_ref(F, theta, mask, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def _family_setup(name, seed=0, n=220):
    fam = C.get_family(name)
    g = C.grid_graph(2, 3)
    theta = fam.random_params(g, jax.random.PRNGKey(seed))
    X = fam.exact_sample(g, theta, n, jax.random.PRNGKey(seed + 1))
    return fam, g, np.asarray(theta, np.float64), jnp.asarray(X)


@pytest.mark.parametrize("name", [f.name for f in C.registered_families()])
def test_family_score_stats_kernel_vs_ref(name):
    """family adapter -> channelized Pallas kernel == jnp reference for
    every registered family, Potts' cross-channel Gram blocks included."""
    from repro.kernels.cl.family import family_kernel_inputs
    fam, g, theta, X = _family_setup(name)
    out = family_score_stats(fam, g, jnp.asarray(theta, jnp.float32), X,
                             use_pallas=True, interpret=True)
    Fin = family_kernel_inputs(fam, g, jnp.asarray(theta, jnp.float32), X)
    ref = cl_score_channels_ref(*Fin, kind=fam.kernel_kind)
    for o, r in zip(out, ref):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-5,
                                   rtol=1e-5)
    Cdim = fam.block_dim
    assert out[2].shape == (Cdim, Cdim, g.p, g.p)


@pytest.mark.parametrize("name", [f.name for f in C.registered_families()])
def test_fused_pseudo_score_matches_autodiff(name):
    """The fused flat pseudo-score over a zero-padded buffer equals the
    family's autodiff gradient on the live rows, for every family."""
    fam, g, theta, X = _family_setup(name, seed=3)
    n_seen = 180
    x_pad = np.zeros((256, g.p), dtype=np.float32)
    x_pad[:n_seen] = np.asarray(X)[:n_seen]
    probe = theta * 0.7
    got = fused_pseudo_score(fam, g, probe, x_pad, n_seen)
    ref = fam.pseudo_score(g, probe, np.asarray(X)[:n_seen])
    np.testing.assert_allclose(got, ref, atol=1e-5)


def test_stream_pseudo_score_use_pallas_passthrough():
    """pseudo_score(use_pallas=True, interpret=True) really runs the kernel
    body and agrees with the default (reference-on-CPU) dispatch."""
    import repro.stream as S
    fam, g, theta, X = _family_setup("potts", seed=4)
    x_pad = np.zeros((256, g.p), dtype=np.float32)
    x_pad[:200] = np.asarray(X)[:200]
    a = S.pseudo_score(g, theta, x_pad, 200, family=fam)
    b = S.pseudo_score(g, theta, x_pad, 200, family=fam,
                       use_pallas=True, interpret=True)
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_channels_op_dispatch_cpu():
    """Off-TPU, the channelized op wrapper routes to the jnp reference."""
    from repro.kernels.cl.ops import score_stats_channels_op
    fam, g, theta, X = _family_setup("potts", seed=2)
    from repro.kernels.cl.family import family_kernel_inputs
    inputs = family_kernel_inputs(fam, g, jnp.asarray(theta, jnp.float32), X)
    out = score_stats_channels_op(*inputs, kind="potts")
    ref = cl_score_channels_ref(*inputs, kind="potts")
    for o, r in zip(out, ref):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-6)


# ------------------------------------------------ fused bucket Newton step
@pytest.mark.parametrize("name", [f.name for f in C.registered_families()])
@pytest.mark.parametrize("weighted", [False, True])
def test_bucket_newton_stats_pallas_matches_ref(name, weighted):
    """The Pallas fused Newton entry (score + Gram in the (k, C, d) bucket
    layout) matches the jnp reference, which itself is bit-identical to the
    engine's historical closed-form contractions."""
    fam, g, theta, X = _family_setup(name, seed=5)
    b = degree_buckets(g)[0]
    Zb, xi, base, _ = _bucket_design(
        fam, X, jnp.asarray(b.nodes), jnp.asarray(b.nbrs),
        jnp.asarray(b.mask), jnp.zeros((len(b.nodes), fam.block_dim)), True)
    k, Cdim, d, n = Zb.shape
    W = 0.1 * jax.random.normal(jax.random.PRNGKey(7), (k, d * Cdim))
    sw = ((jax.random.uniform(jax.random.PRNGKey(8), (k, n)) < 0.7)
          .astype(jnp.float32) if weighted else None)
    g_ref, K_ref = bucket_newton_stats_ref(fam.kernel_kind, Zb, base, xi, W,
                                           sw)
    g_pal, K_pal = bucket_newton_stats(fam.kernel_kind, Zb, base, xi, W, sw,
                                       interpret=True)
    scale = max(float(jnp.max(jnp.abs(K_ref))), 1.0)
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                               atol=2e-5 * scale)
    np.testing.assert_allclose(np.asarray(K_pal), np.asarray(K_ref),
                               atol=2e-5 * scale)
    # and the reference really is the engine's PRE-fusion contraction,
    # bitwise: compare against the legacy family-hook closures
    # (score_curvature -> grad_vec/curvature_matrix), not the newton_stats
    # dispatcher (which would be circular — it calls the ref on CPU)
    denom = jnp.full((k,), float(n))
    sw_engine = sw if weighted else jnp.ones((1, 1), X.dtype)
    score_curvature, grad_vec, curvature_matrix, *_ = _channel_ops(
        fam, Zb, base, xi, sw_engine, weighted, denom)
    r_leg, kap_leg = score_curvature(W)
    np.testing.assert_array_equal(np.asarray(grad_vec(r_leg)),
                                  np.asarray(g_ref))
    np.testing.assert_array_equal(np.asarray(curvature_matrix(kap_leg)),
                                  np.asarray(K_ref))
