"""Fused score-statistics kernel: interpret-mode Pallas vs jnp oracle, and
the score identities against the core library's pseudo-likelihood gradient.
(Kept hypothesis-free so it runs in minimal environments.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ising_cl.ops import score_stats_op
from repro.kernels.ising_cl.ref import cl_score_ref, ising_cl_score_ref
from repro.kernels.ising_cl.score import (KERNEL_KINDS, cl_score,
                                          ising_cl_score)


def _rand_inputs(n, p, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jnp.sign(jax.random.normal(ks[0], (n, p))).astype(dtype)
    theta = (0.3 * jax.random.normal(ks[1], (p, p))).astype(dtype)
    theta = (theta + theta.T) / 2
    mask = (jax.random.uniform(ks[2], (p, p)) < 0.3).astype(dtype)
    mask = jnp.triu(mask, 1) + jnp.triu(mask, 1).T
    bias = (0.1 * jax.random.normal(ks[0], (p,))).astype(dtype)
    return x, theta, mask, bias


@pytest.mark.parametrize("n,p", [(32, 10), (130, 128), (200, 150), (5, 260)])
def test_score_kernel_matches_ref(n, p):
    x, theta, mask, bias = _rand_inputs(n, p)
    out = ising_cl_score(x, theta, mask, bias, interpret=True)
    ref = ising_cl_score_ref(x, theta, mask, bias)
    for o, r in zip(out, ref):
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(r, np.float32),
                                   atol=2e-5, rtol=2e-5)


def test_score_identities_vs_core_gradient():
    """Column means of r = singleton grads; S + S^T on edges = coupling
    grads of the average pseudo-likelihood (Eq. 2)."""
    import repro.core as C
    from repro.core.ising import pair_matrix, pseudo_loglik

    g = C.grid_graph(3, 4)
    m = C.random_model(g, 0.5, 0.3, jax.random.PRNGKey(1))
    X = C.exact_sample(m, 256, jax.random.PRNGKey(2))
    T = pair_matrix(g, m.theta_edges)
    A = jnp.asarray(g.adjacency)

    eta, r, S = ising_cl_score(X, T, A, m.theta_single, interpret=True)
    grad = jax.grad(lambda t: pseudo_loglik(g, t, X))(m.theta)

    np.testing.assert_allclose(np.asarray(jnp.mean(r, axis=0)),
                               np.asarray(grad[:g.p]), atol=1e-5)
    edges = np.asarray(g.edges)
    s_np = np.asarray(S)
    g_edges = s_np[edges[:, 0], edges[:, 1]] + s_np[edges[:, 1], edges[:, 0]]
    np.testing.assert_allclose(g_edges, np.asarray(grad[g.p:]), atol=1e-5)


def test_score_eta_consistent_with_plain_kernel():
    from repro.kernels.ising_cl.kernel import ising_cl_logits
    x, theta, mask, bias = _rand_inputs(64, 40, seed=3)
    eta, _, _ = ising_cl_score(x, theta, mask, bias, interpret=True)
    eta_plain = ising_cl_logits(x, theta, mask, bias, interpret=True)
    np.testing.assert_allclose(np.asarray(eta), np.asarray(eta_plain),
                               atol=2e-5)


def test_score_op_dispatch_cpu():
    x, theta, mask, bias = _rand_inputs(16, 12, seed=4)
    out = score_stats_op(x, theta, mask, bias)        # ref path off-TPU
    ref = ising_cl_score_ref(x, theta, mask, bias)
    for o, r in zip(out, ref):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-6)


def _kind_inputs(kind, n, p, seed, C=2):
    """(F, theta, mask, bias) channelized inputs with kind-valid samples."""
    from repro.kernels.cl.epilogues import get_epilogue
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    if kind == "potts":
        x = jax.random.randint(ks[0], (n, p), 0, C + 1).astype(jnp.float32)
    elif kind == "gaussian":
        x = jax.random.normal(ks[0], (n, p))
    else:
        x = jnp.sign(jax.random.normal(ks[0], (n, p)))
    ep = get_epilogue(kind)
    Cdim = C if ep.channels == "multi" else 1
    F = ep.features(x, Cdim)                         # (C, n, p)
    theta = 0.3 * jax.random.normal(ks[1], (Cdim, p, p))
    theta = (theta + jnp.swapaxes(theta, 1, 2)) / 2
    mask = (jax.random.uniform(ks[2], (p, p)) < 0.3).astype(jnp.float32)
    mask = jnp.triu(mask, 1) + jnp.triu(mask, 1).T
    bias = 0.1 * jax.random.normal(ks[3], (Cdim, p))
    return F, theta, mask, bias


@pytest.mark.parametrize("kind", KERNEL_KINDS)
def test_family_epilogues_match_ref(kind):
    """Every registered fused epilogue (trace-time ``kind`` dispatch)
    matches the jnp reference through the channelized skeleton — Ising,
    Gaussian, and the multi-channel Potts alike."""
    from repro.kernels.cl.kernel import cl_score_channels
    from repro.kernels.cl.ref import cl_score_channels_ref
    F, theta, mask, bias = _kind_inputs(kind, 96, 70, seed=5)
    out = cl_score_channels(F, theta, mask, bias, kind=kind, interpret=True)
    ref = cl_score_channels_ref(F, theta, mask, bias, kind=kind)
    for o, r in zip(out, ref):
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(r, np.float32),
                                   atol=2e-5, rtol=2e-5)


def test_single_channel_entries_match_ref():
    """The seed single-channel entry points are the C = 1 instances of the
    channelized skeleton."""
    x, theta, mask, bias = _rand_inputs(96, 70, seed=5)
    for kind, xs in (("ising", x),
                     ("gaussian",
                      x + 0.3 * jax.random.normal(jax.random.PRNGKey(9),
                                                  x.shape))):
        out = cl_score(xs, theta, mask, bias, kind=kind, interpret=True)
        ref = cl_score_ref(xs, theta, mask, bias, kind=kind)
        for o, r in zip(out, ref):
            np.testing.assert_allclose(np.asarray(o, np.float32),
                                       np.asarray(r, np.float32),
                                       atol=2e-5, rtol=2e-5)


def test_unknown_kind_rejected():
    x, theta, mask, bias = _rand_inputs(8, 6, seed=6)
    with pytest.raises(ValueError):
        cl_score(x, theta, mask, bias, kind="boltzmann", interpret=True)


def test_multi_channel_kind_rejected_by_single_channel_entry():
    """Potts is a registered kind but needs (C, n, p) inputs — the single
    channel entry must fail loudly, not mis-shape."""
    x, theta, mask, bias = _rand_inputs(8, 6, seed=6)
    with pytest.raises(ValueError, match="multi-channel"):
        cl_score(x, theta, mask, bias, kind="potts", interpret=True)
