"""Autotuner contract tests: cache determinism, disk round-trip, invalid
tile rejection, and a hypothesis property that tuned tiles never change
results."""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.cl import autotune as at
from repro.kernels.cl.autotune import TileConfig


@pytest.fixture(autouse=True)
def _fresh_cache(monkeypatch):
    """Every test sees an empty in-process cache and no env cache file."""
    monkeypatch.delenv("REPRO_CL_TUNE_CACHE", raising=False)
    at.clear_cache()
    yield
    at.clear_cache()


# -------------------------------------------------------------- validation
def test_invalid_tiles_rejected():
    with pytest.raises(ValueError, match="unknown kernel op"):
        at.validate_tile_config(TileConfig(), "matmul")
    with pytest.raises(ValueError, match="TileConfig"):
        at.validate_tile_config({"bm": 128}, "score")
    with pytest.raises(ValueError, match="bm"):
        at.validate_tile_config(TileConfig(bm=0), "score")
    with pytest.raises(ValueError, match="bn"):
        at.validate_tile_config(TileConfig(bn=-8), "score")
    with pytest.raises(ValueError, match="bk"):
        at.validate_tile_config(TileConfig(bk=0), "score")
    with pytest.raises(ValueError, match="lane"):
        at.validate_tile_config(TileConfig(lane=100), "newton")
    # Mosaic (compiled) constraints are stricter
    with pytest.raises(ValueError, match="8-aligned"):
        at.validate_tile_config(TileConfig(bm=None), "score", compiled=True)
    with pytest.raises(ValueError, match="128-multiple"):
        at.validate_tile_config(TileConfig(bm=128, bn=64), "score",
                                compiled=True)
    with pytest.raises(ValueError, match="lane"):
        at.validate_tile_config(TileConfig(bm=128, lane=None), "newton",
                                compiled=True)
    # and valid configs pass through unchanged
    cfg = TileConfig(bm=128, bn=128, bk=128, lane=128)
    assert at.validate_tile_config(cfg, "newton", compiled=True) is cfg


def test_tile_key_rejects_unknown_op():
    with pytest.raises(ValueError, match="unknown kernel op"):
        at.tile_key("fft", n=10, p=4, C=1)


def test_tile_config_round_trips_dict():
    cfg = TileConfig(bm=512, bn=256, bk=128, lane=128)
    assert TileConfig.from_dict(cfg.to_dict()) == cfg
    assert TileConfig.from_dict(TileConfig(bm=None).to_dict()).bm is None


# ------------------------------------------------------ cache determinism
def test_search_is_deterministic_and_cached():
    """Two same-key searches: the second never re-measures (empty timings,
    same config) — the acceptance-criterion determinism contract."""
    calls = []

    def measure(cfg):
        calls.append(cfg)
        # deterministic fake cost: prefer the 1024 chunk
        return 1.0 if cfg.bm == 1024 else 2.0

    key = dict(n=50_000, p=9, C=2, backend="cpu")
    best1, t1 = at.search_tiles("newton", measure=measure, **key)
    n_measured = len(calls)
    assert n_measured == len(t1) > 1
    assert best1.bm == 1024
    best2, t2 = at.search_tiles("newton", measure=measure, **key)
    assert best2 == best1
    assert t2 == {}                      # cache hit: no re-search
    assert len(calls) == n_measured      # measure never called again
    # and the trace-time resolver picks the tuned config transparently
    assert at.get_tiles("newton", **key) == best1


def test_search_ties_break_toward_earliest_candidate():
    cands = (TileConfig(bm=None), TileConfig(bm=512), TileConfig(bm=1024))
    best, _ = at.search_tiles("newton", n=40_000, p=5, C=1, backend="cpu",
                              measure=lambda cfg: 1.0, candidates=cands)
    assert best == cands[0]


def test_get_tiles_is_stable_across_calls():
    """Heuristic resolutions are cached: a key resolves once and every
    later lookup returns the identical config (no retrace flip-flop)."""
    a = at.get_tiles("score", n=400, p=20, C=1, backend="cpu")
    b = at.get_tiles("score", n=400, p=20, C=1, backend="cpu")
    assert a == b
    snap = at.cache_snapshot()
    assert at.tile_key("score", n=400, p=20, C=1, backend="cpu") in snap


def test_heuristics_respect_chunk_threshold():
    """Below CHUNK_MIN_N the CPU newton heuristic must be whole-axis (the
    bit-identical reference path the goldens pin)."""
    small = at.get_tiles("newton", n=at.CHUNK_MIN_N - 1, p=5, C=1,
                         backend="cpu")
    assert small.bm is None
    big = at.get_tiles("newton", n=at.CHUNK_MIN_N, p=5, C=1, backend="cpu")
    assert big.bm is not None


# ------------------------------------------------------- disk round-trip
def test_disk_cache_round_trip(tmp_path):
    best, _ = at.search_tiles("newton", n=60_000, p=7, C=1, backend="cpu",
                              measure=lambda cfg: 0.0 if cfg.bm == 2048
                              else 1.0)
    path = str(tmp_path / "tune.json")
    at.save_cache(path)
    payload = json.loads(open(path).read())
    assert payload["version"] == 1

    at.clear_cache()
    assert at.cache_snapshot() == {}
    adopted = at.load_cache(path)
    assert adopted == 1
    assert at.get_tiles("newton", n=60_000, p=7, C=1,
                        backend="cpu") == best
    # in-process entries win over a second load
    assert at.load_cache(path) == 0


def test_load_cache_rejects_unknown_version(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({"version": 99, "entries": {}}))
    with pytest.raises(ValueError, match="version"):
        at.load_cache(str(path))


def test_env_cache_loads_lazily_and_persists_searches(tmp_path,
                                                      monkeypatch):
    path = str(tmp_path / "env.json")
    monkeypatch.setenv("REPRO_CL_TUNE_CACHE", path)
    at.clear_cache()
    best, timings = at.search_tiles(
        "newton", n=70_000, p=5, C=1, backend="cpu",
        measure=lambda cfg: 0.0 if cfg.bm == 512 else 1.0)
    assert timings                       # fresh search really measured
    # the search result was appended to the env file ...
    assert json.loads(open(path).read())["entries"]
    # ... and a fresh process (cleared cache) adopts it without searching
    at.clear_cache()
    hit, timings2 = at.search_tiles(
        "newton", n=70_000, p=5, C=1, backend="cpu",
        measure=lambda cfg: pytest.fail("must not re-measure"))
    assert hit == best and timings2 == {}


# --------------------------------------- tuned == default (hypothesis)
@pytest.mark.parametrize("kind", ["ising", "gaussian", "potts"])
def test_tuned_tiles_never_change_results(kind):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro.kernels.cl.epilogues import get_epilogue
    from repro.kernels.cl.ref import cl_score_channels_ref
    from repro.kernels.cl.tiled import cl_score_channels_tiled

    ep = get_epilogue(kind)
    C = 2 if ep.channels == "multi" else 1

    @given(n=st.integers(3, 60), p=st.integers(2, 9),
           chunk=st.sampled_from([4, 8, 16, 32]),
           seed=st.integers(0, 2 ** 16))
    @settings(max_examples=15, deadline=None)
    def prop(n, p, chunk, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        if kind == "potts":
            x = jax.random.randint(ks[0], (n, p), 0, C + 1) \
                .astype(jnp.float32)
        elif kind == "gaussian":
            x = jax.random.normal(ks[0], (n, p))
        else:
            x = jnp.sign(jax.random.normal(ks[0], (n, p)))
        F = ep.features(x, C)
        theta = 0.3 * jax.random.normal(ks[1], (C, p, p))
        mask = jnp.ones((p, p)) - jnp.eye(p)
        bias = 0.1 * jax.random.normal(ks[2], (C, p))
        default = cl_score_channels_ref(F, theta, mask, bias, kind=kind)
        tuned = cl_score_channels_tiled(F, theta, mask, bias, kind=kind,
                                        chunk=chunk)
        for t, d in zip(tuned, default):
            np.testing.assert_allclose(np.asarray(t), np.asarray(d),
                                       atol=1e-6, rtol=1e-6)

    prop()
