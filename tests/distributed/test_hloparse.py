"""Unit tests for the loop-aware HLO analyzer (the §Roofline measurement
instrument — it must itself be correct)."""
import textwrap

from repro.launch import hloparse


SYNTH = textwrap.dedent("""\
    HloModule m

    %body (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
      %p = (s32[], f32[128,128]) parameter(0)
      %dot.1 = f32[128,128]{1,0} dot(f32[128,128]{1,0} %a, f32[128,128]{1,0} %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[128,128]{1,0} all-reduce(%dot.1), replica_groups=[16,16]<=[256]
      ROOT %t = (s32[], f32[128,128]) tuple(%i, %ar)
    }

    %cond (p: (s32[], f32[128,128])) -> pred[] {
      %p = (s32[], f32[128,128]) parameter(0)
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }

    ENTRY %main (a: f32[128,128], b: f32[128,128]) -> f32[128,128] {
      %a = f32[128,128]{1,0} parameter(0)
      %b = f32[128,128]{1,0} parameter(1)
      %w = (s32[], f32[128,128]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
      %ag = f32[256,128]{1,0} all-gather(%a), replica_groups={{0,256},{1,257}}, dimensions={0}
      ROOT %gte = f32[128,128]{1,0} get-tuple-element(%w), index=1
    }
""")


def test_loop_multiplied_collectives_and_flops():
    r = hloparse.analyze(SYNTH)
    # all-reduce inside the 12-trip loop: 12 instances
    assert r["collectives"]["all-reduce"]["count"] == 12
    assert r["collectives"]["all-reduce"]["bytes"] == 12 * 128 * 128 * 4
    # dot: 2*M*N*K * trip
    assert r["dot_flops"] == 12 * 2 * 128 * 128 * 128
    assert r["max_trip"] == 12


def test_cross_pod_classification():
    r = hloparse.analyze(SYNTH)
    # the all-gather's groups {{0,256},...} span the 256-device pod boundary
    ag_bytes = 256 * 128 * 4
    assert r["cross_pod_bytes"] == ag_bytes
    # the within-pod all-reduce ([16,16]<=[256]) contributes nothing
    assert hloparse.crosses_pod(
        "x all-reduce(%y), replica_groups=[16,16]<=[256]") is False
    assert hloparse.crosses_pod(
        "x all-reduce(%y), replica_groups=[256,2]<=[2,256]T(1,0)") is True


def test_bookkeeping_ops_are_free():
    r = hloparse.analyze(SYNTH)
    # GTE / tuple / parameter contribute zero bytes; the total is the dot
    # line (result + 2 inline operands) + the all-reduce result, x12 trips,
    # + the entry all-gather + the while-carry tuple.
    per_trip = (3 + 1) * 128 * 128 * 4
    expected = 12 * per_trip + 256 * 128 * 4 + 128 * 128 * 4 + 4
    assert abs(r["hbm_bytes"] - expected) <= 64


def test_probe_scan_counts_once_without_correction():
    """Regression-documenting probe: jax cost_analysis counts loop bodies
    once; our analyzer must multiply."""
    import jax
    import jax.numpy as jnp

    def f(x):
        def body(c, _):
            return c @ x, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    # newer JAX returns a list of per-module dicts, older a single dict
    raw = hloparse.normalize_cost_analysis(c.cost_analysis())["flops"]
    deep = hloparse.analyze(c.as_text())
    one = 2 * 64 ** 3
    assert raw < 1.1 * one                 # XLA: body counted once (+eps)
    assert deep["dot_flops"] == 7 * one    # ours: trip-corrected
