"""Pod-consensus trainer semantics (paper Sec. 3 lifted to pods) — runs on
CPU via the stacked-replica vmap formulation (no mesh needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as CFG
from repro.optim import adamw
from repro.train import consensus as CT
from repro.train import step as TS
from repro.data.pipeline import DataConfig, SyntheticLM, pod_sharded_batches

# multi-round consensus training sweeps dominate wall-clock -> slow tier
pytestmark = pytest.mark.slow


def tiny_cfg():
    import dataclasses
    r = CFG.reduced(CFG.get("llama3.2-3b"))
    return dataclasses.replace(r, n_layers=2, d_model=64, n_heads=2,
                               n_kv_heads=1, head_dim=32, d_ff=128,
                               vocab_size=256)


def make_batch(cfg, n_pods, h, bsz=4, s=16, seed=0):
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=s,
                                global_batch=bsz * n_pods, seed=seed))
    return next(iter(pod_sharded_batches(ds, n_pods, h)))


@pytest.mark.parametrize("scheme", ["uniform", "diagonal", "max", "admm"])
def test_round_step_runs_and_params_move(scheme):
    cfg = tiny_cfg()
    ccfg = CT.ConsensusConfig(n_pods=2, scheme=scheme, h_steps=2)
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=100)
    tcfg = TS.TrainConfig()
    state = CT.init_state(cfg, jax.random.PRNGKey(0), ccfg)
    batch = make_batch(cfg, 2, 2)
    round_step = CT.make_round_step(cfg, ocfg, tcfg, ccfg)
    new_state, metrics = round_step(state, batch)
    assert bool(jnp.isfinite(metrics["nll"]))
    p0 = jax.tree_util.tree_leaves(state.params)[0]
    p1 = jax.tree_util.tree_leaves(new_state.params)[0]
    assert not np.allclose(np.asarray(p0), np.asarray(p1))
    if scheme != "admm":
        # one-step consensus: every pod restarts from the same theta_bar
        for leaf in jax.tree_util.tree_leaves(new_state.params):
            np.testing.assert_allclose(np.asarray(leaf[0]),
                                       np.asarray(leaf[1]), atol=1e-6)


def test_uniform_combine_is_mean():
    cfg = tiny_cfg()
    ccfg = CT.ConsensusConfig(n_pods=2, scheme="uniform", h_steps=1)
    state = CT.init_state(cfg, jax.random.PRNGKey(0), ccfg)
    # perturb pod 1's params
    params = jax.tree_util.tree_map(
        lambda p: p.at[1].add(jnp.ones_like(p[1])), state.params)
    w = jax.tree_util.tree_map(lambda p: jnp.ones_like(p, jnp.float32),
                               params)
    comb = CT.combine("uniform", params, w)
    ref = jax.tree_util.tree_map(
        lambda p: (p[0].astype(jnp.float32) +
                   p[1].astype(jnp.float32)) / 2, params)
    for a, b in zip(jax.tree_util.tree_leaves(comb),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-3)


def test_max_combine_selects_argmax_pod():
    cfg = tiny_cfg()
    ccfg = CT.ConsensusConfig(n_pods=2, scheme="max", h_steps=1)
    state = CT.init_state(cfg, jax.random.PRNGKey(0), ccfg)
    params = jax.tree_util.tree_map(
        lambda p: p.at[1].set(7.0), state.params)
    # pod 1 has strictly larger weights everywhere
    w = jax.tree_util.tree_map(
        lambda p: jnp.stack([jnp.ones_like(p[0], jnp.float32),
                             2 * jnp.ones_like(p[0], jnp.float32)]), params)
    comb = CT.combine("max", params, w)
    for leaf in jax.tree_util.tree_leaves(comb):
        assert np.allclose(np.asarray(leaf, np.float32), 7.0)


def test_diagonal_weights_downweight_noisy_pod():
    """Fisher-weighted combine must pull toward the low-variance pod —
    the paper's inverse-variance weighting at pod granularity."""
    cfg = tiny_cfg()
    ccfg = CT.ConsensusConfig(n_pods=2, scheme="diagonal", h_steps=1)
    state = CT.init_state(cfg, jax.random.PRNGKey(0), ccfg)
    params = jax.tree_util.tree_map(
        lambda p: jnp.stack([jnp.zeros_like(p[0]),
                             jnp.ones_like(p[1])]), state.params)
    # pod0 weight 10 (low variance), pod1 weight 1
    w = jax.tree_util.tree_map(
        lambda p: jnp.stack([10 * jnp.ones_like(p[0], jnp.float32),
                             jnp.ones_like(p[1], jnp.float32)]), params)
    comb = CT.combine("diagonal", params, w)
    for leaf in jax.tree_util.tree_leaves(comb):
        v = np.asarray(leaf, np.float32)
        np.testing.assert_allclose(v, 1.0 / 11.0, atol=1e-3)


def test_admm_anytime_theta_bar_stays_finite_and_converges():
    """Thm 3.1 analogue: theta_bar is usable after EVERY round, and local
    params are pulled toward it by the proximal term."""
    cfg = tiny_cfg()
    ccfg = CT.ConsensusConfig(n_pods=2, scheme="admm", h_steps=2, rho=10.0)
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=100)
    tcfg = TS.TrainConfig()
    state = CT.init_state(cfg, jax.random.PRNGKey(0), ccfg)
    round_step = CT.make_round_step(cfg, ocfg, tcfg, ccfg)
    gaps = []
    for r in range(3):
        batch = make_batch(cfg, 2, 2, seed=r)
        state, metrics = round_step(state, batch)
        assert all(bool(jnp.all(jnp.isfinite(l)))
                   for l in jax.tree_util.tree_leaves(state.theta_bar))
        gap = sum(float(jnp.mean(jnp.abs(
            p.astype(jnp.float32) - tb.astype(jnp.float32)[None])))
            for p, tb in zip(jax.tree_util.tree_leaves(state.params),
                             jax.tree_util.tree_leaves(state.theta_bar)))
        gaps.append(gap)
    assert np.isfinite(gaps).all()


def test_consensus_reduces_loss_vs_init():
    """A few rounds of diagonal consensus training reduce the LM loss."""
    cfg = tiny_cfg()
    ccfg = CT.ConsensusConfig(n_pods=2, scheme="diagonal", h_steps=2)
    ocfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50)
    tcfg = TS.TrainConfig()
    state = CT.init_state(cfg, jax.random.PRNGKey(0), ccfg)
    round_step = jax.jit(CT.make_round_step(cfg, ocfg, tcfg, ccfg))
    losses = []
    for r in range(5):
        batch = make_batch(cfg, 2, 2, seed=100 + r)
        state, metrics = round_step(state, batch)
        losses.append(float(metrics["nll"]))
    assert losses[-1] < losses[0]
