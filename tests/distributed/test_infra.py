"""Training infrastructure: optimizer, data pipeline, checkpointing,
sharding resolution (structural, no multi-device mesh needed)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import repro.configs as CFG
from repro.checkpoint import io as CK
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import adamw


def test_adamw_minimizes_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=400,
                            weight_decay=0.0)
    for _ in range(300):
        grads = {"w": params["w"] - target}
        params, state = adamw.update(cfg, grads, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_adamw_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6        # mid-warmup
    assert abs(lrs[2] - 1.0) < 1e-6        # warmup done
    assert lrs[3] < lrs[2]                 # decaying
    assert abs(lrs[4] - 0.1) < 1e-3        # floor


def test_fisher_diag_tracks_grad_scale():
    """Adam v must be larger for the coordinate with larger gradients —
    the paper's per-parameter quality signal."""
    params = {"w": jnp.zeros(2)}
    state = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=1e-3)
    for i in range(50):
        g = jnp.asarray([10.0, 0.1]) * (1 + 0.1 * np.sin(i))
        params, state = adamw.update(cfg, {"w": g}, state, params)
    fd = adamw.fisher_diag(state)["w"]
    assert float(fd[0]) > 100 * float(fd[1])


def test_data_pipeline_deterministic_and_disjoint():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=8, seed=3)
    ds = SyntheticLM(cfg)
    b1 = ds.batch(5, shard=0, n_shards=2)
    b2 = ds.batch(5, shard=0, n_shards=2)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = ds.batch(5, shard=1, n_shards=2)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert b1["tokens"].shape == (4, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))


@given(st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_data_tokens_in_range(idx):
    cfg = DataConfig(vocab_size=50, seq_len=16, global_batch=2, seed=0)
    b = SyntheticLM(cfg).batch(idx)
    toks = np.asarray(b["tokens"])
    assert toks.min() >= 0 and toks.max() < 50


def test_checkpoint_roundtrip(tmp_path):
    from repro.train import step as TS
    r = CFG.reduced(CFG.get("llama3.2-3b"))
    state = TS.init_state(r, jax.random.PRNGKey(0))
    path = CK.save(str(tmp_path), 7, state, extra={"arch": r.arch_id})
    assert os.path.isdir(path)
    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored = CK.restore(str(tmp_path), 7, template)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert CK.latest_step(str(tmp_path)) == 7


def test_param_sharding_divisibility_guard():
    """Sharding resolver must never emit a non-divisible partition."""
    from jax.sharding import Mesh
    from repro.distributed import sharding as SH
    from repro.models import transformer as T
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))

    class Fake16:
        shape = {"data": 16, "model": 16}
    for arch in CFG.ARCH_IDS:
        cfg = CFG.get(arch)
        tree = T.abstract_params(cfg)
        flat = jax.tree_util.tree_flatten(
            tree, is_leaf=lambda x: hasattr(x, "axes"))[0]
        for ps in flat:
            pspec = SH.param_pspec(ps, Fake16)
            for dim, entry in zip(ps.shape, pspec):
                if entry == "model":
                    assert dim % 16 == 0, (arch, ps.shape, tuple(pspec))


def test_cache_sharding_divisibility_guard():
    from repro.distributed import sharding as SH

    class Fake16:
        shape = {"data": 16, "model": 16}
    for arch in CFG.ARCH_IDS:
        cfg = CFG.get(arch)
        from repro.models import transformer as T
        cache = T.init_cache(cfg, 128, 1024)
        flat = jax.tree_util.tree_flatten_with_path(cache)[0]
        for path, leaf in flat:
            name = [str(p.key) for p in path if hasattr(p, "key")][-1]
            stacked = any(str(getattr(p, "key", "")) == "units"
                          for p in path)
            pspec = SH.cache_pspec(name, leaf.shape, Fake16, stacked)
            for dim, entry in zip(leaf.shape, pspec):
                if entry in ("model", "data"):
                    assert dim % 16 == 0, (arch, name, leaf.shape,
                                           tuple(pspec))
