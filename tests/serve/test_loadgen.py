"""The load harness is deterministic: same seed -> byte-identical request
schedules, and replaying one schedule against coalescing ON vs OFF servers
yields identical numerical results per ticket (the property the serving
benchmark's speedup comparison rests on).
"""
import numpy as np

import repro.core as C
from repro.api.plan import Plan
from repro.serve import SessionServer, VirtualClock, run_load, \
    synthetic_workload


def _plans():
    pa = Plan(graph=C.chain_graph(4), family="ising",
              combiners=("diagonal",), n_iter=8)
    pb = pa.replace(combiners=("uniform",))
    return {"a0": pa, "a1": pa, "b0": pb}


def test_synthetic_workload_is_a_pure_function_of_its_seed():
    plans = _plans()
    s1 = synthetic_workload(plans, rounds=2, n_rows=12, seed=5)
    s2 = synthetic_workload(plans, rounds=2, n_rows=12, seed=5)
    s3 = synthetic_workload(plans, rounds=2, n_rows=12, seed=6)
    assert len(s1) == 2 and len(s1[0]) == 3
    for reqs1, reqs2 in zip(s1, s2):
        for (t1, X1, k1), (t2, X2, k2) in zip(reqs1, reqs2):
            assert (t1, k1) == (t2, k2)
            np.testing.assert_array_equal(X1, X2)
    assert any(not np.array_equal(X1, X3)
               for (_, X1, _), (_, X3, _) in zip(s1[0], s3[0]))


def test_coalesced_and_serial_replay_agree_and_report_load():
    plans = _plans()
    schedule = synthetic_workload(plans, rounds=3, n_rows=16, seed=1)

    def serve(coalesce):
        srv = SessionServer(coalesce=coalesce, max_coalesce=4,
                            clock=VirtualClock())
        for tid, plan in plans.items():
            srv.register(tid, plan)
        return srv, run_load(srv, schedule, round_dt=1.0)

    srv_c, rep_c = serve(True)
    srv_s, rep_s = serve(False)
    for rep in (rep_c, rep_s):
        assert rep.n_submitted == 9
        assert rep.n_served == 9
        assert rep.n_rejected == 0
        assert rep.latencies_s.shape == (9,)
        assert rep.wall_s > 0
        summary = rep.summary()
        assert summary["p99_ms"] >= summary["p50_ms"] >= 0
        assert summary["throughput_rps"] > 0
    # coalescing actually grouped the equal-plan tenants...
    assert max(rep_c.coalesce_sizes) == 2
    assert max(rep_s.coalesce_sizes) == 1
    # ...and the numbers a tenant gets back do not depend on the mode
    for tc, ts in zip(rep_c.tickets, rep_s.tickets):
        assert (tc.tenant_id, tc.kind, tc.seq) == (ts.tenant_id, ts.kind,
                                                   ts.seq)
        np.testing.assert_allclose(tc.result.theta, ts.result.theta,
                                   atol=5e-6)
        assert tc.result.comm_scalars == ts.result.comm_scalars


def test_warm_replay_reports_zero_new_compiles():
    plans = _plans()
    schedule = synthetic_workload(plans, rounds=2, n_rows=16, seed=2)
    srv = SessionServer(max_coalesce=4, clock=VirtualClock())
    for tid, plan in plans.items():
        srv.register(tid, plan)
    run_load(srv, schedule)  # cold pass compiles
    rep = run_load(srv, schedule)  # identical warm replay
    assert rep.new_compiles == 0
