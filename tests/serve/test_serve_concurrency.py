"""Deterministic concurrency invariants of the session server.

N tenants registering EQUAL plans share ONE compiled session (the
plan-keyed cache), so the cold cost of a coalesced dispatch is exactly
``n_buckets`` bucket-solver compilations — and once warm, same-shape
requests under sustained multi-tenant load compile NOTHING, measured by
``bucket_compile_count()`` deltas around the serving loop.
"""
import numpy as np
import pytest

import jax

import repro.core as C
from repro.api.plan import Plan
from repro.core.batched import (bucket_compile_count,
                                clear_bucket_solver_caches)
from repro.serve import SessionServer


@pytest.fixture()
def plan():
    return Plan(graph=C.chain_graph(5), family="ising",
                combiners=("diagonal",), n_iter=8)


def _rows(plan, n, seed):
    fam = plan.family_instance
    key = jax.random.PRNGKey(seed)
    theta = np.asarray(fam.random_params(plan.graph, jax.random.fold_in(key, 0)))
    return np.asarray(fam.exact_sample(plan.graph, theta, n,
                                       jax.random.fold_in(key, 1)))


def test_equal_plan_tenants_share_one_session(plan):
    srv = SessionServer(max_coalesce=4)
    tenants = [srv.register(f"t{i}", plan) for i in range(4)]
    first = tenants[0].session
    assert all(t.session is first for t in tenants[1:])
    # and the shared session is the plan's own cached session
    assert first is plan.session()


def test_cold_coalesced_dispatch_compiles_exactly_n_buckets(plan):
    srv = SessionServer(max_coalesce=4)
    for i in range(4):
        srv.register(f"t{i}", plan)
    n_buckets = plan.session().n_buckets
    clear_bucket_solver_caches()
    tickets = [srv.submit(f"t{i}", _rows(plan, 32, 100 + i))
               for i in range(4)]
    served = srv.drain()
    assert len(served) == 4
    # ONE union dispatch for the whole group...
    assert all(t.result.coalesce_size == 4 for t in tickets)
    # ...whose cold cost is one compiled program per degree bucket (the
    # union graph repeats the same distinct padded degrees)
    assert bucket_compile_count() == n_buckets
    assert all(t.result.new_compiles == n_buckets for t in tickets)


def test_warm_same_shape_requests_compile_nothing_under_load(plan):
    srv = SessionServer(max_coalesce=4)
    for i in range(4):
        srv.register(f"t{i}", plan)
    # warm the (fit, shape) path once
    for i in range(4):
        srv.submit(f"t{i}", _rows(plan, 32, 200 + i))
    srv.drain()
    c0 = bucket_compile_count()
    tickets = []
    for rnd in range(3):  # sustained load: 3 rounds x 4 tenants
        for i in range(4):
            tickets.append(srv.submit(f"t{i}",
                                      _rows(plan, 32, 300 + 10 * rnd + i)))
    srv.drain()
    assert all(t.done for t in tickets)
    assert bucket_compile_count() - c0 == 0
    assert all(t.result.new_compiles == 0 for t in tickets)


def test_warm_stream_rounds_settle_to_zero_compiles(plan):
    """Streaming rounds stabilize: after the cold round and the one
    cold->warm flag flip (the warm-start guard is a static solver
    argument), every further same-shape round compiles nothing."""
    srv = SessionServer(max_coalesce=2)
    srv.register("a", plan)
    srv.register("b", plan)
    # 8-row rounds keep 5 rounds within the 64-row buffer capacity, so the
    # padded pool shape (part of the coalesce key) stays constant
    for rnd in range(2):  # cold round + first warm round pay compiles
        srv.submit("a", _rows(plan, 8, 400 + rnd), kind="stream")
        srv.submit("b", _rows(plan, 8, 450 + rnd), kind="stream")
        srv.drain()
    c0 = bucket_compile_count()
    tickets = []
    for rnd in range(3):
        tickets.append(srv.submit("a", _rows(plan, 8, 500 + rnd),
                                  kind="stream"))
        tickets.append(srv.submit("b", _rows(plan, 8, 550 + rnd),
                                  kind="stream"))
        srv.drain()
    assert all(t.done for t in tickets)
    assert all(t.result.coalesce_size == 2 for t in tickets)
    assert bucket_compile_count() - c0 == 0


def test_same_tenant_requests_never_share_a_group(plan):
    """Two queued requests of one tenant stay ordered across groups (a
    tenant appears at most once per dispatch)."""
    srv = SessionServer(max_coalesce=4)
    srv.register("a", plan)
    srv.register("b", plan)
    t1 = srv.submit("a", _rows(plan, 32, 600))
    t2 = srv.submit("b", _rows(plan, 32, 601))
    t3 = srv.submit("a", _rows(plan, 32, 602))
    first = srv.pump()
    assert {t.seq for t in first} == {t1.seq, t2.seq}
    assert t3.status == "queued"
    second = srv.pump()
    assert [t.seq for t in second] == [t3.seq]
    assert t3.result.coalesce_size == 1


def test_fifo_preserved_when_stream_keys_mismatch(plan):
    """A same-plan candidate whose group key mismatches (cold tenant vs
    warm head) still blocks that tenant's LATER queued requests: only the
    first queued request per tenant is ever considered (or ingested) per
    pump, so stream rows enter the pool in submission order and each
    round's refit sees exactly the serial-serving pool."""
    srv = SessionServer(max_coalesce=4)
    srv.register("a", plan)
    srv.register("b", plan)
    # warm a with one round so its next round's warm-start flag (part of
    # the coalesce key) mismatches b's cold first round
    srv.submit("a", _rows(plan, 8, 800), kind="stream")
    srv.drain()
    ta2 = srv.submit("a", _rows(plan, 8, 801), kind="stream")
    Xb1, Xb2 = _rows(plan, 8, 810), _rows(plan, 8, 811)
    tb1 = srv.submit("b", Xb1, kind="stream")
    tb2 = srv.submit("b", Xb2, kind="stream")
    first = srv.pump()
    assert [t.seq for t in first] == [ta2.seq]
    # b's first round was considered (and ingested) but not grouped; its
    # SECOND round must not have been ingested behind it
    assert int(srv.tenant("b").stream.buffer.n) == 8
    second = srv.pump()
    assert [t.seq for t in second] == [tb1.seq]
    assert tb1.result.n_samples == 8
    third = srv.pump()
    assert [t.seq for t in third] == [tb2.seq]
    assert tb2.result.n_samples == 16
    # the round-1 refit saw only round-1 rows: bit-identical to serial
    ref_srv = SessionServer(coalesce=False)
    ref_srv.register("b", plan)
    r1 = ref_srv.submit("b", Xb1, kind="stream")
    ref_srv.drain()
    np.testing.assert_allclose(tb1.result.theta, r1.result.theta,
                               atol=1e-10, rtol=0)


def test_fifo_preserved_across_kinds(plan):
    """A tenant whose first queued request is a fit must not have a later
    stream request considered (or its rows ingested) ahead of it, even
    when the stream request matches the pumping group's kind."""
    srv = SessionServer(max_coalesce=4)
    srv.register("a", plan)
    srv.register("b", plan)
    ts_a = srv.submit("a", _rows(plan, 8, 820), kind="stream")
    tf_b = srv.submit("b", _rows(plan, 8, 821), kind="fit")
    ts_b = srv.submit("b", _rows(plan, 8, 822), kind="stream")
    first = srv.pump()
    assert [t.seq for t in first] == [ts_a.seq]
    # b's stream was never touched: its earlier fit still gates it
    assert srv.tenant("b")._stream is None
    second = srv.pump()
    assert [t.seq for t in second] == [tf_b.seq]
    third = srv.pump()
    assert [t.seq for t in third] == [ts_b.seq]


def test_stream_group_members_report_own_n_samples(plan):
    """Stream groups key on the padded buffer shape, so members with
    different ingested totals coalesce — each must report its own pool
    count, not the head tenant's."""
    srv = SessionServer(max_coalesce=2)
    srv.register("a", plan)
    srv.register("b", plan)
    ta = srv.submit("a", _rows(plan, 8, 830), kind="stream")
    tb = srv.submit("b", _rows(plan, 16, 831), kind="stream")
    served = srv.pump()
    assert {t.seq for t in served} == {ta.seq, tb.seq}
    assert ta.result.coalesce_size == 2
    assert ta.result.n_samples == 8
    assert tb.result.n_samples == 16


def test_coalesce_disabled_serves_serially(plan):
    srv = SessionServer(coalesce=False)
    for i in range(3):
        srv.register(f"t{i}", plan)
    tickets = [srv.submit(f"t{i}", _rows(plan, 32, 700 + i))
               for i in range(3)]
    srv.drain()
    assert all(t.result.coalesce_size == 1 for t in tickets)
    snap = srv.metrics()
    assert snap.counter("serve.dispatches") == 3
