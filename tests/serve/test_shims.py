"""Regression tests for the serving-tier migration shims.

The transformer-era ``repro.serve.engine`` / ``repro.launch.serve`` were
replaced when ``repro.serve`` became the estimation session server; their
module names are kept as shims that raise a ``ModuleNotFoundError`` whose
message points at the new homes (``repro.models.decoding`` for decode,
``repro.serve.SessionServer`` for serving), so a stale import fails loudly
with directions instead of resolving to the wrong subsystem.
"""
import importlib

import pytest


def test_serve_engine_shim_raises_with_pointers():
    with pytest.raises(ModuleNotFoundError) as ei:
        importlib.import_module("repro.serve.engine")
    msg = str(ei.value)
    assert "repro.models.decoding" in msg
    assert "SessionServer" in msg
    assert ei.value.name == "repro.serve.engine"


def test_serve_engine_shim_raises_on_reimport_too():
    """A failed import is not cached as a success: importing the shim a
    second time raises the same migration error."""
    for _ in range(2):
        with pytest.raises(ModuleNotFoundError, match="repro.models.decoding"):
            importlib.import_module("repro.serve.engine")


def test_launch_serve_shim_raises_with_pointers():
    with pytest.raises(ModuleNotFoundError) as ei:
        importlib.import_module("repro.launch.serve")
    msg = str(ei.value)
    assert "repro.serve" in msg
    assert "serve_bench" in msg
    assert ei.value.name == "repro.launch.serve"


def test_serve_package_still_imports():
    """The shim does not poison the parent package: ``repro.serve`` is the
    session-server package and imports cleanly."""
    import repro.serve as S
    assert hasattr(S, "SessionServer")
    assert hasattr(S, "BudgetSpec")


def test_decode_helpers_live_at_new_home():
    from repro.models import decoding as D
    for fn in ("make_serve_step", "prefill", "generate"):
        assert callable(getattr(D, fn))
