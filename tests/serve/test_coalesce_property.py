"""Coalesced serving is numerically identical to serial serving.

The acceptance bar (1e-10) is asserted under float64 plans: the engine's
Newton ``while_loop`` stops on the *bucket-wide* max step, so coalescing a
tenant with others can run its already-converged nodes a few extra
iterations — in float64 those extra steps shrink quadratically below the
solver tolerance (<= ~1e-12 drift), while in float32 they bounce at the
jitter floor (~1e-7), which is why these tests pin ``precision="float64"``
(the default-precision servers in the other modules exercise the same
machinery at float32).

Covered here:
* a deterministic sweep — EVERY registered family x EVERY streamable
  combiner: a coalesced 2-tenant fit dispatch equals each request's own
  session fit + combine to 1e-10;
* a deterministic heterogeneous mix (different plans interleaved, so
  groups must form only among equal plans) seeded from RandomState;
* a hypothesis property test drawing arbitrary tenant mixes of
  (family, combiner set, sample count, group size).
"""
import numpy as np
import pytest

import jax

import repro.core as C
from repro.api.plan import Plan
from repro.serve import SessionServer

FAMILY_NAMES = [f.name for f in C.families.registered_families()]
STREAMABLE_NAMES = [c.name for c in C.combiners.streamable_combiners()]

#: small graphs with distinct degree profiles; low max degree keeps every
#: per-node problem well-posed at modest n (no quasi-separation, where the
#: near-singular sandwich amplifies iteration-schedule jitter)
GRAPHS = {
    "chain": C.chain_graph(5),
    "loop": C.Graph(4, ((0, 1), (1, 2), (2, 3), (0, 3))),
}


@pytest.fixture(scope="module", autouse=True)
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


def _rows(plan, n, key):
    fam = plan.family_instance
    theta = np.asarray(fam.random_params(plan.graph, jax.random.fold_in(key, 0)))
    return np.asarray(
        fam.exact_sample(plan.graph, theta, n, jax.random.fold_in(key, 1)),
        dtype=np.float64)


def _assert_ticket_matches_serial(ticket, plan, atol=1e-10):
    """The served result equals a fit through the request's own session."""
    sess = plan.session()
    ref_fits = sess.fit_local(ticket.result._ref_X)
    for got, ref in zip(ticket.result.fits, ref_fits):
        np.testing.assert_allclose(got.theta, ref.theta, atol=atol, rtol=0)
        np.testing.assert_allclose(got.V, ref.V, atol=atol, rtol=0)
    for c in sess.combiners:
        ref_combined = c.combine(plan.graph, ref_fits,
                                 include_singleton=plan.include_singleton,
                                 theta_fixed=sess.theta_fixed,
                                 family=sess.family)
        np.testing.assert_allclose(ticket.result.combined[c.name],
                                   ref_combined, atol=atol, rtol=0,
                                   err_msg=f"combiner {c.name}")


def _serve_coalesced(tenant_plans, tenant_rows, max_coalesce=8):
    """One coalesced server pass; stashes each request's rows on the result
    so the serial reference can replay it."""
    srv = SessionServer(max_coalesce=max_coalesce)
    tickets = {}
    for tid, plan in tenant_plans.items():
        srv.register(tid, plan)
    for tid in tenant_plans:
        tickets[tid] = srv.submit(tid, tenant_rows[tid])
    srv.drain()
    for tid, t in tickets.items():
        assert t.done, (tid, t.status, t.reject_reason)
        t.result._ref_X = tenant_rows[tid]
    return tickets


@pytest.mark.parametrize("family", FAMILY_NAMES)
@pytest.mark.parametrize("combiner", STREAMABLE_NAMES)
def test_every_family_x_streamable_combiner_bit_identical(family, combiner):
    g = GRAPHS["chain"]
    plan = Plan(graph=g, family=family, combiners=(combiner,),
                precision="float64", n_iter=40)
    seed = (FAMILY_NAMES.index(family) * len(STREAMABLE_NAMES)
            + STREAMABLE_NAMES.index(combiner))
    key = jax.random.PRNGKey(seed)
    rows = {"t0": _rows(plan, 96, jax.random.fold_in(key, 10)),
            "t1": _rows(plan, 96, jax.random.fold_in(key, 11))}
    tickets = _serve_coalesced({"t0": plan, "t1": plan}, rows)
    assert tickets["t0"].result.coalesce_size == 2
    for tid in rows:
        _assert_ticket_matches_serial(tickets[tid], plan)


def test_heterogeneous_tenant_mix_coalesces_only_equal_plans():
    """Interleaved tenants of three different plans: groups form only among
    equal plans, and every result matches its own serial session."""
    rng = np.random.RandomState(0)
    plan_a = Plan(graph=GRAPHS["chain"], family="ising",
                  combiners=("diagonal",), precision="float64", n_iter=40)
    plan_b = Plan(graph=GRAPHS["loop"], family="gaussian",
                  combiners=("uniform", "max"), precision="float64",
                  n_iter=40)
    plan_c = plan_a.replace(combiners=("krum",))
    plans, rows = {}, {}
    key = jax.random.PRNGKey(7)
    for j, plan in enumerate([plan_a, plan_b, plan_c, plan_a, plan_b,
                              plan_a, plan_c]):
        tid = f"t{j}"
        plans[tid] = plan
        rows[tid] = _rows(plan, 64, jax.random.fold_in(key, 100 + j))
    order = list(plans)
    rng.shuffle(order)
    srv = SessionServer(max_coalesce=4)
    for tid in order:
        srv.register(tid, plans[tid])
    tickets = {tid: srv.submit(tid, rows[tid]) for tid in order}
    srv.drain()
    for tid, t in tickets.items():
        assert t.done
        t.result._ref_X = rows[tid]
        _assert_ticket_matches_serial(t, plans[tid])
    # the three plan_a tenants shaped one group (padded pow2 handles r=3)
    sizes = sorted(tickets[tid].result.coalesce_size for tid in plans)
    assert max(sizes) >= 2


def test_stream_rounds_bit_identical_to_serial_stream():
    """Three coalesced streaming rounds reproduce an uncoalesced
    StreamingEstimator round for round (including the warm rounds, which
    dispatch with the warm-start flag in the group key)."""
    plan = Plan(graph=GRAPHS["chain"], family="ising",
                combiners=("diagonal",), precision="float64", n_iter=40)
    key = jax.random.PRNGKey(3)
    srv = SessionServer(max_coalesce=2)
    srv.register("a", plan)
    srv.register("b", plan)
    ref = plan.session().stream()
    for rnd in range(3):
        Xa = _rows(plan, 32, jax.random.fold_in(key, 10 * rnd))
        Xb = _rows(plan, 32, jax.random.fold_in(key, 10 * rnd + 1))
        ta = srv.submit("a", Xa, kind="stream")
        tb = srv.submit("b", Xb, kind="stream")
        srv.drain()
        assert ta.done and tb.done
        assert ta.result.coalesce_size == 2
        ref.ingest(Xa)
        ref_fits = ref.refit()
        for got, want in zip(ta.result.fits, ref_fits):
            np.testing.assert_allclose(got.theta, want.theta,
                                       atol=1e-10, rtol=0)


# --------------------------------------------------------------- hypothesis
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402


@st.composite
def tenant_mixes(draw):
    """2-5 tenants over 1-2 distinct plans (family x combiner subset),
    shared graph per plan, per-tenant sample matrices of a common n."""
    n = draw(st.sampled_from([48, 96]))
    n_plans = draw(st.integers(min_value=1, max_value=2))
    plans = []
    for k in range(n_plans):
        family = draw(st.sampled_from(FAMILY_NAMES))
        combs = tuple(draw(st.lists(st.sampled_from(STREAMABLE_NAMES),
                                    min_size=1, max_size=2, unique=True)))
        gname = draw(st.sampled_from(sorted(GRAPHS)))
        plans.append(Plan(graph=GRAPHS[gname], family=family,
                          combiners=combs, precision="float64", n_iter=40))
    assignment = draw(st.lists(st.integers(0, n_plans - 1),
                               min_size=2, max_size=5))
    seed = draw(st.integers(min_value=0, max_value=2**20))
    return plans, assignment, n, seed


@settings(max_examples=8, deadline=None)
@given(mix=tenant_mixes())
def test_arbitrary_tenant_mixes_match_serial(mix):
    plans, assignment, n, seed = mix
    key = jax.random.PRNGKey(seed)
    tenant_plans, rows = {}, {}
    for j, k in enumerate(assignment):
        tid = f"h{j}"
        tenant_plans[tid] = plans[k]
        rows[tid] = _rows(plans[k], n, jax.random.fold_in(key, j))
    tickets = _serve_coalesced(tenant_plans, rows, max_coalesce=4)
    for tid, t in tickets.items():
        _assert_ticket_matches_serial(t, tenant_plans[tid])
