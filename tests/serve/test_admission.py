"""Admission-control edge cases: exact budget exhaustion, telemetry-surfaced
rejection reasons, replenishment schedules, and queue-full backpressure.

All decisions run on a :class:`VirtualClock`, so every schedule is exact.
"""
import numpy as np
import pytest

import jax

import repro.core as C
from repro.api.plan import Plan
from repro.serve import (REJECT_BUDGET, REJECT_QUEUE_FULL, BudgetSpec,
                         BudgetState, SessionServer, VirtualClock)


@pytest.fixture()
def plan():
    return Plan(graph=C.chain_graph(4), family="ising",
                combiners=("diagonal",), n_iter=8)


def _rows(plan, n, seed):
    fam = plan.family_instance
    key = jax.random.PRNGKey(seed)
    theta = np.asarray(fam.random_params(plan.graph, jax.random.fold_in(key, 0)))
    return np.asarray(fam.exact_sample(plan.graph, theta, n,
                                       jax.random.fold_in(key, 1)))


def _server(plan, budget, **kw):
    clock = VirtualClock()
    srv = SessionServer(clock=clock, **kw)
    srv.register("a", plan, budget=budget)
    return srv, clock


# ------------------------------------------------------------ exact budgets
def test_budget_exactly_exhausted_mid_stream(plan):
    """A budget of exactly 3 rounds admits rounds 1-3 (the third lands the
    ledger on exactly zero) and rejects round 4 with the budget reason."""
    srv, _ = _server(plan, budget=None)
    cost = srv.request_cost("a", 16)
    assert cost > 0
    srv, _ = _server(plan, budget=BudgetSpec(scalars=3 * cost))
    tickets = [srv.submit("a", _rows(plan, 16, 10 + r), kind="stream")
               for r in range(4)]
    srv.drain()
    assert [t.admitted for t in tickets] == [True, True, True, False]
    assert [t.done for t in tickets] == [True, True, True, False]
    assert tickets[3].reject_reason == REJECT_BUDGET
    assert srv.tenant("a").budget.remaining == 0
    # the charge billed on each admitted ticket is the exact one-step cost
    assert all(t.result.comm_scalars == cost for t in tickets[:3])


def test_rejection_reason_surfaces_in_telemetry_counters(plan):
    srv, _ = _server(plan, budget=BudgetSpec(scalars=0))
    t = srv.submit("a", _rows(plan, 16, 20))
    assert not t.admitted
    snap = srv.metrics()
    assert snap.counter("serve.rejected", reason=REJECT_BUDGET) == 1
    assert snap.counter("serve.rejected", reason=REJECT_BUDGET,
                        tenant="a") == 1
    assert snap.counter("serve.rejected", reason=REJECT_QUEUE_FULL) == 0
    assert snap.counter("serve.admitted") == 0


def test_replenishment_resumes_service(plan):
    srv, clock = _server(plan, budget=None)
    cost = srv.request_cost("a", 16)
    srv, clock = _server(plan,
                         budget=BudgetSpec(scalars=cost,
                                           replenish_every=60.0))
    t1 = srv.submit("a", _rows(plan, 16, 30))
    t2 = srv.submit("a", _rows(plan, 16, 31))
    assert t1.admitted and not t2.admitted
    clock.advance(59.9)
    assert not srv.submit("a", _rows(plan, 16, 32)).admitted
    clock.advance(0.1)  # refill boundary: registration + 60s
    t4 = srv.submit("a", _rows(plan, 16, 33))
    assert t4.admitted
    srv.drain()
    assert t1.done and t4.done
    snap = srv.metrics()
    assert snap.counter("serve.rejected", reason=REJECT_BUDGET,
                        tenant="a") == 2
    assert snap.counter("serve.served", tenant="a") == 2


def test_replenishment_catches_up_after_idle_gap():
    spec = BudgetSpec(scalars=10, replenish_every=5.0)
    st = BudgetState(spec, now=0.0)
    assert st.try_charge(10, now=0.0)
    # three whole windows pass unobserved; one refill catches up, and the
    # next boundary is the schedule's (t=20), not now+5
    assert st.try_charge(10, now=17.0)
    assert not st.try_charge(1, now=19.9)
    assert st.try_charge(10, now=20.0)


def test_queue_full_backpressure_never_drops_admitted_requests(plan):
    srv, _ = _server(plan, budget=None, max_queue=3, max_coalesce=1)
    tickets = [srv.submit("a", _rows(plan, 16, 40 + i)) for i in range(5)]
    admitted = [t for t in tickets if t.admitted]
    rejected = [t for t in tickets if not t.admitted]
    assert len(admitted) == 3 and len(rejected) == 2
    assert all(t.reject_reason == REJECT_QUEUE_FULL for t in rejected)
    served = srv.drain()
    assert {t.seq for t in served} == {t.seq for t in admitted}
    assert all(t.done for t in admitted)
    # draining freed the queue — service resumes without intervention
    t6 = srv.submit("a", _rows(plan, 16, 46))
    assert t6.admitted
    srv.drain()
    assert t6.done


def test_queue_full_rejection_does_not_charge_the_budget(plan):
    """Backpressure is checked before the ledger: a queue-full rejection
    leaves the tenant's budget untouched."""
    srv, _ = _server(plan, budget=None)
    cost = srv.request_cost("a", 16)
    clock = VirtualClock()
    srv = SessionServer(max_queue=1, max_coalesce=1, clock=clock)
    srv.register("a", plan, budget=BudgetSpec(scalars=2 * cost))
    t1 = srv.submit("a", _rows(plan, 16, 50))
    t2 = srv.submit("a", _rows(plan, 16, 51))  # queue full
    assert t1.admitted and not t2.admitted
    assert t2.reject_reason == REJECT_QUEUE_FULL
    assert srv.tenant("a").budget.remaining == cost  # only t1 billed
    srv.drain()
    assert srv.submit("a", _rows(plan, 16, 52)).admitted


def test_per_tenant_budgets_are_independent(plan):
    clock = VirtualClock()
    srv = SessionServer(clock=clock)
    srv.register("rich", plan)  # unbudgeted
    srv.register("poor", plan, budget=BudgetSpec(scalars=0))
    tr = srv.submit("rich", _rows(plan, 16, 60))
    tp = srv.submit("poor", _rows(plan, 16, 61))
    assert tr.admitted and not tp.admitted
    srv.drain()
    assert tr.done
    snap = srv.metrics()
    assert snap.counter("serve.rejected", tenant="poor",
                        reason=REJECT_BUDGET) == 1
    assert snap.counter("serve.rejected", tenant="rich") == 0


# ------------------------------------------------------------- validation
def test_submit_validation_errors(plan):
    srv = SessionServer()
    with pytest.raises(KeyError, match="register"):
        srv.submit("ghost", np.zeros((4, 4)))
    srv.register("a", plan)
    with pytest.raises(ValueError, match="kind"):
        srv.submit("a", _rows(plan, 8, 70), kind="joint")
    with pytest.raises(ValueError, match="p=4"):
        srv.submit("a", np.zeros((8, 7)))
    with pytest.raises(ValueError, match="no sample rows"):
        srv.submit("a", np.zeros((0, 4)))
    with pytest.raises(ValueError, match="already registered"):
        srv.register("a", plan)


def test_register_rejects_fault_carrying_plan(plan):
    """The server never injects plan-level faults — coalesced dispatches
    strip them, so admitting a fault-carrying plan would make injection
    depend on which requests happened to group. Rejected at the door."""
    from repro.serve.coalesce import coalesced_plan
    from repro.stream.faults import CrashSpec, FaultPlan
    faulty = plan.replace(faults=FaultPlan(crashes=(CrashSpec(node=0, at=1),)))
    srv = SessionServer()
    with pytest.raises(ValueError, match="FaultPlan"):
        srv.register("a", faulty)
    # and coalesced_plan is fault-free for EVERY group size, including the
    # singleton path that otherwise passes the tenant plan through
    assert coalesced_plan(faulty, 1).faults is None
    assert coalesced_plan(faulty, 2).faults is None


def test_budget_spec_validation():
    with pytest.raises(ValueError, match=">= 0"):
        BudgetSpec(scalars=-1)
    with pytest.raises(ValueError, match="positive interval"):
        BudgetSpec(scalars=1, replenish_every=0.0)
    spec = BudgetSpec(scalars=5, replenish_every=2.5)
    assert BudgetSpec.from_dict(spec.to_dict()) == spec
    with pytest.raises(ValueError, match="forward"):
        VirtualClock().advance(-1.0)
