import os

# Keep tests on the single real CPU device (the dry-run sets its own flags in
# a separate process). Cap intra-op threads for stable CI timing.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)
