import os
import sys

# Keep tests on the single real CPU device (the dry-run sets its own flags in
# a separate process). Cap intra-op threads for stable CI timing.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)


# --------------------------------------------------------------- RNG hygiene
# Every random draw in this repo must come from an explicitly seeded
# generator (np.random.RandomState(seed) / jax.random.PRNGKey(seed)) so runs
# are reproducible. The audit found no remaining global-RNG calls; this
# guard keeps it that way: any call to numpy's *global* RNG convenience
# functions issued from a test module fails the test. Library code called
# by tests is unaffected (it owns its seeding discipline), as are
# hypothesis internals.
_GLOBAL_RNG_FNS = (
    "seed", "rand", "randn", "randint", "random", "random_sample",
    "standard_normal", "uniform", "normal", "choice", "shuffle",
    "permutation", "poisson", "binomial", "beta", "gamma", "exponential",
)


def _is_test_module(filename: str) -> bool:
    f = filename.replace(os.sep, "/")
    return "/tests/" in f or os.path.basename(f).startswith("test_")


@pytest.fixture(autouse=True)
def forbid_global_numpy_rng_in_tests(monkeypatch):
    def make_guard(name, orig):
        def guard(*args, **kwargs):
            caller = sys._getframe(1).f_globals.get("__file__", "")
            if caller and _is_test_module(str(caller)):
                raise AssertionError(
                    f"np.random.{name} uses the unseeded GLOBAL numpy RNG "
                    f"(called from {caller}); use a seeded "
                    f"np.random.RandomState / Generator instead")
            return orig(*args, **kwargs)
        return guard

    for name in _GLOBAL_RNG_FNS:
        orig = getattr(np.random, name, None)
        if orig is not None:
            monkeypatch.setattr(np.random, name, make_guard(name, orig))
