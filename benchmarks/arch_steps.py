"""Per-architecture reduced-config step timings: one train step + one decode
step per family on CPU. Not a performance claim (CPU host), but a living
check that every assigned architecture trains and serves through the public
API, with us/step for regression tracking."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

import repro.configs as CFG
from repro.models import transformer as T
from repro.optim import adamw
from repro.train import step as TS
from .util import emit, scale


def main() -> None:
    archs = CFG.ARCH_IDS if scale(False, True) else (
        "llama3.2-3b", "qwen2-moe-a2.7b", "recurrentgemma-2b",
        "xlstm-1.3b", "minicpm3-4b", "whisper-tiny")
    for arch in archs:
        r = CFG.reduced(CFG.get(arch))
        state = TS.init_state(r, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                    r.vocab_size)
        batch = {"tokens": tokens, "labels": tokens}
        if r.enc_dec:
            batch["enc_frames"] = 0.1 * jnp.ones(
                (2, r.n_frames, r.d_model), r.jdtype)
        step = jax.jit(TS.make_train_step(
            r, adamw.AdamWConfig(warmup_steps=1, total_steps=4),
            TS.TrainConfig()))
        state, m = step(state, batch)        # compile
        t0 = time.perf_counter()
        state, m = step(state, batch)
        jax.block_until_ready(m["nll"])
        us = (time.perf_counter() - t0) * 1e6
        # decode step
        cache = T.materialize_cache(r, 2, 32)
        import functools
        dec = jax.jit(functools.partial(T.decode_step, r))
        kw = {}
        if r.enc_dec:
            kw["enc_out"] = T.encode(r, state.params, batch["enc_frames"])
        lg, cache = dec(state.params, cache, tokens[:, :1], 0, **kw)
        t0 = time.perf_counter()
        lg, cache = dec(state.params, cache, tokens[:, 1:2], 1, **kw)
        jax.block_until_ready(lg)
        dus = (time.perf_counter() - t0) * 1e6
        emit(f"arch_step_{arch}", us,
             f"train_us={us:.0f} decode_us={dus:.0f} "
             f"nll={float(m['nll']):.3f}")


if __name__ == "__main__":
    main()
