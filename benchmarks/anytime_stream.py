"""Any-time streaming benchmark: error trajectories over a live network.

Runs the streaming engine on three topologies (star, grid, scale-free) with
three one-step combiner schemes plus streaming ADMM, against the oracle
centralized joint MPLE that sees all arrived data at once — tracing
error-vs-samples-seen and error-vs-scalars-communicated, the measurable form
of the paper's any-time + low-communication claims. Also asserts the
chunked-streaming == one-shot-batch invariant on each graph.

A second, hostile section replays the same engine through the fault-injection
layer: 20% Byzantine sign-flip (robust combiners must land within 2x their
fault-free error while Linear-Uniform degrades), a mid-stream change-point
with windowed re-fits tracking it, a crash/restart schedule, and a
kill-then-restore round asserting the durable checkpoint reproduces the
uninterrupted trajectory to 1e-10.

Writes ``BENCH_stream.json`` at the repo root.
"""
from __future__ import annotations

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

import repro.api as A
import repro.checkpoint as CK
import repro.core as C
import repro.stream as S
from .util import emit, emit_json, scale

SCHEMES = ("uniform", "diagonal", "max")


def _graphs():
    return [
        ("star10", C.star_graph(10)),
        ("grid", C.grid_graph(*scale((3, 3), (4, 4)))),
        ("scalefree", C.scale_free_graph(scale(15, 40), m=1, seed=0)),
    ]


def _sample_pool(model, n, key):
    if model.graph.p <= 16:
        return np.asarray(C.exact_sample(model, n, key))
    return np.asarray(C.gibbs_sample(model, n, key, burnin=200, thin=2))


def _run_graph(name, g, rounds, rate, seed):
    m = C.random_model(g, 0.5, 0.5, jax.random.PRNGKey(seed))
    theta_star = np.asarray(m.theta)
    pool = _sample_pool(m, rounds * rate + rate, jax.random.PRNGKey(seed + 1))
    rec = {"p": g.p, "m": g.m, "rounds": rounds, "rate": rate,
           "methods": {}}

    # one declarative plan per scheme; the simulator is configured from it
    for scheme in SCHEMES:
        plan = A.Plan(graph=g, combiners=(scheme,), capacity=128)
        sim = S.StreamSimulator.from_plan(
            plan, pool, theta_star=theta_star,
            arrivals=S.ArrivalSpec(rate=float(rate)), seed=seed)
        res = sim.run(rounds)
        rec["methods"][f"one_step_{scheme}"] = {
            "samples_seen": res.samples_seen.tolist(),
            "scalars_sent": res.scalars_sent.tolist(),
            "err": res.err.tolist(),
        }

    admm_plan = A.Plan(graph=g, capacity=128, admm_newton_iters=12)
    sim = S.StreamSimulator.from_plan(
        admm_plan, pool, estimator="admm", theta_star=theta_star,
        arrivals=S.ArrivalSpec(rate=float(rate)), seed=seed)
    res = sim.run(rounds)
    rec["methods"]["admm_stream"] = {
        "samples_seen": res.samples_seen.tolist(),
        "scalars_sent": res.scalars_sent.tolist(),
        "err": res.err.tolist(),
    }

    # oracle: centralized joint MPLE on everything that has arrived, at a
    # few checkpoints (its comm cost is the raw-data count, see comm_costs)
    checkpoints = sorted({rate, (rounds // 2) * rate, rounds * rate})
    orc_err, orc_seen, orc_scalars = [], [], []
    for n in checkpoints:
        th = C.fit_mple(g, jnp.asarray(pool[:n]))
        orc_err.append(C.mse(th, theta_star))
        orc_seen.append(float(n))
        orc_scalars.append(S.comm_costs(g, n, 0)["centralized"])
    rec["methods"]["oracle_mple"] = {
        "samples_seen": orc_seen, "scalars_sent": orc_scalars,
        "err": orc_err,
    }

    # invariant: chunked streaming == one-shot batch when nothing is
    # dropped — both verbs of ONE compiled session
    sess = A.Plan(graph=g, capacity=128).session()
    est = sess.stream()
    for chunk in np.array_split(pool[: rounds * rate], 4):
        est.ingest(chunk)
        est.refit()
    oneshot = sess.fit(pool[: rounds * rate]).fits
    chunk_diff = max(float(np.max(np.abs(a.theta - b.theta)))
                     for a, b in zip(est.fits, oneshot))
    rec["chunked_vs_batch_maxdiff"] = chunk_diff
    assert chunk_diff <= 1e-5, \
        f"{name}: chunked streaming diverged from batch ({chunk_diff:.2e})"

    for meth, tr in rec["methods"].items():
        err = tr["err"]
        assert np.all(np.isfinite(err)), f"{name}/{meth}: non-finite error"
        assert err[-1] < err[0], \
            f"{name}/{meth}: error did not decrease ({err[0]} -> {err[-1]})"
        emit(f"stream_{name}_{meth}", 0.0,
             f"err {err[0]:.4f}->{err[-1]:.4f} "
             f"n={tr['samples_seen'][-1]:.0f} "
             f"scalars={tr['scalars_sent'][-1]}")
    return rec


def _final_err(res) -> float:
    return float(res.err[-1])


def _run_hostile(rounds, rate):
    """Hostile-network rows: the same streaming engine through the fault
    layer. Star topology, leaves 8/9 Byzantine = 20% of the fleet."""
    g = C.star_graph(10)
    m = C.random_model(g, 0.5, 0.5, jax.random.PRNGKey(77))
    theta_star = np.asarray(m.theta)
    pool = _sample_pool(m, rounds * rate + rate, jax.random.PRNGKey(78))
    rec = {"p": g.p, "byzantine_frac": 0.2, "methods": {}}

    def run(scheme, faults=None, seed=21, **over):
        sim = S.StreamSimulator(
            g, pool, scheme=scheme, theta_star=theta_star,
            arrivals=S.ArrivalSpec(rate=float(rate)),
            network=S.NetworkConfig(drop_prob=0.1, delay=1),
            capacity=128, seed=seed, faults=faults, **over)
        return sim.run(rounds)

    # --- 20% Byzantine sign-flip: robust schemes within 2x fault-free ----
    byz = S.FaultPlan(byzantine=(S.ByzantineSpec(node=8, kind="sign_flip"),
                                 S.ByzantineSpec(node=9, kind="sign_flip")))
    for scheme in ("uniform", "trimmed_mean", "krum"):
        clean = _final_err(run(scheme))
        hostile_res = run(scheme, faults=byz)
        hostile = _final_err(hostile_res)
        rec["methods"][f"byzantine_{scheme}"] = {
            "err_fault_free": clean, "err_hostile": hostile,
            "err": hostile_res.err.tolist(),
            "scalars_sent": hostile_res.scalars_sent.tolist(),
        }
        emit(f"stream_hostile_byz_{scheme}", 0.0,
             f"clean {clean:.4f} hostile {hostile:.4f}")
        if scheme in ("trimmed_mean", "krum"):
            assert hostile <= 2.0 * clean + 1e-6, \
                f"{scheme} did not survive 20% sign-flip " \
                f"({hostile:.4f} vs fault-free {clean:.4f})"
    u = rec["methods"]["byzantine_uniform"]
    t = rec["methods"]["byzantine_trimmed_mean"]
    assert u["err_hostile"] > 2.0 * u["err_fault_free"], \
        "uniform unexpectedly survived Byzantine sign-flip"
    assert u["err_hostile"] > 2.0 * t["err_hostile"], \
        "robust fusion shows no advantage over uniform under attack"

    # --- change-point drift: windowed re-fit tracks, infinite memory lags -
    drift = S.FaultPlan(drift=(S.DriftSpec(at=rounds // 2, scale=0.6),))
    plain = _final_err(run("diagonal", faults=drift))
    windowed = _final_err(run("diagonal", faults=drift,
                              window=(rounds - rounds // 2) * rate))
    rec["methods"]["drift"] = {"err_plain": plain, "err_windowed": windowed}
    emit("stream_hostile_drift", 0.0,
         f"plain {plain:.4f} windowed {windowed:.4f}")
    assert windowed < plain, \
        "sliding-window re-fit did not beat infinite memory after drift"

    # --- crash/restart: the survivor fleet keeps converging --------------
    crash = S.FaultPlan(crashes=(
        S.CrashSpec(node=3, at=2, restart_at=rounds - 2),))
    res = run("diagonal", faults=crash)
    rec["methods"]["crash_restart"] = {"err": res.err.tolist()}
    assert np.all(np.isfinite(res.err)) and res.err[-1] < res.err[0], \
        "fleet did not recover from crash/restart"
    emit("stream_hostile_crash", 0.0,
         f"err {res.err[0]:.4f}->{res.err[-1]:.4f}")

    # --- kill + durable restore: bit-level trajectory continuity ---------
    full = run("diagonal", faults=byz, window=4 * rate)
    part_sim = S.StreamSimulator(
        g, pool, scheme="diagonal", theta_star=theta_star,
        arrivals=S.ArrivalSpec(rate=float(rate)),
        network=S.NetworkConfig(drop_prob=0.1, delay=1),
        capacity=128, seed=21, faults=byz, window=4 * rate)
    part_sim.run(rounds // 2)
    with tempfile.TemporaryDirectory() as d:
        CK.save_stream(d, rounds // 2, part_sim)
        fresh = S.StreamSimulator(
            g, pool, scheme="diagonal", theta_star=theta_star,
            arrivals=S.ArrivalSpec(rate=float(rate)),
            network=S.NetworkConfig(drop_prob=0.1, delay=1),
            capacity=128, seed=21, faults=byz, window=4 * rate)
        CK.restore_stream(d, fresh)
    resumed = fresh.run(rounds - rounds // 2)
    restore_maxdiff = float(np.max(np.abs(
        np.asarray(resumed.theta) - np.asarray(full.theta)[rounds // 2:])))
    rec["methods"]["kill_restore"] = {"restore_maxdiff": restore_maxdiff}
    assert restore_maxdiff <= 1e-10, \
        f"restored stream diverged from uninterrupted run " \
        f"({restore_maxdiff:.2e})"
    emit("stream_hostile_restore", 0.0, f"maxdiff {restore_maxdiff:.1e}")

    # --- telemetry: the same hostile run, fully instrumented -------------
    # The JSONL event log (BENCH_stream_trace.jsonl, uploaded as a CI
    # artifact) must replay to EXACTLY the live network counters, and the
    # recorded any-time timeline must equal the result's own err column.
    import os

    from repro.telemetry import (TelemetrySpec, read_events,
                                 replay_network_counters)
    from .util import REPO_ROOT
    trace_path = os.path.join(REPO_ROOT, "BENCH_stream_trace.jsonl")
    if os.path.exists(trace_path):
        os.remove(trace_path)
    tel_sim = S.StreamSimulator(
        g, pool, scheme="trimmed_mean", theta_star=theta_star,
        arrivals=S.ArrivalSpec(rate=float(rate)),
        network=S.NetworkConfig(drop_prob=0.1, delay=1),
        capacity=128, seed=21, faults=byz,
        telemetry=TelemetrySpec(jsonl=trace_path))
    tel_res = tel_sim.run(rounds)
    t_rounds, t_err = tel_res.timeline("err")
    assert np.array_equal(t_rounds, tel_res.rounds) \
        and np.array_equal(t_err, tel_res.err), \
        "telemetry err timeline diverged from the recorded trajectory"
    live = tel_sim.net.counters_dict()
    replayed = replay_network_counters(read_events(trace_path))
    for key, val in live.items():
        assert replayed[key] == val, \
            (f"JSONL replay reconstructed {key}={replayed[key]}, live "
             f"counter says {val}")
    snap = tel_res.telemetry
    rec["telemetry"] = {
        "events": len(snap.events),
        "fault_injections": int(snap.counters.get("fault.injections", 0)),
        "robust_rejections": int(
            snap.counters.get("combine.robust_rejections", 0)),
        "scalars_sent_replayed": int(replayed["scalars_sent"]),
        "trace_file": os.path.basename(trace_path),
    }
    emit("stream_hostile_telemetry", 0.0,
         f"events {len(snap.events)} replay-exact "
         f"scalars {replayed['scalars_sent']}")
    return rec


def main() -> None:
    rounds = scale(10, 30)
    rate = scale(60, 300)
    payload = {"config": {"rounds": rounds, "rate": rate}, "graphs": {}}
    for seed, (name, g) in enumerate(_graphs()):
        payload["graphs"][name] = _run_graph(name, g, rounds, rate,
                                             seed=10 * seed)
    payload["hostile"] = _run_hostile(rounds, rate)
    emit_json("BENCH_stream.json", payload)


if __name__ == "__main__":
    main()
