"""Any-time streaming benchmark: error trajectories over a live network.

Runs the streaming engine on three topologies (star, grid, scale-free) with
three one-step combiner schemes plus streaming ADMM, against the oracle
centralized joint MPLE that sees all arrived data at once — tracing
error-vs-samples-seen and error-vs-scalars-communicated, the measurable form
of the paper's any-time + low-communication claims. Also asserts the
chunked-streaming == one-shot-batch invariant on each graph.

Writes ``BENCH_stream.json`` at the repo root.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.api as A
import repro.core as C
import repro.stream as S
from .util import emit, emit_json, scale

SCHEMES = ("uniform", "diagonal", "max")


def _graphs():
    return [
        ("star10", C.star_graph(10)),
        ("grid", C.grid_graph(*scale((3, 3), (4, 4)))),
        ("scalefree", C.scale_free_graph(scale(15, 40), m=1, seed=0)),
    ]


def _sample_pool(model, n, key):
    if model.graph.p <= 16:
        return np.asarray(C.exact_sample(model, n, key))
    return np.asarray(C.gibbs_sample(model, n, key, burnin=200, thin=2))


def _run_graph(name, g, rounds, rate, seed):
    m = C.random_model(g, 0.5, 0.5, jax.random.PRNGKey(seed))
    theta_star = np.asarray(m.theta)
    pool = _sample_pool(m, rounds * rate + rate, jax.random.PRNGKey(seed + 1))
    rec = {"p": g.p, "m": g.m, "rounds": rounds, "rate": rate,
           "methods": {}}

    # one declarative plan per scheme; the simulator is configured from it
    for scheme in SCHEMES:
        plan = A.Plan(graph=g, combiners=(scheme,), capacity=128)
        sim = S.StreamSimulator.from_plan(
            plan, pool, theta_star=theta_star,
            arrivals=S.ArrivalSpec(rate=float(rate)), seed=seed)
        res = sim.run(rounds)
        rec["methods"][f"one_step_{scheme}"] = {
            "samples_seen": res.samples_seen.tolist(),
            "scalars_sent": res.scalars_sent.tolist(),
            "err": res.err.tolist(),
        }

    admm_plan = A.Plan(graph=g, capacity=128, admm_newton_iters=12)
    sim = S.StreamSimulator.from_plan(
        admm_plan, pool, estimator="admm", theta_star=theta_star,
        arrivals=S.ArrivalSpec(rate=float(rate)), seed=seed)
    res = sim.run(rounds)
    rec["methods"]["admm_stream"] = {
        "samples_seen": res.samples_seen.tolist(),
        "scalars_sent": res.scalars_sent.tolist(),
        "err": res.err.tolist(),
    }

    # oracle: centralized joint MPLE on everything that has arrived, at a
    # few checkpoints (its comm cost is the raw-data count, see comm_costs)
    checkpoints = sorted({rate, (rounds // 2) * rate, rounds * rate})
    orc_err, orc_seen, orc_scalars = [], [], []
    for n in checkpoints:
        th = C.fit_mple(g, jnp.asarray(pool[:n]))
        orc_err.append(C.mse(th, theta_star))
        orc_seen.append(float(n))
        orc_scalars.append(S.comm_costs(g, n, 0)["centralized"])
    rec["methods"]["oracle_mple"] = {
        "samples_seen": orc_seen, "scalars_sent": orc_scalars,
        "err": orc_err,
    }

    # invariant: chunked streaming == one-shot batch when nothing is
    # dropped — both verbs of ONE compiled session
    sess = A.Plan(graph=g, capacity=128).session()
    est = sess.stream()
    for chunk in np.array_split(pool[: rounds * rate], 4):
        est.ingest(chunk)
        est.refit()
    oneshot = sess.fit(pool[: rounds * rate]).fits
    chunk_diff = max(float(np.max(np.abs(a.theta - b.theta)))
                     for a, b in zip(est.fits, oneshot))
    rec["chunked_vs_batch_maxdiff"] = chunk_diff
    assert chunk_diff <= 1e-5, \
        f"{name}: chunked streaming diverged from batch ({chunk_diff:.2e})"

    for meth, tr in rec["methods"].items():
        err = tr["err"]
        assert np.all(np.isfinite(err)), f"{name}/{meth}: non-finite error"
        assert err[-1] < err[0], \
            f"{name}/{meth}: error did not decrease ({err[0]} -> {err[-1]})"
        emit(f"stream_{name}_{meth}", 0.0,
             f"err {err[0]:.4f}->{err[-1]:.4f} "
             f"n={tr['samples_seen'][-1]:.0f} "
             f"scalars={tr['scalars_sent'][-1]}")
    return rec


def main() -> None:
    rounds = scale(10, 30)
    rate = scale(60, 300)
    payload = {"config": {"rounds": rounds, "rate": rate}, "graphs": {}}
    for seed, (name, g) in enumerate(_graphs()):
        payload["graphs"][name] = _run_graph(name, g, rounds, rate,
                                             seed=10 * seed)
    emit_json("BENCH_stream.json", payload)


if __name__ == "__main__":
    main()
