"""Fig. 4 reproduction: 100-node scale-free + Euclidean graphs.

Estimates BOTH singleton and pairwise parameters, data via chromatic Gibbs
sampling (both graphs color sparsely), local fits via the degree-bucketed
batched Newton-IRLS engine. Quick mode shrinks graphs/replicates;
REPRO_BENCH_FULL=1 restores 100 nodes.
"""
from __future__ import annotations

import jax
import numpy as np

import repro.core as C
from .util import emit, scale, timed

SCHEMES = ("uniform", "diagonal", "optimal", "max")


def run_graph(name: str, g: C.Graph, ns, n_models: int, n_sets: int,
              include_joint: bool) -> None:
    hold = {}
    rows = []
    with timed(hold):
        for n in ns:
            acc = {s: [] for s in SCHEMES + (("joint",) if include_joint else ())}
            for mm in range(n_models):
                m = C.random_model(g, 0.5, 0.5, jax.random.PRNGKey(37 + mm))
                for r in range(n_sets):
                    X = C.gibbs_sample(m, n, jax.random.PRNGKey(1000 + mm * 97 + r),
                                       burnin=150, thin=2, method="auto")
                    fits = C.fit_all_local(g, X, method="batched")
                    for sch in SCHEMES:
                        th = C.combine(g, fits, sch)
                        acc[sch].append(C.mse(th, np.asarray(m.theta)))
                    if include_joint:
                        th = C.fit_mple(g, X, n_iter=25)
                        acc["joint"].append(C.mse(th, np.asarray(m.theta)))
            rows.append(f"n={n} " + " ".join(
                f"{s}={np.mean(acc[s]):.3f}" for s in acc))
            print(f"# {name} {rows[-1]}")
    emit(name, hold["t"] / len(rows), " | ".join(rows))


def main() -> None:
    p = scale(40, 100)
    ns = scale((500, 2000), (250, 1000, 4000))
    n_models = scale(2, 5)
    n_sets = scale(2, 10)
    include_joint = True
    g_sf = C.scale_free_graph(p, m=1, seed=0)
    run_graph("fig4a_scalefree_mse", g_sf, ns, n_models, n_sets, include_joint)
    g_eu = C.euclidean_graph(p, radius=scale(0.25, 0.15), seed=0)
    run_graph("fig4b_euclidean_mse", g_eu, ns, n_models, n_sets, include_joint)


if __name__ == "__main__":
    main()
