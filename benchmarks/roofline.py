"""Roofline analysis (deliverable g): derive compute/memory/collective terms
from the dry-run records for every (arch x shape) on the single-pod mesh.

  compute term    = FLOPs / (chips * peak)    [loop-corrected dot FLOPs]
  memory term     = bytes / (chips * HBM bw)  [loop-corrected HBM traffic]
  collective term = coll bytes / link bw      [per-device, post-SPMD]

Dry-run FLOPs/bytes are PER-DEVICE (post-SPMD partitioned module), so the
per-chip division is already applied. Hardware: TPU v5e — 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.

MODEL_FLOPS (per device): 6*N*D/chips for training (N = non-embedding
params; N_active for MoE), 2*N*B/chips per decoded token. The ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os

import jax
import numpy as np

import repro.configs as CFG
from repro.models import transformer as T
from .util import emit

PEAK = 197e12          # bf16 FLOP/s per chip
HBM = 819e9            # B/s per chip
LINK = 50e9            # B/s per chip ICI
CHIPS = 256

_SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def param_counts(cfg):
    """(total, active, embedding) parameter counts from the abstract tree."""
    tree = T.abstract_params(cfg)
    flat = jax.tree_util.tree_flatten(
        tree, is_leaf=lambda x: hasattr(x, "axes"))[0]
    total = sum(int(np.prod(ps.shape)) for ps in flat)
    embed = cfg.padded_vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    active = total
    if cfg.n_experts:
        de = cfg.d_expert or cfg.d_ff
        per_expert = 3 * cfg.d_model * de
        moe_layers = sum(1 for k in cfg.pattern if k == "attn_moe") \
            * cfg.n_units + sum(
                1 for r in range(cfg.n_rem_layers)
                if cfg.pattern[r % len(cfg.pattern)] == "attn_moe")
        inactive = per_expert * (cfg.n_experts - cfg.experts_per_tok) \
            * moe_layers
        active = total - inactive
    return total, active, embed


def model_flops_per_device(cfg, shape_name):
    s, b, kind = _SHAPES[shape_name]
    total, active, embed = param_counts(cfg)
    n = active - embed
    if kind == "train":
        return 6.0 * n * (s * b) / CHIPS
    if kind == "prefill":
        return 2.0 * n * (s * b) / CHIPS
    return 2.0 * n * b / CHIPS          # decode: one token per sequence


def load_records(out_dir="experiments/dryrun", mesh="16x16"):
    recs = {}
    for path in glob.glob(os.path.join(out_dir, "*_pod.json")):
        r = json.load(open(path))
        if r.get("mesh") != mesh or not r.get("ok"):
            continue
        recs[(r["arch"], r["shape"])] = r
    return recs


def roofline_row(cfg, rec):
    shape = rec["shape"]
    t_comp = rec.get("dot_flops", 0.0) / PEAK
    t_mem = rec.get("hbm_bytes", 0.0) / HBM
    t_coll = rec.get("collective_bytes_total", 0.0) / LINK
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops_per_device(cfg, shape)
    ratio = mf / rec["dot_flops"] if rec.get("dot_flops") else float("nan")
    return {
        "arch": rec["arch"], "shape": shape,
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dom,
        "model_flops_dev": mf,
        "useful_ratio": ratio,
        "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
        "args_gib": rec["memory"]["argument_bytes"] / 2**30,
    }


def full_table(out_dir="experiments/dryrun"):
    recs = load_records(out_dir)
    rows = []
    for (arch, shape), rec in sorted(recs.items()):
        try:
            cfg = CFG.get(arch)
        except Exception:
            continue
        rows.append(roofline_row(cfg, rec))
    return rows


def main() -> None:
    rows = full_table()
    for r in rows:
        emit(f"roofline_{r['arch']}_{r['shape']}", 0.0,
             f"compute={r['compute_s']:.2e}s memory={r['memory_s']:.2e}s "
             f"collective={r['collective_s']:.2e}s dom={r['dominant']} "
             f"useful={r['useful_ratio']:.2f} temp={r['temp_gib']:.1f}GiB")
    if not rows:
        emit("roofline", 0.0, "no dry-run records found — run "
             "`python -m repro.launch.dryrun --all` first")


if __name__ == "__main__":
    main()
