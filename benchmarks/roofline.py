"""Migration shim — the transformer-era roofline table is gone.

This module used to derive a compute/memory/collective roofline for the
dormant transformer model zoo (``repro.models.transformer`` shapes on a
16x16 TPU mesh). The estimation repro's roofline evidence now lives in
``BENCH_kernels.json``: every compiled fused-CL row carries dot FLOPs,
HBM bytes, and FLOP/byte from the loop-aware HLO walker
(:mod:`repro.launch.hloparse`), and ``tools/gen_tables.py`` renders them
as the kernel-comparison + roofline tables.

Importing this module raises so stale call sites fail loudly with a
pointer instead of silently rendering a table about models this repo no
longer benchmarks.
"""
raise ModuleNotFoundError(
    "benchmarks.roofline has been removed: the transformer roofline table "
    "it rendered is superseded by the per-kernel HLO roofline columns in "
    "BENCH_kernels.json (regenerate with 'PYTHONPATH=src python -m "
    "benchmarks.kernels_bench', render with 'python tools/gen_tables.py'). "
    "For HLO cost analysis use repro.launch.hloparse.analyze directly.",
    name="benchmarks.roofline")
