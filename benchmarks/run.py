"""Benchmark orchestrator: one module per paper table/figure + framework
benches. Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run            (quick sizes)
    REPRO_BENCH_FULL=1 ... python -m benchmarks.run    (paper-fidelity sizes)
"""
from __future__ import annotations

import sys
import traceback

MODULES = (
    "fig1_toy",      # Fig 1: toy phase diagram + Claim 4.10 boundary
    "fig2_star",     # Fig 2: star graphs (a-d)
    "fig3_grid",     # Fig 3: grid efficiency, MSE vs n, ADMM convergence
    "fig4_large",    # Fig 4: 100-node scale-free + Euclidean
    "comm_cost",     # Sec. 1/3 communication-cost table
    "anytime_stream",  # streaming any-time engine over a lossy network
    "kernels_bench",  # kernel-path comparison rows + HLO rooflines
    "arch_steps",    # assigned-architecture step smoke timings
)


def main() -> None:
    failures = []
    for name in MODULES:
        print(f"# === benchmarks.{name} ===", flush=True)
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"# FAILED modules: {failures}")
        sys.exit(1)
    print("# all benchmark modules completed")


if __name__ == "__main__":
    main()
