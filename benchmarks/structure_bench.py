"""Structure-learning benchmark: planted-graph edge recovery + the path
compile invariant.

A 30-node 5x6 grid with random-sign couplings is planted for BOTH the
Ising (+-0.5, Gibbs-sampled) and Gaussian (+-0.3, exact Cholesky-sampled)
families; ``session.select`` must recover the true edge set from n=2000
rows over the FULL candidate policy (all 435 candidate edges, no hints).
Also traces F1 vs sample size and F1 vs communication budget (knn
screening sweeps the candidate count, which is what the vote bill scales
with).

Invariants this benchmark *asserts* (it is CI for the structure tier's
headline claims, not just a number printer):

* edge-recovery F1 >= 0.95 for both planted families at n=2000, cold AND
  warm;
* the warm-started lambda path compiles exactly one proximal program per
  degree bucket of the candidate graph on the cold run — NOT one per
  lambda — and zero on the warm rerun (fresh data, same shapes).

Writes ``BENCH_structure.json`` (schema v2 + provenance). Quick mode runs
the acceptance pair plus short sweeps; ``REPRO_BENCH_FULL=1`` widens the
n- and knn-sweeps.
"""
from __future__ import annotations

import numpy as np

from repro.api import Plan, StructureSpec
from repro.core import get_family, grid_graph
from repro.core.batched import clear_bucket_solver_caches, degree_buckets
from repro.core.graphs import complete_graph
from .util import emit, emit_json, scale

F1_FLOOR = 0.95
PLANTED = {"ising": 0.5, "gaussian": 0.3}   # edge |coupling| per family


def _planted(famname: str, n: int, key_seed: int = 3):
    """The pinned generator: grid_graph(5, 6), RandomState(7) coupling
    signs, family-appropriate exact/Gibbs sampling."""
    g = grid_graph(5, 6)
    fam = get_family(famname)
    theta = np.zeros(fam.n_params(g))
    signs = np.where(np.random.RandomState(7).rand(g.m) < 0.5, 1.0, -1.0)
    theta[g.p:] = PLANTED[famname] * signs
    import jax
    key = jax.random.PRNGKey(key_seed)
    if famname == "gaussian":
        X = np.asarray(fam.exact_sample(g, theta, n, key))
    else:
        X = np.asarray(fam.sample(g, theta, n, key))
    return g, X


def _row(res, g):
    m = res.edge_metrics(g.edges)
    return {"f1": m["f1"], "precision": m["precision"],
            "recall": m["recall"], "support_size": len(res.support),
            "candidates": len(res.candidate_edges),
            "comm_scalars": res.comm_scalars,
            "lambda_selected": res.lambda_selected,
            "path_compiles": res.path_compiles,
            "new_compiles": res.new_compiles,
            "wall_s": res.wall_s, "compile_s": res.compile_s}


def _acceptance(famname: str, n: int) -> dict:
    """Cold + warm select at the acceptance scale, invariants asserted."""
    g, X = _planted(famname, n)
    sess = Plan(graph=g, family=famname,
                structure=StructureSpec(policy="full")).session()
    n_buckets = len(degree_buckets(complete_graph(g.p)))

    clear_bucket_solver_caches()
    cold = sess.select(X)
    f1_cold = cold.edge_metrics(g.edges)["f1"]
    assert f1_cold >= F1_FLOOR, (
        f"{famname}: cold F1 {f1_cold:.3f} < {F1_FLOOR} on the planted "
        f"30-node grid at n={n}")
    assert cold.path_compiles == n_buckets, (
        f"{famname}: lambda path compiled {cold.path_compiles} prox "
        f"programs; warm-started paths must compile exactly one per "
        f"degree bucket ({n_buckets}), never per lambda")

    # warm: a fresh draw of the same shape reuses every compiled program
    _, X2 = _planted(famname, n, key_seed=9)
    warm = sess.select(X2)
    f1_warm = warm.edge_metrics(g.edges)["f1"]
    assert f1_warm >= F1_FLOOR, (
        f"{famname}: warm F1 {f1_warm:.3f} < {F1_FLOOR}")
    assert warm.new_compiles == 0, (
        f"{famname}: warm select compiled {warm.new_compiles} new "
        f"programs; same-shape reruns must compile nothing")

    emit(f"structure_{famname}_cold", cold.wall_s * 1e6,
         f"f1={f1_cold:.3f};path_compiles={cold.path_compiles}")
    emit(f"structure_{famname}_warm", warm.wall_s * 1e6,
         f"f1={f1_warm:.3f};new_compiles={warm.new_compiles}")
    return {"cold": _row(cold, g), "warm": _row(warm, g),
            "n_buckets": n_buckets}


def _f1_vs_n(famname: str, ns, accept_row: dict, n_accept: int) -> list:
    """Recovery vs sample size: prefixes of one pinned draw."""
    g, X = _planted(famname, max(ns))
    rows = []
    for n in ns:
        if n == n_accept:        # already measured by the acceptance run
            rows.append({"n": n, **{k: accept_row[k]
                                    for k in ("f1", "precision", "recall",
                                              "support_size")}})
            continue
        res = Plan(graph=g, family=famname,
                   structure=StructureSpec(policy="full")
                   ).session().select(X[:n])
        r = _row(res, g)
        rows.append({"n": n, **{k: r[k] for k in ("f1", "precision",
                                                  "recall",
                                                  "support_size")}})
        emit(f"structure_{famname}_n{n}", res.wall_s * 1e6,
             f"f1={r['f1']:.3f}")
    return rows


def _f1_vs_comm(famname: str, ks, n: int) -> list:
    """Recovery vs communication budget: knn screening shrinks the
    candidate set, and the vote bill is exactly linear in it."""
    g, X = _planted(famname, n)
    rows = []
    for k in ks:
        spec = (StructureSpec(policy="full") if k is None
                else StructureSpec(policy="knn", knn_k=k))
        res = Plan(graph=g, family=famname,
                   structure=spec).session().select(X)
        r = _row(res, g)
        rows.append({"knn_k": k, **{key: r[key]
                                    for key in ("candidates",
                                                "comm_scalars", "f1",
                                                "precision", "recall")}})
        emit(f"structure_{famname}_comm_k{k or 'full'}", res.wall_s * 1e6,
             f"scalars={r['comm_scalars']};f1={r['f1']:.3f}")
    return rows


def main():
    n_accept = 2000
    ns = scale((500, 1000, 2000), (250, 500, 1000, 2000, 4000))
    ks = scale((4, 8, None), (3, 4, 6, 8, 12, None))

    families = {}
    for famname in ("ising", "gaussian"):
        accept = _acceptance(famname, n_accept)
        families[famname] = {
            "accept": accept,
            "f1_vs_n": _f1_vs_n(famname, ns, accept["cold"], n_accept),
        }
    comm = {"ising": _f1_vs_comm("ising", ks, n_accept)}

    payload = {
        "config": {"graph": "grid_5x6", "p": 30, "m_true": 49,
                   "n_accept": n_accept, "couplings": PLANTED,
                   "ns": list(ns), "knn_ks": [k for k in ks],
                   "f1_floor": F1_FLOOR},
        "families": families,
        "f1_vs_comm": comm,
        "invariants": {
            "f1_floor_met": True,
            "cold_path_compiles_eq_buckets": True,
            "warm_new_compiles_zero": True,
        },
    }
    emit_json("BENCH_structure.json", payload)


if __name__ == "__main__":
    main()
