"""Fig. 3 reproduction: 4x4 grid (quick mode uses 3x3 for exact parts).

(a) exact asymptotic efficiency vs singleton magnitude — joint MPLE best
(b) empirical MSE vs data size, with asymptotic-MSE horizontal reference
(c) ADMM convergence: zero-init vs one-step-consensus inits (Thm 3.1)
"""
from __future__ import annotations

import jax
import numpy as np

import repro.core as C
from .util import emit, scale, timed

SCHEMES = ("uniform", "diagonal", "optimal", "max")


def _grid():
    return C.grid_graph(*scale((3, 3), (4, 4)))


def fig3a() -> None:
    hold = {}
    rows = []
    g = _grid()
    with timed(hold):
        for ss in scale((0.0, 0.5, 1.0), (0.0, 0.25, 0.5, 0.75, 1.0)):
            acc = {s: [] for s in SCHEMES + ("joint",)}
            for rep in range(scale(3, 50)):
                m = C.random_model(g, 0.5, ss, jax.random.PRNGKey(rep))
                locs = C.exact_locals(m, include_singleton=False)
                tr_mle, _ = C.exact_mle_variance(m, include_singleton=False)
                for sch in SCHEMES:
                    tr, _ = C.exact_consensus_variance(
                        m, locs, sch, include_singleton=False)
                    acc[sch].append(tr / tr_mle)
                tr_j, _ = C.exact_joint_mple_variance(
                    m, include_singleton=False)
                acc["joint"].append(tr_j / tr_mle)
            rows.append(f"sigma_s={ss} " + " ".join(
                f"{s}={np.mean(acc[s]):.2f}" for s in SCHEMES + ("joint",)))
            print(f"# fig3a {rows[-1]}")
    emit("fig3a_grid_efficiency", hold["t"] / len(rows), " | ".join(rows))


def fig3b() -> None:
    hold = {}
    rows = []
    g = _grid()
    m = C.random_model(g, 0.5, 0.5, jax.random.PRNGKey(11))
    tf = np.asarray(m.theta).copy()
    free = C.free_indices(g, include_singleton=False)
    # asymptotic reference lines
    locs = C.exact_locals(m, include_singleton=False)
    with timed(hold):
        refs = {}
        for sch in SCHEMES:
            tr, _ = C.exact_consensus_variance(m, locs, sch,
                                               include_singleton=False)
            refs[sch] = tr
        for n in scale((500, 2000), (300, 1000, 3000, 10000)):
            acc = {s: [] for s in SCHEMES + ("joint",)}
            for r in range(scale(4, 50)):
                X = C.exact_sample(m, n, jax.random.PRNGKey(900 + r))
                fits = C.fit_all_local(g, X, include_singleton=False,
                                       theta_fixed=jax.numpy.asarray(tf))
                for sch in SCHEMES:
                    th = C.combine(g, fits, sch, include_singleton=False,
                                   theta_fixed=tf)
                    acc[sch].append(C.mse(th, tf, free))
                th = C.fit_mple(g, X, free_idx=free,
                                theta_fixed=jax.numpy.asarray(tf))
                acc["joint"].append(C.mse(th, tf, free))
            rows.append(
                f"n={n} " + " ".join(
                    f"{s}={np.mean(acc[s]):.4f}(asym={refs[s]/n:.4f})"
                    if s in refs else f"{s}={np.mean(acc[s]):.4f}"
                    for s in SCHEMES + ("joint",)))
            print(f"# fig3b {rows[-1]}")
    emit("fig3b_grid_mse_vs_n", hold["t"] / len(rows), " | ".join(rows))


def fig3c() -> None:
    hold = {}
    g = _grid()
    m = C.random_model(g, 0.5, 0.5, jax.random.PRNGKey(13))
    X = C.exact_sample(m, scale(1500, 5000), jax.random.PRNGKey(14))
    with timed(hold):
        th_mple = C.fit_mple(g, X)
        fits = C.fit_all_local(g, X)
        iters = scale(10, 25)
        curves = {}
        for init in ("zero", "uniform", "diagonal"):
            res = C.admm_mple(g, X, n_iters=iters, init=init,
                              fits=None if init == "zero" else fits)
            curves[init] = [float(np.linalg.norm(t - th_mple))
                            for t in res.trajectory]
    payload = " | ".join(
        f"{k}: " + ">".join(f"{e:.3f}" for e in v[:: max(1, len(v)//6)])
        for k, v in curves.items())
    emit("fig3c_admm_convergence", hold["t"] / 3, payload)
    assert curves["diagonal"][-1] < curves["zero"][-1], "consensus init must win"


def main() -> None:
    fig3a()
    fig3b()
    fig3c()


if __name__ == "__main__":
    main()
