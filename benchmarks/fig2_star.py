"""Fig. 2 reproduction: star graphs.

(a) hub-vs-leaf local-estimator variance as degree grows
(b) exact + empirical asymptotic efficiency vs star size
(c) efficiency vs singleton-potential magnitude
(d) empirical MSE vs sample size
Pairwise parameters estimated, singletons known (paper Sec. 5.1).
"""
from __future__ import annotations

import jax
import numpy as np

import repro.core as C
from .util import emit, scale, timed

SCHEMES = ("uniform", "diagonal", "optimal", "max")


def _exact_effs(m):
    locs = C.exact_locals(m, include_singleton=False)
    tr_mle, _ = C.exact_mle_variance(m, include_singleton=False)
    out = {}
    for sch in SCHEMES:
        tr, _ = C.exact_consensus_variance(m, locs, sch,
                                           include_singleton=False)
        out[sch] = tr / tr_mle
    tr_j, _ = C.exact_joint_mple_variance(m, include_singleton=False)
    out["joint"] = tr_j / tr_mle
    return out, locs, tr_mle


def fig2a() -> None:
    rng = np.random.RandomState(0)
    hold = {}
    rows = []
    with timed(hold):
        for p in scale((4, 7, 10), (4, 6, 8, 10, 12)):
            hubs, leaves = [], []
            for rep in range(scale(3, 10)):
                g = C.star_graph(p)
                m = C.random_model(g, 0.5, 0.5, jax.random.PRNGKey(rep))
                locs = C.exact_locals(m, include_singleton=False)
                hubs.append(np.mean(np.diag(locs[0].V)))
                leaves.append(np.mean([locs[i].V[0, 0]
                                       for i in range(1, p)]))
            rows.append(f"deg{p-1}:hub={np.mean(hubs):.2f}"
                        f"/leaf={np.mean(leaves):.2f}")
    emit("fig2a_star_hub_variance", hold["t"] / len(rows), " ".join(rows))


def fig2b() -> None:
    hold = {}
    rows = []
    n, R = scale((1500, 8), (4000, 50))
    with timed(hold):
        for p in scale((4, 7, 10), (4, 6, 8, 10)):
            g = C.star_graph(p)
            exact_acc = {s: [] for s in SCHEMES + ("joint",)}
            emp_acc = {s: [] for s in SCHEMES + ("joint",)}
            for rep in range(scale(3, 50)):
                m = C.random_model(g, 0.5, 0.5, jax.random.PRNGKey(100 + rep))
                effs, _, tr_mle = _exact_effs(m)
                for s, v in effs.items():
                    exact_acc[s].append(v)
                tf = np.asarray(m.theta).copy()
                free = C.free_indices(g, include_singleton=False)
                for r in range(R):
                    X = C.exact_sample(m, n, jax.random.PRNGKey(2000 + rep * R + r))
                    fits = C.fit_all_local(g, X, include_singleton=False,
                                           theta_fixed=jax.numpy.asarray(tf))
                    for sch in SCHEMES:
                        th = C.combine(g, fits, sch, include_singleton=False,
                                       theta_fixed=tf)
                        emp_acc[sch].append(
                            n * C.mse(th, tf, free) / tr_mle)
                    th = C.fit_mple(g, X, free_idx=free,
                                    theta_fixed=jax.numpy.asarray(tf))
                    emp_acc["joint"].append(n * C.mse(th, tf, free) / tr_mle)
            row = f"p={p} " + " ".join(
                f"{s}:exact={np.mean(exact_acc[s]):.2f}"
                f"/emp={np.mean(emp_acc[s]):.2f}"
                for s in SCHEMES + ("joint",))
            rows.append(row)
            print(f"# fig2b {row}")
    emit("fig2b_star_efficiency", hold["t"] / len(rows), " | ".join(rows))


def fig2c() -> None:
    hold = {}
    rows = []
    p = 10
    with timed(hold):
        for ss in scale((0.5, 1.0, 2.0), (0.5, 1.0, 1.5, 2.0)):
            g = C.star_graph(p)
            acc = {s: [] for s in SCHEMES + ("joint",)}
            for rep in range(scale(3, 50)):
                m = C.random_model(g, 0.5, ss, jax.random.PRNGKey(300 + rep))
                effs, _, _ = _exact_effs(m)
                for s, v in effs.items():
                    acc[s].append(v)
            rows.append(f"sigma_s={ss} " + " ".join(
                f"{s}={np.mean(acc[s]):.2f}" for s in SCHEMES + ("joint",)))
    emit("fig2c_star_vs_singleton", hold["t"] / len(rows), " | ".join(rows))


def fig2d() -> None:
    hold = {}
    rows = []
    g = C.star_graph(10)
    m = C.random_model(g, 0.5, 0.5, jax.random.PRNGKey(7))
    tf = np.asarray(m.theta).copy()
    free = C.free_indices(g, include_singleton=False)
    with timed(hold):
        for n in scale((300, 1000, 3000), (100, 300, 1000, 3000, 10000)):
            acc = {s: [] for s in SCHEMES}
            for r in range(scale(5, 50)):
                X = C.exact_sample(m, n, jax.random.PRNGKey(5000 + r))
                fits = C.fit_all_local(g, X, include_singleton=False,
                                       theta_fixed=jax.numpy.asarray(tf))
                for sch in SCHEMES:
                    th = C.combine(g, fits, sch, include_singleton=False,
                                   theta_fixed=tf)
                    acc[sch].append(C.mse(th, tf, free))
            rows.append(f"n={n} " + " ".join(
                f"{s}={np.mean(acc[s]):.4f}" for s in SCHEMES))
    emit("fig2d_star_mse_vs_n", hold["t"] / len(rows), " | ".join(rows))


def main() -> None:
    fig2a()
    fig2b()
    fig2c()
    fig2d()


if __name__ == "__main__":
    main()
