"""Benchmark harness utilities: timing + CSV/JSON emission.

Every benchmark prints ``name,us_per_call,derived`` rows so ``benchmarks.run``
output is machine-parsable. ``derived`` is the figure's scientific payload
(efficiency, MSE, ...) as a compact string. Benchmarks that track a perf
trajectory additionally write a ``BENCH_*.json`` file at the repo root via
:func:`emit_json`.
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess
import time
from contextlib import contextmanager

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: current BENCH_*.json schema. v1 = the pre-provenance payloads (no
#: version stamp at all); v2 adds the top-level ``schema_version`` +
#: ``provenance`` block. tools.gen_tables refuses versions it does not
#: know, so a reader never silently misrenders a newer layout.
BENCH_SCHEMA_VERSION = 2


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except Exception:
        return "unknown"


def provenance() -> dict:
    """Where/when/how this benchmark ran: git SHA (``"unknown"`` outside a
    work tree), UTC timestamp, jax backend, and the default kernel path
    the dispatch layer picks on this backend (the
    :data:`repro.kernels.cl.ops.KERNEL_PATHS` taxonomy — Mosaic Pallas on
    TPU/GPU, the XLA tiled twin elsewhere)."""
    import jax
    from repro.kernels.cl.ops import default_kernel_path
    backend = jax.default_backend()
    return {
        "git_sha": _git_sha(),
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(),
        "backend": backend,
        "kernel_path": default_kernel_path(backend),
    }


def emit_json(filename: str, payload: dict) -> str:
    """Write a machine-readable benchmark record to the repo root.

    Every record is stamped with ``schema_version`` and a ``provenance``
    block (git SHA, UTC timestamp, backend/kernel mode) before writing —
    a BENCH file is meaningless as evidence without knowing what produced
    it. Writers may pre-set either key to override."""
    payload.setdefault("schema_version", BENCH_SCHEMA_VERSION)
    payload.setdefault("provenance", provenance())
    path = os.path.join(REPO_ROOT, filename)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}")
    return path


@contextmanager
def timed(holder: dict, key: str = "t"):
    t0 = time.perf_counter()
    yield
    holder[key] = (time.perf_counter() - t0) * 1e6  # microseconds


def scale(quick_val, full_val):
    """Pick a problem size depending on REPRO_BENCH_FULL."""
    return full_val if FULL else quick_val
