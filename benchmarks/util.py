"""Benchmark harness utilities: timing + CSV/JSON emission.

Every benchmark prints ``name,us_per_call,derived`` rows so ``benchmarks.run``
output is machine-parsable. ``derived`` is the figure's scientific payload
(efficiency, MSE, ...) as a compact string. Benchmarks that track a perf
trajectory additionally write a ``BENCH_*.json`` file at the repo root via
:func:`emit_json`.
"""
from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def emit_json(filename: str, payload: dict) -> str:
    """Write a machine-readable benchmark record to the repo root."""
    path = os.path.join(REPO_ROOT, filename)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}")
    return path


@contextmanager
def timed(holder: dict, key: str = "t"):
    t0 = time.perf_counter()
    yield
    holder[key] = (time.perf_counter() - t0) * 1e6  # microseconds


def scale(quick_val, full_val):
    """Pick a problem size depending on REPRO_BENCH_FULL."""
    return full_val if FULL else quick_val
