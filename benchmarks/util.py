"""Benchmark harness utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows so ``benchmarks.run``
output is machine-parsable. ``derived`` is the figure's scientific payload
(efficiency, MSE, ...) as a compact string.
"""
from __future__ import annotations

import os
import time
from contextlib import contextmanager

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


@contextmanager
def timed(holder: dict, key: str = "t"):
    t0 = time.perf_counter()
    yield
    holder[key] = (time.perf_counter() - t0) * 1e6  # microseconds


def scale(quick_val, full_val):
    """Pick a problem size depending on REPRO_BENCH_FULL."""
    return full_val if FULL else quick_val
