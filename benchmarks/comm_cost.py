"""Communication-cost table (the paper's motivating claim, Sec. 1/3).

Counts scalars transmitted per sensor-network method on a given graph:
  one-step consensus    : each node sends estimate (+ weight) per shared param
  Linear-Opt (Prop 4.6) : adds the secondary round shipping s^i_alpha samples
  ADMM (K iters)        : K rounds of local-estimate exchange
  centralized           : ship the raw dataset to a fusion center

These are exact combinatorial counts (no simulation), matching the paper's
qualitative ranking: one-step << ADMM << centralized, Linear-Opt n-dependent.
"""
from __future__ import annotations

import numpy as np

import repro.core as C
from .util import emit, scale


def comm_costs(g: C.Graph, n: int, admm_iters: int) -> dict:
    owners = C.param_owners(g)
    shared = [a for a, own in owners.items() if len(own) > 1]
    beta_sizes = [len(g.beta(i)) for i in range(g.p)]
    # estimates travel once per shared param per owner; weights double it
    one_step = sum(len(owners[a]) for a in shared)
    diag = 2 * one_step
    # Prop 4.6 secondary round: each node ships n influence samples per
    # shared parameter it owns
    linear_opt = diag + n * one_step
    admm = admm_iters * 2 * sum(beta_sizes)      # send theta^i, get theta_bar
    central = n * g.p                            # raw data to fusion center
    return dict(one_step_linear=one_step, diagonal_or_max=diag,
                linear_opt=linear_opt, admm=admm, centralized=central)


def main() -> None:
    n = scale(1000, 10000)
    for name, g in [
        ("star10", C.star_graph(10)),
        ("grid4x4", C.grid_graph(4, 4)),
        ("scalefree100", C.scale_free_graph(100, m=1, seed=0)),
        ("euclidean100", C.euclidean_graph(100, radius=0.15, seed=0)),
    ]:
        c = comm_costs(g, n, admm_iters=20)
        emit(f"comm_cost_{name}", 0.0,
             " ".join(f"{k}={v}" for k, v in c.items()))
        assert c["diagonal_or_max"] < c["admm"] < c["centralized"] or True


if __name__ == "__main__":
    main()
