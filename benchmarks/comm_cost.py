"""Communication-cost table (the paper's motivating claim, Sec. 1/3).

The exact combinatorial accounting lives in :mod:`repro.stream.costs` and is
shared with the streaming simulator's measured scalar counters — one full
broadcast round of the streaming engine transmits exactly the one-step row
of this table (asserted in ``tests/stream``). This module evaluates the
table on reference graphs, prints CSV rows, and writes ``BENCH_comm.json``.
"""
from __future__ import annotations

import repro.core as C
from repro.stream.costs import comm_costs
from .util import emit, emit_json, scale


def main() -> None:
    n = scale(1000, 10000)
    admm_iters = 20
    payload = {"config": {"n": n, "admm_iters": admm_iters}, "graphs": {}}
    for name, g in [
        ("star10", C.star_graph(10)),
        ("grid4x4", C.grid_graph(4, 4)),
        ("scalefree100", C.scale_free_graph(100, m=1, seed=0)),
        ("euclidean100", C.euclidean_graph(100, radius=0.15, seed=0)),
    ]:
        c = comm_costs(g, n, admm_iters=admm_iters)
        payload["graphs"][name] = dict(c, p=g.p, m=g.m)
        emit(f"comm_cost_{name}", 0.0,
             " ".join(f"{k}={v}" for k, v in c.items()))
        assert c["diagonal_or_max"] < c["admm"] < c["centralized"], \
            f"{name}: paper's qualitative cost ranking violated"
    emit_json("BENCH_comm.json", payload)


if __name__ == "__main__":
    main()
