"""Serving benchmark: multi-tenant latency/throughput with and without
cross-tenant coalescing.

A deterministic synthetic workload (many tenants of a few equal plans,
pre-drawn sample matrices) is replayed against two fresh servers — one
coalescing same-shape requests into union dispatches, one serving every
request through its own session serially. Reported per mode: p50/p99
latency, served-request throughput, mean coalesce group size, and the
warm-path compile count.

Invariants this benchmark *asserts* (it is CI for the serving tier's two
headline claims, not just a number printer):

* coalesced throughput strictly exceeds serial throughput on the measured
  (warm) phase;
* the measured phase triggers zero new bucket-solver compilations in
  either mode.

Writes ``BENCH_serve.json`` (schema v2 + provenance). Quick mode runs a
CI-sized load; ``REPRO_BENCH_FULL=1`` scales tenants and rounds up.
"""
from __future__ import annotations

import numpy as np

import repro.core as C
from repro.api.plan import Plan
from repro.serve import SessionServer, VirtualClock, run_load, \
    synthetic_workload
from .util import emit, emit_json, scale


def _tenant_plans(n_tenants: int):
    """n_tenants spread over two distinct plans (so coalescing has both
    same-plan groups to merge and plan boundaries to respect)."""
    base = Plan(graph=C.scale_free_graph(24, seed=0), family="ising",
                combiners=("diagonal",), n_iter=8)
    alt = base.replace(combiners=("uniform",))
    return {f"t{j:02d}": (base if j % 4 else alt)
            for j in range(n_tenants)}


def _serve(plans, schedule):
    def run(coalesce):
        srv = SessionServer(coalesce=coalesce,
                            max_coalesce=scale(4, 8),
                            clock=VirtualClock())
        for tid, plan in plans.items():
            srv.register(tid, plan)
        warm = run_load(srv, schedule[:1])      # compile pass
        measured = run_load(srv, schedule[1:])  # steady state
        return warm, measured
    return run(True), run(False)


def main():
    n_tenants = scale(8, 32)
    rounds = scale(4, 12)   # round 0 is the warmup/compile pass
    n_rows = scale(64, 256)
    plans = _tenant_plans(n_tenants)
    schedule = synthetic_workload(plans, rounds=rounds, n_rows=n_rows,
                                  seed=0)
    (warm_c, meas_c), (warm_s, meas_s) = _serve(plans, schedule)

    for rep, mode in ((meas_c, "coalesced"), (meas_s, "serial")):
        assert rep.n_rejected == 0, (mode, rep.rejected_by_reason)
        assert rep.new_compiles == 0, (
            f"{mode} measured phase compiled {rep.new_compiles} new bucket "
            f"programs; the warm path must compile nothing")
    assert meas_c.throughput_rps > meas_s.throughput_rps, (
        f"coalescing must strictly beat serial serving: "
        f"{meas_c.throughput_rps:.1f} <= {meas_s.throughput_rps:.1f} rps")

    speedup = meas_c.throughput_rps / meas_s.throughput_rps
    for rep, mode in ((meas_c, "coalesced"), (meas_s, "serial")):
        emit(f"serve_{mode}_p50", rep.latency_ms(50) * 1e3,
             f"p99_ms={rep.latency_ms(99):.2f}")
        emit(f"serve_{mode}_throughput", 1e6 / rep.throughput_rps,
             f"rps={rep.throughput_rps:.1f}")
    emit("serve_coalesce_speedup", 0.0, f"x{speedup:.2f}")

    payload = {
        "config": {
            "n_tenants": n_tenants, "rounds": rounds, "n_rows": n_rows,
            "graph_p": 24, "max_coalesce": scale(4, 8),
        },
        "coalesced": {"warmup": warm_c.summary(),
                      "measured": meas_c.summary()},
        "serial": {"warmup": warm_s.summary(),
                   "measured": meas_s.summary()},
        "speedup_throughput": speedup,
        "invariants": {
            "warm_new_compiles_coalesced": meas_c.new_compiles,
            "warm_new_compiles_serial": meas_s.new_compiles,
            "coalesced_strictly_faster": True,
        },
    }
    emit_json("BENCH_serve.json", payload)


if __name__ == "__main__":
    main()
