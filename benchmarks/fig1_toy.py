"""Fig. 1 reproduction: toy two-node model phase diagram.

(a) Region classification in (gamma, rho) space via the Claim 4.10 boundaries.
(b) Varying local potentials (s1, s2) in a binary two-node model and checking
    which estimator achieves the lowest exact asymptotic MSE.
"""
from __future__ import annotations

import jax
import numpy as np

import repro.core as C
from .util import emit, scale, timed


def classify(v_joint, v_unif, v_max):
    if v_joint <= v_unif <= v_max:
        return "I"
    if v_joint <= v_max <= v_unif:
        return "II"
    if v_max <= v_joint:
        return "III"
    return "?"


def main() -> None:
    g = C.Graph(2, ((0, 1),))
    grid = scale(7, 15)
    pots = np.linspace(-2.0, 2.0, grid)
    theta_e = 1.0  # true theta* = 1 as in Fig 1(b)
    counts = {"I": 0, "II": 0, "III": 0, "?": 0}
    best_at_origin = None
    boundary_ok = 0
    total = 0
    hold = {}
    with timed(hold):
        for s1 in pots:
            for s2 in pots:
                th = jax.numpy.asarray(
                    np.array([s1, s2, theta_e], dtype=np.float32))
                m = C.IsingModel(g, th)
                locs = C.exact_locals(m, include_singleton=False)
                v_unif, _ = C.exact_consensus_variance(
                    m, locs, "uniform", include_singleton=False)
                v_max, _ = C.exact_consensus_variance(
                    m, locs, "max", include_singleton=False)
                v_joint, _ = C.exact_joint_mple_variance(
                    m, include_singleton=False)
                counts[classify(v_joint, v_unif, v_max)] += 1
                # Claim 4.10 boundary check
                v1, v2 = locs[0].V[0, 0], locs[1].V[0, 0]
                pr = locs[0].probs
                v12 = float((locs[0].S[:, 0] * pr) @ locs[1].S[:, 0])
                rho = v12 / np.sqrt(v1 * v2)
                gam = min(v1 / v2, v2 / v1)
                pred_joint_wins = rho <= 0.5 * np.sqrt(gam) * (gam + 1)
                if pred_joint_wins == (v_joint <= v_max * (1 + 1e-6)):
                    boundary_ok += 1
                total += 1
                if abs(s1) < 1e-9 and abs(s2) < 1e-9:
                    best_at_origin = classify(v_joint, v_unif, v_max)
    emit("fig1_toy_phase", hold["t"] / total,
         f"regions I:{counts['I']} II:{counts['II']} III:{counts['III']} "
         f"claim4.10_agree={boundary_ok}/{total}")
    # Paper: max wins when potentials differ greatly (heteroskedastic corners)
    emit("fig1_toy_origin", 0.0, f"origin_class={best_at_origin}")


if __name__ == "__main__":
    main()
