"""Local-estimator engine benchmark: seed per-node loop vs the
degree-bucketed batched Newton-IRLS engine, plus sequential vs chromatic
Gibbs, on the fig4 scale-free configuration (p=100, n=1000 by default).

Emits CSV rows for the harness and writes ``BENCH_estimators.json`` so the
perf trajectory is machine-readable across PRs. Cold timings include XLA
compilation (what a fresh fig4 replicate pays); warm timings are steady
state.
"""
from __future__ import annotations

import time

import jax
import numpy as np

import repro.core as C
from repro.core.batched import _solve_bucket
from .util import emit, emit_json, scale


def _wall(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(jax.tree_util.tree_leaves(
        [f.theta if isinstance(f, C.LocalFit) else f for f in out])
        if isinstance(out, list) else out)
    return time.perf_counter() - t0, out


def bench_fit_all_local(g, X):
    # fresh caches so "cold" includes compilation for both paths
    from repro.core import estimators as E
    E._solve_cl.clear_cache()
    _solve_bucket.clear_cache()

    cold_loop, fits_loop = _wall(lambda: C.fit_all_local(g, X, method="loop"))
    warm_loop, _ = _wall(lambda: C.fit_all_local(g, X, method="loop"))
    cold_bat, fits_bat = _wall(lambda: C.fit_all_local(g, X))
    warm_bat, _ = _wall(lambda: C.fit_all_local(g, X))

    max_diff = max(float(np.max(np.abs(a.theta - b.theta)))
                   for a, b in zip(fits_loop, fits_bat))
    n_buckets = len(C.degree_buckets(g))
    compiles = _solve_bucket._cache_size()
    # the fig4 full config fits each graph 150 times (5 models x 10 sets x
    # 3 sample sizes): the wall-clock that matters is one compile plus 149
    # steady-state fits, which is what this workload metric measures.
    reps = 150
    wl_loop = cold_loop + (reps - 1) * warm_loop
    wl_bat = cold_bat + (reps - 1) * warm_bat
    return {
        "fit_loop_cold_s": cold_loop, "fit_loop_warm_s": warm_loop,
        "fit_batched_cold_s": cold_bat, "fit_batched_warm_s": warm_bat,
        "fit_speedup_cold": cold_loop / cold_bat,
        "fit_speedup_warm": warm_loop / warm_bat,
        "fit_fig4_workload_loop_s": wl_loop,
        "fit_fig4_workload_batched_s": wl_bat,
        "fit_speedup_fig4_workload": wl_loop / wl_bat,
        "fit_max_abs_diff_theta": max_diff,
        "n_degree_buckets": n_buckets,
        "bucket_compile_count": compiles,
    }, fits_bat


def bench_gibbs(m, n):
    key = jax.random.PRNGKey(7)
    # warm both compile caches, then time steady-state sampling
    C.gibbs_sample(m, 64, key, burnin=10, thin=1, method="sequential")
    C.gibbs_sample(m, 64, key, burnin=10, thin=1, method="chromatic")
    t_seq, _ = _wall(lambda: C.gibbs_sample(m, n, key, burnin=150, thin=2,
                                            method="sequential"))
    t_chr, _ = _wall(lambda: C.gibbs_sample(m, n, key, burnin=150, thin=2,
                                            method="chromatic"))
    n_colors = int(m.graph.greedy_coloring().max()) + 1
    return {
        "gibbs_sequential_s": t_seq,
        "gibbs_chromatic_s": t_chr,
        "gibbs_speedup": t_seq / t_chr,
        "n_colors": n_colors,
    }


def bench_sharded(g, X, fits_plain):
    """shard_map-over-mesh engine path on the host mesh: the scale-out
    wiring must cost ~nothing on one device and stay numerically identical
    to the plain path (the multi-device win needs real devices; this row
    pins the single-device contract)."""
    from repro.core.batched import fit_all_local_batched
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    cold, fits = _wall(lambda: fit_all_local_batched(g, X, mesh=mesh))
    warm, _ = _wall(lambda: fit_all_local_batched(g, X, mesh=mesh))
    max_diff = max(float(np.max(np.abs(a.theta - b.theta)))
                   for a, b in zip(fits_plain, fits))
    return {
        "fit_sharded_cold_s": cold,
        "fit_sharded_warm_s": warm,
        "fit_sharded_max_abs_diff_theta": max_diff,
        "fit_sharded_mesh": "host(1x1)",
    }


def bench_session_reuse(g, X):
    """The estimation-plan API's compile-reuse contract as a bench row:
    one cold ``EstimationSession.fit`` (pays one compile per degree
    bucket) vs a warm fit on FRESH same-shape data (pays zero). The
    compile counter is asserted, not just reported — a regression that
    breaks solver reuse fails the bench."""
    import repro.api as A
    from repro.core.batched import clear_bucket_solver_caches

    clear_bucket_solver_caches()
    plan = A.Plan(graph=g, combiners=("diagonal", "max"))
    sess = plan.session()
    cold, res_cold = _wall(lambda: sess.fit(X))
    fresh = np.ascontiguousarray(np.asarray(X)[::-1])
    warm, res_warm = _wall(lambda: sess.fit(fresh))
    n_buckets = sess.n_buckets
    assert res_cold.new_compiles == n_buckets, \
        (f"cold session fit compiled {res_cold.new_compiles} bucket "
         f"solvers, expected one per degree bucket ({n_buckets})")
    assert res_warm.new_compiles == 0, \
        (f"warm session fit on fresh same-shape data recompiled "
         f"{res_warm.new_compiles} bucket solvers; session reuse broken")
    # the wall/compile split must be coherent: a cold fit spends most of
    # its wall on compiling dispatches, a warm fit compiles nothing
    assert 0.0 < res_cold.compile_s <= res_cold.wall_s, \
        (f"cold fit compile_s {res_cold.compile_s!r} not within its wall "
         f"{res_cold.wall_s!r}")
    assert res_warm.compile_s == 0.0, \
        (f"warm fit reported compile_s {res_warm.compile_s!r}; the "
         f"compile/execute wall split is broken")
    return {
        "session_fit_cold_s": cold,
        "session_fit_warm_s": warm,
        "session_fit_cold_compile_s": res_cold.compile_s,
        "session_fit_cold_execute_s": cold - res_cold.compile_s,
        "session_reuse_speedup": cold / warm,
        "session_cold_compiles": res_cold.new_compiles,
        "session_warm_compiles": res_warm.new_compiles,
        "session_n_buckets": n_buckets,
    }


def bench_combine(g, fits):
    for sch in ("uniform", "diagonal", "optimal", "max"):
        C.combine(g, fits, sch)                      # warm any lazy setup
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        for sch in ("uniform", "diagonal", "optimal", "max"):
            C.combine(g, fits, sch)
    return {"combine_all_schemes_s": (time.perf_counter() - t0) / reps}


def bench_families(p_small, n):
    """Per-family engine rows: cold/warm batched fit + combine + sampler
    throughput on one shared grid topology, so the perf trajectory tracks
    every registered family, not just Ising."""
    from repro.core.batched import fit_all_local_batched
    import jax.numpy as jnp
    import math

    side = max(int(math.isqrt(p_small)), 2)
    g = C.grid_graph(side, side)
    rows = {}
    for fam in C.registered_families():
        key = jax.random.PRNGKey(17)
        theta = fam.random_params(g, key)
        t_s, X = _wall(lambda: C.gibbs_sample_family(
            fam, g, theta, n, jax.random.PRNGKey(18), burnin=100, thin=2))
        _solve_bucket.clear_cache()
        Xj = jnp.asarray(X)
        cold, fits = _wall(lambda: fit_all_local_batched(g, Xj, family=fam))
        warm, _ = _wall(lambda: fit_all_local_batched(g, Xj, family=fam))
        t0 = time.perf_counter()
        C.combine(g, fits, "diagonal", family=fam)
        t_comb = time.perf_counter() - t0
        rows[fam.name] = {
            "block_dim": fam.block_dim,
            "n_params": fam.n_params(g),
            "sample_s": t_s,
            "fit_batched_cold_s": cold,
            "fit_batched_warm_s": warm,
            "combine_diagonal_s": t_comb,
        }
    return rows


def main() -> None:
    p = scale(100, 100)
    n = scale(1000, 1000)
    g = C.scale_free_graph(p, m=1, seed=0)
    m = C.random_model(g, 0.5, 0.5, jax.random.PRNGKey(37))
    X = C.gibbs_sample(m, n, jax.random.PRNGKey(1000), burnin=150, thin=2)

    metrics, fits = bench_fit_all_local(g, X)
    metrics.update(bench_sharded(g, X, fits))
    session_reuse = bench_session_reuse(g, X)
    metrics.update(bench_gibbs(m, n))
    metrics.update(bench_combine(g, fits))
    fam_rows = bench_families(scale(36, 36), scale(600, 600))

    emit("estimator_fit_loop", metrics["fit_loop_cold_s"] * 1e6,
         f"p={p} n={n} cold_s={metrics['fit_loop_cold_s']:.2f} "
         f"warm_s={metrics['fit_loop_warm_s']:.2f}")
    emit("estimator_fit_batched", metrics["fit_batched_cold_s"] * 1e6,
         f"p={p} n={n} cold_s={metrics['fit_batched_cold_s']:.2f} "
         f"warm_s={metrics['fit_batched_warm_s']:.2f} "
         f"speedup_cold={metrics['fit_speedup_cold']:.1f}x "
         f"speedup_warm={metrics['fit_speedup_warm']:.1f}x "
         f"speedup_fig4={metrics['fit_speedup_fig4_workload']:.1f}x "
         f"maxdiff={metrics['fit_max_abs_diff_theta']:.1e} "
         f"buckets={metrics['n_degree_buckets']} "
         f"compiles={metrics['bucket_compile_count']}")
    emit("estimator_fit_sharded", metrics["fit_sharded_cold_s"] * 1e6,
         f"mesh={metrics['fit_sharded_mesh']} "
         f"cold_s={metrics['fit_sharded_cold_s']:.2f} "
         f"warm_s={metrics['fit_sharded_warm_s']:.2f} "
         f"maxdiff_vs_plain={metrics['fit_sharded_max_abs_diff_theta']:.1e}")
    emit("estimator_gibbs_chromatic", metrics["gibbs_chromatic_s"] * 1e6,
         f"seq_s={metrics['gibbs_sequential_s']:.2f} "
         f"chrom_s={metrics['gibbs_chromatic_s']:.2f} "
         f"speedup={metrics['gibbs_speedup']:.1f}x "
         f"colors={metrics['n_colors']}")
    emit("estimator_session_reuse", session_reuse["session_fit_warm_s"] * 1e6,
         f"cold_s={session_reuse['session_fit_cold_s']:.2f} "
         f"warm_s={session_reuse['session_fit_warm_s']:.3f} "
         f"reuse_speedup={session_reuse['session_reuse_speedup']:.1f}x "
         f"cold_compiles={session_reuse['session_cold_compiles']}"
         f"==buckets={session_reuse['session_n_buckets']} "
         f"warm_compiles={session_reuse['session_warm_compiles']}")
    emit("estimator_combine", metrics["combine_all_schemes_s"] * 1e6,
         "vectorized combine, 4 schemes")
    for name, row in fam_rows.items():
        emit(f"estimator_family_{name}", row["fit_batched_cold_s"] * 1e6,
             f"C={row['block_dim']} cold_s={row['fit_batched_cold_s']:.2f} "
             f"warm_s={row['fit_batched_warm_s']:.3f} "
             f"sample_s={row['sample_s']:.2f}")

    emit_json("BENCH_estimators.json", {
        "config": {"p": p, "n": n, "graph": "scale_free(m=1, seed=0)",
                   "families_config": {"graph": "grid", "p": scale(36, 36),
                                       "n": scale(600, 600)}},
        "metrics": metrics,
        "session_reuse": session_reuse,
        "families": fam_rows,
    })


if __name__ == "__main__":
    main()
