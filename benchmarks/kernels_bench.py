"""Kernel micro-benchmarks: interpret-mode correctness timing plus the
pure-jnp reference path timing at paper-relevant sizes. (Wall-clock MFU is
not measurable on CPU; these benches verify the kernels run and give the
oracle a throughput baseline. On TPU the same harness times the Pallas
path via use_pallas=True.)"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ising_cl.kernel import ising_cl_logits
from repro.kernels.ising_cl.ref import ising_cl_logits_ref
from repro.kernels.gram.kernel import gram
from repro.kernels.gram.ref import gram_ref
from repro.kernels.swa.kernel import swa_attention
from repro.kernels.swa.ref import swa_attention_ref
from .util import emit, scale


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def bench_ising_cl():
    n, p = scale((512, 100), (4096, 256))
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jnp.sign(jax.random.normal(ks[0], (n, p)))
    theta = 0.3 * jax.random.normal(ks[1], (p, p))
    mask = (jax.random.uniform(ks[2], (p, p)) < 0.1).astype(jnp.float32)
    bias = jnp.zeros(p)
    us_ref, ref = _time(jax.jit(ising_cl_logits_ref), x, theta, mask, bias)
    us_k, out = _time(lambda *a: ising_cl_logits(*a, interpret=True),
                      x, theta, mask, bias, reps=1)
    err = float(jnp.max(jnp.abs(out - ref)))
    emit("kernel_ising_cl", us_ref,
         f"n={n} p={p} ref_us={us_ref:.0f} interp_us={us_k:.0f} "
         f"maxerr={err:.2e}")


def bench_gram():
    n, d = scale((2048, 128), (16384, 512))
    s = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    us_ref, ref = _time(jax.jit(gram_ref), s)
    us_k, out = _time(lambda a: gram(a, interpret=True), s, reps=1)
    err = float(jnp.max(jnp.abs(out - ref)))
    emit("kernel_gram", us_ref,
         f"n={n} d={d} ref_us={us_ref:.0f} interp_us={us_k:.0f} "
         f"maxerr={err:.2e}")


def bench_swa():
    b, s, h, d, w = 1, scale(256, 1024), 4, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    us_ref, ref = _time(jax.jit(
        lambda q, k, v: swa_attention_ref(q, k, v, window=w)), q, k, v)
    us_k, out = _time(lambda q, k, v: swa_attention(q, k, v, window=w,
                                                    interpret=True),
                      q, k, v, reps=1)
    err = float(jnp.max(jnp.abs(out - ref)))
    emit("kernel_swa", us_ref,
         f"s={s} window={w} ref_us={us_ref:.0f} interp_us={us_k:.0f} "
         f"maxerr={err:.2e}")


def main() -> None:
    bench_ising_cl()
    bench_gram()
    bench_swa()


if __name__ == "__main__":
    main()
