"""Kernel micro-benchmarks across the kernel-path taxonomy.

Every fused-CL row is timed on up to three paths and emitted as its own
metric so the perf trajectory of each tier is machine-readable
(``BENCH_kernels.json``):

* ``ref`` — the jnp reference contraction exactly as the dispatch layer's
  ``ref`` path runs it (eager, the golden oracle);
* ``compiled`` — the tier the dispatch layer picks by default: the Mosaic
  Pallas kernel on TPU/GPU, the XLA-jitted tiled twin elsewhere, with
  tiles chosen by a **measured** :func:`~repro.kernels.cl.autotune.search_tiles`
  run (the timings land in the JSON next to the winner);
* ``interpret`` — the Python-speed Pallas interpreter (validation only, so
  it is timed with one rep and skipped at the large Newton shapes).

Each compiled row also carries a FLOP/byte roofline estimate from the
loop-aware HLO walker (:mod:`repro.launch.hloparse`) over the lowered XLA
program of the tiled twin — the analyzable dot-level program on every
backend.

Two regression gates run inside the bench, not outside it: the compiled
bucket-Newton rows must beat the jnp reference on the compiled-CPU backend
(the measured ~1.4x chunked-accumulation win), and no compiled score row
may regress past ``REGRESSION_SLACK`` of its reference row."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as C
from repro.kernels.cl.autotune import search_tiles
from repro.kernels.cl.family import family_kernel_inputs
from repro.kernels.cl.kernel import cl_score_channels
from repro.kernels.cl.newton import (bucket_newton_stats,
                                     bucket_newton_stats_ref)
from repro.kernels.cl.ops import default_kernel_path
from repro.kernels.cl.ref import cl_score_channels_ref
from repro.kernels.cl.tiled import (bucket_newton_stats_tiled,
                                    cl_score_channels_tiled)
from repro.kernels.ising_cl.kernel import ising_cl_logits
from repro.kernels.ising_cl.ref import ising_cl_logits_ref, ising_cl_score_ref
from repro.kernels.ising_cl.score import ising_cl_score
from repro.kernels.gram.kernel import gram
from repro.kernels.gram.ref import gram_ref
from repro.kernels.swa.kernel import swa_attention
from repro.kernels.swa.ref import swa_attention_ref
from repro.launch.hloparse import analyze
from .util import emit, emit_json, scale

RESULTS = {}
FAMILY_RESULTS = {}
NEWTON_RESULTS = {}

#: a compiled score row slower than REGRESSION_SLACK x its reference row
#: fails the bench (the compiled tier must never cost more than timing
#: jitter over the reference it replaces at whole-axis tiles).
REGRESSION_SLACK = 1.5

#: bucket-Newton shapes where chunked accumulation is measured to win:
#: large sample axes (>= CHUNK_MIN_N), paper-scale bucket counts.
NEWTON_SHAPES = (
    ("ising", 48, 1, 5, 32768),   # kind, k, C, d, n
    ("potts", 8, 2, 9, 16384),
)


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def _hlo_roofline(fn, *args, **kwargs):
    """dot FLOPs / HBM-byte estimate of the lowered XLA program via the
    loop-aware HLO walker. Best-effort: a lowering failure is recorded,
    never raised (the roofline is evidence, not a gate)."""
    try:
        txt = fn.lower(*args, **kwargs).compile().as_text()
    except Exception as e:  # pragma: no cover - backend-dependent
        return {"error": str(e)[:120]}
    h = analyze(txt)
    fpb = h["dot_flops"] / h["hbm_bytes"] if h["hbm_bytes"] else None
    return {"dot_flops": h["dot_flops"], "hbm_bytes": h["hbm_bytes"],
            "flop_per_byte": fpb}


def _record(name: str, shape_desc: str, us_ref: float, us_kernel: float,
            err: float) -> None:
    """Emit ref and interpret-path rows separately; stash for the JSON."""
    emit(f"{name}_ref", us_ref, f"{shape_desc} maxerr={err:.2e}")
    emit(f"{name}_interpret", us_kernel, f"{shape_desc} maxerr={err:.2e}")
    RESULTS[name] = {"ref_us": us_ref, "kernel_us": us_kernel,
                     "kernel_path": "interpret",
                     "shape": shape_desc, "max_err": err}


def bench_ising_cl():
    n, p = scale((512, 100), (4096, 256))
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jnp.sign(jax.random.normal(ks[0], (n, p)))
    theta = 0.3 * jax.random.normal(ks[1], (p, p))
    mask = (jax.random.uniform(ks[2], (p, p)) < 0.1).astype(jnp.float32)
    bias = jnp.zeros(p)
    us_ref, ref = _time(jax.jit(ising_cl_logits_ref), x, theta, mask, bias)
    us_k, out = _time(lambda *a: ising_cl_logits(*a, interpret=True),
                      x, theta, mask, bias, reps=1)
    err = float(jnp.max(jnp.abs(out - ref)))
    _record("kernel_ising_cl", f"n={n} p={p}", us_ref, us_k, err)


def bench_ising_cl_score():
    n, p = scale((512, 100), (4096, 256))
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jnp.sign(jax.random.normal(ks[0], (n, p)))
    theta = 0.3 * jax.random.normal(ks[1], (p, p))
    mask = (jax.random.uniform(ks[2], (p, p)) < 0.1).astype(jnp.float32)
    bias = 0.1 * jax.random.normal(ks[0], (p,))
    us_ref, ref = _time(jax.jit(ising_cl_score_ref), x, theta, mask, bias)
    us_k, out = _time(lambda *a: ising_cl_score(*a, interpret=True),
                      x, theta, mask, bias, reps=1)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(out, ref))
    _record("kernel_ising_cl_score", f"n={n} p={p}", us_ref, us_k, err)


def _maxerr(out, ref):
    return max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))
               for a, b in zip(out, ref))


def bench_family_scores():
    """Per-family fused score rows: jnp reference vs the compiled tier vs
    the interpret-mode Pallas kernel, for every registered family. The
    compiled tier's tiles come from a measured ``search_tiles`` run whose
    timings are recorded next to the winner."""
    path = default_kernel_path()
    n, p = scale((256, 64), (2048, 256))
    side = max(int(np.sqrt(p)), 2)
    g = C.grid_graph(side, side)
    for fam in C.registered_families():
        kind = fam.kernel_kind
        theta = jnp.asarray(fam.random_params(g, jax.random.PRNGKey(23)),
                            jnp.float32)
        X = jnp.asarray(C.random_rows(fam, jax.random.PRNGKey(11), n, g.p),
                        jnp.float32)
        inputs = family_kernel_inputs(fam, g, theta, X)
        us_ref, ref = _time(
            lambda *a, _k=kind: cl_score_channels_ref(*a, kind=_k),
            *inputs, reps=5)

        def measure(cfg, _k=kind, _inputs=inputs):
            if path == "mosaic":
                fn = lambda *a: cl_score_channels(  # noqa: E731
                    *a, kind=_k, interpret=False, tiles=cfg)
            else:
                fn = lambda *a: cl_score_channels_tiled(  # noqa: E731
                    *a, kind=_k, chunk=cfg.bm)
            return _time(fn, *_inputs, reps=2)[0]

        tiles, timings = search_tiles("score", n=n, p=g.p, C=fam.block_dim,
                                      measure=measure)
        if path == "mosaic":
            comp = lambda *a, _k=kind: cl_score_channels(  # noqa: E731
                *a, kind=_k, interpret=False, tiles=tiles)
        else:
            comp = lambda *a, _k=kind: cl_score_channels_tiled(  # noqa: E731
                *a, kind=_k, chunk=tiles.bm)
        us_comp, out_c = _time(comp, *inputs, reps=5)
        us_int, out_i = _time(
            lambda *a, _k=kind: cl_score_channels(*a, kind=_k,
                                                  interpret=True),
            *inputs, reps=1)
        err_c, err_i = _maxerr(out_c, ref), _maxerr(out_i, ref)
        hlo = _hlo_roofline(cl_score_channels_tiled, *inputs, kind=kind,
                            chunk=tiles.bm)
        shape = f"C={fam.block_dim} n={n} p={g.p}"
        emit(f"kernel_cl_score_{fam.name}_ref", us_ref, shape)
        emit(f"kernel_cl_score_{fam.name}_compiled", us_comp,
             f"{shape} path={path} speedup={us_ref / us_comp:.2f}x "
             f"maxerr={err_c:.2e}")
        emit(f"kernel_cl_score_{fam.name}_interpret", us_int,
             f"{shape} maxerr={err_i:.2e}")
        FAMILY_RESULTS[fam.name] = {
            "shape": shape, "block_dim": fam.block_dim,
            "kernel_kind": kind,
            "rows": {
                "ref": {"us": us_ref, "kernel_path": "ref"},
                "compiled": {"us": us_comp, "kernel_path": path,
                             "max_err": err_c,
                             "speedup_vs_ref": us_ref / us_comp,
                             "tiles": tiles.to_dict(),
                             "search_timings_us": timings, "hlo": hlo},
                "interpret": {"us": us_int, "kernel_path": "interpret",
                              "max_err": err_i},
            },
        }


def bench_bucket_newton():
    """Compiled bucket-Newton vs the jitted jnp reference at the shapes
    where chunked Gram accumulation is measured to win (large sample axes).
    Tiles come from a measured search; on the compiled-CPU backend the
    compiled row MUST beat the reference — asserted, not just reported."""
    path = default_kernel_path()
    for kind, k, Cc, d, n in NEWTON_SHAPES:
        ks = jax.random.split(jax.random.PRNGKey(hash(kind) % 2 ** 31), 5)
        Zb = jax.random.normal(ks[0], (k, Cc, d, n))
        base = 0.1 * jax.random.normal(ks[1], (k, Cc, n))
        if kind == "potts":
            xi = jax.random.randint(ks[2], (k, n), 0, Cc + 1) \
                .astype(jnp.float32)
        else:
            xi = jnp.sign(jax.random.normal(ks[2], (k, n)))
        W = 0.2 * jax.random.normal(ks[3], (k, d * Cc))

        us_ref, ref = _time(
            lambda *a, _k=kind: bucket_newton_stats_ref(_k, *a),
            Zb, base, xi, W, reps=5)

        def measure(cfg, _k=kind, _a=(Zb, base, xi, W)):
            if path == "mosaic":
                fn = lambda *a: bucket_newton_stats(  # noqa: E731
                    _k, *a, interpret=False, tiles=cfg)
            else:
                fn = lambda *a: bucket_newton_stats_tiled(  # noqa: E731
                    _k, *a, chunk=cfg.bm)
            return _time(fn, *_a, reps=2)[0]

        tiles, timings = search_tiles("newton", n=n, p=d, C=Cc,
                                      measure=measure)
        if path == "mosaic":
            comp = lambda *a, _k=kind: bucket_newton_stats(  # noqa: E731
                _k, *a, interpret=False, tiles=tiles)
        else:
            comp = lambda *a, _k=kind: bucket_newton_stats_tiled(  # noqa: E731
                _k, *a, chunk=tiles.bm)
        us_comp, out = _time(comp, Zb, base, xi, W, reps=5)
        err = _maxerr(out, ref)
        speedup = us_ref / us_comp
        hlo = _hlo_roofline(bucket_newton_stats_tiled, kind, Zb, base, xi,
                            W, chunk=tiles.bm)
        shape = f"k={k} C={Cc} d={d} n={n}"
        emit(f"kernel_newton_{kind}_ref", us_ref, shape)
        emit(f"kernel_newton_{kind}_compiled", us_comp,
             f"{shape} path={path} speedup={speedup:.2f}x "
             f"maxerr={err:.2e}")
        NEWTON_RESULTS[kind] = {
            "shape": shape, "ref_us": us_ref, "compiled_us": us_comp,
            "speedup_vs_ref": speedup, "kernel_path": path,
            "max_err": err, "tiles": tiles.to_dict(),
            "search_timings_us": timings, "hlo": hlo,
        }

    if path == "tiled":
        best = max(r["speedup_vs_ref"] for r in NEWTON_RESULTS.values())
        assert best > 1.0, (
            f"compiled bucket-Newton must beat the jnp reference on the "
            f"compiled-CPU backend; best speedup was {best:.2f}x "
            f"({ {k: round(r['speedup_vs_ref'], 2) for k, r in NEWTON_RESULTS.items()} })")


def bench_gram():
    n, d = scale((2048, 128), (16384, 512))
    s = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    us_ref, ref = _time(jax.jit(gram_ref), s)
    us_k, out = _time(lambda a: gram(a, interpret=True), s, reps=1)
    err = float(jnp.max(jnp.abs(out - ref)))
    _record("kernel_gram", f"n={n} d={d}", us_ref, us_k, err)


def bench_swa():
    b, s, h, d, w = 1, scale(256, 1024), 4, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    us_ref, ref = _time(jax.jit(
        lambda q, k, v: swa_attention_ref(q, k, v, window=w)), q, k, v)
    us_k, out = _time(lambda q, k, v: swa_attention(q, k, v, window=w,
                                                    interpret=True),
                      q, k, v, reps=1)
    err = float(jnp.max(jnp.abs(out - ref)))
    _record("kernel_swa", f"s={s} window={w}", us_ref, us_k, err)


def main() -> None:
    bench_ising_cl()
    bench_ising_cl_score()
    bench_family_scores()
    bench_bucket_newton()
    bench_gram()
    bench_swa()
    for fam, rec in FAMILY_RESULTS.items():
        rows = rec["rows"]
        assert rows["compiled"]["us"] <= REGRESSION_SLACK * rows["ref"]["us"], (
            f"compiled score row for {fam} regressed past "
            f"{REGRESSION_SLACK}x the reference: "
            f"{rows['compiled']['us']:.0f}us vs {rows['ref']['us']:.0f}us")
    emit_json("BENCH_kernels.json", {
        "backend": jax.default_backend(),
        "kernel_path": default_kernel_path(),
        "kernels": RESULTS,
        "families": FAMILY_RESULTS,
        "newton": NEWTON_RESULTS,
    })


if __name__ == "__main__":
    main()
