"""Kernel micro-benchmarks: the pure-jnp reference path AND the Pallas
kernel path (interpret mode on CPU) at paper-relevant sizes, each emitted as
its own metric so the perf trajectory of both paths is machine-readable
(``BENCH_kernels.json``). Per-family rows run the channelized fused score
pipeline for EVERY registered model family (multi-channel Potts included),
with the interpret-mode flag recorded per row. Wall-clock MFU is not
measurable on CPU; on TPU the same harness times the compiled Pallas path
via use_pallas=True / interpret=False."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as C
from repro.kernels.cl.family import family_kernel_inputs
from repro.kernels.cl.kernel import cl_score_channels
from repro.kernels.cl.ref import cl_score_channels_ref
from repro.kernels.ising_cl.kernel import ising_cl_logits
from repro.kernels.ising_cl.ref import ising_cl_logits_ref, ising_cl_score_ref
from repro.kernels.ising_cl.score import ising_cl_score
from repro.kernels.gram.kernel import gram
from repro.kernels.gram.ref import gram_ref
from repro.kernels.swa.kernel import swa_attention
from repro.kernels.swa.ref import swa_attention_ref
from .util import emit, emit_json, scale

RESULTS = {}
FAMILY_RESULTS = {}


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def _record(name: str, shape_desc: str, us_ref: float, us_kernel: float,
            err: float) -> None:
    """Emit ref and kernel-path rows separately; stash for the JSON dump."""
    emit(f"{name}_ref", us_ref, f"{shape_desc} maxerr={err:.2e}")
    emit(f"{name}_pallas", us_kernel, f"{shape_desc} maxerr={err:.2e}")
    RESULTS[name] = {"ref_us": us_ref, "kernel_us": us_kernel,
                     "shape": shape_desc, "max_err": err}


def bench_ising_cl():
    n, p = scale((512, 100), (4096, 256))
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jnp.sign(jax.random.normal(ks[0], (n, p)))
    theta = 0.3 * jax.random.normal(ks[1], (p, p))
    mask = (jax.random.uniform(ks[2], (p, p)) < 0.1).astype(jnp.float32)
    bias = jnp.zeros(p)
    us_ref, ref = _time(jax.jit(ising_cl_logits_ref), x, theta, mask, bias)
    us_k, out = _time(lambda *a: ising_cl_logits(*a, interpret=True),
                      x, theta, mask, bias, reps=1)
    err = float(jnp.max(jnp.abs(out - ref)))
    _record("kernel_ising_cl", f"n={n} p={p}", us_ref, us_k, err)


def bench_ising_cl_score():
    n, p = scale((512, 100), (4096, 256))
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jnp.sign(jax.random.normal(ks[0], (n, p)))
    theta = 0.3 * jax.random.normal(ks[1], (p, p))
    mask = (jax.random.uniform(ks[2], (p, p)) < 0.1).astype(jnp.float32)
    bias = 0.1 * jax.random.normal(ks[0], (p,))
    us_ref, ref = _time(jax.jit(ising_cl_score_ref), x, theta, mask, bias)
    us_k, out = _time(lambda *a: ising_cl_score(*a, interpret=True),
                      x, theta, mask, bias, reps=1)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(out, ref))
    _record("kernel_ising_cl_score", f"n={n} p={p}", us_ref, us_k, err)


def bench_family_scores():
    """Per-family fused score rows: jnp reference vs the channelized Pallas
    kernel for every registered family, each row flagged with whether the
    kernel ran in interpret mode (CPU) or compiled (TPU)."""
    interpret = jax.default_backend() != "tpu"
    n, p = scale((256, 64), (2048, 256))
    side = max(int(np.sqrt(p)), 2)
    g = C.grid_graph(side, side)
    for fam in C.registered_families():
        theta = jnp.asarray(fam.random_params(g, jax.random.PRNGKey(23)),
                            jnp.float32)
        X = jnp.asarray(C.random_rows(fam, jax.random.PRNGKey(11), n, g.p),
                        jnp.float32)
        inputs = family_kernel_inputs(fam, g, theta, X)
        us_ref, ref = _time(
            jax.jit(lambda *a: cl_score_channels_ref(
                *a, kind=fam.kernel_kind)), *inputs)
        us_k, out = _time(
            lambda *a: cl_score_channels(*a, kind=fam.kernel_kind,
                                         interpret=interpret),
            *inputs, reps=1)
        err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                        - b.astype(jnp.float32))))
                  for a, b in zip(out, ref))
        shape = f"C={fam.block_dim} n={n} p={g.p}"
        mode = "interpret" if interpret else "pallas"
        emit(f"kernel_cl_score_{fam.name}_ref", us_ref,
             f"{shape} maxerr={err:.2e}")
        emit(f"kernel_cl_score_{fam.name}_{mode}", us_k,
             f"{shape} maxerr={err:.2e}")
        FAMILY_RESULTS[fam.name] = {
            "ref_us": us_ref, "kernel_us": us_k, "shape": shape,
            "max_err": err, "block_dim": fam.block_dim,
            "kernel_kind": fam.kernel_kind, "interpret": interpret,
        }


def bench_gram():
    n, d = scale((2048, 128), (16384, 512))
    s = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    us_ref, ref = _time(jax.jit(gram_ref), s)
    us_k, out = _time(lambda a: gram(a, interpret=True), s, reps=1)
    err = float(jnp.max(jnp.abs(out - ref)))
    _record("kernel_gram", f"n={n} d={d}", us_ref, us_k, err)


def bench_swa():
    b, s, h, d, w = 1, scale(256, 1024), 4, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    us_ref, ref = _time(jax.jit(
        lambda q, k, v: swa_attention_ref(q, k, v, window=w)), q, k, v)
    us_k, out = _time(lambda q, k, v: swa_attention(q, k, v, window=w,
                                                    interpret=True),
                      q, k, v, reps=1)
    err = float(jnp.max(jnp.abs(out - ref)))
    _record("kernel_swa", f"s={s} window={w}", us_ref, us_k, err)


def main() -> None:
    bench_ising_cl()
    bench_ising_cl_score()
    bench_family_scores()
    bench_gram()
    bench_swa()
    emit_json("BENCH_kernels.json", {
        "backend": jax.default_backend(),
        "kernel_path": "interpret" if jax.default_backend() != "tpu"
        else "pallas",
        "kernels": RESULTS,
        "families": FAMILY_RESULTS,
    })


if __name__ == "__main__":
    main()
