"""Production mesh construction (TPU v5e target).

Defined as FUNCTIONS so importing this module never touches jax device
state. The dry-run sets XLA_FLAGS for 512 host devices before any jax
import; tests and benches see the single real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_consensus_mesh(n_pods: int = 2):
    """Mesh for the consensus trainer: explicit pod axis even single-pod
    dry-runs (the pod axis carries the paper's cross-sensor collectives).

    Raises a clear ``ValueError`` when the device count is not divisible by
    ``n_pods`` — the silent floor division it replaced built a mesh over
    fewer devices than exist, which ``jax.make_mesh`` then mis-shapes.
    """
    n_dev = len(jax.devices())
    if n_pods < 1:
        raise ValueError(f"n_pods must be >= 1, got {n_pods}")
    if n_dev % n_pods != 0:
        raise ValueError(
            f"cannot split {n_dev} device(s) into {n_pods} equal pods "
            f"(device count must be divisible by n_pods)")
    per_pod = n_dev // n_pods
    data = 16 if per_pod % 16 == 0 else per_pod
    model = per_pod // data
    return jax.make_mesh((n_pods, data, model), ("pod", "data", "model"))


def make_host_mesh():
    """Degenerate 1x1 mesh on the single real CPU device (tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))
