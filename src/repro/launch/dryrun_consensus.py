import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Paper-technique dry-run: lower one consensus ROUND (H local steps per pod
+ cross-pod combine) vs H fully-synchronous steps on the 2x16x16 multi-pod
mesh, and compare collective traffic. This quantifies the paper's
communication claim at pod scale: one-step consensus replaces H per-step
gradient all-reduces on the pod (DCN) axis with a single weighted parameter
combination per round.

    PYTHONPATH=src python -m repro.launch.dryrun_consensus \
        --arch llama3.2-3b --h-steps 4
"""

import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

import repro.configs as CFG                     # noqa: E402
from repro.distributed import sharding as SH    # noqa: E402
from repro.launch import hloparse               # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import transformer as T       # noqa: E402
from repro.optim import adamw                   # noqa: E402
from repro.train import consensus as CT         # noqa: E402
from repro.train import step as TS              # noqa: E402

SEQ = 4096
LOCAL_B = 32     # per-pod per-local-step batch


def lower_and_analyze(fn, args, in_sh, out_sh, donate=(), mesh=None):
    from repro.distributed.context import use_mesh
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)
    t0 = time.time()
    if mesh is not None:
        with use_mesh(mesh):
            lowered = jitted.lower(*args)
    else:
        lowered = jitted.lower(*args)
    compiled = lowered.compile()
    deep = hloparse.analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    return {
        "compile_s": round(time.time() - t0, 1),
        "collectives": deep["collectives"],
        "collective_bytes_total": deep["collective_bytes_total"],
        "cross_pod_bytes": deep["cross_pod_bytes"],
        "dot_flops": deep["dot_flops"],
        "hbm_bytes": deep["hbm_bytes"],
        "temp_bytes": mem.temp_size_in_bytes,
    }


def sync_spec(cfg, mesh, h):
    """H synchronous steps over the full mesh (pod+data batch sharding)."""
    ocfg = adamw.AdamWConfig()
    tcfg = TS.TrainConfig(mesh=mesh)
    train_step = TS.make_train_step(cfg, ocfg, tcfg)

    def h_steps(state, batches):
        def body(st, b):
            st, metrics = train_step(st, b)
            return st, metrics["nll"]
        state, nlls = jax.lax.scan(body, state, batches)
        return state, nlls.mean()

    gb = LOCAL_B * 2   # same tokens/step as 2 pods of LOCAL_B
    params = T.abstract_params(cfg)
    p_sds = jax.tree_util.tree_map(
        lambda ps: ps.sds(cfg.jdtype), params,
        is_leaf=lambda x: hasattr(x, "axes"))
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    state_sds = TS.TrainState(
        params=p_sds,
        opt=adamw.AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                             m=jax.tree_util.tree_map(f32, p_sds),
                             v=jax.tree_util.tree_map(f32, p_sds)))
    p_sh = SH.param_shardings(params, mesh)
    state_sh = TS.TrainState(
        params=p_sh,
        opt=adamw.AdamWState(step=NamedSharding(mesh, P()),
                             m=jax.tree_util.tree_map(lambda s: s, p_sh),
                             v=jax.tree_util.tree_map(lambda s: s, p_sh)))
    batch_sds = {k: jax.ShapeDtypeStruct((h, gb, SEQ), jnp.int32)
                 for k in ("tokens", "labels")}
    bsh = NamedSharding(mesh, P(None, ("pod", "data"), None))
    batch_sh = {k: bsh for k in batch_sds}
    rep = NamedSharding(mesh, P())
    return ((state_sds, batch_sds), (state_sh, batch_sh),
            (state_sh, rep))


def consensus_spec(cfg, mesh, scheme, h):
    ccfg = CT.ConsensusConfig(n_pods=2, scheme=scheme, h_steps=h)
    ocfg = adamw.AdamWConfig()
    tcfg = TS.TrainConfig()
    round_step = CT.make_round_step(cfg, ocfg, tcfg, ccfg)

    params = T.abstract_params(cfg)
    stack = lambda sds, lead: jax.ShapeDtypeStruct((lead,) + sds.shape,
                                                   sds.dtype)
    p_sds = jax.tree_util.tree_map(
        lambda ps: ps.sds(cfg.jdtype), params,
        is_leaf=lambda x: hasattr(x, "axes"))
    sp_sds = jax.tree_util.tree_map(lambda s: stack(s, 2), p_sds)
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    state_sds = CT.ConsensusState(
        params=sp_sds,
        opt=adamw.AdamWState(step=jax.ShapeDtypeStruct((2,), jnp.int32),
                             m=jax.tree_util.tree_map(f32, sp_sds),
                             v=jax.tree_util.tree_map(f32, sp_sds)),
        lam=jax.tree_util.tree_map(f32, sp_sds),
        theta_bar=p_sds)
    sp_sh = SH.stacked_param_shardings(params, mesh)
    p_sh = SH.param_shardings(params, mesh)
    rep = NamedSharding(mesh, P())
    pod_rep = NamedSharding(mesh, P("pod"))
    state_sh = CT.ConsensusState(
        params=sp_sh,
        opt=adamw.AdamWState(step=pod_rep,
                             m=jax.tree_util.tree_map(lambda s: s, sp_sh),
                             v=jax.tree_util.tree_map(lambda s: s, sp_sh)),
        lam=jax.tree_util.tree_map(lambda s: s, sp_sh),
        theta_bar=p_sh)
    batch_sds = {k: jax.ShapeDtypeStruct((2, h, LOCAL_B, SEQ), jnp.int32)
                 for k in ("tokens", "labels")}
    bsh = NamedSharding(mesh, P("pod", None, "data", None))
    batch_sh = {k: bsh for k in batch_sds}
    metrics_sh = {"nll": rep, "z_loss": rep, "n_tokens": rep, "aux": rep}
    return ((state_sds, batch_sds), (state_sh, batch_sh),
            (state_sh, metrics_sh))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--h-steps", type=int, default=4)
    ap.add_argument("--out", default="experiments/consensus_dryrun.json")
    args = ap.parse_args()

    cfg = CFG.get(args.arch)
    mesh = make_production_mesh(multi_pod=True)
    results = {}

    sds, in_sh, out_sh = sync_spec(cfg, mesh, args.h_steps)
    ocfg = adamw.AdamWConfig()
    tcfg = TS.TrainConfig(mesh=mesh)
    train_step = TS.make_train_step(cfg, ocfg, tcfg)

    def h_sync(state, batches):
        def body(st, b):
            st, m = train_step(st, b)
            return st, m["nll"]
        return jax.lax.scan(body, state, batches)

    print("== sync baseline ==", flush=True)
    results["sync"] = lower_and_analyze(h_sync, sds, in_sh, out_sh, (0,), mesh)
    print(json.dumps(results["sync"]["collectives"], indent=1), flush=True)

    for scheme in ("uniform", "diagonal", "max", "admm"):
        print(f"== consensus {scheme} ==", flush=True)
        ccfg = CT.ConsensusConfig(n_pods=2, scheme=scheme,
                                  h_steps=args.h_steps)
        round_step = CT.make_round_step(cfg, ocfg, TS.TrainConfig(), ccfg)
        sds, in_sh, out_sh = consensus_spec(cfg, mesh, scheme, args.h_steps)
        results[scheme] = lower_and_analyze(round_step, sds, in_sh, out_sh,
                                            (0,), mesh)
        print(json.dumps(results[scheme]["collectives"], indent=1),
              flush=True)

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"arch": args.arch, "h_steps": args.h_steps,
                   "local_batch": LOCAL_B, "seq": SEQ,
                   "results": results}, f, indent=1)
    print("\nper-round collective bytes/device (total | cross-pod/DCN):")
    for k, v in results.items():
        print(f"  {k:9s} {v['collective_bytes_total']:.3e} | "
              f"{v['cross_pod_bytes']:.3e}")


if __name__ == "__main__":
    main()
