import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) on
the production meshes, record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
        --shape train_4k [--multi-pod] [--all] [--out experiments/dryrun]

Must run in its OWN process: the XLA_FLAGS above (512 placeholder host
devices) are locked in at first jax init and would poison tests/benches.
"""

import argparse     # noqa: E402
import json         # noqa: E402
import re           # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import numpy as np  # noqa: E402

import repro.configs as CFG                       # noqa: E402
from repro.launch import specs as SP              # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

COLLECTIVE_RE = re.compile(
    r"=\s+(?:\([^)]*\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
TUPLE_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)")
WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _split_computations(hlo_text: str):
    """HLO text -> {computation_name: [lines]} (+ name of the ENTRY).

    A computation header is a top-level line containing '->' and ending in
    '{'; its name is the leading (optionally ENTRY-prefixed) identifier.
    """
    comps, entry = {}, None
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if cur is None or not line.startswith(" "):
            if stripped.endswith("{") and "->" in stripped:
                m = COMP_NAME_RE.match(stripped)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
                    if stripped.startswith("ENTRY"):
                        entry = cur
                    continue
        if cur is not None:
            if stripped == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def collective_stats(hlo_text: str):
    """Loop-aware collective accounting.

    XLA represents lax.scan as a while op whose body is a separate
    computation; instruction-level sums would count per-layer collectives
    ONCE instead of n_layers times. We therefore walk computations from the
    entry, multiplying by each while loop's trip count (parsed from the
    loop-condition constant). Bytes are the RESULT shape per device (the
    post-SPMD module is per-device) — a topology-independent proxy for link
    traffic. '-done' ops are skipped (their '-start' was counted).
    """
    comps, entry = _split_computations(hlo_text)

    def line_collective(line):
        m = COLLECTIVE_RE.search(line)
        if not m:
            return None
        dtype, dims, kind, suffix = m.group(1), m.group(2), m.group(3), \
            m.group(4)
        if suffix == "-done":
            return None
        if dtype is None:
            tm = TUPLE_SHAPE_RE.search(line)
            if not tm:
                return None
            dtype, dims = tm.group(1), tm.group(2)
        return kind, _shape_bytes(dtype, dims)

    def trip_count(line, cond_name):
        m = TRIP_RE.search(line)          # backend_config, most reliable
        if m:
            return int(m.group(1))
        consts = []
        for ln in comps.get(cond_name, []):
            consts += [int(c) for c in CONST_RE.findall(ln)]
        return max(consts) if consts else 1

    stats = {}

    def walk(name, mult, depth=0):
        if depth > 12 or name not in comps:
            return
        for line in comps[name]:
            lc = line_collective(line)
            if lc:
                kind, b = lc
                c, tot = stats.get(kind, (0, 0))
                stats[kind] = (c + mult, tot + b * mult)
            wm = WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                walk(body, mult * trip_count(line, cond), depth + 1)
            # calls / fusions can hide collectives too
            cm = re.search(r"(?:call|fusion)\(.*?\).*?"
                           r"(?:to_apply|calls)=%?([\w.\-]+)", line)
            if cm:
                walk(cm.group(1), mult, depth + 1)

    if entry:
        walk(entry, 1)
    return {k: {"count": c, "bytes": b} for k, (c, b) in stats.items()}


def run_one(arch: str, shape_name: str, multi_pod: bool,
            microbatch: int = 32, save_hlo_dir=None) -> dict:
    cfg = CFG.get(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = SP.build(cfg, shape_name, mesh, microbatch=microbatch)
    t0 = time.time()
    jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                     out_shardings=spec.out_shardings,
                     donate_argnums=spec.donate)
    from repro.distributed.context import use_mesh
    with use_mesh(mesh):
        lowered = jitted.lower(*spec.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    from repro.launch import hloparse
    cost = hloparse.normalize_cost_analysis(compiled.cost_analysis())
    hlo = compiled.as_text()
    deep = hloparse.analyze(hlo)
    coll = deep["collectives"]
    if save_hlo_dir:
        os.makedirs(save_hlo_dir, exist_ok=True)
        tag = f"{arch.replace('.', '_')}_{shape_name}_" \
              f"{'multipod' if multi_pod else 'pod'}"
        with open(os.path.join(save_hlo_dir, tag + ".hlo"), "w") as f:
            f.write(hlo)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.devices.size,
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
        },
        "flops": cost.get("flops", 0.0),           # body-once caveat
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "dot_flops": deep["dot_flops"],            # loop-corrected, /device
        "hbm_bytes": deep["hbm_bytes"],            # loop-corrected, /device
        "collectives": coll,
        "collective_bytes_total": deep["collective_bytes_total"],
        "window_override": SP.decode_window(cfg, shape_name),
        "microbatch": microbatch if shape_name == "train_4k" else None,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SP.SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--microbatch", type=int, default=32)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list(CFG.ARCH_IDS) if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(SP.SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) \
        else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch.replace('.', '_')}_{shape}_" \
                      f"{'multipod' if mp else 'pod'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    n_skip += 1
                    continue
                print(f"== {tag} ==", flush=True)
                try:
                    rec = run_one(arch, shape, mp,
                                  microbatch=args.microbatch,
                                  save_hlo_dir=(args.out + "/hlo"
                                                if args.save_hlo else None))
                    n_ok += 1
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "ok": False, "error": f"{type(e).__name__}: {e}"}
                    n_fail += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec.get("ok"):
                    gb = rec["memory"]["argument_bytes"] / 2**30
                    print(f"   ok lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"args/dev={gb:.2f}GiB "
                          f"flops={rec['flops']:.3g} "
                          f"coll={rec['collective_bytes_total']:.3g}B",
                          flush=True)
    print(f"done: ok={n_ok} fail={n_fail} skipped={n_skip}")


if __name__ == "__main__":
    main()
