"""Step builders + ShapeDtypeStruct input specs for every
(architecture x input-shape) combination — the shared substrate of the
multi-pod dry-run, the roofline analysis, and the real launchers.

Input shapes (assigned):
  train_4k     seq 4096,   global_batch 256  -> train_step
  prefill_32k  seq 32768,  global_batch 32   -> prefill (forward+cache)
  decode_32k   seq 32768,  global_batch 128  -> serve_step (1 new token)
  long_500k    seq 524288, global_batch 1    -> serve_step; dense archs use
               the sliding-window variant (window 4096), SSM/hybrid native.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as SH
from repro.models import transformer as T
from repro.models.common import ArchConfig, abstract_tree
from repro.optim import adamw
from repro.train import step as TS

LONG_WINDOW = 4096

SHAPES: Dict[str, Dict] = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def _rep(mesh: Mesh):
    return NamedSharding(mesh, P())


def _params_abstract(cfg: ArchConfig):
    return abstract_tree(T.abstract_params(cfg), cfg.jdtype)


def _state_abstract(cfg: ArchConfig):
    params = _params_abstract(cfg)
    f32 = lambda sds: jax.ShapeDtypeStruct(sds.shape, jnp.float32)
    opt = adamw.AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                           m=jax.tree_util.tree_map(f32, params),
                           v=jax.tree_util.tree_map(f32, params))
    return TS.TrainState(params=params, opt=opt)


def _state_shardings(cfg: ArchConfig, mesh: Mesh):
    ps = SH.param_shardings(T.abstract_params(cfg), mesh)
    return TS.TrainState(
        params=ps,
        opt=adamw.AdamWState(step=_rep(mesh),
                             m=jax.tree_util.tree_map(lambda s: s, ps),
                             v=jax.tree_util.tree_map(lambda s: s, ps)))


def _batch_specs(cfg: ArchConfig, b: int, s: int, mesh: Mesh,
                 with_labels: bool):
    sds = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    sh = {"tokens": NamedSharding(mesh, SH.batch_pspec(mesh, b, 2))}
    if with_labels:
        sds["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        sh["labels"] = sh["tokens"]
    if cfg.enc_dec:
        sds["enc_frames"] = jax.ShapeDtypeStruct(
            (b, cfg.n_frames, cfg.d_model), cfg.jdtype)
        sh["enc_frames"] = NamedSharding(mesh, SH.batch_pspec(mesh, b, 3))
    if cfg.n_patches:
        sds["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_patches, cfg.d_model), cfg.jdtype)
        sh["patch_embeds"] = NamedSharding(mesh, SH.batch_pspec(mesh, b, 3))
    return sds, sh


def decode_window(cfg: ArchConfig, shape_name: str) -> Optional[int]:
    """Sub-quadratic carve-out: dense archs serve long_500k via SWA."""
    if shape_name == "long_500k" and cfg.long_variant == "swa":
        return LONG_WINDOW
    return None


@dataclasses.dataclass
class LoweredSpec:
    fn: Any            # callable to jit
    args: Tuple        # ShapeDtypeStruct args
    in_shardings: Tuple
    out_shardings: Any
    donate: Tuple = ()


def build(cfg: ArchConfig, shape_name: str, mesh: Mesh, *,
          microbatch: int = 32) -> LoweredSpec:
    info = SHAPES[shape_name]
    s, b, kind = info["seq_len"], info["global_batch"], info["kind"]

    if kind == "train":
        tcfg = TS.TrainConfig(microbatch=microbatch, remat=True, mesh=mesh)
        ocfg = adamw.AdamWConfig()
        train_step = TS.make_train_step(cfg, ocfg, tcfg)
        state_sds = _state_abstract(cfg)
        state_sh = _state_shardings(cfg, mesh)
        batch_sds, batch_sh = _batch_specs(cfg, b, s, mesh, with_labels=True)
        metrics_sh = {"nll": _rep(mesh), "z_loss": _rep(mesh),
                      "n_tokens": _rep(mesh), "aux": _rep(mesh)}
        return LoweredSpec(
            fn=train_step, args=(state_sds, batch_sds),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, metrics_sh),
            donate=(0,))

    params_sds = _params_abstract(cfg)
    params_sh = SH.param_shardings(T.abstract_params(cfg), mesh)

    if kind == "prefill":
        batch_sds, batch_sh = _batch_specs(cfg, b, s, mesh,
                                           with_labels=False)

        def prefill_step(params, batch):
            return T.forward(cfg, params, batch["tokens"],
                             enc_frames=batch.get("enc_frames"),
                             patch_embeds=batch.get("patch_embeds"),
                             remat=False, return_cache=True, cache_len=s)

        cache_sds = T.init_cache(cfg, b, s)
        cache_sh = SH.cache_shardings(cache_sds, mesh)
        logits_sh = NamedSharding(mesh, SH.batch_pspec(mesh, b, 3))
        return LoweredSpec(
            fn=prefill_step, args=(params_sds, batch_sds),
            in_shardings=(params_sh, batch_sh),
            out_shardings=(logits_sh, _rep(mesh), cache_sh))

    # decode kinds
    window = decode_window(cfg, shape_name)
    cache_sds = T.init_cache(cfg, b, s, window_override=window)
    cache_sh = SH.cache_shardings(cache_sds, mesh)
    tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, SH.batch_pspec(mesh, b, 2))
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    args = [params_sds, cache_sds, tok_sds, pos_sds]
    in_sh = [params_sh, cache_sh, tok_sh, _rep(mesh)]
    extra = {}
    if cfg.enc_dec:
        enc_sds = jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model),
                                       cfg.jdtype)
        args.append(enc_sds)
        in_sh.append(NamedSharding(mesh, SH.batch_pspec(mesh, b, 3)))

        def serve_step(params, cache, tokens, pos, enc_out):
            logits, new_cache = T.decode_step(cfg, params, cache, tokens,
                                              pos, enc_out=enc_out,
                                              window_override=window)
            return logits, new_cache
    else:
        def serve_step(params, cache, tokens, pos):
            logits, new_cache = T.decode_step(cfg, params, cache, tokens,
                                              pos, window_override=window)
            return logits, new_cache

    logits_sh = NamedSharding(mesh, SH.batch_pspec(mesh, b, 3))
    return LoweredSpec(
        fn=serve_step, args=tuple(args), in_shardings=tuple(in_sh),
        out_shardings=(logits_sh, cache_sh),
        donate=(1,))
