"""Loop-aware HLO analysis: collective bytes, dot FLOPs, and HBM traffic,
all multiplied by while-loop trip counts.

Why: XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE
(verified by probe — a 16-iteration scan of a matmul reports 1 matmul of
FLOPs), so for scan-over-layers models it understates per-step totals by
the trip count. This walker descends from ENTRY through while bodies
(multiplying by ``known_trip_count`` from backend_config), call and fusion
computations, and accumulates:

  * collectives: count + result bytes per kind (per-device, post-SPMD)
  * dot FLOPs: 2 * prod(result) * prod(contracted lhs dims)
  * bytes: result + operand bytes per instruction (HBM-traffic proxy;
    fusion-internal instructions contribute dots/collectives but NOT bytes —
    their intermediates stay on-chip)
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}
DT = "|".join(DTYPE_BYTES)
SHAPE_RE = re.compile(rf"\b({DT})\[([\d,]*)\]")
COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)")
WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
CALL_RE = re.compile(r"(?:call|fusion)\(.*?\).*?(?:to_apply|calls)=%?"
                     r"([\w.\-]+)")
COLL_RE = re.compile(
    r"\s(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")
IOTA_GROUPS_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{\{(\d+),(\d+)\}")
DOT_RE = re.compile(r"\sdot\(")
LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def normalize_cost_analysis(cost) -> Dict:
    """Normalize ``compiled.cost_analysis()`` across JAX versions.

    Older JAX returns one properties dict; newer JAX returns a list with one
    dict per executable module. Always returns a plain dict (empty if the
    compiler reported nothing).
    """
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def _dims(s: str) -> List[int]:
    return [int(d) for d in s.split(",") if d]


def _nelem(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=")
OPERANDS_RE = re.compile(r"dot\(([^)]*)\)")
NAME_REF_RE = re.compile(r"%([\w.\-]+)")
HEADER_PARAM_RE = re.compile(rf"%?([\w.\-]+):\s*({DT})\[([\d,]*)\]")


def _split_computations(text: str):
    comps, entry = {}, None
    cur = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None or not line.startswith(" "):
            if stripped.endswith("{") and "->" in stripped:
                m = COMP_NAME_RE.match(stripped)
                if m:
                    cur = m.group(1)
                    comps[cur] = {"lines": [], "header": stripped}
                    if stripped.startswith("ENTRY"):
                        entry = cur
                    continue
        if cur is not None:
            if stripped == "}":
                cur = None
            else:
                comps[cur]["lines"].append(line)
    return comps, entry


def _symtab(comp) -> Dict[str, List[int]]:
    """instruction/parameter name -> result dims within one computation."""
    tab: Dict[str, List[int]] = {}
    for m in HEADER_PARAM_RE.finditer(comp["header"]):
        tab[m.group(1)] = _dims(m.group(3))
    for line in comp["lines"]:
        dm = DEF_RE.match(line)
        if not dm:
            continue
        sm = SHAPE_RE.search(line.split(", metadata=")[0])
        if sm:
            tab[dm.group(1)] = _dims(sm.group(2))
    return tab


def crosses_pod(line: str, pod_size: int = 256) -> bool:
    """True if the collective's first replica group spans a pod boundary
    (device ids on both sides of ``pod_size``). Handles the iota
    ``[g,s]<=[dims]T(perm)`` form and the explicit list form."""
    import numpy as _np
    m = IOTA_GROUPS_RE.search(line)
    if m:
        ng, gs = int(m.group(1)), int(m.group(2))
        dims = _dims(m.group(3))
        ids = _np.arange(int(_np.prod(dims))).reshape(dims)
        if m.group(4):
            ids = ids.transpose(_dims(m.group(4)))
        g0 = ids.reshape(ng, gs)[0]
        return bool((g0 // pod_size).min() != (g0 // pod_size).max())
    m = LIST_GROUPS_RE.search(line)
    if m:
        g0 = _np.array(_dims(m.group(1)))
        return bool((g0 // pod_size).min() != (g0 // pod_size).max())
    m = PERMUTE_PAIRS_RE.search(line)
    if m:
        a, b = int(m.group(1)), int(m.group(2))
        return a // pod_size != b // pod_size
    return False


def analyze(hlo_text: str) -> Dict:
    comps, entry = _split_computations(hlo_text)

    acc = {
        "collectives": {},       # kind -> [count, bytes]
        "dot_flops": 0.0,
        "bytes": 0.0,
        "cross_pod_bytes": 0.0,  # collectives spanning the pod boundary
        "loops": [],             # (trip, depth) for reporting
    }

    def shapes_on(line: str) -> List[Tuple[str, List[int]]]:
        # strip backend_config / metadata tails that contain brackets
        head = line.split(", metadata=")[0].split(", backend_config=")[0]
        return [(m.group(1), _dims(m.group(2)))
                for m in SHAPE_RE.finditer(head)]

    def line_bytes(line: str, symtab=None) -> float:
        # dynamic-(update-)slice inside scan bodies: the instruction's
        # result shape is the FULL buffer, but the op only moves one slice.
        # Counting full shapes inflated xlstm train memory ~20x (v1 proxy,
        # see EXPERIMENTS.md); count the slice operand instead.
        if " dynamic-update-slice(" in line:
            sh = shapes_on(line)
            if len(sh) >= 3:
                # result, operand(buffer), update, indices...
                return 2.0 * _nelem(sh[2][1]) * DTYPE_BYTES[sh[2][0]]
            if symtab is not None:
                om = re.search(r"dynamic-update-slice\(([^)]*)\)", line)
                if om:
                    refs = NAME_REF_RE.findall(om.group(1))
                    if len(refs) >= 2 and refs[1] in symtab:
                        return 2.0 * 4.0 * _nelem(symtab[refs[1]])
            return 0.0
        if " dynamic-slice(" in line:
            sh = shapes_on(line)
            if sh:
                return 2.0 * _nelem(sh[0][1]) * DTYPE_BYTES[sh[0][0]]
        sh = shapes_on(line)
        if not sh:
            return 0.0
        # bookkeeping ops move no HBM bytes
        if re.search(r"\s(get-tuple-element|tuple|parameter|bitcast|"
                     r"after-all|iota|constant)\(", line):
            return 0.0
        # fusion whose result shape equals an operand shape = in-place
        # buffer thread (fused DUS in scan bodies): count only the
        # non-matching operands (the actual slice/update traffic)
        if " fusion(" in line and len(sh) > 1:
            res = sh[0]
            ops = sh[1:]
            if any(o == res for o in ops):
                return float(sum(_nelem(d) * DTYPE_BYTES[t]
                                 for t, d in ops if (t, d) != res))
        return float(sum(_nelem(d) * DTYPE_BYTES[t] for t, d in sh))

    def dot_flops(line: str, symtab: Dict[str, List[int]]) -> float:
        sh = shapes_on(line)
        if not sh:
            return 0.0
        result = sh[0][1]
        lhs: Optional[List[int]] = sh[1][1] if len(sh) > 1 else None
        if lhs is None:
            om = OPERANDS_RE.search(line)
            if om:
                refs = NAME_REF_RE.findall(om.group(1))
                if refs:
                    lhs = symtab.get(refs[0])
        if lhs is None:
            return 0.0
        m = LHS_CONTRACT_RE.search(line)
        if not m:
            return 0.0
        k = 1
        for idx in _dims(m.group(1)):
            if idx < len(lhs):
                k *= lhs[idx]
        return 2.0 * _nelem(result) * k

    def trip_of(line: str, cond: str) -> int:
        m = TRIP_RE.search(line)
        if m:
            return int(m.group(1))
        for ln in comps.get(cond, {}).get("lines", []):
            mm = re.findall(r"constant\((\d+)\)", ln)
            if mm:
                return max(int(x) for x in mm)
        return 1

    symtabs: Dict[str, Dict[str, List[int]]] = {}

    def walk(name: str, mult: float, count_bytes: bool, depth: int = 0):
        if depth > 16 or name not in comps:
            return
        if name not in symtabs:
            symtabs[name] = _symtab(comps[name])
        for line in comps[name]["lines"]:
            cm = COLL_RE.search(line)
            if cm and cm.group(2) != "-done":
                kind = cm.group(1)
                sh = shapes_on(line)
                b = _nelem(sh[0][1]) * DTYPE_BYTES[sh[0][0]] if sh else 0
                ent = acc["collectives"].setdefault(kind, [0, 0.0])
                ent[0] += mult
                ent[1] += b * mult
                if crosses_pod(line):
                    acc["cross_pod_bytes"] += b * mult
            if DOT_RE.search(line):
                acc["dot_flops"] += dot_flops(line, symtabs[name]) * mult
            if count_bytes:
                acc["bytes"] += line_bytes(line, symtabs[name]) * mult
            wm = WHILE_RE.search(line)
            if wm:
                trip = trip_of(line, wm.group(1))
                acc["loops"].append((trip, depth))
                walk(wm.group(2), mult * trip, count_bytes, depth + 1)
                continue
            fm = CALL_RE.search(line)
            if fm:
                is_fusion = " fusion(" in line
                # fusion internals: count dots/collectives, not bytes
                walk(fm.group(1), mult, count_bytes and not is_fusion,
                     depth + 1)

    if entry:
        walk(entry, 1.0, True)
    return {
        "collectives": {k: {"count": int(c), "bytes": float(b)}
                        for k, (c, b) in acc["collectives"].items()},
        "collective_bytes_total": float(
            sum(b for _, b in acc["collectives"].values())),
        "dot_flops": acc["dot_flops"],
        "hbm_bytes": acc["bytes"],
        "cross_pod_bytes": acc["cross_pod_bytes"],
        "n_loops": len(acc["loops"]),
        "max_trip": max((t for t, _ in acc["loops"]), default=1),
    }
