"""Training launcher.

CPU demo (reduced config, host mesh):
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --reduced --steps 20 --scheme diagonal --pods 2

On a real TPU slice the same entrypoint runs the full config on the
production mesh (--mesh pod|multipod). The consensus schemes implement the
paper's estimator combination across the pod axis; --scheme sync is the
fully-synchronous baseline.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as CFG
from repro.checkpoint import io as CK
from repro.data.pipeline import DataConfig, SyntheticLM, pod_sharded_batches
from repro.optim import adamw
from repro.train import consensus as CT
from repro.train import step as TS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--scheme", default="sync",
                    choices=["sync", "uniform", "diagonal", "max", "admm"])
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--h-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = CFG.get(args.arch)
    if args.reduced:
        cfg = CFG.reduced(cfg)
    ocfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                             total_steps=max(args.steps, 2))
    tcfg = TS.TrainConfig()
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                global_batch=args.batch))

    if args.scheme == "sync":
        state = TS.init_state(cfg, jax.random.PRNGKey(0))
        step_fn = jax.jit(TS.make_train_step(cfg, ocfg, tcfg))
        for i, batch in zip(range(args.steps), ds):
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            print(f"step {i:4d} nll={float(metrics['nll']):.4f} "
                  f"({time.time()-t0:.2f}s)", flush=True)
            if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                CK.save(args.ckpt_dir, i + 1, state,
                        extra={"arch": cfg.arch_id})
    else:
        ccfg = CT.ConsensusConfig(n_pods=args.pods, scheme=args.scheme,
                                  h_steps=args.h_steps)
        state = CT.init_state(cfg, jax.random.PRNGKey(0), ccfg)
        round_fn = jax.jit(CT.make_round_step(cfg, ocfg, tcfg, ccfg))
        batches = pod_sharded_batches(ds, args.pods, args.h_steps)
        n_rounds = args.steps // args.h_steps
        for r, batch in zip(range(n_rounds), batches):
            t0 = time.time()
            state, metrics = round_fn(state, batch)
            print(f"round {r:4d} ({args.h_steps} local steps/pod) "
                  f"nll={float(metrics['nll']):.4f} "
                  f"({time.time()-t0:.2f}s)", flush=True)
            if args.ckpt_dir and (r + 1) % args.ckpt_every == 0:
                # Thm 3.1 any-time property: theta_bar is always a valid
                # checkpoint, even mid-ADMM.
                CK.save(args.ckpt_dir, r + 1, state.theta_bar,
                        extra={"arch": cfg.arch_id, "scheme": args.scheme})
    print("done")


if __name__ == "__main__":
    main()
