"""Migration shim — the transformer serving CLI was retired.

``repro.serve`` is now the multi-tenant estimation session server; there
is no decode CLI behind this entry point any more. The batched-decode
demo lives in ``examples/serve_batched.py`` (built on
:mod:`repro.models.decoding`), and the serving benchmark is
``python -m benchmarks.serve_bench``.
"""
raise ModuleNotFoundError(
    "repro.launch.serve has been removed: repro.serve is now the "
    "multi-tenant estimation session server (repro.serve.SessionServer). "
    "For batched transformer decoding use examples/serve_batched.py with "
    "repro.models.decoding; for serving load numbers run "
    "'python -m benchmarks.serve_bench'.",
    name="repro.launch.serve")
