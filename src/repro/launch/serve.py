"""Serving launcher: batched greedy decoding with KV caches.

CPU demo:
    PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
        --reduced --batch 4 --prompt-len 16 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as CFG
from repro.models import transformer as T
from repro.serve import engine as E


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window override (long-context serving)")
    args = ap.parse_args()

    cfg = CFG.get(args.arch)
    if args.reduced:
        cfg = CFG.reduced(cfg)
    params = T.model_init(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    enc = None
    if cfg.enc_dec:
        enc = 0.1 * jnp.ones((args.batch, cfg.n_frames, cfg.d_model),
                             cfg.jdtype)
    t0 = time.time()
    out = E.generate(cfg, params, prompts, args.new_tokens,
                     temperature=args.temperature, enc_frames=enc,
                     window_override=args.window or None)
    dt = time.time() - t0
    n_tok = args.batch * args.new_tokens
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s batched)")
    print(out[:, :12])


if __name__ == "__main__":
    main()
