"""Exact communication-cost accounting (paper Sec. 1/3 motivating claim).

Single source of truth for "how many scalars does a message carry", shared
by the combinatorial table in ``benchmarks/comm_cost.py`` and the measured
counters of :class:`repro.stream.simulator.StreamSimulator` — one full
broadcast round of the streaming one-step engine transmits exactly the
table's per-scheme count, which the tests assert.

Conventions (matching the paper's schemes, Sec. 3.1):
  * a one-step message carries, per shared parameter, the local estimate
    (1 scalar) plus — for weighted schemes — its variance weight (1 more);
  * an ADMM round message carries the local estimate per shared parameter
    (penalties rho are static configuration, not traffic);
  * Linear-Opt's secondary round ships n influence samples per shared
    parameter (why its cost is n-dependent);
  * the centralized baseline ships the raw dataset.
"""
from __future__ import annotations

from ..core.asymptotics import param_owners
from ..core.graphs import Graph

def _registry_scalars() -> dict:
    """Name-keyed view of ``Combiner.scalars_per_shared_param`` over the
    distributable registered combiners — the registry is the single source
    of truth (uniform: estimate only, weights implicitly 1; weighted
    schemes: estimate + weight/vote mass; Linear-Opt's influence samples
    are counted apart)."""
    from ..core.combiners import registered_combiners
    return {c.name: c.scalars_per_shared_param
            for c in registered_combiners()
            if c.scalars_per_shared_param is not None}


#: import-time snapshot for the built-in schemes; ``one_step_message_
#: scalars`` resolves through the LIVE registry, so combiners registered
#: later are billed correctly without touching this table
SCHEME_SCALARS_PER_PARAM = _registry_scalars()


def one_step_message_scalars(n_shared: int, scheme: str) -> int:
    """Scalars in one one-step consensus message covering n_shared params.

    Resolved through the combiner registry (raising the registry's
    ``ValueError`` on unknown names, and a clear one for combiners that
    are not distributable as a one-step message round)."""
    from ..core.combiners import get_combiner
    spp = get_combiner(scheme).scalars_per_shared_param
    if spp is None:
        raise ValueError(
            f"combiner {scheme!r} is not distributable as a one-step "
            f"message round (no scalars_per_shared_param)")
    return int(n_shared) * spp


def structure_vote_scalars(n_candidate_edges: int, rule: str) -> int:
    """Scalars one support-voting round transmits for a candidate edge set.

    Every candidate edge has exactly TWO voters (its endpoints), and each
    ships ``scalars_per_edge_vote`` scalars — the in/out decision, plus
    the vote mass for mass-weighted rules — read from the vote-rule
    registry (:mod:`repro.structure.voting`), so a newly registered rule
    is billed correctly without touching this module. Unknown names raise
    the registry's ``ValueError`` listing what is registered. This is the
    number :class:`repro.structure.StructureResult` reports as
    ``comm_scalars``.
    """
    from ..structure.voting import get_vote_rule
    return 2 * int(n_candidate_edges) * get_vote_rule(rule).scalars_per_edge_vote


def admm_message_scalars(n_shared: int) -> int:
    """Scalars in one ADMM-round message covering n_shared params."""
    return int(n_shared)


def one_step_comm_by_scheme(shared_owner_slots: int, combiners, n: int) -> dict:
    """Per-scheme scalars ONE full one-step round transmits for a plan.

    ``shared_owner_slots`` is the number of (shared parameter, owner)
    pairs — every owner of every multi-owner parameter ships its estimate
    (+ weight when the scheme uses one); influence-needing schemes
    (Linear-Opt) additionally ship their ``n`` influence samples per slot.
    Non-distributable combiners (``scalars_per_shared_param is None``) are
    omitted. Shared by :meth:`repro.api.session.EstimationSession` results
    and the serving tier's per-tenant budget billing.
    """
    from ..core.combiners import get_combiner
    out = {}
    for name in combiners:
        c = get_combiner(name)
        if c.scalars_per_shared_param is None:
            continue               # not distributable as one message round
        cost = c.scalars_per_shared_param * int(shared_owner_slots)
        if "influence" in c.needs:
            cost += int(n) * int(shared_owner_slots)
        out[c.name] = cost
    return out


def shared_owner_slot_count(g: Graph, include_singleton: bool = True,
                            family=None) -> int:
    """(shared parameter, owner) pairs of a graph — the unit the one-step
    accounting bills per scheme."""
    owners = param_owners(g, include_singleton, family)
    return sum(len(own) for own in owners.values() if len(own) > 1)


def plan_request_scalars(g: Graph, combiners, n: int,
                         include_singleton: bool = True,
                         family=None) -> int:
    """Total scalars one fit/stream round of a plan transmits, summed over
    its requested distributable combiners — what the serving tier's
    admission control charges a tenant per request."""
    slots = shared_owner_slot_count(g, include_singleton, family)
    return sum(one_step_comm_by_scheme(slots, combiners, n).values())


def comm_costs(g: Graph, n: int, admm_iters: int) -> dict:
    """Exact combinatorial scalar counts per sensor-network method.

    one-step consensus    : each node sends estimate (+ weight) per shared
                            param
    Linear-Opt (Prop 4.6) : adds the secondary round shipping s^i_alpha
                            samples
    ADMM (K iters)        : K rounds of local-estimate exchange
    centralized           : ship the raw dataset to a fusion center

    No simulation — this is the paper's qualitative ranking
    one-step << ADMM << centralized, with Linear-Opt n-dependent.
    """
    owners = param_owners(g)
    shared = [a for a, own in owners.items() if len(own) > 1]
    beta_sizes = [len(g.beta(i)) for i in range(g.p)]
    # estimates travel once per shared param per owner; weights double it
    one_step = sum(
        one_step_message_scalars(len(owners[a]), "uniform") for a in shared)
    diag = sum(
        one_step_message_scalars(len(owners[a]), "diagonal") for a in shared)
    # Prop 4.6 secondary round: each node ships n influence samples per
    # shared parameter it owns
    linear_opt = diag + n * one_step
    admm = admm_iters * 2 * sum(beta_sizes)      # send theta^i, get theta_bar
    central = n * g.p                            # raw data to fusion center
    return dict(one_step_linear=one_step, diagonal_or_max=diag,
                linear_opt=linear_opt, admm=admm, centralized=central)
