"""Online local estimators: incremental, warm-started re-fits over a stream.

Each sensor's conditional-likelihood M-estimator (paper Eq. 3) is an average
over its observed samples, so as chunks arrive the criterion changes but the
optimum moves only O(new/total). :class:`StreamingEstimator` exploits that:
it pools arrivals into a shape-stable :class:`~repro.stream.buffer.
SampleBuffer`, tracks how far into the pool each sensor has seen (prefix
counts), and re-fits *all* nodes through the degree-bucketed batched engine
with per-node 0/1 observation masks and the previous thetas as Newton warm
starts — an incremental re-fit is a couple of damped Newton steps on one
already-compiled program per bucket, not a from-scratch solve. The whole
bank is **family-generic**: pass any registered
:class:`~repro.core.families.base.ModelFamily` and the same machinery
streams Gaussian MRF or Potts estimation.

:func:`pseudo_score` is the observer-side any-time diagnostic: the exact
gradient of the average pseudo-likelihood at an arbitrary theta. Every
family whose ``kernel_kind`` has a registered epilogue in the fused CL
kernel subsystem (``repro.kernels.cl`` — Ising, Gaussian and the
multi-channel Potts all ship one) runs in one fused pass over the padded
buffer; families without an epilogue fall back to the autodiff reference
score on the live rows. Its norm shrinking toward zero is a model-free
convergence signal for whatever consensus estimate is being traced.

Scale-out: both :class:`StreamingEstimator` and
:class:`~repro.stream.simulator.StreamSimulator` take a ``mesh`` kwarg that
routes every incremental re-fit through the batched engine's
shard_map-over-mesh path (bucket nodes sharded along the mesh's ``data``
axis; numerically identical on a one-device mesh).
"""
from __future__ import annotations

from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from ..core.batched import fit_all_local_batched
from ..core.consensus import TRUST_RADIUS
from ..telemetry.recorder import NULL_RECORDER
from ..core.estimators import LocalFit
from ..core.families import ISING
from ..core.graphs import Graph
from ..kernels.cl.epilogues import get_epilogue
from ..kernels.cl.family import fused_pseudo_score
from .buffer import SampleBuffer


class StreamingEstimator:
    """Bank of all p per-node online CL estimators over a shared pool.

    The pool model: the environment draws i.i.d. samples x_1, x_2, ...;
    sensor i has observed the first ``counts[i]`` of them (sensors sample at
    different rates, so counts are heterogeneous). ``refit()`` updates every
    node's local fit to its current prefix. ``family`` selects the model
    family (default Ising).

    Prefer obtaining instances through the estimation-plan API —
    ``repro.api.Plan(...).session().stream()`` — which binds family, mesh,
    fixed coordinates, capacity, and Newton budget to one declarative plan
    (and shares the compiled bucket solvers with the session's batch/joint
    verbs); direct construction remains supported as the legacy path.
    """

    def __init__(self, graph: Graph, include_singleton: bool = True,
                 theta_fixed: Optional[np.ndarray] = None,
                 capacity: int = 64, n_iter: int = 40,
                 family=None, mesh=None,
                 want_influence: bool = True,
                 window: Optional[int] = None,
                 discount: Optional[float] = None,
                 recorder=None) -> None:
        if window is not None and int(window) < 1:
            raise ValueError(
                f"sliding window must be >= 1 sample (None disables it), "
                f"got {window!r}")
        if discount is not None and not (0.0 < float(discount) <= 1.0):
            raise ValueError(
                f"discount must be in (0.0, 1.0] (1.0 = no forgetting, "
                f"None disables it), got {discount!r}")
        #: drift-tracking re-fit windows — see SampleBuffer.window_weights
        self.window = None if window is None else int(window)
        self.discount = None if discount is None else float(discount)
        #: telemetry recorder (see :mod:`repro.telemetry`); the shared
        #: allocation-free NULL_RECORDER unless an owner (session or
        #: simulator) injects a live one
        self.recorder = NULL_RECORDER if recorder is None else recorder
        self.graph = graph
        self.family = ISING if family is None else family
        self.mesh = mesh
        #: False skips the (n, d) per-sample influence stacks on every
        #: re-fit — none of the streamable one-step schemes read them, so
        #: plan-bound streams and the simulator opt out (LocalFit.s then
        #: has zero rows); the default keeps the legacy full fits
        self.want_influence = want_influence
        self.include_singleton = include_singleton
        n_params = self.family.n_params(graph)
        self.theta_fixed = (np.zeros(n_params, dtype=np.float64)
                            if theta_fixed is None
                            else np.asarray(theta_fixed, dtype=np.float64))
        self.n_iter = n_iter
        self.buffer = SampleBuffer(graph.p, capacity=capacity)
        self.counts = np.zeros(graph.p, dtype=np.int64)
        self.versions = np.zeros(graph.p, dtype=np.int64)
        self.fits: Optional[List[LocalFit]] = None
        self._warm: Optional[List[np.ndarray]] = None
        self._fit_counts = np.full(graph.p, -1, dtype=np.int64)

    # ------------------------------------------------------------ ingestion
    def extend_pool(self, rows) -> None:
        """Append environment samples to the shared pool (nobody has seen
        them yet until ``advance``/``ingest`` says so)."""
        self.buffer.append(rows)

    def advance(self, counts: np.ndarray) -> None:
        """Move per-node seen-counts forward (monotone, clipped to pool)."""
        counts = np.minimum(np.asarray(counts, dtype=np.int64), self.buffer.n)
        if np.any(counts < self.counts):
            raise ValueError("seen-counts must be monotone nondecreasing")
        self.counts = counts

    def ingest(self, rows) -> None:
        """Chunked convenience path: append rows and let every node see the
        whole pool — feeding the same data in k chunks or at once yields the
        same fits (to Newton tolerance)."""
        self.extend_pool(rows)
        self.advance(np.full(self.graph.p, self.buffer.n, dtype=np.int64))

    @property
    def n_pool(self) -> int:
        return self.buffer.n

    @property
    def effective_counts(self) -> np.ndarray:
        """Per-node effective sample sizes: the total fit weight each node
        places on the pool. Equal to ``counts`` without windows; the
        window/discount-weighted mass otherwise — the right ``n`` for
        1/n variance scalings under forgetting."""
        if self.window is None and self.discount is None:
            return self.counts.astype(np.float64)
        return self.buffer.window_weights(
            self.counts, self.window, self.discount).sum(
                axis=1).astype(np.float64)

    # ------------------------------------------------------------ durability
    def state_dict(self):
        """Full restorable state as (arrays, json_meta) — pool, per-node
        prefix counts/versions, warm starts, and the fitted LocalFit bank —
        everything a fresh estimator (constructed with the same
        configuration) needs to continue bit-identically."""
        arrays = {
            "est/pool": self.buffer.data.copy(),
            "est/counts": self.counts.copy(),
            "est/versions": self.versions.copy(),
            "est/fit_counts": self._fit_counts.copy(),
            "est/theta_fixed": self.theta_fixed.copy(),
        }
        meta = {
            "n": int(self.buffer.n),
            "window": self.window,
            "discount": self.discount,
            "warm": [w is not None for w in (self._warm or [])],
            "betas": None,
        }
        if self._warm is not None:
            for i, w in enumerate(self._warm):
                if w is not None:
                    arrays[f"est/warm_{i}"] = np.asarray(w)
        if self.fits is not None:
            meta["betas"] = [list(map(int, f.beta)) for f in self.fits]
            for f in self.fits:
                for part in ("theta", "H", "J", "V", "s"):
                    arrays[f"est/fit{f.i}_{part}"] = np.asarray(
                        getattr(f, part))
        return arrays, meta

    def load_state(self, arrays, meta) -> None:
        """Inverse of :meth:`state_dict`, in place."""
        pool = np.asarray(arrays["est/pool"])
        self.buffer._X = pool.copy()
        self.buffer.n = int(meta["n"])
        self.counts = np.asarray(arrays["est/counts"]).copy()
        self.versions = np.asarray(arrays["est/versions"]).copy()
        self._fit_counts = np.asarray(arrays["est/fit_counts"]).copy()
        self.theta_fixed = np.asarray(arrays["est/theta_fixed"]).copy()
        self.window = meta["window"]
        self.discount = meta["discount"]
        warm_flags = meta.get("warm") or []
        if warm_flags:
            self._warm = [
                np.asarray(arrays[f"est/warm_{i}"]).copy() if present
                else None for i, present in enumerate(warm_flags)]
        else:
            self._warm = None
        betas = meta.get("betas")
        if betas is None:
            self.fits = None
        else:
            self.fits = [
                LocalFit(i=i, beta=list(b),
                         theta=np.asarray(arrays[f"est/fit{i}_theta"]),
                         H=np.asarray(arrays[f"est/fit{i}_H"]),
                         J=np.asarray(arrays[f"est/fit{i}_J"]),
                         V=np.asarray(arrays[f"est/fit{i}_V"]),
                         s=np.asarray(arrays[f"est/fit{i}_s"]))
                for i, b in enumerate(betas)]

    # --------------------------------------------------------------- fitting
    def refit(self) -> List[LocalFit]:
        """Warm-started weighted re-fit of every node at its current prefix.

        Bumps a node's version when its data actually changed since its last
        fit, so a network layer can broadcast only fresh fits. A no-op call
        (no counts moved, e.g. a stalled arrival process) returns the cached
        fits without paying for a solve.
        """
        if self.fits is not None and np.array_equal(self.counts,
                                                    self._fit_counts):
            return self.fits
        rec = self.recorder
        masks = self.buffer.window_weights(self.counts, self.window,
                                           self.discount)
        with rec.span("refit"):
            fits = fit_all_local_batched(
                self.graph, jnp.asarray(self.buffer.data),
                include_singleton=self.include_singleton,
                theta_fixed=jnp.asarray(self.theta_fixed,
                                        dtype=self.buffer.data.dtype),
                n_iter=self.n_iter,
                sample_weight=jnp.asarray(masks),
                warm_start=self._warm,
                family=self.family, mesh=self.mesh,
                want_influence=self.want_influence,
                recorder=rec)
        if rec.enabled:
            # buffer occupancy + window effective counts at this re-fit
            rec.gauge("stream.buffer_rows", int(self.buffer.n))
            rec.gauge("stream.buffer_capacity",
                      int(self.buffer.data.shape[0]))
            rec.gauge("stream.effective_count_mean",
                      float(self.effective_counts.mean()))
        return self._finish_refit(fits)

    def _finish_refit(self, fits: List[LocalFit]) -> List[LocalFit]:
        """Post-solve bookkeeping shared by :meth:`refit` and the serving
        tier's coalesced dispatch (which solves several estimators' banks
        in one union program and hands each its slice): version bumps for
        nodes whose data changed, prefix-count snapshot, and trust-radius
        warm-start hygiene.
        """
        changed = self.counts != self._fit_counts
        self.versions = self.versions + changed.astype(np.int64)
        self._fit_counts = self.counts.copy()
        # a diverged fit (quasi-separation at small n drives the optimum to
        # infinity; NaN is absorbing in Newton) must not poison every future
        # re-fit through its warm start: from |theta| ~ 1e9 no bounded step
        # schedule returns. Cold-restart nodes outside the same trust radius
        # consensus.combine uses to disqualify owners; once the node has
        # enough data its cold re-fit lands at the now-finite optimum.
        self._warm = [
            f.theta if np.all(np.isfinite(f.theta))
            and np.max(np.abs(f.theta)) <= TRUST_RADIUS else None
            for f in fits]
        self.fits = fits
        return fits

    # ----------------------------------------------------------- diagnostics
    def score_norm(self, theta: np.ndarray,
                   interpret: Optional[bool] = None) -> float:
        """||grad pseudo-loglik(theta)|| over the pooled samples."""
        g = pseudo_score(self.graph, theta, self.buffer.data, self.buffer.n,
                         interpret=interpret, family=self.family)
        return float(np.linalg.norm(g))


def pseudo_score(graph: Graph, theta: np.ndarray, x_pad,
                 n_seen: int, interpret: Optional[bool] = None,
                 family=None, use_pallas: Optional[bool] = None) -> np.ndarray:
    """Exact flat gradient of the average pseudo-likelihood at ``theta``.

    Family-dispatched through the fused CL kernel subsystem: any family
    whose ``kernel_kind`` has a registered epilogue (Ising, Gaussian, and
    multi-channel Potts) runs one fused pass over the (zero-padded) sample
    buffer — the channelized kernel emits the per-sample score residuals r
    and the cross-channel score Gram ``S[c, e] = r_c^T F_e / n``;
    channel-c singleton gradients are live-row means of ``r_c`` and the
    coupling gradient of edge (i, j) is ``S[c, c][i, j] + S[c, c][j, i]``
    (see :func:`repro.kernels.cl.family.fused_pseudo_score`). Families
    without an epilogue fall back to the autodiff reference score over the
    live rows.

    ``use_pallas=None`` takes the backend default through the dispatch
    layer — the compiled Mosaic kernel on TPU/GPU, the XLA-compiled tiled
    twin elsewhere; pass ``use_pallas=True`` to force the Pallas kernel
    body, in which case ``interpret`` chooses interpret vs compiled
    execution (``None`` = compiled where the backend supports it).
    """
    if family is None:
        family = ISING
    theta = np.asarray(theta, dtype=np.float64)
    if n_seen <= 0:
        return np.zeros(family.n_params(graph))
    if get_epilogue(getattr(family, "kernel_kind", None)) is None:
        return family.pseudo_score(graph, theta,
                                   np.asarray(x_pad)[: int(n_seen)])
    return fused_pseudo_score(family, graph, theta, x_pad, n_seen,
                              interpret=interpret, use_pallas=use_pallas)
