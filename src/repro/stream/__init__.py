"""Streaming any-time estimation engine + event-driven sensor-network
simulator.

The paper's headline claims — any-time behavior of consensus iterates and
low communication cost (Sec. 1, Sec. 3, Thm 3.1) — become measurable system
properties here: samples arrive at sensors over time (:class:`ArrivalSpec`),
per-node online estimators re-fit incrementally by warm-starting the batched
Newton-IRLS engine over a shape-stable sample buffer
(:class:`StreamingEstimator`), estimates flow over an explicit lossy/laggy
message network (:class:`Network`), and the event-driven
:class:`StreamSimulator` traces error-vs-samples-seen and
error-vs-scalars-communicated trajectories queryable at any round via
``StreamResult.estimate_at(t)``.

Communication accounting (:mod:`repro.stream.costs`) is shared with
``benchmarks/comm_cost.py`` so the simulator's measured scalar counts and
the combinatorial table agree exactly.
"""
from .buffer import SampleBuffer
from .costs import (SCHEME_SCALARS_PER_PARAM, admm_message_scalars,
                    comm_costs, one_step_message_scalars)
from .faults import (BYZANTINE_KINDS, ByzantineSpec, CrashSpec, DriftSpec,
                     FaultPlan, ReplaySpec)
from .network import Message, Network, NetworkConfig
from .online import StreamingEstimator, pseudo_score
from .simulator import (ONE_STEP_SCHEMES, ArrivalSpec, StreamResult,
                        StreamSimulator)
