"""Event-driven sensor-network simulator with an any-time query API.

Discrete rounds; each round:

  1. every sensor's arrival process delivers new samples from the
     environment pool (heterogeneous rates supported);
  2. the online estimator bank re-fits (warm-started, incremental) on a
     configurable cadence — or, in ADMM mode, every node takes one proximal
     primal step (Sec. 3.2) on its current data;
  3. fresh estimates of *shared* parameters travel to neighbor sensors as
     explicit messages through the :class:`~repro.stream.network.Network`
     (link schedules, drops, delays — every scalar is counted);
  4. each parameter's home sensor combines whatever owner estimates have
     arrived (possibly stale) with the paper's one-step weighting schemes —
     or, in ADMM mode, updates its consensus average and dual variable.

``run`` records an error/communication trajectory; ``StreamResult.
estimate_at(t)`` answers "what would the network report if queried at round
t" — the any-time property as a measurable quantity rather than a theorem.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core.asymptotics import free_indices, param_owners
from ..core.batched import prox_update_batched
from ..core.combiners import (TRUST_RADIUS, get_combiner,
                              streamable_combiners)
from ..core.graphs import Graph
from .costs import admm_message_scalars, one_step_message_scalars
from ..telemetry.recorder import make_recorder
from .faults import FaultPlan
from .network import (Network, NetworkConfig, rng_state_from_json,
                      rng_state_to_json)
from .online import StreamingEstimator


def _one_step_schemes() -> Tuple[str, ...]:
    """Streamable one-step schemes, resolved from the LIVE combiner
    registry: distributable as one message round and able to fuse
    (estimate, variance) candidates receiver-side. (The paper's "optimal"
    scheme ships n influence samples per shared param — see
    costs.comm_costs — and is deliberately not a streaming mode.)"""
    return tuple(c.name for c in streamable_combiners())


#: import-time snapshot of the built-in streamable schemes (test
#: parametrization axis); validation and plan resolution use the live
#: ``_one_step_schemes()`` so later-registered combiners stream too
ONE_STEP_SCHEMES = _one_step_schemes()


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """Per-round, per-node sample arrival process.

    kind — "fixed" (exactly ``rate`` samples each round), "poisson"
    (Poisson(``rate``)), or "bursty" (a burst of ``burst`` samples with
    probability ``rate/burst``, same mean as the others). ``rate`` may be a
    scalar or a length-p tuple for sensors sampling at different speeds.
    """
    kind: str = "fixed"
    rate: object = 1.0
    burst: int = 8

    def draw(self, rng: np.random.RandomState, p: int) -> np.ndarray:
        rate = np.broadcast_to(np.asarray(self.rate, dtype=np.float64), (p,))
        if self.kind == "fixed":
            return np.round(rate).astype(np.int64)
        if self.kind == "poisson":
            return rng.poisson(rate).astype(np.int64)
        if self.kind == "bursty":
            prob = np.minimum(1.0, rate / max(self.burst, 1))
            return (rng.binomial(1, prob) * self.burst).astype(np.int64)
        raise ValueError(f"unknown arrival kind {self.kind!r}")


@dataclasses.dataclass
class StreamResult:
    """Recorded trajectory of one simulation; the any-time query surface."""
    rounds: np.ndarray        # (R,) round indices of the snapshots
    theta: np.ndarray         # (R, n_params) combined estimate per snapshot
    samples_seen: np.ndarray  # (R,) mean samples per node
    samples_total: np.ndarray  # (R,) total samples across nodes
    scalars_sent: np.ndarray  # (R,) cumulative scalars transmitted
    err: Optional[np.ndarray]         # (R,) MSE vs theta_star (if given)
    score_norm: Optional[np.ndarray]  # (R,) pseudo-likelihood score norm
    staleness: np.ndarray     # (R,) mean age (rounds) of received views
    #: what the network would have reported when recording started (the
    #: pre-data estimate — theta_fixed for a fresh simulator); answers
    #: any-time queries earlier than the first recorded round
    initial: Optional[np.ndarray] = None
    #: :class:`repro.telemetry.TelemetrySnapshot` of the run's events when
    #: the simulator carried a live recorder, else None
    telemetry: Optional[object] = None

    #: recorded columns addressable through :meth:`timeline`
    _COLUMNS = ("err", "scalars_sent", "samples_seen", "samples_total",
                "staleness", "score_norm")

    def timeline(self, metric: str) -> Tuple[np.ndarray, np.ndarray]:
        """(rounds, values) any-time curve for one recorded metric.

        Resolution order: the telemetry snapshot's ``point`` events when a
        live recorder captured them (byte-identical to a JSONL replay),
        falling back to the result's own recorded columns
        (``err`` / ``scalars_sent`` / ``samples_seen`` / ``samples_total``
        / ``staleness`` / ``score_norm``)."""
        if self.telemetry is not None and metric in self.telemetry.points:
            return self.telemetry.timeline(metric)
        if metric not in self._COLUMNS:
            raise KeyError(
                f"unknown timeline metric {metric!r}; have "
                f"{sorted(self._COLUMNS)}")
        col = getattr(self, metric)
        if col is None:
            raise KeyError(
                f"metric {metric!r} was not recorded for this run "
                f"(pass theta_star / record_score to the simulator)")
        return (np.asarray(self.rounds, dtype=np.int64),
                np.asarray(col, dtype=np.float64))

    def estimate_at(self, t: int) -> np.ndarray:
        """Combined theta as of round ``t``: the last snapshot at or before
        t. A query *earlier than the first recorded round* returns the
        ``initial`` estimate — the network had not produced a recorded
        combination yet, so the answer is what it reported going in, not
        a peek at the round-``rounds[0]`` snapshot (and never an index
        error). Falls back to the earliest snapshot when ``initial`` was
        not recorded (pre-fix pickles)."""
        idx = int(np.searchsorted(self.rounds, t, side="right")) - 1
        if idx < 0:
            if self.initial is not None:
                return self.initial
            return self.theta[0]
        return self.theta[idx]


def _guard(est: float, w: float) -> bool:
    """Same sanity guard as core.consensus.combine's bad-owner logic."""
    return bool(np.isfinite(est) and np.isfinite(w)
                and abs(est) <= TRUST_RADIUS)


class StreamSimulator:
    """Streaming distributed estimation over an explicit message network.

    Parameters
    ----------
    graph : the conditional-independence graph == the sensor network.
    pool : (N, p) pre-drawn environment samples; arrivals reveal prefixes.
    estimator : "one_step" (online local fits + one-step consensus of
        whatever has arrived) or "admm" (streaming ADMM: one warm-started
        proximal round per simulator round over the growing buffers).
    scheme : one-step weighting, any *streamable* combiner from the
        registry (``ONE_STEP_SCHEMES``: uniform / diagonal / max /
        weighted_vote). The receiver-side fusion dispatches through the
        strategy object's ``combine_candidates``.
    mesh : optional jax mesh with a ``data`` axis; every re-fit / proximal
        round then runs through the batched engine's shard_map path
        (numerically identical on a one-device mesh).

    ``StreamSimulator.from_plan(plan, pool, ...)`` configures all of the
    above from a declarative :class:`repro.api.Plan`.
    """

    def __init__(self, graph: Graph, pool, *,
                 estimator: str = "one_step", scheme: str = "diagonal",
                 theta_star: Optional[np.ndarray] = None,
                 include_singleton: bool = True,
                 theta_fixed: Optional[np.ndarray] = None,
                 network: Optional[NetworkConfig] = None,
                 arrivals: ArrivalSpec = ArrivalSpec(rate=8.0),
                 refit_every: int = 1, newton_iters: int = 40,
                 admm_rho: float = 1.0, capacity: int = 64,
                 seed: int = 0, family=None, mesh=None,
                 faults: Optional[FaultPlan] = None,
                 window: Optional[int] = None,
                 discount: Optional[float] = None,
                 telemetry=None) -> None:
        if estimator not in ("one_step", "admm"):
            raise ValueError(f"unknown estimator {estimator!r}")
        streamable = _one_step_schemes()
        if scheme not in streamable:
            raise ValueError(
                f"unknown streaming scheme {scheme!r}; streamable "
                f"combiners: {list(streamable)}")
        if faults is not None and not isinstance(faults, FaultPlan):
            raise TypeError(f"faults must be a FaultPlan, "
                            f"got {type(faults).__name__}")
        from ..core.families import ISING
        #: telemetry recorder threaded through the estimator bank, the
        #: network, and the round loop (a TelemetrySpec, an existing
        #: Recorder — e.g. the owning session's — or None for the shared
        #: zero-overhead null)
        self.recorder = make_recorder(telemetry)
        self.combiner = get_combiner(scheme)
        #: unit weights are implicit and never transmitted (uniform)
        self._sends_weight = self.combiner.scalars_per_shared_param >= 2
        self.graph = graph
        self.family = ISING if family is None else family
        self.mesh = mesh
        self.faults = faults if faults is not None and not faults.empty \
            else None
        if self.faults is not None:
            for spec in (self.faults.crashes + self.faults.byzantine):
                if spec.node >= graph.p:
                    raise ValueError(
                        f"fault spec names node {spec.node}, but the "
                        f"graph has only {graph.p} nodes (0.."
                        f"{graph.p - 1})")
            if self.faults.drift and theta_star is None:
                raise ValueError(
                    "parameter drift needs theta_star (the truth to "
                    "perturb); pass theta_star= to the simulator")
        # drift mutates the unseen pool tail in place — never the caller's
        if self.faults is not None and self.faults.drift:
            self.pool = np.array(pool, dtype=np.float32, copy=True)
        else:
            self.pool = np.asarray(pool, dtype=np.float32)
        self.estimator = estimator
        self.scheme = scheme
        self.include_singleton = include_singleton
        self.theta_fixed = (np.zeros(self.family.n_params(graph))
                            if theta_fixed is None
                            else np.asarray(theta_fixed, dtype=np.float64))
        self.theta_star = (None if theta_star is None
                           else np.asarray(theta_star, dtype=np.float64))
        self.free = np.asarray(free_indices(graph, include_singleton,
                                            self.family))
        self.arrivals = arrivals
        self.refit_every = max(int(refit_every), 1)
        self.newton_iters = newton_iters
        # ONE threaded key: every stochastic subsystem (arrivals, network,
        # fault draws, drift) gets an independent stream derived from the
        # one seed, so a hostile scenario replays exactly
        self.seed = int(seed)
        s_arr, s_net, s_fault, s_drift = (
            int(v) for v in np.random.SeedSequence(self.seed)
            .generate_state(4))
        self._arr_rng = np.random.RandomState(s_arr)
        self._fault_rng = np.random.RandomState(s_fault)
        self._drift_seed = s_drift

        # streamable schemes are exactly the influence-free ones (Linear-Opt
        # is excluded by design), so simulator re-fits never materialize
        # the per-sample influence stacks
        self.est = StreamingEstimator(graph, include_singleton, theta_fixed,
                                      capacity=capacity, n_iter=newton_iters,
                                      family=self.family, mesh=mesh,
                                      want_influence=False,
                                      window=window, discount=discount,
                                      recorder=self.recorder)
        links = [(i, j) for (a, b) in graph.edges for (i, j) in ((a, b),
                                                                (b, a))]
        self.net = Network(links, network or NetworkConfig(),
                           rng=np.random.RandomState(s_net),
                           recorder=self.recorder)
        # params shared between the endpoints of each directed link: exactly
        # the link's own edge-coupling block (beta_i ∩ beta_j, Sec. 3.1)
        self._shared: Dict[Tuple[int, int], List[int]] = {}
        owners = param_owners(graph, include_singleton, self.family)
        for (i, j) in links:
            self._shared[(i, j)] = sorted(
                a for a, own in owners.items()
                if {i, j} <= {node for node, _ in own})
        self._owners = owners
        # (dst, src) -> {"vals": {a: (est, weight)}, "version", "sent_round"}
        self._view: Dict[Tuple[int, int], Dict] = {}
        self._last_sent = {link: -1 for link in links}
        # per-link previous payload — what a replay attack re-injects
        self._last_payload: Dict[Tuple[int, int], Dict] = {}
        self.round = 0
        self._fed = 0

        if estimator == "admm":
            betas = [self.family.beta(graph, i, include_singleton)
                     for i in range(graph.p)]
            self._betas = betas
            self._admm_theta = [self.theta_fixed[np.asarray(b)].copy()
                                for b in betas]
            self._admm_lam = [np.zeros(len(b)) for b in betas]
            self._admm_rho = [np.full(len(b), float(admm_rho))
                              for b in betas]
            self._admm_bar = [self.theta_fixed[np.asarray(b)].copy()
                              for b in betas]

    # ---------------------------------------------------------- plan entry
    @classmethod
    def from_plan(cls, plan, pool, *, estimator: str = "one_step",
                  mesh=None, **overrides) -> "StreamSimulator":
        """Build a simulator from a declarative :class:`repro.api.Plan`.

        The plan supplies graph, family, singleton policy, fixed
        coordinates, buffer capacity, Newton budgets (``n_iter`` for
        one-step re-fits, ``admm_newton_iters``/``admm_rho`` for streaming
        ADMM), mesh policy, and the scheme — the first *streamable*
        combiner the plan requests. ``overrides`` pass through to (and win
        over) the constructor arguments, e.g. ``theta_star=``,
        ``arrivals=``, ``network=``, ``seed=``.
        """
        streamable = _one_step_schemes()
        scheme = next((n for n in plan.combiners if n in streamable), None)
        if scheme is None and estimator == "one_step":
            raise ValueError(
                f"plan requests no streamable combiner "
                f"({list(plan.combiners)}); streamable: "
                f"{list(streamable)}")
        if mesh is None and plan.mesh is not None:
            from ..api.session import _resolve_mesh
            mesh = _resolve_mesh(plan.mesh)
        kwargs = dict(
            estimator=estimator, scheme=scheme or "diagonal",
            include_singleton=plan.include_singleton,
            theta_fixed=(None if plan.theta_fixed is None
                         else np.asarray(plan.theta_fixed,
                                         dtype=np.float64)),
            newton_iters=(plan.n_iter if estimator == "one_step"
                          else plan.admm_newton_iters),
            admm_rho=plan.admm_rho, capacity=plan.capacity,
            family=plan.family_instance, mesh=mesh,
            faults=plan.faults, window=plan.stream_window,
            discount=plan.stream_discount, telemetry=plan.telemetry)
        kwargs.update(overrides)
        return cls(plan.graph, pool, **kwargs)

    # ------------------------------------------------------------- stepping
    def _down_now(self, rnd: int) -> np.ndarray:
        """(p,) crash mask for this round from the fault plan."""
        if self.faults is None or not self.faults.crashes:
            return np.zeros(self.graph.p, dtype=bool)
        return np.array([self.faults.crashed(i, rnd)
                         for i in range(self.graph.p)])

    def _apply_drift(self, spec) -> None:
        """Change-point: jump theta_star and re-draw the unseen pool tail
        from the drifted model. Keyed statelessly off the drift stream and
        the change-point round, so a restored simulator that already passed
        the change-point needs no extra RNG state."""
        key = jax.random.fold_in(jax.random.PRNGKey(self._drift_seed),
                                 spec.at)
        k_delta, k_sample = jax.random.split(key)
        delta = spec.scale * np.asarray(
            jax.random.normal(k_delta, (len(self.free),)), dtype=np.float64)
        self.theta_star = self.theta_star.copy()
        self.theta_star[self.free] += delta
        tail = len(self.pool) - self._fed
        if tail > 0:
            new = self.family.exact_sample(self.graph, self.theta_star,
                                           tail, k_sample)
            self.pool[self._fed:] = np.asarray(new, dtype=np.float32)

    def step(self) -> None:
        rnd = self.round
        p = self.graph.p
        rec = self.recorder
        span = rec.span("round", round=rnd) if rec.enabled else None
        if span is not None:
            span.__enter__()
        try:
            if self.faults is not None:
                spec = self.faults.drift_at(rnd)
                if spec is not None:
                    self._apply_drift(spec)
                    if rec.enabled:
                        rec.inc("fault.injections", 1, kind="drift",
                                round=rnd, at=spec.at)
            # 1. arrivals: reveal new environment samples to each sensor
            # (drawn for every node every round so the arrival stream does
            # not depend on the crash schedule; a crashed sensor just
            # samples none)
            draw = self.arrivals.draw(self._arr_rng, p)
            down = self._down_now(rnd)
            draw = np.where(down, 0, draw)
            if rec.enabled and self.faults is not None \
                    and self.faults.crashes:
                rec.gauge("fault.nodes_down", int(down.sum()), round=rnd)
            target = np.minimum(self.est.counts + draw, len(self.pool))
            need = int(target.max()) if p else 0
            if need > self._fed:
                self.est.extend_pool(self.pool[self._fed: need])
                self._fed = need
            self.est.advance(target)

            if self.estimator == "one_step":
                self._step_one_step(rnd, down)
            else:
                self._step_admm(rnd, down)
            self.round += 1
        finally:
            if span is not None:
                span.__exit__(None, None, None)

    def _corrupt_vals(self, spec, vals: Dict) -> Dict:
        """Byzantine outbound corruption of one message's estimates. The
        transmitted weight is untouched — a convincing liar claims its
        honest precision."""
        out = {}
        for a, (e, w) in vals.items():
            if spec.kind == "sign_flip":
                e = -e
            elif spec.kind == "scaled_noise":
                e = e + spec.scale * float(self._fault_rng.randn())
            else:                                    # fixed_value, colluding
                e = float(spec.value)
            out[a] = (e, w)
        return out

    def _step_one_step(self, rnd: int, down: np.ndarray) -> None:
        # 2. incremental warm-started re-fit on the configured cadence
        if rnd % self.refit_every == 0:
            self.est.refit()
        fits = self.est.fits
        if fits is None:
            return
        eff = self.est.effective_counts
        replay = self.faults.replay if self.faults is not None else None
        # 3. broadcast fresh shared-parameter estimates over live links
        for (i, j) in self.net.links:
            shared = self._shared[(i, j)]
            if not shared or self.est.versions[i] <= self._last_sent[(i, j)]:
                continue
            if self.est.counts[i] == 0:
                continue            # no data yet -> nothing worth sending
            if down[i] or down[j]:
                continue            # a crashed endpoint kills the link
            if not self.net.link_active(rnd, i, j):
                continue            # retry while the version stays fresh
            vals = {}
            n_i = max(float(eff[i]), 1e-12)
            for a in shared:
                pos = fits[i].beta.index(a)
                if not self._sends_weight:
                    # weights are identically 1 and not transmitted — the
                    # billed scalar count must match the information sent
                    vals[a] = (float(fits[i].theta[pos]), 1.0)
                else:
                    # weight = the *estimator's* variance V_aa / n_i, so
                    # owners with more data genuinely count for more
                    # (Prop 4.7); the asymptotic V_aa alone is O(1) in n and
                    # would weight a 10-sample sensor like a 10000-sample
                    # one. n_i is the *effective* (window/discount) mass.
                    vals[a] = (float(fits[i].theta[pos]),
                               float(fits[i].V[pos, pos]) / n_i)
            spec = (self.faults.byzantine_for(i, rnd)
                    if self.faults is not None else None)
            if spec is not None:
                vals = self._corrupt_vals(spec, vals)
                if self.recorder.enabled:
                    self.recorder.inc("fault.injections", 1,
                                      kind="byzantine", node=i,
                                      attack=spec.kind, round=rnd)
            payload = {"vals": vals, "version": int(self.est.versions[i]),
                       "sent_round": rnd}
            n_scal = one_step_message_scalars(len(shared), self.scheme)
            if self.net.send(rnd, i, j, payload, n_scal):
                # a drop is only "paid for" — the update is still owed, so
                # the link keeps retrying until a copy gets through
                self._last_sent[(i, j)] = int(self.est.versions[i])
                # replay attack: re-inject the link's PREVIOUS payload as
                # a late, stale duplicate (billed as real traffic; the
                # receiver's freshest-version-wins rule must absorb it)
                prev = self._last_payload.get((i, j))
                if replay is not None and prev is not None \
                        and self._fault_rng.rand() < replay.prob:
                    self.net.send(rnd, i, j, prev, n_scal,
                                  extra_delay=replay.delay)
                    if self.recorder.enabled:
                        self.recorder.inc("fault.injections", 1,
                                          kind="replay", src=i, dst=j,
                                          round=rnd)
                self._last_payload[(i, j)] = payload
        # 4. deliveries update the receiver's view of its peers
        self._deliver_views(rnd)

    def _step_admm(self, rnd: int, down: np.ndarray) -> None:
        # 2. one warm-started proximal primal round over the growing buffers
        masks = self.est.buffer.window_weights(self.est.counts,
                                               self.est.window,
                                               self.est.discount)
        self._admm_theta = prox_update_batched(
            self.graph, self.est.buffer.data,
            [bar for bar in self._admm_bar],
            self._admm_lam, self._admm_rho,
            thetas0=self._admm_theta,
            include_singleton=self.include_singleton,
            theta_fixed=self.theta_fixed.astype(np.float32),
            sample_weight=masks, n_iter=self.newton_iters,
            family=self.family, mesh=self.mesh)
        # NaN or runaway primal iterates (degenerate small-n prox solves)
        # would be absorbing through the warm start and the dual update —
        # reset the offending coordinates to their consensus view instead.
        self._admm_theta = [
            np.where(np.isfinite(t) & (np.abs(t) <= TRUST_RADIUS), t, b)
            for t, b in zip(self._admm_theta, self._admm_bar)]
        # 3. exchange shared coordinates
        for (i, j) in self.net.links:
            shared = self._shared[(i, j)]
            if not shared or down[i] or down[j] \
                    or not self.net.link_active(rnd, i, j):
                continue
            beta = self._betas[i]
            vals = {a: (float(self._admm_theta[i][beta.index(a)]), 1.0)
                    for a in shared}
            spec = (self.faults.byzantine_for(i, rnd)
                    if self.faults is not None else None)
            if spec is not None:
                vals = self._corrupt_vals(spec, vals)
                if self.recorder.enabled:
                    self.recorder.inc("fault.injections", 1,
                                      kind="byzantine", node=i,
                                      attack=spec.kind, round=rnd)
            payload = {"vals": vals, "version": rnd, "sent_round": rnd}
            self.net.send(rnd, i, j, payload,
                          admm_message_scalars(len(shared)))
        self._deliver_views(rnd)
        # 4. consensus averaging from possibly-stale views + dual ascent
        for i in range(self.graph.p):
            beta = self._betas[i]
            rho = self._admm_rho[i]
            for pos, a in enumerate(beta):
                own = float(self._admm_theta[i][pos])
                num = rho[pos] * own
                den = rho[pos]
                for (node, _) in self._owners[a]:
                    if node == i:
                        continue
                    view = self._view.get((i, node))
                    if view is not None and a in view["vals"]:
                        val = view["vals"][a][0]
                        if _guard(val, 1.0):
                            num += rho[pos] * val
                            den += rho[pos]
                self._admm_bar[i][pos] = num / den
            self._admm_lam[i] = self._admm_lam[i] + rho * (
                np.asarray(self._admm_theta[i]) - self._admm_bar[i])

    def _deliver_views(self, rnd: int) -> None:
        """Apply due messages to receiver views, freshest version wins;
        messages addressed to a crashed receiver are lost (delivered by the
        network, never processed)."""
        down = self._down_now(rnd)
        for msg in self.net.deliver(rnd):
            if down[msg.dst]:
                continue
            key = (msg.dst, msg.src)
            cur = self._view.get(key)
            if cur is None or msg.payload["version"] >= cur["version"]:
                self._view[key] = msg.payload

    # ------------------------------------------------------------- querying
    def current_estimate(self) -> np.ndarray:
        """Combined network estimate right now (home-sensor convention:
        each parameter is reported by its lowest-index owner, which fuses
        its own estimate with the freshest peer estimates it has
        received)."""
        theta = self.theta_fixed.copy()
        if self.estimator == "admm":
            for a, own in self._owners.items():
                home = min(node for node, _ in own)
                pos = self._betas[home].index(a)
                val = float(self._admm_bar[home][pos])
                if _guard(val, 1.0):
                    theta[a] = val
            return theta

        fits = self.est.fits
        if fits is None:
            return theta
        eff = self.est.effective_counts
        anchored = getattr(self.combiner, "anchored", False)
        rec = self.recorder
        guard_rej = robust_rej = 0
        for a, own in self._owners.items():
            home = min(node for node, _ in own)
            raw = []
            if self.est.counts[home] > 0:
                pos = fits[home].beta.index(a)
                if not self._sends_weight:
                    raw.append((float(fits[home].theta[pos]), 1.0, True))
                else:
                    raw.append((float(fits[home].theta[pos]),
                                float(fits[home].V[pos, pos])
                                / max(float(eff[home]), 1e-12), True))
            for (node, _) in own:
                if node == home:
                    continue
                view = self._view.get((home, node))
                if view is not None and a in view["vals"]:
                    e, v = view["vals"][a]
                    raw.append((e, v, False))
            # data-free owners never make it here (they are excluded at the
            # source: a count-0 node neither broadcasts nor contributes its
            # own V = 0 "infinite precision" fit); the clamp below only
            # steadies legitimate near-saturated variances, mirroring
            # the combine driver
            cands, own_index = [], None
            for (e, v, is_own) in raw:
                if _guard(e, v):
                    if is_own:
                        own_index = len(cands)
                    cands.append((e, max(v, 1e-12)))
                else:
                    guard_rej += 1
            if not cands:
                continue
            # receiver-side fusion dispatches through the combiner strategy;
            # robust (anchored) combiners additionally learn which candidate
            # is the receiver's OWN honest fit — third-party combiners with
            # the plain single-argument signature never see the keyword
            if anchored:
                theta[a] = self.combiner.combine_candidates(
                    cands, own_index=own_index)
                if rec.enabled:
                    mask = self.combiner.filter_mask(
                        cands, own_index=own_index)
                    if mask is not None:
                        robust_rej += len(cands) - int(
                            np.count_nonzero(mask))
            else:
                theta[a] = self.combiner.combine_candidates(cands)
        if rec.enabled:
            if guard_rej:
                rec.inc("combine.guard_rejections", guard_rej,
                        round=self.round)
            if robust_rej:
                rec.inc("combine.robust_rejections", robust_rej,
                        round=self.round)
        return theta

    def mean_staleness(self) -> float:
        """Mean age in rounds of the peer views backing the estimate."""
        ages = [self.round - 1 - v["sent_round"]
                for v in self._view.values()]
        return float(np.mean(ages)) if ages else 0.0

    # ------------------------------------------------------------ durability
    @staticmethod
    def _payload_to_json(payload: Dict) -> Dict:
        return {"vals": {str(a): [float(e), float(w)]
                         for a, (e, w) in payload["vals"].items()},
                "version": int(payload["version"]),
                "sent_round": int(payload["sent_round"])}

    @staticmethod
    def _payload_from_json(d: Dict) -> Dict:
        return {"vals": {int(a): (float(ew[0]), float(ew[1]))
                         for a, ew in d["vals"].items()},
                "version": int(d["version"]),
                "sent_round": int(d["sent_round"])}

    def state_dict(self) -> Tuple[Dict[str, np.ndarray], Dict]:
        """Complete mid-stream state as (arrays, json_meta): estimator bank
        (pool buffer, prefix counts, warm starts, fitted LocalFits),
        environment pool and (possibly drifted) truth, per-link owed
        versions and last payloads, received peer views, in-flight network
        queue, bandwidth counters, and every RandomState. A fresh simulator
        constructed with the same configuration + :meth:`load_state`
        continues bit-identically — crash/Byzantine/drift activation is
        derived from ``faults`` and the restored round, and drift keys are
        stateless, so no fault bookkeeping beyond the RNG states is
        needed. See :func:`repro.checkpoint.save_stream`."""
        arrays, meta = self.est.state_dict()
        arrays = dict(arrays)
        arrays["sim/pool"] = self.pool.copy()
        if self.theta_star is not None:
            arrays["sim/theta_star"] = self.theta_star.copy()
        if self.estimator == "admm":
            for i in range(self.graph.p):
                arrays[f"sim/admm_theta_{i}"] = np.asarray(
                    self._admm_theta[i])
                arrays[f"sim/admm_lam_{i}"] = np.asarray(self._admm_lam[i])
                arrays[f"sim/admm_bar_{i}"] = np.asarray(self._admm_bar[i])
        meta.update({
            "round": int(self.round),
            "fed": int(self._fed),
            "seed": self.seed,
            "scheme": self.scheme,
            "estimator": self.estimator,
            "last_sent": [[int(i), int(j), int(v)]
                          for (i, j), v in self._last_sent.items()],
            "last_payload": [[int(i), int(j), self._payload_to_json(p)]
                             for (i, j), p in self._last_payload.items()],
            "views": [[int(dst), int(src), self._payload_to_json(p)]
                      for (dst, src), p in self._view.items()],
            "arr_rng": rng_state_to_json(self._arr_rng),
            "fault_rng": rng_state_to_json(self._fault_rng),
            "net_rng": rng_state_to_json(self.net._rng),
            "net_counters": self.net.counters_dict(),
            "net_queue": [[int(m.src), int(m.dst),
                           self._payload_to_json(m.payload),
                           int(m.n_scalars), int(m.created),
                           int(m.deliver_at)] for m in self.net._queue],
        })
        return arrays, meta

    def load_state(self, arrays: Dict[str, np.ndarray],
                   meta: Dict) -> None:
        """Inverse of :meth:`state_dict`, in place, on a simulator
        constructed with the same configuration (graph, pool shape,
        scheme, faults, network config, seed)."""
        if meta["scheme"] != self.scheme \
                or meta["estimator"] != self.estimator:
            raise ValueError(
                f"checkpoint was written by a "
                f"{meta['estimator']}/{meta['scheme']} simulator; this one "
                f"is {self.estimator}/{self.scheme}")
        self.est.load_state(arrays, meta)
        self.pool = np.asarray(arrays["sim/pool"]).copy()
        if "sim/theta_star" in arrays:
            self.theta_star = np.asarray(arrays["sim/theta_star"]).copy()
        if self.estimator == "admm":
            self._admm_theta = [np.asarray(
                arrays[f"sim/admm_theta_{i}"]).copy()
                for i in range(self.graph.p)]
            self._admm_lam = [np.asarray(arrays[f"sim/admm_lam_{i}"]).copy()
                              for i in range(self.graph.p)]
            self._admm_bar = [np.asarray(arrays[f"sim/admm_bar_{i}"]).copy()
                              for i in range(self.graph.p)]
        self.round = int(meta["round"])
        self._fed = int(meta["fed"])
        self._last_sent = {(int(i), int(j)): int(v)
                           for i, j, v in meta["last_sent"]}
        self._last_payload = {(int(i), int(j)): self._payload_from_json(p)
                              for i, j, p in meta["last_payload"]}
        self._view = {(int(dst), int(src)): self._payload_from_json(p)
                      for dst, src, p in meta["views"]}
        rng_state_from_json(self._arr_rng, meta["arr_rng"])
        rng_state_from_json(self._fault_rng, meta["fault_rng"])
        rng_state_from_json(self.net._rng, meta["net_rng"])
        self.net.set_counters(meta["net_counters"])
        from .network import Message
        self.net._queue = [
            Message(src=int(s), dst=int(d),
                    payload=self._payload_from_json(p), n_scalars=int(n),
                    created=int(c), deliver_at=int(at))
            for s, d, p, n, c, at in meta["net_queue"]]

    # ------------------------------------------------------------ trajectory
    def run(self, rounds: int, record_every: int = 1,
            record_score: bool = False) -> StreamResult:
        # the estimate the network reports as recording starts — for a
        # fresh simulator this is theta_fixed; StreamResult.estimate_at
        # answers queries earlier than the first recorded round with it
        initial = self.current_estimate()
        tel = self.recorder
        mark = tel.mark()
        span = tel.span("stream", rounds=rounds) if tel.enabled else None
        if span is not None:
            span.__enter__()
        try:
            recs: List[dict] = []
            for r in range(rounds):
                self.step()
                if (r + 1) % record_every == 0 or r == rounds - 1:
                    theta = self.current_estimate()
                    rec = {
                        "round": self.round,
                        "theta": theta,
                        "seen": float(self.est.counts.mean()),
                        "total": int(self.est.counts.sum()),
                        "scalars": int(self.net.scalars_sent),
                        "stale": self.mean_staleness(),
                    }
                    if self.theta_star is not None:
                        d = (theta - self.theta_star)[self.free]
                        rec["err"] = float(d @ d)
                    if record_score:
                        rec["score"] = self.est.score_norm(theta)
                    recs.append(rec)
                    if tel.enabled:
                        # any-time timeline samples: same values, same
                        # rounds as the recorded columns, so timeline()
                        # from a snapshot or a JSONL replay is exact
                        tel.point("scalars_sent", self.round,
                                  rec["scalars"])
                        tel.point("samples_seen", self.round, rec["seen"])
                        tel.point("staleness", self.round, rec["stale"])
                        if "err" in rec:
                            tel.point("err", self.round, rec["err"])
                        if "score" in rec:
                            tel.point("score_norm", self.round,
                                      rec["score"])
        finally:
            if span is not None:
                span.__exit__(None, None, None)
        tel.flush()
        return StreamResult(
            rounds=np.array([r["round"] for r in recs]),
            theta=np.stack([r["theta"] for r in recs]),
            samples_seen=np.array([r["seen"] for r in recs]),
            samples_total=np.array([r["total"] for r in recs]),
            scalars_sent=np.array([r["scalars"] for r in recs]),
            err=(np.array([r["err"] for r in recs])
                 if self.theta_star is not None else None),
            score_norm=(np.array([r["score"] for r in recs])
                        if record_score else None),
            staleness=np.array([r["stale"] for r in recs]),
            initial=initial,
            telemetry=tel.snapshot(mark) if tel.enabled else None)
