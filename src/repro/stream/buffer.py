"""Shape-stable growing sample buffer for streaming estimation.

JAX recompiles per distinct array shape, so a naive "re-fit on X[:n]" stream
pays one XLA compile per sample count. The buffer instead zero-pads to a
capacity that doubles on overflow: every consumer sees a (capacity, p) array
whose shape changes only O(log n) times over the whole stream, and expresses
"only the first n rows are real" with 0/1 prefix masks (which the batched
engine and the fused score kernel treat exactly — see
``repro.core.batched`` and ``repro.kernels.cl.score``).
"""
from __future__ import annotations

import numpy as np


class SampleBuffer:
    """Append-only (capacity, p) sample store with power-of-two growth."""

    def __init__(self, p: int, capacity: int = 64,
                 dtype=np.float32) -> None:
        if capacity < 1:
            raise ValueError("capacity must be positive")
        self._X = np.zeros((int(capacity), int(p)), dtype=dtype)
        self.n = 0

    @property
    def p(self) -> int:
        return self._X.shape[1]

    @property
    def capacity(self) -> int:
        return self._X.shape[0]

    @property
    def data(self) -> np.ndarray:
        """The full zero-padded (capacity, p) array (live view, do not
        mutate)."""
        return self._X

    @property
    def rows(self) -> np.ndarray:
        """Only the real samples, shape (n, p)."""
        return self._X[: self.n]

    def append(self, rows) -> None:
        rows = np.asarray(rows, dtype=self._X.dtype)
        if rows.ndim == 1:
            rows = rows[None, :]
        if rows.shape[1] != self.p:
            raise ValueError(f"expected {self.p} columns, got {rows.shape}")
        need = self.n + rows.shape[0]
        cap = self.capacity
        if need > cap:
            while cap < need:
                cap *= 2
            grown = np.zeros((cap, self.p), dtype=self._X.dtype)
            grown[: self.n] = self._X[: self.n]
            self._X = grown
        self._X[self.n: need] = rows
        self.n = need

    def prefix_masks(self, counts: np.ndarray) -> np.ndarray:
        """(len(counts), capacity) 0/1 masks: row i covers the first
        ``counts[i]`` samples. This is how heterogeneous per-sensor arrival
        counts over the shared pool reach the weighted batched engine."""
        counts = np.asarray(counts, dtype=np.int64)
        if np.any(counts > self.n):
            raise ValueError("count exceeds samples in buffer")
        idx = np.arange(self.capacity, dtype=np.int64)
        return (idx[None, :] < counts[:, None]).astype(np.float32)

    def window_weights(self, counts: np.ndarray,
                       window=None, discount=None) -> np.ndarray:
        """(len(counts), capacity) per-row fit weights over the pool.

        The drift-tracking generalization of :meth:`prefix_masks`: with
        both knobs None this IS the 0/1 prefix mask; ``window`` keeps only
        each node's most recent ``window`` observed rows (sliding window);
        ``discount`` in (0, 1) down-weights age — a node's newest row
        weighs 1 and its age-k row ``discount**k`` (exponential
        forgetting). The two compose. Weighted re-fits through the batched
        engine then estimate the *recent* parameter, which is what tracks
        a drifting truth.
        """
        w = self.prefix_masks(counts)
        counts = np.asarray(counts, dtype=np.int64)
        idx = np.arange(self.capacity, dtype=np.int64)
        if window is not None:
            w = w * (idx[None, :] >= counts[:, None] - int(window))
        if discount is not None and discount < 1.0:
            age = np.maximum(counts[:, None] - 1 - idx[None, :], 0)
            w = w * (float(discount) ** age)
        return w.astype(np.float32)
