"""Declarative fault injection for the streaming simulator.

A :class:`FaultPlan` is the frozen, hashable, JSON-serializable description
of one hostile-network scenario — who crashes when, who lies on the wire
and how, whether stale messages get replayed, and when the environment's
true parameter drifts. The :class:`~repro.stream.simulator.StreamSimulator`
*executes* the plan; every random draw it requires (noise lies, replay
coin-flips, drift perturbations) comes from the simulator's single threaded
PRNG key, so a hostile scenario is exactly as reproducible as a clean one.

Fault semantics (the "liar on the wire" model):

* **crash** — a crashed sensor stops sampling, stops transmitting, and
  loses messages addressed to it while down; its last local fit persists
  (the home sensor keeps reporting its stale view). On ``restart_at`` the
  node resumes with its buffer intact — a process restart, not data loss.
* **byzantine** — corruption applies to *outbound messages only*: the
  node's own local estimation stays honest (its sensor hardware works; its
  network stack lies). This matches the pseudo-likelihood setting, where
  each edge block has exactly two owners — a corrupted *home* fit would
  exceed every symmetric breakdown point, so the meaningful defense is the
  receiver anchoring robust fusion on its own honest fit (see the
  ``trimmed_mean`` / ``krum`` combiners).
* **replay** — after a successful send, an adversary may re-inject the
  link's *previous* payload with extra delay: a stale, duplicated message.
  Replayed copies spend real bandwidth (they are billed as sent scalars)
  and are deduplicated receiver-side by the freshest-version-wins rule.
* **drift** — at each change-point the environment's true parameter jumps
  by a random perturbation and the *unseen* remainder of the sample pool
  is re-drawn from the drifted model; already-observed samples keep their
  original distribution. Sliding/discounted buffer windows (``window`` /
  ``discount`` on the estimator) are the tracking response.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

#: outbound-message corruption models a Byzantine node may run
BYZANTINE_KINDS = ("sign_flip", "scaled_noise", "fixed_value")


def _require_nonneg_int(value, what: str) -> int:
    iv = int(value)
    if iv < 0:
        raise ValueError(f"{what} must be a round index >= 0, got {value!r}")
    return iv


@dataclasses.dataclass(frozen=True)
class CrashSpec:
    """Node ``node`` is down during rounds [``at``, ``restart_at``).

    ``restart_at=None`` means it never comes back.
    """
    node: int
    at: int
    restart_at: Optional[int] = None

    def __post_init__(self):
        _require_nonneg_int(self.at, "crash time 'at'")
        if self.node < 0:
            raise ValueError(f"crash node must be >= 0, got {self.node!r}")
        if self.restart_at is not None and self.restart_at <= self.at:
            raise ValueError(
                f"restart_at ({self.restart_at!r}) must be strictly after "
                f"the crash round at={self.at!r}")

    def down(self, rnd: int) -> bool:
        return self.at <= rnd and (self.restart_at is None
                                   or rnd < self.restart_at)


@dataclasses.dataclass(frozen=True)
class ByzantineSpec:
    """Node ``node`` corrupts every outbound estimate from round ``start``.

    kind — "sign_flip" (sends -estimate), "scaled_noise" (adds
    ``scale``-sized Gaussian noise per transmitted scalar), or
    "fixed_value" (sends the colluding constant ``value`` for every
    parameter — several nodes with the same ``value`` collude exactly).
    """
    node: int
    kind: str = "sign_flip"
    start: int = 0
    scale: float = 5.0
    value: float = 3.0

    def __post_init__(self):
        if self.kind not in BYZANTINE_KINDS:
            raise ValueError(
                f"unknown byzantine kind {self.kind!r}; choose from "
                f"{list(BYZANTINE_KINDS)}")
        _require_nonneg_int(self.start, "byzantine start")
        if self.node < 0:
            raise ValueError(f"byzantine node must be >= 0, "
                             f"got {self.node!r}")
        if not np.isfinite(self.scale):
            raise ValueError(f"byzantine scale must be finite, "
                             f"got {self.scale!r}")
        if not np.isfinite(self.value):
            raise ValueError(f"byzantine value must be finite, "
                             f"got {self.value!r}")

    def active(self, rnd: int) -> bool:
        return rnd >= self.start


@dataclasses.dataclass(frozen=True)
class ReplaySpec:
    """After each successful send, replay the link's previous payload with
    probability ``prob``, arriving ``delay`` extra rounds late."""
    prob: float = 0.25
    delay: int = 3

    def __post_init__(self):
        if not (0.0 <= self.prob <= 1.0):
            raise ValueError(
                f"replay prob must be a probability in [0, 1], "
                f"got {self.prob!r}")
        if self.delay < 1:
            raise ValueError(f"replay delay must be >= 1 round "
                             f"(0 would not be stale), got {self.delay!r}")


@dataclasses.dataclass(frozen=True)
class DriftSpec:
    """At round ``at`` the true parameter jumps by a ``scale``-sized random
    perturbation on the free coordinates and unseen pool samples are
    re-drawn from the drifted model."""
    at: int
    scale: float = 0.5

    def __post_init__(self):
        _require_nonneg_int(self.at, "drift change-point 'at'")
        if not (np.isfinite(self.scale) and self.scale >= 0.0):
            raise ValueError(f"drift scale must be finite and >= 0, "
                             f"got {self.scale!r}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One hostile scenario: crash schedules, Byzantine corruption,
    message replay, parameter drift. Frozen and hashable, so a
    :class:`repro.api.Plan` carrying one still keys the session cache."""
    crashes: Tuple[CrashSpec, ...] = ()
    byzantine: Tuple[ByzantineSpec, ...] = ()
    replay: Optional[ReplaySpec] = None
    drift: Tuple[DriftSpec, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "crashes", tuple(self.crashes))
        object.__setattr__(self, "byzantine", tuple(self.byzantine))
        object.__setattr__(self, "drift", tuple(self.drift))
        for c in self.crashes:
            if not isinstance(c, CrashSpec):
                raise TypeError(f"crashes entries must be CrashSpec, "
                                f"got {type(c).__name__}")
        for b in self.byzantine:
            if not isinstance(b, ByzantineSpec):
                raise TypeError(f"byzantine entries must be ByzantineSpec, "
                                f"got {type(b).__name__}")
        for d in self.drift:
            if not isinstance(d, DriftSpec):
                raise TypeError(f"drift entries must be DriftSpec, "
                                f"got {type(d).__name__}")
        if self.replay is not None and not isinstance(self.replay,
                                                      ReplaySpec):
            raise TypeError(f"replay must be a ReplaySpec, "
                            f"got {type(self.replay).__name__}")

    # ------------------------------------------------------------- queries
    def crashed(self, node: int, rnd: int) -> bool:
        return any(c.node == node and c.down(rnd) for c in self.crashes)

    def byzantine_for(self, node: int, rnd: int) -> Optional[ByzantineSpec]:
        for b in self.byzantine:
            if b.node == node and b.active(rnd):
                return b
        return None

    def drift_at(self, rnd: int) -> Optional[DriftSpec]:
        for d in self.drift:
            if d.at == rnd:
                return d
        return None

    @property
    def empty(self) -> bool:
        return not (self.crashes or self.byzantine or self.drift
                    or self.replay is not None)

    # ------------------------------------------------------ serialization
    def to_dict(self) -> dict:
        """Plain-JSON representation; exact inverse of :meth:`from_dict`."""
        return {
            "crashes": [dataclasses.asdict(c) for c in self.crashes],
            "byzantine": [dataclasses.asdict(b) for b in self.byzantine],
            "replay": (None if self.replay is None
                       else dataclasses.asdict(self.replay)),
            "drift": [dataclasses.asdict(d) for d in self.drift],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        rep = d.get("replay")
        return cls(
            crashes=tuple(CrashSpec(**c) for c in d.get("crashes", ())),
            byzantine=tuple(ByzantineSpec(**b)
                            for b in d.get("byzantine", ())),
            replay=None if rep is None else ReplaySpec(**rep),
            drift=tuple(DriftSpec(**s) for s in d.get("drift", ())),
        )
