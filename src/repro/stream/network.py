"""Message-network model: per-round link schedules, drops, delivery delay.

Communication in the simulator is explicit: every estimate that moves
between sensors is a :class:`Message` with a scalar count, pushed through a
:class:`Network` that may refuse the link this round (gossip schedules),
drop the message outright, or delay delivery by a fixed latency plus random
jitter — the staleness/asynchrony regime of dynamic-consensus estimation
(George 2018; Rahimian & Jadbabaie 2016). All randomness comes from one
seeded generator consumed in deterministic iteration order, so a simulation
is exactly reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class NetworkConfig:
    """Network behavior knobs.

    drop_prob — probability a sent message never arrives (bandwidth is still
      spent: dropped messages count toward scalars_sent).
    delay — fixed delivery latency in rounds (0 = arrives the same round).
    jitter — extra uniform random latency in {0, ..., jitter}.
    link_prob — per-round probability a directed link is usable at all
      (asynchronous gossip schedules; refusal costs no bandwidth).
    seed — None (the default) lets an owner inject its generator (the
      simulator threads one from its own key); an explicit int pins a
      private legacy ``RandomState(seed)`` regardless of injection.
    """
    drop_prob: float = 0.0
    delay: int = 0
    jitter: int = 0
    link_prob: float = 1.0
    seed: Optional[int] = None


@dataclasses.dataclass
class Message:
    src: int
    dst: int
    payload: Any
    n_scalars: int
    created: int      # round the message was sent
    deliver_at: int   # round it becomes visible at dst


class Network:
    """Directed links with exact bandwidth accounting and a delivery queue."""

    def __init__(self, links: Sequence[Tuple[int, int]],
                 config: NetworkConfig = NetworkConfig(),
                 rng: Optional[np.random.RandomState] = None,
                 recorder=None) -> None:
        from ..telemetry.recorder import NULL_RECORDER
        self.links = tuple(links)
        self._link_set = set(self.links)
        self.config = config
        #: telemetry recorder; when live, every message transition is
        #: logged as a ``net.send`` / ``net.drop`` / ``net.deliver``
        #: counter event valued at its scalar count, so a JSONL log
        #: replays the exact bandwidth ledger (repro.telemetry.replay)
        self.recorder = NULL_RECORDER if recorder is None else recorder
        if config.seed is not None:
            self._rng = np.random.RandomState(config.seed)
        elif rng is not None:
            self._rng = rng
        else:
            self._rng = np.random.RandomState(0)
        self._queue: List[Message] = []
        self.msgs_sent = 0
        self.msgs_dropped = 0
        self.msgs_delivered = 0
        self.scalars_sent = 0
        self.scalars_dropped = 0
        self.scalars_delivered = 0

    def link_active(self, rnd: int, src: int, dst: int) -> bool:
        """Whether the (src, dst) link is schedulable this round."""
        if (src, dst) not in self._link_set:
            return False
        if self.config.link_prob >= 1.0:
            return True
        return bool(self._rng.rand() < self.config.link_prob)

    def send(self, rnd: int, src: int, dst: int, payload: Any,
             n_scalars: int, extra_delay: int = 0) -> bool:
        """Transmit; returns False if the message was dropped in flight.
        ``extra_delay`` adds rounds of latency on top of the configured
        delay/jitter (replayed stale copies arrive late by construction)."""
        self.msgs_sent += 1
        self.scalars_sent += int(n_scalars)
        rec = self.recorder
        if rec.enabled:
            rec.inc("net.send", int(n_scalars), src=src, dst=dst, round=rnd)
        if self.config.drop_prob > 0.0 and \
                self._rng.rand() < self.config.drop_prob:
            self.msgs_dropped += 1
            self.scalars_dropped += int(n_scalars)
            if rec.enabled:
                rec.inc("net.drop", int(n_scalars), src=src, dst=dst,
                        round=rnd)
            return False
        lat = self.config.delay + int(extra_delay)
        if self.config.jitter > 0:
            lat += int(self._rng.randint(self.config.jitter + 1))
        self._queue.append(Message(src=src, dst=dst, payload=payload,
                                   n_scalars=int(n_scalars), created=rnd,
                                   deliver_at=rnd + lat))
        return True

    def deliver(self, rnd: int) -> List[Message]:
        """Pop every message due by round ``rnd``, in deterministic order."""
        due = [m for m in self._queue if m.deliver_at <= rnd]
        self._queue = [m for m in self._queue if m.deliver_at > rnd]
        due.sort(key=lambda m: (m.deliver_at, m.created, m.src, m.dst))
        self.msgs_delivered += len(due)
        self.scalars_delivered += sum(m.n_scalars for m in due)
        if self.recorder.enabled:
            for m in due:
                self.recorder.inc("net.deliver", m.n_scalars, src=m.src,
                                  dst=m.dst, round=rnd, created=m.created)
        return due

    @property
    def in_flight(self) -> int:
        return len(self._queue)

    @property
    def scalars_in_flight(self) -> int:
        return sum(m.n_scalars for m in self._queue)

    # --------------------------------------------------------- durability
    _COUNTERS = ("msgs_sent", "msgs_dropped", "msgs_delivered",
                 "scalars_sent", "scalars_dropped", "scalars_delivered")

    def counters_dict(self) -> dict:
        return {k: int(getattr(self, k)) for k in self._COUNTERS}

    def set_counters(self, counters: dict) -> None:
        for k in self._COUNTERS:
            setattr(self, k, int(counters[k]))


def rng_state_to_json(rng: np.random.RandomState) -> list:
    """A RandomState's full MT19937 state as plain JSON values. Every entry
    round-trips exactly: the key vector is uint32 ints, and json keeps the
    cached gaussian's float64 repr."""
    kind, keys, pos, has_gauss, cached = rng.get_state()
    return [kind, [int(v) for v in keys], int(pos), int(has_gauss),
            float(cached)]


def rng_state_from_json(rng: np.random.RandomState, state: list) -> None:
    kind, keys, pos, has_gauss, cached = state
    rng.set_state((kind, np.asarray(keys, dtype=np.uint32), int(pos),
                   int(has_gauss), float(cached)))
