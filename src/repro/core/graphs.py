"""Graph structures for pairwise graphical models / sensor networks.

A ``Graph`` is an immutable container of ``p`` nodes and undirected edges
``(i, j)`` with ``i < j``. The flat parameter vector for an Ising model on a
graph is ordered ``[theta_1..theta_p, theta_e1..theta_em]`` (singletons first,
then edges in ``graph.edges`` order); see :mod:`repro.core.ising`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import numpy as np

Edge = Tuple[int, int]


@dataclasses.dataclass(frozen=True)
class Graph:
    p: int
    edges: Tuple[Edge, ...]

    def __post_init__(self):
        seen = set()
        for (i, j) in self.edges:
            if not (0 <= i < j < self.p):
                raise ValueError(f"bad edge ({i},{j}) for p={self.p}")
            if (i, j) in seen:
                raise ValueError(f"duplicate edge ({i},{j})")
            seen.add((i, j))

    # ---- derived structure (cached via object.__setattr__ lazily) ----
    @property
    def m(self) -> int:
        return len(self.edges)

    @property
    def n_params(self) -> int:
        """Size of flat parameter vector: singletons + edges."""
        return self.p + self.m

    @property
    def adjacency(self) -> np.ndarray:
        A = np.zeros((self.p, self.p), dtype=np.float32)
        for (i, j) in self.edges:
            A[i, j] = A[j, i] = 1.0
        return A

    @property
    def edge_index(self) -> Dict[Edge, int]:
        """Edge -> position in the edge block of the flat parameter vector."""
        return {e: k for k, e in enumerate(self.edges)}

    def neighbors(self, i: int) -> List[int]:
        out = []
        for (a, b) in self.edges:
            if a == i:
                out.append(b)
            elif b == i:
                out.append(a)
        return sorted(out)

    def degree(self, i: int) -> int:
        return len(self.neighbors(i))

    def incident_edges(self, i: int) -> List[int]:
        """Edge-block indices of edges touching node i (in edges order)."""
        return [k for k, (a, b) in enumerate(self.edges) if i in (a, b)]

    def beta(self, i: int, include_singleton: bool = True) -> List[int]:
        """Flat-parameter indices in beta_i = {alpha : i in alpha}.

        With ``include_singleton=False`` (the paper's known-singleton small
        experiments) only incident-edge parameters are returned.
        """
        idx = [i] if include_singleton else []
        idx += [self.p + k for k in self.incident_edges(i)]
        return idx

    def greedy_coloring(self) -> np.ndarray:
        """Proper vertex coloring by greedy largest-degree-first assignment.

        Returns a (p,) int array of color ids in [0, n_colors). Nodes of the
        same color are mutually non-adjacent, so a Gibbs sweep may update a
        whole color class in parallel (chromatic Gibbs). Cached per graph
        (graphs are frozen); callers in sampler replicate loops hit the
        cache instead of redoing the Python sweep.
        """
        return _greedy_coloring_cached(self).copy()


@functools.lru_cache(maxsize=64)
def _greedy_coloring_cached(graph: Graph) -> np.ndarray:
    nbrs = {i: set() for i in range(graph.p)}
    for (a, b) in graph.edges:
        nbrs[a].add(b)
        nbrs[b].add(a)
    colors = np.full(graph.p, -1, dtype=np.int64)
    order = sorted(range(graph.p), key=lambda i: -len(nbrs[i]))
    for i in order:
        used = {colors[j] for j in nbrs[i] if colors[j] >= 0}
        c = 0
        while c in used:
            c += 1
        colors[i] = c
    return colors


# ---------------------------------------------------------------- factories
def chain_graph(p: int) -> Graph:
    return Graph(p, tuple((i, i + 1) for i in range(p - 1)))


def star_graph(p: int) -> Graph:
    """Node 0 is the hub; nodes 1..p-1 are leaves."""
    return Graph(p, tuple((0, i) for i in range(1, p)))


def grid_graph(rows: int, cols: int) -> Graph:
    edges = []
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            if c + 1 < cols:
                edges.append((i, i + 1))
            if r + 1 < rows:
                edges.append((i, i + cols))
    return Graph(rows * cols, tuple(sorted(set(edges))))


def complete_graph(p: int) -> Graph:
    return Graph(p, tuple((i, j) for i in range(p) for j in range(i + 1, p)))


def scale_free_graph(p: int, m: int = 1, seed: int = 0) -> Graph:
    """Barabasi-Albert preferential attachment (Barabasi & Albert, 1999)."""
    rng = np.random.RandomState(seed)
    edges = set()
    degrees = np.zeros(p, dtype=np.int64)
    # seed clique of m+1 nodes
    for i in range(m + 1):
        for j in range(i + 1, m + 1):
            edges.add((i, j))
            degrees[i] += 1
            degrees[j] += 1
    for new in range(m + 1, p):
        targets = set()
        while len(targets) < m:
            probs = degrees[:new] / degrees[:new].sum()
            t = int(rng.choice(new, p=probs))
            targets.add(t)
        for t in targets:
            edges.add((min(t, new), max(t, new)))
            degrees[t] += 1
            degrees[new] += 1
    return Graph(p, tuple(sorted(edges)))


def euclidean_graph(p: int, radius: float = 0.15, seed: int = 0) -> Graph:
    """Random geometric graph on [0,1]^2 connecting nodes within ``radius``."""
    rng = np.random.RandomState(seed)
    pts = rng.rand(p, 2)
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    edges = tuple(
        (i, j) for i in range(p) for j in range(i + 1, p)
        if d2[i, j] <= radius ** 2
    )
    return Graph(p, edges)
