"""Pairwise Ising models in exponential-family form (paper Sec. 2.1, Sec. 5).

    p(x | theta) = exp( sum_{(ij) in E} theta_ij x_i x_j
                        + sum_i theta_i x_i - log Z(theta) ),   x in {-1,+1}^p

The flat parameter vector is ordered [singletons (p), edges (m)], matching
``Graph`` conventions. All dense math is jnp so estimators can be jitted and
autodiffed; exact enumeration utilities are provided for small ``p``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graphs import Graph


@dataclasses.dataclass(frozen=True)
class IsingModel:
    graph: Graph
    theta: jnp.ndarray  # flat (p + m,)

    @property
    def theta_single(self) -> jnp.ndarray:
        return self.theta[: self.graph.p]

    @property
    def theta_edges(self) -> jnp.ndarray:
        return self.theta[self.graph.p:]


def random_model(graph: Graph, sigma_pair: float, sigma_single: float,
                 key: jax.Array) -> IsingModel:
    """theta_ij ~ N(0, sigma_pair), theta_i ~ N(0, sigma_single) (Sec. 5)."""
    k1, k2 = jax.random.split(key)
    ts = sigma_single * jax.random.normal(k1, (graph.p,))
    te = sigma_pair * jax.random.normal(k2, (graph.m,))
    return IsingModel(graph, jnp.concatenate([ts, te]))


# ----------------------------------------------------------------- helpers
def pair_matrix(graph: Graph, theta_edges: jnp.ndarray) -> jnp.ndarray:
    """Symmetric (p, p) coupling matrix from the edge block."""
    rows = np.array([e[0] for e in graph.edges], dtype=np.int32)
    cols = np.array([e[1] for e in graph.edges], dtype=np.int32)
    T = jnp.zeros((graph.p, graph.p), dtype=theta_edges.dtype)
    T = T.at[rows, cols].set(theta_edges)
    T = T.at[cols, rows].set(theta_edges)
    return T


def conditional_logits(graph: Graph, theta: jnp.ndarray,
                       X: jnp.ndarray) -> jnp.ndarray:
    """eta_i(x) = theta_i + sum_{j in N(i)} theta_ij x_j for each sample.

    X: (n, p) in {-1, +1}. Returns (n, p). p(x_i=+1 | x_N(i)) = sigmoid(2 eta_i).
    """
    p = graph.p
    T = pair_matrix(graph, theta[p:])
    return X @ T + theta[:p][None, :]


def cond_loglik(graph: Graph, theta: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """Per-node conditional log-likelihood log p(x_i | x_N(i)); (n, p)."""
    eta = conditional_logits(graph, theta, X)
    return jax.nn.log_sigmoid(2.0 * X * eta)


def pseudo_loglik(graph: Graph, theta: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """Average pseudo-likelihood (Eq. 2): mean over samples, summed over nodes."""
    return jnp.mean(jnp.sum(cond_loglik(graph, theta, X), axis=1))


# ------------------------------------------------------- exact enumeration
def all_states(p: int) -> np.ndarray:
    """(2^p, p) array of all {-1, +1} configurations."""
    grid = ((np.arange(2 ** p)[:, None] >> np.arange(p)[None, :]) & 1)
    return (2.0 * grid - 1.0).astype(np.float32)


def suff_stats(graph: Graph, X: jnp.ndarray) -> jnp.ndarray:
    """u(x) = [x_1..x_p, x_i x_j for (ij) in E]; (n, p+m)."""
    rows = np.array([e[0] for e in graph.edges], dtype=np.int32)
    cols = np.array([e[1] for e in graph.edges], dtype=np.int32)
    pair = X[:, rows] * X[:, cols] if graph.m else jnp.zeros((X.shape[0], 0), X.dtype)
    return jnp.concatenate([X, pair], axis=1)


def log_partition(graph: Graph, theta: jnp.ndarray) -> jnp.ndarray:
    """Exact log Z by enumeration; only for small p."""
    U = suff_stats(graph, jnp.asarray(all_states(graph.p)))
    return jax.scipy.special.logsumexp(U @ theta)


def exact_probs(graph: Graph, theta: jnp.ndarray) -> jnp.ndarray:
    U = suff_stats(graph, jnp.asarray(all_states(graph.p)))
    s = U @ theta
    return jax.nn.softmax(s)


def loglik(graph: Graph, theta: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """Average exact log-likelihood (small p only)."""
    U = suff_stats(graph, X)
    return jnp.mean(U @ theta) - log_partition(graph, theta)


def exact_moments(graph: Graph, theta: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(E[u], cov(u)) under p(x|theta) — cov(u) is the full-model Fisher."""
    U = suff_stats(graph, jnp.asarray(all_states(graph.p)))
    pr = exact_probs(graph, theta)
    mu = pr @ U
    centered = U - mu[None, :]
    cov = (centered * pr[:, None]).T @ centered
    return mu, cov
