"""Exact asymptotic analysis (paper Sec. 4): per-node information matrices,
influence functions s^i, cross-estimator covariances, and the asymptotic
variance of every consensus scheme — all computed by enumeration at theta*.

Only usable for small p (2^p states); the paper's small-model experiments
(star graphs, 4x4 grid) use exactly this machinery.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .estimators import node_cl_fn
from .graphs import Graph
from .ising import IsingModel, all_states, exact_moments, exact_probs


@dataclasses.dataclass
class ExactLocal:
    """Population quantities of node i's CL estimator at theta*."""
    i: int
    beta: List[int]     # flat param indices
    H: np.ndarray       # (d, d) -E[grad^2 l^i(theta*)]
    V: np.ndarray       # (d, d) sandwich Hinv J Hinv (= Hinv, info-unbiased)
    S: np.ndarray       # (2^p, d) influence s^i(x) = Hinv grad l^i(theta*, x)
    probs: np.ndarray   # (2^p,) state probabilities


def exact_local(model: IsingModel, i: int,
                include_singleton: bool = True) -> ExactLocal:
    graph = model.graph
    states = jnp.asarray(all_states(graph.p))
    probs = exact_probs(graph, model.theta)
    fun_all, d = node_cl_fn(graph, states, i, include_singleton, model.theta)
    # true local parameter sub-vector
    beta = graph.beta(i, include_singleton)
    w_star = model.theta[np.asarray(beta)]

    # fun_all averages over *all states uniformly*; we need prob-weighted.
    # Build a per-state criterion instead.
    def per_state(w):
        # returns (2^p,) node-i conditional loglik per state
        from .ising import cond_loglik
        theta = model.theta.at[np.asarray(beta)].set(w)
        return cond_loglik(graph, theta, states)[:, i]

    Gfn = jax.jacfwd(per_state)          # (2^p, d)
    G = Gfn(w_star)
    exp_fn = lambda w: probs @ per_state(w)
    H = -jax.hessian(exp_fn)(w_star)
    J = (G * probs[:, None]).T @ G       # E[g g^T]; E[g] = 0 at theta*
    Hinv = jnp.linalg.inv(H)
    V = Hinv @ J @ Hinv
    S = G @ Hinv.T
    return ExactLocal(i=i, beta=beta, H=np.asarray(H), V=np.asarray(V),
                      S=np.asarray(S), probs=np.asarray(probs))


def exact_locals(model: IsingModel,
                 include_singleton: bool = True) -> List[ExactLocal]:
    return [exact_local(model, i, include_singleton)
            for i in range(model.graph.p)]


# --------------------------------------------------------------- ownership
def param_owners(graph: Graph, include_singleton: bool = True,
                 family=None) -> Dict[int, List[Tuple[int, int]]]:
    """flat param index -> [(node i, position of that param in beta_i)].

    With a ``family``, ownership is over parameter *blocks*: every scalar
    of a node block is owned by its node, every scalar of an edge block by
    both endpoints, and positions follow ``family.beta`` block order. The
    default (``family=None``) is the seed's scalar Ising layout.

    Cached per (graph, include_singleton, family) — graphs and family
    instances are frozen/hashable, and every combine call, ADMM round, and
    compiled estimation session walks the same owner structure; treat the
    returned dict as read-only.
    """
    return _param_owners_cached(graph, include_singleton, family)


@functools.lru_cache(maxsize=128)
def _param_owners_cached(graph: Graph, include_singleton: bool,
                         family) -> Dict[int, List[Tuple[int, int]]]:
    owners: Dict[int, List[Tuple[int, int]]] = {}
    for i in range(graph.p):
        beta = (graph.beta(i, include_singleton) if family is None
                else family.beta(graph, i, include_singleton))
        for pos, a in enumerate(beta):
            owners.setdefault(a, []).append((i, pos))
    return owners


def free_indices(graph: Graph, include_singleton: bool = True,
                 family=None) -> np.ndarray:
    C = 1 if family is None else family.block_dim
    if include_singleton:
        return np.arange((graph.p + graph.m) * C)
    return np.arange(graph.p * C, (graph.p + graph.m) * C)


# --------------------------------------------- exact consensus covariances
def cross_cov(locals_: List[ExactLocal], a: int,
              owners_a: List[Tuple[int, int]]) -> np.ndarray:
    """V_alpha (Prop 4.6): cov(s^i_a, s^j_a) across owner nodes, exact."""
    probs = locals_[0].probs
    cols = np.stack([locals_[i].S[:, pos] for (i, pos) in owners_a], axis=1)
    return (cols * probs[:, None]).T @ cols


def exact_consensus_variance(model: IsingModel, locals_: List[ExactLocal],
                             scheme: str,
                             include_singleton: bool = True
                             ) -> Tuple[float, Dict[int, float]]:
    """Asymptotic var of one-step consensus per Thm 4.1/4.3 with exact weights.

    scheme in {"uniform", "diagonal", "optimal", "max"}. Returns
    (tr V over free params, per-param variance dict).
    """
    graph = model.graph
    owners = param_owners(graph, include_singleton)
    per_param: Dict[int, float] = {}
    for a, own in owners.items():
        Va = cross_cov(locals_, a, own)                  # (k, k)
        diag = np.array([locals_[i].V[pos, pos] for (i, pos) in own])
        k = len(own)
        if scheme == "uniform":
            w = np.ones(k)
        elif scheme == "diagonal":
            w = 1.0 / diag
        elif scheme == "max":
            w = np.zeros(k)
            w[int(np.argmin(diag))] = 1.0                # Prop 4.4
        elif scheme == "optimal":
            w = np.linalg.solve(Va + 1e-12 * np.eye(k), np.ones(k))  # Prop 4.6
        else:
            raise ValueError(scheme)
        w = w / w.sum()
        per_param[a] = float(w @ Va @ w)
    tr = float(sum(per_param.values()))
    return tr, per_param


def exact_joint_mple_variance(model: IsingModel,
                              include_singleton: bool = True
                              ) -> Tuple[float, np.ndarray]:
    """Exact asymptotic covariance of joint MPLE (Godambe sandwich)."""
    graph = model.graph
    states = jnp.asarray(all_states(graph.p))
    probs = exact_probs(graph, model.theta)
    free = free_indices(graph, include_singleton)

    from .ising import cond_loglik

    def per_state(w):
        theta = model.theta.at[free].set(w)
        return jnp.sum(cond_loglik(graph, theta, states), axis=1)  # (2^p,)

    w_star = model.theta[free]
    G = jax.jacfwd(per_state)(w_star)                    # (2^p, d)
    H = -jax.hessian(lambda w: probs @ per_state(w))(w_star)
    J = (G * probs[:, None]).T @ G
    Hinv = jnp.linalg.inv(H)
    V = np.asarray(Hinv @ J @ Hinv)
    return float(np.trace(V)), V


def exact_mle_variance(model: IsingModel,
                       include_singleton: bool = True
                       ) -> Tuple[float, np.ndarray]:
    """Cramer-Rao floor: V = Fisher^-1 on the free block (exact)."""
    _, fisher = exact_moments(model.graph, model.theta)
    free = free_indices(model.graph, include_singleton)
    V = np.linalg.inv(np.asarray(fisher)[np.ix_(free, free)])
    return float(np.trace(V)), V


def efficiency(tr_v: float, tr_v_mle: float) -> float:
    """Paper Sec. 5: asymptotic efficiency tr(V)/tr(V_mle) (1 = optimal)."""
    return tr_v / tr_v_mle
