"""Model-family registry.

Families register here by name; everything downstream — the batched
estimator engine, consensus, samplers, the streaming stack, benchmarks and
the conformance test harness — resolves families through :func:`get_family`
/ :func:`registered_families`, so adding a model family is: implement the
:class:`~repro.core.families.base.ModelFamily` contract, register an
instance, and make ``tests/families/test_conformance.py`` pass (the suite
parametrizes over this registry automatically). See the "adding a model
family" guide in the README.
"""
from __future__ import annotations

from typing import Dict, Tuple

from .base import (ModelFamily, fit_mple_family, fit_node_oracle,
                   random_rows)
from .gaussian import GaussianMRF
from .ising import IsingFamily
from .potts import PottsFamily

_REGISTRY: Dict[str, ModelFamily] = {}


def register_family(family: ModelFamily) -> ModelFamily:
    """Register (or replace) a family instance under ``family.name``."""
    if not family.name:
        raise ValueError("family needs a non-empty name")
    _REGISTRY[family.name] = family
    return family


def get_family(name: str) -> ModelFamily:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown model family {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def registered_families() -> Tuple[ModelFamily, ...]:
    """All registered families, name-sorted (the conformance axis)."""
    return tuple(_REGISTRY[k] for k in sorted(_REGISTRY))


#: canonical instances — the three families of this repro
ISING = register_family(IsingFamily())
GAUSSIAN = register_family(GaussianMRF())
POTTS3 = register_family(PottsFamily(q=3))

__all__ = [
    "ModelFamily", "IsingFamily", "GaussianMRF", "PottsFamily",
    "ISING", "GAUSSIAN", "POTTS3",
    "register_family", "get_family", "registered_families",
    "fit_mple_family", "fit_node_oracle", "random_rows",
]
