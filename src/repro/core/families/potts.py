"""q-state Potts family with state-dependent couplings.

States x_i in {0, ..., q-1}; state 0 is the reference. Sufficient stats
(per channel c = 1..q-1, stored as channel index c-1):

    node blocks:  1[x_i = c]
    edge blocks:  1[x_i = c] 1[x_j = c]       (vector-valued per edge)

so the node conditionals are identifiable multinomial logistic channels

    p(x_i = c | x_N(i)) proportional to exp( theta_{i,c}
        + sum_{j in N(i)} theta_{ij,c} 1[x_j = c] ),   p(x_i = 0) prop. 1.

C = q - 1 exercises everything the scalar-edge Ising code could not:
vector parameter blocks, cross-channel Hessian coupling (softmax curvature
``diag(pi) - pi pi'``), and channel-dependent designs. The exact small-p
oracle enumerates all q^p states. Samples are stored as float arrays of
integer states so they flow through the shared (float) sample buffers.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs import Graph
from .base import ModelFamily


@dataclasses.dataclass(frozen=True)
class PottsFamily(ModelFamily):
    q: int = 3
    name: str = "potts"

    def __post_init__(self):
        if self.q < 2:
            raise ValueError("Potts needs q >= 2 states")

    @property
    def kernel_kind(self) -> str:
        return "potts"

    @property
    def block_dim(self) -> int:
        return self.q - 1

    # ----------------------------------------------------- channel hooks
    def edge_features(self, x):
        x = jnp.asarray(x)
        chans = jnp.arange(1, self.q, dtype=x.dtype)
        return (x[..., None] == chans).astype(x.dtype)

    def _extended(self, eta):
        """Prepend the reference channel's zero logit: (..., C, n) ->
        (..., q, n)."""
        zero = jnp.zeros_like(eta[..., :1, :])
        return jnp.concatenate([zero, eta], axis=-2)

    def loglik_eta(self, eta, xi):
        ez = self._extended(eta)
        lse = jax.scipy.special.logsumexp(ez, axis=-2)
        idx = jnp.clip(xi.astype(jnp.int32), 0, self.q - 1)
        sel = jnp.take_along_axis(ez, idx[..., None, :], axis=-2)[..., 0, :]
        return sel - lse

    def _pi(self, eta):
        return jax.nn.softmax(self._extended(eta), axis=-2)[..., 1:, :]

    def dl_deta(self, eta, xi):
        chans = jnp.arange(1, self.q, dtype=xi.dtype)
        shape = (1,) * (xi.ndim - 1) + (self.q - 1, 1)
        y = (xi[..., None, :] == chans.reshape(shape)).astype(eta.dtype)
        return y - self._pi(eta)

    def curvature(self, eta, xi):
        pi = self._pi(eta)                                   # (..., C, n)
        eye = jnp.eye(self.q - 1, dtype=eta.dtype)[..., :, :, None]
        diag = pi[..., :, None, :] * eye
        return diag - pi[..., :, None, :] * pi[..., None, :, :]

    # ---------------------------------------------------- sampling hooks
    def init_draw(self, key, p: int):
        return jax.random.randint(key, (p,), 0, self.q).astype(jnp.float32)

    def cond_draw(self, key, eta):
        zero = jnp.zeros_like(eta[..., :1])
        ez = jnp.concatenate([zero, eta], axis=-1)           # (..., q)
        return jax.random.categorical(key, ez, axis=-1).astype(jnp.float32)

    # ------------------------------------------------------------- model
    def suff_stats(self, graph: Graph, X):
        X = jnp.asarray(X)
        n = X.shape[0]
        F = self.edge_features(X)                            # (n, p, C)
        node = F.reshape(n, graph.p * self.block_dim)
        if graph.m:
            rows = np.array([e[0] for e in graph.edges], dtype=np.int32)
            cols = np.array([e[1] for e in graph.edges], dtype=np.int32)
            pair = (F[:, rows, :] * F[:, cols, :]).reshape(
                n, graph.m * self.block_dim)
        else:
            pair = jnp.zeros((n, 0), X.dtype)
        return jnp.concatenate([node, pair], axis=1)

    # ------------------------------------------------------------ oracle
    def all_states(self, p: int) -> np.ndarray:
        """(q^p, p) enumeration of all state vectors (small p only)."""
        q = self.q
        idx = np.arange(q ** p, dtype=np.int64)
        return ((idx[:, None] // q ** np.arange(p)[None, :]) % q
                ).astype(np.float32)

    def exact_probs(self, graph: Graph, theta) -> jnp.ndarray:
        U = self.suff_stats(graph, jnp.asarray(self.all_states(graph.p)))
        return jax.nn.softmax(U @ jnp.asarray(theta, U.dtype))

    def log_partition(self, graph: Graph, theta):
        U = self.suff_stats(graph, jnp.asarray(self.all_states(graph.p)))
        return jax.scipy.special.logsumexp(U @ jnp.asarray(theta, U.dtype))

    def exact_moments(self, graph: Graph, theta) -> np.ndarray:
        U = self.suff_stats(graph, jnp.asarray(self.all_states(graph.p)))
        pr = self.exact_probs(graph, theta)
        return np.asarray(pr @ U, dtype=np.float64)

    def exact_sample(self, graph: Graph, theta, n: int, key):
        states = self.all_states(graph.p)
        pr = self.exact_probs(graph, theta)
        idx = jax.random.categorical(key, jnp.log(pr + 1e-30), shape=(n,))
        return jnp.asarray(states)[idx]

    def random_params(self, graph: Graph, key, scale_edge: float = 0.4,
                      scale_node: float = 0.3):
        k1, k2 = jax.random.split(key)
        C = self.block_dim
        node = scale_node * jax.random.normal(k1, (graph.p * C,))
        edge = scale_edge * jax.random.normal(k2, (graph.m * C,))
        return jnp.concatenate([node, edge])
