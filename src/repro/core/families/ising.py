"""Ising family: the seed model re-expressed as a :class:`ModelFamily`.

Single-channel (C = 1) logistic node conditionals over x in {-1, +1}; the
flat layout and all model math delegate to :mod:`repro.core.ising`, so the
family instance and the seed code paths agree exactly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs import Graph
from .. import ising as I
from .base import ModelFamily


@dataclasses.dataclass(frozen=True)
class IsingFamily(ModelFamily):
    name: str = "ising"

    @property
    def kernel_kind(self) -> str:
        return "ising"

    @property
    def block_dim(self) -> int:
        return 1

    # ----------------------------------------------------- channel hooks
    def edge_features(self, x):
        return jnp.asarray(x)[..., None]

    def loglik_eta(self, eta, xi):
        return jax.nn.log_sigmoid(2.0 * xi * eta[..., 0, :])

    def dl_deta(self, eta, xi):
        r = 2.0 * xi * jax.nn.sigmoid(-2.0 * xi * eta[..., 0, :])
        return r[..., None, :]

    def curvature(self, eta, xi):
        r = 2.0 * xi * jax.nn.sigmoid(-2.0 * xi * eta[..., 0, :])
        kap = r * (2.0 * xi - r)      # = 4 sigma(2 eta) sigma(-2 eta)
        return kap[..., None, None, :]

    # ---------------------------------------------------- sampling hooks
    def init_draw(self, key, p: int):
        return jnp.where(jax.random.uniform(key, (p,)) < 0.5, 1.0, -1.0)

    def cond_draw(self, key, eta):
        u = jax.random.uniform(key, eta.shape[:-1])
        return jnp.where(u < jax.nn.sigmoid(2.0 * eta[..., 0]), 1.0, -1.0)

    # ------------------------------------------------------------- model
    def suff_stats(self, graph: Graph, X):
        return I.suff_stats(graph, jnp.asarray(X))

    # ------------------------------------------------------------ oracle
    def exact_moments(self, graph: Graph, theta) -> np.ndarray:
        mu, _ = I.exact_moments(graph, jnp.asarray(theta))
        return np.asarray(mu, dtype=np.float64)

    def exact_sample(self, graph: Graph, theta, n: int, key):
        from ..sampling import exact_sample
        return exact_sample(I.IsingModel(graph, jnp.asarray(theta)), n, key)

    def random_params(self, graph: Graph, key, scale_edge: float = 0.4,
                      scale_node: float = 0.3):
        m = I.random_model(graph, scale_edge, scale_node, key)
        return m.theta
