"""Gaussian MRF family: unit-conditional-variance Gauss-Markov field.

    p(x | theta) = exp( h'x + sum_{(ij) in E} T_ij x_i x_j - x'x/2 - log Z ),

i.e. x ~ N(mu, Sigma) with precision J = I - T, mean mu = Sigma h, valid
whenever I - T is positive definite (``random_params`` keeps it diagonally
dominant). The node conditionals are linear-Gaussian with unit variance,

    x_i | x_N(i) ~ N( h_i + sum_j T_ij x_j , 1 ),

so each local CL fit is a weighted least-squares solve: the curvature hook
is the constant 1 and the degree-bucketed Newton engine converges in one
step without any IRLS iteration. The exact oracle (moments, sampler,
log-partition) is closed form — no enumeration needed.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs import Graph
from .base import ModelFamily

_LOG_2PI = float(np.log(2.0 * np.pi))


@dataclasses.dataclass(frozen=True)
class GaussianMRF(ModelFamily):
    name: str = "gaussian"

    @property
    def kernel_kind(self) -> str:
        return "gaussian"

    @property
    def block_dim(self) -> int:
        return 1

    # ----------------------------------------------------- channel hooks
    def edge_features(self, x):
        return jnp.asarray(x)[..., None]

    def loglik_eta(self, eta, xi):
        r = xi - eta[..., 0, :]
        return -0.5 * r * r - 0.5 * _LOG_2PI

    def dl_deta(self, eta, xi):
        return (xi - eta[..., 0, :])[..., None, :]

    def curvature(self, eta, xi):
        kap = jnp.ones_like(eta[..., 0, :])
        return kap[..., None, None, :]

    # ---------------------------------------------------- sampling hooks
    def init_draw(self, key, p: int):
        return jax.random.normal(key, (p,))

    def cond_draw(self, key, eta):
        return eta[..., 0] + jax.random.normal(key, eta.shape[:-1])

    # ------------------------------------------------------------- model
    def suff_stats(self, graph: Graph, X):
        X = jnp.asarray(X)
        rows = np.array([e[0] for e in graph.edges], dtype=np.int32)
        cols = np.array([e[1] for e in graph.edges], dtype=np.int32)
        pair = (X[:, rows] * X[:, cols] if graph.m
                else jnp.zeros((X.shape[0], 0), X.dtype))
        return jnp.concatenate([X, pair], axis=1)

    # ------------------------------------------------------------ oracle
    def _precision(self, graph: Graph, theta) -> np.ndarray:
        T = np.zeros((graph.p, graph.p))
        te = np.asarray(theta)[graph.p:]
        for k, (i, j) in enumerate(graph.edges):
            T[i, j] = T[j, i] = te[k]
        return np.eye(graph.p) - T

    def moments(self, graph: Graph, theta):
        """(mu, Sigma) of the joint Gaussian — the closed-form oracle."""
        J = self._precision(graph, theta)
        Sigma = np.linalg.inv(J)
        mu = Sigma @ np.asarray(theta)[: graph.p]
        return mu, Sigma

    def log_partition(self, graph: Graph, theta) -> float:
        J = self._precision(graph, theta)
        h = np.asarray(theta)[: graph.p]
        sign, logdet = np.linalg.slogdet(J)
        if sign <= 0:
            raise ValueError("I - T is not positive definite")
        mu = np.linalg.solve(J, h)
        return float(0.5 * (h @ mu) - 0.5 * logdet
                     + 0.5 * graph.p * _LOG_2PI)

    def exact_moments(self, graph: Graph, theta) -> np.ndarray:
        mu, Sigma = self.moments(graph, theta)
        second = np.array([Sigma[i, j] + mu[i] * mu[j]
                           for (i, j) in graph.edges])
        return np.concatenate([mu, second])

    def exact_sample(self, graph: Graph, theta, n: int, key):
        mu, Sigma = self.moments(graph, theta)
        L = np.linalg.cholesky(Sigma)
        z = jax.random.normal(key, (n, graph.p))
        return jnp.asarray(mu)[None, :] + z @ jnp.asarray(L).T

    def random_params(self, graph: Graph, key, scale_edge: float = 0.4,
                      scale_node: float = 0.3):
        k1, k2 = jax.random.split(key)
        h = scale_node * jax.random.normal(k1, (graph.p,))
        te = scale_edge * jax.random.normal(k2, (graph.m,))
        # keep I - T strictly diagonally dominant -> positive definite
        row = np.zeros(graph.p)
        te_np = np.abs(np.asarray(te))
        for k, (i, j) in enumerate(graph.edges):
            row[i] += te_np[k]
            row[j] += te_np[k]
        worst = float(row.max()) if graph.m else 0.0
        if worst > 0.9:
            te = te * (0.9 / worst)
        return jnp.concatenate([h, te])
