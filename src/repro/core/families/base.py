"""The ``ModelFamily`` contract: one estimator interface per exponential
family (Liu & Ihler 2012 Sec. 2; Liu & Ihler 2014; Mizrahi et al. 2014).

Every family is a pairwise exponential-family model over a :class:`~repro.
core.graphs.Graph` whose per-node conditionals are **channelized GLMs**:
node i's conditional distribution given its neighbors is determined by a
``(C,)`` vector of channel logits

    eta_c(x) = theta_{i,c} + sum_{j in N(i)} theta_{ij,c} * f_c(x_j),

where ``C = family.block_dim`` is the shared per-node / per-edge parameter
block size and ``f`` is the family's :meth:`~ModelFamily.edge_features` map.
Concretely:

* **Ising** — C = 1, f(x) = x, logistic channel likelihood;
* **Gaussian MRF** — C = 1, f(x) = x, unit-variance linear-Gaussian channel
  (the node conditional is weighted least squares, so Newton converges in
  one step);
* **Potts (q states)** — C = q - 1, f_c(x) = 1[x = c + 1], multinomial
  logistic channels with *vector-valued* per-edge parameter blocks.

The flat parameter vector is ordered ``[node blocks (p*C), edge blocks
(m*C)]``, generalizing the seed's ``[singletons, edges]`` layout (C = 1
reproduces it exactly). Families must supply closed-form per-channel score
``dl_deta`` and curvature hooks — that is what lets the degree-bucketed
batched engine (:mod:`repro.core.batched`) solve every family without
autodiff — plus sampler draws and an exact small-p oracle, which is what
the conformance harness (``tests/families/test_conformance.py``) checks
each registered family against.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs import Graph


class ModelFamily:
    """Abstract base for exponential-family model plugins.

    Subclasses are frozen dataclasses holding only hashable configuration
    (name, q, ...), so a family instance can be a static jit argument and a
    dict key in the registry. All array math lives in methods.
    """

    name: str

    # ------------------------------------------------------------ kernels
    @property
    def kernel_kind(self) -> Optional[str]:
        """Epilogue key into the fused CL kernel registry
        (:mod:`repro.kernels.cl.epilogues`), or None for no fused path.

        A family returning a registered kind gets the fused Pallas
        score/Gram pipeline and the fused bucket Newton statistics for
        free; families without one transparently use the closed-form hook
        / autodiff reference paths everywhere.
        """
        return None

    # ------------------------------------------------------------ layout
    @property
    def block_dim(self) -> int:
        """C: size of every per-node and per-edge parameter block."""
        raise NotImplementedError

    def n_params(self, graph: Graph) -> int:
        return (graph.p + graph.m) * self.block_dim

    def node_block(self, graph: Graph, i: int) -> List[int]:
        C = self.block_dim
        return list(range(i * C, (i + 1) * C))

    def edge_block(self, graph: Graph, k: int) -> List[int]:
        C = self.block_dim
        base = graph.p * C
        return list(range(base + k * C, base + (k + 1) * C))

    def beta(self, graph: Graph, i: int,
             include_singleton: bool = True) -> List[int]:
        """Flat indices of the parameters node i estimates, block-ordered:
        singleton block first (when free), then incident-edge blocks in
        ``graph.incident_edges(i)`` order — the generalization of
        ``graph.beta``; identical to it at C = 1."""
        idx = self.node_block(graph, i) if include_singleton else []
        for k in graph.incident_edges(i):
            idx += self.edge_block(graph, k)
        return idx

    def node_params(self, graph: Graph, theta) -> jnp.ndarray:
        """(p, C) node blocks of a flat theta."""
        C = self.block_dim
        return jnp.asarray(theta)[: graph.p * C].reshape(graph.p, C)

    def edge_params(self, graph: Graph, theta) -> jnp.ndarray:
        """(m, C) edge blocks of a flat theta."""
        C = self.block_dim
        return jnp.asarray(theta)[graph.p * C:].reshape(graph.m, C)

    def coupling_tensor(self, graph: Graph, theta) -> jnp.ndarray:
        """Symmetric (p, p, C) dense coupling tensor from the edge blocks."""
        te = self.edge_params(graph, theta)
        rows = np.array([e[0] for e in graph.edges], dtype=np.int32)
        cols = np.array([e[1] for e in graph.edges], dtype=np.int32)
        T = jnp.zeros((graph.p, graph.p, self.block_dim), dtype=te.dtype)
        T = T.at[rows, cols].set(te)
        return T.at[cols, rows].set(te)

    # ----------------------------------------------------- channel hooks
    def edge_features(self, x: jnp.ndarray) -> jnp.ndarray:
        """Per-channel feature of a neighbor's value: (...,) -> (..., C)."""
        raise NotImplementedError

    def loglik_eta(self, eta: jnp.ndarray, xi: jnp.ndarray) -> jnp.ndarray:
        """Per-sample conditional loglik from channel logits.

        eta: (..., C, n); xi: (..., n) node values. Returns (..., n).
        """
        raise NotImplementedError

    def dl_deta(self, eta: jnp.ndarray, xi: jnp.ndarray) -> jnp.ndarray:
        """Closed-form d loglik / d eta: (..., C, n)."""
        raise NotImplementedError

    def curvature(self, eta: jnp.ndarray, xi: jnp.ndarray) -> jnp.ndarray:
        """Closed-form -d^2 loglik / d eta^2, PSD: (..., C, C, n)."""
        raise NotImplementedError

    # --------------------------------------------------- sampling hooks
    def init_draw(self, key: jax.Array, p: int) -> jnp.ndarray:
        """(p,) initial Gibbs state."""
        raise NotImplementedError

    def cond_draw(self, key: jax.Array, eta: jnp.ndarray) -> jnp.ndarray:
        """Draw node values from conditionals: eta (..., C) -> (...)."""
        raise NotImplementedError

    # ------------------------------------------------------------ model
    def suff_stats(self, graph: Graph, X: jnp.ndarray) -> jnp.ndarray:
        """u(x): (n, n_params) in flat block order."""
        raise NotImplementedError

    def cond_logits(self, graph: Graph, theta, X: jnp.ndarray) -> jnp.ndarray:
        """All-node channel logits: (n, p, C)."""
        h = self.node_params(graph, theta)                   # (p, C)
        Tc = self.coupling_tensor(graph, theta)              # (p, p, C)
        F = self.edge_features(jnp.asarray(X))               # (n, p, C)
        return h[None] + jnp.einsum("njc,jic->nic", F, Tc)

    def cond_loglik(self, graph: Graph, theta, X: jnp.ndarray) -> jnp.ndarray:
        """Per-node conditional loglik log p(x_i | x_N(i)): (n, p)."""
        X = jnp.asarray(X)
        eta = self.cond_logits(graph, theta, X)              # (n, p, C)
        ll = self.loglik_eta(jnp.moveaxis(eta, 0, 2), X.T)   # (p, n)
        return ll.T

    def pseudo_loglik(self, graph: Graph, theta, X: jnp.ndarray):
        """Average pseudo-likelihood (Eq. 2 generalized)."""
        return jnp.mean(jnp.sum(self.cond_loglik(graph, theta, X), axis=1))

    def pseudo_score(self, graph: Graph, theta, X: jnp.ndarray) -> np.ndarray:
        """Reference flat gradient of the average pseudo-likelihood."""
        t = jnp.asarray(np.asarray(theta), dtype=jnp.float32)
        g = jax.grad(lambda w: self.pseudo_loglik(graph, w,
                                                  jnp.asarray(X)))(t)
        return np.asarray(g, dtype=np.float64)

    # ------------------------------------------------------------ oracle
    def exact_moments(self, graph: Graph, theta) -> np.ndarray:
        """E[u(x)] under p(x | theta) — small p / closed form only."""
        raise NotImplementedError

    def exact_sample(self, graph: Graph, theta, n: int,
                     key: jax.Array) -> jnp.ndarray:
        """n iid samples from the exact joint (small p / closed form)."""
        raise NotImplementedError

    def random_params(self, graph: Graph, key: jax.Array,
                      scale_edge: float = 0.4,
                      scale_node: float = 0.3) -> jnp.ndarray:
        """A valid random flat theta (families enforce their own
        constraints, e.g. the Gaussian precision staying PD)."""
        raise NotImplementedError

    def sample(self, graph: Graph, theta, n: int, key: jax.Array,
               burnin: int = 200, thin: int = 5,
               n_chains: int = 8) -> jnp.ndarray:
        """Default sampler: family-generic chromatic Gibbs."""
        from ..sampling import gibbs_sample_family
        return gibbs_sample_family(self, graph, theta, n, key,
                                   burnin=burnin, thin=thin,
                                   n_chains=n_chains)


# ---------------------------------------------------------------- generic
def random_rows(family: ModelFamily, key: jax.Array, n: int,
                p: int) -> jnp.ndarray:
    """(n, p) iid rows of *valid* node values via ``family.init_draw``.

    The family-generic cheap sample source benchmarks and property tests
    use when they need well-typed data (spin signs, reals, Potts states)
    without paying for draws from any particular joint model — a fourth
    registered family gets correct rows here automatically instead of
    falling through some name-keyed special case.
    """
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: family.init_draw(k, p))(keys)


# Reference fits shared by every family: plain autodiff Newton on the
# family criteria. Slow but definitionally correct — the conformance
# harness pits the batched engine against these.
def fit_mple_family(family: ModelFamily, graph: Graph, X,
                    free_idx: Optional[Sequence[int]] = None,
                    theta_fixed: Optional[np.ndarray] = None,
                    n_iter: int = 40) -> np.ndarray:
    """Centralized joint MPLE for any family; returns full flat theta."""
    from ..estimators import newton_maximize
    n_params = family.n_params(graph)
    X = jnp.asarray(X)
    if theta_fixed is None:
        theta_fixed = jnp.zeros(n_params, X.dtype)
    theta_fixed = jnp.asarray(theta_fixed, X.dtype)
    if free_idx is None:
        free_idx = np.arange(n_params)
    free_idx = np.asarray(free_idx)

    def fun(w):
        theta = theta_fixed.at[free_idx].set(w)
        return family.pseudo_loglik(graph, theta, X)

    w = newton_maximize(fun, theta_fixed[free_idx], n_iter=n_iter)
    return np.asarray(theta_fixed.at[free_idx].set(w))


def fit_node_oracle(family: ModelFamily, graph: Graph, X, i: int,
                    include_singleton: bool = True,
                    theta_fixed: Optional[np.ndarray] = None,
                    n_iter: int = 40) -> np.ndarray:
    """Node i's local CL fit by autodiff Newton — the per-node oracle.

    Returns the ``family.beta(graph, i, include_singleton)``-ordered local
    parameter vector (block layout identical to the batched engine's).
    """
    from ..estimators import newton_maximize
    C = family.block_dim
    X = jnp.asarray(X)
    if theta_fixed is None:
        theta_fixed = jnp.zeros(family.n_params(graph), X.dtype)
    theta_fixed = jnp.asarray(theta_fixed, X.dtype)

    ks = graph.incident_edges(i)
    others = [graph.edges[k][0] if graph.edges[k][1] == i else graph.edges[k][1]
              for k in ks]
    F = family.edge_features(X[:, others]) if others else \
        jnp.zeros((X.shape[0], 0, C), X.dtype)               # (n, deg, C)
    xi = X[:, i]
    lead = 1 if include_singleton else 0
    d = (lead + len(others)) * C
    offset = theta_fixed[np.asarray(family.node_block(graph, i))]

    def fun(w):
        Wb = w.reshape(lead + len(others), C)
        We = Wb[lead:]                                       # (deg, C)
        eta = jnp.einsum("njc,jc->nc", F, We)                # (n, C)
        eta = eta + (Wb[0][None, :] if include_singleton else offset[None, :])
        return jnp.mean(family.loglik_eta(eta.T, xi))

    w = newton_maximize(fun, jnp.zeros(d, X.dtype), n_iter=n_iter)
    return np.asarray(w)
