"""Batched local-estimator engine: degree-bucketed, vmapped Newton-IRLS.

The paper's local CL estimators (Eq. 3) are p independent logistic
regressions of x_i on its neighbors. The seed implementation fit them in a
Python loop — one separately-jitted solve per node, each recomputing a full
autodiff ``jax.hessian`` every Newton iteration. This module exploits the
embarrassing parallelism structurally:

* nodes are grouped into **degree buckets** (degree padded up to the next
  power of four), so XLA compiles one solver per bucket instead of one per
  node;
* within a bucket all k neighbor designs are stacked into a ``(k, n, deg)``
  tensor and solved simultaneously by batched einsum Newton steps;
* gradients and Hessians use the **closed forms** of the logistic CL
  criterion — ``g = Z_b^T r / n`` with ``r = 2 x sigma(-2 x eta)`` and
  ``H = -4 Z_b^T diag(sigma(2 eta) sigma(-2 eta)) Z_b / n`` — dropping an
  autodiff order per iteration relative to ``jax.hessian``;
* Newton systems are solved by a **pure-XLA batched Gauss-Jordan sweep**
  (sign-definite systems need no pivoting), avoiding the per-matrix LAPACK
  dispatch of ``jnp.linalg.solve`` that dominates wall-clock for the tiny
  per-node systems — and the custom-call lowering that dominates compile
  time;
* iteration stops early (``while_loop``) once every node's damped Newton
  step is below tolerance, instead of always burning the full budget.

Padding is exact: padded design columns are zero, so their gradient entries
vanish and the Hessian is block-diagonal with a ``-1`` placeholder on padded
coordinates; the Newton direction on real coordinates is untouched.

Public entry points: :func:`degree_buckets`, :func:`fit_all_local_batched`,
and the per-bucket compile-count probe :func:`bucket_compile_count`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .estimators import LocalFit
from .graphs import Graph


def _pad_degree(deg: int) -> int:
    """Bucket width for a node of degree ``deg``: next power of 4 (min 1).

    Coarser-than-power-of-2 padding trades a little wasted compute inside a
    bucket (at most 4x on zero columns, which the einsums eat on the VPU)
    for fewer distinct shapes, i.e. fewer XLA compilations.
    """
    pad = 1
    while pad < deg:
        pad *= 4
    return pad


@dataclasses.dataclass(frozen=True)
class DegreeBucket:
    """All nodes whose padded degree is ``deg_pad``, with gather metadata."""
    deg_pad: int
    nodes: np.ndarray      # (k,) node indices, ascending
    nbrs: np.ndarray       # (k, deg_pad) neighbor indices, 0-padded
    mask: np.ndarray       # (k, deg_pad) 1.0 on real columns, 0.0 on padding


@functools.lru_cache(maxsize=64)
def _degree_buckets_cached(graph: Graph):
    by_pad: Dict[int, List[int]] = {}
    nbrs_of: Dict[int, List[int]] = {}
    for i in range(graph.p):
        ks = graph.incident_edges(i)
        others = [graph.edges[k][0] if graph.edges[k][1] == i
                  else graph.edges[k][1] for k in ks]
        nbrs_of[i] = others
        by_pad.setdefault(_pad_degree(len(others)), []).append(i)

    buckets = []
    for deg_pad in sorted(by_pad):
        nodes = np.asarray(sorted(by_pad[deg_pad]), dtype=np.int32)
        k = len(nodes)
        nbrs = np.zeros((k, deg_pad), dtype=np.int32)
        mask = np.zeros((k, deg_pad), dtype=np.float32)
        for row, i in enumerate(nodes):
            d = len(nbrs_of[i])
            nbrs[row, :d] = nbrs_of[i]
            mask[row, :d] = 1.0
        buckets.append(DegreeBucket(deg_pad=deg_pad, nodes=nodes,
                                    nbrs=nbrs, mask=mask))
    return tuple(buckets)


def degree_buckets(graph: Graph) -> List[DegreeBucket]:
    """Group nodes by padded degree; neighbor order matches ``node_design``.

    Columns are ordered like ``graph.incident_edges(i)`` (edge order), which
    is what :func:`repro.core.estimators.node_design` and ``graph.beta`` use,
    so bucketed estimates line up coordinate-for-coordinate with the seed
    per-node solver. Cached per graph (graphs are frozen/hashable).
    """
    return list(_degree_buckets_cached(graph))


def _gauss_jordan_solve(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """Batched solve A @ X = B for sign-definite A via Gauss-Jordan.

    A: (k, d, d) uniformly positive- or negative-definite (no pivoting
    needed); B: (k, d, m). Pure jnp ops — one fori_loop of rank-1 updates —
    so it lowers to plain XLA vector code instead of per-matrix LAPACK
    custom calls, which dominate both runtime and compile time for the
    small systems this engine solves.
    """
    d = A.shape[-1]
    M = jnp.concatenate([A, B], axis=2)              # (k, d, d + m)

    def body(i, M):
        piv = M[:, i, :] / M[:, i, i][:, None]       # (k, d + m)
        coef = M[:, :, i]                            # (k, d)
        M = M - coef[:, :, None] * piv[:, None, :]
        return M.at[:, i, :].set(piv)                # pivot row normalized

    M = jax.lax.fori_loop(0, d, body, M)
    return M[:, :, d:]


@functools.partial(jax.jit, static_argnames=("include_singleton", "n_iter"))
def _solve_bucket(X, nodes, nbrs, mask, offsets, include_singleton: bool,
                  n_iter: int, tol: float = 2e-6,
                  ridge: float = 1e-8, max_step: float = 5.0):
    """Solve every node of one degree bucket in a single XLA program.

    X: (n, p) samples; nodes: (k,); nbrs: (k, deg_pad); mask: (k, deg_pad);
    offsets: (k,) fixed singleton thetas (used when include_singleton=False).

    Designs live in (k, d, n) layout so the per-iteration Hessian is one
    batched matmul contracting over the contiguous sample axis. The
    curvature weights use the x in {-1,+1} identity
    ``kappa = 4 sigma(2 eta) sigma(-2 eta) = r (2 x - r)``, which costs no
    extra transcendentals beyond the residual ``r``. ``tol`` (on the damped
    step's inf-norm) is chosen just above the float32 jitter floor: iterating
    past it only bounces around the optimum, which is all the seed's fixed
    40-iteration schedule does after convergence.

    Returns (W, H, J, V, S) with leading bucket dimension k and parameter
    dimension d = deg_pad (+1 with a free singleton); padded coordinates are
    exactly zero in W and carry a ``-1`` placeholder diagonal in H.
    """
    n = X.shape[0]
    # (k, deg_pad, n): gather neighbor columns, zero the padded ones
    Zt = jnp.swapaxes(jnp.swapaxes(X[:, nbrs], 0, 1), 1, 2) * mask[:, :, None]
    xi = X[:, nodes].T                                       # (k, n)

    if include_singleton:
        ones = jnp.ones((Zt.shape[0], 1, Zt.shape[2]), Zt.dtype)
        Zb = jnp.concatenate([ones, Zt], axis=1)             # (k, d, n)
        cmask = jnp.concatenate(
            [jnp.ones((mask.shape[0], 1), mask.dtype), mask], axis=1)
        base = jnp.zeros_like(xi)
    else:
        Zb = Zt
        cmask = mask
        base = offsets[:, None] * jnp.ones_like(xi)

    k, d, _ = Zb.shape
    ZbT = jnp.swapaxes(Zb, 1, 2)                             # (k, n, d)
    eye = jnp.eye(d, dtype=Zb.dtype)
    # -1 on padded diagonals keeps the (exactly block-diagonal) system
    # uniformly negative definite without touching the real block's
    # Newton direction.
    pad_diag = (1.0 - cmask)[:, :, None] * eye[None, :, :]

    def score_curvature(W):
        eta = base + jnp.einsum("kdn,kd->kn", Zb, W)
        r = 2.0 * xi * jax.nn.sigmoid(-2.0 * xi * eta)       # dl/deta
        kap = r * (2.0 * xi - r)
        return r, kap

    def cond(carry):
        _, it, delta = carry
        return (it < n_iter) & (delta > tol)

    def newton_step(carry):
        W, it, _ = carry
        r, kap = score_curvature(W)
        g = jnp.einsum("kdn,kn->kd", Zb, r) / n
        H = -(Zb * kap[:, None, :]) @ ZbT / n \
            - ridge * eye[None, :, :] - pad_diag
        dirn = _gauss_jordan_solve(H, g[..., None])[..., 0]  # (k, d)
        norm = jnp.linalg.norm(dirn, axis=1, keepdims=True)
        dirn = jnp.where(norm > max_step,
                         dirn * (max_step / (norm + 1e-30)), dirn)
        # a node that NaN'd (degenerate data, quasi-separation) must not
        # poison the bucket-wide convergence check and freeze its siblings:
        # treat non-finite steps as converged — NaN is absorbing anyway.
        delta = jnp.max(jnp.where(jnp.isfinite(dirn), jnp.abs(dirn), 0.0))
        return W - dirn, it + 1, delta

    W0 = jnp.zeros((k, d), Zb.dtype)
    W, _, _ = jax.lax.while_loop(cond, newton_step, (W0, 0, jnp.inf))

    # sandwich diagnostics at W_hat (closed forms again; no autodiff)
    r, kap = score_curvature(W)
    G = Zb * r[:, None, :]                                   # (k, d, n)
    J = G @ jnp.swapaxes(G, 1, 2) / n
    H = (Zb * kap[:, None, :]) @ ZbT / n                     # = -hessian(fun)
    Hreg = H + 1e-9 * eye[None, :, :] + pad_diag
    Hinv = _gauss_jordan_solve(Hreg, jnp.broadcast_to(eye, Hreg.shape))
    V = Hinv @ J @ jnp.swapaxes(Hinv, 1, 2)
    S = jnp.swapaxes(G, 1, 2) @ jnp.swapaxes(Hinv, 1, 2)     # (k, n, d)
    return W, H, J, V, S


def bucket_compile_count() -> int:
    """Bucket-solver compilations since the last ``clear_cache()``.

    Counts across every graph / ``include_singleton`` variant solved so far,
    so callers asserting "compiles == #buckets" should clear the cache first.
    Returns -1 if the (private) jit cache probe disappears in a future JAX.
    """
    probe = getattr(_solve_bucket, "_cache_size", None)
    return int(probe()) if callable(probe) else -1


def fit_all_local_batched(graph: Graph, X: jnp.ndarray,
                          include_singleton: bool = True,
                          theta_fixed: Optional[jnp.ndarray] = None,
                          n_iter: int = 40) -> List[LocalFit]:
    """Fit all p local CL estimators via degree-bucketed batched solves.

    Drop-in replacement for the per-node loop: returns the same
    ``List[LocalFit]`` (ordered by node), with per-node results trimmed back
    to the node's true degree.
    """
    if theta_fixed is None:
        theta_fixed = jnp.zeros(graph.n_params, X.dtype)
    theta_fixed = jnp.asarray(theta_fixed)

    out: List[Optional[LocalFit]] = [None] * graph.p
    for b in degree_buckets(graph):
        offsets = theta_fixed[jnp.asarray(b.nodes)]
        W, H, J, V, S = _solve_bucket(
            X, jnp.asarray(b.nodes), jnp.asarray(b.nbrs),
            jnp.asarray(b.mask), offsets, include_singleton, n_iter)
        W, H, J, V, S = (np.asarray(W), np.asarray(H), np.asarray(J),
                         np.asarray(V), np.asarray(S))
        lead = 1 if include_singleton else 0
        degs = b.mask.sum(axis=1).astype(np.int64)
        for row, i in enumerate(b.nodes):
            i = int(i)
            d = lead + int(degs[row])
            out[i] = LocalFit(
                i=i, beta=graph.beta(i, include_singleton),
                theta=W[row, :d].copy(), H=H[row, :d, :d].copy(),
                J=J[row, :d, :d].copy(), V=V[row, :d, :d].copy(),
                s=S[row, :, :d].copy())
    return out  # type: ignore[return-value]
