"""Batched local-estimator engine: degree-bucketed, vmapped Newton-IRLS,
generalized over exponential-family models.

The paper's local CL estimators (Eq. 3) are p independent node-conditional
GLM fits. The seed implementation fit them in a Python loop — one
separately-jitted solve per node, each recomputing a full autodiff
``jax.hessian`` every Newton iteration. This module exploits the
embarrassing parallelism structurally:

* nodes are grouped into **degree buckets** (degree padded up to the next
  power of four), so XLA compiles one solver per bucket instead of one per
  node;
* within a bucket all k neighbor designs are stacked into a
  ``(k, C, deg, n)`` tensor — C the family's channel count (1 for
  Ising/Gaussian, q-1 for Potts) — and solved simultaneously by batched
  einsum Newton steps;
* gradients and Hessians use each family's **closed-form** per-channel
  score ``r = dl/deta`` and curvature ``kappa = -d2l/deta2`` hooks
  (:class:`repro.core.families.base.ModelFamily`) — logistic
  ``r = 2 x sigma(-2 x eta)``, Gaussian ``r = x - eta`` with constant unit
  curvature (so the "IRLS" is a single weighted least-squares step), and
  multinomial-softmax ``diag(pi) - pi pi'`` cross-channel curvature —
  dropping an autodiff order per iteration relative to ``jax.hessian``;
* Newton systems are solved by a **pure-XLA batched Gauss-Jordan sweep**
  (sign-definite systems need no pivoting), avoiding the per-matrix LAPACK
  dispatch of ``jnp.linalg.solve`` that dominates wall-clock for the tiny
  per-node systems — and the custom-call lowering that dominates compile
  time;
* iteration stops early (``while_loop``) once every node's damped Newton
  step is below tolerance, instead of always burning the full budget.

Padding is exact: padded design columns are zero, so their gradient entries
vanish and the Hessian is block-diagonal with a ``-1`` placeholder on padded
coordinates; the Newton direction on real coordinates is untouched.

Per-node parameters are flat in **coordinate-major block layout**
``[singleton block (C), edge block (C) per incident edge]``, matching
``family.beta``; at C = 1 this is exactly the seed's scalar layout.

Public entry points: :func:`degree_buckets`, :func:`fit_all_local_batched`,
the streaming-ADMM primal update :func:`prox_update_batched`, and the
per-bucket compile-count probe :func:`bucket_compile_count`.

Streaming support (used by :mod:`repro.stream`): ``sample_weight`` lets every
node weight the shared sample pool independently — a 0/1 prefix mask per node
expresses "sensor i has only seen its first n_i rows" without changing array
shapes, so a growing stream stays on one compiled program per (bucket,
capacity); ``warm_start`` seeds Newton at the previous fit so incremental
re-fits converge in a couple of damped steps instead of from scratch.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..kernels.cl.epilogues import get_epilogue
from ..kernels.cl.ops import bucket_newton_stats_op
from ..telemetry.recorder import NULL_RECORDER
from .estimators import LocalFit
from .families import ISING
from .graphs import Graph

# Backtracking candidates for clipped Newton steps, largest first so ties at
# the optimum keep the full step; 0 is the "every direction hurts" escape.
_LS_CAND = np.array([1.0, 0.5, 0.25, 0.125, 0.0625, 0.015625, 0.0],
                    dtype=np.float32)
# Gradient-direction scales tried alongside the Newton candidates: when the
# Hessian is near-singular (saturated fits) the Newton direction can be
# useless at every scale, but a small enough ascent step along the gradient
# of a concave criterion always improves off-optimum — so nodes cannot get
# permanently stuck.
_LS_GRAD = np.array([1.0, 0.25, 0.0625, 0.015625, 0.00390625],
                    dtype=np.float32)


def _backtrack_step(objective, W, dirn, g, max_step):
    """Pick, per node, the best step among scaled Newton and gradient
    candidates by the concave per-node ``objective``; returns (k, d) steps.

    Convention matches the solvers: the update is ``W - step``, so Newton
    candidates are ``s * dirn`` and ascent candidates ``-s * g_unit``.
    """
    k = W.shape[0]
    ncand = jnp.asarray(_LS_CAND, W.dtype)[:, None, None] * dirn[None]
    gnorm = jnp.linalg.norm(g, axis=1, keepdims=True)
    gdir = -g * (max_step / (gnorm + 1e-30))
    gcand = jnp.asarray(_LS_GRAD, W.dtype)[:, None, None] * gdir[None]
    steps = jnp.concatenate([ncand, gcand], axis=0)          # (c, k, d)
    vals = objective(W[None] - steps)
    vals = jnp.where(jnp.isfinite(vals), vals, -jnp.inf)
    best = jnp.argmax(vals, axis=0)                          # (k,)
    return steps[best, jnp.arange(k)]


def _pad_degree(deg: int) -> int:
    """Bucket width for a node of degree ``deg``: next power of 4 (min 1).

    Coarser-than-power-of-2 padding trades a little wasted compute inside a
    bucket (at most 4x on zero columns, which the einsums eat on the VPU)
    for fewer distinct shapes, i.e. fewer XLA compilations.
    """
    pad = 1
    while pad < deg:
        pad *= 4
    return pad


@dataclasses.dataclass(frozen=True)
class DegreeBucket:
    """All nodes whose padded degree is ``deg_pad``, with gather metadata."""
    deg_pad: int
    nodes: np.ndarray      # (k,) node indices, ascending
    nbrs: np.ndarray       # (k, deg_pad) neighbor indices, 0-padded
    mask: np.ndarray       # (k, deg_pad) 1.0 on real columns, 0.0 on padding


@functools.lru_cache(maxsize=64)
def _degree_buckets_cached(graph: Graph):
    by_pad: Dict[int, List[int]] = {}
    nbrs_of: Dict[int, List[int]] = {}
    for i in range(graph.p):
        ks = graph.incident_edges(i)
        others = [graph.edges[k][0] if graph.edges[k][1] == i
                  else graph.edges[k][1] for k in ks]
        nbrs_of[i] = others
        by_pad.setdefault(_pad_degree(len(others)), []).append(i)

    buckets = []
    for deg_pad in sorted(by_pad):
        nodes = np.asarray(sorted(by_pad[deg_pad]), dtype=np.int32)
        k = len(nodes)
        nbrs = np.zeros((k, deg_pad), dtype=np.int32)
        mask = np.zeros((k, deg_pad), dtype=np.float32)
        for row, i in enumerate(nodes):
            d = len(nbrs_of[i])
            nbrs[row, :d] = nbrs_of[i]
            mask[row, :d] = 1.0
        buckets.append(DegreeBucket(deg_pad=deg_pad, nodes=nodes,
                                    nbrs=nbrs, mask=mask))
    return tuple(buckets)


def degree_buckets(graph: Graph) -> List[DegreeBucket]:
    """Group nodes by padded degree; neighbor order matches ``node_design``.

    Columns are ordered like ``graph.incident_edges(i)`` (edge order), which
    is what :func:`repro.core.estimators.node_design` and ``family.beta``
    use, so bucketed estimates line up coordinate-for-coordinate with the
    seed per-node solver. Cached per graph (graphs are frozen/hashable).
    """
    return list(_degree_buckets_cached(graph))


def _gauss_jordan_solve(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """Batched solve A @ X = B for sign-definite A via Gauss-Jordan.

    A: (k, d, d) uniformly positive- or negative-definite (no pivoting
    needed); B: (k, d, m). Pure jnp ops — one fori_loop of rank-1 updates —
    so it lowers to plain XLA vector code instead of per-matrix LAPACK
    custom calls, which dominate both runtime and compile time for the
    small systems this engine solves.
    """
    d = A.shape[-1]
    M = jnp.concatenate([A, B], axis=2)              # (k, d, d + m)

    def body(i, M):
        piv = M[:, i, :] / M[:, i, i][:, None]       # (k, d + m)
        coef = M[:, :, i]                            # (k, d)
        M = M - coef[:, :, None] * piv[:, None, :]
        return M.at[:, i, :].set(piv)                # pivot row normalized

    M = jax.lax.fori_loop(0, d, body, M)
    return M[:, :, d:]


def _solver_dtype(dtype):
    """Newton/solver state dtype for a design dtype.

    bfloat16 designs keep float32 solver state — the mixed-precision mode
    is load/matmul-side only: bf16 designs contracted against float32
    parameters promote every Gram/score accumulation to float32 (see
    :mod:`repro.kernels.cl.precision`), and the Newton iterate, Hessian
    ridge, and convergence test must not quantize. float32/float64 pass
    through untouched (bit-stable with the goldens).
    """
    dtype = jnp.dtype(dtype)
    return jnp.dtype(jnp.float32) if dtype == jnp.bfloat16 else dtype


def _bucket_design(family, X, nodes, nbrs, mask, offsets,
                   include_singleton: bool):
    """Build the channelized (k, C, d, n) bucket design + targets/masks.

    Shared by the plain and proximal bucket solvers. Returns
    ``(Zb, xi, base, cmask)``: per-channel stacked designs, node samples,
    fixed-singleton block offsets folded into ``base`` (k, C, n), and the
    d-length coordinate mask (all channels of a coordinate share one mask
    entry). ``offsets``: (k, C) fixed singleton blocks.
    """
    C = family.block_dim
    # (n, k, deg_pad, C): family features of the gathered neighbor values
    F = family.edge_features(X[:, nbrs])
    # cast the 0/1 mask to the design dtype so a bf16 design stays bf16
    # (f32/f64 designs see the same promotion as before, bit-identically)
    Zt = jnp.transpose(F, (1, 3, 2, 0)) \
        * mask.astype(F.dtype)[:, None, :, None]
    xi = X[:, nodes].T                                       # (k, n)
    k, _, _, n = Zt.shape

    if include_singleton:
        ones = jnp.ones((k, C, 1, n), Zt.dtype)
        Zb = jnp.concatenate([ones, Zt], axis=2)             # (k, C, d, n)
        cmask = jnp.concatenate(
            [jnp.ones((mask.shape[0], 1), mask.dtype), mask], axis=1)
        base = jnp.zeros((k, C, n), Zt.dtype)
    else:
        Zb = Zt
        cmask = mask
        base = offsets[:, :, None] * jnp.ones((k, C, n), Zt.dtype)
    return Zb, xi, base, cmask


def _flat_coord_mask(cmask: jnp.ndarray, C: int) -> jnp.ndarray:
    """(k, d) coordinate mask -> (k, d*C) flat-parameter mask."""
    k, d = cmask.shape
    return jnp.broadcast_to(cmask[:, :, None], (k, d, C)).reshape(k, d * C)


def _channel_ops(family, Zb, base, xi, sw, weighted, denom):
    """Channelized-GLM contraction closures shared by the plain and proximal
    bucket solvers, all in the flat coordinate-major (k, d*C) layout.

    C == 1 (Ising/Gaussian) keeps the seed's single-channel matmul forms —
    XLA contracts them noticeably faster than the general channelized
    einsums. The branch is static (``block_dim`` is a trace-time constant),
    so each family compiles only its own form.

    Returns ``(score_curvature, grad_vec, curvature_matrix, avg_loglik,
    score_matrix, newton_stats)``: per-sample channel score/curvature at a
    flat W, the flat gradient vector from a channel score, the (k, dC, dC)
    curvature matrix from a channel curvature, the (c, k) per-node average
    loglik of a candidate stack, the (k, dC, n) per-sample score matrix,
    and the fused Newton statistics ``W -> (g_raw, K_raw)``.

    ``newton_stats`` is the per-iteration hot path: for families with a
    registered fused-kernel epilogue (``family.kernel_kind``) it goes
    through :func:`repro.kernels.cl.ops.bucket_newton_stats_op` — the fused
    score + Gram entry emitting both directly in this (k, C, d) bucket
    layout (compiled Pallas on TPU, the bit-identical jnp reference
    elsewhere) without materializing the per-sample residual/curvature
    between contractions; families without an epilogue fall back to the
    closed-form hook closures.
    """
    k, C, d, _ = Zb.shape
    dC = d * C
    Z1 = Zb[:, 0] if C == 1 else None

    def eta_of(W):
        if C == 1:
            return base + jnp.einsum("kdn,kd->kn", Z1, W)[:, None, :]
        return base + jnp.einsum("kcdn,kdc->kcn", Zb, W.reshape(k, d, C))

    def score_curvature(W):
        eta = eta_of(W)
        r = family.dl_deta(eta, xi)                          # (k, C, n)
        kap = family.curvature(eta, xi)                      # (k, C, C, n)
        if weighted:
            r = r * sw[:, None, :]
            kap = kap * sw[:, None, None, :]
        return r, kap

    def grad_vec(r):
        if C == 1:
            return jnp.einsum("kdn,kn->kd", Z1, r[:, 0])
        return jnp.einsum("kcdn,kcn->kdc", Zb, r).reshape(k, dC)

    def curvature_matrix(kap):
        if C == 1:
            return (Z1 * kap[:, 0, 0][:, None, :]) @ jnp.swapaxes(Z1, 1, 2)
        H = jnp.einsum("kcdn,kcen,kefn->kdcfe", Zb, kap, Zb)
        return H.reshape(k, dC, dC)

    def avg_loglik(Ws):
        # per-node average conditional loglik for a (c, k, d*C) stack of
        # candidate parameter points; returns (c, k)
        if C == 1:
            etas = base[None] \
                + jnp.einsum("kdn,akd->akn", Z1, Ws)[:, :, None, :]
        else:
            Wb = Ws.reshape(Ws.shape[0], k, d, C)
            etas = base[None] + jnp.einsum("kcdn,akdc->akcn", Zb, Wb)
        ll = family.loglik_eta(etas, xi[None])
        if weighted:
            ll = ll * sw[None]
        return ll.sum(axis=2) / denom[None, :]

    def score_matrix(r):
        if C == 1:
            return Z1 * r[:, 0][:, None, :]                  # (k, d, n)
        n = Zb.shape[-1]
        return jnp.transpose(Zb * r[:, :, None, :],
                             (0, 2, 1, 3)).reshape(k, dC, n)

    kind = getattr(family, "kernel_kind", None)
    fused_kind = kind if get_epilogue(kind) is not None else None

    def newton_stats(W):
        if fused_kind is not None:
            return bucket_newton_stats_op(fused_kind, Zb, base, xi, W,
                                          sw if weighted else None)
        r, kap = score_curvature(W)
        return grad_vec(r), curvature_matrix(kap)

    return score_curvature, grad_vec, curvature_matrix, avg_loglik, \
        score_matrix, newton_stats


def _solve_bucket_impl(X, nodes, nbrs, mask, offsets, W0, sw,
                       include_singleton: bool, n_iter: int,
                       weighted: bool = False, guarded: bool = False,
                       family=ISING, tol: float = 2e-6,
                       ridge: float = 1e-8, max_step: float = 5.0,
                       want_influence: bool = True):
    """Solve every node of one degree bucket in a single XLA program.

    X: (n, p) samples; nodes: (k,); nbrs: (k, deg_pad); mask: (k, deg_pad);
    offsets: (k, C) fixed singleton blocks (used when
    include_singleton=False); W0: (k, d*C) Newton warm start (zeros for a
    cold fit); sw: (k, n) per-node sample weights, only read when
    ``weighted`` — a 0/1 prefix mask lets each node of the bucket see a
    different prefix of a shared streaming pool at fixed array shapes.
    ``family`` (static) supplies the closed-form per-channel score and
    curvature; the Ising default reproduces the seed engine exactly.

    Designs live in (k, C, d, n) layout so the per-iteration Hessian is one
    batched einsum contracting over the contiguous sample axis; for C = 1
    the channel axes collapse and nothing is wasted. ``tol`` (on the damped
    step's inf-norm) is chosen just above the float32 jitter floor: iterating
    past it only bounces around the optimum, which is all the seed's fixed
    40-iteration schedule does after convergence.

    Returns (W, H, J, V, S, I) with leading bucket dimension k and flat
    parameter dimension d*C (coordinate-major blocks); padded coordinates
    are exactly zero in W and carry a ``-1`` placeholder diagonal in the
    Newton system. ``I`` is the (k,) Newton-iteration count the damped
    solve actually used (bucket-wide — the while_loop stops when every
    node's step converged — broadcast per node so it shards like the other
    outputs). A node whose weights sum to zero (nothing observed yet)
    stays at W0 untouched by data: its gradient vanishes and the guarded
    denominator keeps it finite.
    """
    n = X.shape[0]
    Zb, xi, base, cmask = _bucket_design(family, X, nodes, nbrs, mask,
                                         offsets, include_singleton)
    k, C, d, _ = Zb.shape
    dC = d * C
    cdtype = _solver_dtype(Zb.dtype)
    W0 = W0.astype(cdtype)
    eye = jnp.eye(dC, dtype=cdtype)
    # -1 on padded diagonals keeps the (exactly block-diagonal) system
    # uniformly negative definite without touching the real block's
    # Newton direction.
    cflat = _flat_coord_mask(cmask, C)
    pad_diag = (1.0 - cflat)[:, :, None] * eye[None, :, :]
    if weighted:
        denom = jnp.maximum(jnp.sum(sw, axis=1), 1.0)        # (k,)
    else:
        denom = jnp.full((k,), float(n), cdtype)

    score_curvature, grad_vec, curvature_matrix, objective, score_matrix, \
        newton_stats = _channel_ops(family, Zb, base, xi, sw, weighted, denom)

    def cond(carry):
        _, it, delta = carry
        return (it < n_iter) & (delta > tol)

    def newton_step(carry):
        W, it, _ = carry
        g_raw, K_raw = newton_stats(W)           # fused score + Gram
        g = g_raw / denom[:, None]
        H = -K_raw / denom[:, None, None] \
            - ridge * eye[None, :, :] - pad_diag
        dirn = _gauss_jordan_solve(H, g[..., None])[..., 0]  # (k, dC)
        # an untrusted direction: non-finite (curvature underflow at a
        # saturated point makes the solve blow up) or clipped (outside
        # Newton's trust region). NaN directions are zeroed so they cannot
        # poison the bucket-wide convergence check.
        finite = jnp.all(jnp.isfinite(dirn), axis=1, keepdims=True)
        dirn = jnp.where(finite, dirn, 0.0)
        norm = jnp.linalg.norm(dirn, axis=1, keepdims=True)
        untrusted = (norm > max_step) | ~finite
        dirn = jnp.where(norm > max_step,
                         dirn * (max_step / (norm + 1e-30)), dirn)
        if guarded:
            # An untrusted direction means the quadratic model failed there
            # — a full clipped step from a saturated warm start can land
            # where the next clipped step points exactly back (a period-2
            # cycle), and a near-singular Hessian can make the direction
            # useless at any scale. Guard with a per-node backtracking
            # search over Newton + gradient candidates on the concave CL
            # objective. Only warm-started solves compile this branch: the
            # pathologies need a saturated starting point, and cold starts
            # from zero (the benchmarked hot path) never produce one.
            step = jax.lax.cond(
                jnp.any(untrusted),
                lambda: _backtrack_step(objective, W, dirn, g, max_step),
                lambda: dirn)
        else:
            step = dirn
        delta = jnp.max(jnp.abs(step))
        return W - step, it + 1, delta

    W, iters, _ = jax.lax.while_loop(cond, newton_step, (W0, 0, jnp.inf))
    I = jnp.full((k,), iters, dtype=jnp.int32)

    # sandwich diagnostics at W_hat (closed forms again; no autodiff).
    # Under 0/1 weights the masked-out samples' scores are zeroed, so their
    # rows of S are exactly zero and J/H average only the live samples;
    # consumers that normalize influence columns by the row count (the
    # "optimal" combiner) should use the live count, not the buffer size.
    r, kap = score_curvature(W)
    G = score_matrix(r)                                      # (k, dC, n)
    J = G @ jnp.swapaxes(G, 1, 2) / denom[:, None, None]
    H = curvature_matrix(kap) / denom[:, None, None]         # = -hessian
    Hreg = H + 1e-9 * eye[None, :, :] + pad_diag
    Hinv = _gauss_jordan_solve(Hreg, jnp.broadcast_to(eye, Hreg.shape))
    V = Hinv @ J @ jnp.swapaxes(Hinv, 1, 2)
    if want_influence:
        S = jnp.swapaxes(G, 1, 2) @ jnp.swapaxes(Hinv, 1, 2)  # (k, n, dC)
    else:
        # only the Linear-Opt combiner reads the (k, n, dC) per-sample
        # influence stack; a session whose combiners never request
        # "influence" skips materializing it (static branch)
        S = jnp.zeros((k, 0, dC), cdtype)
    return W, H, J, V, S, I


@functools.partial(jax.jit,
                   static_argnames=("include_singleton", "n_iter", "weighted",
                                    "guarded", "family", "want_influence"))
def _solve_bucket(X, nodes, nbrs, mask, offsets, W0, sw,
                  include_singleton: bool, n_iter: int, weighted: bool = False,
                  guarded: bool = False, family=ISING, tol: float = 2e-6,
                  ridge: float = 1e-8, max_step: float = 5.0,
                  want_influence: bool = True):
    """Single-device bucket solve (jitted :func:`_solve_bucket_impl`)."""
    return _solve_bucket_impl(X, nodes, nbrs, mask, offsets, W0, sw,
                              include_singleton, n_iter, weighted, guarded,
                              family, tol, ridge, max_step, want_influence)


def _mesh_data_size(mesh) -> int:
    """Size of the mesh's ``data`` axis; clear error when there isn't one."""
    if "data" not in mesh.axis_names:
        raise ValueError(
            f"batched engine shards degree buckets along a 'data' mesh axis;"
            f" mesh has axes {tuple(mesh.axis_names)}")
    return int(mesh.shape["data"])


@functools.partial(jax.jit,
                   static_argnames=("include_singleton", "n_iter", "weighted",
                                    "guarded", "family", "mesh",
                                    "want_influence"))
def _solve_bucket_sharded(X, nodes, nbrs, mask, offsets, W0, sw,
                          include_singleton: bool, n_iter: int,
                          weighted: bool = False, guarded: bool = False,
                          family=ISING, mesh=None,
                          want_influence: bool = True):
    """Mesh-sharded bucket solve: nodes split along the ``data`` axis.

    The bucket's k per-node problems are embarrassingly parallel, so each
    device solves its contiguous slice of the (padded) node axis against
    the replicated sample pool — no collectives at all. On a one-device
    mesh (the host mesh) the single shard is the whole bucket and the
    computation is identical to :func:`_solve_bucket` op for op, which is
    what makes the single-device fallback numerically exact. The caller
    pads the node axis to a multiple of the shard count
    (:func:`_pad_bucket_rows`).
    """
    body = functools.partial(
        _solve_bucket_impl, include_singleton=include_singleton,
        n_iter=n_iter, weighted=weighted, guarded=guarded, family=family,
        want_influence=want_influence)
    data = P("data")
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), data, data, data, data, data,
                  data if weighted else P()),
        out_specs=(data, data, data, data, data, data),
        check_rep=False,
    )(X, nodes, nbrs, mask, offsets, W0, sw)


def _pad_bucket_rows(shards: int, *arrays):
    """Zero-pad each array's leading (bucket-node) axis to a multiple of
    ``shards`` so shard_map can split it evenly. Padded rows are inert
    dummy problems (zero design mask / zero weights) whose results the
    caller slices off."""
    k = arrays[0].shape[0]
    pad = (-k) % shards
    if pad == 0:
        return arrays
    out = []
    for a in arrays:
        widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        out.append(jnp.pad(a, widths))
    return tuple(out)


def bucket_compile_count() -> int:
    """Bucket-solver compilations since the last ``clear_cache()``, summed
    over the plain AND mesh-sharded fit solvers — so compile-reuse
    invariants (cold == #buckets, warm == 0) hold for mesh-policy sessions
    too, not just the single-program path.

    Counts across every graph / family / ``include_singleton`` variant
    solved so far, so callers asserting "compiles == #buckets" should clear
    the caches first. Returns -1 if the (private) jit cache probe
    disappears in a future JAX.
    """
    total = 0
    for fn in (_solve_bucket, _solve_bucket_sharded):
        probe = getattr(fn, "_cache_size", None)
        if not callable(probe):
            return -1
        total += int(probe())
    return total


def clear_bucket_solver_caches() -> None:
    """Reset the bucket-solver compile caches — fit AND proximal, plain
    and mesh-sharded — so :func:`bucket_compile_count` and
    :func:`prox_compile_count` restart from zero — what tests and
    benches asserting the absolute "compiles == #buckets" invariant call
    first."""
    _solve_bucket.clear_cache()
    _solve_bucket_sharded.clear_cache()
    _solve_bucket_prox.clear_cache()
    _solve_bucket_prox_sharded.clear_cache()


def _bucket_weights(sample_weight, nodes: np.ndarray, n: int):
    """Per-bucket (k, n) weight rows from a global (n,) or per-node (p, n)
    sample-weight array; ``None`` means unweighted."""
    if sample_weight is None:
        return None
    sample_weight = jnp.asarray(sample_weight)
    if sample_weight.ndim == 1:
        return jnp.broadcast_to(sample_weight[None, :], (len(nodes), n))
    return sample_weight[jnp.asarray(nodes)]


def _bucket_warm_start(warm_start, b: DegreeBucket, dC: int, lead: int,
                       C: int, dtype) -> jnp.ndarray:
    """Stack per-node warm-start thetas into the bucket's padded (k, d*C)."""
    W0 = np.zeros((len(b.nodes), dC), dtype=np.float32)
    if warm_start is not None:
        degs = b.mask.sum(axis=1).astype(np.int64)
        for row, i in enumerate(b.nodes):
            w = warm_start[int(i)]
            if w is None:
                continue
            di = (lead + int(degs[row])) * C
            W0[row, :di] = np.asarray(w, dtype=np.float32)[:di]
    return jnp.asarray(W0, dtype=dtype)


def fit_all_local_batched(graph: Graph, X: jnp.ndarray,
                          include_singleton: bool = True,
                          theta_fixed: Optional[jnp.ndarray] = None,
                          n_iter: int = 40,
                          sample_weight: Optional[jnp.ndarray] = None,
                          warm_start: Optional[Sequence] = None,
                          family=None, mesh=None,
                          want_influence: bool = True,
                          recorder=None,
                          stats: Optional[dict] = None) -> List[LocalFit]:
    """Fit all p local CL estimators via degree-bucketed batched solves.

    Drop-in replacement for the per-node loop: returns the same
    ``List[LocalFit]`` (ordered by node), with per-node results trimmed back
    to the node's true block count. ``family`` selects the model family
    (default Ising); local parameter vectors follow
    ``family.beta(graph, i, include_singleton)`` block order.

    Streaming extensions:
      sample_weight — ``(n,)`` shared or ``(p, n)`` per-node 0/1 observation
        masks over the sample pool; rows with weight 0 are invisible to the
        fit (so a zero-padded, capacity-doubling buffer compiles once per
        capacity, not once per sample count). Weights are meant to be masks;
        the sandwich J uses the masked scores directly.
      warm_start — optional length-p sequence of previous per-node thetas
        (``None`` entries allowed) used to seed Newton; incremental re-fits
        then converge in a couple of damped steps.

    Scale-out: ``mesh`` (a :func:`jax.make_mesh` mesh with a ``data`` axis,
    e.g. from :mod:`repro.launch.mesh`) runs every bucket solve through
    :func:`_solve_bucket_sharded` — bucket nodes sharded along the ``data``
    axis, sample pool replicated. On a one-device mesh the sharded path is
    numerically identical to the default path; ``mesh=None`` keeps the
    plain single-program solve.

    ``want_influence=False`` skips materializing the (n, d) per-sample
    influence stacks (``LocalFit.s`` comes back with zero rows) — only the
    Linear-Opt combiner reads them, and a compiled estimation session whose
    requested combiners never declare ``"influence"`` opts out.

    Observability: ``recorder`` (a :mod:`repro.telemetry` recorder; the
    allocation-free ``NULL_RECORDER`` when None) gets one ``bucket_solve``
    span per degree bucket with Newton-iteration histograms; ``stats``
    (a caller-provided dict) receives the compile-time split —
    ``stats["compile_s"]`` accumulates the wall seconds of bucket
    dispatches that triggered a compilation (the first-dispatch path) and
    ``stats["dispatch_s"]`` the total dispatch wall. Both default to off
    and cost nothing when unused.
    """
    if family is None:
        family = ISING
    rec = NULL_RECORDER if recorder is None else recorder
    track = stats is not None or rec.enabled
    C = family.block_dim
    if theta_fixed is None:
        theta_fixed = jnp.zeros(family.n_params(graph), X.dtype)
    theta_fixed = jnp.asarray(theta_fixed)
    node_tf = theta_fixed[: graph.p * C].reshape(graph.p, C)
    n = X.shape[0]
    lead = 1 if include_singleton else 0

    out: List[Optional[LocalFit]] = [None] * graph.p
    for b in degree_buckets(graph):
        k = len(b.nodes)
        offsets = node_tf[jnp.asarray(b.nodes)]
        dC = (b.deg_pad + lead) * C
        sw = _bucket_weights(sample_weight, b.nodes, n)
        W0 = _bucket_warm_start(warm_start, b, dC, lead, C,
                                _solver_dtype(X.dtype))
        weighted = sample_weight is not None
        if sw is None:
            sw = jnp.ones((1, 1), _solver_dtype(X.dtype))  # never read
        if track:
            c0 = bucket_compile_count()
            t0 = time.perf_counter()
        span = (rec.span("bucket_solve", deg_pad=b.deg_pad, k=k)
                if rec.enabled else None)
        if span is not None:
            span.__enter__()
        if mesh is None:
            W, H, J, V, S, I = _solve_bucket(
                X, jnp.asarray(b.nodes), jnp.asarray(b.nbrs),
                jnp.asarray(b.mask), offsets, W0, sw, include_singleton,
                n_iter, weighted, warm_start is not None, family,
                want_influence=want_influence)
        else:
            shards = _mesh_data_size(mesh)
            nodes_, nbrs_, mask_, offsets_, W0_ = _pad_bucket_rows(
                shards, jnp.asarray(b.nodes), jnp.asarray(b.nbrs),
                jnp.asarray(b.mask), offsets, W0)
            sw_ = _pad_bucket_rows(shards, sw)[0] if weighted else sw
            W, H, J, V, S, I = _solve_bucket_sharded(
                X, nodes_, nbrs_, mask_, offsets_, W0_, sw_,
                include_singleton, n_iter, weighted,
                warm_start is not None, family, mesh,
                want_influence=want_influence)
        W, H, J, V, S = (np.asarray(W)[:k], np.asarray(H)[:k],
                         np.asarray(J)[:k], np.asarray(V)[:k],
                         np.asarray(S)[:k])
        if span is not None:
            span.__exit__(None, None, None)
        if track:
            # the np.asarray conversions above block on the device work, so
            # dt covers trace+compile+execute for a compiling dispatch
            dt = time.perf_counter() - t0
            c1 = bucket_compile_count()
            compiled = c1 > c0 >= 0
            if stats is not None:
                stats["dispatch_s"] = stats.get("dispatch_s", 0.0) + dt
                if compiled:
                    stats["compile_s"] = stats.get("compile_s", 0.0) + dt
            if rec.enabled:
                rec.observe("engine.newton_iters", int(np.max(np.asarray(I)[:k])),
                            deg_pad=b.deg_pad)
                rec.observe("engine.bucket_dispatch_s", dt,
                            deg_pad=b.deg_pad, compiled=compiled)
        degs = b.mask.sum(axis=1).astype(np.int64)
        for row, i in enumerate(b.nodes):
            i = int(i)
            di = (lead + int(degs[row])) * C
            out[i] = LocalFit(
                i=i, beta=family.beta(graph, i, include_singleton),
                theta=W[row, :di].copy(), H=H[row, :di, :di].copy(),
                J=J[row, :di, :di].copy(), V=V[row, :di, :di].copy(),
                s=S[row, :, :di].copy())
    return out  # type: ignore[return-value]


# ------------------------------------------------------- proximal updates
def _solve_bucket_prox_impl(X, nodes, nbrs, mask, offsets, W0, sw, lam, rho,
                            tbar, include_singleton: bool, n_iter: int,
                            weighted: bool = False, family=ISING,
                            tol: float = 2e-6, ridge: float = 1e-8,
                            max_step: float = 5.0):
    """ADMM primal update for a whole degree bucket in one XLA program.

    Maximizes, per node,  ``l^i(w) - lam'w - sum_a rho_a (w_a - tbar_a)^2/2``
    (the objective of :func:`repro.core.admm._prox_solve`) with the same
    closed-form family-dispatched Newton machinery as :func:`_solve_bucket`:
    the prox terms only shift the gradient by ``-lam - rho*(w - tbar)`` and
    the Hessian by ``-diag(rho)``, so the bucket stays uniformly negative
    definite. lam, rho, tbar: (k, d*C) with zeros on padded coordinates.
    Returns W only.
    """
    n = X.shape[0]
    Zb, xi, base, cmask = _bucket_design(family, X, nodes, nbrs, mask,
                                         offsets, include_singleton)
    k, C, d, _ = Zb.shape
    dC = d * C
    cdtype = _solver_dtype(Zb.dtype)
    W0 = W0.astype(cdtype)
    eye = jnp.eye(dC, dtype=cdtype)
    cflat = _flat_coord_mask(cmask, C)
    pad_diag = (1.0 - cflat)[:, :, None] * eye[None, :, :]
    rho_diag = rho[:, :, None] * eye[None, :, :]
    if weighted:
        denom = jnp.maximum(jnp.sum(sw, axis=1), 1.0)
    else:
        denom = jnp.full((k,), float(n), cdtype)

    score_curvature, grad_vec, curvature_matrix, avg_loglik, _, \
        newton_stats = _channel_ops(family, Zb, base, xi, sw, weighted, denom)

    def objective(Ws):
        # (c, k): penalized criterion for a stack of candidate points
        pen = (lam[None] * Ws).sum(axis=2) \
            + 0.5 * (rho[None] * (Ws - tbar[None]) ** 2).sum(axis=2)
        return avg_loglik(Ws) - pen

    def cond(carry):
        _, it, delta = carry
        return (it < n_iter) & (delta > tol)

    def newton_step(carry):
        W, it, _ = carry
        g_raw, K_raw = newton_stats(W)           # fused score + Gram
        g = g_raw / denom[:, None] - lam - rho * (W - tbar)
        H = -K_raw / denom[:, None, None] \
            - rho_diag - ridge * eye[None, :, :] - pad_diag
        dirn = _gauss_jordan_solve(H, g[..., None])[..., 0]
        finite = jnp.all(jnp.isfinite(dirn), axis=1, keepdims=True)
        dirn = jnp.where(finite, dirn, 0.0)
        norm = jnp.linalg.norm(dirn, axis=1, keepdims=True)
        untrusted = (norm > max_step) | ~finite
        dirn = jnp.where(norm > max_step,
                         dirn * (max_step / (norm + 1e-30)), dirn)

        # same saturation guard as _solve_bucket, on the penalized objective
        step = jax.lax.cond(
            jnp.any(untrusted),
            lambda: _backtrack_step(objective, W, dirn, g, max_step),
            lambda: dirn)
        delta = jnp.max(jnp.abs(step))
        return W - step, it + 1, delta

    W, _, _ = jax.lax.while_loop(cond, newton_step, (W0, 0, jnp.inf))
    return W


@functools.partial(jax.jit,
                   static_argnames=("include_singleton", "n_iter", "weighted",
                                    "family"))
def _solve_bucket_prox(X, nodes, nbrs, mask, offsets, W0, sw, lam, rho, tbar,
                       include_singleton: bool, n_iter: int,
                       weighted: bool = False, family=ISING, tol: float = 2e-6,
                       ridge: float = 1e-8, max_step: float = 5.0):
    """Single-device proximal bucket solve (jitted impl)."""
    return _solve_bucket_prox_impl(X, nodes, nbrs, mask, offsets, W0, sw,
                                   lam, rho, tbar, include_singleton, n_iter,
                                   weighted, family, tol, ridge, max_step)


@functools.partial(jax.jit,
                   static_argnames=("include_singleton", "n_iter", "weighted",
                                    "family", "mesh"))
def _solve_bucket_prox_sharded(X, nodes, nbrs, mask, offsets, W0, sw, lam,
                               rho, tbar, include_singleton: bool,
                               n_iter: int, weighted: bool = False,
                               family=ISING, mesh=None):
    """Mesh-sharded proximal bucket solve — the ADMM-primal twin of
    :func:`_solve_bucket_sharded` (same data-axis node sharding, replicated
    sample pool, no collectives)."""
    body = functools.partial(
        _solve_bucket_prox_impl, include_singleton=include_singleton,
        n_iter=n_iter, weighted=weighted, family=family)
    data = P("data")
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), data, data, data, data, data,
                  data if weighted else P(), data, data, data),
        out_specs=data,
        check_rep=False,
    )(X, nodes, nbrs, mask, offsets, W0, sw, lam, rho, tbar)


def prox_compile_count() -> int:
    """Proximal-solver compilations (plain + mesh-sharded) — the ADMM twin
    of :func:`bucket_compile_count`, used for the joint verb's
    compile-time split. Returns -1 if the jit-cache probe is gone."""
    total = 0
    for fn in (_solve_bucket_prox, _solve_bucket_prox_sharded):
        probe = getattr(fn, "_cache_size", None)
        if not callable(probe):
            return -1
        total += int(probe())
    return total


def group_soft_threshold(v: np.ndarray, thr: float, block_dim: int,
                         lead: int = 1) -> np.ndarray:
    """Group soft-thresholding on a ``family.beta``-ordered local vector.

    The proximal operator of ``thr * sum_blocks ||w_block||_2`` in the
    coordinate-major per-node layout the bucket solvers emit: the first
    ``lead`` blocks (the unpenalized singleton block, when free) pass
    through untouched; every following ``block_dim``-wide edge block ``g``
    is scaled by ``max(0, 1 - thr / ||g||_2)`` — shrunk toward zero and
    EXACTLY zeroed once its norm falls below ``thr``, which is what lets
    structure learning read the support off the iterate with no epsilon
    tolerance. At C = 1 this is the scalar soft-threshold, so plain-lasso
    Ising/Gaussian selection and group-lasso Potts selection share one
    code path (the z-update half of the ADMM split whose smooth half is
    :func:`prox_update_batched`).
    """
    v = np.asarray(v, dtype=np.float64)
    off = lead * block_dim
    nblk, rem = divmod(v.size - off, block_dim)
    if rem:
        raise ValueError(
            f"vector of length {v.size} is not lead={lead} plus whole "
            f"blocks of size {block_dim}")
    out = v.copy()
    if nblk > 0 and thr > 0.0:
        blocks = out[off:].reshape(nblk, block_dim)
        norms = np.linalg.norm(blocks, axis=1)
        scale = np.where(norms > thr,
                         1.0 - thr / np.where(norms > 0.0, norms, 1.0), 0.0)
        out[off:] = (blocks * scale[:, None]).ravel()
    return out


def prox_update_batched(graph: Graph, X: jnp.ndarray,
                        theta_bar: np.ndarray,
                        lambdas: Sequence[np.ndarray],
                        rhos: Sequence[np.ndarray],
                        thetas0: Optional[Sequence[np.ndarray]] = None,
                        include_singleton: bool = True,
                        theta_fixed: Optional[jnp.ndarray] = None,
                        sample_weight: Optional[jnp.ndarray] = None,
                        n_iter: int = 15, family=None,
                        mesh=None, recorder=None,
                        stats: Optional[dict] = None) -> List[np.ndarray]:
    """Batched ADMM primal update across all nodes (one solve per bucket).

    Per-node inputs follow :func:`repro.core.admm.admm_mple`: ``lambdas`` /
    ``rhos`` are length-p lists of ``beta_i``-length vectors, ``theta_bar``
    is the full flat consensus iterate — or, for asynchronous streaming
    where every node holds its own possibly-stale consensus view, a
    length-p list of ``beta_i``-length vectors. ``thetas0`` are optional
    warm starts (defaults to the consensus view restricted to ``beta_i``).
    Supports the same ``sample_weight`` masks as
    :func:`fit_all_local_batched`, which is what lets the streaming engine
    run ADMM rounds over a growing buffer without recompiling, the same
    ``family`` dispatch (default Ising; ``beta_i`` then follows
    ``family.beta`` block order), and the same ``mesh`` scale-out path
    (bucket nodes sharded along the mesh's ``data`` axis). Returns the
    updated per-node theta vectors.

    ``recorder`` / ``stats`` mirror :func:`fit_all_local_batched`: one
    ``prox_bucket_solve`` span per bucket, and ``stats["compile_s"]`` /
    ``stats["dispatch_s"]`` accumulation keyed to the prox-solver caches.
    """
    if family is None:
        family = ISING
    rec = NULL_RECORDER if recorder is None else recorder
    track = stats is not None or rec.enabled
    C = family.block_dim
    if theta_fixed is None:
        theta_fixed = jnp.zeros(family.n_params(graph), X.dtype)
    theta_fixed = jnp.asarray(theta_fixed)
    node_tf = theta_fixed[: graph.p * C].reshape(graph.p, C)
    per_node_bar = isinstance(theta_bar, (list, tuple))
    if not per_node_bar:
        theta_bar = np.asarray(theta_bar)
    n = X.shape[0]
    lead = 1 if include_singleton else 0

    out: List[Optional[np.ndarray]] = [None] * graph.p
    for b in degree_buckets(graph):
        k = len(b.nodes)
        dC = (b.deg_pad + lead) * C
        degs = b.mask.sum(axis=1).astype(np.int64)
        lam = np.zeros((k, dC), dtype=np.float32)
        rho = np.zeros((k, dC), dtype=np.float32)
        tbar = np.zeros((k, dC), dtype=np.float32)
        for row, i in enumerate(b.nodes):
            i = int(i)
            di = (lead + int(degs[row])) * C
            lam[row, :di] = np.asarray(lambdas[i])[:di]
            rho[row, :di] = np.asarray(rhos[i])[:di]
            if per_node_bar:
                tbar[row, :di] = np.asarray(theta_bar[i])[:di]
            else:
                beta = np.asarray(family.beta(graph, i, include_singleton))
                tbar[row, :di] = theta_bar[beta][:di]
        # warm-start at the previous iterate where given; nodes without one
        # (thetas0 absent or a None entry) start at their consensus view
        W0 = np.array(tbar, copy=True)
        if thetas0 is not None:
            for row, i in enumerate(b.nodes):
                t0 = thetas0[int(i)]
                if t0 is not None:
                    di = (lead + int(degs[row])) * C
                    W0[row, :di] = np.asarray(t0, dtype=np.float32)[:di]
        W0 = jnp.asarray(W0, dtype=_solver_dtype(X.dtype))
        sw = _bucket_weights(sample_weight, b.nodes, n)
        weighted = sample_weight is not None
        if sw is None:
            sw = jnp.ones((1, 1), _solver_dtype(X.dtype))
        offsets = node_tf[jnp.asarray(b.nodes)]
        if track:
            c0 = prox_compile_count()
            t0 = time.perf_counter()
        span = (rec.span("prox_bucket_solve", deg_pad=b.deg_pad, k=k)
                if rec.enabled else None)
        if span is not None:
            span.__enter__()
        if mesh is None:
            W = _solve_bucket_prox(
                X, jnp.asarray(b.nodes), jnp.asarray(b.nbrs),
                jnp.asarray(b.mask), offsets, W0, sw,
                jnp.asarray(lam), jnp.asarray(rho), jnp.asarray(tbar),
                include_singleton, n_iter, weighted, family)
        else:
            shards = _mesh_data_size(mesh)
            nodes_, nbrs_, mask_, offsets_, W0_, lam_, rho_, tbar_ = \
                _pad_bucket_rows(shards, jnp.asarray(b.nodes),
                                 jnp.asarray(b.nbrs), jnp.asarray(b.mask),
                                 offsets, W0, jnp.asarray(lam),
                                 jnp.asarray(rho), jnp.asarray(tbar))
            sw_ = _pad_bucket_rows(shards, sw)[0] if weighted else sw
            W = _solve_bucket_prox_sharded(
                X, nodes_, nbrs_, mask_, offsets_, W0_, sw_, lam_, rho_,
                tbar_, include_singleton, n_iter, weighted, family, mesh)
        W = np.asarray(W)[:len(b.nodes)]
        if span is not None:
            span.__exit__(None, None, None)
        if track:
            dt = time.perf_counter() - t0
            c1 = prox_compile_count()
            compiled = c1 > c0 >= 0
            if stats is not None:
                stats["dispatch_s"] = stats.get("dispatch_s", 0.0) + dt
                if compiled:
                    stats["compile_s"] = stats.get("compile_s", 0.0) + dt
            if rec.enabled:
                rec.observe("engine.prox_dispatch_s", dt,
                            deg_pad=b.deg_pad, compiled=compiled)
        for row, i in enumerate(b.nodes):
            di = (lead + int(degs[row])) * C
            out[int(i)] = W[row, :di].copy()
    return out  # type: ignore[return-value]
