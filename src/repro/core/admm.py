"""Joint MPLE via ADMM (paper Sec. 3.2, Thm 3.1).

The joint optimization (Eq. 6) is decomposed into per-node proximal updates
plus a linear-consensus averaging step; initializing theta_bar at a
consistent one-step estimator (and lambda = 0) keeps every iterate
asymptotically consistent — the "any-time" property.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .asymptotics import param_owners
from .consensus import combine
from .estimators import LocalFit, newton_maximize, node_cl_fn
from .graphs import Graph


import functools


@functools.partial(jax.jit, static_argnames=("include_singleton", "n_iter"))
def _prox_solve(Z, xi, offset, lam, rho, tbar_beta, w0,
                include_singleton: bool, n_iter: int):
    """Node-i ADMM primal update: argmax l^i(w) - lam'w - sum rho/2 (w-tbar)^2."""
    if include_singleton:
        def ll(w):
            eta = w[0] + Z @ w[1:]
            return jnp.mean(jax.nn.log_sigmoid(2.0 * xi * eta))
    else:
        def ll(w):
            eta = offset + Z @ w
            return jnp.mean(jax.nn.log_sigmoid(2.0 * xi * eta))

    def obj(w):
        return ll(w) - lam @ w - jnp.sum(rho * (w - tbar_beta) ** 2) / 2.0

    return newton_maximize(obj, w0, n_iter=n_iter)


@dataclasses.dataclass
class ADMMResult:
    trajectory: np.ndarray        # (n_iters + 1, n_params) theta_bar iterates
    primal_residual: np.ndarray   # (n_iters,) ||theta^i - theta_bar|| rms


def _rho_from_fits(graph: Graph, fits: Optional[List[LocalFit]],
                   scheme: str, include_singleton: bool) -> List[np.ndarray]:
    """Per-node penalty vectors rho^i_{beta_i} matching consensus weights."""
    rhos = []
    for i in range(graph.p):
        beta = graph.beta(i, include_singleton)
        if scheme == "uniform" or fits is None:
            rhos.append(np.ones(len(beta)))
        elif scheme == "diagonal":
            V = fits[i].V
            rhos.append(1.0 / np.maximum(np.diag(V), 1e-12))
        else:
            raise ValueError(scheme)
    return rhos


def admm_mple(graph: Graph, X: jnp.ndarray, n_iters: int = 30,
              init: str = "diagonal",
              fits: Optional[List[LocalFit]] = None,
              include_singleton: bool = True,
              theta_fixed: Optional[np.ndarray] = None,
              newton_iters: int = 15) -> ADMMResult:
    """Run ADMM on the joint MPLE objective.

    init: "zero" (theta_bar = 0, rho = 1) or "uniform"/"diagonal"
    (theta_bar = the corresponding one-step linear consensus, rho = its
    weights), matching Fig. 3(c).
    """
    if theta_fixed is None:
        theta_fixed = np.zeros(graph.n_params)
    tf = jnp.asarray(theta_fixed)

    if init == "zero":
        theta_bar = np.array(theta_fixed, copy=True)
        rho_scheme = "uniform"
        rhos = _rho_from_fits(graph, None, "uniform", include_singleton)
    else:
        assert fits is not None, "one-step init requires local fits"
        theta_bar = combine(graph, fits, init, include_singleton, theta_fixed)
        rho_scheme = init
        rhos = _rho_from_fits(graph, fits, init, include_singleton)

    owners = param_owners(graph, include_singleton)
    betas = [graph.beta(i, include_singleton) for i in range(graph.p)]
    lambdas = [np.zeros(len(b)) for b in betas]
    # local estimates start at the consensus value restricted to beta_i
    thetas = [np.array(theta_bar[np.asarray(b)]) for b in betas]

    # Shape-cached jitted prox solves: nodes of equal degree share a compile.
    from .estimators import node_design
    designs = [node_design(graph, X, i) for i in range(graph.p)]

    traj = [np.array(theta_bar, copy=True)]
    resid = []
    for _ in range(n_iters):
        # 1) local proximal updates
        for i in range(graph.p):
            b = np.asarray(betas[i])
            thetas[i] = np.asarray(
                _prox_solve(designs[i], X[:, i], tf[i],
                            jnp.asarray(lambdas[i]), jnp.asarray(rhos[i]),
                            jnp.asarray(theta_bar[b]), jnp.asarray(thetas[i]),
                            include_singleton, newton_iters))
        # 2) weighted linear consensus
        new_bar = np.array(theta_bar, copy=True)
        for a, own in owners.items():
            num, den = 0.0, 0.0
            for (i, pos) in own:
                num += rhos[i][pos] * thetas[i][pos]
                den += rhos[i][pos]
            new_bar[a] = num / den
        theta_bar = new_bar
        # 3) dual ascent
        r2, cnt = 0.0, 0
        for i in range(graph.p):
            b = np.asarray(betas[i])
            diff = thetas[i] - theta_bar[b]
            lambdas[i] = lambdas[i] + rhos[i] * diff
            r2 += float(diff @ diff)
            cnt += len(b)
        resid.append(np.sqrt(r2 / max(cnt, 1)))
        traj.append(np.array(theta_bar, copy=True))

    return ADMMResult(trajectory=np.stack(traj),
                      primal_residual=np.asarray(resid))


def rho_from_fits(graph: Graph, fits, scheme: str,
                  include_singleton: bool = True,
                  family=None) -> List[np.ndarray]:
    """Per-node penalty vectors rho^i_{beta_i} matching consensus weights —
    family-generic: "uniform" (or no fits) gives unit penalties, "diagonal"
    the inverse sandwich-variance diagonals of the local fits.

    The family-generic sibling of the private Ising helper; block order
    follows ``family.beta`` (the scalar seed layout when ``family=None``).
    """
    rhos = []
    for i in range(graph.p):
        beta = (graph.beta(i, include_singleton) if family is None
                else family.beta(graph, i, include_singleton))
        if scheme == "uniform" or fits is None:
            rhos.append(np.ones(len(beta)))
        elif scheme == "diagonal":
            rhos.append(1.0 / np.maximum(np.diag(fits[i].V), 1e-12))
        else:
            raise ValueError(
                f"ADMM penalty scheme must be 'uniform' or 'diagonal', "
                f"got {scheme!r}")
    return rhos


def admm_mple_family(graph: Graph, X, n_iters: int = 30,
                     init: str = "diagonal",
                     fits: Optional[List[LocalFit]] = None,
                     include_singleton: bool = True,
                     theta_fixed: Optional[np.ndarray] = None,
                     newton_iters: int = 15, family=None,
                     mesh=None, sample_weight=None,
                     rho0: float = 1.0, recorder=None,
                     stats: Optional[dict] = None) -> ADMMResult:
    """Joint MPLE via ADMM, generalized over the model-family contract and
    run through the degree-bucketed batched proximal engine.

    The same decomposition as :func:`admm_mple` — per-node proximal primal
    updates, weighted linear consensus, dual ascent — but every primal
    round is ONE :func:`repro.core.batched.prox_update_batched` call (one
    compiled solve per degree bucket, any registered family, optional
    ``mesh`` scale-out and streaming ``sample_weight`` masks) instead of a
    per-node Python loop of separately-jitted solves. This is the engine
    behind ``EstimationSession.joint``; for the default Ising family it
    solves the identical objective as the seed path, differing only by
    solver round-off.

    init: "zero" (theta_bar = 0, rho = rho0) or "uniform"/"diagonal"
    (theta_bar = the corresponding one-step consensus of ``fits``, rho =
    its weights — "uniform" scaled by ``rho0``), matching Fig. 3(c).

    ``recorder`` / ``stats`` (see :func:`repro.core.batched.
    fit_all_local_batched`): one ``admm_iter`` span per round with the rms
    primal residual observed, prox compile/dispatch time accumulated into
    ``stats``.
    """
    import jax.numpy as jnp

    from ..telemetry.recorder import NULL_RECORDER
    from .batched import prox_update_batched
    from .families import ISING

    rec = NULL_RECORDER if recorder is None else recorder

    fam = ISING if family is None else family
    n_params = fam.n_params(graph)
    if theta_fixed is None:
        theta_fixed = np.zeros(n_params)
    theta_fixed = np.asarray(theta_fixed, dtype=np.float64)

    if init == "zero":
        theta_bar = np.array(theta_fixed, copy=True)
        rhos = rho_from_fits(graph, None, "uniform", include_singleton, fam)
    else:
        assert fits is not None, "one-step init requires local fits"
        theta_bar = combine(graph, fits, init, include_singleton,
                            theta_fixed, family=fam)
        rhos = rho_from_fits(graph, fits, init, include_singleton, fam)
    if init in ("zero", "uniform") and rho0 != 1.0:
        rhos = [r * float(rho0) for r in rhos]

    owners = param_owners(graph, include_singleton, fam)
    betas = [fam.beta(graph, i, include_singleton) for i in range(graph.p)]
    lambdas = [np.zeros(len(b)) for b in betas]
    thetas = [np.array(theta_bar[np.asarray(b)]) for b in betas]
    X = jnp.asarray(X)

    traj = [np.array(theta_bar, copy=True)]
    resid = []
    for it in range(n_iters):
        span = rec.span("admm_iter", it=it) if rec.enabled else None
        if span is not None:
            span.__enter__()
        # 1) batched local proximal updates (one solve per degree bucket)
        thetas = prox_update_batched(
            graph, X, theta_bar, lambdas, rhos, thetas0=thetas,
            include_singleton=include_singleton,
            theta_fixed=jnp.asarray(theta_fixed, X.dtype),
            sample_weight=sample_weight, n_iter=newton_iters,
            family=fam, mesh=mesh, recorder=recorder, stats=stats)
        # 2) weighted linear consensus
        new_bar = np.array(theta_bar, copy=True)
        for a, own in owners.items():
            num, den = 0.0, 0.0
            for (i, pos) in own:
                num += rhos[i][pos] * thetas[i][pos]
                den += rhos[i][pos]
            new_bar[a] = num / den
        theta_bar = new_bar
        # 3) dual ascent
        r2, cnt = 0.0, 0
        for i in range(graph.p):
            b = np.asarray(betas[i])
            diff = np.asarray(thetas[i], dtype=np.float64) - theta_bar[b]
            lambdas[i] = lambdas[i] + rhos[i] * diff
            r2 += float(diff @ diff)
            cnt += len(b)
        resid.append(np.sqrt(r2 / max(cnt, 1)))
        traj.append(np.array(theta_bar, copy=True))
        if span is not None:
            rec.observe("admm.primal_residual", resid[-1], it=it)
            span.__exit__(None, None, None)

    return ADMMResult(trajectory=np.stack(traj),
                      primal_residual=np.asarray(resid))
