"""Samplers: exact enumeration (small p), sequential Gibbs, chromatic
(graph-colored) Gibbs that updates whole color classes in parallel per sweep
(any p), and a family-generic chromatic chain that draws from any registered
:class:`~repro.core.families.base.ModelFamily` via its conditional-draw
hooks."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .graphs import Graph
from .ising import IsingModel, all_states, exact_probs, pair_matrix


def exact_sample(model: IsingModel, n: int, key: jax.Array) -> jnp.ndarray:
    """Draw n iid samples by enumerating all 2^p states (small p only)."""
    probs = exact_probs(model.graph, model.theta)
    idx = jax.random.categorical(key, jnp.log(probs + 1e-30), shape=(n,))
    return jnp.asarray(all_states(model.graph.p))[idx]


@functools.partial(jax.jit, static_argnames=("n", "burnin", "thin", "p"))
def _gibbs_chain(theta_single, T, p: int, n: int, burnin: int, thin: int,
                 key: jax.Array) -> jnp.ndarray:
    """One Gibbs chain producing n samples (sequential single-site updates)."""
    total = burnin + n * thin

    def site_update(carry, i):
        x, key = carry
        key, sub = jax.random.split(key)
        eta = theta_single[i] + x @ T[:, i]
        p_plus = jax.nn.sigmoid(2.0 * eta)
        xi = jnp.where(jax.random.uniform(sub) < p_plus, 1.0, -1.0)
        return (x.at[i].set(xi), key), None

    def sweep(carry, _):
        carry, _ = jax.lax.scan(site_update, carry, jnp.arange(p))
        return carry, carry[0]

    key, init_key = jax.random.split(key)
    x0 = jnp.where(jax.random.uniform(init_key, (p,)) < 0.5, 1.0, -1.0)
    (_, _), xs = jax.lax.scan(sweep, (x0, key), None, length=total)
    return xs[burnin::thin][:n]


@functools.partial(jax.jit,
                   static_argnames=("n", "burnin", "thin", "p"))
def _chromatic_chain(theta_single, T, class_idx, class_mask, p: int, n: int,
                     burnin: int, thin: int, key: jax.Array) -> jnp.ndarray:
    """One chromatic-Gibbs chain: per sweep, scan over color classes and
    update every node of a class simultaneously (valid because same-color
    nodes are mutually non-adjacent, so their conditionals don't interact).

    class_idx: (n_colors, pad) node indices, padded with the out-of-range
    index ``p`` which addresses a dummy slot in the extended state vector;
    class_mask: (n_colors, pad) 1.0 on real entries.
    """
    total = burnin + n * thin
    ts_pad = jnp.pad(theta_single, (0, 1))       # dummy slot p
    T_pad = jnp.pad(T, ((0, 0), (0, 1)))

    def color_update(carry, inp):
        x, key = carry                           # x: (p + 1,)
        idx, mask = inp                          # (pad,), (pad,)
        key, sub = jax.random.split(key)
        eta = ts_pad[idx] + x[:p] @ T_pad[:, idx]
        u = jax.random.uniform(sub, idx.shape)
        xi = jnp.where(u < jax.nn.sigmoid(2.0 * eta), 1.0, -1.0)
        xi = jnp.where(mask > 0, xi, x[idx])     # padded slots keep old value
        return (x.at[idx].set(xi), key), None

    def sweep(carry, _):
        carry, _ = jax.lax.scan(color_update, carry, (class_idx, class_mask))
        return carry, carry[0][:p]

    key, init_key = jax.random.split(key)
    x0 = jnp.where(jax.random.uniform(init_key, (p + 1,)) < 0.5, 1.0, -1.0)
    (_, _), xs = jax.lax.scan(sweep, (x0, key), None, length=total)
    return xs[burnin::thin][:n]


def color_classes(graph: Graph):
    """(class_idx, class_mask) arrays for chromatic sweeps; padded with p."""
    colors = graph.greedy_coloring()
    n_colors = int(colors.max()) + 1
    groups = [np.flatnonzero(colors == c) for c in range(n_colors)]
    pad = max(len(g) for g in groups)
    class_idx = np.full((n_colors, pad), graph.p, dtype=np.int32)
    class_mask = np.zeros((n_colors, pad), dtype=np.float32)
    for c, g in enumerate(groups):
        class_idx[c, :len(g)] = g
        class_mask[c, :len(g)] = 1.0
    return class_idx, class_mask


def chromatic_gibbs_sample(model: IsingModel, n: int, key: jax.Array,
                           burnin: int = 200, thin: int = 5,
                           n_chains: int = 8) -> jnp.ndarray:
    """Draw ~n samples via parallel chromatic-Gibbs chains."""
    per = -(-n // n_chains)
    keys = jax.random.split(key, n_chains)
    T = pair_matrix(model.graph, model.theta_edges)
    class_idx, class_mask = color_classes(model.graph)
    chains = jax.vmap(
        lambda k: _chromatic_chain(model.theta_single, T,
                                   jnp.asarray(class_idx),
                                   jnp.asarray(class_mask),
                                   model.graph.p, per, burnin, thin, k)
    )(keys)
    return chains.reshape(-1, model.graph.p)[:n]


def gibbs_sample(model: IsingModel, n: int, key: jax.Array,
                 burnin: int = 200, thin: int = 5,
                 n_chains: int = 8, method: str = "auto") -> jnp.ndarray:
    """Draw ~n samples via ``n_chains`` parallel Gibbs chains.

    method="auto" uses chromatic sweeps when the greedy coloring is sparse
    (few color classes relative to p — each sweep then runs a handful of
    vectorized color updates instead of p sequential site updates) and falls
    back to the sequential single-site scan for dense colorings, where the
    color classes are tiny and the chromatic schedule has no parallelism to
    exploit. "sequential" / "chromatic" force a path.
    """
    if method == "auto":
        n_colors = int(model.graph.greedy_coloring().max()) + 1
        method = ("chromatic" if n_colors <= max(2, model.graph.p // 2)
                  else "sequential")
    if method == "chromatic":
        return chromatic_gibbs_sample(model, n, key, burnin, thin, n_chains)
    if method != "sequential":
        raise ValueError(f"unknown method {method!r}")
    per = -(-n // n_chains)
    keys = jax.random.split(key, n_chains)
    T = pair_matrix(model.graph, model.theta_edges)
    chains = jax.vmap(
        lambda k: _gibbs_chain(model.theta_single, T, model.graph.p,
                               per, burnin, thin, k)
    )(keys)
    return chains.reshape(-1, model.graph.p)[:n]


# ------------------------------------------------------ family-generic Gibbs
@functools.partial(jax.jit,
                   static_argnames=("family", "p", "n", "burnin", "thin"))
def _family_chromatic_chain(family, h, Tc, class_idx, class_mask, p: int,
                            n: int, burnin: int, thin: int,
                            key: jax.Array) -> jnp.ndarray:
    """One chromatic-Gibbs chain for an arbitrary model family.

    The channel logits of every node in a color class are assembled from
    the family's ``edge_features`` and the dense coupling tensor, then the
    class is redrawn in parallel via ``cond_draw`` (same-color nodes are
    mutually non-adjacent, so their conditionals don't interact). h: (p, C)
    node blocks; Tc: (p, p, C) symmetric couplings; class_idx/class_mask as
    in :func:`color_classes` (padded with the dummy index ``p``).
    """
    total = burnin + n * thin
    h_pad = jnp.pad(h, ((0, 1), (0, 0)))
    Tc_pad = jnp.pad(Tc, ((0, 0), (0, 1), (0, 0)))

    def color_update(carry, inp):
        x, key = carry                            # x: (p + 1,)
        idx, mask = inp                           # (pad,), (pad,)
        key, sub = jax.random.split(key)
        F = family.edge_features(x[:p])           # (p, C)
        eta = h_pad[idx] + jnp.einsum("pc,pmc->mc", F, Tc_pad[:, idx, :])
        xi = family.cond_draw(sub, eta)
        xi = jnp.where(mask > 0, xi, x[idx])      # padded slots keep value
        return (x.at[idx].set(xi), key), None

    def sweep(carry, _):
        carry, _ = jax.lax.scan(color_update, carry, (class_idx, class_mask))
        return carry, carry[0][:p]

    key, init_key = jax.random.split(key)
    x0 = jnp.pad(family.init_draw(init_key, p).astype(jnp.float32), (0, 1))
    (_, _), xs = jax.lax.scan(sweep, (x0, key), None, length=total)
    return xs[burnin::thin][:n]


def gibbs_sample_family(family, graph: Graph, theta, n: int, key: jax.Array,
                        burnin: int = 200, thin: int = 5,
                        n_chains: int = 8) -> jnp.ndarray:
    """Draw ~n samples from any registered family via chromatic Gibbs.

    One compiled chain program per (family, graph-shape) pair; chains run
    vmapped in parallel. For the Ising family this targets the same law as
    :func:`chromatic_gibbs_sample` (the conformance suite cross-checks both
    against exact moments).
    """
    per = -(-n // n_chains)
    keys = jax.random.split(key, n_chains)
    h = family.node_params(graph, theta).astype(jnp.float32)
    Tc = family.coupling_tensor(graph, theta).astype(jnp.float32)
    class_idx, class_mask = color_classes(graph)
    chains = jax.vmap(
        lambda k: _family_chromatic_chain(family, h, Tc,
                                          jnp.asarray(class_idx),
                                          jnp.asarray(class_mask),
                                          graph.p, per, burnin, thin, k)
    )(keys)
    return chains.reshape(-1, graph.p)[:n]
