"""Samplers for Ising models: exact enumeration (small p) and Gibbs (any p)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .graphs import Graph
from .ising import IsingModel, all_states, exact_probs, pair_matrix


def exact_sample(model: IsingModel, n: int, key: jax.Array) -> jnp.ndarray:
    """Draw n iid samples by enumerating all 2^p states (small p only)."""
    probs = exact_probs(model.graph, model.theta)
    idx = jax.random.categorical(key, jnp.log(probs + 1e-30), shape=(n,))
    return jnp.asarray(all_states(model.graph.p))[idx]


@functools.partial(jax.jit, static_argnames=("n", "burnin", "thin", "p"))
def _gibbs_chain(theta_single, T, p: int, n: int, burnin: int, thin: int,
                 key: jax.Array) -> jnp.ndarray:
    """One Gibbs chain producing n samples (sequential single-site updates)."""
    total = burnin + n * thin

    def site_update(carry, i):
        x, key = carry
        key, sub = jax.random.split(key)
        eta = theta_single[i] + x @ T[:, i]
        p_plus = jax.nn.sigmoid(2.0 * eta)
        xi = jnp.where(jax.random.uniform(sub) < p_plus, 1.0, -1.0)
        return (x.at[i].set(xi), key), None

    def sweep(carry, _):
        carry, _ = jax.lax.scan(site_update, carry, jnp.arange(p))
        return carry, carry[0]

    key, init_key = jax.random.split(key)
    x0 = jnp.where(jax.random.uniform(init_key, (p,)) < 0.5, 1.0, -1.0)
    (_, _), xs = jax.lax.scan(sweep, (x0, key), None, length=total)
    return xs[burnin::thin][:n]


def gibbs_sample(model: IsingModel, n: int, key: jax.Array,
                 burnin: int = 200, thin: int = 5,
                 n_chains: int = 8) -> jnp.ndarray:
    """Draw ~n samples via ``n_chains`` parallel Gibbs chains."""
    per = -(-n // n_chains)
    keys = jax.random.split(key, n_chains)
    T = pair_matrix(model.graph, model.theta_edges)
    chains = jax.vmap(
        lambda k: _gibbs_chain(model.theta_single, T, model.graph.p,
                               per, burnin, thin, k)
    )(keys)
    return chains.reshape(-1, model.graph.p)[:n]
