"""M-estimators for Ising models: local conditional-likelihood (CL) fits,
joint MPLE, and exact MLE (paper Sec. 2.2-2.3, Sec. 3).

Every estimator is a Newton maximizer of a concave criterion. Parameters are
flat vectors over [singletons, edges]; ``free_idx`` selects the coordinates
being estimated (the paper's small experiments fix the singletons).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .graphs import Graph
from .ising import pseudo_loglik, suff_stats, log_partition


# ---------------------------------------------------------------- solvers
def newton_maximize(fun, w0: jnp.ndarray, n_iter: int = 40,
                    ridge: float = 1e-8, max_step: float = 5.0) -> jnp.ndarray:
    """Maximize a (strictly) concave ``fun`` by damped Newton iterations."""
    grad = jax.grad(fun)
    hess = jax.hessian(fun)
    eye = jnp.eye(w0.shape[0], dtype=w0.dtype)

    def step(w, _):
        g = grad(w)
        H = hess(w) - ridge * eye          # keep negative definite
        d = jnp.linalg.solve(H, g)         # Newton direction is w - d
        norm = jnp.linalg.norm(d)
        d = jnp.where(norm > max_step, d * (max_step / (norm + 1e-30)), d)
        return w - d, None

    w, _ = jax.lax.scan(step, w0, None, length=n_iter)
    return w


# ---------------------------------------------------------- local CL fits
def node_design(graph: Graph, X: jnp.ndarray, i: int):
    """Neighbor design matrix Z (n, deg(i)) ordered like incident_edges(i)."""
    ks = graph.incident_edges(i)
    others = [graph.edges[k][0] if graph.edges[k][1] == i else graph.edges[k][1]
              for k in ks]
    Z = X[:, others] if others else jnp.zeros((X.shape[0], 0), X.dtype)
    return Z


def _cl_objective(Z: jnp.ndarray, xi: jnp.ndarray, offset,
                  include_singleton: bool):
    """(fun, d): average conditional loglik of one node's CL criterion.

    ``w`` is ordered singleton-first (when free) then incident-edge
    couplings; ``offset`` is the fixed singleton theta_i otherwise.
    Shared by the per-node and loop paths so the criterion is defined once.
    """
    if include_singleton:
        def fun(w):
            eta = w[0] + Z @ w[1:]
            return jnp.mean(jax.nn.log_sigmoid(2.0 * xi * eta))
        d = 1 + Z.shape[1]
    else:
        def fun(w):
            eta = offset + Z @ w
            return jnp.mean(jax.nn.log_sigmoid(2.0 * xi * eta))
        d = Z.shape[1]
    return fun, d


def node_cl_fn(graph: Graph, X: jnp.ndarray, i: int,
               include_singleton: bool, theta_fixed: jnp.ndarray):
    """Returns (fun, d) where fun(w) is node i's average conditional loglik.

    ``w`` is ordered as ``graph.beta(i, include_singleton)``: singleton first
    (if free) then incident-edge couplings.
    """
    Z = node_design(graph, X, i)
    return _cl_objective(Z, X[:, i], theta_fixed[i], include_singleton)


@dataclasses.dataclass
class LocalFit:
    """Result of one sensor's local estimator (paper Eq. 3) + diagnostics."""
    i: int
    beta: List[int]            # flat parameter indices this node estimates
    theta: np.ndarray          # (d,) local estimate theta^i_{beta_i}
    H: np.ndarray              # (d, d) empirical Hessian  -mean grad^2
    J: np.ndarray              # (d, d) empirical Fisher    mean g g^T
    V: np.ndarray              # (d, d) sandwich H^-1 J H^-1
    s: np.ndarray              # (n, d) influence H^-1 grad l(theta_hat; x_k)


@functools.partial(jax.jit, static_argnames=("include_singleton", "n_iter"))
def _solve_cl(Z: jnp.ndarray, xi: jnp.ndarray, offset: jnp.ndarray,
              include_singleton: bool, n_iter: int):
    """Shape-cached local CL solve: nodes of equal degree share one compile.

    Returns (w, H, J, V, s). ``offset`` is the fixed singleton theta_i (only
    used when include_singleton=False).
    """
    n = Z.shape[0]
    fun, d = _cl_objective(Z, xi, offset, include_singleton)
    w = newton_maximize(fun, jnp.zeros(d, Z.dtype), n_iter=n_iter)

    # per-sample score at w_hat; dl/deta = 2 x sigmoid(-2 x eta)
    eta = (w[0] + Z @ w[1:]) if include_singleton else (offset + Z @ w)
    r = 2.0 * xi * jax.nn.sigmoid(-2.0 * xi * eta)          # (n,)
    G = r[:, None] * Z                                       # (n, deg)
    if include_singleton:
        G = jnp.concatenate([r[:, None], G], axis=1)         # (n, d)
    J = (G.T @ G) / n
    H = -jax.hessian(fun)(w)
    Hinv = jnp.linalg.inv(H + 1e-9 * jnp.eye(d, dtype=Z.dtype))
    V = Hinv @ J @ Hinv
    s = G @ Hinv.T
    return w, H, J, V, s


def fit_local_cl(graph: Graph, X: jnp.ndarray, i: int,
                 include_singleton: bool = True,
                 theta_fixed: Optional[jnp.ndarray] = None,
                 n_iter: int = 40) -> LocalFit:
    """Fit node i's conditional-likelihood M-estimator and its asymptotics."""
    if theta_fixed is None:
        theta_fixed = jnp.zeros(graph.n_params, X.dtype)
    Z = node_design(graph, X, i)
    w, H, J, V, s = _solve_cl(Z, X[:, i], theta_fixed[i],
                              include_singleton, n_iter)
    return LocalFit(i=i, beta=graph.beta(i, include_singleton),
                    theta=np.asarray(w), H=np.asarray(H), J=np.asarray(J),
                    V=np.asarray(V), s=np.asarray(s))


def fit_all_local_loop(graph: Graph, X: jnp.ndarray,
                       include_singleton: bool = True,
                       theta_fixed: Optional[jnp.ndarray] = None
                       ) -> List[LocalFit]:
    """Seed per-node loop: one jitted solve per degree, autodiff Hessians.

    Kept as the reference path; ``fit_all_local`` dispatches to the
    degree-bucketed batched engine in :mod:`repro.core.batched`.
    """
    return [fit_local_cl(graph, X, i, include_singleton, theta_fixed)
            for i in range(graph.p)]


def fit_all_local(graph: Graph, X: jnp.ndarray,
                  include_singleton: bool = True,
                  theta_fixed: Optional[jnp.ndarray] = None,
                  method: str = "batched",
                  sample_weight: Optional[jnp.ndarray] = None,
                  warm_start: Optional[Sequence] = None,
                  family=None, mesh=None) -> List[LocalFit]:
    """Fit all p local CL estimators.

    Thin shim over the estimation-plan API: method="batched" (default)
    builds the equivalent default :class:`repro.api.Plan` and runs the
    cached :class:`~repro.api.session.EstimationSession`'s local-fit engine
    — degree buckets grouped, each solved in one vmapped Newton-IRLS
    program with closed-form gradients/Hessians, numerically identical to
    calling the engine directly (the golden fixtures pin this).
    method="loop" is the legacy per-node Ising path.

    ``sample_weight`` (0/1 observation masks, ``(n,)`` or ``(p, n)``),
    ``warm_start`` (previous per-node thetas), ``family`` (any registered
    :class:`~repro.core.families.base.ModelFamily`; default Ising), and
    ``mesh`` (shard bucket solves along a mesh's ``data`` axis) are
    extensions of the batched engine — see
    :func:`repro.core.batched.fit_all_local_batched`; the loop path does
    not support them.
    """
    if method == "batched":
        from .families import get_family
        fam_name = "ising" if family is None else getattr(family, "name", "")
        try:
            registered = family is None or get_family(fam_name) is family
        except KeyError:
            registered = False
        if registered:
            from ..api import Plan
            from ..api.session import EstimationSession
            # theta_fixed stays a per-call argument (not a plan field):
            # callers varying it would otherwise mint a distinct plan —
            # and churn the session cache — per value
            plan = Plan(graph=graph, family=fam_name,
                        include_singleton=include_singleton)
            sess = EstimationSession.for_plan(plan, mesh=mesh)
            return sess.fit_local(X, sample_weight=sample_weight,
                                  warm_start=warm_start, want_influence=True,
                                  theta_fixed=theta_fixed)
        # unregistered family instance: call the engine directly (no plan
        # can name it; sessions require registry families)
        from .batched import fit_all_local_batched
        return fit_all_local_batched(graph, X, include_singleton, theta_fixed,
                                     sample_weight=sample_weight,
                                     warm_start=warm_start, family=family,
                                     mesh=mesh)
    if method == "loop":
        if sample_weight is not None or warm_start is not None or \
                mesh is not None:
            raise ValueError(
                "sample_weight/warm_start/mesh require method='batched'")
        if family is not None and family.name != "ising":
            raise ValueError(
                "method='loop' implements only the Ising family; "
                f"use method='batched' for {family.name!r}")
        return fit_all_local_loop(graph, X, include_singleton, theta_fixed)
    raise ValueError(f"unknown method {method!r}")


# ------------------------------------------------------------- joint fits
def _masked_objective(base_fn, theta_fixed: jnp.ndarray, free_idx: np.ndarray):
    def fun(w):
        theta = theta_fixed.at[free_idx].set(w)
        return base_fn(theta)
    return fun


def fit_mple(graph: Graph, X: jnp.ndarray,
             free_idx: Optional[Sequence[int]] = None,
             theta_fixed: Optional[jnp.ndarray] = None,
             n_iter: int = 40) -> np.ndarray:
    """Joint MPLE (Eq. 2) over ``free_idx``; returns full flat theta."""
    if theta_fixed is None:
        theta_fixed = jnp.zeros(graph.n_params, X.dtype)
    if free_idx is None:
        free_idx = np.arange(graph.n_params)
    free_idx = np.asarray(free_idx)
    fun = _masked_objective(lambda t: pseudo_loglik(graph, t, X),
                            theta_fixed, free_idx)
    w = newton_maximize(fun, theta_fixed[free_idx], n_iter=n_iter)
    return np.asarray(theta_fixed.at[free_idx].set(w))


def fit_mle_exact(graph: Graph, X: jnp.ndarray,
                  free_idx: Optional[Sequence[int]] = None,
                  theta_fixed: Optional[jnp.ndarray] = None,
                  n_iter: int = 40) -> np.ndarray:
    """Exact MLE by enumeration (small p only); returns full flat theta."""
    if theta_fixed is None:
        theta_fixed = jnp.zeros(graph.n_params, X.dtype)
    if free_idx is None:
        free_idx = np.arange(graph.n_params)
    free_idx = np.asarray(free_idx)
    mean_u = jnp.mean(suff_stats(graph, X), axis=0)

    def ll(theta):
        return theta @ mean_u - log_partition(graph, theta)

    fun = _masked_objective(ll, theta_fixed, free_idx)
    w = newton_maximize(fun, theta_fixed[free_idx], n_iter=n_iter)
    return np.asarray(theta_fixed.at[free_idx].set(w))
