"""Core reproduction of Liu & Ihler (ICML 2012), "Distributed Parameter
Estimation via Pseudo-likelihood": an exponential-family model zoo (Ising,
Gaussian MRF, q-state Potts) behind one estimator contract
(:mod:`repro.core.families`), local conditional-likelihood estimators, the
degree-bucketed batched engine, one-step consensus (linear/max/matrix), ADMM
joint MPLE, and the exact asymptotic-variance machinery behind the paper's
theory."""
from .graphs import (Graph, chain_graph, star_graph, grid_graph,
                     complete_graph, scale_free_graph, euclidean_graph)
from .ising import (IsingModel, random_model, conditional_logits, cond_loglik,
                    pseudo_loglik, suff_stats, log_partition, exact_probs,
                    loglik, exact_moments, all_states, pair_matrix)
from .families import (ModelFamily, IsingFamily, GaussianMRF, PottsFamily,
                       ISING, GAUSSIAN, POTTS3, register_family, get_family,
                       registered_families, fit_mple_family, fit_node_oracle,
                       random_rows)
from .sampling import (exact_sample, gibbs_sample, chromatic_gibbs_sample,
                       gibbs_sample_family)
from .estimators import (LocalFit, newton_maximize, fit_local_cl,
                         fit_all_local, fit_all_local_loop, fit_mple,
                         fit_mle_exact, node_design)
from .batched import (DegreeBucket, degree_buckets, fit_all_local_batched,
                      prox_update_batched, group_soft_threshold,
                      bucket_compile_count, prox_compile_count,
                      clear_bucket_solver_caches)
from .asymptotics import (ExactLocal, exact_local, exact_locals, param_owners,
                          free_indices, exact_consensus_variance,
                          exact_joint_mple_variance, exact_mle_variance,
                          efficiency, cross_cov)
from .combiners import (Combiner, register_combiner, get_combiner,
                        registered_combiners, streamable_combiners,
                        TRUST_RADIUS)
from .consensus import combine, mse, empirical_cross_cov, SCHEMES
from .admm import admm_mple, admm_mple_family, rho_from_fits, ADMMResult
