"""Pluggable one-step consensus combiners behind a registry.

The paper's combination methods (Sec. 3.1, Eq. 4-5, 7) — and their sequel
framing as interchangeable moment-matching strategies over exponential
families (Liu & Ihler 2014) — are *strategies*, not branches: each one turns
the per-owner local estimates of a shared parameter into one consensus
value. This module mirrors the model-family registry
(:mod:`repro.core.families`): a combiner is a small strategy object
registered by name via :func:`register_combiner`, resolved by
:func:`get_combiner`, and enumerated by :func:`registered_combiners`; the
estimation-plan API (:mod:`repro.api`), ``consensus.combine``, the streaming
simulator, benchmarks, and the conformance harness all dispatch through it.

Each combiner declares what it ``needs`` — ``"variance"`` (the sandwich
diagonal), ``"influence"`` (per-sample influence columns, the expensive
second-order cross-covariance input of Linear-Opt), ``"hessian"`` (full
local Hessians) — so a compiled session only computes or retains the
second-order objects some *requested* combiner actually asks for, and
``scalars_per_shared_param`` — the per-parameter message size the shared
communication accounting bills (``None`` marks a combiner that is not
distributable as one message round, e.g. the matrix reference).

Registered combiners:

  uniform        — Linear-Uniform, w = 1                          (Eq. 4)
  diagonal       — Linear-Diagonal, w^i_a = 1 / Vhat^i_aa         (Prop 4.7)
  optimal        — Linear-Opt, w_a = Vhat_a^{-1} e                (Prop 4.6)
  max            — Max-Diagonal voting: argmax 1 / Vhat^i_aa      (Prop 4.4)
  weighted_vote  — variance-weighted voting: owners vote for their estimate
                   with mass 1 / Vhat^i_aa and the weighted *median* wins —
                   the soft generalization of max-voting suggested by the
                   moment-matching view (Liu & Ihler 2014): with two owners
                   it coincides with max-voting (up to ties), with larger
                   owner sets it is robust to any minority of diverged
                   owners without collapsing to a single voter.
  matrix         — matrix consensus W^i = Hhat^i (Eq. 7)          (Cor 4.2)
  trimmed_mean   — Byzantine-robust coordinate-wise trimmed mean: symmetric
                   order-statistic trimming for larger owner sets, plus an
                   anchored compatibility filter (candidates statistically
                   incompatible with the home owner are discarded) that
                   stays meaningful at the paper's two-owner edge blocks.
  krum           — Krum-style nearest-neighbor selection (Blanchard et al.
                   2017 adapted to scalar owner candidates): the candidate
                   with the smallest summed distance to its nearest
                   neighbors wins; exact score ties prefer the home owner,
                   so a lying peer can never displace the home's own data.

Each combiner also declares its ``breakdown_point`` — the fraction of
Byzantine (arbitrarily corrupted) owner candidates it tolerates before the
combined value can be driven arbitrarily far. The classical linear schemes
all have breakdown 0 (one lying owner moves the mean arbitrarily); the
voting/robust schemes trade statistical efficiency for a positive one.

The grouped vectorized driver (pad per-node fits into dense float64 stacks,
group parameters by owner count, batch every group's weighting) is the
engine previously inlined in ``consensus.combine``; its numerics are pinned
to 1e-10 by the golden fixtures, so strategies only supply *weights*.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from .asymptotics import free_indices, param_owners
from .graphs import Graph

#: estimates beyond this magnitude mark a diverged local fit
#: (quasi-separation); shared with repro.stream's warm-start reset and
#: message guards so streaming disqualifies owners exactly when combine does
TRUST_RADIUS = 25.0


class Combiner:
    """One consensus combination strategy.

    Subclasses either override :meth:`group_weights` (linear/voting schemes
    that fit the grouped driver) or :meth:`combine` wholesale (the matrix
    reference). ``needs`` declares which second-order inputs the strategy
    reads so sessions can skip producing the rest.
    """

    name: str = ""
    #: subset of {"variance", "influence", "hessian"}
    needs: frozenset = frozenset()
    #: scalars per shared parameter in a one-step message (None: the
    #: combiner is not expressible as one distributable message round)
    scalars_per_shared_param: Optional[int] = None
    #: fraction of Byzantine owner candidates tolerated before the combined
    #: value can be driven arbitrarily far (0.0 for the linear schemes)
    breakdown_point: float = 0.0
    #: True for robust combiners whose streaming fusion anchors on the
    #: receiver's OWN candidate — the simulator then passes ``own_index``
    #: to :meth:`combine_candidates` (third-party combiners with the plain
    #: single-argument signature are never handed the extra keyword)
    anchored: bool = False

    # ------------------------------------------------------------- strategy
    def group_weights(self, est: np.ndarray, diag: np.ndarray,
                      bad: np.ndarray,
                      cols: Optional[np.ndarray]) -> np.ndarray:
        """(P, k) combination weights for one owner-count group.

        est — (P, k) owner estimates (zeroed where ``bad``); diag — (P, k)
        sandwich-variance diagonals (``inf`` where ``bad``); bad — (P, k)
        disqualified-owner mask; cols — (P, k, n) per-sample influence
        columns, only provided when ``"influence" in self.needs``.
        """
        raise NotImplementedError

    def combine_candidates(self, cands: List[Tuple[float, float]]) -> float:
        """Streaming-side combination of ``(estimate, variance)`` candidate
        pairs for ONE parameter — the simulator's receiver-side fuse of its
        own fit with possibly-stale peer views. Only combiners implementing
        this are streamable one-step schemes."""
        raise NotImplementedError(
            f"combiner {self.name!r} is not a streamable one-step scheme")

    def filter_mask(self, cands: List[Tuple[float, float]],
                    own_index: Optional[int] = None
                    ) -> Optional[np.ndarray]:
        """(k,) boolean keep mask a *filtering* robust combiner would apply
        to ``cands`` before averaging, or None when the strategy does not
        reject candidates (linear and voting schemes select/weight instead
        of discarding). The observability hook behind the streaming
        simulator's robust-combiner rejection counters — it must match
        what :meth:`combine_candidates` actually drops."""
        return None

    # --------------------------------------------------------------- driver
    def combine(self, graph: Graph, fits, include_singleton: bool = True,
                theta_fixed: Optional[np.ndarray] = None,
                family=None) -> np.ndarray:
        """One-step consensus estimate; returns the full flat theta vector.

        Vectorized over the owner structure: parameters are grouped by owner
        count and every group's weights/averages are computed with batched
        float64 array ops (no per-parameter Python loop). Single-owner
        parameters — the singleton blocks — pass the local estimate through
        exactly. With a ``family``, ownership runs over the family's
        parameter *blocks*; the default is the scalar Ising layout.
        """
        n_params = graph.n_params if family is None else family.n_params(graph)
        if theta_fixed is None:
            theta_fixed = np.zeros(n_params, dtype=np.float64)
        theta = np.array(theta_fixed, dtype=np.float64, copy=True)

        # pad per-node results into dense (p, dmax) float64 stacks
        dmax = max(len(f.theta) for f in fits)
        theta_mat = np.zeros((graph.p, dmax), dtype=np.float64)
        vdiag_mat = np.ones((graph.p, dmax), dtype=np.float64)
        for f in fits:
            d = len(f.theta)
            theta_mat[f.i, :d] = f.theta
            vdiag_mat[f.i, :d] = np.diag(f.V)
        s_pad = None
        if "influence" in self.needs:
            n = fits[0].s.shape[0]
            if n == 0:
                raise ValueError(
                    f"combiner {self.name!r} needs per-sample influence "
                    f"columns, but the local fits were computed without "
                    f"them (want_influence=False / a plan whose combiners "
                    f"did not request 'influence')")
            s_pad = np.zeros((graph.p, n, dmax), dtype=np.float64)
            for f in fits:
                s_pad[f.i, :, :len(f.theta)] = f.s

        owners = param_owners(graph, include_singleton, family)
        for k, (aidx, node, pos) in _owner_groups(owners).items():
            est = theta_mat[node, pos]                          # (P, k)
            diag = np.maximum(vdiag_mat[node, pos], 1e-12)
            # Robustness guard: a saturated/diverged local fit
            # (quasi-separation, e.g. high-degree hubs at small n) yields
            # non-finite estimates or a deceptively tiny Vhat. Treat such
            # owners as infinite-variance so every weighting scheme zeroes
            # them out; keep uniform truly uniform only over sane owners.
            bad = (~np.isfinite(est)) | (~np.isfinite(diag)) \
                | (np.abs(est) > TRUST_RADIUS)
            est = np.where(bad, 0.0, est)
            all_bad = bad.all(axis=1)

            if k == 1:
                # exact passthrough: a parameter with one owner (the
                # singletons) IS the local estimate under every scheme.
                theta[aidx] = np.where(all_bad, 0.0, est[:, 0])
                continue

            diag = np.where(bad, np.inf, diag)
            cols = s_pad[node, :, pos] if s_pad is not None else None
            w = self.group_weights(est, diag, bad, cols)
            w = np.where(bad, 0.0, w)
            wsum = np.where(all_bad, 1.0, w.sum(axis=1))
            theta[aidx] = np.where(all_bad, 0.0, (w * est).sum(axis=1) / wsum)
        return theta


def _owner_groups(owners: Dict[int, List[Tuple[int, int]]]):
    """Group params by owner count k -> (param_idx (P,), node (P,k), pos (P,k)).

    Owner counts are tiny (1 for singletons, 2 for edges), so grouping by k
    turns the per-parameter Python loop into a handful of batched array ops.
    """
    by_k: Dict[int, List[Tuple[int, List[Tuple[int, int]]]]] = {}
    for a, own in owners.items():
        by_k.setdefault(len(own), []).append((a, own))
    out = {}
    for k, items in by_k.items():
        aidx = np.array([a for a, _ in items], dtype=np.int64)
        node = np.array([[i for (i, _) in own] for _, own in items],
                        dtype=np.int64)
        pos = np.array([[p_ for (_, p_) in own] for _, own in items],
                       dtype=np.int64)
        out[k] = (aidx, node, pos)
    return out


# ------------------------------------------------------------- strategies
class UniformCombiner(Combiner):
    """Linear-Uniform (Eq. 4): every sane owner weighs 1."""
    name = "uniform"
    needs = frozenset()
    scalars_per_shared_param = 1     # estimate only; unit weights not sent

    def group_weights(self, est, diag, bad, cols):
        return np.where(bad, 0.0, 1.0)

    def combine_candidates(self, cands):
        return float(np.mean([e for e, _ in cands]))


class DiagonalCombiner(Combiner):
    """Linear-Diagonal (Prop 4.7): inverse-variance weights."""
    name = "diagonal"
    needs = frozenset({"variance"})
    scalars_per_shared_param = 2     # estimate + 1/Vhat_aa weight

    def group_weights(self, est, diag, bad, cols):
        return 1.0 / diag

    def combine_candidates(self, cands):
        w = np.array([1.0 / v for _, v in cands])
        e = np.array([e for e, _ in cands])
        return float((w @ e) / w.sum())


class MaxCombiner(Combiner):
    """Max-Diagonal voting (Prop 4.4): the min-variance owner wins."""
    name = "max"
    needs = frozenset({"variance"})
    scalars_per_shared_param = 2     # estimate + weight; receiver argmaxes

    def group_weights(self, est, diag, bad, cols):
        w = np.zeros_like(est)
        w[np.arange(est.shape[0]), np.argmin(diag, axis=1)] = 1.0
        return w

    def combine_candidates(self, cands):
        return min(cands, key=lambda c: c[1])[0]


class WeightedVoteCombiner(Combiner):
    """Variance-weighted voting (Liu & Ihler 2014's moment-matching view of
    voting): each owner votes for its estimate with mass 1 / Vhat^i_aa; the
    weighted *median* of the votes wins. With two owners this coincides
    with max-voting (up to exact weight ties); with larger owner sets it
    stays robust to any minority of diverged owners without handing the
    whole decision to a single voter the way argmax does."""
    name = "weighted_vote"
    needs = frozenset({"variance"})
    scalars_per_shared_param = 2     # estimate + vote mass

    def group_weights(self, est, diag, bad, cols):
        # one-hot weights at the weighted-median owner, so the grouped
        # driver's weighted average reduces to the winning vote exactly
        w = 1.0 / diag                                        # 0 where bad
        order = np.argsort(est, axis=1, kind="stable")
        w_s = np.take_along_axis(w, order, axis=1)
        cum = np.cumsum(w_s, axis=1)
        half = 0.5 * cum[:, -1:]
        # first sorted position whose cumulative vote mass reaches half;
        # zero-mass (bad) positions can never be first to cross
        med = np.argmax(cum >= half, axis=1)
        onehot = np.zeros_like(est)
        rows = np.arange(est.shape[0])
        onehot[rows, order[rows, med]] = 1.0
        return onehot

    def combine_candidates(self, cands):
        order = sorted(range(len(cands)), key=lambda i: cands[i][0])
        masses = np.array([1.0 / cands[i][1] for i in order])
        cum = np.cumsum(masses)
        med = int(np.argmax(cum >= 0.5 * cum[-1]))
        return float(cands[order[med]][0])


class TrimmedMeanCombiner(Combiner):
    """Byzantine-robust coordinate-wise trimmed mean.

    Two filters compose, then the surviving candidates are plainly
    averaged:

    * **symmetric order-statistic trim** — drop the ``floor(trim * k)``
      smallest and largest estimates among the sane candidates (the
      classical coordinate-wise trimmed mean; breakdown point = ``trim``).
      With the paper's two-owner edge blocks this trims nothing, which is
      why the second filter exists;
    * **anchored compatibility filter** — candidates farther than
      ``kappa * sqrt(V_anchor + V_cand)`` from the *home* candidate
      (column 0 convention: the lowest-index sane owner in the batch
      driver, the receiver's own fit in streaming fusion) are discarded.
      Since the streamed variances shrink as 1/n_i, any fixed-magnitude
      lie (sign-flip, colluding constant) is eventually rejected, while
      honest candidates — estimates of the same truth — stay within a few
      standard errors of the anchor.

    A Byzantine *peer* therefore never moves the combined value beyond the
    compatibility radius of the home's own data; only a corrupted home
    (which no per-parameter rule can fix at two owners) breaks it.
    """
    name = "trimmed_mean"
    needs = frozenset({"variance"})
    scalars_per_shared_param = 2     # estimate + variance (the filter input)
    anchored = True

    def __init__(self, trim: float = 0.25, kappa: float = 3.0) -> None:
        if not (0.0 <= trim < 0.5):
            raise ValueError(
                f"trim fraction must be in [0.0, 0.5), got {trim!r} "
                f"(trimming half or more of the owners from each side "
                f"leaves nothing to average)")
        if not (kappa > 0.0 and np.isfinite(kappa)):
            raise ValueError(f"kappa must be a finite positive "
                             f"compatibility radius, got {kappa!r}")
        self.trim = float(trim)
        self.kappa = float(kappa)
        self.breakdown_point = float(trim)

    def _keep_mask(self, est: np.ndarray, var: np.ndarray,
                   bad: np.ndarray, anchor: np.ndarray) -> np.ndarray:
        """(P, k) keep mask: symmetric trim ∩ anchored compatibility."""
        P, k = est.shape
        rows = np.arange(P)
        a_e = est[rows, anchor]
        a_v = np.where(np.isfinite(var[rows, anchor]),
                       var[rows, anchor], 0.0)
        tol = self.kappa * np.sqrt(np.maximum(a_v[:, None] + var, 1e-24))
        keep = np.abs(est - a_e[:, None]) <= tol
        # symmetric trim among sane candidates: rank sane estimates
        # ascending (bad pushed to the end) and drop t from each side
        sane = (~bad).sum(axis=1)
        t = np.minimum((self.trim * sane).astype(np.int64),
                       np.maximum(sane - 1, 0) // 2)
        order = np.argsort(np.where(bad, np.inf, est), axis=1, kind="stable")
        rank = np.empty_like(order)
        np.put_along_axis(rank, order, np.broadcast_to(np.arange(k), (P, k)),
                          axis=1)
        keep &= (rank >= t[:, None]) & (rank < (sane - t)[:, None])
        # the anchor itself always survives (it is its own reference)
        keep[rows, anchor] = True
        return keep & ~bad

    def group_weights(self, est, diag, bad, cols):
        anchor = np.argmax(~bad, axis=1)         # first sane owner = home
        return self._keep_mask(est, diag, bad, anchor).astype(np.float64)

    def filter_mask(self, cands, own_index=None):
        est = np.array([[e for e, _ in cands]])
        var = np.array([[v for _, v in cands]])
        bad = ~np.isfinite(est) | ~np.isfinite(var)
        anchor = np.array([0 if own_index is None else int(own_index)])
        return self._keep_mask(est, var, bad, anchor)[0]

    def combine_candidates(self, cands, own_index=None):
        keep = self.filter_mask(cands, own_index=own_index)
        est = np.array([e for e, _ in cands])
        return float(np.mean(est[keep]))


class KrumCombiner(Combiner):
    """Krum-style nearest-neighbor selection over owner candidates.

    Each sane candidate is scored by the summed squared distance to its
    ``q = max(k_sane - t - 2, 1)`` nearest other candidates (``t =
    floor((k_sane - 1) / 2)`` assumed Byzantines, the Krum rule of
    Blanchard et al. 2017 collapsed to per-coordinate scalars); the lowest
    score wins. Exact score ties — in particular the unavoidable tie at
    the paper's two-owner edge blocks, where both candidates see the same
    single distance — resolve to the *home* candidate (column 0 in the
    batch driver, the receiver's own fit in streaming fusion): when
    geometry cannot distinguish honest from lying, trust your own data.
    Needs no transmitted variance, so its messages are as cheap as
    Linear-Uniform's.
    """
    name = "krum"
    needs = frozenset()
    scalars_per_shared_param = 1     # estimate only (distances need no V)
    breakdown_point = 0.5
    anchored = True

    @staticmethod
    def _scores(est: np.ndarray, bad: np.ndarray) -> np.ndarray:
        """(P, k) Krum scores (inf where bad)."""
        d2 = (est[:, :, None] - est[:, None, :]) ** 2          # (P, k, k)
        k = est.shape[1]
        eye = np.eye(k, dtype=bool)
        invalid = bad[:, :, None] | bad[:, None, :] | eye
        d2 = np.where(invalid, np.inf, d2)
        d2_sorted = np.sort(d2, axis=2)
        sane = (~bad).sum(axis=1)
        t = np.maximum(sane - 1, 0) // 2
        q = np.maximum(sane - t - 2, 1)
        take = np.minimum(q, np.maximum(sane - 1, 1))          # (P,)
        idx = np.arange(k)
        mask = idx[None, None, :] < take[:, None, None]
        scores = np.where(mask & np.isfinite(d2_sorted),
                          d2_sorted, 0.0).sum(axis=2)
        return np.where(bad, np.inf, scores)

    def group_weights(self, est, diag, bad, cols):
        scores = self._scores(est, bad)
        # argmin takes the FIRST minimum: column order is owner (node)
        # order, so exact ties resolve to the lowest-index sane owner —
        # the home-sensor convention
        winner = np.argmin(scores, axis=1)
        onehot = np.zeros_like(est)
        onehot[np.arange(est.shape[0]), winner] = 1.0
        return onehot

    def combine_candidates(self, cands, own_index=None):
        est = np.array([[e for e, _ in cands]])
        bad = ~np.isfinite(est)
        scores = self._scores(est, bad)[0]
        if own_index is not None and np.isfinite(scores[own_index]) \
                and scores[own_index] <= scores.min():
            return float(est[0, own_index])
        return float(est[0, int(np.argmin(scores))])


class OptimalCombiner(Combiner):
    """Linear-Opt (Prop 4.6): weights from the empirical cross-covariance
    of the owners' influence columns, with a diagonal fallback when the
    covariance is degenerate."""
    name = "optimal"
    needs = frozenset({"variance", "influence"})
    scalars_per_shared_param = 2     # + the n influence samples, billed
    #                                  separately (see stream.costs)

    def group_weights(self, est, diag, bad, cols):
        n = cols.shape[-1]
        Va = cols @ cols.transpose(0, 2, 1) / n               # (P, k, k)
        k = est.shape[1]
        finite = np.isfinite(Va).all(axis=(1, 2))
        Va = np.where(finite[:, None, None], Va, np.eye(k))
        w = np.linalg.solve(Va + 1e-10 * np.eye(k),
                            np.ones((est.shape[0], k, 1)))[..., 0]
        fallback = (bad.any(axis=1) | ~finite
                    | (np.abs(w.sum(axis=1)) < 1e-12))
        return np.where(fallback[:, None], 1.0 / diag, w)


class MatrixCombiner(Combiner):
    """Matrix consensus with W^i = Hhat^i (Eq. 7, Cor 4.2).

    Not distributable (global matrix inverse) — included as the reference
    point that is asymptotically equivalent to joint MPLE.

    Diverged local fits (non-finite theta/H, or estimates outside the
    shared trust radius) are *excluded* from the information sums — the
    same disqualification rule the grouped driver applies — instead of
    poisoning the global solve with NaNs; parameters whose every
    contributing fit was excluded fall back to ``theta_fixed`` through the
    ridge term.
    """
    name = "matrix"
    needs = frozenset({"hessian"})
    scalars_per_shared_param = None

    def combine(self, graph, fits, include_singleton=True, theta_fixed=None,
                family=None):
        n_params = graph.n_params if family is None else family.n_params(graph)
        if theta_fixed is None:
            theta_fixed = np.zeros(n_params, dtype=np.float64)
        theta = np.array(theta_fixed, dtype=np.float64, copy=True)
        free = free_indices(graph, include_singleton, family)
        pos_of = {int(a): k for k, a in enumerate(free)}
        d = len(free)
        W_sum = np.zeros((d, d))
        Wt_sum = np.zeros(d)
        for f in fits:
            if not (np.all(np.isfinite(f.theta)) and np.all(np.isfinite(f.H))
                    and np.max(np.abs(f.theta)) <= TRUST_RADIUS):
                continue
            idx = np.array([pos_of[a] for a in f.beta])
            W_sum[np.ix_(idx, idx)] += f.H
            Wt_sum[idx] += f.H @ f.theta
        sol = np.linalg.solve(W_sum + 1e-10 * np.eye(d), Wt_sum)
        theta[free] = sol
        return theta


# --------------------------------------------------------------- registry
_REGISTRY: Dict[str, Combiner] = {}


def register_combiner(combiner: Combiner) -> Combiner:
    """Register (or replace) a combiner instance under ``combiner.name``."""
    if not combiner.name:
        raise ValueError("combiner needs a non-empty name")
    _REGISTRY[combiner.name] = combiner
    return combiner


def get_combiner(name: str) -> Combiner:
    """Resolve a combiner by registry name; unknown names fail loudly with
    the list of registered schemes (never fall through silently)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown combiner scheme {name!r}; registered combiners: "
            f"{[c.name for c in registered_combiners()]}") from None


def registered_combiners() -> Tuple[Combiner, ...]:
    """All registered combiners, name-sorted (the conformance axis)."""
    return tuple(_REGISTRY[k] for k in sorted(_REGISTRY))


def streamable_combiners() -> Tuple[Combiner, ...]:
    """Combiners usable as streaming one-step schemes: distributable as one
    message round AND able to fuse (estimate, variance) candidate pairs on
    the receiver — detected by the subclass *overriding*
    ``combine_candidates`` (never by executing it on fabricated data,
    which would let one misbehaving third-party combiner break simulator
    construction for every scheme). Registration order (paper order
    first)."""
    return tuple(
        c for c in _REGISTRY.values()
        if c.scalars_per_shared_param is not None
        and type(c).combine_candidates is not Combiner.combine_candidates)


#: canonical instances — the paper's four schemes, the matrix reference,
#: and the 2014 variance-weighted-voting addition (the registry's proof of
#: pluggability)
UNIFORM = register_combiner(UniformCombiner())
DIAGONAL = register_combiner(DiagonalCombiner())
OPTIMAL = register_combiner(OptimalCombiner())
MAX = register_combiner(MaxCombiner())
MATRIX = register_combiner(MatrixCombiner())
WEIGHTED_VOTE = register_combiner(WeightedVoteCombiner())
TRIMMED_MEAN = register_combiner(TrimmedMeanCombiner())
KRUM = register_combiner(KrumCombiner())
