"""One-step consensus combiners (paper Sec. 3.1, Eq. 4-5, 7).

``combine(graph, fits, scheme)`` is the legacy facade kept for the seed
API; schemes now live as pluggable strategy objects in the combiner
registry (:mod:`repro.core.combiners` — ``register_combiner`` /
``get_combiner`` / ``registered_combiners``), which is what the
estimation-plan API (:mod:`repro.api`), the streaming simulator, and the
conformance harness dispatch through. An unknown scheme name raises a
``ValueError`` listing the registered combiners.

Schemes (see :mod:`repro.core.combiners` for the strategy objects):
  uniform        — Linear-Uniform, w = 1
  diagonal       — Linear-Diagonal, w^i_a = 1 / Vhat^i_aa        (Prop 4.7)
  optimal        — Linear-Opt,     w_a = Vhat_a^{-1} e           (Prop 4.6)
  max            — Max-Diagonal,   pick argmax 1 / Vhat^i_aa     (Prop 4.4)
  weighted_vote  — variance-weighted voting (weighted median)    (2014)
  matrix         — matrix consensus with W^i = Hhat^i (Eq. 7)    (Cor 4.2)
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from .combiners import TRUST_RADIUS, get_combiner  # noqa: F401  (shared)
from .estimators import LocalFit
from .graphs import Graph

#: the seed's scheme tuple, kept name-stable; the full live axis is
#: ``repro.core.combiners.registered_combiners()``
SCHEMES = ("uniform", "diagonal", "optimal", "max", "matrix")


def empirical_cross_cov(fits: List[LocalFit],
                        owners_a: List[Tuple[int, int]]) -> np.ndarray:
    """Vhat_alpha: sample covariance of influence columns s^i_a (Prop 4.6)."""
    cols = np.stack([fits[i].s[:, pos] for (i, pos) in owners_a], axis=1)
    n = cols.shape[0]
    return cols.T @ cols / n


def combine(graph: Graph, fits: List[LocalFit], scheme: str,
            include_singleton: bool = True,
            theta_fixed: Optional[np.ndarray] = None,
            family=None) -> np.ndarray:
    """One-step consensus estimate; returns the full flat theta vector.

    Thin shim over the combiner registry: resolves ``scheme`` by name
    (raising ``ValueError`` with the registered names on an unknown one)
    and runs the strategy's vectorized grouped driver — numerics are
    unchanged from the historical inline implementation (the 1e-10 golden
    fixtures pin this). See :class:`repro.core.combiners.Combiner`.
    """
    return get_combiner(scheme).combine(
        graph, fits, include_singleton=include_singleton,
        theta_fixed=theta_fixed, family=family)


def mse(theta_hat: np.ndarray, theta_star: np.ndarray,
        free: Optional[Sequence[int]] = None) -> float:
    """||theta_hat - theta*||^2 over the estimated coordinates."""
    d = theta_hat - theta_star
    if free is not None:
        d = d[np.asarray(free)]
    return float(d @ d)
