"""One-step consensus combiners (paper Sec. 3.1, Eq. 4-5, 7).

Operate on the per-node :class:`LocalFit` results; every scheme returns a
full flat theta (fixed coordinates taken from ``theta_fixed``).

Schemes:
  uniform   — Linear-Uniform, w = 1
  diagonal  — Linear-Diagonal, w^i_a = 1 / Vhat^i_aa           (Prop 4.7)
  optimal   — Linear-Opt,     w_a = Vhat_a^{-1} e              (Prop 4.6)
  max       — Max-Diagonal,   pick argmax 1 / Vhat^i_aa        (Prop 4.4)
  matrix    — matrix consensus with W^i = Hhat^i (Eq. 7)       (Cor 4.2)
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .asymptotics import param_owners, free_indices
from .estimators import LocalFit
from .graphs import Graph

SCHEMES = ("uniform", "diagonal", "optimal", "max", "matrix")


def empirical_cross_cov(fits: List[LocalFit],
                        owners_a: List[Tuple[int, int]]) -> np.ndarray:
    """Vhat_alpha: sample covariance of influence columns s^i_a (Prop 4.6)."""
    cols = np.stack([fits[i].s[:, pos] for (i, pos) in owners_a], axis=1)
    n = cols.shape[0]
    return cols.T @ cols / n


def combine(graph: Graph, fits: List[LocalFit], scheme: str,
            include_singleton: bool = True,
            theta_fixed: Optional[np.ndarray] = None) -> np.ndarray:
    """One-step consensus estimate; returns the full flat theta vector."""
    if theta_fixed is None:
        theta_fixed = np.zeros(graph.n_params, dtype=np.float64)
    theta = np.array(theta_fixed, dtype=np.float64, copy=True)

    if scheme == "matrix":
        return _matrix_consensus(graph, fits, include_singleton, theta)

    owners = param_owners(graph, include_singleton)
    for a, own in owners.items():
        est = np.array([fits[i].theta[pos] for (i, pos) in own])
        diag = np.array([max(fits[i].V[pos, pos], 1e-12) for (i, pos) in own])
        # Robustness guard: a saturated/diverged local fit (quasi-separation,
        # e.g. high-degree hubs at small n) yields non-finite estimates or a
        # deceptively tiny Vhat. Treat such owners as infinite-variance so
        # every weighting scheme zeroes them out; keep uniform truly uniform
        # only over sane owners.
        bad = (~np.isfinite(est)) | (~np.isfinite(diag)) | (np.abs(est) > 25.0)
        if bad.all():
            theta[a] = 0.0
            continue
        diag = np.where(bad, np.inf, diag)
        k = len(own)
        if scheme == "uniform":
            w = np.where(bad, 0.0, 1.0)
        elif scheme == "diagonal":
            w = 1.0 / diag
        elif scheme == "max":
            w = np.zeros(k)
            w[int(np.argmin(diag))] = 1.0
        elif scheme == "optimal":
            Va = empirical_cross_cov(fits, own)
            if bad.any() or not np.all(np.isfinite(Va)):
                w = 1.0 / diag                # fall back to diagonal weights
            else:
                w = np.linalg.solve(Va + 1e-10 * np.eye(k), np.ones(k))
                if abs(w.sum()) < 1e-12:      # degenerate; fall back
                    w = 1.0 / diag
        else:
            raise ValueError(f"unknown scheme {scheme!r}")
        w = np.where(bad, 0.0, w)
        est = np.where(bad, 0.0, est)
        theta[a] = float(w @ est / w.sum())
    return theta


def _matrix_consensus(graph: Graph, fits: List[LocalFit],
                      include_singleton: bool,
                      theta: np.ndarray) -> np.ndarray:
    """theta = (sum_i W^i)^{-1} sum_i W^i theta^i with W^i = Hhat^i (Eq. 7).

    Not distributable (global matrix inverse) — included as the reference
    point that is asymptotically equivalent to joint MPLE (Cor 4.2).
    """
    free = free_indices(graph, include_singleton)
    pos_of = {int(a): k for k, a in enumerate(free)}
    d = len(free)
    W_sum = np.zeros((d, d))
    Wt_sum = np.zeros(d)
    for f in fits:
        idx = np.array([pos_of[a] for a in f.beta])
        W_sum[np.ix_(idx, idx)] += f.H
        Wt_sum[idx] += f.H @ f.theta
    sol = np.linalg.solve(W_sum + 1e-10 * np.eye(d), Wt_sum)
    theta[free] = sol
    return theta


def mse(theta_hat: np.ndarray, theta_star: np.ndarray,
        free: Optional[Sequence[int]] = None) -> float:
    """||theta_hat - theta*||^2 over the estimated coordinates."""
    d = theta_hat - theta_star
    if free is not None:
        d = d[np.asarray(free)]
    return float(d @ d)
