"""One-step consensus combiners (paper Sec. 3.1, Eq. 4-5, 7).

Operate on the per-node :class:`LocalFit` results; every scheme returns a
full flat theta (fixed coordinates taken from ``theta_fixed``).

Schemes:
  uniform   — Linear-Uniform, w = 1
  diagonal  — Linear-Diagonal, w^i_a = 1 / Vhat^i_aa           (Prop 4.7)
  optimal   — Linear-Opt,     w_a = Vhat_a^{-1} e              (Prop 4.6)
  max       — Max-Diagonal,   pick argmax 1 / Vhat^i_aa        (Prop 4.4)
  matrix    — matrix consensus with W^i = Hhat^i (Eq. 7)       (Cor 4.2)
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .asymptotics import param_owners, free_indices
from .estimators import LocalFit
from .graphs import Graph

SCHEMES = ("uniform", "diagonal", "optimal", "max", "matrix")

#: estimates beyond this magnitude mark a diverged local fit
#: (quasi-separation); shared with repro.stream's warm-start reset and
#: message guards so streaming disqualifies owners exactly when combine does
TRUST_RADIUS = 25.0


def empirical_cross_cov(fits: List[LocalFit],
                        owners_a: List[Tuple[int, int]]) -> np.ndarray:
    """Vhat_alpha: sample covariance of influence columns s^i_a (Prop 4.6)."""
    cols = np.stack([fits[i].s[:, pos] for (i, pos) in owners_a], axis=1)
    n = cols.shape[0]
    return cols.T @ cols / n


def _owner_groups(owners: Dict[int, List[Tuple[int, int]]]):
    """Group params by owner count k -> (param_idx (P,), node (P,k), pos (P,k)).

    Owner counts are tiny (1 for singletons, 2 for edges), so grouping by k
    turns the per-parameter Python loop into a handful of batched array ops.
    """
    by_k: Dict[int, List[Tuple[int, List[Tuple[int, int]]]]] = {}
    for a, own in owners.items():
        by_k.setdefault(len(own), []).append((a, own))
    out = {}
    for k, items in by_k.items():
        aidx = np.array([a for a, _ in items], dtype=np.int64)
        node = np.array([[i for (i, _) in own] for _, own in items],
                        dtype=np.int64)
        pos = np.array([[p_ for (_, p_) in own] for _, own in items],
                       dtype=np.int64)
        out[k] = (aidx, node, pos)
    return out


def combine(graph: Graph, fits: List[LocalFit], scheme: str,
            include_singleton: bool = True,
            theta_fixed: Optional[np.ndarray] = None,
            family=None) -> np.ndarray:
    """One-step consensus estimate; returns the full flat theta vector.

    Vectorized over the owner structure: parameters are grouped by owner
    count and every group's weights/averages are computed with batched
    float64 array ops (no per-parameter Python loop). Single-owner
    parameters — the singleton blocks — pass the local estimate through
    exactly. With a ``family``, ownership runs over the family's parameter
    *blocks* (every scalar of an edge block shares the block's two owners,
    at ``family.beta`` block positions); the default is the scalar Ising
    layout.
    """
    n_params = graph.n_params if family is None else family.n_params(graph)
    if theta_fixed is None:
        theta_fixed = np.zeros(n_params, dtype=np.float64)
    theta = np.array(theta_fixed, dtype=np.float64, copy=True)

    if scheme == "matrix":
        return _matrix_consensus(graph, fits, include_singleton, theta,
                                 family)
    if scheme not in SCHEMES:
        raise ValueError(f"unknown scheme {scheme!r}")

    # pad per-node results into dense (p, dmax) float64 stacks
    dmax = max(len(f.theta) for f in fits)
    theta_mat = np.zeros((graph.p, dmax), dtype=np.float64)
    vdiag_mat = np.ones((graph.p, dmax), dtype=np.float64)
    for f in fits:
        d = len(f.theta)
        theta_mat[f.i, :d] = f.theta
        vdiag_mat[f.i, :d] = np.diag(f.V)
    s_pad = None
    if scheme == "optimal":
        n = fits[0].s.shape[0]
        s_pad = np.zeros((graph.p, n, dmax), dtype=np.float64)
        for f in fits:
            s_pad[f.i, :, :len(f.theta)] = f.s

    owners = param_owners(graph, include_singleton, family)
    for k, (aidx, node, pos) in _owner_groups(owners).items():
        est = theta_mat[node, pos]                          # (P, k)
        diag = np.maximum(vdiag_mat[node, pos], 1e-12)
        # Robustness guard: a saturated/diverged local fit (quasi-separation,
        # e.g. high-degree hubs at small n) yields non-finite estimates or a
        # deceptively tiny Vhat. Treat such owners as infinite-variance so
        # every weighting scheme zeroes them out; keep uniform truly uniform
        # only over sane owners.
        bad = (~np.isfinite(est)) | (~np.isfinite(diag)) \
            | (np.abs(est) > TRUST_RADIUS)
        est = np.where(bad, 0.0, est)
        all_bad = bad.all(axis=1)

        if k == 1:
            # exact passthrough: a parameter with one owner (the singletons)
            # IS the local estimate under every weighting scheme.
            theta[aidx] = np.where(all_bad, 0.0, est[:, 0])
            continue

        diag = np.where(bad, np.inf, diag)
        if scheme == "uniform":
            w = np.where(bad, 0.0, 1.0)
        elif scheme == "diagonal":
            w = 1.0 / diag
        elif scheme == "max":
            w = np.zeros_like(est)
            w[np.arange(len(aidx)), np.argmin(diag, axis=1)] = 1.0
        else:                                               # optimal
            cols = s_pad[node, :, pos]                      # (P, k, n)
            n = cols.shape[-1]
            Va = cols @ cols.transpose(0, 2, 1) / n         # (P, k, k)
            finite = np.isfinite(Va).all(axis=(1, 2))
            Va = np.where(finite[:, None, None], Va, np.eye(k))
            w = np.linalg.solve(Va + 1e-10 * np.eye(k),
                                np.ones((len(aidx), k, 1)))[..., 0]
            fallback = (bad.any(axis=1) | ~finite
                        | (np.abs(w.sum(axis=1)) < 1e-12))
            w = np.where(fallback[:, None], 1.0 / diag, w)
        w = np.where(bad, 0.0, w)
        wsum = np.where(all_bad, 1.0, w.sum(axis=1))
        theta[aidx] = np.where(all_bad, 0.0, (w * est).sum(axis=1) / wsum)
    return theta


def _matrix_consensus(graph: Graph, fits: List[LocalFit],
                      include_singleton: bool,
                      theta: np.ndarray, family=None) -> np.ndarray:
    """theta = (sum_i W^i)^{-1} sum_i W^i theta^i with W^i = Hhat^i (Eq. 7).

    Not distributable (global matrix inverse) — included as the reference
    point that is asymptotically equivalent to joint MPLE (Cor 4.2).
    """
    free = free_indices(graph, include_singleton, family)
    pos_of = {int(a): k for k, a in enumerate(free)}
    d = len(free)
    W_sum = np.zeros((d, d))
    Wt_sum = np.zeros(d)
    for f in fits:
        idx = np.array([pos_of[a] for a in f.beta])
        W_sum[np.ix_(idx, idx)] += f.H
        Wt_sum[idx] += f.H @ f.theta
    sol = np.linalg.solve(W_sum + 1e-10 * np.eye(d), Wt_sum)
    theta[free] = sol
    return theta


def mse(theta_hat: np.ndarray, theta_star: np.ndarray,
        free: Optional[Sequence[int]] = None) -> float:
    """||theta_hat - theta*||^2 over the estimated coordinates."""
    d = theta_hat - theta_star
    if free is not None:
        d = d[np.asarray(free)]
    return float(d @ d)
