"""Pluggable telemetry sinks.

Every :class:`~repro.telemetry.recorder.Recorder` aggregates in memory;
a :class:`JsonlSink` additionally appends each event — one JSON object per
line — to a durable log whose replay reconstructs the run's accounting
(:mod:`repro.telemetry.replay`). The file is opened lazily in append mode
so several recorders (or resumed runs) can extend one log.
"""
from __future__ import annotations

import json
import os
from typing import Iterator, List


def _jsonable(v):
    """Coerce tag/value payloads to plain JSON scalars and lists."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if hasattr(v, "item"):          # numpy scalars
        return v.item()
    return str(v)


class JsonlSink:
    """Append-only JSONL event log (one event object per line)."""

    def __init__(self, path: str) -> None:
        self.path = path
        self._f = None

    def write(self, event: dict) -> None:
        if self._f is None:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._f = open(self.path, "a")
        self._f.write(json.dumps(
            {k: _jsonable(v) for k, v in event.items()}) + "\n")

    def flush(self) -> None:
        if self._f is not None:
            self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __del__(self):  # best-effort durability for abandoned recorders
        try:
            self.close()
        except Exception:
            pass


def iter_jsonl(path: str) -> Iterator[dict]:
    """Stream events back out of a JSONL log."""
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                yield json.loads(line)


def read_jsonl(path: str) -> List[dict]:
    """The whole event log as a list (see :func:`iter_jsonl` to stream)."""
    return list(iter_jsonl(path))
