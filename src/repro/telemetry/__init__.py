"""Telemetry: structured spans, a metrics registry, and pluggable sinks.

The observability substrate for the estimation stack. A frozen
:class:`TelemetrySpec` on a :class:`~repro.api.plan.Plan` turns recording
on; the default is the shared :data:`NULL_RECORDER`, whose every method is
a no-op so instrumented hot paths stay allocation-free and bit-identical
when telemetry is off.

* :class:`Recorder` — hierarchical spans (wall time + bucket-solver
  compile-count deltas), counters/gauges/histograms, per-round timeline
  points, and trace-time kernel tags.
* sinks — every event lands in the in-memory aggregator (exposed as
  ``EstimateResult.telemetry`` / ``StreamResult.timeline(metric)``) and,
  when ``TelemetrySpec.jsonl`` names a path, in an append-only JSONL
  event log.
* :mod:`~repro.telemetry.replay` — reconstructs the exact comm accounting
  (the :class:`~repro.stream.network.Network` counters) from a JSONL log.
"""
from .recorder import (NULL_RECORDER, NullRecorder, Recorder,
                       TelemetrySnapshot, make_recorder, record_kernel_trace)
from .replay import (read_events, replay_comm_scalars,
                     replay_network_counters, timeline_from_events)
from .sinks import JsonlSink, read_jsonl
from .spec import TelemetrySpec

__all__ = [
    "TelemetrySpec", "Recorder", "NullRecorder", "NULL_RECORDER",
    "TelemetrySnapshot", "make_recorder", "record_kernel_trace",
    "JsonlSink", "read_jsonl", "read_events", "replay_network_counters",
    "replay_comm_scalars", "timeline_from_events",
]
