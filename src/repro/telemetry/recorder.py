"""Recorders: hierarchical spans, a metrics registry, and the null default.

Two implementations of one implicit protocol:

* :class:`NullRecorder` (the module singleton :data:`NULL_RECORDER`) — the
  default every instrumented code path receives when telemetry is off.
  Every method is a constant no-op and ``span`` returns one shared,
  stateless context manager, so hot paths stay allocation-free; callers
  guard tag-building work behind ``recorder.enabled``.
* :class:`Recorder` — the live implementation. Spans nest (a span's
  ``path`` is the slash-joined stack of open span names) and carry wall
  time plus the bucket-solver compile-count delta observed while they
  were open; counters accumulate, gauges keep the last value, histograms
  keep observations, and ``point`` records (round, value) timeline
  samples. Every event lands in the in-memory list and, when the spec
  names a ``jsonl`` path, in the append-only JSONL sink.

While any real span is open the recorder is also *active* for trace-time
kernel tags: :func:`record_kernel_trace`, called from the kernel dispatch
layer (``repro.kernels.cl.ops``) during jit tracing, lands kernel-kind and
shape events on the innermost active recorder. With no active recorder the
hook is a single falsy list check.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from .sinks import JsonlSink
from .spec import TelemetrySpec

__all__ = ["NullRecorder", "NULL_RECORDER", "Recorder", "TelemetrySnapshot",
           "make_recorder", "record_kernel_trace"]


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullRecorder:
    """The zero-overhead default: every method is a no-op."""

    __slots__ = ()
    enabled = False

    def span(self, name, **tags):
        return _NULL_SPAN

    def inc(self, name, value=1, **tags):
        pass

    def gauge(self, name, value, **tags):
        pass

    def observe(self, name, value, **tags):
        pass

    def event(self, name, **tags):
        pass

    def point(self, metric, rnd, value):
        pass

    def mark(self) -> int:
        return 0

    def snapshot(self, since: int = 0):
        return None

    def flush(self):
        pass


NULL_RECORDER = NullRecorder()

#: stack of recorders with an open span — the trace-time kernel-tag target
_ACTIVE: List["Recorder"] = []


def record_kernel_trace(name: str, **tags) -> None:
    """Tag the innermost active recorder with a trace-time kernel event.

    Called from the kernel dispatch layer while jit traces a compiled
    region; with telemetry off (no active recorder) this is one list
    check.
    """
    if _ACTIVE:
        _ACTIVE[-1].event(name, **tags)


def _bucket_compiles() -> int:
    # late import: core.batched itself imports this module for NULL_RECORDER
    try:
        from ..core.batched import bucket_compile_count, prox_compile_count
        fit, prox = bucket_compile_count(), prox_compile_count()
        if fit < 0 or prox < 0:
            return -1
        return fit + prox
    except Exception:
        return -1


class _Span:
    """One open span; records start/end events and restores the stack."""

    __slots__ = ("rec", "name", "_t0", "_c0")

    def __init__(self, rec: "Recorder", name: str, tags: dict):
        self.rec = rec
        self.name = name
        rec._stack.append(name)
        _ACTIVE.append(rec)
        if rec._outermost_profile():
            rec._profile_start()
        self._c0 = _bucket_compiles()
        rec._emit("span_start", "/".join(rec._stack), tags=tags or None)
        self._t0 = time.perf_counter()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self._t0
        c1 = _bucket_compiles()
        rec = self.rec
        path = "/".join(rec._stack)
        rec._emit("span_end", path, value=dur,
                  new_compiles=(c1 - self._c0
                                if c1 >= 0 and self._c0 >= 0 else 0))
        rec._stack.pop()
        _ACTIVE.pop()
        if not rec._stack:
            rec._profile_stop()
        return False


class Recorder:
    """Live telemetry recorder (see module docstring)."""

    enabled = True

    def __init__(self, spec: Optional[TelemetrySpec] = None) -> None:
        self.spec = spec if spec is not None else TelemetrySpec()
        self.events: List[dict] = []
        self._seq = 0
        self._t0 = time.perf_counter()
        self._stack: List[str] = []
        self._sink = (JsonlSink(self.spec.jsonl)
                      if self.spec.jsonl else None)
        self._profiling = False

    # ------------------------------------------------------------ emission
    def _emit(self, kind: str, name: str, value=None, tags=None,
              rnd=None, new_compiles=None) -> None:
        ev = {"seq": self._seq, "t": time.perf_counter() - self._t0,
              "kind": kind, "name": name}
        if value is not None:
            ev["value"] = value
        if rnd is not None:
            ev["round"] = int(rnd)
        if new_compiles is not None:
            ev["new_compiles"] = int(new_compiles)
        if tags:
            ev["tags"] = tags
        self._seq += 1
        self.events.append(ev)
        if self._sink is not None:
            self._sink.write(ev)

    # ------------------------------------------------------------- recording
    def span(self, name: str, **tags) -> _Span:
        """Open a hierarchical span (a context manager); on exit records
        wall seconds and the bucket-solver compile-count delta."""
        if not self.spec.spans:
            return _NULL_SPAN
        return _Span(self, name, tags)

    def inc(self, name: str, value=1, **tags) -> None:
        if self.spec.metrics:
            self._emit("counter", name, value=value, tags=tags or None)

    def gauge(self, name: str, value, **tags) -> None:
        if self.spec.metrics:
            self._emit("gauge", name, value=value, tags=tags or None)

    def observe(self, name: str, value, **tags) -> None:
        if self.spec.metrics:
            self._emit("hist", name, value=value, tags=tags or None)

    def event(self, name: str, **tags) -> None:
        self._emit("event", name, tags=tags or None)

    def point(self, metric: str, rnd: int, value) -> None:
        """One any-time timeline sample: metric value at stream round."""
        if self.spec.metrics:
            self._emit("point", metric, value=float(value), rnd=rnd)

    # ------------------------------------------------------------ profiling
    def _outermost_profile(self) -> bool:
        return (self.spec.profile_dir is not None
                and len(self._stack) == 1 and not self._profiling)

    def _profile_start(self) -> None:
        try:
            import jax
            jax.profiler.start_trace(self.spec.profile_dir)
            self._profiling = True
        except Exception:
            self._profiling = False

    def _profile_stop(self) -> None:
        if self._profiling:
            try:
                import jax
                jax.profiler.stop_trace()
            except Exception:
                pass
            self._profiling = False

    # ----------------------------------------------------------- reading out
    def mark(self) -> int:
        """Current event index — pass to :meth:`snapshot` to scope one
        verb's events out of a long-lived recorder."""
        return len(self.events)

    def snapshot(self, since: int = 0) -> "TelemetrySnapshot":
        """Aggregate events[since:] into a :class:`TelemetrySnapshot`."""
        return TelemetrySnapshot.from_events(self.events[since:])

    def flush(self) -> None:
        if self._sink is not None:
            self._sink.flush()

    def close(self) -> None:
        if self._sink is not None:
            self._sink.close()


@dataclasses.dataclass
class TelemetrySnapshot:
    """The in-memory aggregate of one run's events.

    events     — the raw event dicts (same schema as the JSONL log).
    counters   — name -> accumulated total.
    gauges     — name -> last recorded value.
    histograms — name -> list of observations.
    spans      — span path -> {"count", "total_s", "new_compiles"}.
    points     — metric -> list of (round, value) timeline samples.
    """

    events: List[dict]
    counters: Dict[str, float]
    gauges: Dict[str, float]
    histograms: Dict[str, List[float]]
    spans: Dict[str, dict]
    points: Dict[str, List[Tuple[int, float]]]

    @classmethod
    def from_events(cls, events: List[dict]) -> "TelemetrySnapshot":
        counters: Dict[str, float] = {}
        gauges: Dict[str, float] = {}
        hists: Dict[str, List[float]] = {}
        spans: Dict[str, dict] = {}
        points: Dict[str, List[Tuple[int, float]]] = {}
        for ev in events:
            kind, name = ev["kind"], ev["name"]
            if kind == "counter":
                counters[name] = counters.get(name, 0) + ev["value"]
            elif kind == "gauge":
                gauges[name] = ev["value"]
            elif kind == "hist":
                hists.setdefault(name, []).append(ev["value"])
            elif kind == "span_end":
                agg = spans.setdefault(
                    name, {"count": 0, "total_s": 0.0, "new_compiles": 0})
                agg["count"] += 1
                agg["total_s"] += ev["value"]
                agg["new_compiles"] += ev.get("new_compiles", 0)
            elif kind == "point":
                points.setdefault(name, []).append(
                    (ev["round"], ev["value"]))
        return cls(events=events, counters=counters, gauges=gauges,
                   histograms=hists, spans=spans, points=points)

    def counter(self, name: str, **tags) -> float:
        """Accumulated total of one counter restricted to matching tags.

        ``counters[name]`` aggregates across every tag combination; this
        accessor sums only increments whose tags include every given
        ``key=value`` pair — how the serving tier's tests read per-tenant
        and per-rejection-reason admission counts out of one registry
        (e.g. ``snap.counter("serve.rejected", reason="budget_exhausted")``).
        """
        total = 0.0
        for ev in self.events:
            if ev["kind"] != "counter" or ev["name"] != name:
                continue
            evt = ev.get("tags") or {}
            if all(evt.get(k) == v for k, v in tags.items()):
                total += ev["value"]
        return total

    def timeline(self, metric: str) -> Tuple[np.ndarray, np.ndarray]:
        """(rounds, values) arrays for one recorded timeline metric."""
        if metric not in self.points:
            raise KeyError(
                f"no timeline recorded for {metric!r}; have "
                f"{sorted(self.points)}")
        pts = self.points[metric]
        return (np.asarray([r for r, _ in pts], dtype=np.int64),
                np.asarray([v for _, v in pts], dtype=np.float64))


def make_recorder(spec) -> "Recorder | NullRecorder":
    """The recorder for a plan's telemetry declaration: the shared
    :data:`NULL_RECORDER` when ``spec`` is None/falsy, a live
    :class:`Recorder` otherwise. Accepts an existing recorder unchanged
    (so simulators can share a session's recorder)."""
    if spec is None or spec is False:
        return NULL_RECORDER
    if isinstance(spec, (Recorder, NullRecorder)):
        return spec
    if isinstance(spec, dict):
        spec = TelemetrySpec.from_dict(spec)
    if not isinstance(spec, TelemetrySpec):
        raise TypeError(f"expected TelemetrySpec, Recorder, or None; got "
                        f"{type(spec).__name__}")
    return Recorder(spec)
