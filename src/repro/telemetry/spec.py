"""The frozen, serializable telemetry declaration a :class:`Plan` carries.

Like :class:`~repro.stream.faults.FaultPlan`, a :class:`TelemetrySpec` is a
plain hashable value object: it rides on the (frozen, hashable) plan, keys
session caches, and round-trips exactly through ``to_dict``/``from_dict``
so plans with telemetry still serialize into configs and benchmark JSON.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class TelemetrySpec:
    """Declarative telemetry configuration.

    spans — record hierarchical spans (``fit`` → bucket solve → kernel
        dispatch; ``stream`` → round → receive/refit/combine; ``joint`` →
        ADMM iteration) with wall time and compile-count deltas.
    metrics — record counters/gauges/histograms (comm scalars by scheme,
        buffer occupancy, window effective counts, fault injections fired,
        robust-combiner rejections, per-bucket Newton iterations).
    jsonl — path of an append-only JSONL event log (None = in-memory
        only). Replaying the log reconstructs the exact comm accounting
        (see :mod:`repro.telemetry.replay`).
    profile_dir — when set, activate a ``jax.profiler`` trace around the
        outermost span of each instrumented verb (compiled regions show up
        in the profile); silently skipped if the profiler is unavailable.
    """

    spans: bool = True
    metrics: bool = True
    jsonl: Optional[str] = None
    profile_dir: Optional[str] = None

    def __post_init__(self):
        for field in ("jsonl", "profile_dir"):
            v = getattr(self, field)
            if v is not None and not isinstance(v, str):
                raise TypeError(f"TelemetrySpec.{field} must be a path "
                                f"string or None, got {type(v).__name__}")

    # ------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        """Plain-JSON form; exact inverse of :meth:`from_dict`."""
        return {"spans": self.spans, "metrics": self.metrics,
                "jsonl": self.jsonl, "profile_dir": self.profile_dir}

    @classmethod
    def from_dict(cls, d: dict) -> "TelemetrySpec":
        return cls(spans=bool(d.get("spans", True)),
                   metrics=bool(d.get("metrics", True)),
                   jsonl=d.get("jsonl"),
                   profile_dir=d.get("profile_dir"))
