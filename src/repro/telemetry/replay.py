"""Replay: reconstruct run accounting from a telemetry event stream.

The network layer emits one counter event per message transition —
``net.send`` / ``net.drop`` / ``net.deliver``, each valued at the message's
scalar count — so a JSONL log (or the in-memory event list) is a complete,
order-preserving record of the bandwidth ledger. Replaying it rebuilds the
exact :class:`~repro.stream.network.Network` counters, including the
in-flight remainders, and therefore the scalar-conservation invariant
``sent == delivered + dropped + in_flight`` that the stream benchmarks and
property tests assert.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from .sinks import read_jsonl

#: event names the network layer emits, in ledger order
NET_EVENTS = ("net.send", "net.drop", "net.deliver")


def read_events(path: str) -> List[dict]:
    """Load a JSONL event log (alias of :func:`repro.telemetry.sinks.
    read_jsonl`, re-exported here because replay is its main consumer)."""
    return read_jsonl(path)


def replay_network_counters(events: List[dict]) -> Dict[str, int]:
    """Rebuild the full :class:`Network` bandwidth ledger from events.

    Returns every counter of ``Network.counters_dict()`` plus the derived
    ``in_flight`` / ``scalars_in_flight`` remainders; exact by
    construction, since every transition was logged with its scalar count.
    """
    msgs = {"net.send": 0, "net.drop": 0, "net.deliver": 0}
    scal = {"net.send": 0, "net.drop": 0, "net.deliver": 0}
    for ev in events:
        name = ev.get("name")
        if ev.get("kind") == "counter" and name in msgs:
            msgs[name] += 1
            scal[name] += int(ev["value"])
    return {
        "msgs_sent": msgs["net.send"],
        "msgs_dropped": msgs["net.drop"],
        "msgs_delivered": msgs["net.deliver"],
        "scalars_sent": scal["net.send"],
        "scalars_dropped": scal["net.drop"],
        "scalars_delivered": scal["net.deliver"],
        "in_flight": msgs["net.send"] - msgs["net.drop"]
        - msgs["net.deliver"],
        "scalars_in_flight": scal["net.send"] - scal["net.drop"]
        - scal["net.deliver"],
    }


def replay_comm_scalars(events: List[dict]) -> int:
    """Total scalars transmitted — the comm-cost ledger a run actually
    spent, reconstructed from the log (matches ``Network.scalars_sent``
    and the per-scheme accounting asserted in ``BENCH_comm.json``)."""
    return replay_network_counters(events)["scalars_sent"]


def timeline_from_events(events: List[dict],
                         metric: str) -> Tuple[np.ndarray, np.ndarray]:
    """(rounds, values) for one timeline metric out of a raw event list."""
    pts = [(ev["round"], ev["value"]) for ev in events
           if ev.get("kind") == "point" and ev.get("name") == metric]
    if not pts:
        raise KeyError(f"no timeline points for {metric!r} in event log")
    return (np.asarray([r for r, _ in pts], dtype=np.int64),
            np.asarray([v for _, v in pts], dtype=np.float64))
