"""Autoregressive decoding over KV caches: prefill + batched greedy/
temperature generation for the transformer model zoo.

Moved verbatim from the pre-serving ``repro.serve.engine`` — these are
*model* utilities (the decode dry-run shapes and the arch smoke tests use
them), not a serving tier; ``repro.serve`` now hosts the multi-tenant
estimation session server.

``make_serve_step`` builds the one-token jitted step the decode dry-run
shapes (decode_32k, long_500k) lower. ``generate`` is the host loop used by
the examples; prefill reuses ``forward(..., return_cache=True)`` so the
prefill compute path is identical to training (and to the prefill_32k
dry-run shape).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig
from repro.models import transformer as T


def make_serve_step(cfg: ArchConfig, *, window_override: Optional[int] = None,
                    temperature: float = 0.0):
    """Returns serve_step(params, cache, tokens, pos, rng, enc_out=None).

    tokens: (B, 1) current token; returns (next_token (B, 1), logits, cache).
    """
    def serve_step(params, cache, tokens, pos, rng, enc_out=None):
        logits, cache = T.decode_step(cfg, params, cache, tokens, pos,
                                      enc_out=enc_out,
                                      window_override=window_override)
        last = logits[:, -1, : cfg.vocab_size].astype(jnp.float32)
        if temperature > 0.0:
            nxt = jax.random.categorical(rng, last / temperature, axis=-1)
        else:
            nxt = jnp.argmax(last, axis=-1)
        return nxt[:, None].astype(jnp.int32), logits, cache
    return serve_step


def prefill(cfg: ArchConfig, params, tokens, max_len: int, *,
            enc_frames=None, patch_embeds=None,
            window_override: Optional[int] = None):
    """Run the full-sequence forward and return (logits, cache) with the
    cache sized to ``max_len`` (prompt written at positions [0, S))."""
    logits, _, cache = T.forward(cfg, params, tokens, enc_frames=enc_frames,
                                 patch_embeds=patch_embeds, remat=False,
                                 return_cache=True, cache_len=max_len,
                                 window_override=window_override)
    return logits, cache


def generate(cfg: ArchConfig, params, prompt, n_new: int, *,
             max_len: Optional[int] = None, temperature: float = 0.0,
             seed: int = 0, enc_frames=None,
             window_override: Optional[int] = None):
    """Greedy/temperature generation. prompt: (B, S) int32."""
    b, s = prompt.shape
    max_len = max_len or (s + n_new)
    enc_out = None
    if cfg.enc_dec:
        enc_out = T.encode(cfg, params, enc_frames)
    logits, cache = prefill(cfg, params, prompt, max_len,
                            enc_frames=enc_frames,
                            window_override=window_override)
    step = jax.jit(make_serve_step(cfg, window_override=window_override,
                                   temperature=temperature))
    last = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None]
    last = last.astype(jnp.int32)
    out = [last]
    rng = jax.random.PRNGKey(seed)
    for t in range(n_new - 1):
        rng, sub = jax.random.split(rng)
        last, _, cache = step(params, cache, last, s + t, sub,
                              enc_out=enc_out)
        out.append(last)
    return jnp.concatenate(out, axis=1)
