"""Model composition: block registry, unit-scanned decoder stacks,
encoder-decoder (whisper) and early-fusion multimodal variants, plus the
single-token decode path with structured caches.

Layers are grouped into repeating "units" (the arch's ``pattern``); the stack
is a ``lax.scan`` over units so HLO size is independent of depth (critical
for 80 dry-run compiles). Heterogeneous patterns (Griffin's rec/rec/attn,
xLSTM's 7 mLSTM : 1 sLSTM) scan naturally: each pattern slot has its own
stacked params. Remainder layers (depth % pattern) run unscanned.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from . import moe as M
from . import ssm as S
from . import xlstm as X
from .common import (ArchConfig, abstract_tree, apply_norm, init_params,
                     mlp_apply, mlp_spec, norm_spec, spec)


# ----------------------------------------------------------- block registry
def _block_spec(cfg: ArchConfig, kind: str, stack: int):
    if kind == "attn":
        p = {"norm1": norm_spec(cfg, stack), "norm2": norm_spec(cfg, stack),
             "mlp": mlp_spec(cfg)}
        if cfg.attn_kind == "mla":
            p["attn"] = A.mla_spec(cfg, stack)
        else:
            p["attn"] = A.gqa_spec(cfg, stack)
        if stack:
            p["mlp"] = {k: spec((stack,) + v.shape, (None,) + v.axes,
                                v.init, v.scale, v.dtype)
                        for k, v in p["mlp"].items()}
        return p
    if kind == "attn_moe":
        p = {"norm1": norm_spec(cfg, stack), "norm2": norm_spec(cfg, stack),
             "attn": A.gqa_spec(cfg, stack), "moe": M.moe_spec(cfg, stack)}
        return p
    if kind == "rec":
        p = {"norm1": norm_spec(cfg, stack), "norm2": norm_spec(cfg, stack),
             "rec": S.rglru_spec(cfg, stack), "mlp": mlp_spec(cfg)}
        if stack:
            p["mlp"] = {k: spec((stack,) + v.shape, (None,) + v.axes,
                                v.init, v.scale, v.dtype)
                        for k, v in p["mlp"].items()}
        return p
    if kind == "m":
        return {"norm1": norm_spec(cfg, stack), "mix": X.mlstm_spec(cfg, stack)}
    if kind == "s":
        return {"norm1": norm_spec(cfg, stack), "mix": X.slstm_spec(cfg, stack)}
    if kind == "xattn":
        return {"norm1": norm_spec(cfg, stack), "norm2": norm_spec(cfg, stack),
                "norm3": norm_spec(cfg, stack), "attn": A.gqa_spec(cfg, stack),
                "cross": A.cross_spec(cfg, stack), "mlp": _stack_mlp(cfg, stack)}
    if kind == "enc":
        return {"norm1": norm_spec(cfg, stack), "norm2": norm_spec(cfg, stack),
                "attn": A.gqa_spec(cfg, stack), "mlp": _stack_mlp(cfg, stack)}
    raise ValueError(kind)


def _stack_mlp(cfg: ArchConfig, stack: int):
    base = mlp_spec(cfg)
    if not stack:
        return base
    return {k: spec((stack,) + v.shape, (None,) + v.axes, v.init, v.scale,
                    v.dtype) for k, v in base.items()}


def _block_apply(cfg: ArchConfig, kind: str, p: Dict, x, positions,
                 enc_out=None, *, return_cache: bool = False,
                 cache_len: int = 0,
                 window_override: Optional[int] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, Any]:
    """Full-sequence block application. Returns (x, aux_loss, cache|None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    window = cfg.window if window_override is None else window_override
    if kind in ("attn", "attn_moe"):
        h = apply_norm(cfg, p["norm1"], x)
        if cfg.attn_kind == "mla":
            out = A.mla_apply(cfg, p["attn"], h, positions,
                              return_cache=return_cache, cache_len=cache_len)
        else:
            out = A.gqa_apply(cfg, p["attn"], h, positions, window=window,
                              return_cache=return_cache, cache_len=cache_len)
        if return_cache:
            out, cache = out
        x = x + out
        h = apply_norm(cfg, p["norm2"], x)
        if kind == "attn_moe":
            out, aux = M.moe_apply(cfg, p["moe"], h)
            x = x + out
        else:
            x = x + mlp_apply(cfg, p["mlp"], h)
    elif kind == "rec":
        out = S.rglru_apply(cfg, p["rec"], apply_norm(cfg, p["norm1"], x),
                            return_cache=return_cache)
        if return_cache:
            out, cache = out
        x = x + out
        x = x + mlp_apply(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x))
    elif kind == "m":
        out = X.mlstm_apply(cfg, p["mix"], apply_norm(cfg, p["norm1"], x),
                            return_cache=return_cache)
        if return_cache:
            out, cache = out
        x = x + out
    elif kind == "s":
        out = X.slstm_apply(cfg, p["mix"], apply_norm(cfg, p["norm1"], x),
                            return_cache=return_cache)
        if return_cache:
            out, cache = out
        x = x + out
    elif kind == "xattn":
        out = A.gqa_apply(cfg, p["attn"], apply_norm(cfg, p["norm1"], x),
                          positions, window=window,
                          return_cache=return_cache, cache_len=cache_len)
        if return_cache:
            out, cache = out
        x = x + out
        x = x + A.cross_apply(cfg, p["cross"],
                              apply_norm(cfg, p["norm2"], x), enc_out)
        x = x + mlp_apply(cfg, p["mlp"], apply_norm(cfg, p["norm3"], x))
    elif kind == "enc":
        h = apply_norm(cfg, p["norm1"], x)
        b, s, _ = h.shape
        hd = cfg.hd
        q = (h @ p["attn"]["wq"]).reshape(b, s, cfg.n_heads, hd)
        k = (h @ p["attn"]["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
        v = (h @ p["attn"]["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
        k = A._repeat_kv(k, cfg.n_heads // cfg.n_kv_heads)
        v = A._repeat_kv(v, cfg.n_heads // cfg.n_kv_heads)
        o = A.sdpa(q, k, v, causal=False, window=0, force_blocked=False)
        x = x + o.reshape(b, s, cfg.n_heads * hd) @ p["attn"]["wo"]
        x = x + mlp_apply(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x))
    else:
        raise ValueError(kind)
    return x, aux, cache


def _block_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                 stack: int, window_override: Optional[int] = None):
    window = cfg.window if window_override is None else window_override
    if kind in ("attn", "attn_moe", "xattn", "enc"):
        if cfg.attn_kind == "mla":
            return A.mla_cache_spec(cfg, batch, max_len, stack)
        return A.gqa_cache_spec(cfg, batch, max_len, stack, window=window)
    if kind == "rec":
        return S.rglru_cache_spec(cfg, batch, stack)
    if kind == "m":
        return X.mlstm_cache_spec(cfg, batch, stack)
    if kind == "s":
        return X.slstm_cache_spec(cfg, batch, stack)
    raise ValueError(kind)


def _block_decode(cfg: ArchConfig, kind: str, p: Dict, x, cache, pos,
                  enc_out=None, window_override: Optional[int] = None):
    window = cfg.window if window_override is None else window_override
    if kind in ("attn", "attn_moe"):
        h = apply_norm(cfg, p["norm1"], x)
        if cfg.attn_kind == "mla":
            out, cache = A.mla_decode(cfg, p["attn"], h, cache, pos)
        else:
            out, cache = A.gqa_decode(cfg, p["attn"], h, cache, pos,
                                      window=window)
        x = x + out
        h = apply_norm(cfg, p["norm2"], x)
        if kind == "attn_moe":
            out, _ = M.moe_apply(cfg, p["moe"], h)
            x = x + out
        else:
            x = x + mlp_apply(cfg, p["mlp"], h)
    elif kind == "rec":
        out, cache = S.rglru_decode(cfg, p["rec"],
                                    apply_norm(cfg, p["norm1"], x), cache, pos)
        x = x + out
        x = x + mlp_apply(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x))
    elif kind == "m":
        out, cache = X.mlstm_decode(cfg, p["mix"],
                                    apply_norm(cfg, p["norm1"], x), cache, pos)
        x = x + out
    elif kind == "s":
        out, cache = X.slstm_decode(cfg, p["mix"],
                                    apply_norm(cfg, p["norm1"], x), cache, pos)
        x = x + out
    elif kind == "xattn":
        h = apply_norm(cfg, p["norm1"], x)
        out, cache = A.gqa_decode(cfg, p["attn"], h, cache, pos,
                                  window=window)
        x = x + out
        x = x + A.cross_apply(cfg, p["cross"],
                              apply_norm(cfg, p["norm2"], x), enc_out)
        x = x + mlp_apply(cfg, p["mlp"], apply_norm(cfg, p["norm3"], x))
    else:
        raise ValueError(kind)
    return x, cache


# ------------------------------------------------------------- model params
def abstract_params(cfg: ArchConfig):
    """Full model ParamSpec tree."""
    d, vp = cfg.d_model, cfg.padded_vocab
    tree: Dict[str, Any] = {
        "embed": spec((vp, d), ("vocab", None), scale=1.0),
        "final_norm": norm_spec(cfg),
    }
    if not cfg.tie_embeddings:
        tree["head"] = spec((d, vp), (None, "vocab"))
    if cfg.pos_emb == "learned":
        tree["pos_table"] = spec((4096, d), (None, None))
    units = {}
    for slot, kind in enumerate(cfg.pattern):
        units[f"b{slot}"] = _block_spec(cfg, kind, stack=cfg.n_units)
    tree["units"] = units
    rem = {}
    for r in range(cfg.n_rem_layers):
        kind = cfg.pattern[r % len(cfg.pattern)]
        rem[f"r{r}"] = _block_spec(cfg, kind, stack=0)
    if rem:
        tree["rem"] = rem
    if cfg.enc_dec:
        tree["encoder"] = {
            "pos_table": spec((cfg.n_frames, d), (None, None)),
            "layers": _block_spec(cfg, "enc", stack=cfg.n_enc_layers),
            "final_norm": norm_spec(cfg),
        }
    return tree


def model_abstract(cfg: ArchConfig):
    return abstract_tree(abstract_params(cfg), cfg.jdtype)


def model_init(cfg: ArchConfig, key: jax.Array):
    return init_params(abstract_params(cfg), key, cfg.jdtype)


# ------------------------------------------------------------------ encoder
def encode(cfg: ArchConfig, params: Dict, frames):
    """Whisper-style encoder over precomputed frame embeddings (stub
    frontend per the brief). frames: (B, n_frames, d_model)."""
    enc = params["encoder"]
    x = frames + enc["pos_table"][None, : frames.shape[1], :].astype(frames.dtype)

    def body(x, layer_p):
        x, _, _ = _block_apply(cfg, "enc", layer_p, x, None)
        return x, None

    x, _ = jax.lax.scan(body, x, enc["layers"])
    return apply_norm(cfg, enc["final_norm"], x)


# ------------------------------------------------------------------ forward
def forward(cfg: ArchConfig, params: Dict, tokens, *,
            enc_frames=None, patch_embeds=None, remat: bool = True,
            return_cache: bool = False, cache_len: int = 0,
            window_override: Optional[int] = None):
    """Full-sequence forward -> (logits, aux_loss[, cache]).

    tokens: (B, S) int32. For VLM early fusion, ``patch_embeds``
    (B, n_patches, d) replaces the first n_patches embedding slots.
    For enc-dec, ``enc_frames`` (B, n_frames, d) feeds the encoder.
    With ``return_cache`` the per-layer decode caches (KV / recurrent
    state) are also returned — this is the true prefill path.
    """
    b, s = tokens.shape
    x = params["embed"][tokens]                              # (B, S, d)
    if patch_embeds is not None and cfg.n_patches:
        npch = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x[:, npch:, :]],
                            axis=1)
    if cfg.pos_emb == "learned":
        tbl = params["pos_table"]
        pos_idx = jnp.arange(s) % tbl.shape[0]
        x = x + tbl[pos_idx][None].astype(x.dtype)
    positions = jnp.arange(s)
    enc_out = None
    if cfg.enc_dec:
        enc_out = encode(cfg, params, enc_frames)

    def unit_body(carry, unit_p):
        x, aux = carry
        caches = {}
        for slot, kind in enumerate(cfg.pattern):
            x, a, c = _block_apply(cfg, kind, unit_p[f"b{slot}"], x,
                                   positions, enc_out,
                                   return_cache=return_cache,
                                   cache_len=cache_len,
                                   window_override=window_override)
            aux = aux + a
            if return_cache:
                caches[f"b{slot}"] = c
        return (x, aux), (caches if return_cache else None)

    body = jax.checkpoint(unit_body) if (remat and not return_cache) \
        else unit_body
    (x, aux), unit_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params["units"])
    cache = {"units": unit_caches} if return_cache else None
    if cfg.n_rem_layers:
        if return_cache:
            cache["rem"] = {}
        for r in range(cfg.n_rem_layers):
            kind = cfg.pattern[r % len(cfg.pattern)]
            x, a, c = _block_apply(cfg, kind, params["rem"][f"r{r}"], x,
                                   positions, enc_out,
                                   return_cache=return_cache,
                                   cache_len=cache_len,
                                   window_override=window_override)
            aux = aux + a
            if return_cache:
                cache["rem"][f"r{r}"] = c
    x = apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["head"]
    if return_cache:
        return logits, aux, cache
    return logits, aux


# -------------------------------------------------------------------- cache
def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               window_override: Optional[int] = None):
    """ShapeDtypeStruct cache tree (materialize with jnp.zeros for real)."""
    tree: Dict[str, Any] = {"units": {}}
    for slot, kind in enumerate(cfg.pattern):
        tree["units"][f"b{slot}"] = _block_cache(
            cfg, kind, batch, max_len, cfg.n_units, window_override)
    rem = {}
    for r in range(cfg.n_rem_layers):
        kind = cfg.pattern[r % len(cfg.pattern)]
        rem[f"r{r}"] = _block_cache(cfg, kind, batch, max_len, 0,
                                    window_override)
    if rem:
        tree["rem"] = rem
    return tree


def materialize_cache(cfg: ArchConfig, batch: int, max_len: int,
                      window_override: Optional[int] = None):
    return jax.tree_util.tree_map(lambda sds: jnp.zeros(sds.shape, sds.dtype),
                                  init_cache(cfg, batch, max_len,
                                             window_override))


# --------------------------------------------------------------- decode step
def decode_step(cfg: ArchConfig, params: Dict, cache, tokens, pos, *,
                enc_out=None, window_override: Optional[int] = None):
    """One-token decode. tokens: (B, 1) int32, pos: scalar position.

    Returns (logits (B, 1, V), new_cache).
    """
    x = params["embed"][tokens]
    if cfg.pos_emb == "learned":
        tbl = params["pos_table"]
        x = x + tbl[pos % tbl.shape[0]][None, None].astype(x.dtype)

    def unit_body(carry, scanned):
        x = carry
        unit_p, unit_c = scanned
        new_c = {}
        for slot, kind in enumerate(cfg.pattern):
            x, c = _block_decode(cfg, kind, unit_p[f"b{slot}"], x,
                                 unit_c[f"b{slot}"], pos, enc_out,
                                 window_override)
            new_c[f"b{slot}"] = c
        return x, new_c

    x, new_unit_caches = jax.lax.scan(unit_body, x,
                                      (params["units"], cache["units"]))
    new_cache = {"units": new_unit_caches}
    if cfg.n_rem_layers:
        new_cache["rem"] = {}
        for r in range(cfg.n_rem_layers):
            kind = cfg.pattern[r % len(cfg.pattern)]
            x, c = _block_decode(cfg, kind, params["rem"][f"r{r}"], x,
                                 cache["rem"][f"r{r}"], pos, enc_out,
                                 window_override)
            new_cache["rem"][f"r{r}"] = c
    x = apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["head"]
    return logits, new_cache
