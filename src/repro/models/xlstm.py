"""xLSTM blocks (arXiv:2405.04517): chunkwise-parallel mLSTM (matrix memory,
exponential gating) and sequential sLSTM (scalar memory, recurrent h).

The mLSTM chunkwise formulation carries (C, n, m) across chunks of length
``MLSTM_CHUNK`` — intra-chunk work is parallel (MXU-friendly), inter-chunk
is a short scan. This is the TPU-native adaptation: quadratic-but-tiled
within chunks, linear across them, so train_4k fits memory and long_500k
decode is O(1) per token from the (C, n, m) state.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import ArchConfig, rms_norm, spec

MLSTM_CHUNK = 256


# ------------------------------------------------------------------ mLSTM
def mlstm_spec(cfg: ArchConfig, stack: int = 0):
    d = cfg.d_model
    du = int(cfg.proj_factor * d)
    nh = cfg.mlstm_heads or cfg.n_heads
    st = (stack,) if stack else ()
    sa = (None,) if stack else ()
    return {
        "w_up": spec(st + (d, 2 * du), sa + (None, "model")),
        "conv_k": spec(st + (cfg.conv_width, du), sa + (None, "model"),
                       scale=0.5),
        "w_q": spec(st + (du, du), sa + (None, "model")),
        "w_k": spec(st + (du, du), sa + (None, "model")),
        "w_v": spec(st + (du, du), sa + (None, "model")),
        "w_if": spec(st + (du, 2 * nh), sa + (None, None), scale=0.3,
                     dtype=jnp.float32),
        "skip": spec(st + (du,), sa + (None,), init="ones",
                     dtype=jnp.float32),
        "out_norm": spec(st + (du,), sa + (None,), init="ones",
                         dtype=jnp.float32),
        "w_down": spec(st + (du, d), sa + ("model", None)),
    }


def _mlstm_chunk_scan(q, k, v, li, lf):
    """Chunkwise stabilized mLSTM.

    q,k,v: (B, H, S, D); li, lf: (B, H, S) log input/forget gates.
    Returns h: (B, H, S, D).
    """
    b, h, s, d = q.shape
    L = min(MLSTM_CHUNK, s)
    assert s % L == 0, (s, L)
    nc = s // L
    scale = 1.0 / np.sqrt(d)

    qc = q.reshape(b, h, nc, L, d) * scale
    kc = k.reshape(b, h, nc, L, d)
    vc = v.reshape(b, h, nc, L, d)
    lic = li.reshape(b, h, nc, L)
    lfc = lf.reshape(b, h, nc, L)
    bc = jnp.cumsum(lfc, axis=-1)                       # inclusive decay sums

    def step(carry, inp):
        C, n, m = carry         # (B,H,D,D), (B,H,D), (B,H)
        qi, ki, vi, lii, bi = inp
        # bi: inclusive cumsum of lf within chunk; decay from chunk start
        # to position j (inclusive of f_j).
        m_inter = bi + m[..., None]                      # (B,H,L)
        # intra-chunk log weights D_jk = b_j - b_k + li_k (k <= j)
        Djk = bi[..., :, None] - bi[..., None, :] + lii[..., None, :]
        mask = jnp.tril(jnp.ones((L, L), bool))
        Djk = jnp.where(mask, Djk, -jnp.inf)
        m_intra = jnp.max(Djk, axis=-1)                  # (B,H,L)
        m_j = jnp.maximum(m_inter, m_intra)              # (B,H,L)
        # intra scores
        Sjk = jnp.einsum("bhjd,bhkd->bhjk", qi, ki) * jnp.exp(
            Djk - m_j[..., None])
        num = jnp.einsum("bhjk,bhkd->bhjd", Sjk, vi)
        den = jnp.sum(Sjk, axis=-1)                      # k-normalizer part 1
        # inter contribution
        w_int = jnp.exp(m_inter - m_j)                   # (B,H,L)
        num = num + w_int[..., None] * jnp.einsum("bhjd,bhde->bhje", qi, C)
        den = den + w_int * jnp.einsum("bhjd,bhd->bhj", qi, n)
        hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_j))[..., None]
        # state update to end of chunk
        btot = bi[..., -1]                               # (B,H)
        m_new = jnp.maximum(btot + m, jnp.max(
            btot[..., None] - bi + lii, axis=-1))
        wk = jnp.exp(btot[..., None] - bi + lii - m_new[..., None])  # (B,H,L)
        C_new = jnp.exp(btot + m - m_new)[..., None, None] * C + \
            jnp.einsum("bhk,bhkd,bhke->bhde", wk, ki, vi)
        n_new = jnp.exp(btot + m - m_new)[..., None] * n + \
            jnp.einsum("bhk,bhkd->bhd", wk, ki)
        return (C_new, n_new, m_new), hout

    C0 = jnp.zeros((b, h, d, d), jnp.float32)
    n0 = jnp.zeros((b, h, d), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    inputs = (qc.transpose(2, 0, 1, 3, 4).astype(jnp.float32),
              kc.transpose(2, 0, 1, 3, 4).astype(jnp.float32),
              vc.transpose(2, 0, 1, 3, 4).astype(jnp.float32),
              lic.transpose(2, 0, 1, 3),
              bc.transpose(2, 0, 1, 3))
    carry, hs = jax.lax.scan(step, (C0, n0, m0), inputs)
    return hs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, d), carry


def mlstm_apply(cfg: ArchConfig, p: Dict, x, positions=None, *,
                return_cache: bool = False):
    """Full-sequence mLSTM block. x: (B, S, d_model)."""
    from .ssm import _causal_depthwise_conv
    b, s, d = x.shape
    du = int(cfg.proj_factor * d)
    nh = cfg.mlstm_heads or cfg.n_heads
    hd = du // nh
    up = x @ p["w_up"]
    xm, z = up[..., :du], up[..., du:]
    xc = jax.nn.silu(_causal_depthwise_conv(xm, p["conv_k"]))
    q = (xc @ p["w_q"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    k = (xc @ p["w_k"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    v = (xm @ p["w_v"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    gif = xc.astype(jnp.float32) @ p["w_if"]                 # (B,S,2*nh)
    li = gif[..., :nh].transpose(0, 2, 1)                    # log input gate
    lf = jax.nn.log_sigmoid(gif[..., nh:]).transpose(0, 2, 1)
    h, (C, n, m) = _mlstm_chunk_scan(q, k, v, li, lf)        # (B,H,S,hd)
    h = h.transpose(0, 2, 1, 3).reshape(b, s, du).astype(x.dtype)
    h = rms_norm(h, p["out_norm"]) + xc * p["skip"].astype(x.dtype)
    h = h * jax.nn.silu(z)
    out = h @ p["w_down"]
    if return_cache:
        w = cfg.conv_width
        hist = xm[:, -(w - 1):, :]
        pad = (w - 1) - hist.shape[1]
        if pad > 0:
            hist = jnp.pad(hist, ((0, 0), (pad, 0), (0, 0)))
        return out, {"C": C, "n": n, "m": m,
                     "conv": hist.astype(cfg.jdtype)}
    return out


def mlstm_cache_spec(cfg: ArchConfig, batch: int, stack: int = 0):
    du = int(cfg.proj_factor * cfg.d_model)
    nh = cfg.mlstm_heads or cfg.n_heads
    hd = du // nh
    st = (stack,) if stack else ()
    return {
        "C": jax.ShapeDtypeStruct(st + (batch, nh, hd, hd), jnp.float32),
        "n": jax.ShapeDtypeStruct(st + (batch, nh, hd), jnp.float32),
        "m": jax.ShapeDtypeStruct(st + (batch, nh), jnp.float32),
        "conv": jax.ShapeDtypeStruct(st + (batch, cfg.conv_width - 1, du),
                                     cfg.jdtype),
    }


def mlstm_decode(cfg: ArchConfig, p: Dict, x, cache: Dict, pos):
    """One-step mLSTM from (C, n, m) state. x: (B, 1, d)."""
    b = x.shape[0]
    du = int(cfg.proj_factor * cfg.d_model)
    nh = cfg.mlstm_heads or cfg.n_heads
    hd = du // nh
    up = x @ p["w_up"]
    xm, z = up[..., :du], up[..., du:]
    hist = jnp.concatenate([cache["conv"],
                            xm.astype(cache["conv"].dtype)], axis=1)
    w = p["conv_k"].shape[0]
    xc = jnp.einsum("bwc,wc->bc", hist[:, -w:, :].astype(x.dtype),
                    p["conv_k"])
    xc = jax.nn.silu(xc)
    q = (xc @ p["w_q"]).reshape(b, nh, hd).astype(jnp.float32)
    k = (xc @ p["w_k"]).reshape(b, nh, hd).astype(jnp.float32)
    v = (xm[:, 0] @ p["w_v"]).reshape(b, nh, hd).astype(jnp.float32)
    gif = xc.astype(jnp.float32) @ p["w_if"]
    li, lf_raw = gif[..., :nh], gif[..., nh:]
    lf = jax.nn.log_sigmoid(lf_raw)
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(lf + m, li)
    fp = jnp.exp(lf + m - m_new)
    ip = jnp.exp(li - m_new)
    C_new = fp[..., None, None] * C + ip[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k, v)
    n_new = fp[..., None] * n + ip[..., None] * k
    scale = 1.0 / np.sqrt(hd)
    num = jnp.einsum("bhd,bhde->bhe", q * scale, C_new)
    den = jnp.einsum("bhd,bhd->bh", q * scale, n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    h = h.reshape(b, du).astype(x.dtype)
    h = rms_norm(h, p["out_norm"]) + xc * p["skip"].astype(x.dtype)
    h = (h * jax.nn.silu(z[:, 0]))[:, None, :]
    return h @ p["w_down"], {"C": C_new, "n": n_new, "m": m_new,
                             "conv": hist[:, 1:, :]}


# ------------------------------------------------------------------ sLSTM
def slstm_spec(cfg: ArchConfig, stack: int = 0):
    d = cfg.d_model
    st = (stack,) if stack else ()
    sa = (None,) if stack else ()
    dff = int(d * 4 / 3)
    return {
        "w_gates": spec(st + (d, 4 * d), sa + (None, "model")),
        "r_gates": spec(st + (d, 4 * d), sa + (None, "model"), scale=0.5),
        "out_norm": spec(st + (d,), sa + (None,), init="ones",
                         dtype=jnp.float32),
        "ff_gate": spec(st + (d, dff), sa + (None, "model")),
        "ff_up": spec(st + (d, dff), sa + (None, "model")),
        "ff_out": spec(st + (dff, d), sa + ("model", None)),
    }


def _slstm_cell(p, zx_t, state):
    """zx_t: (B, 4d) PRE-PROJECTED input gates (x_t @ w_gates — hoisted out
    of the sequential scan since it is time-parallel; EXPERIMENTS.md
    hillclimb D). state: (c, n, m, h)."""
    c, n, m, h = state
    z4 = zx_t + h.astype(zx_t.dtype) @ p["r_gates"]
    zi, zf, zz, zo = jnp.split(z4.astype(jnp.float32), 4, axis=-1)
    li = zi
    lf = jax.nn.log_sigmoid(zf)
    m_new = jnp.maximum(lf + m, li)
    ip = jnp.exp(li - m_new)
    fp = jnp.exp(lf + m - m_new)
    c_new = fp * c + ip * jnp.tanh(zz)
    n_new = fp * n + ip
    h_new = jax.nn.sigmoid(zo) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new)


def slstm_apply(cfg: ArchConfig, p: Dict, x, positions=None, *,
                return_cache: bool = False):
    """Sequential sLSTM block + GeGLU FFN. x: (B, S, d)."""
    b, s, d = x.shape
    z0 = jnp.zeros((b, d), jnp.float32)
    m0 = jnp.full((b, d), -1e30, jnp.float32)
    zx = x @ p["w_gates"]                    # (B, S, 4d), one big matmul

    def step(state, zx_t):
        new = _slstm_cell(p, zx_t, state)
        return new, new[3]

    carry, hs = jax.lax.scan(step, (z0, z0, m0, z0), zx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    h = rms_norm(h, p["out_norm"])
    ff = (jax.nn.gelu(h @ p["ff_gate"]) * (h @ p["ff_up"])) @ p["ff_out"]
    out = h + ff
    if return_cache:
        return out, {"c": carry[0], "n": carry[1], "m": carry[2],
                     "h": carry[3]}
    return out


def slstm_cache_spec(cfg: ArchConfig, batch: int, stack: int = 0):
    d = cfg.d_model
    st = (stack,) if stack else ()
    sds = lambda: jax.ShapeDtypeStruct(st + (batch, d), jnp.float32)
    return {"c": sds(), "n": sds(), "m": sds(), "h": sds()}


def slstm_decode(cfg: ArchConfig, p: Dict, x, cache: Dict, pos):
    state = (cache["c"], cache["n"], cache["m"], cache["h"])
    new = _slstm_cell(p, x[:, 0, :] @ p["w_gates"], state)
    h = new[3][:, None, :].astype(x.dtype)
    h = rms_norm(h, p["out_norm"])
    ff = (jax.nn.gelu(h @ p["ff_gate"]) * (h @ p["ff_up"])) @ p["ff_out"]
    out = h + ff
    return out, {"c": new[0], "n": new[1], "m": new[2], "h": new[3]}
