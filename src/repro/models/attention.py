"""Attention blocks: GQA (full / sliding-window / blocked-flash), MLA
(latent KV compression, minicpm3-style), cross-attention (whisper), and the
decode paths with KV caches.

The full-sequence path switches to a blocked flash-style scan over KV chunks
(online softmax, O(block) memory) once seq_len exceeds ``BLOCK_THRESHOLD`` —
this is what makes prefill_32k lowerable without materializing (S, S) scores.
On TPU the Pallas kernel in ``repro.kernels.swa`` replaces the blocked path;
the pure-JAX version here is the oracle and the CPU/dry-run lowering path.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import ArchConfig, apply_rope, rms_norm, spec

BLOCK_THRESHOLD = 8192
KV_BLOCK = 1024
NEG_INF = -2.0e38


# ------------------------------------------------------------------- specs
def gqa_spec(cfg: ArchConfig, stack: int = 0):
    hd = cfg.hd
    st = (stack,) if stack else ()
    sa = (None,) if stack else ()
    p = {
        "wq": spec(st + (cfg.d_model, cfg.n_heads * hd), sa + (None, "model")),
        "wk": spec(st + (cfg.d_model, cfg.n_kv_heads * hd), sa + (None, "model")),
        "wv": spec(st + (cfg.d_model, cfg.n_kv_heads * hd), sa + (None, "model")),
        "wo": spec(st + (cfg.n_heads * hd, cfg.d_model), sa + ("model", None)),
    }
    if cfg.qk_norm:
        p["q_norm"] = spec(st + (hd,), sa + (None,), init="ones",
                           dtype=jnp.float32)
        p["k_norm"] = spec(st + (hd,), sa + (None,), init="ones",
                           dtype=jnp.float32)
    return p


def mla_spec(cfg: ArchConfig, stack: int = 0):
    st = (stack,) if stack else ()
    sa = (None,) if stack else ()
    qk_hd = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq_a": spec(st + (cfg.d_model, cfg.q_lora_rank), sa + (None, None)),
        "q_a_norm": spec(st + (cfg.q_lora_rank,), sa + (None,), init="ones",
                         dtype=jnp.float32),
        "wq_b": spec(st + (cfg.q_lora_rank, cfg.n_heads * qk_hd),
                     sa + (None, "model")),
        "wkv_a": spec(st + (cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_dim),
                      sa + (None, None)),
        "kv_a_norm": spec(st + (cfg.kv_lora_rank,), sa + (None,), init="ones",
                          dtype=jnp.float32),
        "wkv_b": spec(st + (cfg.kv_lora_rank,
                            cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)),
                      sa + (None, "model")),
        "wo": spec(st + (cfg.n_heads * cfg.v_head_dim, cfg.d_model),
                   sa + ("model", None)),
    }


def cross_spec(cfg: ArchConfig, stack: int = 0):
    return gqa_spec(cfg, stack)


# ---------------------------------------------------------------- core math
def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, kh, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, n_rep, d)
                            ).reshape(b, s, kh * n_rep, d)


def _plain_attention(q, k, v, *, causal: bool, window: int,
                     q_offset: int = 0):
    """Materialized-score attention. q (B,Sq,H,D), k/v (B,Sk,H,D)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _blocked_attention(q, k, v, *, causal: bool, window: int):
    """Flash-style online-softmax scan over KV blocks; O(KV_BLOCK) memory.

    Differentiable (lax.scan) and exactly equal to _plain_attention.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    nb = (sk + KV_BLOCK - 1) // KV_BLOCK
    pad = nb * KV_BLOCK - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nb, KV_BLOCK, h, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, KV_BLOCK, h, d).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / np.sqrt(d)
    qpos = jnp.arange(sq)

    def step(carry, inp):
        m, l, acc = carry                     # (B,H,Sq), (B,H,Sq), (B,Sq,H,D)
        kblk, vblk, blk_idx = inp
        kpos = blk_idx * KV_BLOCK + jnp.arange(KV_BLOCK)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kblk).astype(jnp.float32) * scale
        mask = (kpos[None, :] < sk)
        mask = jnp.broadcast_to(mask, (sq, KV_BLOCK))
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
            "bhqk,bkhd->bqhd", p.astype(q.dtype), vblk).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, sq, h, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def sdpa(q, k, v, *, causal: bool = True, window: int = 0,
         force_blocked: Optional[bool] = None):
    """Dispatch: Pallas SWA kernel on TPU for windowed attention, blocked
    flash-style scan for long sequences, materialized scores otherwise."""
    if causal and jax.default_backend() == "tpu":
        from repro.kernels.swa.ops import swa_op
        return swa_op(q, k, v, window=window, use_pallas=True)
    blocked = (q.shape[1] > BLOCK_THRESHOLD if force_blocked is None
               else force_blocked)
    if blocked:
        return _blocked_attention(q, k, v, causal=causal, window=window)
    return _plain_attention(q, k, v, causal=causal, window=window)


# --------------------------------------------------------------- GQA block
def _cache_from_seq(k, v, cache_len: int, window: int, kh: int):
    """Arrange full-sequence K/V (B, S, kv, hd) into the decode cache layout.

    Full attention: first S slots of a (B, cache_len) buffer. Sliding window:
    ring buffer of size min(window, cache_len) with slot = pos % eff_len.
    """
    b, s, _, hd = k.shape
    k = _repeat_kv(k, kh // k.shape[2])
    v = _repeat_kv(v, kh // v.shape[2])
    eff = min(window, cache_len) if window else cache_len
    if window and s >= eff:
        shift = (s - eff) % eff
        k_c = jnp.roll(k[:, s - eff:], shift, axis=1)
        v_c = jnp.roll(v[:, s - eff:], shift, axis=1)
    else:
        pad = eff - s
        k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return {"k": k_c, "v": v_c}


def gqa_apply(cfg: ArchConfig, p: Dict, x, positions, *,
              window: Optional[int] = None, return_cache: bool = False,
              cache_len: int = 0):
    """Full-sequence GQA attention (train/prefill). x: (B, S, d_model)."""
    b, s, _ = x.shape
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    w = cfg.window if window is None else window
    cache = None
    if return_cache:
        cache = _cache_from_seq(k, v, cache_len or s, w, _cache_heads(cfg))
    k = _repeat_kv(k, cfg.n_heads // cfg.n_kv_heads)
    v = _repeat_kv(v, cfg.n_heads // cfg.n_kv_heads)
    out = sdpa(q, k, v, causal=True, window=w)
    out = out.reshape(b, s, cfg.n_heads * hd) @ p["wo"]
    return (out, cache) if return_cache else out


def gqa_cache_spec(cfg: ArchConfig, batch: int, max_len: int,
                   stack: int = 0, window: int = 0):
    """KV cache ShapeDtypeStructs. KV heads are expanded to >= 16 replicas
    (Megatron-style KV replication) so the cache shards over the model axis.
    """
    eff_len = min(max_len, window) if window else max_len
    kh = _cache_heads(cfg)
    st = (stack,) if stack else ()
    shape = st + (batch, eff_len, kh, cfg.hd)
    return {"k": jax.ShapeDtypeStruct(shape, cfg.jdtype),
            "v": jax.ShapeDtypeStruct(shape, cfg.jdtype)}


def _cache_heads(cfg: ArchConfig) -> int:
    """KV-cache head count: the smallest multiple of n_kv_heads that BOTH
    divides n_heads (so Q-head grouping stays integral) and is divisible by
    16 (so the cache shards over the model axis) — Megatron-style KV
    replication. If no such multiple exists (llama3's 24H/8kv, whisper's 6H)
    the cache keeps n_kv_heads and the sharding layer falls back to a
    sequence-sharded cache."""
    kh = cfg.n_kv_heads
    k = kh
    while k <= cfg.n_heads:
        if cfg.n_heads % k == 0 and k % 16 == 0:
            return k
        k += kh
    return kh


def gqa_decode(cfg: ArchConfig, p: Dict, x, cache: Dict, pos, *,
               window: int = 0):
    """One-token decode with KV cache. x: (B, 1, d). pos: scalar int."""
    b = x.shape[0]
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(b, 1, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(b, 1, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, 1, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.pos_emb == "rope":
        pp = jnp.full((1,), pos)
        q = apply_rope(q, pp, cfg.rope_theta)
        k = apply_rope(k, pp, cfg.rope_theta)
    kh = _cache_heads(cfg)
    k = _repeat_kv(k, kh // cfg.n_kv_heads)
    v = _repeat_kv(v, kh // cfg.n_kv_heads)
    eff_len = cache["k"].shape[1]
    slot = pos % eff_len if window else pos
    new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, 1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, 1)
    # attend to valid positions only
    kpos = jnp.arange(eff_len)
    if window:
        valid = (kpos <= slot) | (pos >= eff_len)   # ring buffer full => all
    else:
        valid = kpos <= pos
    scale = 1.0 / np.sqrt(hd)
    # Distributed decode attention over a sequence-sharded cache, grouped-
    # query form (KV heads are never materially repeated — iteration 3 cut
    # the 3x cache-read amplification). The score layout pin keeps L
    # sharded: without it the partitioner all-gathers the entire KV cache
    # per step (56 GiB on llama3 decode_32k, EXPERIMENTS.md hillclimb B).
    # Softmax reductions over the sharded L and the probs@V contraction
    # lower as small all-reduces instead.
    from repro.distributed.context import constrain
    qg = q.reshape(b, 1, kh, cfg.n_heads // kh, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, new_k).astype(jnp.float32)
    s = s * scale
    s = constrain(s, "data", None, None, None, "model")
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    probs = constrain(probs, "data", None, None, None, "model")
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, new_v)
    out = out.reshape(b, 1, cfg.n_heads * hd) @ p["wo"]
    return out, {"k": new_k, "v": new_v}


# --------------------------------------------------------------- MLA block
def mla_apply(cfg: ArchConfig, p: Dict, x, positions, *,
              return_cache: bool = False, cache_len: int = 0):
    """Multi-head Latent Attention, full-sequence path. x: (B, S, d)."""
    b, s, _ = x.shape
    nh, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = rms_norm(x @ p["wq_a"], p["q_a_norm"]) @ p["wq_b"]
    q = q.reshape(b, s, nh, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv_a = x @ p["wkv_a"]                                  # (B,S,rank+dr)
    c_kv = rms_norm(kv_a[..., :cfg.kv_lora_rank], p["kv_a_norm"])
    k_rope = kv_a[..., cfg.kv_lora_rank:][:, :, None, :]   # shared across heads
    kv = (c_kv @ p["wkv_b"]).reshape(b, s, nh, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    cache = None
    if return_cache:
        entry = jnp.concatenate(
            [kv_a[..., : cfg.kv_lora_rank], k_rope[:, :, 0, :]], axis=-1)
        cl = cache_len or s
        entry = jnp.pad(entry, ((0, 0), (0, cl - s), (0, 0)))
        cache = {"ckv": entry}
    k_rope = jnp.broadcast_to(k_rope, (b, s, nh, dr))
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    k_full = jnp.concatenate([k_nope, k_rope], -1)
    if dv < dn + dr:  # pad V so sdpa shapes match, then slice back
        v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
    else:
        v_p = v
    out = sdpa(q_full, k_full, v_p, causal=True, window=cfg.window)
    out = out[..., :dv].reshape(b, s, nh * dv)
    out = out @ p["wo"]
    return (out, cache) if return_cache else out


def mla_cache_spec(cfg: ArchConfig, batch: int, max_len: int, stack: int = 0):
    """MLA caches the COMPRESSED latent (kv_lora_rank + rope dims) — the
    memory win that motivates MLA."""
    st = (stack,) if stack else ()
    shape = st + (batch, max_len, cfg.kv_lora_rank + cfg.qk_rope_dim)
    return {"ckv": jax.ShapeDtypeStruct(shape, cfg.jdtype)}


def mla_decode(cfg: ArchConfig, p: Dict, x, cache: Dict, pos):
    """One-token MLA decode from the compressed cache."""
    b = x.shape[0]
    nh, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rank = cfg.kv_lora_rank
    q = rms_norm(x @ p["wq_a"], p["q_a_norm"]) @ p["wq_b"]
    q = q.reshape(b, 1, nh, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv_a = x @ p["wkv_a"]                                   # (B,1,rank+dr)
    c_new = kv_a[..., :rank]
    kr_new = kv_a[..., rank:]
    pp = jnp.full((1,), pos)
    q_rope = apply_rope(q_rope, pp, cfg.rope_theta)
    kr_new = apply_rope(kr_new[:, :, None, :], pp, cfg.rope_theta)[:, :, 0, :]
    entry = jnp.concatenate([c_new, kr_new], -1)
    new_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], entry.astype(cache["ckv"].dtype), pos, 1)
    c_all = rms_norm(new_cache[..., :rank], p["kv_a_norm"])  # (B,T,rank)
    kr_all = new_cache[..., rank:]                           # (B,T,dr)
    kv = (c_all @ p["wkv_b"]).reshape(b, -1, nh, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    t = new_cache.shape[1]
    valid = jnp.arange(t) <= pos
    s = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope) +
         jnp.einsum("bqhd,bkd->bhqk", q_rope, kr_all)).astype(jnp.float32)
    s = s / np.sqrt(dn + dr)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    probs = jax.nn.softmax(s, -1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, 1, nh * dv)
    return out @ p["wo"], {"ckv": new_cache}


# ------------------------------------------------------- cross attn (enc-dec)
def cross_apply(cfg: ArchConfig, p: Dict, x, enc_out):
    """Cross-attention: queries from decoder x, keys/values from enc_out."""
    b, s, _ = x.shape
    se = enc_out.shape[1]
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (enc_out @ p["wk"]).reshape(b, se, cfg.n_kv_heads, hd)
    v = (enc_out @ p["wv"]).reshape(b, se, cfg.n_kv_heads, hd)
    k = _repeat_kv(k, cfg.n_heads // cfg.n_kv_heads)
    v = _repeat_kv(v, cfg.n_heads // cfg.n_kv_heads)
    out = sdpa(q, k, v, causal=False, window=0, force_blocked=False)
    return out.reshape(b, s, cfg.n_heads * hd) @ p["wo"]
