"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_a y_t)          recurrence gate
    i_t = sigmoid(W_i y_t)          input gate
    a_t = exp(c * r_t * log_a)      per-channel decay, log_a = -softplus(L)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * y_t)

Full-sequence path uses ``jax.lax.associative_scan`` (parallel prefix — the
TPU-native adaptation of the paper-agnostic recurrence); decode is a single
fused step. A causal depthwise conv (width 4) precedes the RG-LRU as in
Griffin's recurrent block.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, spec

RGLRU_C = 8.0


def rglru_spec(cfg: ArchConfig, stack: int = 0):
    d, dr = cfg.d_model, cfg.rglru_width or cfg.d_model
    st = (stack,) if stack else ()
    sa = (None,) if stack else ()
    return {
        "w_x": spec(st + (d, dr), sa + (None, "model")),
        "w_gate": spec(st + (d, dr), sa + (None, "model")),
        "conv_k": spec(st + (cfg.conv_width, dr), sa + (None, "model"),
                       scale=0.5),
        "w_a": spec(st + (dr, dr), sa + ("model", None), scale=0.5),
        "w_i": spec(st + (dr, dr), sa + ("model", None), scale=0.5),
        "lamb": spec(st + (dr,), sa + (None,), init="ones",
                     dtype=jnp.float32),
        "w_out": spec(st + (dr, d), sa + ("model", None)),
    }


def _causal_depthwise_conv(y, kernel):
    """y: (B, S, C); kernel: (W, C). Causal depthwise conv."""
    w = kernel.shape[0]
    ypad = jnp.pad(y, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(y)
    for t in range(w):
        out = out + ypad[:, t: t + y.shape[1], :] * kernel[t]
    return out


def _rglru_gates(p: Dict, y):
    r = jax.nn.sigmoid(y @ p["w_a"])
    i = jax.nn.sigmoid(y @ p["w_i"])
    log_a = -jax.nn.softplus(p["lamb"]) * RGLRU_C * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = (i * y).astype(jnp.float32) * jnp.sqrt(
        jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    return a, gated


def rglru_apply(cfg: ArchConfig, p: Dict, x, positions=None, *,
                return_cache: bool = False):
    """Full-sequence RG-LRU block. x: (B, S, d_model)."""
    gate = jax.nn.gelu(x @ p["w_gate"])
    y_raw = x @ p["w_x"]
    y = _causal_depthwise_conv(y_raw, p["conv_k"])
    a, b = _rglru_gates(p, y)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = (h.astype(x.dtype) * gate) @ p["w_out"]
    if return_cache:
        w = cfg.conv_width
        hist = y_raw[:, -(w - 1):, :]
        pad = (w - 1) - hist.shape[1]
        if pad > 0:
            hist = jnp.pad(hist, ((0, 0), (pad, 0), (0, 0)))
        cache = {"h": h[:, -1, :], "conv": hist.astype(cfg.jdtype)}
        return out, cache
    return out


def rglru_cache_spec(cfg: ArchConfig, batch: int, stack: int = 0):
    dr = cfg.rglru_width or cfg.d_model
    st = (stack,) if stack else ()
    return {
        "h": jax.ShapeDtypeStruct(st + (batch, dr), jnp.float32),
        "conv": jax.ShapeDtypeStruct(st + (batch, cfg.conv_width - 1, dr),
                                     cfg.jdtype),
    }


def rglru_decode(cfg: ArchConfig, p: Dict, x, cache: Dict, pos):
    """One-step RG-LRU. x: (B, 1, d)."""
    gate = jax.nn.gelu(x @ p["w_gate"])                     # (B,1,dr)
    y = (x @ p["w_x"])[:, 0, :]                             # (B, dr)
    hist = jnp.concatenate([cache["conv"],
                            y[:, None, :].astype(cache["conv"].dtype)], axis=1)
    w = p["conv_k"].shape[0]
    yc = jnp.einsum("bwc,wc->bc", hist[:, -w:, :].astype(y.dtype), p["conv_k"])
    a, b = _rglru_gates(p, yc[:, None, :])
    h_new = a[:, 0] * cache["h"] + b[:, 0]
    out = (h_new[:, None, :].astype(x.dtype) * gate) @ p["w_out"]
    return out, {"h": h_new, "conv": hist[:, 1:, :]}
