"""Mixture-of-Experts FFN: top-k routing with capacity-based dispatch
(GShard/Switch-style), shared always-on experts (qwen2-moe), and the router
load-balance auxiliary loss.

Expert weights are stacked (E, d, d_ff) and logically sharded over the
"expert" axis -> model mesh axis (expert parallelism). The einsum dispatch
pattern lowers to the all-to-all-like collectives the paper's consensus
analysis cares about (heteroskedastic per-expert sample sizes).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, act_fn, spec


# Pin MoE intermediates to explicit (group->data, expert->model) shardings.
# Best OFF for the CPU-backend dry-run (XLA reshards via all-gather there);
# turn ON for real TPU slices where the reshard lowers as all-to-all.
PIN_EXPERT_SHARDING = False


def padded_experts(e: int) -> int:
    """Experts padded to a multiple of 16 so the expert dim shards over the
    model axis (true expert parallelism). qwen's 60 -> 64; llama4's 16 -> 16.
    Padding experts receive -inf router logits and are never selected."""
    return ((e + 15) // 16) * 16


def moe_spec(cfg: ArchConfig, stack: int = 0):
    d, de = cfg.d_model, cfg.d_expert or cfg.d_ff
    e = padded_experts(cfg.n_experts)
    st = (stack,) if stack else ()
    sa = (None,) if stack else ()
    p = {
        "router": spec(st + (d, cfg.n_experts), sa + (None, None), scale=0.1,
                       dtype=jnp.float32),
        # expert dim is padded-to-16 so it always shards over the model axis
        "w_gate": spec(st + (e, d, de), sa + ("expert", None, "model")),
        "w_up": spec(st + (e, d, de), sa + ("expert", None, "model")),
        "w_out": spec(st + (e, de, d), sa + ("expert", "model", None)),
    }
    if cfg.n_shared_experts:
        ds = de * cfg.n_shared_experts
        p["shared_gate"] = spec(st + (d, ds), sa + (None, "model"))
        p["shared_up"] = spec(st + (d, ds), sa + (None, "model"))
        p["shared_out"] = spec(st + (ds, d), sa + ("model", None))
    return p


def moe_apply(cfg: ArchConfig, p: Dict, x,
              n_groups: int = 16) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d). Returns (output, aux_load_balance_loss).

    Group-blocked dispatch: tokens are split into ``n_groups`` blocks
    aligned with the data-parallel shards, each block scatters into its own
    slice of the (G, E_pad, Cg, d) buffer. With the buffer sharded
    (G -> data, E_pad -> model) the dispatch scatter and both expert
    matmuls stay DEVICE-LOCAL; only the k-way combine sum crosses the
    model axis. This replaced a global scatter the SPMD partitioner
    lowered as replicate + 5.4 GB all-reduce per layer per microbatch
    (see EXPERIMENTS.md section Perf, hillclimb A).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_tok
    ep = padded_experts(e)
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(0)                                       # (E,)
    one_hot_top1 = jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32)
    ce = one_hot_top1.mean(0)
    aux = e * jnp.sum(me * ce)

    g = n_groups if t % n_groups == 0 and t >= n_groups else 1
    tg = t // g
    # Dropless for small token counts (decode / tiny smoke batches): with
    # capacity = Tg no token can overflow, so routing is exact.
    if t <= 4096:
        cap = tg
    else:
        cap = int(max(1, cfg.capacity_factor * k * tg / e))

    idx_g = gate_idx.reshape(g, tg, k)
    gv_g = gate_vals.reshape(g, tg, k)
    x_g = xt.reshape(g, tg, d)

    # position of each (token, slot) within its expert's per-group buffer
    flat_idx = idx_g.reshape(g, tg * k)                      # (G, Tg*k)
    onehot = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)    # (G, Tg*k, E)
    pos_in_exp = jnp.cumsum(onehot, axis=1) - onehot         # exclusive
    pos = (pos_in_exp * onehot).sum(-1)                      # (G, Tg*k)
    keep = pos < cap

    tok_idx = jnp.repeat(jnp.arange(tg), k)                  # (Tg*k,)
    # dropped (over-capacity) entries get an out-of-bounds slot and are
    # eliminated by mode='drop' — they can never collide with a real slot
    slots = jnp.where(keep, flat_idx * cap + pos, ep * cap)  # (G, Tg*k)
    from repro.distributed.context import constrain
    xtk = x_g[:, tok_idx, :] * keep[..., None].astype(x.dtype)
    gidx = jnp.broadcast_to(jnp.arange(g)[:, None], slots.shape)
    # 1) scatter into FLAT slots (dim unsharded) — stays device-local
    flat = jnp.zeros((g, ep * cap, d), x.dtype)
    if PIN_EXPERT_SHARDING:
        flat = constrain(flat, "data", None, None)
    flat = flat.at[gidx, slots].add(xtk, mode="drop")
    # 2) (optional) pin to (G->data, E->model) expert parallelism. On the
    #    CPU-backend SPMD partitioner the pinned reshard lowers as
    #    all-gather + all-reduce (43.6 s collective term) while the
    #    unpinned program lets XLA replicate expert compute and stay
    #    memory-bound at 14.7 s — see EXPERIMENTS.md hillclimb A for the
    #    full iteration log. On a real TPU the pin should lower as a true
    #    all-to-all; flip PIN_EXPERT_SHARDING there.
    buf = flat.reshape(g, ep, cap, d)
    if PIN_EXPERT_SHARDING:
        buf = constrain(buf, "data", "model", None, None)

    # expert FFN, local per (group, expert): (G,E,C,d) x (E,d,f)
    h = act_fn(cfg, jnp.einsum("gecd,edf->gecf", buf, p["w_gate"]),
               jnp.einsum("gecd,edf->gecf", buf, p["w_up"]))
    if PIN_EXPERT_SHARDING:
        h = constrain(h, "data", "model", None, None)
    out_e = jnp.einsum("gecf,efd->gecd", h, p["w_out"])      # (G,E,C,d)
    if PIN_EXPERT_SHARDING:
        out_e = constrain(out_e, "data", "model", None, None)

    # 3) combine as a slot-major SCATTER from the expert-sharded buffer:
    #    each expert shard scatter-adds its own slots' (gate-weighted)
    #    outputs into the token buffer; the partitioner lowers this as
    #    local scatter + all-reduce of the small (Tg, d) result instead of
    #    all-gathering the whole buffer (EXPERIMENTS.md hillclimb A, it. 5).
    slot_tok = jnp.zeros((g, ep * cap), jnp.int32).at[gidx, slots].max(
        jnp.broadcast_to(tok_idx[None, :], slots.shape) + 1, mode="drop")
    w = (gv_g.reshape(g, tg * k) * keep).astype(x.dtype)
    slot_gate = jnp.zeros((g, ep * cap), x.dtype).at[gidx, slots].max(
        w, mode="drop")
    out_flat = out_e.reshape(g, ep * cap, d) * slot_gate[..., None]
    sg = jnp.broadcast_to(jnp.arange(g)[:, None], slot_tok.shape)
    out = jnp.zeros((g, tg + 1, d), x.dtype).at[sg, slot_tok].add(out_flat)
    if PIN_EXPERT_SHARDING:
        out = constrain(out, "data", None, None)
    out = out[:, 1:, :].reshape(t, d)                        # drop sentinel 0

    if cfg.n_shared_experts:
        out = out + act_fn(cfg, xt @ p["shared_gate"],
                           xt @ p["shared_up"]) @ p["shared_out"]
    return out.reshape(b, s, d), aux
