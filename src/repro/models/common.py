"""Shared model substrate: configs, parameter specs, norms, embeddings, RoPE.

Design: pure-functional JAX. Every parameter is described by a ``ParamSpec``
(shape, dtype, logical sharding axes); ``abstract_params`` builds the spec
tree, ``init_params`` materializes it, and the distributed layer resolves
logical axes -> mesh PartitionSpecs with a divisibility guard. Layers are
stacked for ``lax.scan`` (leading layer dim on every block parameter).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    pattern: Tuple[str, ...] = ("attn",)   # per-layer block types, cycled
    # --- MoE ---
    n_experts: int = 0
    experts_per_tok: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    # --- attention flavour ---
    attn_kind: str = "gqa"      # gqa | mla
    window: int = 0             # sliding-window size; 0 = full attention
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    # --- MLA (minicpm3) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- encoder-decoder / modality stubs ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_frames: int = 0           # audio stub: precomputed frame embeddings
    n_patches: int = 0          # vlm stub: precomputed patch embeddings
    # --- recurrent / ssm ---
    rglru_width: int = 0
    conv_width: int = 4
    mlstm_heads: int = 0
    proj_factor: float = 2.0    # xlstm block up-projection
    # --- misc ---
    act: str = "swiglu"         # swiglu | geglu | gelu
    norm: str = "rms"           # rms | layer
    pos_emb: str = "rope"       # rope | learned | none
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    long_variant: str = "swa"   # how long_500k decodes: swa | native | skip
    max_target_len: int = 524_288

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so embeddings shard over 16-way axes."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def n_rem_layers(self) -> int:
        return self.n_layers - self.n_units * len(self.pattern)


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Abstract parameter: shape + dtype + logical axes for sharding.

    ``axes`` names each dim: None (replicate/batch-like), "model" (shard over
    tensor-parallel axis), "layer" (scan-stacked, never sharded), "vocab"
    (sharded over model axis), "expert" (expert-parallel over model axis).
    """
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = None
    init: str = "normal"        # normal | zeros | ones
    scale: float = 1.0

    def sds(self, default_dtype) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype or default_dtype)


def spec(shape, axes, init="normal", scale=1.0, dtype=None) -> ParamSpec:
    assert len(shape) == len(axes), (shape, axes)
    return ParamSpec(tuple(shape), tuple(axes), dtype, init, scale)


def materialize(ps: ParamSpec, key: jax.Array, default_dtype) -> jnp.ndarray:
    dt = ps.dtype or default_dtype
    if ps.init == "zeros":
        return jnp.zeros(ps.shape, dt)
    if ps.init == "ones":
        return jnp.ones(ps.shape, dt)
    fan_in = ps.shape[-2] if len(ps.shape) >= 2 else ps.shape[-1]
    std = ps.scale / math.sqrt(max(fan_in, 1))
    return (std * jax.random.normal(key, ps.shape, jnp.float32)).astype(dt)


def init_params(tree, key: jax.Array, default_dtype):
    """Materialize a ParamSpec pytree into arrays (deterministic per-leaf)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [materialize(ps, k, default_dtype) for ps, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_tree(tree, default_dtype):
    """ParamSpec pytree -> ShapeDtypeStruct pytree (for dry-run lowering)."""
    return jax.tree_util.tree_map(
        lambda ps: ps.sds(default_dtype), tree,
        is_leaf=lambda x: isinstance(x, ParamSpec))


# -------------------------------------------------------------------- norms
def rms_norm(x, gamma, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps) * gamma
    return out.astype(x.dtype)


def layer_norm(x, gamma, beta, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
    return out.astype(x.dtype)


def apply_norm(cfg: ArchConfig, p: Dict, x):
    if cfg.norm == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def norm_spec(cfg: ArchConfig, stack: int = 0):
    shape = (cfg.d_model,) if not stack else (stack, cfg.d_model)
    axes = (None,) if not stack else (None, None)
    out = {"scale": spec(shape, axes, init="ones", dtype=jnp.float32)}
    if cfg.norm == "layer":
        out["bias"] = spec(shape, axes, init="zeros", dtype=jnp.float32)
    return out


# --------------------------------------------------------------------- rope
def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) rotated by position; positions (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))              # (D/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- activations
def act_fn(cfg: ArchConfig, gate, up):
    if cfg.act == "swiglu":
        return jax.nn.silu(gate) * up
    if cfg.act == "geglu":
        return jax.nn.gelu(gate) * up
    raise ValueError(cfg.act)


def mlp_spec(cfg: ArchConfig, d_ff: int = 0):
    d_ff = d_ff or cfg.d_ff
    if cfg.act == "gelu":
        return {"w_in": spec((cfg.d_model, d_ff), (None, "model")),
                "w_out": spec((d_ff, cfg.d_model), ("model", None))}
    return {"w_gate": spec((cfg.d_model, d_ff), (None, "model")),
            "w_up": spec((cfg.d_model, d_ff), (None, "model")),
            "w_out": spec((d_ff, cfg.d_model), ("model", None))}


def mlp_apply(cfg: ArchConfig, p: Dict, x):
    if cfg.act == "gelu":
        return jax.nn.gelu(x @ p["w_in"]) @ p["w_out"]
    return act_fn(cfg, x @ p["w_gate"], x @ p["w_up"]) @ p["w_out"]
