"""repro: Distributed Parameter Estimation via Pseudo-likelihood
(Liu & Ihler, ICML 2012) — faithful reproduction (repro.core) behind a
declarative estimation-plan API (repro.api: Plan -> compiled
EstimationSession with fit/stream/joint verbs and a pluggable combiner
registry), plus the technique lifted to TPU-pod scale
(repro.train.consensus) over a 10-arch model zoo (repro.models /
repro.configs), with Pallas TPU kernels (repro.kernels), a streaming
any-time engine + event-driven sensor-network simulator (repro.stream),
and a multi-pod dry-run + roofline harness (repro.launch).

See README.md for entry points, DESIGN.md for the paper->TPU mapping, and
EXPERIMENTS.md for the validation and performance record.
"""
