"""Cross-tenant request coalescing: block-diagonal union problems.

Coalescing exploits that every per-node local CL fit is *independent*
given its own samples (paper Eq. 3): r same-plan requests are exactly the
local fits of ONE estimation problem on the disjoint union of r copies of
the tenant graph, with the r sample matrices stacked along the column
(node) axis. The union graph has the same distinct (padded) degrees as a
single copy, so the union session compiles the same number of bucket
programs — one XLA dispatch then solves every node of every coalesced
request, instead of one dispatch chain per request.

Bit-identity with serial serving follows from the engine's layout
guarantees: copy-t edges occupy positions ``[t*m, (t+1)*m)`` of the union
edge list in tenant order, so ``incident_edges`` of a copied node returns
its tenant's edges in the tenant's order, per-node designs gather the same
columns, and the vmapped bucket solve computes each node's row
independently. :func:`split_fits` then only relabels node ids and beta
indices back to tenant-local coordinates — the numerical payloads
(``theta``/``H``/``J``/``V``/``s``) pass through untouched.

Group sizes are padded to powers of two (phantom slots repeat a real
member, results discarded) so a server under fluctuating load re-uses a
small, bounded set of compiled union shapes instead of minting one per
queue depth.
"""
from __future__ import annotations

import functools
from typing import List, Sequence

import numpy as np

from ..api.plan import Plan
from ..core.estimators import LocalFit
from ..core.families import get_family
from ..core.graphs import Graph

__all__ = ["union_graph", "tenant_param_slots", "coalesced_plan",
           "split_fits", "pad_group_size", "stack_columns"]


@functools.lru_cache(maxsize=256)
def union_graph(graph: Graph, r: int) -> Graph:
    """Disjoint union of ``r`` copies of ``graph`` (``r = 1`` is identity).

    Copy ``t`` owns nodes ``[t*p, (t+1)*p)`` and its edges sit at positions
    ``[t*m, (t+1)*m)`` of the union edge list, preserving the tenant's
    edge order — the property :func:`split_fits` relies on.
    """
    if r < 1:
        raise ValueError(f"need at least one copy, got r={r}")
    if r == 1:
        return graph
    p = graph.p
    edges = tuple((t * p + a, t * p + b)
                  for t in range(r) for (a, b) in graph.edges)
    return Graph(r * p, edges)


@functools.lru_cache(maxsize=256)
def tenant_param_slots(family_name: str, graph: Graph, r: int) -> np.ndarray:
    """(r, n_params) union flat-parameter indices of each tenant slot.

    Row ``t`` maps tenant-local flat parameters (family block layout:
    ``p`` node blocks then ``m`` edge blocks of size C) to their indices
    in the union problem's flat vector.
    """
    fam = get_family(family_name)
    C = fam.block_dim
    p, m = graph.p, graph.m
    c = np.arange(C, dtype=np.int64)
    slots = np.empty((r, (p + m) * C), dtype=np.int64)
    for t in range(r):
        node_part = ((t * p + np.arange(p, dtype=np.int64))[:, None] * C
                     + c[None, :]).reshape(-1)
        edge_part = ((r * p + t * m + np.arange(m, dtype=np.int64))[:, None]
                     * C + c[None, :]).reshape(-1)
        slots[t] = np.concatenate([node_part, edge_part])
    slots.setflags(write=False)
    return slots


@functools.lru_cache(maxsize=256)
def coalesced_plan(plan: Plan, r: int) -> Plan:
    """The union plan a coalesced group of ``r`` equal-plan requests
    dispatches through: same family/combiners/solver budget on the
    ``r``-copy union graph, with per-tenant side channels (faults,
    telemetry) stripped — the server owns observability for coalesced
    dispatches. For a fault-free plan, ``r = 1`` returns the tenant plan
    itself, so singleton groups share the tenant's own compiled session;
    faults are stripped on the ``r = 1`` path too, so plan-level fault
    injection never depends on whether a request happened to coalesce
    (the server additionally rejects fault-carrying plans at
    registration)."""
    if r == 1:
        return plan if plan.faults is None else plan.replace(faults=None)
    g = union_graph(plan.graph, r)
    tf = None
    if plan.theta_fixed is not None:
        fam = plan.family_instance
        slots = tenant_param_slots(plan.family, plan.graph, r)
        out = np.zeros(fam.n_params(g), dtype=np.float64)
        for t in range(r):
            out[slots[t]] = np.asarray(plan.theta_fixed, dtype=np.float64)
        tf = tuple(float(v) for v in out)
    return plan.replace(graph=g, theta_fixed=tf, faults=None, telemetry=None)


def pad_group_size(r: int, max_coalesce: int) -> int:
    """Power-of-two group padding, capped at ``max_coalesce`` — bounds the
    set of union shapes (and therefore compiled programs) a server can
    ever dispatch to O(log max_coalesce)."""
    if r < 1:
        raise ValueError(f"empty coalesce group (r={r})")
    size = 1
    while size < r:
        size *= 2
    return min(size, max(max_coalesce, r))


def stack_columns(mats: Sequence[np.ndarray], r_pad: int) -> np.ndarray:
    """Column-stack r same-shape (n, p) sample matrices into the union's
    (n, r_pad*p), repeating the last member into phantom padding slots."""
    mats = list(mats)
    if r_pad > len(mats):
        mats = mats + [mats[-1]] * (r_pad - len(mats))
    return np.concatenate([np.asarray(m) for m in mats], axis=1)


def split_fits(union_fits: Sequence[LocalFit], graph: Graph, family,
               include_singleton: bool, r: int) -> List[List[LocalFit]]:
    """Per-tenant ``List[LocalFit]`` banks from a union dispatch.

    Only node ids and beta index lists are relabeled to tenant-local
    coordinates; the numerical arrays are the union solve's outputs
    unchanged. Phantom padding slots (``t >= r``) are dropped by passing
    the real ``r``.
    """
    p = graph.p
    betas = [family.beta(graph, i, include_singleton) for i in range(p)]
    out: List[List[LocalFit]] = []
    for t in range(r):
        out.append([
            LocalFit(i=i, beta=betas[i], theta=f.theta, H=f.H, J=f.J,
                     V=f.V, s=f.s)
            for i, f in enumerate(union_fits[t * p: (t + 1) * p])])
    return out
