"""Multi-tenant estimation session server with coalesced batching.

The serving tier over the plan-keyed session cache (:mod:`repro.api`): a
:class:`SessionServer` accepts many concurrent *tenants* — each a frozen
:class:`~repro.api.plan.Plan` (optionally carrying a
:class:`~repro.telemetry.TelemetrySpec`) plus an admission
:class:`~repro.serve.admission.BudgetSpec` — and routes their ``fit`` /
``stream`` requests through the cached
:class:`~repro.api.session.EstimationSession` machinery. Equal plans share
ONE session, so a warm tenant population compiles nothing per request.

**Coalesced batching.** Queued same-shape requests of equal plans are
merged into a single batched-engine dispatch: the group becomes a
block-diagonal union problem (:mod:`repro.serve.coalesce`) — r tenant
graphs as one disjoint-union graph, r sample matrices column-stacked —
solved by ONE XLA call per degree bucket, instead of one dispatch chain
per request (continuous batching of streaming rounds). Group sizes are
padded to a bounded set of power-of-two shapes so the compiled-program
universe stays O(#buckets · log max_coalesce) under arbitrary load, and
results are split back per tenant bit-identically to serial serving.

**Admission control.** ``submit`` is where requests are accepted or
rejected, never dropped later: a bounded queue applies backpressure
(reject reason ``"queue_full"``) and per-tenant communication budgets —
billed with the exact combiner-registry scalar accounting of
:mod:`repro.stream.costs` — reject with ``"budget_exhausted"`` until the
configured replenishment schedule refills the ledger. Every decision lands
in the server's telemetry registry (``serve.admitted`` /
``serve.rejected`` counters tagged by tenant and reason, queue-depth
gauges, latency histograms, coalesce-size observations).

The transformer-era ``repro.serve.engine`` (KV-cache decode) this package
replaces lives on as :mod:`repro.models.decoding`; importing the old
module names raises a migration error pointing here.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional

import numpy as np

from ..api.plan import Plan
from ..api.session import EstimationSession
from ..core.batched import bucket_compile_count
from ..core.estimators import LocalFit
from ..stream.costs import plan_request_scalars
from ..telemetry.recorder import make_recorder
from ..telemetry.spec import TelemetrySpec
from .admission import (REJECT_BUDGET, REJECT_QUEUE_FULL, BudgetSpec,
                        BudgetState)
from .coalesce import coalesced_plan, pad_group_size, split_fits

__all__ = ["SessionServer", "ServeResult", "Ticket", "Tenant"]

#: request kinds a tenant may submit
KINDS = ("fit", "stream")


@dataclasses.dataclass
class ServeResult:
    """One served request's payload.

    theta/combined/fits mirror :class:`~repro.api.result.EstimateResult`
    (the headline estimate is the plan's first combiner); the serving
    extras record how the request was executed: the true coalesce group
    size it rode in (1 = serial), the bucket-solver compilations its
    dispatch triggered (shared across the group; 0 on a warm path), and
    the comm scalars its admission charge billed.
    """

    tenant_id: str
    kind: str
    theta: np.ndarray
    combined: Dict[str, np.ndarray]
    fits: List[LocalFit]
    n_samples: int
    coalesce_size: int
    new_compiles: int
    comm_scalars: int


@dataclasses.dataclass
class Ticket:
    """Handle returned by :meth:`SessionServer.submit`.

    status moves ``queued -> done`` for admitted requests; a rejected
    request is born ``rejected`` with ``reject_reason`` set (one of the
    :mod:`repro.serve.admission` reason constants) and is never queued.
    An *accepted* ticket is never dropped: every queued request is served
    by a subsequent :meth:`SessionServer.pump` / :meth:`drain`.
    """

    tenant_id: str
    kind: str
    seq: int
    status: str = "queued"
    result: Optional[ServeResult] = None
    reject_reason: Optional[str] = None
    submitted_wall: float = 0.0
    latency_s: Optional[float] = None
    #: scalars the admission charge billed (the plan's exact one-step
    #: message cost for this request's rows)
    comm_cost: int = 0
    #: request payload; cleared once served
    _X: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)

    @property
    def admitted(self) -> bool:
        return self.status != "rejected"

    @property
    def done(self) -> bool:
        return self.status == "done"


class Tenant:
    """Server-side tenant state: plan, shared session, budget ledger,
    lazily-created plan-bound streaming estimator."""

    def __init__(self, tenant_id: str, plan: Plan,
                 budget: Optional[BudgetSpec], now: float) -> None:
        self.tenant_id = tenant_id
        self.plan = plan
        self.session: EstimationSession = plan.session()
        self.budget = None if budget is None else BudgetState(budget, now)
        self._stream = None
        self.served = 0
        self.rejected = 0

    @property
    def stream(self):
        """The tenant's plan-bound StreamingEstimator (created on first
        stream request; persists across rounds — that is the stream)."""
        if self._stream is None:
            self._stream = self.session.stream()
        return self._stream


class SessionServer:
    """See module docstring.

    Parameters
    ----------
    max_queue    — queue-depth bound; ``submit`` beyond it rejects with
                   ``"queue_full"`` (graceful backpressure — nothing
                   already accepted is affected).
    max_coalesce — largest coalesced group (power-of-two padded).
    coalesce     — False serves every request through its own session
                   serially (the bench's baseline mode).
    telemetry    — server-level :class:`TelemetrySpec` (default: live
                   in-memory recorder, so admission counters are always
                   inspectable); pass ``None`` for the null recorder.
    clock        — callable returning logical seconds for budget
                   replenishment; inject a
                   :class:`~repro.serve.admission.VirtualClock` for
                   deterministic schedules (default ``time.monotonic``).
    """

    def __init__(self, *, max_queue: int = 256, max_coalesce: int = 8,
                 coalesce: bool = True,
                 telemetry: Optional[TelemetrySpec] = TelemetrySpec(),
                 clock=None) -> None:
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue!r}")
        if max_coalesce < 1:
            raise ValueError(
                f"max_coalesce must be >= 1, got {max_coalesce!r}")
        self.max_queue = int(max_queue)
        self.max_coalesce = int(max_coalesce) if coalesce else 1
        self.coalesce = bool(coalesce)
        self.recorder = make_recorder(telemetry)
        self.clock = clock if clock is not None else time.monotonic
        self._tenants: Dict[str, Tenant] = {}
        self._queue: Deque[Ticket] = collections.deque()
        self._seq = 0

    # ------------------------------------------------------------- tenants
    def register(self, tenant_id: str, plan: Plan,
                 budget: Optional[BudgetSpec] = None) -> Tenant:
        """Admit a tenant: bind its (frozen) plan to the shared session
        cache and open its budget ledger at the current clock."""
        if tenant_id in self._tenants:
            raise ValueError(f"tenant {tenant_id!r} is already registered")
        if not isinstance(plan, Plan):
            raise TypeError(f"tenant plan must be a repro.api.Plan, got "
                            f"{type(plan).__name__}")
        if budget is not None and not isinstance(budget, BudgetSpec):
            raise TypeError(f"budget must be a BudgetSpec or None, got "
                            f"{type(budget).__name__}")
        if plan.faults is not None:
            raise ValueError(
                f"tenant {tenant_id!r}'s plan carries a FaultPlan; the "
                f"server never injects plan-level faults (coalesced "
                f"dispatches strip them, so injection would depend on "
                f"which requests happened to group) — register "
                f"plan.replace(faults=None) and drive fault scenarios "
                f"through repro.stream.simulator instead")
        t = Tenant(tenant_id, plan, budget, float(self.clock()))
        self._tenants[tenant_id] = t
        if self.recorder.enabled:
            self.recorder.inc("serve.tenants_registered", tenant=tenant_id)
        return t

    def tenant(self, tenant_id: str) -> Tenant:
        try:
            return self._tenants[tenant_id]
        except KeyError:
            raise KeyError(
                f"unknown tenant {tenant_id!r}; register(tenant_id, plan) "
                f"first (registered: {sorted(self._tenants)})") from None

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def request_cost(self, tenant_id: str, n: int) -> int:
        """Scalars a request with ``n`` sample rows is billed — the exact
        one-step accounting of the tenant's plan (summed over its
        distributable combiners)."""
        t = self.tenant(tenant_id)
        return plan_request_scalars(
            t.plan.graph, t.plan.combiners, n,
            include_singleton=t.plan.include_singleton,
            family=t.session.family)

    def metrics(self):
        """Snapshot of the server's telemetry registry (None when the
        server was built with ``telemetry=None``)."""
        return self.recorder.snapshot()

    # ------------------------------------------------------------ admission
    def submit(self, tenant_id: str, X, kind: str = "fit") -> Ticket:
        """Admission-controlled enqueue of one request; see class docs."""
        t = self.tenant(tenant_id)
        if kind not in KINDS:
            raise ValueError(f"unknown request kind {kind!r}; choose from "
                             f"{KINDS}")
        X = np.asarray(X)
        if X.ndim == 1:
            X = X[None, :]
        if X.ndim != 2 or X.shape[1] != t.plan.graph.p:
            raise ValueError(
                f"request samples must be (n, p={t.plan.graph.p}) for "
                f"tenant {tenant_id!r}'s graph, got shape {X.shape}")
        if X.shape[0] < 1:
            raise ValueError("request carries no sample rows")
        self._seq += 1
        ticket = Ticket(tenant_id=tenant_id, kind=kind, seq=self._seq,
                        submitted_wall=time.perf_counter(), _X=X)
        ticket.comm_cost = self.request_cost(tenant_id, int(X.shape[0]))
        if len(self._queue) >= self.max_queue:
            return self._reject(t, ticket, REJECT_QUEUE_FULL)
        if t.budget is not None and not t.budget.try_charge(
                ticket.comm_cost, float(self.clock())):
            return self._reject(t, ticket, REJECT_BUDGET)
        self._queue.append(ticket)
        if self.recorder.enabled:
            self.recorder.inc("serve.admitted", tenant=tenant_id, kind=kind)
            self.recorder.gauge("serve.queue_depth", len(self._queue))
        return ticket

    def _reject(self, t: Tenant, ticket: Ticket, reason: str) -> Ticket:
        ticket.status = "rejected"
        ticket.reject_reason = reason
        ticket._X = None
        t.rejected += 1
        if self.recorder.enabled:
            self.recorder.inc("serve.rejected", tenant=t.tenant_id,
                              reason=reason, kind=ticket.kind)
        return ticket

    # ------------------------------------------------------------- serving
    def pump(self) -> List[Ticket]:
        """Serve ONE coalesced group from the queue head (FIFO; one
        request per tenant per group so streaming rounds stay ordered).
        Returns the tickets served; [] when the queue is empty."""
        group = self._next_group()
        if not group:
            return []
        rec = self.recorder
        if rec.enabled:
            with rec.span("serve_dispatch", kind=group[0].kind,
                          group=len(group)):
                self._dispatch(group)
        else:
            self._dispatch(group)
        if rec.enabled:
            rec.gauge("serve.queue_depth", len(self._queue))
        return group

    def drain(self) -> List[Ticket]:
        """Pump until the queue is empty; every accepted request is served
        (backpressure rejects at admission, never drops afterwards)."""
        served: List[Ticket] = []
        while True:
            batch = self.pump()
            if not batch:
                return served
            served.extend(batch)

    # -------------------------------------------------------- group forming
    def _group_key(self, ticket: Ticket):
        t = self._tenants[ticket.tenant_id]
        if ticket.kind == "fit":
            return (t.plan, "fit", ticket._X.shape)
        # stream rounds coalesce on the post-ingest padded buffer shape
        # (ingestion happens exactly once, when the request is first
        # considered) plus the warm-start flag, which is a static argument
        # of the bucket solver: a tenant's very first round solves cold
        # while warmed tenants solve guarded, so the two never share a
        # dispatch — keeping every coalesced round bit-identical to the
        # serial path.
        est = t.stream
        return (t.plan, "stream", est.buffer.data.shape,
                est._warm is not None)

    def _next_group(self) -> List[Ticket]:
        if not self._queue:
            return []
        head = self._queue[0]
        self._ingest_if_needed(head)
        key = self._group_key(head)
        group = [head]
        # Every tenant encountered in the scan is marked seen — grouped or
        # not — so at most the FIRST queued request per tenant is ever
        # considered (or stream-ingested) per pump. A candidate that fails
        # the kind/plan/key checks still blocks that tenant's later
        # requests; otherwise a later round could be ingested (or even
        # dispatched) ahead of an earlier one, breaking per-tenant FIFO
        # order and the coalesced==serial guarantee.
        seen = {head.tenant_id}
        if self.max_coalesce > 1:
            for ticket in list(self._queue)[1:]:
                if len(group) >= self.max_coalesce:
                    break
                if ticket.tenant_id in seen:
                    continue
                seen.add(ticket.tenant_id)
                if ticket.kind != head.kind:
                    continue
                if (self._tenants[ticket.tenant_id].plan
                        != self._tenants[head.tenant_id].plan):
                    continue
                self._ingest_if_needed(ticket)
                if self._group_key(ticket) != key:
                    continue
                group.append(ticket)
        for ticket in group:
            self._queue.remove(ticket)
        return group

    def _ingest_if_needed(self, ticket: Ticket) -> None:
        """A stream request's rows enter the tenant's pool exactly once,
        at first consideration — the buffer's (possibly doubled) padded
        shape is then this round's coalesce key."""
        if ticket.kind != "stream" or ticket._X is None:
            return
        est = self._tenants[ticket.tenant_id].stream
        est.ingest(ticket._X)
        ticket._X = None

    # ------------------------------------------------------------- dispatch
    def _dispatch(self, group: List[Ticket]) -> None:
        head = self._tenants[group[0].tenant_id]
        plan, session = head.plan, head.session
        r = len(group)
        r_pad = pad_group_size(r, self.max_coalesce)
        usession = coalesced_plan(plan, r_pad).session()
        c0 = bucket_compile_count()
        if group[0].kind == "fit":
            Xs = [t._X for t in group]
            # fit groups key on the request X shape, so one n fits all
            n_fit = int(Xs[0].shape[0])
            X_union = np.concatenate(Xs + [Xs[-1]] * (r_pad - r), axis=1)
            union_fits = usession.fit_local(
                X_union, want_influence=session.want_influence)
        else:
            ests = [self._tenants[t.tenant_id].stream for t in group]
            pads = ests + [ests[-1]] * (r_pad - r)
            X_union = np.concatenate([e.buffer.data for e in pads], axis=1)
            sw = np.concatenate(
                [e.buffer.window_weights(e.counts, e.window, e.discount)
                 for e in pads], axis=0)
            warm = None
            if any(e._warm is not None for e in ests):
                warm = []
                for e in pads:
                    warm.extend(e._warm if e._warm is not None
                                else [None] * e.graph.p)
            union_fits = usession.fit_local(
                X_union, sample_weight=sw, warm_start=warm,
                want_influence=session.want_influence)
        c1 = bucket_compile_count()
        new_compiles = (c1 - c0) if c0 >= 0 and c1 >= 0 else -1
        per_tenant = split_fits(union_fits, plan.graph, session.family,
                                plan.include_singleton, r)
        now_wall = time.perf_counter()
        for ticket, fits in zip(group, per_tenant):
            tenant = self._tenants[ticket.tenant_id]
            if ticket.kind == "stream":
                tenant.stream._finish_refit(fits)
                # stream groups key on the padded buffer shape, so group
                # members may carry different ingested totals — report
                # each tenant's own pool count
                n_served = int(tenant.stream.buffer.n)
            else:
                n_served = n_fit
            combined = {
                c.name: c.combine(plan.graph, fits,
                                  include_singleton=plan.include_singleton,
                                  theta_fixed=session.theta_fixed,
                                  family=session.family)
                for c in session.combiners}
            ticket.result = ServeResult(
                tenant_id=ticket.tenant_id, kind=ticket.kind,
                theta=combined[plan.combiners[0]], combined=combined,
                fits=fits, n_samples=n_served, coalesce_size=r,
                new_compiles=new_compiles, comm_scalars=ticket.comm_cost)
            ticket.status = "done"
            ticket.latency_s = now_wall - ticket.submitted_wall
            ticket._X = None
            tenant.served += 1
            if self.recorder.enabled:
                self.recorder.inc("serve.served", tenant=ticket.tenant_id,
                                  kind=ticket.kind)
                self.recorder.observe("serve.latency_s", ticket.latency_s,
                                      tenant=ticket.tenant_id)
        if self.recorder.enabled:
            self.recorder.observe("serve.coalesce_size", r)
            self.recorder.inc("serve.dispatches")
            if new_compiles > 0:
                self.recorder.inc("serve.new_compiles", new_compiles)
