"""Migration shim — the KV-cache decode helpers moved out of the serving
tier.

``repro.serve`` is now the multi-tenant *estimation* session server
(:class:`repro.serve.SessionServer`); the transformer decode utilities
that used to live here (``make_serve_step``, ``prefill``, ``generate``)
are model-zoo code and moved unchanged to :mod:`repro.models.decoding`.

Importing this module raises so stale call sites fail loudly with a
pointer instead of silently resolving to the wrong subsystem.
"""
raise ModuleNotFoundError(
    "repro.serve.engine has been removed: the KV-cache decode helpers "
    "(make_serve_step, prefill, generate) moved to repro.models.decoding, "
    "and repro.serve now hosts the multi-tenant estimation session server "
    "(repro.serve.SessionServer). Update imports to "
    "'from repro.models import decoding' for decode, or "
    "'from repro.serve import SessionServer' for serving.",
    name="repro.serve.engine")
