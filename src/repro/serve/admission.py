"""Admission control: per-tenant communication budgets and queue bounds.

The serving tier's admission decisions are made in the currency the paper
cares about — *scalars on the wire*. A tenant's request is billed the exact
number of scalars its plan's one-step consensus messages would transmit
(the combiner-registry accounting of :mod:`repro.stream.costs`, the same
single source the simulator's measured counters reconcile against), so a
per-tenant :class:`BudgetSpec` is a communication budget in the sense of
Liu & Ihler 2014 (arXiv:1410.2653): it caps the information a tenant may
pull out of the sensor network per replenishment window.

Decisions are deterministic functions of (queue depth, budget ledger,
clock). The clock is injected — production servers run on
``time.monotonic``, the deterministic load harness and the admission tests
drive a :class:`VirtualClock` by hand so replenishment schedules are exact.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["BudgetSpec", "BudgetState", "VirtualClock",
           "REJECT_QUEUE_FULL", "REJECT_BUDGET"]

#: admission rejection reasons, surfaced verbatim on tickets and as the
#: ``reason`` tag of the ``serve.rejected`` telemetry counter
REJECT_QUEUE_FULL = "queue_full"
REJECT_BUDGET = "budget_exhausted"


class VirtualClock:
    """A hand-advanced logical clock (seconds). Deterministic stand-in for
    ``time.monotonic`` in tests, benches, and the load harness."""

    def __init__(self, t0: float = 0.0) -> None:
        self.t = float(t0)

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clocks only move forward, got dt={dt!r}")
        self.t += float(dt)
        return self.t

    def __call__(self) -> float:
        return self.t


@dataclasses.dataclass(frozen=True)
class BudgetSpec:
    """Declarative per-tenant communication budget.

    scalars         — scalars the tenant may transmit per window; every
                      admitted request is charged its plan's exact one-step
                      message cost up front (so an accepted request is
                      never dropped later for lack of funds).
    replenish_every — logical seconds between refills; each refill restores
                      the ledger to the full ``scalars`` (reset, not
                      additive). ``None`` never replenishes — a hard
                      lifetime cap.
    """

    scalars: int
    replenish_every: Optional[float] = None

    def __post_init__(self):
        if int(self.scalars) < 0:
            raise ValueError(
                f"budget scalars must be >= 0, got {self.scalars!r}")
        object.__setattr__(self, "scalars", int(self.scalars))
        if self.replenish_every is not None:
            ev = float(self.replenish_every)
            if not ev > 0.0:
                raise ValueError(
                    f"replenish_every must be a positive interval (None "
                    f"disables replenishment), got {self.replenish_every!r}")
            object.__setattr__(self, "replenish_every", ev)

    def to_dict(self) -> dict:
        return {"scalars": self.scalars,
                "replenish_every": self.replenish_every}

    @classmethod
    def from_dict(cls, d: dict) -> "BudgetSpec":
        return cls(scalars=int(d["scalars"]),
                   replenish_every=d.get("replenish_every"))


class BudgetState:
    """One tenant's live ledger for a :class:`BudgetSpec`.

    ``try_charge`` first applies every replenishment the clock has earned
    (refill boundaries are multiples of ``replenish_every`` from
    registration time, independent of traffic), then admits iff the full
    cost fits in the remaining ledger — a request is either funded
    completely at admission or rejected, never half-billed.
    """

    def __init__(self, spec: BudgetSpec, now: float) -> None:
        self.spec = spec
        self.remaining = spec.scalars
        self._next_refill = (None if spec.replenish_every is None
                             else now + spec.replenish_every)

    def replenish(self, now: float) -> None:
        if self._next_refill is None or now < self._next_refill:
            return
        every = self.spec.replenish_every
        missed = int((now - self._next_refill) // every) + 1
        self.remaining = self.spec.scalars
        self._next_refill += missed * every

    def try_charge(self, cost: int, now: float) -> bool:
        if cost < 0:
            raise ValueError(f"negative request cost {cost!r}")
        self.replenish(now)
        if cost > self.remaining:
            return False
        self.remaining -= cost
        return True
