"""Estimation-as-a-service: the multi-tenant session server.

Serve many concurrent tenants — each a frozen
:class:`~repro.api.plan.Plan` plus an optional communication
:class:`BudgetSpec` — through the plan-keyed session cache, with
cross-tenant coalesced batching (one XLA dispatch per same-shape group,
see :mod:`repro.serve.coalesce`), admission control billed in exact
one-step message scalars (:mod:`repro.serve.admission`), and a
deterministic load harness (:mod:`repro.serve.loadgen`).

    from repro.serve import SessionServer, BudgetSpec

    srv = SessionServer(max_coalesce=8)
    srv.register("acme", plan, budget=BudgetSpec(scalars=10_000,
                                                 replenish_every=60.0))
    ticket = srv.submit("acme", X)          # admission-controlled
    srv.drain()                             # coalesced dispatch
    ticket.result.theta                     # == serial session.fit(X)

The transformer-era ``repro.serve.engine`` (KV-cache decode) moved to
:mod:`repro.models.decoding`; importing the old name raises a migration
error.
"""
from .admission import (REJECT_BUDGET, REJECT_QUEUE_FULL, BudgetSpec,
                        BudgetState, VirtualClock)
from .coalesce import (coalesced_plan, pad_group_size, split_fits,
                       tenant_param_slots, union_graph)
from .loadgen import LoadReport, run_load, synthetic_workload
from .server import ServeResult, SessionServer, Tenant, Ticket

__all__ = [
    "SessionServer", "Tenant", "Ticket", "ServeResult",
    "BudgetSpec", "BudgetState", "VirtualClock",
    "REJECT_QUEUE_FULL", "REJECT_BUDGET",
    "union_graph", "coalesced_plan", "split_fits", "tenant_param_slots",
    "pad_group_size",
    "synthetic_workload", "run_load", "LoadReport",
]
