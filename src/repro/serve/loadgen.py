"""Deterministic load-test harness for the session server.

The workload is built up-front and replayed: :func:`synthetic_workload`
pre-draws every request's sample rows from each tenant family's exact
sampler with keys folded from ``(seed, round, tenant)``, so two runs (or
two server configurations — coalescing ON vs OFF) see byte-identical
request streams in the same order. :func:`run_load` submits round by
round, drains between rounds, optionally advances a
:class:`~repro.serve.admission.VirtualClock`, and folds the tickets into a
:class:`LoadReport` — p50/p99 latency, throughput, admission outcomes,
coalesce sizes, and warm-path compile counts, the numbers
``benchmarks/serve_bench.py`` publishes.

Determinism covers everything *decision-shaped*: which requests are
admitted or rejected (and why), how groups coalesce, and every numerical
result. Wall-clock latencies obviously vary by machine — they are the
measurement, not the schedule.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..api.plan import Plan
from .admission import VirtualClock
from .server import SessionServer, Ticket

__all__ = ["LoadReport", "synthetic_workload", "run_load"]

#: one request: (tenant_id, sample rows, kind)
Request = Tuple[str, np.ndarray, str]


@dataclasses.dataclass
class LoadReport:
    """Aggregate of one load run; latencies in seconds."""

    n_submitted: int
    n_served: int
    n_rejected: int
    rejected_by_reason: Dict[str, int]
    latencies_s: np.ndarray
    wall_s: float
    coalesce_sizes: List[int]
    new_compiles: int
    tickets: List[Ticket]

    @property
    def throughput_rps(self) -> float:
        return self.n_served / self.wall_s if self.wall_s > 0 else 0.0

    def latency_ms(self, q: float) -> float:
        """The q-th latency percentile in milliseconds (e.g. 50, 99)."""
        if self.latencies_s.size == 0:
            return float("nan")
        return float(np.percentile(self.latencies_s, q) * 1e3)

    def summary(self) -> dict:
        return {
            "n_submitted": self.n_submitted,
            "n_served": self.n_served,
            "n_rejected": self.n_rejected,
            "rejected_by_reason": dict(self.rejected_by_reason),
            "p50_ms": self.latency_ms(50),
            "p99_ms": self.latency_ms(99),
            "throughput_rps": self.throughput_rps,
            "wall_s": self.wall_s,
            "mean_coalesce_size": (float(np.mean(self.coalesce_sizes))
                                   if self.coalesce_sizes else 0.0),
            "new_compiles": self.new_compiles,
        }


#: largest graph the exact (full state enumeration) sampler is used for;
#: beyond it the workload draws via vmapped chromatic Gibbs instead
_EXACT_SAMPLE_MAX_P = 12


def _draw_rows(plan: Plan, theta: np.ndarray, n: int, key) -> np.ndarray:
    fam = plan.family_instance
    if plan.graph.p <= _EXACT_SAMPLE_MAX_P:
        return np.asarray(fam.exact_sample(plan.graph, theta, n, key))
    from ..core.sampling import gibbs_sample_family
    return np.asarray(gibbs_sample_family(fam, plan.graph, theta, n, key))


def synthetic_workload(tenant_plans: Dict[str, Plan], rounds: int,
                       n_rows: int, seed: int = 0,
                       kind: str = "fit",
                       theta: Optional[dict] = None
                       ) -> List[List[Request]]:
    """Pre-drawn multi-tenant request schedule: every round, every tenant
    submits one ``kind`` request of ``n_rows`` fresh rows sampled from its
    plan's family at parameters ``theta[tenant]`` (default: the family's
    seeded ``random_params``). All randomness is folded from ``seed`` —
    the schedule is a pure function of its arguments. Small graphs draw
    from the exact distribution; past ``p = 12`` (where state enumeration
    explodes) the draw switches to seeded chromatic Gibbs."""
    base = jax.random.PRNGKey(seed)
    schedule: List[List[Request]] = []
    thetas = {}
    for j, (tid, plan) in enumerate(sorted(tenant_plans.items())):
        fam = plan.family_instance
        if theta is not None and tid in theta:
            thetas[tid] = np.asarray(theta[tid])
        else:
            thetas[tid] = np.asarray(
                fam.random_params(plan.graph,
                                  jax.random.fold_in(base, 1000 + j)))
    for rnd in range(rounds):
        requests: List[Request] = []
        for j, (tid, plan) in enumerate(sorted(tenant_plans.items())):
            key = jax.random.fold_in(jax.random.fold_in(base, rnd), j)
            requests.append((tid, _draw_rows(plan, thetas[tid], n_rows, key),
                             kind))
        schedule.append(requests)
    return schedule


def run_load(server: SessionServer, schedule: Sequence[Sequence[Request]],
             *, round_dt: Optional[float] = None) -> LoadReport:
    """Replay a workload: submit each round's requests, drain the server,
    advance a :class:`VirtualClock` by ``round_dt`` between rounds (only
    when the server runs on one), and fold the tickets into a
    :class:`LoadReport`. ``new_compiles`` is the bucket-solver
    compile-count delta over the whole run — a warm run reports 0."""
    from ..core.batched import bucket_compile_count
    tickets: List[Ticket] = []
    c0 = bucket_compile_count()
    t0 = time.perf_counter()
    for requests in schedule:
        for (tid, X, kind) in requests:
            tickets.append(server.submit(tid, X, kind=kind))
        server.drain()
        if round_dt is not None and isinstance(server.clock, VirtualClock):
            server.clock.advance(round_dt)
    wall = time.perf_counter() - t0
    c1 = bucket_compile_count()
    new_compiles = (c1 - c0) if c0 >= 0 and c1 >= 0 else -1
    done = [t for t in tickets if t.done]
    rejected = [t for t in tickets if not t.admitted]
    by_reason: Dict[str, int] = {}
    for t in rejected:
        by_reason[t.reject_reason] = by_reason.get(t.reject_reason, 0) + 1
    return LoadReport(
        n_submitted=len(tickets),
        n_served=len(done),
        n_rejected=len(rejected),
        rejected_by_reason=by_reason,
        latencies_s=np.asarray([t.latency_s for t in done],
                               dtype=np.float64),
        wall_s=wall,
        coalesce_sizes=[t.result.coalesce_size for t in done],
        new_compiles=new_compiles,
        tickets=tickets)
