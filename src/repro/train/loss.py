"""Cross-entropy LM loss with label masking and z-loss regularization.

Computed in float32 regardless of activation dtype; padded-vocab logits are
safe because labels never index the padding region.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels, *, z_loss: float = 1e-4):
    """logits: (B, S, V); labels: (B, S) int32, -1 = masked.

    Returns (mean_loss, metrics dict).
    """
    lf = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    safe_labels = jnp.maximum(labels, 0)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, safe_labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    zl = z_loss * jnp.square(lse) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll + zl).sum() / denom
    metrics = {
        "nll": nll.sum() / denom,
        "z_loss": zl.sum() / denom,
        "n_tokens": mask.sum(),
    }
    return loss, metrics
