"""Pod-level consensus training — the paper's technique lifted to TPU pods.

Each pod is a "sensor": it holds a data shard and runs H local AdamW steps
(cheap intra-pod communication only). Every round the per-pod parameter
estimates are combined across the ``pod`` mesh axis with the paper's
one-step consensus rules (Sec. 3.1), or kept in an ADMM loop (Sec. 3.2):

  uniform   — plain average (Linear-Uniform; FedAvg/local-SGD analogue)
  diagonal  — inverse-variance weights from the per-pod Fisher diagonal
              (Adam's v EMA) — Prop 4.4/4.7 weights, ZERO extra comm
  max       — per-parameter argmax-weight vote (Max-Diagonal)
  admm      — per-pod proximal objective + dual state, theta_bar via
              weighted consensus; Thm 3.1's any-time property: theta_bar
              is a valid checkpoint after every round

Implementation: per-pod replicas are STACKED on a leading axis sharded over
the ``pod`` mesh axis; the local step is ``jax.vmap`` over that axis, so XLA
turns cross-pod reductions into pod-axis collectives and everything else
stays pod-local. Cross-pod bytes drop from one grad all-reduce per step to
one weighted parameter reduction per H steps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig
from repro.optim import adamw
from .step import TrainConfig, TrainState, grads_of


@dataclasses.dataclass(frozen=True)
class ConsensusConfig:
    n_pods: int = 2
    scheme: str = "diagonal"     # uniform | diagonal | max | admm
    h_steps: int = 4             # local steps per consensus round
    rho: float = 1.0             # ADMM penalty scale on fisher weights
    eps: float = 1e-8


class ConsensusState(NamedTuple):
    params: Any       # (P, ...) per-pod replicas
    opt: adamw.AdamWState  # (P, ...) stacked
    lam: Any          # (P, ...) ADMM duals (zeros unless scheme == admm)
    theta_bar: Any    # (...) consensus reference (ADMM; else last combine)


def init_state(cfg: ArchConfig, key: jax.Array,
               ccfg: ConsensusConfig) -> ConsensusState:
    from repro.models import transformer as T
    params = T.model_init(cfg, key)
    stacked = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (ccfg.n_pods,) + p.shape), params)
    opt = adamw.init(stacked)
    # per-pod step counters
    opt = opt._replace(step=jnp.zeros((ccfg.n_pods,), jnp.int32))
    lam = jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), stacked)
    return ConsensusState(params=stacked, opt=opt, lam=lam, theta_bar=params)


def _fisher_weights(opt: adamw.AdamWState, eps: float):
    """Per-pod, per-parameter 1/Vhat weights from the Adam second moment."""
    fd = adamw.fisher_diag(opt._replace(step=opt.step.max()))
    return jax.tree_util.tree_map(lambda v: v + eps, fd)


def combine(scheme: str, params, weights):
    """Combine per-pod stacked params (P, ...) -> consensus (...)."""
    if scheme == "uniform":
        return jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32).mean(0).astype(p.dtype), params)
    if scheme in ("diagonal", "admm"):
        def f(p, w):
            num = (p.astype(jnp.float32) * w).sum(0)
            return (num / w.sum(0)).astype(p.dtype)
        return jax.tree_util.tree_map(f, params, weights)
    if scheme == "max":
        # compare-and-select instead of argmax + take_along_axis: the gather
        # lowered as a 3.7 GB cross-pod transfer per round; max+select is two
        # parameter-sized pod reductions (EXPERIMENTS.md hillclimb C).
        def f(p, w):
            wmax = w.max(axis=0, keepdims=True)
            sel = (w == wmax).astype(jnp.float32)
            num = (p.astype(jnp.float32) * sel).sum(0)
            den = jnp.maximum(sel.sum(0), 1.0)     # ties averaged
            return (num / den).astype(p.dtype)
        return jax.tree_util.tree_map(f, params, weights)
    raise ValueError(scheme)


def make_round_step(cfg: ArchConfig, ocfg: adamw.AdamWConfig,
                    tcfg: TrainConfig, ccfg: ConsensusConfig):
    """One consensus round: H local steps per pod + cross-pod combination.

    batch: dict of (P, H, local_batch, ...) arrays (pod-major).
    """
    def local_step(params, opt, lam, theta_bar, batch):
        grads, metrics = grads_of(cfg, tcfg, params, batch)
        if ccfg.scheme == "admm":
            # proximal gradient: grad += lam + rho_w * (theta - theta_bar)
            w = _fisher_weights(opt, ccfg.eps)
            grads = jax.tree_util.tree_map(
                lambda g, l, p, tb, wi: g.astype(jnp.float32) + l +
                ccfg.rho * wi * (p.astype(jnp.float32) -
                                 tb.astype(jnp.float32)),
                grads, lam, params, theta_bar, w)
        new_params, new_opt = adamw.update(ocfg, grads, opt, params)
        return new_params, new_opt, metrics

    def round_step(state: ConsensusState, batch: Dict):
        def h_body(carry, hbatch):
            params, opt = carry
            new_params, new_opt, metrics = jax.vmap(
                lambda p, o, l, b: local_step(p, o, l, state.theta_bar, b),
                in_axes=(0, 0, 0, 0))(params, opt, state.lam, hbatch)
            return (new_params, new_opt), metrics

        hmajor = jax.tree_util.tree_map(lambda x: x.swapaxes(0, 1), batch)
        (params, opt), metrics = jax.lax.scan(
            h_body, (state.params, state.opt), hmajor)
        metrics = jax.tree_util.tree_map(lambda m: m.mean(), metrics)

        w = _fisher_weights(opt, ccfg.eps)
        theta_bar = combine(ccfg.scheme, params, w)
        if ccfg.scheme == "admm":
            # dual ascent; local params stay local (joint optimization)
            lam = jax.tree_util.tree_map(
                lambda l, p, tb, wi: l + ccfg.rho * wi * (
                    p.astype(jnp.float32) - tb.astype(jnp.float32)[None]),
                state.lam, params, theta_bar, w)
            new_state = ConsensusState(params=params, opt=opt, lam=lam,
                                       theta_bar=theta_bar)
        else:
            # one-step consensus: pods restart from the combined estimate
            params = jax.tree_util.tree_map(
                lambda tb, p: jnp.broadcast_to(tb[None], p.shape).astype(
                    p.dtype), theta_bar, params)
            new_state = ConsensusState(params=params, opt=opt,
                                       lam=state.lam, theta_bar=theta_bar)
        return new_state, metrics

    return round_step
