"""Single-replica training step: loss, grad accumulation over microbatches,
AdamW update. The distributed wrappers (sync data-parallel baseline and the
paper's pod-consensus trainer) build on this.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig
from repro.models import transformer as T
from repro.optim import adamw
from .loss import cross_entropy


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatch: int = 0          # 0 = no accumulation
    aux_weight: float = 0.01     # MoE load-balance loss weight
    remat: bool = True
    # Optional mesh: constrains each microbatch to stay batch-sharded over
    # the data axis. Without it the (accum, micro, ...) reshape lets the
    # SPMD partitioner drop to a partial batch sharding (observed: 2-way
    # instead of 16-way on llama3 train_4k, inflating activation
    # all-reduces ~8x).
    mesh: Any = None


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState


def init_state(cfg: ArchConfig, key: jax.Array) -> TrainState:
    params = T.model_init(cfg, key)
    return TrainState(params=params, opt=adamw.init(params))


def make_loss_fn(cfg: ArchConfig, tcfg: TrainConfig):
    def loss_fn(params, batch: Dict):
        logits, aux = T.forward(
            cfg, params, batch["tokens"],
            enc_frames=batch.get("enc_frames"),
            patch_embeds=batch.get("patch_embeds"),
            remat=tcfg.remat)
        ce, metrics = cross_entropy(logits, batch["labels"])
        metrics["aux"] = aux
        return ce + tcfg.aux_weight * aux, metrics
    return loss_fn


def grads_of(cfg: ArchConfig, tcfg: TrainConfig, params, batch: Dict):
    """Gradients with optional microbatch accumulation (lax.scan)."""
    loss_fn = make_loss_fn(cfg, tcfg)
    b = batch["tokens"].shape[0]
    mb = tcfg.microbatch or b
    n_micro = max(b // mb, 1)
    if n_micro == 1:
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    split = jax.tree_util.tree_map(
        lambda x: x.reshape(n_micro, mb, *x.shape[1:]), batch)
    if tcfg.mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        data_axes = tuple(n for n in ("pod", "data")
                          if n in tcfg.mesh.shape)
        ax = data_axes if len(data_axes) > 1 else data_axes[0]

        def constrain(x):
            sh = NamedSharding(
                tcfg.mesh, P(None, ax, *([None] * (x.ndim - 2))))
            return jax.lax.with_sharding_constraint(x, sh)

        split = jax.tree_util.tree_map(constrain, split)

    def body(acc, mbatch):
        (_, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mbatch)
        acc = jax.tree_util.tree_map(lambda a, b_: a + b_.astype(a.dtype),
                                     acc, g)
        return acc, metrics

    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    acc, metrics = jax.lax.scan(body, zeros, split)
    grads = jax.tree_util.tree_map(lambda g: g / n_micro, acc)
    metrics = jax.tree_util.tree_map(lambda m: m.mean(), metrics)
    return grads, metrics


def make_train_step(cfg: ArchConfig, ocfg: adamw.AdamWConfig,
                    tcfg: TrainConfig):
    """Plain synchronous train step (the paper's 'centralized' analogue)."""
    def train_step(state: TrainState, batch: Dict):
        grads, metrics = grads_of(cfg, tcfg, state.params, batch)
        new_params, new_opt = adamw.update(ocfg, grads, state.opt,
                                           state.params)
        return TrainState(new_params, new_opt), metrics
    return train_step
