"""Pallas TPU kernel: sliding-window flash attention (forward).

Serving-side hot spot: makes long_500k prefill/decode sub-quadratic for the
dense architectures and implements recurrentgemma's local-attention blocks.
Online-softmax accumulators (m, l, acc) live in VMEM scratch; each q block
visits only the (window + block) band of KV blocks, so HBM traffic is
O(S * window / BK) instead of O(S^2). GQA is handled in the index maps
(kv head = q head // group) — KV is never materially repeated.

TPU adaptation: band iteration is a static grid dimension with clamped
index maps (duplicated edge loads are masked), keeping the kernel free of
dynamic control flow the TPU lowering cannot pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 128
BK = 128
NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            window: int, seq: int, scale: float, wb: int):
    qi = pl.program_id(1)
    t = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kb_unclamped = qi * (BQ // BK) - wb + t
    kb = jnp.maximum(kb_unclamped, 0)
    qpos = qi * BQ + jax.lax.iota(jnp.int32, BQ)
    kpos = kb * BK + jax.lax.iota(jnp.int32, BK)

    s = jnp.dot(q_ref[...], k_ref[...].T,
                preferred_element_type=jnp.float32) * scale     # (BQ, BK)
    mask = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] < seq)
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    mask &= (kb_unclamped >= 0)          # drop duplicated clamp-edge loads
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p.astype(v_ref.dtype), v_ref[...],
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(t == nt - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[...] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "interpret"))
def swa_attention(q, k, v, *, window: int = 0, interpret: bool = True):
    """Causal (optionally sliding-window) attention.

    q: (B, S, H, D); k, v: (B, S, KH, D) with H % KH == 0. Returns
    (B, S, H, D). S is padded to BQ alignment internally.
    """
    b, s, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    pad_s = (-s) % BQ
    pad_d = (-d) % 128
    qp = jnp.pad(q, ((0, 0), (0, pad_s), (0, 0), (0, pad_d)))
    kp = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, pad_d)))
    vp = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, pad_d)))
    sp, dp = s + pad_s, d + pad_d
    # (B, S, H, D) -> (B*H, S, D) / (B*KH, S, D)
    qf = qp.transpose(0, 2, 1, 3).reshape(b * h, sp, dp)
    kf = kp.transpose(0, 2, 1, 3).reshape(b * kh, sp, dp)
    vf = vp.transpose(0, 2, 1, 3).reshape(b * kh, sp, dp)

    eff_w = window if window else sp
    wb = (eff_w + BK - 1) // BK
    nt = wb + BQ // BK                    # band blocks per q block
    grid = (b * h, sp // BQ, nt)

    def q_map(bh, qi, t):
        return (bh, qi, 0)

    def kv_map(bh, qi, t):
        kvh = (bh // h) * kh + (bh % h) // g
        kb = jnp.maximum(qi * (BQ // BK) - wb + t, 0)
        return (kvh, kb, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, window=window, seq=s,
                          scale=1.0 / np.sqrt(d), wb=wb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, BQ, dp), q_map),
            pl.BlockSpec((None, BK, dp), kv_map),
            pl.BlockSpec((None, BK, dp), kv_map),
        ],
        out_specs=pl.BlockSpec((None, BQ, dp), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, sp, dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BQ,), jnp.float32),
            pltpu.VMEM((BQ,), jnp.float32),
            pltpu.VMEM((BQ, dp), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, h, sp, dp).transpose(0, 2, 1, 3)
    return out[:, :s, :, :d]
