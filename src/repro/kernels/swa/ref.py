"""Pure-jnp oracle for the swa kernel (materialized-score attention)."""
import jax.numpy as jnp
import numpy as np


def swa_attention_ref(q, k, v, *, window: int = 0):
    b, s, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / np.sqrt(d)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -2.0e38)
    probs = jnp.exp(scores - scores.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype), v)
