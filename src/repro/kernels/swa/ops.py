"""Jit'd public wrapper for sliding-window flash attention.

The Pallas kernel is forward-only; ``swa_op`` wraps it in a custom_vjp
whose backward recomputes through the pure-jnp oracle (standard
flash-attention practice: recompute beats storing probs)."""
import functools

import jax

from .kernel import swa_attention
from .ref import swa_attention_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _swa_pallas(q, k, v, window):
    return swa_attention(q, k, v, window=window, interpret=False)


def _swa_fwd(q, k, v, window):
    return _swa_pallas(q, k, v, window), (q, k, v)


def _swa_bwd(window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: swa_attention_ref(q, k, v,
                                                       window=window),
                     q, k, v)
    return vjp(g)


_swa_pallas.defvjp(_swa_fwd, _swa_bwd)


def swa_op(q, k, v, *, window: int = 0, use_pallas=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return _swa_pallas(q, k, v, window)
    return swa_attention_ref(q, k, v, window=window)
