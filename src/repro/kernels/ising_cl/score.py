"""Backward-compat shim: the fused score kernel moved to the family-generic
:mod:`repro.kernels.cl` subsystem. Every public name keeps importing from
here; new code should import from ``repro.kernels.cl`` directly."""
from ..cl.score import (KERNEL_KINDS, cl_score, cl_score_channels_padded,
                        cl_score_padded, ising_cl_score,
                        ising_cl_score_padded)
from ..cl.kernel import BM, BN, BK, cl_score_channels

__all__ = [
    "KERNEL_KINDS", "cl_score", "cl_score_padded", "cl_score_channels",
    "cl_score_channels_padded", "ising_cl_score", "ising_cl_score_padded",
    "BM", "BN", "BK",
]
