"""Pallas TPU kernel: fused pseudo-likelihood score statistics.

Extends the masked conditional-logit matmul (``kernel.py``) to emit the
whole score pipeline of the paper's CL/PL estimators in ONE pass over X:

    eta = X @ (Theta * A) + b                 (masked MXU matmul)
    r   = dl/deta(eta, X)                     (VPU epilogue, per family)
    S   = r^T X / n                           (score Gram, second MXU dot)

The epilogue residual is **family-dispatched at trace time** via the static
``kind`` argument: ``"ising"`` uses the logistic score
``r = 2 X sigma(-2 X eta)`` and ``"gaussian"`` the linear-Gaussian score
``r = X - eta`` of the unit-conditional-variance Gaussian MRF
(:mod:`repro.core.families.gaussian`) — both single-channel families share
the identical masked-matmul + Gram pipeline, so they share the kernel.
Multi-channel families (Potts) fall back to the reference pseudo-score
(see :func:`repro.stream.online.pseudo_score`).

``r`` is the per-sample score residual every gradient statistic is built
from: column means of ``r`` are the singleton gradients of the average
pseudo-likelihood, ``S[i, j] + S[j, i]`` (for an edge (i, j)) the coupling
gradients, and ``r[:, i] * Z_i`` node i's per-sample CL score. Fusing the
epilogue and the Gram contraction means X is read from HBM once and eta
never round-trips.

Grid is (j, i, k): j tiles output columns (and S rows), i tiles samples,
k tiles the contraction. The X strip for the current sample tile is stashed
in VMEM during the k loop, so the S contraction re-reads it from on-chip
memory rather than HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM, BN, BK = 128, 128, 128

#: families whose score statistics the fused kernel can emit directly
KERNEL_KINDS = ("ising", "gaussian")


def _residual(kind: str, xj, eta):
    """Per-family score residual dl/deta — static (trace-time) dispatch."""
    if kind == "ising":
        return 2.0 * xj * jax.nn.sigmoid(-2.0 * xj * eta)
    if kind == "gaussian":
        return xj - eta
    raise ValueError(f"fused score kernel has no epilogue for {kind!r}; "
                     f"supported: {KERNEL_KINDS}")


def _kernel(x_ref, theta_ref, mask_ref, bias_ref,
            eta_ref, r_ref, s_ref, acc_ref, xstrip_ref, *, n: int,
            kind: str = "ising"):
    j = pl.program_id(0)
    i = pl.program_id(1)
    k = pl.program_id(2)
    ni = pl.num_programs(1)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((i == 0) & (k == 0))
    def _init_s():
        s_ref[...] = jnp.zeros_like(s_ref)

    # stash this sample-tile's X strip so the S contraction stays on-chip
    xstrip_ref[:, pl.ds(k * BK, BK)] = x_ref[...].astype(jnp.float32)
    masked = theta_ref[...] * mask_ref[...]          # VPU fuse, no HBM trip
    acc_ref[...] += jnp.dot(x_ref[...], masked,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        eta = acc_ref[...] + bias_ref[...].astype(jnp.float32)
        eta_ref[...] = eta.astype(eta_ref.dtype)
        xj = xstrip_ref[:, pl.ds(j * BN, BN)]        # X columns of this tile
        r = _residual(kind, xj, eta)
        r_ref[...] = r.astype(r_ref.dtype)
        s_ref[...] += jnp.dot(r.T, xstrip_ref[...],
                              preferred_element_type=jnp.float32)

    @pl.when((k == nk - 1) & (i == ni - 1))
    def _finish():
        s_ref[...] = s_ref[...] / n


@functools.partial(jax.jit, static_argnames=("interpret", "kind"))
def cl_score(x, theta, mask, bias, *, kind: str = "ising",
             interpret: bool = True):
    """(eta, r, S) = fused score statistics; see module docstring.

    x: (n, p); theta, mask: (p, p); bias: (p,). ``kind`` picks the
    family epilogue (one compiled kernel per kind). Returns eta, r of shape
    (n, p) in x.dtype and S of shape (p, p) in float32. interpret=True runs
    the kernel body in Python on CPU (validation); on TPU pass False.
    """
    if kind not in KERNEL_KINDS:
        raise ValueError(f"unsupported kernel kind {kind!r}")
    n, p = x.shape
    pad_n = (-n) % BM
    pad_p = (-p) % BK
    xp = jnp.pad(x, ((0, pad_n), (0, pad_p)))
    tp = jnp.pad(theta, ((0, pad_p), (0, pad_p)))
    mp = jnp.pad(mask, ((0, pad_p), (0, pad_p)))
    bp = jnp.pad(bias, (0, pad_p))[None, :]
    np_, pp = xp.shape

    grid = (pp // BN, np_ // BM, pp // BK)
    eta, r, s = pl.pallas_call(
        functools.partial(_kernel, n=n, kind=kind),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda j, i, k: (i, k)),
            pl.BlockSpec((BK, BN), lambda j, i, k: (k, j)),
            pl.BlockSpec((BK, BN), lambda j, i, k: (k, j)),
            pl.BlockSpec((1, BN), lambda j, i, k: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((BM, BN), lambda j, i, k: (i, j)),
            pl.BlockSpec((BM, BN), lambda j, i, k: (i, j)),
            pl.BlockSpec((BN, pp), lambda j, i, k: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, pp), x.dtype),
            jax.ShapeDtypeStruct((np_, pp), x.dtype),
            jax.ShapeDtypeStruct((pp, pp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((BM, BN), jnp.float32),
            pltpu.VMEM((BM, pp), jnp.float32),
        ],
        interpret=interpret,
    )(xp, tp, mp, bp)
    return eta[:n, :p], r[:n, :p], s[:p, :p]


def ising_cl_score(x, theta, mask, bias, *, interpret: bool = True):
    """Ising instance of :func:`cl_score` (seed-compatible entry point)."""
    return cl_score(x, theta, mask, bias, kind="ising", interpret=interpret)


def cl_score_padded(x_pad, theta, mask, bias, n_seen: int, *,
                    kind: str = "ising", interpret: bool = True):
    """Fused score statistics over a zero-padded streaming buffer.

    ``x_pad`` is a capacity-doubling sample buffer whose rows past ``n_seen``
    are all-zero padding. Zero rows contribute nothing to the score Gram
    (``S = r^T X`` and the padded X rows are zero), so the only correction
    needed is the Gram normalizer: the kernel divides by the buffer
    capacity, we rescale to the live sample count. Keeping the buffer shape
    fixed between capacity doublings means a growing stream re-uses one
    compiled kernel instead of one per sample count.

    Returns (eta, r, S) like :func:`cl_score`, with ``S`` normalized by
    ``n_seen``. For the Ising kind, rows of ``r`` past ``n_seen`` are
    guaranteed zero (``x = 0`` makes ``r = 2 x sigma(-2 x eta) = 0``); the
    Gaussian residual ``x - eta`` is ``-bias`` on padded rows, so consumers
    of per-sample residuals must slice ``r[:n_seen]`` (the singleton
    gradient assembly in :func:`repro.stream.online.pseudo_score` does).
    """
    eta, r, S = cl_score(x_pad, theta, mask, bias, kind=kind,
                         interpret=interpret)
    scale = x_pad.shape[0] / max(int(n_seen), 1)
    return eta, r, S * scale


def ising_cl_score_padded(x_pad, theta, mask, bias, n_seen: int, *,
                          interpret: bool = True):
    """Ising instance of :func:`cl_score_padded` (seed-compatible name)."""
    return cl_score_padded(x_pad, theta, mask, bias, n_seen, kind="ising",
                           interpret=interpret)
