"""Backward-compat shim: the masked conditional-logit matmul kernel moved
to :mod:`repro.kernels.cl.kernel` (it is the C = 1 instance of the
channelized ``cl_logits`` skeleton)."""
from ..cl.kernel import BM, BN, BK, cl_logits, ising_cl_logits

__all__ = ["ising_cl_logits", "cl_logits", "BM", "BN", "BK"]
