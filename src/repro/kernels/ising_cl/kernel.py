"""Pallas TPU kernel: fused Ising conditional-logit matmul.

Computes eta = X @ (Theta * A) + b without materializing the masked
coupling matrix (Theta * A) in HBM — the mask fuses into the MXU K-loop.
This is the inner-loop hot spot of every pseudo-likelihood evaluation
(paper Eq. 2): eta feeds log sigma(2 x_i eta_i) and all gradient statistics.

TPU adaptation (vs a CUDA port): tiles are MXU-aligned (128x128), the
accumulator lives in VMEM scratch across the K-grid dimension, and the mask
multiply happens on the VPU between the HBM->VMEM copy and the MXU dot —
zero extra HBM traffic for A.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM, BN, BK = 128, 128, 128


def _kernel(x_ref, theta_ref, mask_ref, bias_ref, out_ref, acc_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    masked = theta_ref[...] * mask_ref[...]          # VPU fuse, no HBM trip
    acc_ref[...] += jnp.dot(x_ref[...], masked,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        out_ref[...] = (acc_ref[...] +
                        bias_ref[...].astype(jnp.float32)
                        ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ising_cl_logits(x, theta, mask, bias, *, interpret: bool = True):
    """eta = x @ (theta * mask) + bias.

    x: (n, p); theta, mask: (p, p); bias: (p,). Shapes are padded to the
    128-aligned grid internally. interpret=True executes the kernel body in
    Python on CPU (validation mode); on TPU pass interpret=False.
    """
    n, p = x.shape
    pad_n = (-n) % BM
    pad_p = (-p) % BK
    xp = jnp.pad(x, ((0, pad_n), (0, pad_p)))
    tp = jnp.pad(theta, ((0, pad_p), (0, pad_p)))
    mp = jnp.pad(mask, ((0, pad_p), (0, pad_p)))
    bp = jnp.pad(bias, (0, pad_p))[None, :]
    np_, pp = xp.shape

    grid = (np_ // BM, pp // BN, pp // BK)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, k: (i, k)),
            pl.BlockSpec((BK, BN), lambda i, j, k: (k, j)),
            pl.BlockSpec((BK, BN), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, BN), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, pp), x.dtype),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=interpret,
    )(xp, tp, mp, bp)
    return out[:n, :p]
