"""Pure-jnp oracles for the ising_cl kernels."""
import jax
import jax.numpy as jnp


def ising_cl_logits_ref(x, theta, mask, bias):
    return (x @ (theta * mask) + bias[None, :]).astype(x.dtype)


def cl_score_ref(x, theta, mask, bias, kind: str = "ising"):
    """(eta, r, S): conditional logits, score residuals, score Gram.

    ``kind`` mirrors the fused kernel's family epilogue dispatch: "ising"
    logistic residual or "gaussian" linear residual.
    """
    eta = x.astype(jnp.float32) @ (theta * mask).astype(jnp.float32) \
        + bias[None, :].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    if kind == "ising":
        r = 2.0 * xf * jax.nn.sigmoid(-2.0 * xf * eta)
    elif kind == "gaussian":
        r = xf - eta
    else:
        raise ValueError(f"unknown score kind {kind!r}")
    s = r.T @ xf / x.shape[0]
    return eta.astype(x.dtype), r.astype(x.dtype), s


def ising_cl_score_ref(x, theta, mask, bias):
    """Ising instance of :func:`cl_score_ref` (seed-compatible name)."""
    return cl_score_ref(x, theta, mask, bias, kind="ising")
