"""Pure-jnp oracle for the ising_cl kernel."""
import jax.numpy as jnp


def ising_cl_logits_ref(x, theta, mask, bias):
    return (x @ (theta * mask) + bias[None, :]).astype(x.dtype)
