"""Backward-compat shim: the jnp kernel oracles moved to
:mod:`repro.kernels.cl.ref`."""
from ..cl.ref import (cl_score_channels_ref, cl_score_ref,
                      ising_cl_logits_ref, ising_cl_score_ref)

__all__ = [
    "cl_score_ref", "cl_score_channels_ref", "ising_cl_logits_ref",
    "ising_cl_score_ref",
]
