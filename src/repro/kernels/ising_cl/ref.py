"""Pure-jnp oracles for the ising_cl kernels."""
import jax
import jax.numpy as jnp


def ising_cl_logits_ref(x, theta, mask, bias):
    return (x @ (theta * mask) + bias[None, :]).astype(x.dtype)


def ising_cl_score_ref(x, theta, mask, bias):
    """(eta, r, S): conditional logits, score residuals, score Gram."""
    eta = x.astype(jnp.float32) @ (theta * mask).astype(jnp.float32) \
        + bias[None, :].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    r = 2.0 * xf * jax.nn.sigmoid(-2.0 * xf * eta)
    s = r.T @ xf / x.shape[0]
    return eta.astype(x.dtype), r.astype(x.dtype), s
