"""Jit'd public wrappers: pick the Pallas kernel on TPU, the pure-jnp
reference elsewhere (CPU dry-run / tests use interpret mode explicitly)."""
import jax

from .kernel import ising_cl_logits
from .ref import cl_score_ref, ising_cl_logits_ref
from .score import cl_score


def conditional_logits_op(x, theta, mask, bias, *, use_pallas=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return ising_cl_logits(x, theta, mask, bias, interpret=False)
    return ising_cl_logits_ref(x, theta, mask, bias)


def score_stats_op(x, theta, mask, bias, *, kind: str = "ising",
                   use_pallas=None):
    """Fused (eta, r, S) pseudo-likelihood score statistics.

    ``kind`` selects the family epilogue ("ising" or "gaussian"); both the
    Pallas kernel and the jnp reference dispatch on it.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return cl_score(x, theta, mask, bias, kind=kind, interpret=False)
    return cl_score_ref(x, theta, mask, bias, kind=kind)
