"""Backward-compat shim: backend-dispatching ops moved to
:mod:`repro.kernels.cl.ops`."""
from ..cl.ops import (conditional_logits_op, score_stats_channels_op,
                      score_stats_op)

__all__ = ["conditional_logits_op", "score_stats_op",
           "score_stats_channels_op"]
