"""Jit'd public wrapper: picks the Pallas kernel on TPU, the pure-jnp
reference elsewhere (CPU dry-run / tests use interpret mode explicitly)."""
import jax

from .kernel import ising_cl_logits
from .ref import ising_cl_logits_ref


def conditional_logits_op(x, theta, mask, bias, *, use_pallas=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return ising_cl_logits(x, theta, mask, bias, interpret=False)
    return ising_cl_logits_ref(x, theta, mask, bias)
