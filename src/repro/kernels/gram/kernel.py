"""Pallas TPU kernel: tiled Gram/covariance accumulation G = S^T S / n.

This is the empirical-Fisher / cross-estimator-covariance hot spot: the
paper's Vhat_alpha matrices (Prop 4.6 optimal weights) and Jhat Fisher
estimates are Gram matrices of per-sample influence statistics. The kernel
streams S (n, d) through VMEM in (BN, BD) tiles and accumulates d x d
outer products on the MXU; n never resides on-chip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BD = 128   # output tile side
BN = 512   # samples streamed per step


def _kernel(si_ref, sj_ref, out_ref, acc_ref, *, n: int):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(si_ref[...].T, sj_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        out_ref[...] = (acc_ref[...] / n).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gram(s, *, interpret: bool = True):
    """G = s^T s / n for s: (n, d) -> (d, d) float32."""
    n, d = s.shape
    pad_n = (-n) % BN
    pad_d = (-d) % BD
    sp = jnp.pad(s, ((0, pad_n), (0, pad_d)))
    np_, dp = sp.shape
    grid = (dp // BD, dp // BD, np_ // BN)
    out = pl.pallas_call(
        functools.partial(_kernel, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BN, BD), lambda i, j, k: (k, i)),
            pl.BlockSpec((BN, BD), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((BD, BD), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp, dp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BD, BD), jnp.float32)],
        interpret=interpret,
    )(sp, sp)
    return out[:d, :d]
