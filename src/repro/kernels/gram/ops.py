"""Jit'd public wrapper for Gram/Fisher accumulation."""
import jax

from .kernel import gram
from .ref import gram_ref


def gram_op(s, *, use_pallas=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return gram(s, interpret=False)
    return gram_ref(s)
