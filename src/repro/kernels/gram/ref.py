"""Pure-jnp oracle for the gram kernel."""
import jax.numpy as jnp


def gram_ref(s):
    n = s.shape[0]
    return (s.astype(jnp.float32).T @ s.astype(jnp.float32)) / n
