"""Family-generic fused conditional-likelihood (CL) kernel subsystem.

One channelized Pallas pipeline — load -> eta -> residual -> score/Gram
epilogue — shared by every registered exponential family, with the
per-family math isolated in a small :mod:`~repro.kernels.cl.epilogues`
registry keyed by ``ModelFamily.kernel_kind``:

* :mod:`.kernel` — the pallas_call skeleton (masked-matmul logits kernel and
  the channelized fused score kernel);
* :mod:`.epilogues` — the epilogue registry (ising / gaussian / potts ship);
* :mod:`.ref` — pure-jnp oracles for everything;
* :mod:`.newton` — the fused Newton-step entry point emitting score + Gram
  directly in the degree-bucket ``(k, C, d)`` layout ``core/batched.py``
  consumes;
* :mod:`.score` — seed-compatible single-channel entry points
  (``cl_score``, ``ising_cl_score``, padded-buffer variants) plus the
  channelized ``cl_score_channels``;
* :mod:`.family` — adapters from a :class:`ModelFamily` + graph + flat theta
  to kernel inputs, and the fused flat pseudo-score the streaming stack
  uses;
* :mod:`.ops` — backend dispatch (compiled Pallas on TPU, jnp reference
  elsewhere).

The old ``repro.kernels.ising_cl`` package remains as import shims.
"""
from .epilogues import (Epilogue, get_epilogue, register_epilogue,
                        registered_kinds)
from .kernel import cl_logits, cl_score_channels, ising_cl_logits
from .newton import bucket_newton_stats, bucket_newton_stats_ref
from .ops import (bucket_newton_stats_op, conditional_logits_op,
                  score_stats_channels_op, score_stats_op)
from .ref import (cl_logits_ref, cl_score_channels_ref, cl_score_ref,
                  ising_cl_logits_ref, ising_cl_score_ref)
from .score import (KERNEL_KINDS, cl_score, cl_score_channels_padded,
                    cl_score_padded, ising_cl_score, ising_cl_score_padded)
from .family import family_kernel_inputs, family_score_stats, fused_pseudo_score

__all__ = [
    "Epilogue", "register_epilogue", "get_epilogue", "registered_kinds",
    "cl_logits", "ising_cl_logits", "cl_score_channels",
    "cl_score", "cl_score_padded", "cl_score_channels_padded",
    "ising_cl_score", "ising_cl_score_padded", "KERNEL_KINDS",
    "cl_score_ref", "cl_score_channels_ref", "cl_logits_ref",
    "ising_cl_logits_ref", "ising_cl_score_ref",
    "bucket_newton_stats", "bucket_newton_stats_ref",
    "conditional_logits_op", "score_stats_op", "score_stats_channels_op",
    "bucket_newton_stats_op",
    "family_kernel_inputs", "family_score_stats", "fused_pseudo_score",
]
