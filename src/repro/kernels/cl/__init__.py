"""Family-generic fused conditional-likelihood (CL) kernel subsystem.

One channelized Pallas pipeline — load -> eta -> residual -> score/Gram
epilogue — shared by every registered exponential family, with the
per-family math isolated in a small :mod:`~repro.kernels.cl.epilogues`
registry keyed by ``ModelFamily.kernel_kind``:

* :mod:`.kernel` — the pallas_call skeleton (masked-matmul logits kernel and
  the channelized fused score kernel), tile-parametric with
  divisibility-safe edge padding;
* :mod:`.epilogues` — the epilogue registry (ising / gaussian / potts ship);
* :mod:`.ref` — pure-jnp oracles for everything;
* :mod:`.newton` — the fused Newton-step entry point emitting score + Gram
  directly in the degree-bucket ``(k, C, d)`` layout ``core/batched.py``
  consumes, with lane-aligned padding of the tiny ``d*C`` output axis;
* :mod:`.tiled` — XLA-compiled CPU twins of the fused kernels (Pallas is
  interpret-only on CPU); the compiled-CPU dispatch tier;
* :mod:`.autotune` — bounded tile-size search keyed by
  ``(op, backend, dtype, n, p, C)`` with deterministic in-process and
  on-disk JSON caches;
* :mod:`.precision` — the documented per-``Plan.precision`` conformance
  tolerances (float64 / float32 / mixed-precision bfloat16);
* :mod:`.score` — seed-compatible single-channel entry points
  (``cl_score``, ``ising_cl_score``, padded-buffer variants) plus the
  channelized ``cl_score_channels``;
* :mod:`.family` — adapters from a :class:`ModelFamily` + graph + flat theta
  to kernel inputs, and the fused flat pseudo-score the streaming stack
  uses;
* :mod:`.ops` — backend-aware dispatch (Mosaic on TPU/GPU, the compiled
  tiled twins on CPU, ref / interpret on request) with tuned tiles and
  trace-time telemetry tags.

The old ``repro.kernels.ising_cl`` package remains as import shims.
"""
from .autotune import (CHUNK_MIN_N, KERNEL_OPS, TileConfig, cache_snapshot,
                       candidate_tiles, clear_cache, get_tiles, load_cache,
                       save_cache, search_tiles, tile_key,
                       validate_tile_config)
from .epilogues import (Epilogue, get_epilogue, register_epilogue,
                        registered_kinds)
from .kernel import cl_logits, cl_score_channels, ising_cl_logits
from .newton import (bucket_newton_stats, bucket_newton_stats_ref,
                     lane_padded_width)
from .ops import (KERNEL_PATHS, bucket_newton_stats_op, conditional_logits_op,
                  default_kernel_path, resolve_kernel_path,
                  score_stats_channels_op, score_stats_op)
from .precision import PRECISION_TOLERANCES, precision_tolerance
from .ref import (cl_logits_ref, cl_score_channels_ref, cl_score_ref,
                  ising_cl_logits_ref, ising_cl_score_ref)
from .score import (KERNEL_KINDS, cl_score, cl_score_channels_padded,
                    cl_score_padded, ising_cl_score, ising_cl_score_padded)
from .tiled import bucket_newton_stats_tiled, cl_score_channels_tiled
from .family import family_kernel_inputs, family_score_stats, fused_pseudo_score

__all__ = [
    "Epilogue", "register_epilogue", "get_epilogue", "registered_kinds",
    "cl_logits", "ising_cl_logits", "cl_score_channels",
    "cl_score", "cl_score_padded", "cl_score_channels_padded",
    "ising_cl_score", "ising_cl_score_padded", "KERNEL_KINDS",
    "cl_score_ref", "cl_score_channels_ref", "cl_logits_ref",
    "ising_cl_logits_ref", "ising_cl_score_ref",
    "bucket_newton_stats", "bucket_newton_stats_ref", "lane_padded_width",
    "cl_score_channels_tiled", "bucket_newton_stats_tiled",
    "conditional_logits_op", "score_stats_op", "score_stats_channels_op",
    "bucket_newton_stats_op", "KERNEL_PATHS", "default_kernel_path",
    "resolve_kernel_path",
    "TileConfig", "KERNEL_OPS", "CHUNK_MIN_N", "get_tiles", "search_tiles",
    "candidate_tiles", "validate_tile_config", "tile_key", "save_cache",
    "load_cache", "clear_cache", "cache_snapshot",
    "PRECISION_TOLERANCES", "precision_tolerance",
    "family_kernel_inputs", "family_score_stats", "fused_pseudo_score",
]
