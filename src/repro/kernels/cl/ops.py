"""Backend-aware dispatch for the fused CL kernels.

Four paths, one resolver:

* ``"mosaic"``    — the compiled Pallas kernel (TPU/GPU only; Pallas cannot
  compile on the CPU backend).
* ``"tiled"``     — the XLA-compiled CPU twins (:mod:`.tiled`): same tiling
  idea as the Pallas kernels, compiled through plain jit. The default off
  TPU/GPU.
* ``"ref"``       — the plain jnp reference (:mod:`.ref`).
* ``"interpret"`` — the Pallas kernel body run in Python (validation only;
  orders of magnitude slower than everything else).

Tile sizes come from the autotuner (:func:`.autotune.get_tiles`): cached
tuned tiles when a search ran, deterministic shape heuristics otherwise.

Every dispatcher tags the innermost active telemetry recorder (see
:func:`repro.telemetry.record_kernel_trace`) with the kernel kind, the
*resolved path* (``backend=`` tag), and the operand shape. The calls run
at trace time — inside jit they fire once per compiled shape, so a
telemetry log shows exactly which kernels compiled for which shapes, at
zero steady-state cost; with telemetry off the hook is a falsy list check.

Back-compat: callers keep passing ``use_pallas`` (None = backend default,
True = the Pallas kernel, False = the jnp reference). ``interpret=True``
with ``use_pallas=True`` — the historical CPU validation spelling — still
means interpret mode.
"""
from typing import Optional

import jax

from ...telemetry.recorder import record_kernel_trace
from .autotune import TileConfig, get_tiles
from .kernel import cl_score_channels, ising_cl_logits
from .newton import bucket_newton_stats, bucket_newton_stats_ref
from .ref import cl_score_channels_ref, cl_score_ref, ising_cl_logits_ref
from .score import cl_score
from .tiled import bucket_newton_stats_tiled, cl_score_channels_tiled

#: the resolved dispatch paths, as recorded in telemetry ``backend=`` tags.
KERNEL_PATHS = ("mosaic", "tiled", "ref", "interpret")


def default_kernel_path(backend: Optional[str] = None) -> str:
    """The path picked when callers don't force one: compiled everywhere —
    Mosaic on TPU/GPU, the XLA-compiled tiled twins elsewhere."""
    backend = backend or jax.default_backend()
    return "mosaic" if backend in ("tpu", "gpu") else "tiled"


def resolve_kernel_path(use_pallas=None, interpret: Optional[bool] = None,
                        backend: Optional[str] = None) -> str:
    """Map the (use_pallas, interpret) caller knobs onto one path name.

    ``use_pallas=None`` → the backend default (:func:`default_kernel_path`);
    ``False`` → ``"ref"``; ``True`` → the Pallas kernel — ``"mosaic"`` where
    it compiles, ``"interpret"`` on CPU or when ``interpret=True`` asks for
    the validation mode explicitly.
    """
    backend = backend or jax.default_backend()
    if use_pallas is None:
        return default_kernel_path(backend)
    if not use_pallas:
        return "ref"
    if interpret or (interpret is None and backend not in ("tpu", "gpu")):
        return "interpret"
    return "mosaic"


def _tiles_for(op: str, path: str, *, n: int, p: int, C: int,
               dtype) -> Optional[TileConfig]:
    """Tuned/heuristic tiles for the executing path (None for ref)."""
    if path == "mosaic":
        return get_tiles(op, n=n, p=p, C=C, backend=jax.default_backend(),
                         dtype=str(dtype))
    if path == "tiled":
        return get_tiles(op, n=n, p=p, C=C, backend="cpu", dtype=str(dtype))
    return None


def conditional_logits_op(x, theta, mask, bias, *, use_pallas=None,
                          interpret: Optional[bool] = None):
    path = resolve_kernel_path(use_pallas, interpret)
    if path == "tiled":
        path = "ref"  # logits have no fused tiled twin; ref IS compiled jnp
    record_kernel_trace("kernel.conditional_logits", backend=path,
                        shape=tuple(x.shape))
    if path == "mosaic":
        return ising_cl_logits(x, theta, mask, bias, interpret=False)
    if path == "interpret":
        return ising_cl_logits(x, theta, mask, bias, interpret=True)
    return ising_cl_logits_ref(x, theta, mask, bias)


def score_stats_op(x, theta, mask, bias, *, kind: str = "ising",
                   use_pallas=None, interpret: Optional[bool] = None):
    """Fused (eta, r, S) pseudo-likelihood score statistics, single-channel.

    ``kind`` selects the family epilogue; every path dispatches through the
    same registry. Safe inside jit — the path choice is a trace-time
    constant.
    """
    path = resolve_kernel_path(use_pallas, interpret)
    record_kernel_trace("kernel.score_stats", kind=kind, backend=path,
                        shape=tuple(x.shape))
    n, p = x.shape
    if path == "mosaic":
        tiles = _tiles_for("score", path, n=n, p=p, C=1, dtype=x.dtype)
        return cl_score(x, theta, mask, bias, kind=kind, interpret=False,
                        tiles=tiles)
    if path == "interpret":
        return cl_score(x, theta, mask, bias, kind=kind, interpret=True)
    if path == "tiled":
        tiles = _tiles_for("score", path, n=n, p=p, C=1, dtype=x.dtype)
        if tiles.bm is not None and tiles.bm < n:
            eta, r, S = cl_score_channels_tiled(
                x[None], theta[None], mask, bias[None], kind=kind,
                chunk=tiles.bm)
            return eta[0], r[0], S[0, 0]
        # whole-axis tiled == the reference contraction, bit-identical
    return cl_score_ref(x, theta, mask, bias, kind=kind)


def score_stats_channels_op(F, theta, mask, bias, *, kind: str,
                            use_pallas=None,
                            interpret: Optional[bool] = None):
    """Channelized fused (eta, r, S) — the multi-channel twin of
    :func:`score_stats_op`."""
    path = resolve_kernel_path(use_pallas, interpret)
    record_kernel_trace("kernel.score_stats_channels", kind=kind,
                        backend=path, shape=tuple(F.shape))
    C, n, p = F.shape
    if path == "mosaic":
        tiles = _tiles_for("score", path, n=n, p=p, C=C, dtype=F.dtype)
        return cl_score_channels(F, theta, mask, bias, kind=kind,
                                 interpret=False, tiles=tiles)
    if path == "interpret":
        return cl_score_channels(F, theta, mask, bias, kind=kind,
                                 interpret=True)
    if path == "tiled":
        tiles = _tiles_for("score", path, n=n, p=p, C=C, dtype=F.dtype)
        if tiles.bm is not None and tiles.bm < n:
            return cl_score_channels_tiled(F, theta, mask, bias, kind=kind,
                                           chunk=tiles.bm)
        # whole-axis tiled == the reference contraction, bit-identical
    return cl_score_channels_ref(F, theta, mask, bias, kind=kind)


def bucket_newton_stats_op(kind, Zb, base, xi, W, sw=None, *,
                           use_pallas=None,
                           interpret: Optional[bool] = None):
    """Fused bucket Newton statistics (g, K), backend-aware.

    Mosaic on TPU/GPU (lane-padded via the autotuner's tiles), the
    XLA-compiled chunked twin on CPU, plain ref / interpret on request.
    Safe to call inside a jit trace — the path choice is a trace-time
    constant.
    """
    path = resolve_kernel_path(use_pallas, interpret)
    record_kernel_trace("kernel.bucket_newton_stats", kind=kind,
                        backend=path, shape=tuple(Zb.shape))
    k, C, d, n = Zb.shape
    if path == "mosaic":
        tiles = _tiles_for("newton", path, n=n, p=d, C=C, dtype=Zb.dtype)
        return bucket_newton_stats(kind, Zb, base, xi, W, sw,
                                   interpret=False, tiles=tiles)
    if path == "interpret":
        return bucket_newton_stats(kind, Zb, base, xi, W, sw,
                                   interpret=True)
    if path == "tiled":
        tiles = _tiles_for("newton", path, n=n, p=d, C=C, dtype=Zb.dtype)
        if tiles.bm is not None and tiles.bm < n:
            return bucket_newton_stats_tiled(kind, Zb, base, xi, W, sw,
                                             chunk=tiles.bm)
        # whole-axis tiled == the reference contraction, bit-identical
    return bucket_newton_stats_ref(kind, Zb, base, xi, W, sw)
