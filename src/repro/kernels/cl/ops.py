"""Jit'd public wrappers: pick the compiled Pallas kernel on TPU, the
pure-jnp reference elsewhere (CPU dry-runs / tests use interpret mode
explicitly).

Every dispatcher tags the innermost active telemetry recorder (see
:func:`repro.telemetry.record_kernel_trace`) with the kernel kind, the
chosen backend, and the operand shape. The calls run at *trace time* —
inside jit they fire once per compiled shape, so a telemetry log shows
exactly which kernels compiled for which shapes, at zero steady-state
cost; with telemetry off the hook is a single falsy list check.
"""
import jax

from ...telemetry.recorder import record_kernel_trace
from .kernel import cl_score_channels, ising_cl_logits
from .newton import bucket_newton_stats, bucket_newton_stats_ref
from .ref import cl_score_channels_ref, cl_score_ref, ising_cl_logits_ref
from .score import cl_score


def _backend_tag(use_pallas: bool) -> str:
    return "pallas" if use_pallas else "jnp_ref"


def conditional_logits_op(x, theta, mask, bias, *, use_pallas=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    record_kernel_trace("kernel.conditional_logits",
                        backend=_backend_tag(use_pallas),
                        shape=tuple(x.shape))
    if use_pallas:
        return ising_cl_logits(x, theta, mask, bias, interpret=False)
    return ising_cl_logits_ref(x, theta, mask, bias)


def score_stats_op(x, theta, mask, bias, *, kind: str = "ising",
                   use_pallas=None):
    """Fused (eta, r, S) pseudo-likelihood score statistics, single-channel.

    ``kind`` selects the family epilogue; both the Pallas kernel and the
    jnp reference dispatch through the same registry.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    record_kernel_trace("kernel.score_stats", kind=kind,
                        backend=_backend_tag(use_pallas),
                        shape=tuple(x.shape))
    if use_pallas:
        return cl_score(x, theta, mask, bias, kind=kind, interpret=False)
    return cl_score_ref(x, theta, mask, bias, kind=kind)


def score_stats_channels_op(F, theta, mask, bias, *, kind: str,
                            use_pallas=None):
    """Channelized fused (eta, r, S) — the multi-channel twin of
    :func:`score_stats_op`."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    record_kernel_trace("kernel.score_stats_channels", kind=kind,
                        backend=_backend_tag(use_pallas),
                        shape=tuple(F.shape))
    if use_pallas:
        return cl_score_channels(F, theta, mask, bias, kind=kind,
                                 interpret=False)
    return cl_score_channels_ref(F, theta, mask, bias, kind=kind)


def bucket_newton_stats_op(kind, Zb, base, xi, W, sw=None, *,
                           use_pallas=None):
    """Fused bucket Newton statistics (g, K); Pallas on TPU, jnp ref
    elsewhere. Safe to call inside a jit trace — the backend choice is a
    trace-time constant."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    record_kernel_trace("kernel.bucket_newton_stats", kind=kind,
                        backend=_backend_tag(use_pallas),
                        shape=tuple(Zb.shape))
    if use_pallas:
        return bucket_newton_stats(kind, Zb, base, xi, W, sw,
                                   interpret=False)
    return bucket_newton_stats_ref(kind, Zb, base, xi, W, sw)
