"""Pure-jnp oracles for the family-generic CL kernels."""
import jax.numpy as jnp

from .epilogues import require_epilogue


def cl_logits_ref(F, theta, mask, bias):
    """Channelized masked logits: (C, n, p) inputs like :func:`cl_logits`."""
    return (jnp.einsum("cnj,cji->cni", F, theta * mask[None])
            + bias[:, None, :]).astype(F.dtype)


def ising_cl_logits_ref(x, theta, mask, bias):
    return (x @ (theta * mask) + bias[None, :]).astype(x.dtype)


def cl_score_channels_ref(F, theta, mask, bias, kind: str):
    """(eta, r, S): channelized logits, residuals, cross-channel score Gram.

    Mirrors :func:`repro.kernels.cl.kernel.cl_score_channels` — same
    shapes, same family epilogue registry — in plain jnp.
    """
    ep = require_epilogue(kind)
    Ff = F.astype(jnp.float32)
    eta = jnp.einsum("cnj,cji->cni", Ff,
                     (theta * mask[None]).astype(jnp.float32)) \
        + bias[:, None, :].astype(jnp.float32)
    r = ep.residual(Ff, eta)
    s = jnp.einsum("cni,enj->ceij", r, Ff) / F.shape[1]
    return eta.astype(F.dtype), r.astype(F.dtype), s


def cl_score_ref(x, theta, mask, bias, kind: str = "ising"):
    """(eta, r, S): conditional logits, score residuals, score Gram —
    the single-channel (n, p) entry.

    ``kind`` mirrors the fused kernel's family epilogue dispatch; kinds
    whose epilogue is multi-channel (Potts) need
    :func:`cl_score_channels_ref`.
    """
    ep = require_epilogue(kind)
    if ep.channels != "single":
        raise ValueError(
            f"kind {kind!r} is multi-channel; use cl_score_channels_ref")
    eta = x.astype(jnp.float32) @ (theta * mask).astype(jnp.float32) \
        + bias[None, :].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    r = ep.residual(xf[None], eta[None])[0]
    s = r.T @ xf / x.shape[0]
    return eta.astype(x.dtype), r.astype(x.dtype), s


def ising_cl_score_ref(x, theta, mask, bias):
    """Ising instance of :func:`cl_score_ref` (seed-compatible name)."""
    return cl_score_ref(x, theta, mask, bias, kind="ising")
