"""Seed-compatible fused score entry points over the channelized skeleton.

:func:`cl_score` keeps the original single-channel ``(n, p)`` signature the
Ising/Gaussian callers (and the seed tests) use; it is the C = 1 instance
of :func:`repro.kernels.cl.kernel.cl_score_channels`. Multi-channel kinds
(Potts) are rejected here with a pointer to the channelized entry —
:func:`repro.kernels.cl.family.family_score_stats` builds the channelized
inputs from a :class:`ModelFamily` directly.

``cl_score_padded`` / ``cl_score_channels_padded`` are the streaming-buffer
variants: zero-padded rows beyond ``n_seen`` are invisible to the score
Gram for every registered kind (padded feature rows are zero — for Potts
because state 0 is the reference state with an all-zero indicator row), so
only the Gram normalizer needs rescaling from buffer capacity to the live
sample count. Keeping the buffer shape fixed between capacity doublings
means a growing stream re-uses one compiled kernel per capacity.
"""
from __future__ import annotations

from typing import Optional

from .epilogues import registered_kinds, require_epilogue
from .kernel import cl_score_channels


#: families with a registered fused-kernel epilogue (seed-compatible name —
#: the seed tuple ("ising", "gaussian") grew a "potts" entry when the
#: multi-channel epilogue landed). NOTE: an import-time snapshot for
#: seed compatibility only — epilogues registered later won't appear here;
#: live checks must use ``registered_kinds()`` / ``get_epilogue()``.
KERNEL_KINDS = registered_kinds()


def cl_score(x, theta, mask, bias, *, kind: str = "ising",
             interpret: Optional[bool] = None, tiles=None):
    """(eta, r, S) = fused single-channel score statistics.

    x: (n, p); theta, mask: (p, p); bias: (p,). ``kind`` picks the family
    epilogue (one compiled kernel per kind); multi-channel kinds raise —
    use :func:`cl_score_channels` / ``family_score_stats`` for those.
    Returns eta, r of shape (n, p) in x.dtype and S of shape (p, p) in
    float32. ``interpret=None`` derives from the backend (compiled on
    TPU/GPU, interpret mode — Python-speed validation — elsewhere);
    ``tiles`` is an optional autotuner :class:`TileConfig`.
    """
    ep = require_epilogue(kind)
    if ep.channels != "single":
        raise ValueError(
            f"kind {kind!r} is multi-channel (C > 1); use cl_score_channels "
            f"with (C, n, p) inputs — see repro.kernels.cl.family")
    eta, r, S = cl_score_channels(x[None], theta[None], mask, bias[None],
                                  kind=kind, interpret=interpret,
                                  tiles=tiles)
    return eta[0], r[0], S[0, 0]


def ising_cl_score(x, theta, mask, bias, *,
                   interpret: Optional[bool] = None):
    """Ising instance of :func:`cl_score` (seed-compatible entry point)."""
    return cl_score(x, theta, mask, bias, kind="ising", interpret=interpret)


def cl_score_padded(x_pad, theta, mask, bias, n_seen: int, *,
                    kind: str = "ising",
                    interpret: Optional[bool] = None):
    """Fused score statistics over a zero-padded streaming buffer.

    ``x_pad`` is a capacity-doubling sample buffer whose rows past ``n_seen``
    are all-zero padding. Zero rows contribute nothing to the score Gram
    (``S = r^T X`` and the padded X rows are zero), so the only correction
    needed is the Gram normalizer: the kernel divides by the buffer
    capacity, we rescale to the live sample count.

    Returns (eta, r, S) like :func:`cl_score`, with ``S`` normalized by
    ``n_seen``. For the Ising kind, rows of ``r`` past ``n_seen`` are
    guaranteed zero (``x = 0`` makes ``r = 2 x sigma(-2 x eta) = 0``); the
    Gaussian residual ``x - eta`` is ``-bias`` on padded rows, so consumers
    of per-sample residuals must slice ``r[:n_seen]`` (the singleton
    gradient assembly in :func:`repro.stream.online.pseudo_score` does).
    """
    eta, r, S = cl_score(x_pad, theta, mask, bias, kind=kind,
                         interpret=interpret)
    scale = x_pad.shape[0] / max(int(n_seen), 1)
    return eta, r, S * scale


def ising_cl_score_padded(x_pad, theta, mask, bias, n_seen: int, *,
                          interpret: Optional[bool] = None):
    """Ising instance of :func:`cl_score_padded` (seed-compatible name)."""
    return cl_score_padded(x_pad, theta, mask, bias, n_seen, kind="ising",
                           interpret=interpret)


def cl_score_channels_padded(F_pad, theta, mask, bias, n_seen: int, *,
                             kind: str, interpret: Optional[bool] = None):
    """Channelized :func:`cl_score_padded`: F_pad is (C, capacity, p) with
    all-zero feature rows past ``n_seen`` (for Potts, zero-padded raw rows
    ARE the all-zero reference-state indicator rows). S is renormalized to
    the live count; per-sample consumers must slice ``r[:, :n_seen]``.
    """
    eta, r, S = cl_score_channels(F_pad, theta, mask, bias, kind=kind,
                                  interpret=interpret)
    scale = F_pad.shape[1] / max(int(n_seen), 1)
    return eta, r, S * scale
