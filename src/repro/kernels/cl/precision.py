"""Per-precision conformance tolerances for the fused CL kernels.

``Plan.precision`` picks the dtype every design tensor is cast to before
it reaches the kernels. float64/float32 run the whole pipeline — loads,
matmuls, Gram accumulation — in that dtype (float64 requires
``jax_enable_x64``). ``"bfloat16"`` is the mixed-precision mode: designs
and feature loads are bf16, but every contraction against the float32
solver state promotes to float32 under jnp's type promotion, so the
score/curvature Gram *accumulators are always float32* — bf16 trims
memory traffic and matmul width, never the reduction dtype.

The table below is the documented fused-vs-ref gate each precision must
pass in the conformance harness (max-abs error of the fused kernel
against the float32 jnp reference on the standard conformance shapes):

==========  =========  =====================================================
precision   tolerance  why
==========  =========  =====================================================
float64     1e-10      golden-pinned; bit-stable contraction order
float32     1e-5       float32 reduction jitter across contraction orders
bfloat16    5e-2       8-bit mantissa loads; accumulation still float32, so
                       the error is load-quantization, not drift
==========  =========  =====================================================
"""
from __future__ import annotations

__all__ = ["PRECISION_TOLERANCES", "precision_tolerance"]

#: max-abs fused-vs-ref tolerance per Plan.precision (see module docstring).
PRECISION_TOLERANCES = {
    "float64": 1e-10,
    "float32": 1e-5,
    "bfloat16": 5e-2,
}


def precision_tolerance(precision: str) -> float:
    """The documented conformance tolerance for one ``Plan.precision``."""
    try:
        return PRECISION_TOLERANCES[precision]
    except KeyError:
        raise ValueError(
            f"no documented tolerance for precision {precision!r}; known: "
            f"{tuple(PRECISION_TOLERANCES)}") from None
