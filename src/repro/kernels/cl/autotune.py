"""Bounded tile-size autotuner for the fused CL kernels.

The fused score kernel (:mod:`.kernel`) and the bucket Newton kernel
(:mod:`.newton`) take a :class:`TileConfig` of trace-time tile sizes; the
compiled-CPU twins (:mod:`.tiled`) take a sample-chunk size. Which tiles
win depends on the operand shape and the backend, so the dispatch layer
(:mod:`.ops`) asks this module instead of hardcoding constants:

* :func:`get_tiles` — the *cheap, deterministic* entry safe to call at jit
  trace time: in-process cache -> optional on-disk JSON cache -> shape
  heuristic. Never times anything, and a given key always resolves to the
  same config within a process (the config is cached on first resolution),
  so repeated traces of one shape compile one program.
* :func:`search_tiles` — the *measured* entry the benchmarks use: times a
  bounded candidate list (:func:`candidate_tiles`) through a caller-provided
  ``measure`` callable and caches the argmin under the same key, so later
  :func:`get_tiles` calls pick the tuned tiles transparently.

Keys are ``(op, backend, dtype, n, p, C)`` — ``op`` is ``"score"`` or
``"newton"``, ``p`` doubles as the bucket design width ``d`` for the
newton op. The cache round-trips through JSON (:func:`save_cache` /
:func:`load_cache`); setting ``REPRO_CL_TUNE_CACHE=/path.json`` loads that
file lazily on first lookup and appends every search result to it.

Search is bounded by construction: candidate lists are a handful of
lane-friendly configs per (op, backend), every candidate is validated by
:func:`validate_tile_config` before it is timed, and ties break toward the
earliest candidate so two same-key searches agree bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax

__all__ = [
    "TileConfig", "KERNEL_OPS", "validate_tile_config", "candidate_tiles",
    "get_tiles", "search_tiles", "save_cache", "load_cache", "clear_cache",
    "cache_snapshot", "tile_key", "CHUNK_MIN_N",
]

#: ops the tuner knows; "score" = the fused (eta, r, S) score pipeline,
#: "newton" = the fused bucket Newton statistics (g, K).
KERNEL_OPS = ("score", "newton")

#: below this many samples the compiled-CPU heuristic never chunks: the
#: whole-axis path is *exactly* the jnp reference contraction (bit-stable
#: with the 1e-10 golden fixtures), and measured chunking only wins once
#: the sample axis outgrows cache (see BENCH_kernels.json newton rows).
CHUNK_MIN_N = 16384

_ENV_CACHE = "REPRO_CL_TUNE_CACHE"


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One tile assignment, hashable so it rides as a static jit argument.

    bm : sample-axis tile. For the Pallas kernels this is the per-grid-step
        sample block; for the compiled-CPU twins it is the scan chunk.
        ``None`` means "whole axis" — no chunking, reference contraction
        order.
    bn : output-column tile of the score kernel (ignored by newton).
    bk : contraction tile of the score kernel (ignored by newton).
    lane : target lane width the newton kernel pads its tiny ``d*C`` output
        axis up to (``None`` = no padding; the Mosaic path wants 128).
    """

    bm: Optional[int] = 128
    bn: int = 128
    bk: int = 128
    lane: Optional[int] = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TileConfig":
        return cls(bm=d.get("bm"), bn=int(d.get("bn", 128)),
                   bk=int(d.get("bk", 128)), lane=d.get("lane"))


def _is_pow2(v: int) -> bool:
    return v >= 1 and (v & (v - 1)) == 0


def validate_tile_config(cfg: TileConfig, op: str,
                         compiled: bool = False) -> TileConfig:
    """Reject tile configs the kernels cannot run; returns ``cfg``.

    ``compiled=True`` applies the Mosaic (real-TPU) constraints on top of
    the structural ones: 128-multiple lane tiles and an explicit (8-aligned)
    sample tile. Interpret mode and the compiled-CPU twins only need
    positive sizes.
    """
    if op not in KERNEL_OPS:
        raise ValueError(f"unknown kernel op {op!r}; choose from "
                         f"{KERNEL_OPS}")
    if not isinstance(cfg, TileConfig):
        raise ValueError(f"expected a TileConfig, got "
                         f"{type(cfg).__name__}")
    if cfg.bm is not None and (not isinstance(cfg.bm, int) or cfg.bm < 1):
        raise ValueError(f"bm must be a positive int or None, got "
                         f"{cfg.bm!r}")
    for name, v in (("bn", cfg.bn), ("bk", cfg.bk)):
        if not isinstance(v, int) or v < 1:
            raise ValueError(f"{name} must be a positive int, got {v!r}")
    if cfg.lane is not None and (
            not isinstance(cfg.lane, int) or not _is_pow2(cfg.lane)
            or not 8 <= cfg.lane <= 1024):
        raise ValueError(f"lane must be a power of two in [8, 1024] or "
                         f"None, got {cfg.lane!r}")
    if compiled:
        if cfg.bm is None or cfg.bm % 8:
            raise ValueError(
                f"compiled Pallas path needs an explicit 8-aligned sample "
                f"tile, got bm={cfg.bm!r}")
        if op == "score" and (cfg.bn % 128 or cfg.bk % 128):
            raise ValueError(
                f"compiled score kernel needs 128-multiple lane tiles, got "
                f"bn={cfg.bn} bk={cfg.bk}")
        if op == "newton" and (cfg.lane is None or cfg.lane % 128):
            raise ValueError(
                f"compiled newton kernel needs a 128-multiple lane target, "
                f"got lane={cfg.lane!r}")
    return cfg


def tile_key(op: str, *, n: int, p: int, C: int,
             backend: Optional[str] = None, dtype: str = "float32") -> str:
    """The canonical cache key string for one (op, shape, backend, dtype)."""
    if op not in KERNEL_OPS:
        raise ValueError(f"unknown kernel op {op!r}; choose from "
                         f"{KERNEL_OPS}")
    backend = backend or jax.default_backend()
    return f"{op}|{backend}|{dtype}|n={int(n)}|p={int(p)}|C={int(C)}"


# ------------------------------------------------------------------ caches
_LOCK = threading.Lock()
_CACHE: Dict[str, TileConfig] = {}
_ENV_LOADED = False


def clear_cache() -> None:
    """Drop every in-process entry (and forget the lazy env-file load)."""
    global _ENV_LOADED
    with _LOCK:
        _CACHE.clear()
        _ENV_LOADED = False


def cache_snapshot() -> Dict[str, TileConfig]:
    """A copy of the current in-process cache (for tests / diagnostics)."""
    with _LOCK:
        return dict(_CACHE)


def save_cache(path: str) -> str:
    """Write the in-process cache to ``path`` as JSON; returns ``path``."""
    with _LOCK:
        payload = {"version": 1,
                   "entries": {k: v.to_dict() for k, v in _CACHE.items()}}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def load_cache(path: str) -> int:
    """Merge a :func:`save_cache` file into the in-process cache.

    Existing in-process entries win (they may be fresher searches). Returns
    the number of entries adopted from disk. Unknown file versions raise —
    a layout this reader predates must not silently misconfigure kernels.
    """
    with open(path) as f:
        payload = json.load(f)
    version = payload.get("version")
    if version != 1:
        raise ValueError(f"{path}: tune-cache version {version!r} unknown "
                         f"to this reader (understands 1)")
    adopted = 0
    with _LOCK:
        for key, d in payload.get("entries", {}).items():
            if key not in _CACHE:
                _CACHE[key] = TileConfig.from_dict(d)
                adopted += 1
    return adopted


def _load_env_cache() -> None:
    global _ENV_LOADED
    if _ENV_LOADED:
        return
    _ENV_LOADED = True
    path = os.environ.get(_ENV_CACHE)
    if path and os.path.exists(path):
        try:
            load_cache(path)
        except (ValueError, OSError, json.JSONDecodeError):
            pass  # a corrupt cache must never break dispatch


# -------------------------------------------------------------- heuristics
def _default_tiles(op: str, *, n: int, p: int, C: int,
                   backend: str) -> TileConfig:
    """Shape heuristic used when nothing tuned is cached. Deterministic.

    TPU/GPU: MXU-aligned 128s everywhere, lane-pad the newton output axis.
    CPU: the compiled-CPU twins — whole-axis (== the jnp reference
    contraction, golden-bit-stable) below :data:`CHUNK_MIN_N` samples,
    cache-sized 1024-sample chunks above it.
    """
    if backend in ("tpu", "gpu"):
        return TileConfig(bm=128, bn=128, bk=128,
                          lane=128 if op == "newton" else None)
    if op == "newton" and n >= CHUNK_MIN_N:
        return TileConfig(bm=1024, lane=None)
    return TileConfig(bm=None, lane=None)


def candidate_tiles(op: str, *, n: int, p: int, C: int,
                    backend: Optional[str] = None) -> Tuple[TileConfig, ...]:
    """The bounded search space for one key, heuristic default first.

    Small by design — the tuner is a measured tiebreak between a handful of
    lane-friendly configs, not a general scheduler. Candidates whose chunk
    would exceed the sample axis are dropped (they alias the whole-axis
    config).
    """
    backend = backend or jax.default_backend()
    default = _default_tiles(op, n=n, p=p, C=C, backend=backend)
    if backend in ("tpu", "gpu"):
        if op == "newton":
            cands = [default] + [TileConfig(bm=bm, lane=128)
                                 for bm in (256, 512)]
        else:
            cands = [default,
                     TileConfig(bm=256, bn=128, bk=128),
                     TileConfig(bm=128, bn=256, bk=128),
                     TileConfig(bm=512, bn=128, bk=128)]
    else:
        chunks = (None, 512, 1024, 2048) if op == "newton" \
            else (None, 1024, 4096)
        cands = [TileConfig(bm=c) for c in chunks
                 if c is None or c < n]
        if default not in cands:
            cands.insert(0, default)
    seen, out = set(), []
    for c in cands:
        if c not in seen:
            seen.add(c)
            out.append(validate_tile_config(c, op,
                                            compiled=backend in
                                            ("tpu", "gpu")))
    return tuple(out)


# ----------------------------------------------------------------- entries
def get_tiles(op: str, *, n: int, p: int, C: int,
              backend: Optional[str] = None,
              dtype: str = "float32") -> TileConfig:
    """Resolve tiles for one key without measuring anything.

    Lookup order: in-process cache -> ``REPRO_CL_TUNE_CACHE`` JSON (loaded
    once, lazily) -> shape heuristic. The resolution is cached, so the
    same key always returns the same config for the life of the process —
    jit traces of one shape can never flip tiles between retraces.
    """
    backend = backend or jax.default_backend()
    key = tile_key(op, n=n, p=p, C=C, backend=backend, dtype=dtype)
    with _LOCK:
        hit = _CACHE.get(key)
    if hit is not None:
        return hit
    _load_env_cache()
    with _LOCK:
        hit = _CACHE.get(key)
        if hit is None:
            hit = _default_tiles(op, n=n, p=p, C=C, backend=backend)
            _CACHE[key] = hit
    return hit


def search_tiles(op: str, *, n: int, p: int, C: int,
                 measure: Callable[[TileConfig], float],
                 backend: Optional[str] = None, dtype: str = "float32",
                 candidates: Optional[Sequence[TileConfig]] = None,
                 ) -> Tuple[TileConfig, Dict[str, float]]:
    """Measured tile search; returns ``(best, timings)``.

    ``measure(cfg)`` runs the kernel under ``cfg`` and returns a cost
    (seconds or any monotone proxy). The argmin — ties break toward the
    earliest candidate, so same-key searches are deterministic — is cached
    under the key, after which :func:`get_tiles` (and therefore the
    :mod:`.ops` dispatch layer) picks it transparently. A key already in
    the cache is returned as-is with empty ``timings`` — **no re-search** —
    which is what makes two same-key runs cheap and identical; call
    :func:`clear_cache` to force a fresh search.

    With ``REPRO_CL_TUNE_CACHE`` set, every fresh search result is appended
    to that JSON file so later processes skip the search too.
    """
    backend = backend or jax.default_backend()
    key = tile_key(op, n=n, p=p, C=C, backend=backend, dtype=dtype)
    _load_env_cache()
    with _LOCK:
        hit = _CACHE.get(key)
    if hit is not None:
        return hit, {}
    cands = tuple(candidates) if candidates is not None else \
        candidate_tiles(op, n=n, p=p, C=C, backend=backend)
    if not cands:
        raise ValueError(f"no tile candidates for key {key!r}")
    compiled = backend in ("tpu", "gpu")
    timings: Dict[str, float] = {}
    best, best_cost = None, None
    for cfg in cands:
        validate_tile_config(cfg, op, compiled=compiled)
        cost = float(measure(cfg))
        timings[repr(cfg)] = cost
        if best_cost is None or cost < best_cost:
            best, best_cost = cfg, cost
    with _LOCK:
        _CACHE[key] = best
    path = os.environ.get(_ENV_CACHE)
    if path:
        try:
            save_cache(path)
        except OSError:
            pass
    return best, timings
