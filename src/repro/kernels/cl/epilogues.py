"""Per-family kernel epilogues: the registry the fused CL pipeline keys on.

The channelized score kernel (:mod:`repro.kernels.cl.kernel`) and the fused
Newton-step entry (:mod:`repro.kernels.cl.newton`) are family-agnostic: one
load -> eta -> residual -> score/Gram skeleton. Everything family-specific
is concentrated here as three pure elementwise maps over **leading-channel**
arrays (channel axis first, so the same closures run on kernel tiles
``(C, BM, BN)`` and on bucket slabs ``(C, k, n)`` alike):

* ``features(x, C) -> (C, ...)`` — the family's sufficient-statistic
  feature of a raw node value (identity for Ising/Gaussian, state
  indicators for Potts). The kernel feeds raw sample values through this
  both for the design side of the matmul and for the residual's target
  side; single-channel kinds ignore ``C``.
* ``residual(F, eta) -> (C, ...)`` — the per-sample score dl/deta given the
  node's own features ``F`` and its channel logits ``eta``. For Potts this
  is the softmax residual over all C = q - 1 channels at once (the reference
  channel's zero logit is implicit), which is why the channel axis must be
  whole inside one kernel tile.
* ``curvature(F, eta) -> (C, C, ...)`` — closed-form -d2l/deta2, including
  the cross-channel softmax coupling ``diag(pi) - pi pi'`` for Potts.

``channels`` declares whether the kind is expressible through the
single-channel ``(n, p)`` entry points (``"single"``) or needs the
channelized ``(C, n, p)`` pipeline (``"multi"``); the single-channel
entry points reject multi-channel kinds with a clear error.

A new model family plugs into the fused path by registering an epilogue
here and returning its kind from ``ModelFamily.kernel_kind`` — nothing in
the skeleton, the batched engine, or the streaming dispatch changes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """One family's fused-kernel math (leading-channel layout throughout)."""
    kind: str
    channels: str                 # "single" | "multi"
    features: Callable            # (x (...,), C) -> (C, ...)
    residual: Callable            # (F, eta) (C, ...) -> (C, ...)
    curvature: Callable           # (F, eta) (C, ...) -> (C, C, ...)

    def __post_init__(self):
        if self.channels not in ("single", "multi"):
            raise ValueError("channels must be 'single' or 'multi'")


_REGISTRY: Dict[str, Epilogue] = {}


def register_epilogue(ep: Epilogue) -> Epilogue:
    """Register (or replace) the epilogue for ``ep.kind``."""
    if not ep.kind:
        raise ValueError("epilogue needs a non-empty kind")
    _REGISTRY[ep.kind] = ep
    return ep


def get_epilogue(kind: Optional[str]) -> Optional[Epilogue]:
    """The registered epilogue for ``kind``, or None (no fused path)."""
    if kind is None:
        return None
    return _REGISTRY.get(kind)


def require_epilogue(kind: str) -> Epilogue:
    ep = get_epilogue(kind)
    if ep is None:
        raise ValueError(f"fused CL kernel has no epilogue for {kind!r}; "
                         f"registered: {registered_kinds()}")
    return ep


def registered_kinds() -> Tuple[str, ...]:
    """All registered epilogue kinds, name-sorted."""
    return tuple(sorted(_REGISTRY))


# ------------------------------------------------------------------ ising
def _ising_features(x, C: int = 1):
    return x[None]


def _ising_residual(F, eta):
    # logistic score of x in {-1, +1}: r = 2 x sigma(-2 x eta)
    return 2.0 * F * jax.nn.sigmoid(-2.0 * F * eta)


def _ising_curvature(F, eta):
    r = _ising_residual(F, eta)
    return (r * (2.0 * F - r))[None]   # = 4 sigma(2 eta) sigma(-2 eta)


# ---------------------------------------------------------------- gaussian
def _gaussian_features(x, C: int = 1):
    return x[None]


def _gaussian_residual(F, eta):
    # unit-conditional-variance linear-Gaussian score: r = x - eta
    return F - eta


def _gaussian_curvature(F, eta):
    return jnp.ones_like(eta)[None]


# ------------------------------------------------------------------- potts
# NOTE: these run inside Pallas kernel bodies, which forbid captured array
# constants — channel indices are unrolled as static Python scalars instead
# of materialized arange/eye arrays.
def _potts_features(x, C: int):
    return jnp.stack([(x == float(c)).astype(x.dtype)
                      for c in range(1, C + 1)])


def _potts_pi(eta):
    """Softmax over the C live channels with the reference channel's zero
    logit implicit: (C, ...) -> (C, ...)."""
    zero = jnp.zeros_like(eta[:1])
    return jax.nn.softmax(jnp.concatenate([zero, eta], axis=0), axis=0)[1:]


def _potts_residual(F, eta):
    # multinomial-logistic score: y - pi, with y = the node's own indicator
    # features (state 0 is the reference, all-zero feature row)
    return F - _potts_pi(eta)


def _potts_curvature(F, eta):
    pi = _potts_pi(eta)
    C = eta.shape[0]
    return jnp.stack([
        jnp.stack([(pi[c] - pi[c] * pi[e]) if c == e else (-pi[c] * pi[e])
                   for e in range(C)])
        for c in range(C)])


ISING_EPILOGUE = register_epilogue(Epilogue(
    kind="ising", channels="single", features=_ising_features,
    residual=_ising_residual, curvature=_ising_curvature))
GAUSSIAN_EPILOGUE = register_epilogue(Epilogue(
    kind="gaussian", channels="single", features=_gaussian_features,
    residual=_gaussian_residual, curvature=_gaussian_curvature))
POTTS_EPILOGUE = register_epilogue(Epilogue(
    kind="potts", channels="multi", features=_potts_features,
    residual=_potts_residual, curvature=_potts_curvature))
