"""Compiled-CPU twins of the fused CL kernels.

Pallas cannot compile on the CPU backend (interpret mode only, and
interpret mode is a Python-speed validation tool). These entries are the
*compiled* CPU tier the dispatch layer (:mod:`.ops`) picks by default off
TPU/GPU: XLA-jitted mirrors of the Pallas kernels' tiling — the sample
axis split into chunks, per-chunk epilogue residual/curvature, and the
score/curvature Grams accumulated across chunks in a ``lax.scan`` — so
the working set per step stays cache-sized the same way a VMEM tile does.

Chunking contract (what keeps the 1e-10 goldens safe):

* ``chunk=None`` (or >= n) delegates to the jnp reference **verbatim** —
  identical contraction order, bit-identical results. This is the
  heuristic default below :data:`~repro.kernels.cl.autotune.CHUNK_MIN_N`
  samples, i.e. for every golden fixture and test shape.
* an explicit chunk reorders the float accumulation (chunk partial sums),
  which is measured to win ~1.4x on large sample axes
  (BENCH_kernels.json newton rows) at the usual reordering-jitter cost;
  the autotuner only asks for it above the threshold.

Zero-padding the sample axis up to a chunk multiple is provably invisible:
padded design/feature columns are zero, so their score and Gram
contributions vanish term-by-term (padded *residuals* need not be zero —
they are always multiplied by a zero feature entry), and per-sample
outputs are sliced back to the live rows.

Mixed precision falls out of jnp promotion: bfloat16 designs against the
float32 solver state promote every contraction to float32, so bf16 is
load/matmul-side only and the Gram accumulators are always float32 (or
float64 under x64 plans).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .epilogues import require_epilogue
from .newton import bucket_newton_stats_ref
from .ref import cl_score_channels_ref

__all__ = ["cl_score_channels_tiled", "bucket_newton_stats_tiled"]


@functools.partial(jax.jit, static_argnames=("kind", "chunk"))
def cl_score_channels_tiled(F, theta, mask, bias, *, kind: str,
                            chunk=None):
    """(eta, r, S) fused channelized score statistics, XLA-compiled.

    Same contract as :func:`repro.kernels.cl.kernel.cl_score_channels` /
    its jnp reference. ``chunk`` (static) tiles the sample axis; ``None``
    is the exact reference path (see module docstring).
    """
    require_epilogue(kind)
    C, n, p = F.shape
    if chunk is None or chunk >= n:
        return cl_score_channels_ref(F, theta, mask, bias, kind)
    ep = require_epilogue(kind)
    pad = (-n) % chunk
    Fp = jnp.pad(F, ((0, 0), (0, pad), (0, 0)))
    nt = (n + pad) // chunk
    # (nt, C, chunk, p): scan steps over sample chunks
    Fc = jnp.moveaxis(Fp.reshape(C, nt, chunk, p), 1, 0)
    tm = (theta * mask[None]).astype(jnp.float32)
    b32 = bias[:, None, :].astype(jnp.float32)

    def step(S, Ft):
        Ff = Ft.astype(jnp.float32)
        eta = jnp.einsum("cnj,cji->cni", Ff, tm) + b32
        r = ep.residual(Ff, eta)
        S = S + jnp.einsum("cni,enj->ceij", r, Ff)
        return S, (eta.astype(F.dtype), r.astype(F.dtype))

    S0 = jnp.zeros((C, C, p, p), jnp.float32)
    S, (etas, rs) = jax.lax.scan(step, S0, Fc)
    eta = jnp.moveaxis(etas, 0, 1).reshape(C, nt * chunk, p)[:, :n]
    r = jnp.moveaxis(rs, 0, 1).reshape(C, nt * chunk, p)[:, :n]
    return eta, r, S / n


@functools.partial(jax.jit, static_argnames=("kind", "chunk"))
def bucket_newton_stats_tiled(kind: str, Zb, base, xi, W, sw=None, *,
                              chunk=None):
    """(g, K) fused bucket Newton statistics, XLA-compiled.

    Same contract as :func:`repro.kernels.cl.newton.bucket_newton_stats_ref`
    (whose chunk the scan body literally calls, so the per-chunk math —
    including the C == 1 fast path — is contraction-identical). ``chunk``
    (static) tiles the sample axis; ``None`` is the exact reference path.
    """
    k, C, d, n = Zb.shape
    if chunk is None or chunk >= n:
        return bucket_newton_stats_ref(kind, Zb, base, xi, W, sw)
    pad = (-n) % chunk
    nt = (n + pad) // chunk
    Zp = jnp.pad(Zb, ((0, 0), (0, 0), (0, 0), (0, pad)))
    bp = jnp.pad(base, ((0, 0), (0, 0), (0, pad)))
    xp = jnp.pad(xi, ((0, 0), (0, pad)))
    # chunk-major: (nt, k, C, d, chunk) etc., one scan step per chunk
    Zc = jnp.moveaxis(Zp.reshape(k, C, d, nt, chunk), 3, 0)
    bc = jnp.moveaxis(bp.reshape(k, C, nt, chunk), 2, 0)
    xc = jnp.moveaxis(xp.reshape(k, nt, chunk), 1, 0)
    weighted = sw is not None
    if weighted:
        sc = jnp.moveaxis(jnp.pad(sw, ((0, 0), (0, pad)))
                          .reshape(k, nt, chunk), 1, 0)
        xs = (Zc, bc, xc, sc)
    else:
        xs = (Zc, bc, xc)

    acc_dtype = jnp.result_type(Zb.dtype, W.dtype, jnp.float32)
    dC = d * C

    def step(carry, inp):
        g, K = carry
        if weighted:
            Zt, bt, xt, st = inp
        else:
            (Zt, bt, xt), st = inp, None
        gi, Ki = bucket_newton_stats_ref(kind, Zt, bt, xt, W, st)
        return (g + gi, K + Ki), None

    g0 = jnp.zeros((k, dC), acc_dtype)
    K0 = jnp.zeros((k, dC, dC), acc_dtype)
    (g, K), _ = jax.lax.scan(step, (g0, K0), xs)
    return g, K
