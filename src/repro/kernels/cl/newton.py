"""Fused Newton-step statistics in the degree-bucket layout.

The batched engine (:mod:`repro.core.batched`) solves every node of a
degree bucket simultaneously: designs live as a channelized ``(k, C, d, n)``
tensor and each damped Newton iteration needs, per node, the score vector

    g = sum_n Z[:, :, :, n] r[:, :, n]           (flat (k, d*C))

and the curvature Gram

    K = sum_n Z kappa Z                          ((k, d*C, d*C))

where ``r = dl/deta`` and ``kappa = -d2l/deta2`` come from the family
epilogue. This module emits BOTH directly in that bucket layout in one
fused pass — eta, r and kappa never materialize in HBM between the design
contraction and the score/Gram contraction:

* :func:`bucket_newton_stats_ref` — the jnp reference. Its contraction
  forms are kept **identical** to the engine's historical einsums
  (including the C = 1 single-channel fast path), so swapping the engine
  onto this entry point is bit-stable — the 1e-10 golden fixtures pin it.
* :func:`bucket_newton_stats` — the Pallas kernel: grid over (bucket node,
  sample tile), design slab stashed in VMEM, epilogue residual + curvature
  on the VPU, g and K accumulated on-chip across sample tiles. ``d`` and
  ``d*C`` are the tiny per-node design dims (engine buckets pad degree to
  powers of four), so the sample axis is the only tiled one. A
  :class:`~repro.kernels.cl.autotune.TileConfig` supplies the sample tile
  (``bm``) and, for real hardware, a ``lane`` target the tiny ``d*C``
  output axis is zero-padded up to (128 = the TPU register lane width):
  padded design rows are zero, so every score and Gram term they touch
  vanishes identically and the outputs are sliced back — lane alignment is
  provably invisible (the edge-tile/lane hypothesis properties pin it).

Both dispatch on the static epilogue ``kind``; coordinate-major flat layout
``[(d0,c0), (d0,c1), ..., (d1,c0), ...]`` matches ``family.beta`` exactly.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .epilogues import require_epilogue

BNK = 128   # default sample-axis tile


def _lead(eta_kcn):
    """(k, C, n) channel-middle -> (C, k, n) leading-channel (pure layout)."""
    return jnp.moveaxis(eta_kcn, 1, 0)


def _unlead(a_ckn):
    return jnp.moveaxis(a_ckn, 0, 1)


def bucket_residual_curvature(kind: str, eta, xi):
    """Epilogue residual r (k, C, n) and curvature kappa (k, C, C, n) at
    bucket-layout logits ``eta`` (k, C, n) for targets ``xi`` (k, n)."""
    ep = require_epilogue(kind)
    C = eta.shape[1]
    el = _lead(eta)                               # (C, k, n)
    F = ep.features(xi, C)                        # (C, k, n)
    r = _unlead(ep.residual(F, el))               # (k, C, n)
    kap = jnp.moveaxis(ep.curvature(F, el), (0, 1), (1, 2))  # (k, C, C, n)
    return r, kap


def bucket_newton_stats_ref(kind: str, Zb, base, xi, W, sw=None):
    """(g, K) un-normalized score vector and curvature Gram, jnp reference.

    Zb: (k, C, d, n) bucket design; base: (k, C, n) fixed-offset logits;
    xi: (k, n) targets; W: (k, d*C) coordinate-major flat parameters;
    sw: optional (k, n) sample weights (None = unweighted). Returns
    g (k, d*C) and K (k, d*C, d*C); the engine divides by its own sample
    denominator and negates K into the Newton system.
    """
    k, C, d, _ = Zb.shape
    dC = d * C
    if C == 1:
        Z1 = Zb[:, 0]
        eta = base + jnp.einsum("kdn,kd->kn", Z1, W)[:, None, :]
        r, kap = bucket_residual_curvature(kind, eta, xi)
        if sw is not None:
            r = r * sw[:, None, :]
            kap = kap * sw[:, None, None, :]
        g = jnp.einsum("kdn,kn->kd", Z1, r[:, 0])
        K = (Z1 * kap[:, 0, 0][:, None, :]) @ jnp.swapaxes(Z1, 1, 2)
        return g, K
    eta = base + jnp.einsum("kcdn,kdc->kcn", Zb, W.reshape(k, d, C))
    r, kap = bucket_residual_curvature(kind, eta, xi)
    if sw is not None:
        r = r * sw[:, None, :]
        kap = kap * sw[:, None, None, :]
    g = jnp.einsum("kcdn,kcn->kdc", Zb, r).reshape(k, dC)
    K = jnp.einsum("kcdn,kcen,kefn->kdcfe", Zb, kap, Zb).reshape(k, dC, dC)
    return g, K


# ------------------------------------------------------------ pallas kernel
def _newton_kernel(z_ref, base_ref, xi_ref, sw_ref, w_ref, g_ref, k_ref, *,
                   kind: str, weighted: bool):
    t = pl.program_id(1)
    ep = require_epilogue(kind)
    C, d = z_ref.shape[1], z_ref.shape[2]
    dC = d * C

    @pl.when(t == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        k_ref[...] = jnp.zeros_like(k_ref)

    Z = z_ref[0].astype(jnp.float32)               # (C, d, BNK)
    Wb = w_ref[0].astype(jnp.float32).reshape(d, C)
    eta = base_ref[0].astype(jnp.float32) + jnp.stack(
        [jnp.dot(Wb[:, c], Z[c], preferred_element_type=jnp.float32)
         for c in range(C)])                       # (C, BNK)
    x = xi_ref[0].astype(jnp.float32)
    F = ep.features(x, C)                          # (C, BNK)
    r = ep.residual(F, eta)                        # (C, BNK)
    kap = ep.curvature(F, eta)                     # (C, C, BNK)
    if weighted:
        w = sw_ref[0].astype(jnp.float32)
        r = r * w[None]
        kap = kap * w[None, None]
    # score vector, coordinate-major flat (d*C)
    g = jnp.stack([jnp.dot(Z[c], r[c], preferred_element_type=jnp.float32)
                   for c in range(C)], axis=1)     # (d, C)
    g_ref[0, :] += g.reshape(dC)
    # curvature Gram: all (C, C) cross-channel blocks, (d,c) x (f,e) flat
    blocks = jnp.stack([
        jnp.stack([jnp.dot(Z[c] * kap[c, e][None, :], Z[e].T,
                           preferred_element_type=jnp.float32)
                   for e in range(C)])
        for c in range(C)])                        # (C, C, d, d)
    k_ref[0, :, :] += jnp.transpose(blocks, (2, 0, 3, 1)).reshape(dC, dC)


def lane_padded_width(d: int, C: int, lane: int) -> int:
    """Smallest ``d' >= d`` such that ``d' * C`` is a multiple of ``lane``.

    Padding the *coordinate* axis (not the flat ``d*C`` axis) keeps the
    coordinate-major layout intact: the pad lands as trailing all-zero
    coordinates, so ``g[:, :d*C]`` / ``K[:, :d*C, :d*C]`` slice the real
    block back out.
    """
    step = lane // math.gcd(C, lane)
    return d + ((-d) % step)


@functools.partial(jax.jit, static_argnames=("kind", "interpret", "tiles"))
def bucket_newton_stats(kind: str, Zb, base, xi, W, sw=None, *,
                        interpret: Optional[bool] = None, tiles=None):
    """Pallas-fused (g, K) bucket Newton statistics; see module docstring.

    Same contract as :func:`bucket_newton_stats_ref`. ``interpret=None``
    derives from the backend (compiled on TPU/GPU, interpret elsewhere —
    Pallas cannot compile on CPU). ``tiles`` is an optional
    :class:`~repro.kernels.cl.autotune.TileConfig`: ``tiles.bm`` sets the
    sample tile (default 128) and ``tiles.lane`` zero-pads the ``d*C``
    output axis up to a lane multiple (see :func:`lane_padded_width`).
    All padding — sample *and* lane — is exact: padded design entries are
    zero, so every contraction term they touch vanishes.
    """
    require_epilogue(kind)
    if interpret is None:
        interpret = jax.default_backend() not in ("tpu", "gpu")
    bm = BNK if tiles is None or tiles.bm is None else int(tiles.bm)
    lane = None if tiles is None else tiles.lane
    k, C, d, n = Zb.shape
    dC_out = d * C
    if lane:
        d_pad = lane_padded_width(d, C, lane) - d
        if d_pad:
            Zb = jnp.pad(Zb, ((0, 0), (0, 0), (0, d_pad), (0, 0)))
            W = jnp.pad(W, ((0, 0), (0, d_pad * C)))
            d = d + d_pad
    dC = d * C
    pad_n = (-n) % bm
    Zp = jnp.pad(Zb, ((0, 0), (0, 0), (0, 0), (0, pad_n)))
    bp = jnp.pad(base, ((0, 0), (0, 0), (0, pad_n)))
    xp = jnp.pad(xi, ((0, 0), (0, pad_n)))
    weighted = sw is not None
    swp = (jnp.pad(sw, ((0, 0), (0, pad_n))) if weighted
           else jnp.zeros((k, n + pad_n), Zb.dtype))

    g, K = pl.pallas_call(
        functools.partial(_newton_kernel, kind=kind, weighted=weighted),
        grid=(k, (n + pad_n) // bm),
        in_specs=[
            pl.BlockSpec((1, C, d, bm), lambda a, t: (a, 0, 0, t)),
            pl.BlockSpec((1, C, bm), lambda a, t: (a, 0, t)),
            pl.BlockSpec((1, bm), lambda a, t: (a, t)),
            pl.BlockSpec((1, bm), lambda a, t: (a, t)),
            pl.BlockSpec((1, dC), lambda a, t: (a, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, dC), lambda a, t: (a, 0)),
            pl.BlockSpec((1, dC, dC), lambda a, t: (a, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, dC), jnp.float32),
            jax.ShapeDtypeStruct((k, dC, dC), jnp.float32),
        ],
        interpret=interpret,
    )(Zp, bp, xp, swp, W)
    return g[:, :dC_out], K[:, :dC_out, :dC_out]
