"""Adapters from a :class:`ModelFamily` to the channelized kernel inputs.

The fused pipeline speaks (C, n, p) feature stacks and (C, p, p) coupling
slabs; model families speak flat block-ordered theta vectors over a graph.
This module is the (one-way) bridge: it depends only on the family object's
public hooks (``block_dim``, ``edge_features``, ``coupling_tensor``,
``node_params``, ``kernel_kind``), never on :mod:`repro.core` itself, so
the kernel layer stays import-cycle-free.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import cl_score_channels


def family_kernel_inputs(family, graph, theta, X):
    """(F, theta_c, mask, bias) channelized kernel inputs.

    theta is the family's flat [node blocks, edge blocks] vector; X is the
    raw (n, p) sample matrix. Returns F (C, n, p) per-channel design
    features, theta_c (C, p, p) symmetric per-channel couplings, the (p, p)
    adjacency mask and bias (C, p) node blocks.
    """
    X = jnp.asarray(X)
    theta = jnp.asarray(theta, X.dtype)
    F = jnp.moveaxis(family.edge_features(X), -1, 0)       # (C, n, p)
    theta_c = jnp.moveaxis(family.coupling_tensor(graph, theta), -1, 0)
    mask = jnp.asarray(graph.adjacency, X.dtype)
    bias = family.node_params(graph, theta).T              # (C, p)
    return F, theta_c, mask, bias


def family_score_stats(family, graph, theta, X, *,
                       interpret: Optional[bool] = None,
                       use_pallas: Optional[bool] = None):
    """Fused (eta, r, S) channelized score statistics for any family whose
    ``kernel_kind`` has a registered epilogue. Shapes as in
    :func:`repro.kernels.cl.kernel.cl_score_channels`.

    ``use_pallas=None`` picks the backend default through the dispatch
    layer (:func:`repro.kernels.cl.ops.score_stats_channels_op`): the
    compiled Mosaic kernel on TPU/GPU, the XLA-compiled tiled twin
    elsewhere — and records the resolved path in telemetry. ``use_pallas=
    True`` forces the Pallas kernel (``interpret=None`` compiles where the
    backend can, interpret mode on CPU or on explicit ``interpret=True`` —
    the validation spelling, ~10x the reference's cost); ``False`` forces
    the jnp reference.
    """
    from .ops import score_stats_channels_op
    F, theta_c, mask, bias = family_kernel_inputs(family, graph, theta, X)
    if use_pallas is None or not use_pallas:
        return score_stats_channels_op(F, theta_c, mask, bias,
                                       kind=family.kernel_kind,
                                       use_pallas=use_pallas,
                                       interpret=interpret)
    if interpret is None:
        interpret = jax.default_backend() not in ("tpu", "gpu")
    return cl_score_channels(F, theta_c, mask, bias,
                             kind=family.kernel_kind,
                             interpret=interpret)


def fused_pseudo_score(family, graph, theta, x_pad, n_seen: int, *,
                       interpret: Optional[bool] = None,
                       use_pallas: Optional[bool] = None) -> np.ndarray:
    """Exact flat gradient of the average pseudo-likelihood at ``theta``
    over the first ``n_seen`` rows of a zero-padded sample buffer, via one
    fused kernel pass.

    Works for every registered epilogue kind, multi-channel included:
    channel-c singleton gradients are live-row means of ``r_c`` and the
    edge-(i, j) channel-c gradient is ``S[c, c][i, j] + S[c, c][j, i]``
    (padded rows have all-zero feature rows — for Potts because state 0 is
    the reference state — so only the Gram normalizer needs rescaling).
    """
    p = graph.p
    C = family.block_dim
    theta32 = jnp.asarray(np.asarray(theta), jnp.float32)
    x_pad = jnp.asarray(x_pad, jnp.float32)
    eta, r, S = family_score_stats(family, graph, theta32, x_pad,
                                   interpret=interpret,
                                   use_pallas=use_pallas)
    n_seen = int(n_seen)
    S = np.asarray(S, dtype=np.float64) * (x_pad.shape[0] / max(n_seen, 1))
    r = np.asarray(r, dtype=np.float64)[:, :n_seen, :]     # live rows only
    g = np.zeros(family.n_params(graph))
    g[: p * C] = (r.sum(axis=1) / max(n_seen, 1)).T.reshape(p * C)
    for k, (i, j) in enumerate(graph.edges):
        for c in range(C):
            g[p * C + k * C + c] = S[c, c, i, j] + S[c, c, j, i]
    return g
