"""Pallas TPU kernels: the family-generic fused CL pipeline.

Two kernels share one channelized skeleton:

* :func:`cl_logits` — the masked conditional-logit matmul
  ``eta_c = F_c @ (Theta_c * A) + b_c`` (the seed's ``ising_cl_logits`` is
  its C = 1 instance);
* :func:`cl_score_channels` — the whole fused score pipeline in ONE pass
  over the samples:

      eta_c = F_c @ (Theta_c * A) + b_c      (masked MXU matmul, per channel)
      r     = epilogue.residual(F_self, eta) (VPU, all C channels together)
      S[c,e] = r_c^T F_e / n                 (cross-channel score Gram)

The per-family residual comes from the epilogue registry
(:mod:`repro.kernels.cl.epilogues`) and is dispatched **at trace time** by
the static ``kind`` argument — one compiled kernel per family kind.
Multi-channel families (Potts, C = q - 1 softmax channels) run the same
skeleton as Ising/Gaussian: the channel axis is carried whole inside every
tile (C is small — q - 1 for Potts, 1 otherwise), so the softmax residual
sees all channels of a node's logits at once and the Gram epilogue emits
the full (C, C) grid of cross-channel blocks.

``r`` is the per-sample score residual every gradient statistic is built
from: channel-c column means of ``r_c`` are the singleton-block gradients of
the average pseudo-likelihood and ``S[c, c][i, j] + S[c, c][j, i]`` (for an
edge (i, j)) its coupling-block gradients; the off-diagonal ``S[c, e]``
blocks are the cross-channel score products the second-order (sandwich /
Gram) machinery consumes. Fusing the epilogue and the Gram contraction
means F is read from HBM once and eta never round-trips.

Grid is (j, i, k): j tiles output columns (and S rows), i tiles samples,
k tiles the contraction. The F strip for the current sample tile is stashed
in VMEM during the k loop, so the Gram contraction re-reads it from on-chip
memory rather than HBM. Tile sizes default to the MXU-aligned 128s and are
tunable through a :class:`~repro.kernels.cl.autotune.TileConfig` (static
``tiles=`` argument); operand shapes never have to divide the tiles —
every axis is zero-padded up to the tile grid and sliced back, and the
padding is provably invisible (zero feature rows/columns contribute
nothing to any contraction; the edge-tile hypothesis properties pin it).
``interpret=None`` derives from the backend: compiled on TPU/GPU,
interpret (the Python-speed validation mode) elsewhere — Pallas cannot
compile on CPU, where the dispatch layer uses :mod:`.tiled` instead.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .epilogues import require_epilogue

BM, BN, BK = 128, 128, 128


def _resolve(interpret: Optional[bool], tiles):
    """(interpret, bm, bn, bk) trace-time constants from the static args."""
    if interpret is None:
        interpret = jax.default_backend() not in ("tpu", "gpu")
    if tiles is None:
        return interpret, BM, BN, BK
    bm = BM if tiles.bm is None else int(tiles.bm)
    return interpret, bm, int(tiles.bn), int(tiles.bk)


# ------------------------------------------------------------- logits kernel
def _logits_kernel(f_ref, theta_ref, mask_ref, bias_ref, out_ref, acc_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)
    C = f_ref.shape[0]

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    masked = theta_ref[...] * mask_ref[...][None]    # VPU fuse, no HBM trip
    for c in range(C):                               # static channel unroll
        acc_ref[c] += jnp.dot(f_ref[c], masked[c],
                              preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        out_ref[...] = (acc_ref[...] +
                        bias_ref[...].astype(jnp.float32)
                        ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "tiles"))
def cl_logits(F, theta, mask, bias, *, interpret: Optional[bool] = None,
              tiles=None):
    """Channelized masked-matmul logits: eta_c = F_c @ (theta_c * mask) + b_c.

    F: (C, n, p); theta: (C, p, p); mask: (p, p); bias: (C, p). Returns
    eta of shape (C, n, p) in F.dtype. Shapes are padded to the tile grid
    internally (128s by default; ``tiles`` overrides). ``interpret=None``
    derives from the backend — compiled on TPU/GPU, interpret elsewhere.
    """
    interpret, bm, bn, bk = _resolve(interpret, tiles)
    C, n, p = F.shape
    pad_n = (-n) % bm
    pad_p = (-p) % math.lcm(bn, bk)
    fp = jnp.pad(F, ((0, 0), (0, pad_n), (0, pad_p)))
    tp = jnp.pad(theta, ((0, 0), (0, pad_p), (0, pad_p)))
    mp = jnp.pad(mask, ((0, pad_p), (0, pad_p)))
    bp = jnp.pad(bias, ((0, 0), (0, pad_p)))[:, None, :]
    _, np_, pp = fp.shape

    grid = (np_ // bm, pp // bn, pp // bk)
    out = pl.pallas_call(
        _logits_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, bm, bk), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((C, bk, bn), lambda i, j, k: (0, k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((C, 1, bn), lambda i, j, k: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((C, bm, bn), lambda i, j, k: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((C, np_, pp), F.dtype),
        scratch_shapes=[pltpu.VMEM((C, bm, bn), jnp.float32)],
        interpret=interpret,
    )(fp, tp, mp, bp)
    return out[:, :n, :p]


def ising_cl_logits(x, theta, mask, bias, *,
                    interpret: Optional[bool] = None):
    """eta = x @ (theta * mask) + bias — the seed single-channel entry.

    x: (n, p); theta, mask: (p, p); bias: (p,). The C = 1 instance of
    :func:`cl_logits`.
    """
    return cl_logits(x[None], theta[None], mask, bias[None],
                     interpret=interpret)[0]


# -------------------------------------------------------------- score kernel
def _score_kernel_c1(x_ref, theta_ref, mask_ref, bias_ref,
                     eta_ref, r_ref, s_ref, acc_ref, xstrip_ref, *, n: int,
                     kind: str, bn: int, bk: int):
    """Single-channel (C = 1) specialization of :func:`_score_kernel`.

    Same grid, same VMEM strip, same epilogue registry — but 2-D refs
    throughout, which keeps the interpret-mode (CPU validation) path ~10x
    cheaper than carrying a unit channel axis through every ref op. Picked
    at trace time by ``cl_score_channels`` exactly like the batched
    engine's own C == 1 contraction fast path.
    """
    j = pl.program_id(0)
    i = pl.program_id(1)
    k = pl.program_id(2)
    ni = pl.num_programs(1)
    nk = pl.num_programs(2)
    epilogue = require_epilogue(kind)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((i == 0) & (k == 0))
    def _init_s():
        s_ref[...] = jnp.zeros_like(s_ref)

    xstrip_ref[:, pl.ds(k * bk, bk)] = x_ref[...].astype(jnp.float32)
    masked = theta_ref[...] * mask_ref[...]          # VPU fuse, no HBM trip
    acc_ref[...] += jnp.dot(x_ref[...], masked,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        eta = acc_ref[...] + bias_ref[...].astype(jnp.float32)
        eta_ref[...] = eta.astype(eta_ref.dtype)
        xj = xstrip_ref[:, pl.ds(j * bn, bn)]        # j-tile nodes' values
        r = epilogue.residual(xj[None], eta[None])[0]
        r_ref[...] = r.astype(r_ref.dtype)
        s_ref[...] += jnp.dot(r.T, xstrip_ref[...],
                              preferred_element_type=jnp.float32)

    @pl.when((k == nk - 1) & (i == ni - 1))
    def _finish():
        s_ref[...] = s_ref[...] / n


def _score_kernel(f_ref, theta_ref, mask_ref, bias_ref,
                  eta_ref, r_ref, s_ref, acc_ref, fstrip_ref, *, n: int,
                  kind: str, bn: int, bk: int):
    j = pl.program_id(0)
    i = pl.program_id(1)
    k = pl.program_id(2)
    ni = pl.num_programs(1)
    nk = pl.num_programs(2)
    C = f_ref.shape[0]
    epilogue = require_epilogue(kind)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((i == 0) & (k == 0))
    def _init_s():
        s_ref[...] = jnp.zeros_like(s_ref)

    # stash this sample-tile's F strip so the Gram contraction stays on-chip
    fstrip_ref[:, :, pl.ds(k * bk, bk)] = f_ref[...].astype(jnp.float32)
    masked = theta_ref[...] * mask_ref[...][None]    # VPU fuse, no HBM trip
    for c in range(C):                               # static channel unroll
        acc_ref[c] += jnp.dot(f_ref[c], masked[c],
                              preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        eta = acc_ref[...] + bias_ref[...].astype(jnp.float32)
        eta_ref[...] = eta.astype(eta_ref.dtype)
        # the j-tile nodes' own features = the residual's target side
        y = fstrip_ref[:, :, pl.ds(j * bn, bn)]      # (C, bm, bn)
        r = epilogue.residual(y, eta)                # all channels at once
        r_ref[...] = r.astype(r_ref.dtype)
        for c in range(C):
            for e in range(C):
                s_ref[c, e] += jnp.dot(r[c].T, fstrip_ref[e],
                                       preferred_element_type=jnp.float32)

    @pl.when((k == nk - 1) & (i == ni - 1))
    def _finish():
        s_ref[...] = s_ref[...] / n


@functools.partial(jax.jit, static_argnames=("interpret", "kind", "tiles"))
def cl_score_channels(F, theta, mask, bias, *, kind: str,
                      interpret: Optional[bool] = None, tiles=None):
    """(eta, r, S) = fused channelized score statistics; see module docstring.

    F: (C, n, p) per-channel design features (for single-channel kinds
    F[0] is the raw sample matrix; for Potts, state indicators); theta:
    (C, p, p) per-channel couplings; mask: (p, p); bias: (C, p). ``kind``
    picks the family epilogue from the registry (one compiled kernel per
    kind). Returns eta, r of shape (C, n, p) in F.dtype and the
    cross-channel score Gram S of shape (C, C, p, p) in float32 with
    ``S[c, e] = r_c^T F_e / n``.

    ``interpret=None`` derives from the backend (compiled on TPU/GPU,
    interpret — the Python-speed validation mode — elsewhere); ``tiles``
    is an optional :class:`~repro.kernels.cl.autotune.TileConfig`
    overriding the 128-aligned defaults. Shapes need not divide the tiles:
    n is padded to the sample tile and p to lcm(bn, bk), and zero padding
    is invisible to every output (sliced off for eta/r, contributing
    exactly zero to S).
    """
    require_epilogue(kind)        # fail at trace time with a clear error
    interpret, bm, bn, bk = _resolve(interpret, tiles)
    C, n, p = F.shape
    pad_n = (-n) % bm
    pad_p = (-p) % math.lcm(bn, bk)
    fp = jnp.pad(F, ((0, 0), (0, pad_n), (0, pad_p)))
    tp = jnp.pad(theta, ((0, 0), (0, pad_p), (0, pad_p)))
    mp = jnp.pad(mask, ((0, pad_p), (0, pad_p)))
    bp = jnp.pad(bias, ((0, 0), (0, pad_p)))[:, None, :]
    _, np_, pp = fp.shape

    grid = (pp // bn, np_ // bm, pp // bk)
    if C == 1:
        # trace-time single-channel specialization: same skeleton, 2-D refs
        eta, r, s = pl.pallas_call(
            functools.partial(_score_kernel_c1, n=n, kind=kind, bn=bn,
                              bk=bk),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda j, i, k: (i, k)),
                pl.BlockSpec((bk, bn), lambda j, i, k: (k, j)),
                pl.BlockSpec((bk, bn), lambda j, i, k: (k, j)),
                pl.BlockSpec((1, bn), lambda j, i, k: (0, j)),
            ],
            out_specs=[
                pl.BlockSpec((bm, bn), lambda j, i, k: (i, j)),
                pl.BlockSpec((bm, bn), lambda j, i, k: (i, j)),
                pl.BlockSpec((bn, pp), lambda j, i, k: (j, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((np_, pp), F.dtype),
                jax.ShapeDtypeStruct((np_, pp), F.dtype),
                jax.ShapeDtypeStruct((pp, pp), jnp.float32),
            ],
            scratch_shapes=[
                pltpu.VMEM((bm, bn), jnp.float32),
                pltpu.VMEM((bm, pp), jnp.float32),
            ],
            interpret=interpret,
        )(fp[0], tp[0], mp, bp[0])
        return (eta[None, :n, :p], r[None, :n, :p],
                s[None, None, :p, :p])
    eta, r, s = pl.pallas_call(
        functools.partial(_score_kernel, n=n, kind=kind, bn=bn, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, bm, bk), lambda j, i, k: (0, i, k)),
            pl.BlockSpec((C, bk, bn), lambda j, i, k: (0, k, j)),
            pl.BlockSpec((bk, bn), lambda j, i, k: (k, j)),
            pl.BlockSpec((C, 1, bn), lambda j, i, k: (0, 0, j)),
        ],
        out_specs=[
            pl.BlockSpec((C, bm, bn), lambda j, i, k: (0, i, j)),
            pl.BlockSpec((C, bm, bn), lambda j, i, k: (0, i, j)),
            pl.BlockSpec((C, C, bn, pp), lambda j, i, k: (0, 0, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, np_, pp), F.dtype),
            jax.ShapeDtypeStruct((C, np_, pp), F.dtype),
            jax.ShapeDtypeStruct((C, C, pp, pp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((C, bm, bn), jnp.float32),
            pltpu.VMEM((C, bm, pp), jnp.float32),
        ],
        interpret=interpret,
    )(fp, tp, mp, bp)
    return eta[:, :n, :p], r[:, :n, :p], s[:, :, :p, :p]
