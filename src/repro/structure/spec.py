"""Declarative configuration for the structure-learning verb.

A :class:`StructureSpec` says *how* ``session.select`` should estimate the
edge set: which candidate edges to consider (``policy``), which lambda
grid to walk (explicit ``lambdas`` or an auto-scaled geometric path), how
the two endpoints' neighborhoods are reconciled (``vote``), and the ADMM /
EBIC knobs. Like :class:`repro.api.Plan` it is frozen, hashable, and
round-trips through ``to_dict``/``from_dict``; every invalid combination
fails loudly at construction with a pointed ``ValueError`` (negative or
unsorted lambda grids, unknown vote rules listing what IS registered,
``given`` policy without edges, ...). The one check the spec cannot do
alone — ``knn`` k against the plan's node count — lives in
``Plan.__post_init__`` and :func:`repro.structure.candidates.candidate_graph`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from .voting import get_vote_rule

__all__ = ["StructureSpec", "CANDIDATE_POLICIES"]

#: candidate-edge policies ``session.select`` understands
CANDIDATE_POLICIES = ("full", "knn", "given")


@dataclasses.dataclass(frozen=True)
class StructureSpec:
    """How to run neighborhood selection. All fields have working defaults;
    ``StructureSpec()`` walks an auto-scaled 12-point lambda path over all
    candidate edges and reconciles supports by variance-weighted vote.

    policy           — candidate-edge policy: ``full`` (every pair),
                       ``knn`` (per-node top-``knn_k`` correlation
                       screening, union-symmetrized), or ``given``
                       (caller-supplied ``given_edges``).
    knn_k            — neighbors kept per node under ``knn``; must be
                       >= 1 and < p (checked against the plan's graph).
    given_edges      — the candidate edges for ``given``; (i, j) pairs
                       with i < j, as for :class:`repro.core.Graph`.
    lambdas          — explicit regularization grid, strictly decreasing
                       and non-negative (the path is walked coldest-first:
                       largest lambda = sparsest model seeds the next).
                       ``None`` auto-scales a geometric grid from the
                       data's lambda_max.
    n_lambdas        — auto-grid length (ignored when ``lambdas`` given).
    lambda_min_ratio — auto-grid floor as a fraction of lambda_max,
                       in (0, 1).
    vote             — registered vote-rule name (``and`` / ``or`` /
                       ``weighted``; see :mod:`repro.structure.voting`).
    ebic_gamma       — extended-BIC graph-complexity weight in [0, 1]
                       (0 = plain BIC; 0.5 is the usual high-dim default).
    admm_rounds      — max ADMM iterations per lambda (warm starts mean
                       later lambdas converge in a few).
    admm_rho         — ADMM augmented-Lagrangian penalty (> 0).
    admm_tol         — primal/dual residual norm for early stop (> 0).
    newton_iters     — Newton steps inside each batched prox solve.
    """

    policy: str = "full"
    knn_k: int = 8
    given_edges: Optional[Tuple[Tuple[int, int], ...]] = None
    lambdas: Optional[Tuple[float, ...]] = None
    n_lambdas: int = 12
    lambda_min_ratio: float = 0.05
    vote: str = "weighted"
    ebic_gamma: float = 0.5
    admm_rounds: int = 40
    admm_rho: float = 1.0
    admm_tol: float = 1e-5
    newton_iters: int = 15

    def __post_init__(self):
        if self.policy not in CANDIDATE_POLICIES:
            raise ValueError(
                f"unknown candidate policy {self.policy!r}; choose one of "
                f"{list(CANDIDATE_POLICIES)}")
        if self.given_edges is not None:
            object.__setattr__(
                self, "given_edges",
                tuple((int(i), int(j)) for i, j in self.given_edges))
        if self.policy == "given" and not self.given_edges:
            raise ValueError(
                "policy 'given' needs given_edges=((i, j), ...) — an "
                "explicit candidate edge set; got none")
        if self.given_edges is not None and self.policy != "given":
            raise ValueError(
                f"given_edges only makes sense with policy 'given' "
                f"(got policy {self.policy!r}); drop one or the other")
        if self.policy == "knn" and self.knn_k < 1:
            raise ValueError(
                f"knn_k must be >= 1 for policy 'knn'; got {self.knn_k}")
        if self.lambdas is not None:
            lams = tuple(float(l) for l in self.lambdas)
            object.__setattr__(self, "lambdas", lams)
            if not lams:
                raise ValueError("lambdas must be a non-empty grid or None "
                                 "for the auto-scaled path")
            neg = [l for l in lams if l < 0.0]
            if neg:
                raise ValueError(
                    f"lambda grid must be non-negative; got negative "
                    f"entries {neg} in {list(lams)}")
            if any(a <= b for a, b in zip(lams, lams[1:])):
                raise ValueError(
                    f"lambda grid must be strictly decreasing (the path is "
                    f"walked coldest-first, each solution warm-starting "
                    f"the next); got {list(lams)} — sort it descending and "
                    f"drop duplicates")
        if self.n_lambdas < 1:
            raise ValueError(f"n_lambdas must be >= 1; got {self.n_lambdas}")
        if not (0.0 < self.lambda_min_ratio < 1.0):
            raise ValueError(
                f"lambda_min_ratio must lie in (0, 1); got "
                f"{self.lambda_min_ratio}")
        # resolves through the registry → unknown names raise the registry's
        # pointed error listing every registered rule
        get_vote_rule(self.vote)
        if not (0.0 <= self.ebic_gamma <= 1.0):
            raise ValueError(
                f"ebic_gamma must lie in [0, 1]; got {self.ebic_gamma}")
        if self.admm_rounds < 1:
            raise ValueError(
                f"admm_rounds must be >= 1; got {self.admm_rounds}")
        if self.admm_rho <= 0.0:
            raise ValueError(f"admm_rho must be > 0; got {self.admm_rho}")
        if self.admm_tol <= 0.0:
            raise ValueError(f"admm_tol must be > 0; got {self.admm_tol}")
        if self.newton_iters < 1:
            raise ValueError(
                f"newton_iters must be >= 1; got {self.newton_iters}")

    # ------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if d["given_edges"] is not None:
            d["given_edges"] = [list(e) for e in d["given_edges"]]
        if d["lambdas"] is not None:
            d["lambdas"] = list(d["lambdas"])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "StructureSpec":
        kw = dict(d)
        if kw.get("given_edges") is not None:
            kw["given_edges"] = tuple(tuple(e) for e in kw["given_edges"])
        if kw.get("lambdas") is not None:
            kw["lambdas"] = tuple(kw["lambdas"])
        unknown = set(kw) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(
                f"unknown StructureSpec fields {sorted(unknown)}")
        return cls(**kw)

    def replace(self, **kw) -> "StructureSpec":
        return dataclasses.replace(self, **kw)
