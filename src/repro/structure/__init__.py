"""Structure learning: estimate the GRAPH, not just the parameters.

Everything below the API facade assumed a known edge set; this package
lifts that. ``repro.structure`` runs distributed pseudo-likelihood lasso —
group-lasso-penalized neighborhood selection per node, over a screened
candidate-edge set, along a warm-started regularization path — and then
reconciles the p disagreeing neighborhoods into one support by registered
vote rules, with exact per-scalar message accounting. Ising, Gaussian and
Potts all work: the penalty operates on the family's C-wide edge blocks.

Reachable as the fourth session verb:

    from repro.api import Plan, StructureSpec
    res = Plan(graph=g, family="ising",
               structure=StructureSpec(policy="full")).session().select(X)
    res.graph          # the recovered Graph
    res.edge_metrics(true_edges)["f1"]

Modules: :mod:`.spec` (declarative config + loud validation),
:mod:`.candidates` (full / knn / given screening), :mod:`.solver`
(ADMM group-lasso path on the batched engine, auto lambda grids, EBIC),
:mod:`.voting` (vote-rule registry + reconciliation), :mod:`.result`
(:class:`StructureResult`).
"""
from .candidates import candidate_graph
from .result import StructureResult
from .solver import (auto_lambda_grid, debias_to_support, ebic_scores,
                     edge_supports, lasso_path, node_logliks)
from .spec import CANDIDATE_POLICIES, StructureSpec
from .voting import (VoteRule, get_vote_rule, reconcile, register_vote_rule,
                     registered_vote_rules)

__all__ = [
    "StructureSpec", "StructureResult", "CANDIDATE_POLICIES",
    "candidate_graph", "auto_lambda_grid", "lasso_path", "node_logliks",
    "ebic_scores", "edge_supports", "debias_to_support",
    "VoteRule", "register_vote_rule", "get_vote_rule",
    "registered_vote_rules", "reconcile",
]
