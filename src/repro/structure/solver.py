"""Warm-started group-lasso regularization paths over candidate edges.

Neighborhood selection is p independent penalized conditional fits

    max_w  l^i(w)  -  lambda * sum_{edge blocks b} ||w_b||_2,

one per node, over the candidate graph — exactly the paper's local CL
objectives plus a group penalty on the C-wide edge blocks. We solve them
all at once by ADMM splitting, reusing the batched engine wholesale:

  w-update — the smooth proximal solve IS :func:`repro.core.batched.
             prox_update_batched` (quadratic penalty ``rho/2 (w - (z-u))^2``,
             zero linear term): degree-bucketed, family-dispatched,
             mesh-shardable, ONE XLA compile per degree bucket;
  z-update — closed-form :func:`repro.core.batched.group_soft_threshold`
             per node (threshold lambda/rho), where exact zeros appear —
             the support is read off z with no epsilon;
  u-update — scaled dual ascent, plain numpy.

The lambda grid is walked **coldest-first** (largest lambda, sparsest
model): each lambda's (w, z, u) seed the next, so later lambdas converge
in a couple of ADMM rounds, and — because every round calls the SAME
jitted bucket program with identical shapes and static arguments — the
whole path costs exactly ``n_buckets`` prox compilations total, not per
lambda (``prox_compile_count`` deltas assert this in the bench). A
``lambda == 0`` grid entry short-circuits to the caller's dense
unpenalized fit (the same compiled program ``session.fit`` uses), which
is what pins the path's dense end to the fit verb at 1e-8.

Model selection is extended BIC over the path (Chen & Chen 2008; Foygel &
Drton 2010 for graphical models): per node,

    EBIC_i(lambda) = -2 n ll_i + df_i (log n + 2 gamma log(p - 1)),

summed over nodes; ``ll_i`` is node i's average conditional loglik at its
sparse iterate and ``df_i`` counts selected edge-block scalars.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.batched import group_soft_threshold, prox_update_batched
from ..core.graphs import Graph
from .spec import StructureSpec

__all__ = ["auto_lambda_grid", "lasso_path", "node_logliks", "ebic_scores",
           "edge_supports", "debias_to_support"]


def _node_others(graph: Graph, i: int) -> List[int]:
    """Neighbor node ids in ``graph.incident_edges(i)`` (= beta block)
    order."""
    return [graph.edges[k][0] if graph.edges[k][1] == i else graph.edges[k][1]
            for k in graph.incident_edges(i)]


def auto_lambda_grid(graph: Graph, X: np.ndarray, family,
                     spec: StructureSpec) -> Tuple[float, ...]:
    """Geometric lambda grid scaled to the data, descending.

    lambda_max is the group-lasso activation bound: the largest candidate
    edge-block norm of the average-pseudo-loglik gradient at theta = 0,
    ``max_(i,j) ||(1/n) sum_t dl/deta_c(0) f_c(x_j)||_2`` over both
    orientations — the smallest lambda at which EVERY edge block of the
    penalized solution is exactly zero (up to the free singleton). The
    grid is ``n_lambdas`` points geometric down to
    ``lambda_max * lambda_min_ratio``.
    """
    X = np.asarray(X)
    n, p = X.shape
    C = family.block_dim
    F = np.asarray(family.edge_features(X), dtype=np.float64)  # (n, p, C)
    import jax.numpy as jnp
    eta0 = jnp.zeros((p, C, n))
    r = np.asarray(family.dl_deta(eta0, jnp.asarray(X.T)),
                   dtype=np.float64)                            # (p, C, n)
    if not graph.edges:
        return tuple(np.geomspace(1.0, spec.lambda_min_ratio,
                                  spec.n_lambdas))
    I = np.array([e[0] for e in graph.edges])
    J = np.array([e[1] for e in graph.edges])
    # g[a, c] = (1/n) sum_t r[i_a, c, t] * F[t, j_a, c]  (and the swap)
    g_ab = np.einsum("act,tac->ac", r[I], F[:, J, :]) / n
    g_ba = np.einsum("act,tac->ac", r[J], F[:, I, :]) / n
    lam_max = max(float(np.linalg.norm(g_ab, axis=1).max()),
                  float(np.linalg.norm(g_ba, axis=1).max()))
    lam_max = max(lam_max, 1e-8)
    return tuple(float(l) for l in
                 np.geomspace(lam_max, lam_max * spec.lambda_min_ratio,
                              spec.n_lambdas))


def lasso_path(graph: Graph, X, lambdas: Sequence[float],
               spec: StructureSpec, family, *,
               include_singleton: bool = True,
               theta_fixed=None,
               dense_thetas: Optional[Sequence[np.ndarray]] = None,
               mesh=None, recorder=None,
               stats: Optional[dict] = None) -> List[List[np.ndarray]]:
    """Walk the descending lambda grid; return per-lambda sparse iterates.

    Returns ``zs[l][i]`` — node i's ``family.beta``-ordered iterate at
    ``lambdas[l]``, with exact zeros on unselected edge blocks. The ADMM
    state (w, z, u) carries across lambdas (warm starts); each lambda runs
    at most ``spec.admm_rounds`` rounds with a primal/dual residual early
    stop at ``spec.admm_tol``. A ``lambda == 0`` entry copies
    ``dense_thetas`` (the caller's unpenalized fit on the same candidate
    graph) instead of iterating, keeping the path's dense end bit-aligned
    with ``session.fit``.
    """
    p = graph.p
    C = family.block_dim
    lead = 1 if include_singleton else 0
    dims = [(lead + len(graph.incident_edges(i))) * C for i in range(p)]
    w = [np.zeros(d) for d in dims]
    z = [np.zeros(d) for d in dims]
    u = [np.zeros(d) for d in dims]
    zero_lam = [np.zeros(d, dtype=np.float32) for d in dims]
    rho = float(spec.admm_rho)
    rho_vecs = [np.full(d, rho, dtype=np.float32) for d in dims]

    out: List[List[np.ndarray]] = []
    for lam in lambdas:
        if lam == 0.0:
            if dense_thetas is None:
                raise ValueError(
                    "lambda == 0 in the grid needs dense_thetas — the "
                    "unpenalized fit on the candidate graph (session."
                    "select supplies it automatically)")
            z = [np.asarray(t, dtype=np.float64).copy()
                 for t in dense_thetas]
            w = [t.copy() for t in z]
            u = [np.zeros_like(t) for t in z]
            out.append([t.copy() for t in z])
            continue
        thr = lam / rho
        for _ in range(spec.admm_rounds):
            tbar = [z[i] - u[i] for i in range(p)]
            w = prox_update_batched(
                graph, X, theta_bar=tbar, lambdas=zero_lam, rhos=rho_vecs,
                thetas0=w, include_singleton=include_singleton,
                theta_fixed=theta_fixed, n_iter=spec.newton_iters,
                family=family, mesh=mesh, recorder=recorder, stats=stats)
            w = [np.asarray(wi, dtype=np.float64) for wi in w]
            z_old = z
            z = [group_soft_threshold(w[i] + u[i], thr, C, lead)
                 for i in range(p)]
            u = [u[i] + w[i] - z[i] for i in range(p)]
            r_prim = max((float(np.abs(w[i] - z[i]).max()) if dims[i] else 0.0)
                         for i in range(p))
            s_dual = rho * max(
                (float(np.abs(z[i] - z_old[i]).max()) if dims[i] else 0.0)
                for i in range(p))
            if max(r_prim, s_dual) < spec.admm_tol:
                break
        out.append([zi.copy() for zi in z])
    return out


def edge_supports(graph: Graph, zs: Sequence[np.ndarray], C: int,
                  lead: int = 1) -> np.ndarray:
    """(p, m) bool: does node i's iterate select candidate edge k?

    Reads exact zeros off the thresholded iterates — block norm > 0 means
    selected. Rows are only meaningful for edges incident to the node.
    """
    sup = np.zeros((graph.p, graph.m), dtype=bool)
    for i in range(graph.p):
        ks = graph.incident_edges(i)
        if not ks:
            continue
        blocks = np.asarray(zs[i])[lead * C:].reshape(len(ks), C)
        nz = np.linalg.norm(blocks, axis=1) > 0.0
        sup[i, ks] = nz
    return sup


def debias_to_support(graph: Graph, zs: Sequence[np.ndarray],
                      dense_thetas: Sequence[np.ndarray], C: int,
                      lead: int = 1) -> List[np.ndarray]:
    """Dense estimates masked to each iterate's support — refit-free
    debiasing.

    The lasso iterate's support is right but its surviving blocks are
    shrunk toward zero, so scoring a path point at z itself makes sparse
    models look worse than they are (EBIC then drifts dense). The cheap
    classical fix: keep the UNPENALIZED fit's values on the selected
    blocks and exact zeros elsewhere — for a sparse truth the dense fit's
    on-support coordinates are near the refit values while its off-support
    coordinates are near zero, so this approximates a per-support refit
    without compiling per-support programs (which would break the
    one-compile-per-bucket path invariant).
    """
    out = []
    for i in range(graph.p):
        ks = graph.incident_edges(i)
        t = np.asarray(dense_thetas[i], dtype=np.float64).copy()
        zb = np.asarray(zs[i])[lead * C:].reshape(len(ks), C) if ks else \
            np.zeros((0, C))
        nz = np.linalg.norm(zb, axis=1) > 0.0
        for idx in range(len(ks)):
            if not nz[idx]:
                t[(lead + idx) * C:(lead + idx + 1) * C] = 0.0
        out.append(t)
    return out


def node_logliks(graph: Graph, X, zs: Sequence[np.ndarray], family,
                 include_singleton: bool = True,
                 theta_fixed=None) -> np.ndarray:
    """(p,) average conditional loglik of each node at its own iterate.

    Evaluated with the family's closed-form channel likelihood on the
    node's beta-ordered local vector — per-node, so the (generally
    inconsistent) endpoint estimates of a shared edge never need
    reconciling just to score a path point.
    """
    import jax.numpy as jnp
    X = np.asarray(X)
    n, p = X.shape
    C = family.block_dim
    lead = 1 if include_singleton else 0
    F = np.asarray(family.edge_features(X), dtype=np.float64)  # (n, p, C)
    if theta_fixed is not None:
        node_tf = np.asarray(theta_fixed)[: p * C].reshape(p, C)
    out = np.zeros(p)
    for i in range(p):
        others = _node_others(graph, i)
        zb = np.asarray(zs[i], dtype=np.float64).reshape(
            lead + len(others), C)
        eta = np.zeros((n, C))
        if lead:
            eta += zb[0][None, :]
        elif theta_fixed is not None:
            eta += node_tf[i][None, :]
        if others:
            eta += np.einsum("njc,jc->nc", F[:, others, :], zb[lead:])
        ll = family.loglik_eta(jnp.asarray(eta.T), jnp.asarray(X[:, i]))
        out[i] = float(np.mean(np.asarray(ll)))
    return out


def ebic_scores(graph: Graph, X, path: Sequence[Sequence[np.ndarray]],
                family, spec: StructureSpec,
                include_singleton: bool = True,
                theta_fixed=None,
                debias_thetas: Optional[Sequence[np.ndarray]] = None
                ) -> np.ndarray:
    """Extended-BIC score of every path point (lower is better).

    With ``debias_thetas`` (the dense unpenalized fit on the same graph)
    each point's likelihood is evaluated at the support-masked dense
    estimates (:func:`debias_to_support`) instead of the shrunk iterates —
    without it, lasso shrinkage penalizes exactly the sparse models EBIC
    is supposed to prefer.
    """
    X = np.asarray(X)
    n, p = X.shape
    C = family.block_dim
    lead = 1 if include_singleton else 0
    complexity = math.log(n) + 2.0 * spec.ebic_gamma * math.log(max(p - 1, 1))
    scores = np.zeros(len(path))
    for l, zs in enumerate(path):
        ts = (debias_to_support(graph, zs, debias_thetas, C, lead)
              if debias_thetas is not None else zs)
        ll = node_logliks(graph, X, ts, family, include_singleton,
                          theta_fixed)
        sup = edge_supports(graph, zs, C, lead)
        df = C * sup.sum(axis=1)                                # (p,)
        scores[l] = float(np.sum(-2.0 * n * ll + df * complexity))
    return scores
