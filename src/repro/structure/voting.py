"""Distributed support voting: reconcile the two endpoints' neighborhoods.

Neighborhood selection runs one group-lasso per node, so every candidate
edge (i, j) gets TWO independent in/out verdicts — node i's and node j's —
and at finite n they disagree (Mizrahi et al. 2014 reconcile exactly such
marginal-subgraph estimates; Liu & Ihler 2014's message-sufficiency view
says what a vote message must carry: the decision, plus a confidence mass
for weighted rules). A :class:`VoteRule` turns the two verdicts into one
support decision per edge, with a signed **vote margin** in [-1, 1]
(positive = in-support; magnitude = confidence) recorded per candidate
edge.

Mirroring the family/combiner registries, rules are small strategy objects
registered by name (:func:`register_vote_rule` / :func:`get_vote_rule` /
:func:`registered_vote_rules`); unknown names fail loudly listing what is
registered, and the vote-message accounting
(:func:`repro.stream.costs.structure_vote_scalars`) reads each rule's
``scalars_per_edge_vote`` so a new rule is billed correctly without
touching the cost tables.

Registered rules:

  and       — intersection (Meinshausen-Buhlmann "min" symmetrization):
              an edge survives only if BOTH endpoints selected it. Fewest
              false positives; margin = min of the two signed votes.
  or        — union ("max" symmetrization): either endpoint suffices.
              Fewest false negatives; margin = max of the signed votes.
  weighted  — variance-weighted vote (the structure-learning twin of the
              ``weighted_vote`` combiner): each endpoint votes with mass
              1 / Vhat of its edge-block estimate (from the dense
              candidate-graph fit's sandwich diagonal — the combiner
              second-order info, reused), the signed masses are summed and
              normalized, and the sign decides. An exact mass tie falls
              back to the union rule, so the decision never depends on
              node ids — relabeling nodes permutes the support, bit-for-
              bit (tested).

Every rule is symmetric in its endpoints by construction: ``decide`` may
only combine the two votes through symmetric reductions (min/max/sum), so
support recovery is equivariant under node permutations.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

__all__ = ["VoteRule", "register_vote_rule", "get_vote_rule",
           "registered_vote_rules", "reconcile",
           "AND_VOTE", "OR_VOTE", "WEIGHTED_VOTE"]


class VoteRule:
    """One support-reconciliation strategy for candidate-edge votes.

    ``decide`` is vectorized over the candidate-edge axis and must be
    symmetric under swapping the a/b endpoint arguments (the registry's
    permutation-equivariance contract, pinned by the voting tests).
    """

    name: str = ""
    #: scalars ONE endpoint ships per candidate edge in a vote round: the
    #: in/out decision (1), plus the vote mass for mass-weighted rules —
    #: what :func:`repro.stream.costs.structure_vote_scalars` bills
    scalars_per_edge_vote: int = 1
    #: True when the rule reads the per-endpoint vote masses (inverse
    #: sandwich variances); the select verb only computes the dense
    #: candidate-graph fit's second-order info when some rule needs it
    needs_mass: bool = False

    def decide(self, in_a: np.ndarray, in_b: np.ndarray,
               mass_a: np.ndarray, mass_b: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray]:
        """(keep, margin) over candidate edges.

        in_a/in_b — (E,) bool endpoint verdicts; mass_a/mass_b — (E,)
        positive vote masses (all-ones for unweighted rules). Returns the
        (E,) bool keep mask and the (E,) signed margin in [-1, 1].
        """
        raise NotImplementedError


class AndVote(VoteRule):
    """Intersection: both endpoints must select the edge."""
    name = "and"
    scalars_per_edge_vote = 1

    def decide(self, in_a, in_b, mass_a, mass_b):
        s_a = np.where(in_a, 1.0, -1.0)
        s_b = np.where(in_b, 1.0, -1.0)
        margin = np.minimum(s_a, s_b)
        return margin > 0.0, margin


class OrVote(VoteRule):
    """Union: either endpoint suffices."""
    name = "or"
    scalars_per_edge_vote = 1

    def decide(self, in_a, in_b, mass_a, mass_b):
        s_a = np.where(in_a, 1.0, -1.0)
        s_b = np.where(in_b, 1.0, -1.0)
        margin = np.maximum(s_a, s_b)
        return margin > 0.0, margin


class WeightedVote(VoteRule):
    """Variance-weighted vote: signed masses summed, sign decides.

    margin = (s_a * m_a + s_b * m_b) / (m_a + m_b) with s = +-1 the
    endpoint verdicts — a confident (low-variance) endpoint outvotes a
    shaky one. Exact zero margin (equal masses, opposite verdicts) falls
    back to the union rule so ties resolve identically under any node
    relabeling.
    """
    name = "weighted"
    scalars_per_edge_vote = 2    # decision + vote mass
    needs_mass = True

    def decide(self, in_a, in_b, mass_a, mass_b):
        m_a = np.where(np.isfinite(mass_a) & (mass_a > 0.0), mass_a, 0.0)
        m_b = np.where(np.isfinite(mass_b) & (mass_b > 0.0), mass_b, 0.0)
        s_a = np.where(in_a, 1.0, -1.0)
        s_b = np.where(in_b, 1.0, -1.0)
        tot = m_a + m_b
        margin = np.where(tot > 0.0, (s_a * m_a + s_b * m_b)
                          / np.where(tot > 0.0, tot, 1.0), 0.0)
        keep = (margin > 0.0) | ((margin == 0.0) & (in_a | in_b))
        return keep, margin


def reconcile(in_a: np.ndarray, in_b: np.ndarray, rule,
              mass_a: Optional[np.ndarray] = None,
              mass_b: Optional[np.ndarray] = None
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Reconcile both endpoints' verdicts over the candidate-edge axis.

    ``rule`` is a :class:`VoteRule` or a registered name. ``mass_a/b``
    default to all-ones (what unweighted rules see anyway; a mass-needing
    rule then degrades to majority-of-two, which its tie fallback handles).
    Returns ``(keep, margin)`` arrays aligned with the inputs.
    """
    r = get_vote_rule(rule) if isinstance(rule, str) else rule
    in_a = np.asarray(in_a, dtype=bool)
    in_b = np.asarray(in_b, dtype=bool)
    if in_a.shape != in_b.shape:
        raise ValueError(f"endpoint verdicts disagree in shape: "
                         f"{in_a.shape} vs {in_b.shape}")
    ones = np.ones(in_a.shape, dtype=np.float64)
    m_a = ones if mass_a is None else np.asarray(mass_a, dtype=np.float64)
    m_b = ones if mass_b is None else np.asarray(mass_b, dtype=np.float64)
    return r.decide(in_a, in_b, m_a, m_b)


# --------------------------------------------------------------- registry
_VOTE_RULES: Dict[str, VoteRule] = {}


def register_vote_rule(rule: VoteRule) -> VoteRule:
    """Register (or replace) a vote rule under ``rule.name``."""
    if not rule.name:
        raise ValueError("vote rule needs a non-empty name")
    _VOTE_RULES[rule.name] = rule
    return rule


def get_vote_rule(name: str) -> VoteRule:
    """Resolve a vote rule by name; unknown names fail loudly listing the
    registered rules (the registry convention shared with families and
    combiners)."""
    try:
        return _VOTE_RULES[name]
    except KeyError:
        raise ValueError(
            f"unknown vote rule {name!r}; registered vote rules: "
            f"{sorted(_VOTE_RULES)}") from None


def registered_vote_rules() -> Tuple[VoteRule, ...]:
    """All registered vote rules, name-sorted."""
    return tuple(_VOTE_RULES[k] for k in sorted(_VOTE_RULES))


AND_VOTE = register_vote_rule(AndVote())
OR_VOTE = register_vote_rule(OrVote())
WEIGHTED_VOTE = register_vote_rule(WeightedVote())
