"""Candidate-edge screening: which pairs is the lasso even allowed to pick?

Structure learning at million-node ambitions cannot afford the complete
graph's O(p^2) edge blocks, so the select verb first builds a *candidate*
:class:`~repro.core.graphs.Graph` and only runs the group-lasso path over
its edges. Three policies (``StructureSpec.policy``):

  full   — every pair. Exact, O(p^2) candidates; the right default for
           the paper-scale benchmarks, and the policy whose candidate
           graph is data-independent (so repeat selects on fresh
           same-shape data reuse every compiled solver — the bench's
           warm == 0 assertion runs under ``full``).
  knn    — per-node top-k screening, union-symmetrized: keep (i, j) when
           j is among i's k most correlated nodes OR vice versa. The
           screen is family-generic — it correlates the *edge features*
           ``family.edge_features(X)`` channel-wise and takes the max
           |corr| over the C x C channel pairs — so Potts indicator
           channels screen as correctly as Ising spins.
  given  — the caller's explicit edge set, normalized to i < j order.

All policies return a plain ``Graph``, so the downstream path solver,
voting, and comm accounting never care how the candidates were chosen.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.graphs import Graph, complete_graph
from .spec import StructureSpec

__all__ = ["candidate_graph"]


def _knn_screen(X: np.ndarray, k: int, family) -> Graph:
    """Union-of-top-k screening on max channel |correlation|."""
    n, p = X.shape
    C = family.block_dim
    F = np.asarray(family.edge_features(X), dtype=np.float64)  # (n, p, C)
    F = F.reshape(n, p * C)
    F = F - F.mean(axis=0, keepdims=True)
    sd = F.std(axis=0)
    F = F / np.where(sd > 0.0, sd, 1.0)
    corr = np.abs(F.T @ F) / max(n, 1)                          # (pC, pC)
    # max |corr| over the C x C channel block of each node pair
    score = corr.reshape(p, C, p, C).max(axis=(1, 3))           # (p, p)
    np.fill_diagonal(score, -np.inf)
    edges = set()
    for i in range(p):
        # deterministic top-k: sort by (-score, node id)
        order = np.lexsort((np.arange(p), -score[i]))[:k]
        for j in order:
            j = int(j)
            if j != i:
                edges.add((min(i, j), max(i, j)))
    return Graph(p, tuple(sorted(edges)))


def candidate_graph(spec: StructureSpec, p: int,
                    X: Optional[np.ndarray] = None,
                    family=None) -> Graph:
    """Build the candidate-edge graph ``session.select`` searches over.

    ``X``/``family`` are only consulted by the ``knn`` policy (the screen
    is data-dependent); ``full`` and ``given`` are shape-only.
    """
    if spec.policy == "full":
        return complete_graph(p)
    if spec.policy == "given":
        return Graph(p, tuple(sorted(spec.given_edges)))
    # knn
    if spec.knn_k >= p:
        raise ValueError(
            f"knn_k must be < p (a node has at most p-1 = {p - 1} "
            f"neighbors); got knn_k={spec.knn_k} with p={p} — use "
            f"policy 'full' to consider every pair")
    if X is None or family is None:
        raise ValueError("policy 'knn' screens on data: candidate_graph "
                         "needs X and family")
    X = np.asarray(X)
    if X.ndim != 2 or X.shape[1] != p:
        raise ValueError(f"X must be (n, p={p}); got {X.shape}")
    return _knn_screen(X, spec.knn_k, family)
