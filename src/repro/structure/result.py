"""The structure verb's return type: an estimated edge set with receipts.

A :class:`StructureResult` carries everything a caller needs to audit HOW
the support was chosen, mirroring :class:`repro.api.EstimateResult`'s
philosophy — the selected graph plus the full decision trail: the lambda
path walked, the EBIC curve and its argmin, every candidate edge's vote
margin, the exact vote-message scalar bill, and the compile/wall split.
``edge_metrics(true_edges)`` scores the recovery against a known
generator (precision / recall / F1 — what the planted-graph bench
asserts).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.graphs import Edge, Graph

__all__ = ["StructureResult"]


@dataclasses.dataclass
class StructureResult:
    """What ``session.select`` returns.

    support        — the voted edge set, (i, j) pairs with i < j.
    graph          — the same support as a :class:`~repro.core.Graph`
                     (ready to drop into a new ``Plan`` and fit).
    candidate_edges — the screened candidate set the path searched over.
    vote_rule      — name of the rule that reconciled the endpoints.
    margins        — per *candidate* edge signed vote margin in [-1, 1]
                     (aligned with ``candidate_edges``; > 0 means kept).
    lambdas        — the descending grid actually walked.
    lambda_selected — EBIC's pick.
    ebic           — per-lambda EBIC scores (aligned with ``lambdas``).
    support_sizes  — per-lambda VOTED support size (the path's sparsity
                     trace, after reconciliation).
    thetas         — per-node beta-ordered estimates at the selected
                     lambda: the dense fit's values masked to the
                     selected support (refit-free debiasing; exact zeros
                     off-support).
    n_samples      — rows of X consumed.
    comm_scalars   — exact vote-message bill from
                     :func:`repro.stream.costs.structure_vote_scalars`.
    wall_s / compile_s — select wall clock and the compile share.
    path_compiles  — prox-solver programs compiled during the path
                     (== n_buckets cold, 0 warm — the bench invariant).
    new_compiles   — total new programs (fit + prox) this call triggered.
    telemetry      — span/counter snapshot when the plan enables it.
    """

    support: Tuple[Edge, ...]
    graph: Graph
    candidate_edges: Tuple[Edge, ...]
    vote_rule: str
    margins: np.ndarray
    lambdas: Tuple[float, ...]
    lambda_selected: float
    ebic: np.ndarray
    support_sizes: Tuple[int, ...]
    thetas: List[np.ndarray]
    n_samples: int
    comm_scalars: int
    wall_s: float
    compile_s: float
    path_compiles: int
    new_compiles: int
    telemetry: Optional[dict] = None

    def edge_metrics(self, true_edges) -> Dict[str, float]:
        """Precision / recall / F1 of ``support`` against a known edge set."""
        truth = {(min(i, j), max(i, j)) for i, j in true_edges}
        got = set(self.support)
        tp = len(got & truth)
        prec = tp / len(got) if got else (1.0 if not truth else 0.0)
        rec = tp / len(truth) if truth else 1.0
        f1 = (2 * prec * rec / (prec + rec)) if (prec + rec) > 0 else 0.0
        return {"precision": prec, "recall": rec, "f1": f1,
                "tp": float(tp), "fp": float(len(got - truth)),
                "fn": float(len(truth - got))}

    def __repr__(self):
        return (f"StructureResult(|support|={len(self.support)}, "
                f"|candidates|={len(self.candidate_edges)}, "
                f"vote={self.vote_rule!r}, "
                f"lambda={self.lambda_selected:.4g}, "
                f"comm_scalars={self.comm_scalars}, "
                f"wall_s={self.wall_s:.3f})")
