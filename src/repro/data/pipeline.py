"""Data pipeline: deterministic synthetic LM token streams (document-style,
EOS-delimited, Zipfian unigrams with a bigram mixing kernel so the loss is
learnable), shardable by (pod, data) for the consensus trainer, plus the
Ising data module feeding the paper's estimators.

Everything is seeded and stateless-resumable: batch ``i`` of host ``h`` is a
pure function of (seed, h, i) — the property checkpoint-resume tests rely on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos_id: int = 0
    zipf_a: float = 1.2
    mean_doc_len: int = 512


class SyntheticLM:
    """Deterministic synthetic token stream with document structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = 1.0 / ranks ** cfg.zipf_a
        self._probs = probs / probs.sum()

    def batch(self, index: int, shard: int = 0, n_shards: int = 1) -> Dict:
        """Batch ``index`` for shard ``shard`` — pure function of inputs."""
        cfg = self.cfg
        b = cfg.global_batch // n_shards
        rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + index * 9_973 + shard * 7) % 2**31)
        toks = rng.choice(cfg.vocab_size, size=(b, cfg.seq_len + 1),
                          p=self._probs).astype(np.int32)
        # bigram structure: with prob .5 next token = (prev * 31 + 7) % V
        mix = rng.rand(b, cfg.seq_len) < 0.5
        nxt = (toks[:, :-1] * 31 + 7) % cfg.vocab_size
        toks[:, 1:] = np.where(mix, nxt, toks[:, 1:])
        # EOS-delimited documents
        doc_breaks = rng.rand(b, cfg.seq_len + 1) < (1.0 / cfg.mean_doc_len)
        toks = np.where(doc_breaks, cfg.eos_id, toks)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    def __iter__(self) -> Iterator[Dict]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


def pod_sharded_batches(ds: SyntheticLM, n_pods: int, h_steps: int,
                        start_round: int = 0) -> Iterator[Dict]:
    """Batches for one consensus round: (P, H, local_batch, S) arrays.

    Each pod sees a DISJOINT slice of the stream — the paper's per-sensor
    local datasets X_A(i)."""
    r = start_round
    while True:
        per_pod = []
        for pod in range(n_pods):
            steps = [ds.batch(r * h_steps + h, shard=pod, n_shards=n_pods)
                     for h in range(h_steps)]
            per_pod.append(jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *steps))
        yield jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_pod)
        r += 1


def ising_batches(model, n: int, n_batches: int, key,
                  sampler: str = "gibbs"):
    """Streaming Ising datasets for the paper's estimators."""
    from repro.core import exact_sample, gibbs_sample
    for i in range(n_batches):
        key, sub = jax.random.split(key)
        if sampler == "exact":
            yield exact_sample(model, n, sub)
        else:
            yield gibbs_sample(model, n, sub)
