"""AdamW built from scratch (no optax dependency), pytree-native.

The second-moment EMA ``v`` doubles as the per-parameter empirical Fisher
diagonal — exactly the 1/Vhat weight the paper's Prop 4.4/4.7 uses for
diagonal/max consensus. ``fisher_diag(state)`` exposes it; the consensus
trainer reads it with zero extra communication (the paper's key point about
max-consensus weights being locally computable).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(zeros, params),
                      v=jax.tree_util.tree_map(zeros, params))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """One AdamW step. Returns (new_params, new_state)."""
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m_new, v_new

    out = jax.tree_util.tree_map(upd, grads, state.m, state.v, params)
    new_params = jax.tree_util.tree_map(lambda o: o[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda o: o[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)


def fisher_diag(state: AdamWState):
    """Per-parameter empirical Fisher proxy (bias-corrected grad^2 EMA).

    This is the paper's 1/Vhat^i_aa diagonal weight at pod granularity —
    available with NO extra communication (Prop 4.4's practical advantage).
    """
    b2c = 1 - 0.95 ** jnp.maximum(state.step.astype(jnp.float32), 1.0)
    return jax.tree_util.tree_map(lambda v: v / b2c, state.v)
