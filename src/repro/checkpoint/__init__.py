"""Durable checkpoints: pytree and streaming-state save/restore.

:func:`save`/:func:`restore` move arbitrary pytrees through the atomic
``step_<N>/arrays.npz + manifest.json`` layout; :func:`save_state`/
:func:`load_state` do the same for flat array dicts with a JSON meta blob;
:func:`save_stream`/:func:`restore_stream` capture a full
:class:`~repro.stream.simulator.StreamSimulator` mid-stream so a killed
fleet restores to bit-identical ``estimate_at(t)`` trajectories.
"""
from .io import (latest_step, load_state, restore, restore_stream, save,
                 save_state, save_stream)
