"""Checkpointing: pytree <-> npz + JSON manifest. No orbax dependency.

Layout: <dir>/step_<N>/arrays.npz + manifest.json. Keys are '/'-joined
pytree paths; restore rebuilds the exact tree structure. Atomic via
write-to-tmp + rename. Works for TrainState, ConsensusState, caches.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(directory: str, step: int, tree, extra: Optional[Dict] = None):
    """Save pytree at <directory>/step_<step>; returns the final path."""
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def restore(directory: str, step: int, like) -> Any:
    """Restore into the structure of ``like`` (a pytree template)."""
    path = os.path.join(directory, f"step_{step}")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
    vals = []
    for kpath, leaf in leaves_with_path[0]:
        key = "/".join(_path_str(p) for p in kpath)
        arr = data[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        vals.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(leaves_with_path[1], vals)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


# ------------------------------------------------------- stateful objects
def save_state(directory: str, step: int, arrays: Dict[str, np.ndarray],
               meta: Dict) -> str:
    """Save a flat name->array dict plus a JSON meta blob (same atomic
    step_<N> layout as :func:`save`; ``meta`` rides in the manifest's
    ``extra`` field — JSON float reprs round-trip float64 exactly)."""
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays = {k: np.asarray(v) for k, v in arrays.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "extra": meta,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_state(directory: str,
               step: Optional[int] = None) -> Tuple[Dict[str, np.ndarray],
                                                    Dict]:
    """Inverse of :func:`save_state`; ``step=None`` loads the latest."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(
                f"no step_<N> checkpoints under {directory!r}")
    path = os.path.join(directory, f"step_{step}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        arrays = {k: data[k] for k in data.files}
    with open(os.path.join(path, "manifest.json")) as f:
        meta = json.load(f)["extra"]
    return arrays, meta


def save_stream(directory: str, step: int, sim) -> str:
    """Durable mid-stream checkpoint of a
    :class:`~repro.stream.simulator.StreamSimulator`: buffers, warm
    thetas, fitted banks, owed messages, in-flight queue, comm counters
    and every RNG state — everything
    :meth:`~repro.stream.simulator.StreamSimulator.state_dict` reports."""
    arrays, meta = sim.state_dict()
    return save_state(directory, step, arrays, meta)


def restore_stream(directory: str, sim, step: Optional[int] = None):
    """Restore ``sim`` (a freshly constructed simulator with the same
    configuration — graph, pool, scheme, network config, faults, seed)
    from a :func:`save_stream` checkpoint, in place; returns ``sim``. The
    restored fleet's ``estimate_at(t)`` trajectory continues bit-identical
    to the uninterrupted run."""
    arrays, meta = load_state(directory, step)
    sim.load_state(arrays, meta)
    return sim
