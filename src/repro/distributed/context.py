"""Ambient mesh context: lets deep model internals (MoE dispatch buffers,
attention caches) place with_sharding_constraint on intermediates without
threading the mesh through every call signature.

Set by the launchers/dry-run (``use_mesh``); absent on single-device test
runs, where constraints are skipped.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CURRENT: list = []


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    _CURRENT.append(mesh)
    try:
        yield mesh
    finally:
        _CURRENT.pop()


def current_mesh() -> Optional[Mesh]:
    return _CURRENT[-1] if _CURRENT else None


def constrain(x, *axes):
    """with_sharding_constraint(x, P(*axes)) if a mesh is active and every
    named axis divides the corresponding dim; identity otherwise."""
    mesh = current_mesh()
    if mesh is None:
        return x
    fixed = []
    for dim, ax in zip(x.shape, axes):
        names = ax if isinstance(ax, tuple) else (ax,) if ax else ()
        ok = all(n in mesh.shape for n in names)
        size = 1
        for n in names:
            size *= mesh.shape.get(n, 1)
        if ax is None or not ok or dim % size != 0:
            fixed.append(None)
        else:
            fixed.append(ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))
