"""Sharding resolution: logical parameter axes -> mesh PartitionSpecs.

Rules:
  * A ParamSpec axis labeled "model" / "vocab" / "expert" is a CANDIDATE for
    the mesh "model" axis. The first candidate (left-to-right in the spec's
    preference order) whose dim size divides the mesh axis size wins; the
    rest replicate. This is the divisibility guard that makes every arch
    (6-head whisper, 60-expert qwen, kv=2 glm4) lower on a 16-way axis.
  * Batch-like inputs shard over ("pod", "data") for the sync trainer and
    over "data" within a pod replica for the consensus trainer.
  * Caches shard by structural convention (see cache_pspec).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ArchConfig, ParamSpec

MODEL_LABELS = ("model", "vocab", "expert")


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def param_pspec(ps: ParamSpec, mesh: Mesh,
                pod_replicated: bool = True) -> P:
    """Resolve one ParamSpec to a PartitionSpec with the divisibility guard."""
    msize = _axis_size(mesh, "model")
    entries = [None] * len(ps.shape)
    for i, (label, dim) in enumerate(zip(ps.axes, ps.shape)):
        if label in MODEL_LABELS and dim % msize == 0:
            entries[i] = "model"
            break  # one model-sharded dim per tensor
    return P(*entries)


def param_shardings(tree, mesh: Mesh):
    """ParamSpec pytree -> NamedSharding pytree."""
    return jax.tree_util.tree_map(
        lambda ps: NamedSharding(mesh, param_pspec(ps, mesh)),
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def stacked_param_shardings(tree, mesh: Mesh):
    """Consensus trainer: per-pod replicas stacked on a leading 'pod' dim."""
    def f(ps: ParamSpec):
        inner = param_pspec(ps, mesh)
        return NamedSharding(mesh, P("pod", *inner))
    return jax.tree_util.tree_map(
        f, tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def like_params(shardings_tree, target_tree):
    """Broadcast a params sharding tree onto a same-structure tree (e.g.
    optimizer moments)."""
    return jax.tree_util.tree_map(lambda s, _: s, shardings_tree, target_tree)


# ----------------------------------------------------------------- batches
def batch_pspec(mesh: Mesh, batch: int, ndim: int, *,
                pod_major: bool = False) -> P:
    """Token batches: shard dim 0 over the largest valid data-ish axes."""
    names = [n for n in ("pod", "data") if n in mesh.shape]
    combo: Tuple[str, ...] = tuple(names)
    size = int(np.prod([mesh.shape[n] for n in combo]))
    if batch % size == 0:
        first = combo if len(combo) > 1 else combo[0]
    elif batch % mesh.shape.get("data", 1) == 0:
        first = "data"
    else:
        first = None
    return P(first, *([None] * (ndim - 1)))


def consensus_batch_pspec(mesh: Mesh, local_batch: int, ndim: int) -> P:
    """(P, H, local_batch, ...) batches: pod on dim0, data on dim2."""
    data_ok = local_batch % mesh.shape["data"] == 0
    return P("pod", None, "data" if data_ok else None,
             *([None] * (ndim - 3)))


# ------------------------------------------------------------------ caches
def cache_pspec(key_name: str, shape: Tuple[int, ...], mesh: Mesh,
                stacked: bool) -> P:
    """Structural cache sharding (see module docstring).

    k/v:  (stack?, B, L, KH, HD)  -> B: data, KH: model (if divisible)
    ckv:  (stack?, B, L, R)       -> B: data
    C:    (stack?, B, NH, HD, HD) -> B: data, first HD: model
    h/c/n/m/conv: last dim model if divisible, B: data
    """
    msize = _axis_size(mesh, "model")
    dsize = _axis_size(mesh, "data")
    off = 1 if stacked else 0
    entries: list = [None] * len(shape)
    bdim = off
    if bdim < len(shape) and shape[bdim] % dsize == 0:
        entries[bdim] = "data"
    if key_name in ("k", "v") and len(shape) >= off + 4:
        kh = shape[off + 2]
        if kh % msize == 0:
            entries[off + 2] = "model"
        elif shape[off + 1] % msize == 0:
            entries[off + 1] = "model"       # sequence-sharded cache
    elif key_name == "C" and len(shape) >= off + 4:
        if shape[off + 2] % msize == 0:
            entries[off + 2] = "model"       # heads
        elif shape[off + 3] % msize == 0:
            entries[off + 3] = "model"       # head_dim (xlstm: 4 heads, 512)
    elif key_name in ("h", "c", "n", "m", "conv", "ckv"):
        last = len(shape) - 1
        if last > bdim and shape[last] % msize == 0:
            entries[last] = "model"
    return P(*entries)


def cache_shardings(cache_tree, mesh: Mesh):
    """ShapeDtypeStruct cache tree -> NamedSharding tree by key convention."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    out = []
    for path, leaf in flat:
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = str(p.key)
                break
        # unit-scanned caches carry a leading stack dim ("units" subtree);
        # remainder-layer caches ("rem" subtree) do not.
        stacked = any(hasattr(p, "key") and str(p.key) == "units"
                      for p in path)
        out.append(NamedSharding(mesh,
                                 cache_pspec(name or "", leaf.shape, mesh,
                                             stacked)))
    return jax.tree_util.tree_unflatten(treedef, out)
