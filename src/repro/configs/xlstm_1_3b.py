"""xLSTM-1.3B [arXiv:2405.04517]: 48 blocks d=2048, 4 heads, 7 mLSTM : 1
sLSTM pattern, no separate FFN (d_ff=0; blocks carry their own
projections). Attention-free: long_500k runs natively from (C, n, m)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=512,
    pattern=("m", "m", "m", "m", "m", "m", "m", "s"),
    mlstm_heads=4, proj_factor=2.0, conv_width=4,
    pos_emb="none", act="geglu", long_variant="native",
)
