"""GLM-4-9B [hf:THUDM/glm-4-9b]: dense 40L d=4096 32H (kv=2) d_ff=13696,
vocab 151552, RoPE + SwiGLU + extreme GQA (kv=2)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab_size=151552, head_dim=128,
    pattern=("attn",), rope_theta=10_000.0, act="swiglu",
    long_variant="swa",
)
