"""Phi-3-mini 3.8B [arXiv:2404.14219]: dense 32L d=3072 32H (kv=32)
d_ff=8192, vocab 32064, RoPE + SwiGLU."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064, head_dim=96,
    pattern=("attn",), rope_theta=10_000.0, act="swiglu",
    long_variant="swa",
)
