"""Assigned-architecture registry: ``get(arch_id)`` -> ArchConfig,
``reduced(cfg)`` -> CPU-smoke-testable variant of the same family."""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.common import ArchConfig

ARCH_IDS = (
    "qwen2_moe_a2_7b",
    "phi3_mini_3_8b",
    "whisper_tiny",
    "llama3_2_3b",
    "glm4_9b",
    "recurrentgemma_2b",
    "chameleon_34b",
    "llama4_scout_17b_a16e",
    "minicpm3_4b",
    "xlstm_1_3b",
)

# external ids (dashes) map to module names (underscores)
ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def get(arch_id: str) -> ArchConfig:
    mod_name = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs():
    return {a: get(a) for a in ARCH_IDS}


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Shrink a config to a CPU-runnable variant of the same family:
    <=2 pattern repeats, d_model<=512, <=4 experts, tiny vocab."""
    n_layers = len(cfg.pattern) * min(2, max(1, cfg.n_units))
    d_model = min(cfg.d_model, 256)
    n_heads = max(2, min(cfg.n_heads, 4))
    hd = d_model // n_heads
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    if cfg.n_kv_heads >= cfg.n_heads:
        n_kv = n_heads
    repl = dict(
        n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        n_kv_heads=n_kv, head_dim=hd,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 1024),
        window=min(cfg.window, 64) if cfg.window else 0,
        max_target_len=2048,
        dtype="float32",   # smoke tests check exact math; bf16 is TPU-only
    )
    if cfg.n_experts:
        repl.update(n_experts=4,
                    experts_per_tok=min(cfg.experts_per_tok, 2),
                    n_shared_experts=min(cfg.n_shared_experts, 1),
                    d_expert=min(cfg.d_expert or 256, 256))
    if cfg.enc_dec:
        repl.update(n_enc_layers=2, n_frames=16)
    if cfg.n_patches:
        repl.update(n_patches=4)
    if cfg.rglru_width:
        repl.update(rglru_width=d_model)
    if cfg.attn_kind == "mla":
        repl.update(q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=32,
                    qk_rope_dim=16, v_head_dim=32, head_dim=48)
    if cfg.mlstm_heads:
        repl.update(mlstm_heads=2)
    return dataclasses.replace(cfg, **repl)
