"""Llama-3.2-3B [hf:meta-llama/Llama-3.2-*]: dense 28L d=3072 24H (kv=8)
d_ff=8192, vocab 128256, RoPE + SwiGLU + GQA."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab_size=128256, head_dim=128,
    pattern=("attn",), rope_theta=500_000.0, act="swiglu",
    long_variant="swa",
)
