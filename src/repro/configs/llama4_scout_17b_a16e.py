"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E]: MoE 48L
d=5120 40H (kv=8), 16 routed experts top-1 + 1 shared (d_expert=8192),
vocab 202048, early fusion: vision encoder is a STUB — input_specs supplies
precomputed patch embeddings fused at the sequence head."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    pattern=("attn_moe",),
    n_experts=16, experts_per_tok=1, n_shared_experts=1, d_expert=8192,
    n_patches=64,
    rope_theta=500_000.0, act="swiglu", long_variant="swa",
)
