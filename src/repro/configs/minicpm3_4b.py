"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B]: dense 62L d=2560 40H with MLA
(multi-head latent attention: q_lora=768, kv_lora=256, nope=64, rope=32,
v=64), d_ff=6400, vocab 73448. Decode caches the compressed latent."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab_size=73448, head_dim=96,
    pattern=("attn",), attn_kind="mla",
    q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64, qk_rope_dim=32,
    v_head_dim=64,
    rope_theta=10_000.0, act="swiglu", long_variant="swa",
)
