"""Chameleon-34B [arXiv:2405.09818]: early-fusion VLM, 48L d=8192 64H (kv=8)
d_ff=22016, vocab 65536 (text + VQ image tokens share the vocab — the
early-fusion design means image tokens ARE tokens; no patch stub needed),
qk-norm as in the paper."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=65536, head_dim=128,
    pattern=("attn",), qk_norm=True,
    rope_theta=10_000.0, act="swiglu", long_variant="swa",
)
