"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d=2048 16H (kv=16)
MoE 60 experts top-4 + 4 shared experts (d_expert=1408), vocab 151936."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=151936, head_dim=128,
    pattern=("attn_moe",),
    n_experts=60, experts_per_tok=4, n_shared_experts=4, d_expert=1408,
    rope_theta=1_000_000.0, act="swiglu", long_variant="swa",
)
