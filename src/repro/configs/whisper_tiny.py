"""Whisper-tiny [arXiv:2212.04356]: enc-dec 4+4L d=384 6H d_ff=1536,
vocab 51865, GELU + LayerNorm + learned positions. Conv/mel frontend is a
STUB per the brief: input_specs supplies precomputed frame embeddings."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865, head_dim=64,
    pattern=("xattn",), enc_dec=True, n_enc_layers=4, n_frames=1500,
    act="gelu", norm="layer", pos_emb="learned", long_variant="swa",
)
