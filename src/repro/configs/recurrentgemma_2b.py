"""RecurrentGemma-2B / Griffin [arXiv:2402.19427]: hybrid 26L d=2560
10H (MQA kv=1, local window 2048), d_ff=7680 GeGLU, RG-LRU width 2560,
pattern 2 recurrent : 1 local-attention. Runs long_500k natively."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab_size=256000, head_dim=256,
    pattern=("rec", "rec", "attn"), window=2048,
    rglru_width=2560, conv_width=4,
    rope_theta=10_000.0, act="geglu", long_variant="native",
)
