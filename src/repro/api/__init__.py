"""The declarative estimation-plan API: one :class:`Plan` -> a compiled
:class:`EstimationSession` with three verbs sharing one solver cache.

This is the stable facade the serving / scale-out layers target. Declare
the whole problem once —

    import repro.api as A
    plan = A.Plan(graph=g, family="ising",
                  combiners=("diagonal", "max"), mesh=None)
    sess = plan.session()              # cached per plan; compiles lazily

    result = sess.fit(X)               # batch: local fits + combiners
    est = sess.stream()                # plan-bound StreamingEstimator
    joint = sess.joint(X)              # ADMM joint MPLE (Sec. 3.2)
    struct = sess.select(X)            # structure learning (lasso + vote)

— and every batch verb returns a structured :class:`EstimateResult` (theta,
per-scheme combined estimates, per-node fits, pseudo-score norm,
wall/compile counters, communication scalars); ``select`` returns a
:class:`~repro.structure.StructureResult` (voted support, EBIC-selected
lambda, per-edge vote margins, comm scalars). Combination schemes are
pluggable strategies from the combiner registry
(:mod:`repro.core.combiners`); model families come from the family registry
(:mod:`repro.core.families`); vote rules from the vote-rule registry
(:mod:`repro.structure.voting`); plans serialize via
``to_dict``/``from_dict`` and hash-key the session cache.

The legacy entry points (``repro.core.fit_all_local`` + ``combine``,
``admm_mple``, direct ``StreamingEstimator``/``StreamSimulator``
construction) remain as thin shims over a default plan.
"""
from ..structure import StructureResult, StructureSpec
from ..telemetry import TelemetrySpec
from .plan import MESH_POLICIES, Plan
from .result import EstimateResult
from .session import EstimationSession, compile_plan

__all__ = ["Plan", "EstimationSession", "EstimateResult", "compile_plan",
           "MESH_POLICIES", "TelemetrySpec", "StructureSpec",
           "StructureResult"]
