"""The declarative estimation plan.

A :class:`Plan` is the *complete*, frozen, hashable description of one
distributed-estimation problem: the graph, the model family, the requested
combination schemes, solver options, precision, mesh policy, and the
streaming/ADMM configuration. It is everything the kwarg soup of
``fit_all_local`` / ``combine`` / ``admm_mple`` / ``StreamingEstimator`` /
``StreamSimulator`` used to thread separately — declared once, up front.

Because a plan is hashable it can key caches: compiling a plan yields an
:class:`~repro.api.session.EstimationSession` (cached per plan, so two equal
plans share one session and therefore one set of jitted bucket solvers),
and a plan can ride along as a static jit argument. ``to_dict`` /
``from_dict`` round-trip exactly, so plans serialize into configs, logs,
and benchmark JSON.

Families and combiners are referenced by *registry name* (the instances
themselves stay in :mod:`repro.core.families` / :mod:`repro.core.combiners`)
— that is what keeps a plan a plain value object and makes the
serialization unambiguous.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from ..core.combiners import get_combiner
from ..core.families import get_family
from ..core.graphs import Graph

#: mesh policies a plan may request; actual Mesh objects are resolved at
#: session-compile time (they hold device handles and do not serialize)
MESH_POLICIES = (None, "host", "data")

_PRECISIONS = ("float32", "float64", "bfloat16")
_ADMM_INITS = ("zero", "uniform", "diagonal")


@dataclasses.dataclass(frozen=True)
class Plan:
    """Declarative description of one estimation problem.

    Parameters
    ----------
    graph : the conditional-independence graph == the sensor network.
    family : registry name of the model family ("ising", "gaussian",
        "potts", ...). Resolved through ``repro.core.families.get_family``.
    combiners : registry names of the one-step combination schemes the
        session should produce, in priority order — the first one is the
        headline ``EstimateResult.theta``. Resolved through
        ``repro.core.combiners.get_combiner``; the session only computes
        second-order objects (influence stacks, cross-covariances) when
        some listed combiner declares it needs them.
    include_singleton : estimate singleton blocks (False fixes them at
        ``theta_fixed`` — the paper's known-singleton small experiments).
    theta_fixed : fixed coordinates as a plain tuple of floats (hashable);
        None means zeros.
    n_iter : damped-Newton budget per local solve.
    mesh : mesh policy — None (single program), "host" (the degenerate
        1x1 host mesh; numerically identical, exercises the shard_map
        path), or "data" (shard bucket nodes over all visible devices
        along a ``data`` axis).
    precision : dtype the sample matrix is cast to before solves.
        "float64" requires jax x64 to be enabled (``JAX_ENABLE_X64=1``);
        a session verb fed samples without it raises rather than silently
        truncating to float32. "bfloat16" is the opt-in mixed-precision
        mode: designs and kernel loads/matmuls run in bf16 while the
        score/curvature Gram accumulators and all Newton solver state stay
        float32 (tolerances in
        :data:`repro.kernels.cl.precision.PRECISION_TOLERANCES`). Applies
        to the batch/joint verbs — the streaming buffer is float32 by
        design (see :class:`~repro.stream.buffer.SampleBuffer`).
    capacity : initial sample-buffer capacity for ``session.stream()``.
    admm_iters / admm_init / admm_newton_iters / admm_rho : the
        ``session.joint`` ADMM configuration (Sec. 3.2; ``admm_init`` of
        "uniform"/"diagonal" starts from that one-step consensus,
        ``admm_rho`` scales the "zero"-init unit penalties).
    faults : optional :class:`~repro.stream.faults.FaultPlan` — the
        hostile-network scenario ``session.simulate`` executes (crash
        schedules, Byzantine corruption, replay, parameter drift). Frozen
        and hashable like the plan itself.
    telemetry : optional :class:`~repro.telemetry.TelemetrySpec` — turn on
        the instrumentation layer (spans, metrics, JSONL event log) for
        every verb of this plan's session and for simulators built from
        it. None (the default) keeps the allocation-free
        :data:`~repro.telemetry.NULL_RECORDER` on every hot path. Frozen
        and serialized like ``faults``.
    stream_window / stream_discount : drift-tracking re-fit windows for
        the streaming verbs — keep only each node's most recent
        ``stream_window`` samples, and/or decay age-k samples by
        ``stream_discount**k`` (see ``SampleBuffer.window_weights``).
    structure : optional :class:`~repro.structure.StructureSpec` — the
        configuration ``session.select`` (the structure-learning verb)
        uses: candidate-edge policy, lambda grid/path, vote rule, ADMM and
        EBIC knobs. None leaves the verb usable with its defaults (or a
        per-call spec). Frozen and serialized like ``faults``.
    """

    graph: Graph
    family: str = "ising"
    combiners: Tuple[str, ...] = ("diagonal",)
    include_singleton: bool = True
    theta_fixed: Optional[Tuple[float, ...]] = None
    n_iter: int = 40
    mesh: Optional[str] = None
    precision: str = "float32"
    capacity: int = 64
    admm_iters: int = 30
    admm_init: str = "diagonal"
    admm_newton_iters: int = 15
    admm_rho: float = 1.0
    faults: Optional["FaultPlan"] = None
    stream_window: Optional[int] = None
    stream_discount: Optional[float] = None
    telemetry: Optional["TelemetrySpec"] = None
    structure: Optional["StructureSpec"] = None

    def __post_init__(self):
        if not isinstance(self.graph, Graph):
            raise TypeError(f"plan.graph must be a Graph, got "
                            f"{type(self.graph).__name__}")
        get_family(self.family)                      # raises listing names
        if isinstance(self.combiners, str):
            object.__setattr__(self, "combiners", (self.combiners,))
        else:
            object.__setattr__(self, "combiners", tuple(self.combiners))
        if not self.combiners:
            raise ValueError("plan needs at least one combiner")
        for name in self.combiners:
            get_combiner(name)                       # raises listing names
        if self.theta_fixed is not None:
            tf = tuple(float(v) for v in self.theta_fixed)
            expect = get_family(self.family).n_params(self.graph)
            if len(tf) != expect:
                raise ValueError(
                    f"theta_fixed has {len(tf)} entries; family "
                    f"{self.family!r} on this graph has {expect} params")
            object.__setattr__(self, "theta_fixed", tf)
        if self.mesh not in MESH_POLICIES:
            raise ValueError(f"unknown mesh policy {self.mesh!r}; "
                             f"choose from {MESH_POLICIES}")
        if self.precision not in _PRECISIONS:
            raise ValueError(f"unknown precision {self.precision!r}; "
                             f"choose from {_PRECISIONS}")
        if self.admm_init not in _ADMM_INITS:
            raise ValueError(f"unknown admm_init {self.admm_init!r}; "
                             f"choose from {_ADMM_INITS}")
        if self.n_iter < 1 or self.admm_iters < 1 \
                or self.admm_newton_iters < 1:
            raise ValueError("iteration budgets must be >= 1")
        if self.capacity < 1:
            raise ValueError("capacity must be >= 1")
        if not (self.admm_rho > 0.0 and np.isfinite(self.admm_rho)):
            raise ValueError(
                f"admm_rho must be a finite positive penalty, got "
                f"{self.admm_rho!r} (zero rhos make the weighted consensus "
                f"average 0/0)")
        from ..stream.faults import FaultPlan
        if self.faults is not None:
            if isinstance(self.faults, dict):
                object.__setattr__(self, "faults",
                                   FaultPlan.from_dict(self.faults))
            elif not isinstance(self.faults, FaultPlan):
                raise TypeError(
                    f"plan.faults must be a FaultPlan (or its to_dict "
                    f"form), got {type(self.faults).__name__}")
        from ..telemetry.spec import TelemetrySpec
        if self.telemetry is not None:
            if isinstance(self.telemetry, dict):
                object.__setattr__(self, "telemetry",
                                   TelemetrySpec.from_dict(self.telemetry))
            elif not isinstance(self.telemetry, TelemetrySpec):
                raise TypeError(
                    f"plan.telemetry must be a TelemetrySpec (or its "
                    f"to_dict form), got {type(self.telemetry).__name__}")
        from ..structure.spec import StructureSpec
        if self.structure is not None:
            if isinstance(self.structure, dict):
                object.__setattr__(self, "structure",
                                   StructureSpec.from_dict(self.structure))
            elif not isinstance(self.structure, StructureSpec):
                raise TypeError(
                    f"plan.structure must be a StructureSpec (or its "
                    f"to_dict form), got {type(self.structure).__name__}")
            s = self.structure
            # the one check the spec cannot run alone: k against this
            # plan's node count
            if s.policy == "knn" and s.knn_k >= self.graph.p:
                raise ValueError(
                    f"structure.knn_k must be < p (a node has at most "
                    f"p-1 = {self.graph.p - 1} neighbors); got "
                    f"knn_k={s.knn_k} with p={self.graph.p} — use policy "
                    f"'full' to consider every pair")
            if s.policy == "given":
                for (a, b) in s.given_edges:
                    if not (0 <= a < b < self.graph.p):
                        raise ValueError(
                            f"structure.given_edges entry ({a},{b}) is not "
                            f"a valid i<j edge for p={self.graph.p}")
        if self.stream_window is not None and int(self.stream_window) < 1:
            raise ValueError(f"stream_window must be >= 1 sample (None "
                             f"disables it), got {self.stream_window!r}")
        if self.stream_discount is not None and not (
                0.0 < float(self.stream_discount) <= 1.0):
            raise ValueError(
                f"stream_discount must be in (0.0, 1.0] (None disables "
                f"forgetting), got {self.stream_discount!r}")

    # -------------------------------------------------------- conveniences
    @property
    def family_instance(self):
        """The registered :class:`ModelFamily` this plan names."""
        return get_family(self.family)

    @property
    def combiner_instances(self):
        """The registered :class:`Combiner` strategies, in plan order."""
        return tuple(get_combiner(n) for n in self.combiners)

    def replace(self, **changes) -> "Plan":
        """A new plan with ``changes`` applied (frozen-dataclass replace)."""
        return dataclasses.replace(self, **changes)

    def session(self, mesh=None):
        """Compile (or fetch the cached) :class:`EstimationSession`."""
        from .session import EstimationSession
        return EstimationSession.for_plan(self, mesh=mesh)

    # -------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        """Plain-JSON representation; exact inverse of :meth:`from_dict`."""
        return {
            "graph": {"p": self.graph.p,
                      "edges": [list(e) for e in self.graph.edges]},
            "family": self.family,
            "combiners": list(self.combiners),
            "include_singleton": self.include_singleton,
            "theta_fixed": (None if self.theta_fixed is None
                            else list(self.theta_fixed)),
            "n_iter": self.n_iter,
            "mesh": self.mesh,
            "precision": self.precision,
            "capacity": self.capacity,
            "admm_iters": self.admm_iters,
            "admm_init": self.admm_init,
            "admm_newton_iters": self.admm_newton_iters,
            "admm_rho": self.admm_rho,
            "faults": (None if self.faults is None
                       else self.faults.to_dict()),
            "stream_window": self.stream_window,
            "stream_discount": self.stream_discount,
            "telemetry": (None if self.telemetry is None
                          else self.telemetry.to_dict()),
            "structure": (None if self.structure is None
                          else self.structure.to_dict()),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Plan":
        g = d["graph"]
        graph = Graph(int(g["p"]),
                      tuple((int(a), int(b)) for a, b in g["edges"]))
        tf = d.get("theta_fixed")
        return cls(
            graph=graph,
            family=d.get("family", "ising"),
            combiners=tuple(d.get("combiners", ("diagonal",))),
            include_singleton=bool(d.get("include_singleton", True)),
            theta_fixed=None if tf is None else tuple(float(v) for v in tf),
            n_iter=int(d.get("n_iter", 40)),
            mesh=d.get("mesh"),
            precision=d.get("precision", "float32"),
            capacity=int(d.get("capacity", 64)),
            admm_iters=int(d.get("admm_iters", 30)),
            admm_init=d.get("admm_init", "diagonal"),
            admm_newton_iters=int(d.get("admm_newton_iters", 15)),
            admm_rho=float(d.get("admm_rho", 1.0)),
            faults=d.get("faults"),
            stream_window=(None if d.get("stream_window") is None
                           else int(d["stream_window"])),
            stream_discount=(None if d.get("stream_discount") is None
                             else float(d["stream_discount"])),
            telemetry=d.get("telemetry"),
            structure=d.get("structure"),
        )
